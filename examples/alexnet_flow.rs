//! AlexNet full flow: the paper's headline experiment (Tables 1-3, Fig 6).
//!
//! Runs CNN2Gate for AlexNet on all three evaluation boards: DSE (both
//! explorers), fit, synthesis-time model, latency simulation and the
//! per-layer Fig. 6 breakdown. With artifacts present it also times the
//! emulation mode (Table 1's CPU row).
//!
//! Run: `cargo run --release --example alexnet_flow`

use cnn2gate::dse::{brute, rl, RlConfig};
use cnn2gate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4, CYCLONE_V_5CSEMA5};
use cnn2gate::estimator::Thresholds;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::metrics;
use cnn2gate::onnx::zoo;
use cnn2gate::report::fig6;
use cnn2gate::runtime::Manifest;
use cnn2gate::session::{CompileJob, Session};
use cnn2gate::sim::simulate;
use cnn2gate::synth::Explorer;
use cnn2gate::util::table::fmt_duration;

fn main() -> anyhow::Result<()> {
    let graph = zoo::build("alexnet", false).unwrap();
    let flow = ComputationFlow::extract(&graph)?;
    let th = Thresholds::default();
    println!(
        "AlexNet: {:.2} GOp/frame, {} rounds\n",
        flow.gops(),
        flow.layers.len()
    );

    // one session, one 1×3 job: every board's synth report in one run
    let boards = [&CYCLONE_V_5CSEMA4, &CYCLONE_V_5CSEMA5, &ARRIA_10_GX1150];
    let session = Session::builder().build();
    let outcome = session.run(
        &CompileJob::builder()
            .model(graph)
            .devices(boards)
            .explorer(Explorer::BruteForce)
            .build()?,
    )?;
    for (rep, dev) in outcome.entries.iter().zip(boards) {
        println!("=== {} ===", dev.name);
        let bf = brute::explore(&flow, dev, th);
        let rl = rl::explore(&flow, dev, th, RlConfig::default());
        println!(
            "  BF-DSE: {:?} in {} ({} queries, modeled {})",
            bf.best,
            fmt_duration(bf.wall_seconds),
            bf.queries,
            fmt_duration(bf.modeled_seconds)
        );
        println!(
            "  RL-DSE: {:?} in {} ({} queries, modeled {})",
            rl.best,
            fmt_duration(rl.wall_seconds),
            rl.queries,
            fmt_duration(rl.modeled_seconds)
        );
        match (&rep.estimate, &rep.sim) {
            (Some(est), Some(sim)) => {
                println!(
                    "  fit: ALM {:.0}K ({:.0}%)  DSP {:.0} ({:.0}%)  RAM {:.0} ({:.0}%)  fmax {:.0} MHz",
                    est.alms / 1e3,
                    est.p_lut,
                    est.dsps,
                    est.p_dsp,
                    est.ram_blocks,
                    est.p_mem,
                    est.fmax_mhz
                );
                println!(
                    "  synthesis ≈ {}   latency {:.2} ms   {:.1} GOp/s   {:.3} GOp/s/DSP",
                    fmt_duration(rep.synthesis_minutes.unwrap() * 60.0),
                    sim.total_millis,
                    metrics::gops_per_s(sim.gops, sim.total_millis),
                    metrics::gops_per_dsp(
                        metrics::gops_per_s(sim.gops, sim.total_millis),
                        est.dsps
                    )
                );
            }
            _ => println!("  Does not fit"),
        }
        println!();
    }

    // Fig. 6 on the Arria 10 at the paper's option
    let sim = simulate(&flow, &ARRIA_10_GX1150, 16, 32);
    println!("{}", fig6(&sim).render());

    // Emulation mode (Table 1 CPU row) when artifacts exist and the
    // real PJRT backend is built (`--features pjrt`)
    let dir = std::path::Path::new("artifacts");
    if cnn2gate::runtime::Runtime::available() && dir.join("manifest.json").exists() {
        let manifest = Manifest::load(dir)?;
        if let Some(art) = manifest.model("alexnet") {
            let secs = cnn2gate::coordinator::pipeline::time_emulation_synthetic(art, 1)?;
            println!(
                "emulation mode (PJRT CPU): {} per frame (paper's Core-i7 row: 13 s)",
                fmt_duration(secs)
            );
        }
    } else {
        println!("(run `make artifacts` to add the emulation-mode timing)");
    }
    Ok(())
}
