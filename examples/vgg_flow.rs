//! VGG-16 full flow (Tables 1 and 4): the paper's "CNN2Gate performs
//! better for larger neural networks" experiment.
//!
//! Run: `cargo run --release --example vgg_flow`

use cnn2gate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA5};
use cnn2gate::estimator::estimate;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::metrics;
use cnn2gate::onnx::zoo;
use cnn2gate::report::{baselines, comparison_table};
use cnn2gate::session::{CompileJob, Session};
use cnn2gate::sim::simulate;
use cnn2gate::synth::Explorer;
use cnn2gate::util::table::fmt_duration;

fn main() -> anyhow::Result<()> {
    let graph = zoo::build("vgg16", false).unwrap();
    let flow = ComputationFlow::extract(&graph)?;
    println!(
        "VGG-16: {:.1} GOp/frame, {} conv + {} fc rounds\n",
        flow.gops(),
        flow.conv_rounds(),
        flow.fc_rounds()
    );

    // one session, one 1×2 job: the new front door for the whole flow
    let session = Session::builder().build();
    let outcome = session.run(
        &CompileJob::builder()
            .model(graph)
            .devices([&CYCLONE_V_5CSEMA5, &ARRIA_10_GX1150])
            .explorer(Explorer::Reinforcement)
            .build()?,
    )?;
    for rep in &outcome.entries {
        match (&rep.estimate, &rep.sim) {
            (Some(_est), Some(sim)) => {
                let gops = metrics::gops_per_s(sim.gops, sim.total_millis);
                println!(
                    "{}: H_best {:?}  latency {}  {:.1} GOp/s  (efficiency {:.0}% of lane peak)",
                    rep.device,
                    rep.option().unwrap(),
                    fmt_duration(sim.total_millis / 1e3),
                    gops,
                    100.0 * sim.efficiency()
                );
            }
            _ => println!("{}: does not fit", rep.device),
        }
    }

    // AlexNet-vs-VGG efficiency claim (§5: "CNN2Gate is performing
    // better for larger neural networks such as VGG")
    let alex = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap())?;
    let asim = simulate(&alex, &ARRIA_10_GX1150, 16, 32);
    let vsim = simulate(&flow, &ARRIA_10_GX1150, 16, 32);
    let a_gops = metrics::gops_per_s(asim.gops, asim.total_millis);
    let v_gops = metrics::gops_per_s(vsim.gops, vsim.total_millis);
    println!(
        "\nthroughput: AlexNet {a_gops:.1} GOp/s vs VGG-16 {v_gops:.1} GOp/s ({}x)",
        (v_gops / a_gops * 10.0).round() / 10.0
    );

    // Table 4
    let est = estimate(&alex, &ARRIA_10_GX1150, 16, 32);
    println!(
        "\n{}",
        comparison_table(
            "Table 4: Comparison to existing works, VGG-16 (Ni,Nl)=(16,32)",
            &baselines::vgg16(),
            &vsim,
            (est.alms, est.p_lut),
            (est.dsps, est.p_dsp),
        )
        .render()
    );
    Ok(())
}
