//! DSE ablation: brute force vs reinforcement learning (paper §4.3-4.4,
//! Table 2) across models, devices and RL seeds.
//!
//! Demonstrates the paper's two claims: (1) RL-DSE finds the same H_best
//! as the exhaustive search, (2) with fewer estimator queries — ~25%
//! faster at the Intel-compiler time scale.
//!
//! Run: `cargo run --release --example dse_compare`

use cnn2gate::dse::{brute, rl, RlConfig};
use cnn2gate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4, CYCLONE_V_5CSEMA5};
use cnn2gate::estimator::Thresholds;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::zoo;
use cnn2gate::util::table::Table;

fn main() -> anyhow::Result<()> {
    let th = Thresholds::default();
    let mut t = Table::new(
        "BF-DSE vs RL-DSE (modeled minutes at Intel-compiler query cost)",
        &["Model", "Device", "BF best", "RL best", "BF q", "RL q", "BF min", "RL min", "speedup"],
    );
    let mut agree = 0usize;
    let mut total = 0usize;
    for model in ["lenet5", "alexnet", "vgg16"] {
        let flow = ComputationFlow::extract(&zoo::build(model, false).unwrap())?;
        for dev in [&CYCLONE_V_5CSEMA4, &CYCLONE_V_5CSEMA5, &ARRIA_10_GX1150] {
            let bf = brute::explore(&flow, dev, th);
            let rl_res = rl::explore(&flow, dev, th, RlConfig::default());
            total += 1;
            if bf.best == rl_res.best {
                agree += 1;
            }
            t.row(&[
                model.to_string(),
                dev.name.to_string(),
                format!("{:?}", bf.best),
                format!("{:?}", rl_res.best),
                bf.queries.to_string(),
                rl_res.queries.to_string(),
                format!("{:.1}", bf.modeled_seconds / 60.0),
                format!("{:.1}", rl_res.modeled_seconds / 60.0),
                format!("{:.0}%", 100.0 * (1.0 - rl_res.modeled_seconds / bf.modeled_seconds)),
            ]);
        }
    }
    println!("{}", t.render());
    println!("RL-DSE matched BF-DSE H_best on {agree}/{total} (model, device) pairs");

    // Seed sensitivity: the paper's time-limited episodes make RL
    // stochastic; check H_best stability across seeds on the Arria 10.
    let flow = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap())?;
    let bf = brute::explore(&flow, &ARRIA_10_GX1150, th);
    let mut hits = 0;
    let seeds = 25;
    let mut queries_sum = 0usize;
    for seed in 0..seeds {
        let cfg = RlConfig {
            seed: seed as u64,
            ..RlConfig::default()
        };
        let r = rl::explore(&flow, &ARRIA_10_GX1150, th, cfg);
        queries_sum += r.queries;
        if r.best == bf.best {
            hits += 1;
        }
    }
    println!(
        "seed sweep (AlexNet on Arria 10): RL found the BF optimum {hits}/{seeds} times, avg {:.1} queries vs BF's {}",
        queries_sum as f64 / seeds as f64,
        bf.queries
    );
    Ok(())
}
