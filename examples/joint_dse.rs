//! Joint parallelism + quantization exploration — the extension the paper
//! proposes in §4.4: "The RL-DSE algorithm would be more valuable if it
//! could be exploited in conjunction to the reinforcement learning
//! quantization algorithms such as ReLeQ."
//!
//! One agent explores (N_i, N_l, m_w) with the HAQ-style composite
//! reward β·F_avg − λ·E_q(m_w); sweeping λ exposes the
//! utilization-vs-fidelity frontier.
//!
//! Run: `cargo run --release --example joint_dse`

use cnn2gate::dse::joint::{self, JointConfig};
use cnn2gate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA5};
use cnn2gate::estimator::Thresholds;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::zoo;
use cnn2gate::util::table::Table;

fn main() -> anyhow::Result<()> {
    let graph = zoo::build("alexnet", true).unwrap();
    let flow = ComputationFlow::extract(&graph)?;

    // the quantization-error curve the reward consumes
    let curve = joint::quant_error_curve(&graph)?;
    println!("weight quantization error curve (normalized):");
    for (m, e) in &curve {
        let bar = "#".repeat((e * 40.0).round() as usize);
        println!("  m_w={m}: {e:.3} {bar}");
    }

    for dev in [&CYCLONE_V_5CSEMA5, &ARRIA_10_GX1150] {
        let mut t = Table::new(
            format!("joint DSE on {}: λ sweep (8-seed vote)", dev.name),
            &["lambda", "H_best (Ni,Nl,m_w)", "avg queries", "modeled time"],
        );
        for lambda in [0.0, 0.25, 0.5, 1.0, 2.0] {
            // vote across seeds: exploration is stochastic by design
            let mut counts: std::collections::HashMap<(usize, usize, i8), usize> =
                std::collections::HashMap::new();
            let mut queries = 0usize;
            let mut modeled = 0.0;
            let seeds = 8;
            for seed in 0..seeds {
                let cfg = JointConfig {
                    lambda,
                    seed,
                    ..JointConfig::default()
                };
                let r = joint::explore(&graph, &flow, dev, Thresholds::default(), cfg)?;
                queries += r.queries;
                modeled += r.modeled_seconds;
                if let Some(b) = r.best {
                    *counts.entry(b).or_default() += 1;
                }
            }
            let winner = counts
                .into_iter()
                .max_by_key(|(_, c)| *c)
                .map(|(b, c)| format!("{b:?} ({c}/{seeds})"))
                .unwrap_or_else(|| "none".into());
            t.row(&[
                format!("{lambda:.2}"),
                winner,
                format!("{:.1}", queries as f64 / seeds as f64),
                cnn2gate::util::table::fmt_duration(modeled / seeds as f64),
            ]);
        }
        println!("\n{}", t.render());
    }
    println!(
        "reading: λ=0 reduces to pure RL-DSE (utilization only); larger λ\n\
         pushes m_w toward {} fraction bits while keeping the same\n\
         parallelism corner — the joint agent recovers both knobs in one\n\
         exploration, as §4.4 anticipated.",
        joint::M_MAX
    );
    Ok(())
}
