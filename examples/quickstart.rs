//! Quickstart: the 60-second tour of the public API.
//!
//! Parse a model, extract its computation flow, explore the design space
//! for a small FPGA, and print the predicted latency — the minimal
//! version of what `cnn2gate synth` does.
//!
//! Run: `cargo run --example quickstart`

use cnn2gate::dse::{brute, OptionSpace};
use cnn2gate::estimator::{device, estimate, synthesis_minutes, Thresholds};
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::zoo;
use cnn2gate::quant::{self, QuantSpec};
use cnn2gate::session::{CompileJob, Session};
use cnn2gate::sim::simulate;
use cnn2gate::synth::Explorer;

fn main() -> anyhow::Result<()> {
    // 1. A model: from the zoo here; onnx::parse_file reads the
    //    ONNX-subset JSON that `make artifacts` exports.
    let graph = zoo::build("lenet5", true).expect("zoo model");
    graph.validate().map_err(anyhow::Error::msg)?;
    println!("parsed {}: {} params", graph.name, graph.param_count());

    // 2. Computation flow: the fused conv(+relu)(+pool) / FC rounds the
    //    pipelined architecture executes (paper §4.1).
    let flow = ComputationFlow::extract(&graph)?;
    println!(
        "flow: {} rounds ({} conv + {} fc), {:.4} GOp/frame",
        flow.layers.len(),
        flow.conv_rounds(),
        flow.fc_rounds(),
        flow.gops()
    );

    // 3. Apply the user-given fixed-point quantization (paper §4.2).
    let quant = quant::apply(&graph, &QuantSpec::default()).map_err(anyhow::Error::msg)?;
    println!(
        "quantized {} weight tensors, worst |err| {:.4}",
        quant.tensors.len(),
        quant.worst_abs_err()
    );

    // 4. Design-space exploration against the resource estimator.
    let dev = device::find("5csema5").unwrap();
    let space = OptionSpace::from_flow(&flow);
    println!("option space on {}: {:?} x {:?}", dev.name, space.ni, space.nl);
    let dse = brute::explore(&flow, dev, Thresholds::default());
    let (ni, nl) = dse.best.expect("lenet5 fits the 5CSEMA5");
    println!(
        "H_best = ({ni},{nl}) after {} estimator queries (modeled {:.1} min)",
        dse.queries,
        dse.modeled_seconds / 60.0
    );

    // 5. Fit + latency prediction.
    let est = estimate(&flow, dev, ni, nl);
    let sim = simulate(&flow, dev, ni, nl);
    println!(
        "fit: ALM {:.0}% DSP {:.0}% RAM {:.0}% @ {:.0} MHz, synthesis ≈ {:.0} min",
        est.p_lut,
        est.p_dsp,
        est.p_mem,
        est.fmax_mhz,
        synthesis_minutes(&est, dev)
    );
    println!(
        "predicted latency: {:.3} ms/frame ({:.2} GOp/s)",
        sim.total_millis,
        sim.gops / (sim.total_millis / 1e3)
    );

    // 6. Or all of the above through the one front door: a Session owns
    //    the evaluator/cache/fidelity machinery, a CompileJob names the
    //    models × devices, and `run` returns the whole outcome (here a
    //    1×1 job — the same call scales to fleet fits and M×N sweeps).
    let session = Session::builder().build();
    let job = CompileJob::builder()
        .model(zoo::build("lenet5", true).expect("zoo model"))
        .device(dev)
        .explorer(Explorer::BruteForce)
        .quantize(QuantSpec::default())
        .build()?;
    let rep = session.run(&job)?.into_synth_report().expect("1x1 job");
    println!(
        "session front door agrees: H_best {:?}, {:.3} ms/frame",
        rep.option().expect("fits"),
        rep.latency_ms().expect("fits")
    );
    Ok(())
}
