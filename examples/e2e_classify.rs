//! End-to-end driver: every layer of the system composed on a real small
//! workload (EXPERIMENTS.md §E2E).
//!
//!  1. parse LeNet-5 from the ONNX-subset file `make artifacts` exported
//!     (front-end parser + external weight data),
//!  2. apply the fixed-point quantization (paper §4.2),
//!  3. DSE + fit + simulated-FPGA latency on Cyclone V and Arria 10
//!     (the paper's headline metric),
//!  4. serve a synthetic digit dataset through the batched PJRT
//!     emulation server — float32 and int8 variants — verifying the
//!     Rust-parsed weights reproduce the Python golden bit-for-bit and
//!     that the int8 datapath tracks float top-1,
//!  5. report latency/throughput statistics.
//!
//! Run: `make artifacts && cargo run --release --example e2e_classify`

use anyhow::{anyhow, Context, Result};

use cnn2gate::coordinator::{InferenceServer, ServiceConfig};
use cnn2gate::dse::brute;
use cnn2gate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA5};
use cnn2gate::estimator::Thresholds;
use cnn2gate::ir::{ComputationFlow, DType};
use cnn2gate::onnx::parser;
use cnn2gate::quant::{self, QuantSpec};
use cnn2gate::runtime::{load_golden, Manifest, Tensor};
use cnn2gate::sim::simulate;
use cnn2gate::util::rng::Rng;

const N_IMAGES: usize = 64;

/// Synthetic MNIST-like frame: a bright blob on a noisy background whose
/// position depends on the class, so float and int8 classifiers have
/// structure to agree on.
fn synth_digit(rng: &mut Rng, class: usize) -> Vec<f32> {
    let (h, w) = (28usize, 28usize);
    let mut img = vec![0f32; h * w];
    for v in img.iter_mut() {
        *v = (rng.normal() * 0.1) as f32;
    }
    let cx = 6 + (class % 5) * 4;
    let cy = 6 + (class / 5) * 12;
    for dy in 0..8 {
        for dx in 0..8 {
            let (x, y) = (cx + dx, cy + dy);
            if x < w && y < h {
                let d = ((dx as f32 - 3.5).powi(2) + (dy as f32 - 3.5).powi(2)).sqrt();
                img[y * w + x] += (2.0 - d * 0.4).max(0.0);
            }
        }
    }
    img
}

fn argmax_f32(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

fn argmax_i32(xs: &[i32]) -> usize {
    xs.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap()
}

fn main() -> Result<()> {
    let art_dir = std::path::Path::new("artifacts");
    let manifest = Manifest::load(art_dir).context("run `make artifacts` first")?;

    // ---- 1. front-end parse of the exported ONNX-subset model ---------
    let model_json = art_dir.join("models/lenet5.json");
    let graph = parser::parse_file(&model_json)?;
    let flow = ComputationFlow::extract(&graph).map_err(|e| anyhow!("{e}"))?;
    println!(
        "[1] parsed {} from {}: {} rounds, {:.4} GOp/frame, weights resident: {}",
        graph.name,
        model_json.display(),
        flow.layers.len(),
        flow.gops(),
        graph.has_weights()
    );

    // ---- 2. quantization application -----------------------------------
    let qrep = quant::apply(&graph, &QuantSpec::default()).map_err(|e| anyhow!("{e}"))?;
    println!(
        "[2] quantized {} weight tensors (worst |err| {:.5}, worst saturation {:.2}%)",
        qrep.tensors.len(),
        qrep.worst_abs_err(),
        100.0 * qrep.worst_sat_ratio()
    );

    // ---- 3. DSE + fit + simulated FPGA latency -------------------------
    println!("[3] hardware fits:");
    for dev in [&CYCLONE_V_5CSEMA5, &ARRIA_10_GX1150] {
        let dse = brute::explore(&flow, dev, Thresholds::default());
        match dse.best {
            Some((ni, nl)) => {
                let sim = simulate(&flow, dev, ni, nl);
                println!(
                    "    {}: ({ni},{nl})  {:.3} ms/frame simulated",
                    dev.name, sim.total_millis
                );
            }
            None => println!("    {}: does not fit", dev.name),
        }
    }

    // ---- 4. emulation servers (float + int8) ---------------------------
    // Golden check first: the weights parsed from the ONNX-subset file
    // must reproduce the Python-side golden output through PJRT.
    let art = manifest.model("lenet5").ok_or_else(|| anyhow!("lenet5 artifact"))?;
    let golden = load_golden(art.golden.as_ref().unwrap())?;
    let mut parsed_weights = Vec::new();
    for spec in &art.params {
        let init = graph
            .initializers
            .get(&spec.name)
            .ok_or_else(|| anyhow!("parsed model missing {}", spec.name))?;
        parsed_weights.push(Tensor::F32(
            spec.shape.clone(),
            init.data.clone().unwrap(),
        ));
    }
    let server = InferenceServer::start(art, parsed_weights.clone(), ServiceConfig::default())?;
    let reply = server.infer(golden.input.clone())?;
    let max_err = reply
        .output
        .as_f32()
        .unwrap()
        .iter()
        .zip(golden.expected.as_f32().unwrap())
        .map(|(g, w)| (g - w).abs())
        .fold(0f32, f32::max);
    println!(
        "[4] golden replay through Rust-parsed weights: max |err| = {max_err:.2e} {}",
        if max_err < 1e-4 { "(OK)" } else { "(MISMATCH!)" }
    );
    assert!(max_err < 1e-4, "parser→PJRT numerics broken");

    // int8 server with the quantized-artifact weights
    let art8 = manifest
        .model("lenet5_int8")
        .ok_or_else(|| anyhow!("lenet5_int8 artifact"))?;
    let golden8 = load_golden(art8.golden.as_ref().unwrap())?;
    let server8 = InferenceServer::start(art8, golden8.params.clone(), ServiceConfig::default())?;

    // classify the synthetic dataset on both datapaths
    let mut rng = Rng::new(2024);
    let m_in = 4i8; // DEFAULT_QCFG m_in
    let mut agreement = 0usize;
    let mut blob_hits_f32 = vec![0usize; 10];
    for i in 0..N_IMAGES {
        let class = i % 10;
        let img = synth_digit(&mut rng, class);
        let t_f = Tensor::F32(vec![1, 28, 28], img.clone());
        let codes: Vec<i32> = img
            .iter()
            .map(|&x| {
                ((x as f64 * 2f64.powi(m_in as i32)).round() as i64).clamp(-128, 127) as i32
            })
            .collect();
        let t_q = Tensor::I32(vec![1, 28, 28], codes);
        let rf = server.infer(t_f)?;
        let rq = server8.infer(t_q)?;
        let cf = argmax_f32(rf.output.as_f32().unwrap());
        let cq = argmax_i32(rq.output.as_i32().unwrap());
        if cf == cq {
            agreement += 1;
        }
        blob_hits_f32[cf] += 1;
    }
    let stats_f = server.shutdown();
    let stats_q = server8.shutdown();
    println!(
        "    float/int8 top-1 agreement: {agreement}/{N_IMAGES} ({:.0}%)",
        100.0 * agreement as f64 / N_IMAGES as f64
    );
    println!(
        "    class histogram (float head): {:?}",
        blob_hits_f32
    );

    // ---- 5. latency report ---------------------------------------------
    println!("[5] emulation-server latency (PJRT CPU, batch ≤ 8):");
    println!(
        "    float32: {} served, exec p50 {:.2} ms p99 {:.2} ms | e2e p50 {:.2} ms",
        stats_f.served, stats_f.exec.p50_ms, stats_f.exec.p99_ms, stats_f.e2e.p50_ms
    );
    println!(
        "    int8   : {} served, exec p50 {:.2} ms p99 {:.2} ms | e2e p50 {:.2} ms",
        stats_q.served, stats_q.exec.p50_ms, stats_q.exec.p99_ms, stats_q.e2e.p50_ms
    );
    let throughput = stats_f.served as f64 / (stats_f.exec.mean_ms / 1e3 * stats_f.served as f64);
    println!("    float32 throughput ≈ {throughput:.0} frames/s");
    println!("\nE2E OK — all layers composed (parser → quant → DSE → sim → PJRT serving).");
    let _ = DType::F32; // keep the import obviously used
    Ok(())
}
