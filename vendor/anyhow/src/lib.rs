//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline build image has no crates.io registry access, so the
//! workspace vendors the subset of the `anyhow` 1.x API this repo
//! actually uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]
//! macros and the [`Context`] extension trait. Error chains are
//! flattened into the message at construction time ("context: cause"),
//! which preserves the `{e}` / `{e:#}` rendering the binaries rely on.
//!
//! Swap this for the real crate by pointing the `anyhow` dependency in
//! `rust/Cargo.toml` back at the registry — no source changes needed.

use std::fmt::{self, Display};

/// A flattened error message, API-compatible with `anyhow::Error` for
/// the operations this workspace performs on it.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message (mirrors `anyhow::Error::msg`).
    pub fn msg<M: Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }

    /// Construct from a concrete error value (mirrors `anyhow::Error::new`).
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error::msg(error)
    }

    /// Wrap with additional context, outermost first.
    pub fn context<C: Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::msg(error)
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::{Display, Error};

    /// Sealed dispatch helper so [`super::Context`] works both for
    /// `Result<T, E: std::error::Error>` and for `Result<T, Error>`
    /// (the same trick the real crate uses).
    pub trait StdError {
        fn ext_context<C: Display>(self, context: C) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: Display>(self, context: C) -> Error {
            Error::msg(format!("{context}: {self}"))
        }
    }

    impl StdError for Error {
        fn ext_context<C: Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, mirroring `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn macro_formats_and_displays() {
        let n = 3;
        let e = anyhow!("bad value {n}");
        assert_eq!(e.to_string(), "bad value 3");
        assert_eq!(format!("{e:#}"), "bad value 3");
    }

    #[test]
    fn bail_early_returns() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("nope ({})", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "nope (7)");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let e: Result<String, std::io::Error> = Err(io_err());
            Ok(e?)
        }
        assert!(f().unwrap_err().to_string().contains("missing thing"));
    }

    #[test]
    fn context_wraps_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: missing thing");

        let already: Result<()> = Err(anyhow!("inner"));
        let e = already.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");

        let none: Option<u8> = None;
        assert_eq!(none.context("absent").unwrap_err().to_string(), "absent");
    }
}
