//! Cache-store scale bench (the PR-9 tentpole's perf gate): generate a
//! 100 000-entry evaluation memo across 10 tenant shards, then measure
//! the three store paths against the legacy v5 single-file cache:
//!
//!   * cold full save (one base rewrite per shard) and streamed load;
//!   * the legacy whole-document save/load baseline;
//!   * the differential win — appending ONE new entry to the 100k-entry
//!     store must cost bytes proportional to the entry, not the corpus.
//!
//! Shape gates (fatal at finish()):
//!   * the store round-trips bit-identically: the incremental history
//!     (full save → +1 delta append → compaction) reproduces byte-for-
//!     byte the base files of a single-shot save of the same memo;
//!   * the 1-entry delta append writes >100× fewer bytes than the v5
//!     whole-file rewrite (the asymptotic I/O gain, measured ~10⁵).
//!
//! Writes `BENCH_PR9.json` (gitignored) for `tools/perf_compare.sh`:
//! wall times are lower-is-better keys, the I/O gain is higher-is-
//! better, raw byte counts ride along as informational.

mod common;

use std::time::Instant;

use cnn2gate::dse::{CacheStore, EvalCache, EvalRequest, Fidelity, TenantId};
use cnn2gate::estimator::device::CYCLONE_V_5CSEMA5;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::zoo;
use cnn2gate::util::json::{Json, JsonObj};
use common::Harness;

const TENANTS: usize = 10;
const NI_MAX: usize = 25;
const NL_MAX: usize = 25;
const BATCH_MAX: usize = 16;
// 10 tenants x 25 x 25 options x 16 batch sizes
const ENTRIES: usize = TENANTS * NI_MAX * NL_MAX * BATCH_MAX;

/// Sum of the store's delta-log sizes (the bytes a differential save
/// actually wrote).
fn delta_bytes(dir: &std::path::Path) -> u64 {
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().ends_with(".delta.jsonl"))
                .filter_map(|e| e.metadata().ok().map(|m| m.len()))
                .sum()
        })
        .unwrap_or(0)
}

/// Names and bytes of every canonical (non-delta) store file.
fn canonical_files(dir: &std::path::Path) -> Vec<(String, Vec<u8>)> {
    let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| !e.file_name().to_string_lossy().ends_with(".delta.jsonl"))
        .filter(|e| e.file_name().to_string_lossy() != "store.lock")
        .map(|e| {
            let name = e.file_name().to_string_lossy().into_owned();
            (name, std::fs::read(e.path()).unwrap())
        })
        .collect();
    out.sort();
    out
}

fn main() {
    let mut h = Harness::new();
    let graph = zoo::build("tiny", false).expect("zoo model 'tiny'");
    let flow = ComputationFlow::extract(&graph).expect("tiny flow");
    let dev = &CYCLONE_V_5CSEMA5;

    let tmp = std::env::temp_dir().join(format!("cnn2gate-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    let store_dir = tmp.join("store"); // incremental history
    let fresh_dir = tmp.join("fresh"); // single-shot history
    let legacy_path = tmp.join("legacy.json");

    // -- generate: 100k distinct (tenant, ni, nl, batch) evaluations
    let t0 = Instant::now();
    let cache = EvalCache::new();
    for t in 0..TENANTS {
        let tenant = TenantId::of(&format!("tenant-{t}"));
        for ni in 1..=NI_MAX {
            for nl in 1..=NL_MAX {
                for b in 1..=BATCH_MAX {
                    cache.get_or_compute(
                        &flow,
                        dev,
                        ni,
                        nl,
                        EvalRequest::at(Fidelity::Analytical).tenant(tenant).batched(b),
                    );
                }
            }
        }
    }
    let generate_s = t0.elapsed().as_secs_f64();
    println!("bench store/generate({ENTRIES} entries) {:>18} {generate_s:.3} s wall", "");
    h.check(cache.stats().entries == ENTRIES, &format!("{ENTRIES} distinct memo entries"));

    // -- cold full save: one base rewrite per (tenant, model) shard
    let opened = CacheStore::open(&store_dir);
    let t0 = Instant::now();
    let saved = opened.store.save(&cache).unwrap();
    let cold_save_s = t0.elapsed().as_secs_f64();
    let store_bytes: u64 = canonical_files(&store_dir).iter().map(|(_, b)| b.len() as u64).sum();
    println!(
        "bench store/cold_save({} shards, {:.1} MB) {:>8} {cold_save_s:.3} s wall",
        saved.rewritten,
        store_bytes as f64 / 1e6,
        ""
    );
    h.check(saved.rewritten == TENANTS, "one fresh shard per tenant");
    h.check(saved.entries == ENTRIES, "every safe entry persisted");

    // -- streamed load: line-per-entry, no whole-document tree
    let t0 = Instant::now();
    let reopened = CacheStore::open(&store_dir);
    let load_s = t0.elapsed().as_secs_f64();
    println!("bench store/load({ENTRIES} entries) {:>22} {load_s:.3} s wall", "");
    h.check(reopened.warnings.is_empty(), "scale load is warning-free");
    h.check(reopened.cache.stats().entries == ENTRIES, "scale load is complete");

    // -- legacy v5 baseline: whole-document save + load
    let t0 = Instant::now();
    let legacy_written = cache.save(&legacy_path).unwrap();
    let legacy_save_s = t0.elapsed().as_secs_f64();
    let legacy_bytes = std::fs::metadata(&legacy_path).unwrap().len();
    let t0 = Instant::now();
    let (legacy_cache, legacy_warn) = EvalCache::load_or_cold(&legacy_path);
    let legacy_load_s = t0.elapsed().as_secs_f64();
    println!(
        "bench legacy/save+load({:.1} MB) {:>15} {legacy_save_s:.3} s / {legacy_load_s:.3} s wall",
        legacy_bytes as f64 / 1e6,
        ""
    );
    h.check(legacy_written == ENTRIES, "legacy baseline saved every entry");
    h.check(
        legacy_warn.is_none() && legacy_cache.stats().entries == ENTRIES,
        "legacy baseline loads clean",
    );

    // -- the differential win: ONE new evaluation lands in the loaded
    // store as a single appended delta record, while the legacy format
    // re-serializes the whole 100k-entry world
    reopened.cache.get_or_compute(
        &flow,
        dev,
        NI_MAX + 1,
        NL_MAX + 1,
        EvalRequest::at(Fidelity::Analytical).tenant(TenantId::of("tenant-0")),
    );
    let t0 = Instant::now();
    let inc = reopened.store.save(&reopened.cache).unwrap();
    let append_s = t0.elapsed().as_secs_f64();
    let appended_bytes = delta_bytes(&store_dir);
    println!(
        "bench store/append_1({appended_bytes} B vs {:.1} MB rewrite) {append_s:.3} s wall",
        legacy_bytes as f64 / 1e6
    );
    h.check(
        inc.appended == 1 && inc.rewritten == 0 && inc.tombstones == 0,
        "one new entry appends exactly one delta record",
    );
    let io_gain = legacy_bytes as f64 / (appended_bytes.max(1) as f64);
    h.check(
        io_gain > 100.0,
        &format!("delta append beats the whole-file rewrite {io_gain:.0}x on bytes written"),
    );

    // -- bit-identical round-trip: compact the incremental history,
    // then save the same memo single-shot; every canonical file agrees
    let compacted = reopened.store.compact_all().unwrap();
    h.check(compacted == 1, "only the appended shard needed compaction");
    let fresh = CacheStore::open(&fresh_dir);
    fresh.store.save(&reopened.cache).unwrap();
    let a = canonical_files(&store_dir);
    let b = canonical_files(&fresh_dir);
    h.check(
        a == b,
        "100k store round-trips through shard+delta+compaction bit-identically",
    );

    // -- machine-readable PR-9 perf record
    {
        let mut store = JsonObj::new();
        store.insert("entries", ENTRIES.into());
        store.insert("shards", TENANTS.into());
        store.insert("generate_seconds", generate_s.into());
        store.insert("cold_save_seconds", cold_save_s.into());
        store.insert("load_seconds", load_s.into());
        store.insert("bytes", (store_bytes as i64).into());
        let mut legacy = JsonObj::new();
        legacy.insert("save_seconds", legacy_save_s.into());
        legacy.insert("load_seconds", legacy_load_s.into());
        legacy.insert("bytes", (legacy_bytes as i64).into());
        let mut delta = JsonObj::new();
        delta.insert("append_seconds", append_s.into());
        delta.insert("appended_bytes", (appended_bytes as i64).into());
        delta.insert("io_gain", io_gain.into());
        let mut doc = JsonObj::new();
        doc.insert("format", "cnn2gate-bench-pr9".into());
        doc.insert("store", Json::Obj(store));
        doc.insert("legacy", Json::Obj(legacy));
        doc.insert("delta", Json::Obj(delta));
        let path = std::path::Path::new("BENCH_PR9.json");
        std::fs::write(path, Json::Obj(doc).to_string_pretty()).unwrap();
        println!("perf record written to {}", path.display());
    }

    std::fs::remove_dir_all(&tmp).ok();
    h.finish();
}
