//! Shared bench harness (criterion is not in the offline crate set).
//!
//! `bench(name, iters, f)` reports min/median/mean wall time per
//! iteration; `check(cond, msg)` records paper-shape assertions and
//! `finish()` exits non-zero if any failed, so `cargo bench` doubles as a
//! reproduction gate.

use std::time::Instant;

pub struct Harness {
    failures: Vec<String>,
    checks: usize,
}

impl Default for Harness {
    fn default() -> Self {
        Self::new()
    }
}

impl Harness {
    pub fn new() -> Self {
        Harness {
            failures: Vec::new(),
            checks: 0,
        }
    }

    /// Time `f` over `iters` iterations (after one warm-up) and print a
    /// criterion-style line. Returns median seconds per iteration.
    pub fn bench<T>(&mut self, name: &str, iters: usize, mut f: impl FnMut() -> T) -> f64 {
        std::hint::black_box(f()); // warm-up
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
        println!(
            "bench {name:<44} iters {iters:>4}  min {:>10}  median {:>10}  mean {:>10}",
            fmt_t(samples[0]),
            fmt_t(median),
            fmt_t(mean)
        );
        median
    }

    /// Paper-shape assertion: recorded, not fatal until finish().
    pub fn check(&mut self, cond: bool, msg: &str) {
        self.checks += 1;
        if cond {
            println!("  ✓ {msg}");
        } else {
            println!("  ✗ {msg}");
            self.failures.push(msg.to_string());
        }
    }

    /// Shape check with a relative tolerance: |got/want - 1| <= tol.
    pub fn check_close(&mut self, got: f64, want: f64, tol: f64, what: &str) {
        let rel = (got / want - 1.0).abs();
        self.check(
            rel <= tol,
            &format!("{what}: got {got:.3}, paper {want:.3} (rel {:.0}%, tol {:.0}%)", rel * 100.0, tol * 100.0),
        );
    }

    pub fn finish(self) {
        if self.failures.is_empty() {
            println!("\nall {} shape checks passed", self.checks);
        } else {
            eprintln!(
                "\n{}/{} shape checks FAILED:",
                self.failures.len(),
                self.checks
            );
            for f in &self.failures {
                eprintln!("  - {f}");
            }
            std::process::exit(1);
        }
    }
}

fn fmt_t(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}
