//! Hot-path microbenches — the §Perf targets of DESIGN.md §9 (L3):
//!   estimator query        < 10 µs
//!   full DSE sweep         < 5 s wall (it's actually ~ms)
//!   simulator              ≥ 10 M simulated cycles/s (stepped mode)
//!   JSON parse             model-file scale in ms
//! plus PJRT dispatch overhead when artifacts are present.

mod common;

use cnn2gate::coordinator::pipeline;
use cnn2gate::dse::{brute, eval, EvalCache, Evaluator, Fidelity};
use cnn2gate::estimator::device::ARRIA_10_GX1150;
use cnn2gate::estimator::{estimate, Thresholds};
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::zoo;
use cnn2gate::runtime::Manifest;
use cnn2gate::sim::{step_round, RoundWork};
use cnn2gate::util::json::Json;
use common::Harness;

fn main() {
    let mut h = Harness::new();
    let flow = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();

    // estimator query
    let q = h.bench("estimator/query", 10_000, || {
        estimate(&flow, &ARRIA_10_GX1150, 16, 32)
    });
    h.check(q < 10e-6, &format!("estimator query {:.2} µs < 10 µs", q * 1e6));

    // full BF sweep — sequential seed path, the compute reference
    let sweep = h.bench("dse/bf_full_sweep (seq)", 1000, || {
        brute::explore_seq(&flow, &ARRIA_10_GX1150, Thresholds::default())
    });
    h.check(sweep < 5.0, "full DSE sweep < 5 s");

    // pooled + memoized sweep: the first call computes each candidate
    // once, every repeat is served from the eval memo
    let ev = Evaluator::new(eval::default_threads());
    brute::explore_with(&ev, &flow, &ARRIA_10_GX1150, Thresholds::default());
    let warm = h.bench("dse/bf_full_sweep (pool, warm memo)", 1000, || {
        brute::explore_with(&ev, &flow, &ARRIA_10_GX1150, Thresholds::default())
    });
    h.check(warm < 5.0, "warm pooled sweep < 5 s");

    // memo-hit fast path: one lookup + Arc clone, no estimator call
    let hit = h.bench("eval/cache_hit", 10_000, || {
        ev.evaluate(&flow, &ARRIA_10_GX1150, 16, 32, Fidelity::Analytical)
    });
    h.check(hit < 10e-6, &format!("memo hit {:.2} µs < 10 µs", hit * 1e6));

    // persistent memo: save/load a grid-sized cache file and warm-start
    // an evaluator from it (the `--cache-file` path of dse/fit-fleet/sweep)
    let cache_path = std::env::temp_dir().join(format!(
        "cnn2gate-bench-cache-{}.json",
        std::process::id()
    ));
    let entries = ev.cache().stats().entries;
    let save_t = h.bench("evalcache/save(grid)", 200, || {
        ev.cache().save(&cache_path).unwrap()
    });
    let load_t = h.bench("evalcache/load(grid)", 200, || {
        EvalCache::load(&cache_path).unwrap()
    });
    h.check(save_t < 50e-3, &format!("cache save ({entries} entries) < 50 ms"));
    h.check(load_t < 50e-3, &format!("cache load ({entries} entries) < 50 ms"));
    let warm_start = Evaluator::with_cache(
        eval::default_threads(),
        std::sync::Arc::new(EvalCache::load(&cache_path).unwrap()),
    );
    let (_, disk_hit) = warm_start.evaluate(&flow, &ARRIA_10_GX1150, 16, 32, Fidelity::Analytical);
    h.check(disk_hit, "disk-loaded cache serves the hot option without recompute");
    std::fs::remove_file(&cache_path).ok();

    // stepped simulator throughput
    let work = RoundWork {
        pixels: 729,
        groups: 6,
        red_steps: 100,
        bytes_per_step: 16,
        ddr_bytes_per_cycle: 40.0,
        out_bytes: 32,
    };
    let cycles = step_round(&work).cycles as f64;
    let t = h.bench("sim/step_round(alexnet-conv2-ish)", 20, || step_round(&work));
    let rate = cycles / t;
    h.check(
        rate > 10e6,
        &format!("stepped simulator {:.1} M cycles/s ≥ 10 M", rate / 1e6),
    );

    // zoo build + flow extraction
    h.bench("zoo/alexnet+flow", 500, || {
        let g = zoo::build("alexnet", false).unwrap();
        ComputationFlow::extract(&g).unwrap()
    });

    // JSON parse at model-file scale
    let model_path = std::path::Path::new("artifacts/models/vgg16.json");
    if model_path.exists() {
        let text = std::fs::read_to_string(model_path).unwrap();
        let jt = h.bench("json/parse vgg16.json", 200, || Json::parse(&text).unwrap());
        h.check(jt < 10e-3, &format!("vgg16.json parse {:.2} ms < 10 ms", jt * 1e3));
    }

    // PJRT dispatch overhead: run tiny model, measure non-execute overhead
    let dir = std::path::Path::new("artifacts");
    if cnn2gate::runtime::Runtime::available() && dir.join("manifest.json").exists() {
        let manifest = Manifest::load(dir).unwrap();
        if let Some(art) = manifest.model("tiny") {
            let per_frame = pipeline::time_emulation_synthetic(art, 50).unwrap();
            println!(
                "bench pjrt/tiny end-to-end {:>38} {:.3} ms/frame",
                "", per_frame * 1e3
            );
            h.check(per_frame < 0.1, "tiny-model PJRT round trip < 100 ms");
        }
    }
    h.finish();
}
