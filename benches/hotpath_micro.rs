//! Hot-path microbenches — the §Perf targets of DESIGN.md §9 (L3):
//!   estimator query        < 10 µs
//!   full DSE sweep         < 5 s wall (it's actually ~ms)
//!   simulator              ≥ 10 M simulated cycles/s (stepped mode)
//!   skip-ahead stepper     ≥ 10x the naive reference on alexnet-conv2
//!   JSON parse             model-file scale in ms
//! plus PJRT dispatch overhead when artifacts are present.
//!
//! Writes `BENCH_PR3.json` (machine-readable: stepped speedup, stepped
//! full-network candidates/s, model×device sweep wall-clock) and
//! `BENCH_PR5.json` (specialization-pass wall time + cycle gain) so the
//! perf trajectory is data, not prose.

mod common;

use cnn2gate::coordinator::pipeline;
use cnn2gate::dse::{
    brute, eval, specialize, EvalCache, EvalRequest, Evaluation, Evaluator, Fidelity,
};
use cnn2gate::estimator::device::ARRIA_10_GX1150;
use cnn2gate::estimator::{estimate, Thresholds};
use cnn2gate::ir::ComputationFlow;
use cnn2gate::metrics;
use cnn2gate::onnx::zoo;
use cnn2gate::runtime::Manifest;
use cnn2gate::session::{CompileJob, Session};
use cnn2gate::sim::{dominant_round_work, step_round, step_round_reference, RoundWork};
use cnn2gate::synth::Explorer;
use cnn2gate::util::json::{Json, JsonObj};
use common::Harness;

fn main() {
    let mut h = Harness::new();
    let flow = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();

    // estimator query
    let q = h.bench("estimator/query", 10_000, || {
        estimate(&flow, &ARRIA_10_GX1150, 16, 32)
    });
    h.check(q < 10e-6, &format!("estimator query {:.2} µs < 10 µs", q * 1e6));

    // full BF sweep — sequential seed path, the compute reference
    let sweep = h.bench("dse/bf_full_sweep (seq)", 1000, || {
        brute::explore_seq(&flow, &ARRIA_10_GX1150, Thresholds::default())
    });
    h.check(sweep < 5.0, "full DSE sweep < 5 s");

    // pooled + memoized sweep: the first call computes each candidate
    // once, every repeat is served from the eval memo
    let ev = Evaluator::new(eval::default_threads());
    brute::explore_with(&ev, &flow, &ARRIA_10_GX1150, Thresholds::default());
    let warm = h.bench("dse/bf_full_sweep (pool, warm memo)", 1000, || {
        brute::explore_with(&ev, &flow, &ARRIA_10_GX1150, Thresholds::default())
    });
    h.check(warm < 5.0, "warm pooled sweep < 5 s");

    // memo-hit fast path: one lookup + Arc clone, no estimator call
    let hit = h.bench("eval/cache_hit", 10_000, || {
        ev.evaluate(&flow, &ARRIA_10_GX1150, 16, 32, EvalRequest::at(Fidelity::Analytical))
    });
    h.check(hit < 10e-6, &format!("memo hit {:.2} µs < 10 µs", hit * 1e6));

    // persistent memo: save/load a grid-sized cache file and warm-start
    // an evaluator from it (the `--cache-file` path of dse/fit-fleet/sweep)
    let cache_path = std::env::temp_dir().join(format!(
        "cnn2gate-bench-cache-{}.json",
        std::process::id()
    ));
    let entries = ev.cache().stats().entries;
    let save_t = h.bench("evalcache/save(grid)", 200, || {
        ev.cache().save(&cache_path).unwrap()
    });
    let load_t = h.bench("evalcache/load(grid)", 200, || {
        EvalCache::load(&cache_path).unwrap()
    });
    h.check(save_t < 50e-3, &format!("cache save ({entries} entries) < 50 ms"));
    h.check(load_t < 50e-3, &format!("cache load ({entries} entries) < 50 ms"));
    let warm_start = Evaluator::with_cache(
        eval::default_threads(),
        std::sync::Arc::new(EvalCache::load(&cache_path).unwrap()),
    );
    let (_, disk_hit) =
        warm_start.evaluate(&flow, &ARRIA_10_GX1150, 16, 32, EvalRequest::at(Fidelity::Analytical));
    h.check(disk_hit, "disk-loaded cache serves the hot option without recompute");
    std::fs::remove_file(&cache_path).ok();

    // stepped simulator throughput (skip-ahead engine)
    let work = RoundWork {
        pixels: 729,
        groups: 6,
        red_steps: 100,
        bytes_per_step: 16,
        feed2_bytes_per_step: 0,
        ddr_bytes_per_cycle: 40.0,
        out_bytes: 32,
        batch: 1,
    };
    let cycles = step_round(&work).cycles as f64;
    let t = h.bench("sim/step_round(alexnet-conv2-ish)", 200, || step_round(&work));
    let rate = cycles / t;
    h.check(
        rate > 10e6,
        &format!("stepped simulator {:.1} M cycles/s ≥ 10 M", rate / 1e6),
    );

    // naive reference vs epoch skip-ahead on the REAL dominant round the
    // DSE steps (memory-bound at (16,32): the hard case for skip-ahead)
    let est = estimate(&flow, &ARRIA_10_GX1150, 16, 32);
    let conv2 = dominant_round_work(&flow, &ARRIA_10_GX1150, est.fmax_mhz, 16, 32).unwrap();
    h.check(
        step_round(&conv2) == step_round_reference(&conv2),
        "skip-ahead bit-identical to the naive reference on alexnet-conv2",
    );
    let t_ref = h.bench("sim/step_round_reference(alexnet-conv2)", 5, || {
        step_round_reference(&conv2)
    });
    let t_fast = h.bench("sim/step_round skip-ahead(alexnet-conv2)", 200, || {
        step_round(&conv2)
    });
    let stepped_speedup = metrics::speedup(t_ref, t_fast);
    h.check(
        stepped_speedup >= 10.0,
        &format!("skip-ahead ≥10x the naive stepper ({stepped_speedup:.0}x)"),
    );

    // full-network stepped candidate throughput (what SteppedFullNetwork
    // DSE pays per uncached candidate)
    let t_cand = h.bench("eval/stepped_full_network(alexnet 16,32)", 20, || {
        Evaluation::compute(&flow, &ARRIA_10_GX1150, 16, 32, Fidelity::SteppedFullNetwork)
    });
    let cand_per_s = metrics::candidates_per_s(1, t_cand);
    h.check(
        t_cand < 1.0,
        &format!("full-network stepped candidate < 1 s ({:.1} ms)", t_cand * 1e3),
    );

    // model×device sweep wall-clock through the session engine's
    // work-stealing scheduler (an M×N CompileJob)
    let sweep_models = [
        zoo::build("alexnet", false).unwrap(),
        zoo::build("vgg16", false).unwrap(),
    ];
    let t0 = std::time::Instant::now();
    let session = Session::builder().threads(eval::default_threads()).build();
    let sweep_rep = session
        .run(
            &CompileJob::builder()
                .models(sweep_models)
                .all_devices()
                .explorer(Explorer::BruteForce)
                .build()
                .unwrap(),
        )
        .unwrap();
    let sweep_s = t0.elapsed().as_secs_f64();
    println!(
        "bench sweep/work-stealing(2 models x {} devices) {:>13} {:.3} s wall",
        sweep_rep.entries.len() / 2,
        "",
        sweep_s
    );
    h.check(sweep_s < 5.0, "cold work-stealing sweep < 5 s");

    // machine-readable perf record (BENCH_PR3.json)
    {
        let mut stepped = JsonObj::new();
        stepped.insert("reference_seconds", t_ref.into());
        stepped.insert("skip_ahead_seconds", t_fast.into());
        stepped.insert("speedup", stepped_speedup.into());
        stepped.insert("round_cycles", Json::Num(step_round(&conv2).cycles as f64));
        let mut full = JsonObj::new();
        full.insert("seconds_per_candidate", t_cand.into());
        full.insert("candidates_per_s", cand_per_s.into());
        let mut sweep = JsonObj::new();
        sweep.insert("models", 2usize.into());
        sweep.insert("devices", (sweep_rep.entries.len() / 2).into());
        sweep.insert("wall_seconds", sweep_s.into());
        let mut doc = JsonObj::new();
        doc.insert("format", "cnn2gate-bench-pr3".into());
        doc.insert("stepped_dominant_round", Json::Obj(stepped));
        doc.insert("stepped_full_network", Json::Obj(full));
        doc.insert("sweep", Json::Obj(sweep));
        let path = std::path::Path::new("BENCH_PR3.json");
        std::fs::write(path, Json::Obj(doc).to_string_pretty()).unwrap();
        println!("perf record written to {}", path.display());
    }

    // per-layer specialization pass on the uniform stepped-full winner
    // (the PR-5 tentpole): wall time of the greedy re-fold, plus THE
    // acceptance gate — ≥5% fewer stepped-full total cycles than the
    // uniform (Ni,Nl) winner on AlexNet / Arria 10
    let spec_est = estimate(&flow, &ARRIA_10_GX1150, 16, 32);
    let census = cnn2gate::sim::step_network(&flow, &ARRIA_10_GX1150, spec_est.fmax_mhz, 16, 32);
    let th = Thresholds::default();
    let t_spec = h.bench("dse/specialize(alexnet a10)", 20, || {
        specialize::specialize(&flow, &ARRIA_10_GX1150, &th, &spec_est, &census)
    });
    let spec = specialize::specialize(&flow, &ARRIA_10_GX1150, &th, &spec_est, &census);
    let cyc_uniform = spec.uniform_total_cycles();
    let cyc_spec = spec.specialized_total_cycles();
    h.check(
        cyc_spec as f64 <= 0.95 * cyc_uniform as f64,
        &format!(
            "specialized alexnet/a10 ≥5% fewer stepped-full cycles ({:.1}% gain)",
            100.0 * spec.gain_fraction()
        ),
    );
    h.check(t_spec < 2.0, "specialization pass stays interactive (< 2 s)");

    // machine-readable PR-5 perf record
    {
        let mut s = JsonObj::new();
        s.insert("pass_seconds", t_spec.into());
        s.insert("uniform_total_cycles", Json::Num(cyc_uniform as f64));
        s.insert("specialized_total_cycles", Json::Num(cyc_spec as f64));
        s.insert("gain_fraction", spec.gain_fraction().into());
        s.insert("specialized_rounds", spec.specialized_rounds().into());
        let mut doc = JsonObj::new();
        doc.insert("format", "cnn2gate-bench-pr5".into());
        doc.insert("specialization", Json::Obj(s));
        let path = std::path::Path::new("BENCH_PR5.json");
        std::fs::write(path, Json::Obj(doc).to_string_pretty()).unwrap();
        println!("perf record written to {}", path.display());
    }

    // zoo build + flow extraction
    h.bench("zoo/alexnet+flow", 500, || {
        let g = zoo::build("alexnet", false).unwrap();
        ComputationFlow::extract(&g).unwrap()
    });

    // JSON parse at model-file scale
    let model_path = std::path::Path::new("artifacts/models/vgg16.json");
    if model_path.exists() {
        let text = std::fs::read_to_string(model_path).unwrap();
        let jt = h.bench("json/parse vgg16.json", 200, || Json::parse(&text).unwrap());
        h.check(jt < 10e-3, &format!("vgg16.json parse {:.2} ms < 10 ms", jt * 1e3));
    }

    // PJRT dispatch overhead: run tiny model, measure non-execute overhead
    let dir = std::path::Path::new("artifacts");
    if cnn2gate::runtime::Runtime::available() && dir.join("manifest.json").exists() {
        let manifest = Manifest::load(dir).unwrap();
        if let Some(art) = manifest.model("tiny") {
            let per_frame = pipeline::time_emulation_synthetic(art, 50).unwrap();
            println!(
                "bench pjrt/tiny end-to-end {:>38} {:.3} ms/frame",
                "", per_frame * 1e3
            );
            h.check(per_frame < 0.1, "tiny-model PJRT round trip < 100 ms");
        }
    }
    h.finish();
}
