//! Branched-family gates — the PR-10 perf fence for the DAG-aware
//! fused-round IR: ResNet-class residual joins and MobileNet-class
//! depthwise/separable stacks through the cycle-accurate stepper.
//!
//! Three tiers:
//!
//! * structure: resnet18 extracts as a DAG with its 8 residual
//!   Add-merge rounds, mobilenetv1 as a linear chain of 13 depthwise +
//!   pointwise pairs;
//! * bit-identity + the skip-ahead gate: EVERY resnet18 fused round —
//!   including the dual-feed Add rounds — stepped by the skip-ahead
//!   engine must match the naive per-cycle oracle field-for-field, and
//!   the skip-ahead pass over the whole network must run ≥ 10x faster
//!   than the oracle pass (wall clock);
//! * serving: both branched families produce a finite stepped-full
//!   frames/s, and the Add rounds' per-feed starvation census is
//!   populated (one read port alternating two feeds starves the
//!   lagging branch deterministically).
//!
//! Writes `BENCH_PR10.json` for cross-commit comparison via
//! `tools/perf_compare.sh`. Gated metrics are deterministic model
//! outputs (cycles, frames/s, round counts); the measured oracle wall
//! ratio is recorded under a key the compare treats as informational,
//! so runner noise cannot flake the fence.

mod common;

use cnn2gate::estimator::device::ARRIA_10_GX1150;
use cnn2gate::estimator::estimate;
use cnn2gate::ir::{ComputationFlow, LayerKind};
use cnn2gate::onnx::zoo;
use cnn2gate::sim::{network_round_work, step_network, step_round, step_round_reference};
use cnn2gate::util::json::{Json, JsonObj};
use common::Harness;
use std::time::Instant;

fn main() {
    let mut h = Harness::new();

    // -- structure tier ------------------------------------------------
    let res = ComputationFlow::extract(&zoo::build("resnet18", false).unwrap()).unwrap();
    let mob = ComputationFlow::extract(&zoo::build("mobilenetv1", false).unwrap()).unwrap();
    h.check(!res.is_linear_chain(), "resnet18 extracts as a DAG");
    let adds = res
        .layers
        .iter()
        .filter(|l| matches!(l.kind, LayerKind::Add { .. }))
        .count();
    h.check(adds == 8, &format!("resnet18 carries 8 residual Add rounds (got {adds})"));
    h.check(
        res.layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Add { .. }))
            .all(|l| l.producers.len() == 2),
        "every Add round reads two producer rounds",
    );
    let depthwise = mob.layers.iter().filter(|l| l.is_depthwise()).count();
    h.check(
        depthwise == 13,
        &format!("mobilenetv1 carries 13 depthwise rounds (got {depthwise})"),
    );
    h.check(mob.is_linear_chain(), "mobilenetv1 stays a linear chain (no joins)");

    // -- bit-identity + the ≥10x skip-ahead gate -----------------------
    let (ni, nl) = (16, 32);
    let est = estimate(&res, &ARRIA_10_GX1150, ni, nl);
    let rounds = network_round_work(&res, &ARRIA_10_GX1150, est.fmax_mhz, ni, nl);

    let t0 = Instant::now();
    let skip: Vec<_> = rounds.iter().map(step_round).collect();
    let skip_wall = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let oracle: Vec<_> = rounds.iter().map(step_round_reference).collect();
    let oracle_wall = t0.elapsed().as_secs_f64();

    let mut identical = true;
    for (i, (s, o)) in skip.iter().zip(&oracle).enumerate() {
        if s != o {
            identical = false;
            println!("  round {i} diverges:\n    skip   {s:?}\n    oracle {o:?}");
        }
    }
    h.check(
        identical,
        "skip-ahead census bit-identical to the per-cycle oracle on all resnet18 rounds",
    );
    let ratio = oracle_wall / skip_wall.max(1e-12);
    println!(
        "  resnet18 full network: skip-ahead {:.3} ms, oracle {:.1} ms ({ratio:.0}x)",
        skip_wall * 1e3,
        oracle_wall * 1e3
    );
    h.check(
        ratio >= 10.0,
        &format!("skip-ahead {ratio:.0}x >= 10x faster than the oracle on resnet18"),
    );
    h.bench("stepped_full/resnet18_skip_ahead", 20, || {
        rounds.iter().map(step_round).collect::<Vec<_>>()
    });

    // -- serving tier --------------------------------------------------
    let res_net = step_network(&res, &ARRIA_10_GX1150, est.fmax_mhz, ni, nl);
    let mob_est = estimate(&mob, &ARRIA_10_GX1150, ni, nl);
    let mob_net = step_network(&mob, &ARRIA_10_GX1150, mob_est.fmax_mhz, ni, nl);
    println!(
        "  stepped-full serving: resnet18 {:.1} frames/s, mobilenetv1 {:.1} frames/s",
        res_net.frames_per_s(),
        mob_net.frames_per_s()
    );
    h.check(res_net.frames_per_s() > 0.0, "resnet18 serves finite stepped-full frames/s");
    h.check(mob_net.frames_per_s() > 0.0, "mobilenetv1 serves finite stepped-full frames/s");
    let add_feed_stalls: u64 = res
        .layers
        .iter()
        .zip(&res_net.layers)
        .filter(|(l, _)| matches!(l.kind, LayerKind::Add { .. }))
        .map(|(_, s)| s.feed_a_empty_stalls + s.feed_b_empty_stalls)
        .sum();
    h.check(
        add_feed_stalls > 0,
        "Add rounds record per-feed starvation (one port, two feeds)",
    );

    // machine-readable PR-10 perf record — every gated metric is a
    // deterministic model output; the wall ratio rides along under an
    // informational key
    {
        let mut doc = JsonObj::new();
        doc.insert("format", "cnn2gate-bench-pr10".into());
        let mut resnet = JsonObj::new();
        resnet.insert("add_rounds", adds.into());
        resnet.insert("total_cycles", (res_net.total_cycles() as f64).into());
        resnet.insert("frames_per_s", res_net.frames_per_s().into());
        resnet.insert("add_feed_stalls", (add_feed_stalls as f64).into());
        doc.insert("resnet18", Json::Obj(resnet));
        let mut mobilenet = JsonObj::new();
        mobilenet.insert("depthwise_rounds", depthwise.into());
        mobilenet.insert("total_cycles", (mob_net.total_cycles() as f64).into());
        mobilenet.insert("frames_per_s", mob_net.frames_per_s().into());
        doc.insert("mobilenetv1", Json::Obj(mobilenet));
        doc.insert("oracle_vs_skip_wall_ratio", ratio.into());
        let path = std::path::Path::new("BENCH_PR10.json");
        std::fs::write(path, Json::Obj(doc).to_string_pretty()).unwrap();
        println!("perf record written to {}", path.display());
    }

    h.finish();
}
