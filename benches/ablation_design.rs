//! Ablation benches for the design choices DESIGN.md calls out:
//!   A. pipe depth (OpenCL FIFO size) vs stepped-simulator stalls
//!   B. RL hyper-parameters (ε, episode budget) vs optimum-found rate
//!   C. feature-buffer budget fraction vs the feasibility frontier
//!   D. N_i/N_l caps vs the chosen operating point (why (16,32))

mod common;

use cnn2gate::dse::{brute, rl, RlConfig};
use cnn2gate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA5};
use cnn2gate::estimator::{estimate, Thresholds};
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::zoo;
use cnn2gate::sim::{simulate, step_round, RoundWork};
use common::Harness;

fn main() {
    let mut h = Harness::new();
    let flow = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();
    let th = Thresholds::default();

    // --- A. pipe depth: a deep-enough FIFO hides the DDR burstiness ----
    println!("[A] conv-round utilization vs pipe depth (stepped sim):");
    let base = RoundWork {
        pixels: 729,
        groups: 6,
        red_steps: 100,
        bytes_per_step: 48,
        feed2_bytes_per_step: 0,
        ddr_bytes_per_cycle: 40.0,
        out_bytes: 32,
        batch: 1,
    };
    // NB: PIPE_DEPTH is a compile-time constant in the estimator; the
    // stepped sim exposes the effect through the work's burstiness knobs
    let rep = step_round(&base);
    println!(
        "    depth=512: conv util {:.2}, rd->conv full stalls {}",
        rep.conv_utilization(),
        rep.rd_to_conv_full_stalls
    );
    h.check(
        rep.conv_utilization() > 0.6,
        "deep pipes keep the lane array >60% utilized on a balanced round",
    );
    let starved = step_round(&RoundWork {
        ddr_bytes_per_cycle: 4.0,
        ..base
    });
    h.check(
        starved.conv_utilization() < rep.conv_utilization(),
        "cutting DDR bandwidth starves the lanes (backpressure visible)",
    );

    // --- B. RL hyper-parameters ------------------------------------------
    println!("[B] RL-DSE optimum-found rate across hyper-parameters:");
    let bf = brute::explore(&flow, &ARRIA_10_GX1150, th);
    for (eps, episodes, steps) in [
        (0.05, 4, 8),
        (0.35, 4, 8), // default
        (0.35, 2, 4),
        (0.80, 4, 8),
    ] {
        let mut hits = 0;
        let mut queries = 0;
        let seeds = 20;
        for seed in 0..seeds {
            let cfg = RlConfig {
                epsilon: eps,
                episodes,
                steps_per_episode: steps,
                seed,
                ..RlConfig::default()
            };
            let r = rl::explore(&flow, &ARRIA_10_GX1150, th, cfg);
            queries += r.queries;
            hits += (r.best == bf.best) as usize;
        }
        println!(
            "    eps={eps:.2} episodes={episodes} steps={steps}: found {hits}/{seeds}, avg queries {:.1}",
            queries as f64 / seeds as f64
        );
        if (eps, episodes, steps) == (0.35, 4, 8) {
            h.check(hits >= 18, "default RL config finds the optimum on ≥90% of seeds");
        }
        if (eps, episodes, steps) == (0.35, 2, 4) {
            h.check(
                hits < 20 || queries / (seeds as usize) < bf.queries,
                "a starved episode budget trades hit rate for queries",
            );
        }
    }

    // --- C. feature-budget fraction: drives the CycloneV RAM anchor ----
    println!("[C] feasibility at (8,8) on 5CSEMA5 (feature-budget calibration):");
    let est = estimate(&flow, &CYCLONE_V_5CSEMA5, 8, 8);
    println!(
        "    RAM blocks {:.0}/397 ({:.1}%), mem bits {:.2} M",
        est.ram_blocks,
        est.p_mem,
        est.mem_bits / 1e6
    );
    h.check(
        est.p_mem > 95.0 && est.p_mem <= 101.0,
        "the (8,8) fit saturates the 5CSEMA5 block RAM (paper: 100%)",
    );

    // --- D. why (16,32): remove the option caps and the fitter would
    //        choose a bigger design that the OpenCL flow can't route ----
    println!("[D] operating point with vs without the hardware caps:");
    let capped = brute::explore(&flow, &ARRIA_10_GX1150, th);
    // uncapped exploration: evaluate a 5x5 pow2 grid directly
    let mut best = (0usize, 0usize, 0.0f64);
    for ni in [4usize, 8, 16, 32, 64] {
        for nl in [4usize, 8, 16, 32, 64] {
            let e = estimate(&flow, &ARRIA_10_GX1150, ni, nl);
            if e.fits(&th) && e.f_avg() > best.2 {
                best = (ni, nl, e.f_avg());
            }
        }
    }
    println!(
        "    capped H_best {:?} vs uncapped argmax ({}, {}) at F_avg {:.1}%",
        capped.best, best.0, best.1, best.2
    );
    h.check(capped.best == Some((16, 32)), "caps reproduce the paper's (16,32)");
    h.check(
        best.0 * best.1 > 16 * 32,
        "without the memory-interface/fan-out caps the fitter would pick a larger design — the paper's §5 'limited options' remark",
    );

    // latency sanity at both points
    let t_capped = simulate(&flow, &ARRIA_10_GX1150, 16, 32).total_millis;
    let t_big = simulate(&flow, &ARRIA_10_GX1150, best.0, best.1).total_millis;
    h.check(
        t_big < t_capped,
        "the uncapped point would be faster — scalability/automation is the trade-off the paper accepts",
    );
    h.finish();
}
