//! Table 1: execution times for AlexNet and VGG-16 (batch = 1) on the
//! Core-i7 emulation row (PJRT CPU here), Cyclone V 5CSEMA5 and Arria 10
//! GX1150 — regenerated live, with paper-shape checks.

mod common;

use cnn2gate::coordinator::pipeline;
use cnn2gate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA5};
use cnn2gate::estimator::estimate;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::zoo;
use cnn2gate::report::table1;
use cnn2gate::runtime::Manifest;
use cnn2gate::sim::simulate;
use common::Harness;

fn main() {
    let mut h = Harness::new();
    let aflow = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();
    let vflow = ComputationFlow::extract(&zoo::build("vgg16", false).unwrap()).unwrap();

    // --- FPGA rows via the cycle simulator (timed: this is the bench) ---
    let a_cv = h.bench("sim/alexnet/cycloneV(8,8)", 50, || {
        simulate(&aflow, &CYCLONE_V_5CSEMA5, 8, 8).total_millis
    });
    let _ = a_cv;
    let alex_cv = simulate(&aflow, &CYCLONE_V_5CSEMA5, 8, 8);
    let vgg_cv = simulate(&vflow, &CYCLONE_V_5CSEMA5, 8, 8);
    h.bench("sim/alexnet/arria10(16,32)", 50, || {
        simulate(&aflow, &ARRIA_10_GX1150, 16, 32).total_millis
    });
    let alex_a10 = simulate(&aflow, &ARRIA_10_GX1150, 16, 32);
    let vgg_a10 = simulate(&vflow, &ARRIA_10_GX1150, 16, 32);

    // --- emulation row (PJRT CPU) when artifacts exist and the real
    // backend is built (stub builds skip the row) ------------------------
    let dir = std::path::Path::new("artifacts");
    let manifest = if cnn2gate::runtime::Runtime::available() {
        Manifest::load(dir).ok()
    } else {
        None
    };
    let emu = manifest.map(|m| {
        let a = m
            .model("alexnet")
            .map(|art| pipeline::time_emulation_synthetic(art, 1).unwrap());
        let v = m
            .model("vgg16")
            .map(|art| pipeline::time_emulation_synthetic(art, 1).unwrap());
        (a, v)
    });

    let mut rows = Vec::new();
    if let Some((a, v)) = &emu {
        rows.push((
            "CPU (PJRT emulation)".to_string(),
            "N/A".to_string(),
            a.map(|s| s * 1e3),
            v.map(|s| s * 1e3),
            None,
        ));
    }
    let est_cv = estimate(&aflow, &CYCLONE_V_5CSEMA5, 8, 8);
    let est_a10 = estimate(&aflow, &ARRIA_10_GX1150, 16, 32);
    rows.push((
        CYCLONE_V_5CSEMA5.name.to_string(),
        format!(
            "Logic {:.0}% DSP {:.0}% RAM {:.0}%",
            est_cv.p_lut, est_cv.p_dsp, est_cv.p_mem
        ),
        Some(alex_cv.total_millis),
        Some(vgg_cv.total_millis),
        Some(est_cv.fmax_mhz),
    ));
    rows.push((
        ARRIA_10_GX1150.name.to_string(),
        format!(
            "Logic {:.0}% DSP {:.0}% RAM {:.0}%",
            est_a10.p_lut, est_a10.p_dsp, est_a10.p_mem
        ),
        Some(alex_a10.total_millis),
        Some(vgg_a10.total_millis),
        Some(est_a10.fmax_mhz),
    ));
    println!("\n{}", table1(&rows).render());

    // --- paper-shape checks ------------------------------------------------
    h.check_close(alex_a10.total_millis, 18.24, 0.12, "AlexNet Arria10 latency (ms)");
    h.check_close(vgg_a10.total_millis, 205.0, 0.17, "VGG-16 Arria10 latency (ms)");
    h.check_close(alex_cv.total_millis, 153.0, 0.13, "AlexNet CycloneV latency (ms)");
    h.check(
        (2000.0..7000.0).contains(&vgg_cv.total_millis),
        &format!(
            "VGG CycloneV in the seconds regime ({:.2} s, paper 4.26 s)",
            vgg_cv.total_millis / 1e3
        ),
    );
    h.check(
        alex_a10.total_millis < alex_cv.total_millis / 4.0,
        "Arria 10 ≫ Cyclone V (AlexNet)",
    );
    let ratio = vgg_a10.total_millis / alex_a10.total_millis;
    h.check(
        (8.0..20.0).contains(&ratio),
        &format!("VGG/AlexNet latency ratio {ratio:.1} (paper 11.2)"),
    );
    h.check_close(est_cv.fmax_mhz, 131.0, 0.06, "CycloneV fmax (MHz)");
    h.check_close(est_a10.fmax_mhz, 199.0, 0.04, "Arria10 fmax (MHz)");
    if let Some((Some(a), Some(v))) = emu {
        h.check(
            v > a,
            &format!("emulation: VGG ({v:.1}s) slower than AlexNet ({a:.1}s), paper 148s vs 13s"),
        );
    }
    h.finish();
}
