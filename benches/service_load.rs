//! Compile-service load bench (the PR-6 tentpole's perf gate): one
//! daemon, thousands of queued jobs, mixed tiny/huge models across
//! three tenants, all multiplexed onto the shared evaluator.
//!
//! Gates (recorded via the harness, fatal at finish()):
//!   * wall clock — the whole mixed backlog drains in bounded time;
//!   * p99 tail latency of the TINY (interactive) jobs — the fairness
//!     policy's cost priority must keep them from queueing behind the
//!     fleet-sized jobs that share the daemon;
//!   * cross-tenant fairness — per-tenant mean finish rank (from the
//!     reducer's replayable event log) stays balanced even though every
//!     tenant floods the queue at once.
//!
//! Writes `BENCH_PR6.json` (machine-readable: wall, sojourn
//! distribution, tiny-job tail, fairness ratio) for cross-commit
//! comparison. Deterministic outcomes are pinned by `tests/service.rs`;
//! this file only measures.

mod common;

use std::collections::HashMap;
use std::time::Instant;

use cnn2gate::coordinator::service::Event;
use cnn2gate::coordinator::{CompileService, JobSpec, ServiceConfig};
use cnn2gate::dse::TenantId;
use cnn2gate::estimator::device::ARRIA_10_GX1150;
use cnn2gate::metrics::LatencyStats;
use cnn2gate::onnx::zoo;
use cnn2gate::session::CompileJob;
use cnn2gate::synth::Explorer;
use cnn2gate::util::json::{Json, JsonObj};
use common::Harness;

const TENANTS: &[&str] = &["acme", "zen", "bolt"];
/// Jobs per tenant; every `HUGE_EVERY`-th is a fleet-sized job.
const PER_TENANT: usize = 400;
const HUGE_EVERY: usize = 40;

fn job(huge: bool) -> CompileJob {
    let builder = if huge {
        // "huge": a full device-database fleet fit of AlexNet
        CompileJob::builder().model(zoo::build("alexnet", false).unwrap()).all_devices()
    } else {
        CompileJob::builder().model(zoo::build("tiny", false).unwrap()).device(&ARRIA_10_GX1150)
    };
    builder.explorer(Explorer::BruteForce).build().unwrap()
}

fn main() {
    let mut h = Harness::new();
    let total = TENANTS.len() * PER_TENANT;
    let service = CompileService::start(ServiceConfig {
        workers: 4,
        queue_capacity: total + 8,
        threads: 0,
        ..ServiceConfig::default()
    });

    // flood: every tenant submits its whole backlog up front,
    // interleaved so the queue is genuinely mixed
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(total);
    for i in 0..PER_TENANT {
        for tenant in TENANTS {
            let huge = i % HUGE_EVERY == HUGE_EVERY - 1;
            let spec = JobSpec::new(job(huge)).tenant(TenantId::of(tenant));
            let ticket = service.submit(spec).expect("admission: queue sized for the backlog");
            tickets.push((ticket, huge, t0.elapsed().as_secs_f64()));
        }
    }
    let submit_s = t0.elapsed().as_secs_f64();
    println!(
        "bench service/submit({total} jobs, {} tenants) {:>13} {:.3} s wall",
        TENANTS.len(),
        "",
        submit_s
    );

    // drain: within a tenant equal-cost jobs finish FIFO and tiny jobs
    // jump huge ones, so draining in submission order observes each
    // completion close to when it actually happened
    let mut sojourn = Vec::with_capacity(total);
    let mut tiny_sojourn = Vec::new();
    for (ticket, huge, submitted_s) in &tickets {
        loop {
            let event = ticket.recv().expect("service dropped a stream");
            match event {
                Event::Finished { .. } => break,
                e => assert!(!e.is_terminal(), "job died under load: {e:?}"),
            }
        }
        let s = t0.elapsed().as_secs_f64() - submitted_s;
        sojourn.push(s);
        if !huge {
            tiny_sojourn.push(s);
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let report = service.shutdown();
    println!(
        "bench service/drain({total} jobs: {} huge) {:>16} {:.3} s wall",
        total / HUGE_EVERY,
        "",
        wall_s
    );

    let all = LatencyStats::from_seconds(&sojourn);
    let tiny = LatencyStats::from_seconds(&tiny_sojourn);
    println!(
        "  sojourn p50 {:.1} ms p99 {:.1} ms max {:.1} ms | tiny p99 {:.1} ms",
        all.p50_ms, all.p99_ms, all.max_ms, tiny.p99_ms
    );

    // cross-tenant fairness: mean finish rank per tenant from the
    // reducer's log (Finished events, in emission order)
    let mut rank = 0usize;
    let mut sums: HashMap<u64, (usize, usize)> = HashMap::new();
    for event in report.reducer.log() {
        if let Event::Finished { job, .. } = event {
            let tenant = report.reducer.get(*job).expect("logged job").tenant.as_u64();
            let e = sums.entry(tenant).or_insert((0, 0));
            e.0 += rank;
            e.1 += 1;
            rank += 1;
        }
    }
    let means: Vec<f64> = sums.values().map(|&(sum, n)| sum as f64 / n as f64).collect();
    let best = means.iter().cloned().fold(f64::INFINITY, f64::min);
    let worst = means.iter().cloned().fold(0.0f64, f64::max);
    let fairness = worst / best.max(1.0);
    println!("  fairness: mean finish rank worst/best = {fairness:.3}");

    h.check(report.reducer.open_jobs() == 0, "every job reached a terminal state");
    h.check(
        report.reducer.jobs().count() == total,
        &format!("all {total} jobs admitted and recorded"),
    );
    h.check(wall_s < 60.0, &format!("mixed backlog drains < 60 s (took {wall_s:.1} s)"));
    h.check(
        tiny.p99_ms < 30_000.0,
        &format!("tiny-job p99 sojourn {:.0} ms < 30 s (cost priority holds)", tiny.p99_ms),
    );
    h.check(
        fairness < 1.5,
        &format!("cross-tenant mean finish rank ratio {fairness:.3} < 1.5"),
    );

    // machine-readable PR-6 perf record
    {
        let mut load = JsonObj::new();
        load.insert("jobs", total.into());
        load.insert("tenants", TENANTS.len().into());
        load.insert("huge_jobs", (total / HUGE_EVERY).into());
        load.insert("workers", 4usize.into());
        load.insert("submit_seconds", submit_s.into());
        load.insert("wall_seconds", wall_s.into());
        let mut lat = JsonObj::new();
        lat.insert("p50_ms", all.p50_ms.into());
        lat.insert("p99_ms", all.p99_ms.into());
        lat.insert("max_ms", all.max_ms.into());
        lat.insert("tiny_p99_ms", tiny.p99_ms.into());
        let mut fair = JsonObj::new();
        fair.insert("mean_rank_ratio", fairness.into());
        let mut doc = JsonObj::new();
        doc.insert("format", "cnn2gate-bench-pr6".into());
        doc.insert("load", Json::Obj(load));
        doc.insert("sojourn", Json::Obj(lat));
        doc.insert("fairness", Json::Obj(fair));
        let path = std::path::Path::new("BENCH_PR6.json");
        std::fs::write(path, Json::Obj(doc).to_string_pretty()).unwrap();
        println!("perf record written to {}", path.display());
    }

    h.finish();
}
