//! Batch-size throughput sweep — the paper's §5 remark: "There are also
//! other latency reports in the literature such as [7]. However, those
//! latency reports are measured in the favorable batch size (e.g. 16).
//! Increasing batch size can make more parallelism available to the
//! algorithm that can lead to higher throughput."
//!
//! This bench regenerates that claim as a curve: per-frame latency and
//! GOp/s for batch 1..32 on both evaluation nets.

mod common;

use cnn2gate::estimator::device::ARRIA_10_GX1150;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::zoo;
use cnn2gate::sim::{simulate, simulate_batched};
use cnn2gate::util::table::Table;
use common::Harness;

fn main() {
    let mut h = Harness::new();
    for model in ["alexnet", "vgg16"] {
        let flow = ComputationFlow::extract(&zoo::build(model, false).unwrap()).unwrap();
        h.bench(&format!("batch_sim/{model}"), 100, || {
            simulate_batched(&flow, &ARRIA_10_GX1150, 16, 32, 16)
        });
        let mut t = Table::new(
            format!("{model} on Arria 10 (16,32): batch sweep"),
            &["batch", "total (ms)", "ms/frame", "GOp/s", "fc1 bound"],
        );
        let mut prev = 0.0;
        let mut monotone = true;
        for batch in [1usize, 2, 4, 8, 16, 32] {
            let rep = simulate_batched(&flow, &ARRIA_10_GX1150, 16, 32, batch);
            monotone &= rep.gops_per_s >= prev - 1e-9;
            prev = rep.gops_per_s;
            let fc1 = rep.layers.iter().find(|l| !l.is_conv).map(|l| l.memory_bound);
            t.row(&[
                batch.to_string(),
                format!("{:.2}", rep.total_millis),
                format!("{:.2}", rep.millis_per_frame),
                format!("{:.1}", rep.gops_per_s),
                fc1.map_or("-".into(), |b| if b { "memory" } else { "compute" }.into()),
            ]);
        }
        println!("\n{}", t.render());
        h.check(monotone, &format!("{model}: throughput monotone in batch"));
        let b1 = simulate(&flow, &ARRIA_10_GX1150, 16, 32);
        let b16 = simulate_batched(&flow, &ARRIA_10_GX1150, 16, 32, 16);
        let gain = b16.gops_per_s / (flow.gops() / (b1.total_millis / 1e3));
        println!("  batch-16 throughput gain: {gain:.2}x");
        h.check(gain >= 1.0, &format!("{model}: batch 16 never hurts"));
        if model == "alexnet" {
            // FC-heavy AlexNet gains much more than conv-dominated VGG
            h.check(
                gain > 1.3,
                &format!("alexnet batch-16 gain {gain:.2}x > 1.3 (fc weights amortized)"),
            );
        }
    }
    // AlexNet gains more than VGG (fc-dominated vs conv-dominated)
    let a = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();
    let v = ComputationFlow::extract(&zoo::build("vgg16", false).unwrap()).unwrap();
    let ga = simulate_batched(&a, &ARRIA_10_GX1150, 16, 32, 16).gops_per_s
        / simulate_batched(&a, &ARRIA_10_GX1150, 16, 32, 1).gops_per_s;
    let gv = simulate_batched(&v, &ARRIA_10_GX1150, 16, 32, 16).gops_per_s
        / simulate_batched(&v, &ARRIA_10_GX1150, 16, 32, 1).gops_per_s;
    h.check(
        ga > gv,
        &format!("batching helps AlexNet ({ga:.2}x) more than VGG ({gv:.2}x)"),
    );
    h.finish();
}
