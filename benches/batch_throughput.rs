//! Batch-size throughput sweep + the PR-8 perf gates — the paper's §5
//! remark: "There are also other latency reports in the literature such
//! as [7]. However, those latency reports are measured in the favorable
//! batch size (e.g. 16). Increasing batch size can make more
//! parallelism available to the algorithm that can lead to higher
//! throughput."
//!
//! Two tiers:
//!
//! * the analytical curve: per-frame latency and GOp/s for batch 1..32
//!   on both evaluation nets (`simulate_batched`), monotone in B;
//! * the stepped-full gates: on AlexNet/Arria-10 the cycle-accurate
//!   batched pipeline (`step_network_batched`) must serve ≥ 3x the
//!   frames/s at B = 16 that it serves at B = 1, and the rounds that
//!   are DDR-starved under the uniform streamed kernel at B = 1 must
//!   all flip compute-bound once the weight stream amortizes over the
//!   batch.
//!
//! Writes `BENCH_PR8.json` (machine-readable: stepped frames/s at B = 1
//! and B = 16, the speedup, the starved-round census, the analytical
//! batch-16 gains) for cross-commit comparison via
//! `tools/perf_compare.sh`. Every recorded metric is a deterministic
//! model output — no wall-clock — so the comparison cannot flake on
//! runner noise.

mod common;

use cnn2gate::estimator::device::ARRIA_10_GX1150;
use cnn2gate::estimator::estimate;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::zoo;
use cnn2gate::sim::{simulate, simulate_batched, step_network_batched, NetworkStepReport};
use cnn2gate::util::json::{Json, JsonObj};
use cnn2gate::util::table::Table;
use common::Harness;

/// DDR-starvation verdict threshold — the same 25% the stepped census
/// table uses to call a round memory-bound.
const STARVED_FRAC: f64 = 0.25;

/// Rounds whose conv lanes sat DDR-starved more than the verdict
/// threshold.
fn starved_rounds(net: &NetworkStepReport) -> usize {
    net.layers
        .iter()
        .filter(|l| l.conv_empty_stalls as f64 / l.cycles.max(1) as f64 > STARVED_FRAC)
        .count()
}

fn main() {
    let mut h = Harness::new();

    // -- analytical tier: the batch curve on both evaluation nets ------
    for model in ["alexnet", "vgg16"] {
        let flow = ComputationFlow::extract(&zoo::build(model, false).unwrap()).unwrap();
        h.bench(&format!("batch_sim/{model}"), 100, || {
            simulate_batched(&flow, &ARRIA_10_GX1150, 16, 32, 16)
        });
        let mut t = Table::new(
            format!("{model} on Arria 10 (16,32): batch sweep"),
            &["batch", "total (ms)", "ms/frame", "GOp/s", "fc1 bound"],
        );
        let mut prev = 0.0;
        let mut monotone = true;
        for batch in [1usize, 2, 4, 8, 16, 32] {
            let rep = simulate_batched(&flow, &ARRIA_10_GX1150, 16, 32, batch);
            monotone &= rep.gops_per_s >= prev - 1e-9;
            prev = rep.gops_per_s;
            let fc1 = rep.layers.iter().find(|l| !l.is_conv).map(|l| l.memory_bound);
            t.row(&[
                batch.to_string(),
                format!("{:.2}", rep.total_millis),
                format!("{:.2}", rep.millis_per_frame),
                format!("{:.1}", rep.gops_per_s),
                fc1.map_or("-".into(), |b| if b { "memory" } else { "compute" }.into()),
            ]);
        }
        println!("\n{}", t.render());
        h.check(monotone, &format!("{model}: throughput monotone in batch"));
        let b1 = simulate(&flow, &ARRIA_10_GX1150, 16, 32);
        let b16 = simulate_batched(&flow, &ARRIA_10_GX1150, 16, 32, 16);
        let gain = b16.gops_per_s / (flow.gops() / (b1.total_millis / 1e3));
        println!("  batch-16 throughput gain: {gain:.2}x");
        h.check(gain >= 1.0, &format!("{model}: batch 16 never hurts"));
        if model == "alexnet" {
            // FC-heavy AlexNet gains much more than conv-dominated VGG
            h.check(
                gain > 1.3,
                &format!("alexnet batch-16 gain {gain:.2}x > 1.3 (fc weights amortized)"),
            );
        }
    }
    // AlexNet gains more than VGG (fc-dominated vs conv-dominated)
    let a = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();
    let v = ComputationFlow::extract(&zoo::build("vgg16", false).unwrap()).unwrap();
    let gain_at_16 = |f: &ComputationFlow| {
        simulate_batched(f, &ARRIA_10_GX1150, 16, 32, 16).gops_per_s
            / simulate_batched(f, &ARRIA_10_GX1150, 16, 32, 1).gops_per_s
    };
    let (ga, gv) = (gain_at_16(&a), gain_at_16(&v));
    h.check(
        ga > gv,
        &format!("batching helps AlexNet ({ga:.2}x) more than VGG ({gv:.2}x)"),
    );

    // -- stepped-full tier: the PR-8 frames/s gate ---------------------
    // the uniform flow ships one generic (streamed) memory-read kernel,
    // so at B = 1 every AlexNet round re-fetches its weight slice per
    // reduction step and sits DDR-starved; holding the slice across a
    // 16-frame batch divides that stream by 16
    let est = estimate(&a, &ARRIA_10_GX1150, 16, 32);
    let b1 = step_network_batched(&a, &ARRIA_10_GX1150, est.fmax_mhz, 16, 32, 1);
    h.bench("stepped_full/alexnet_b16", 5, || {
        step_network_batched(&a, &ARRIA_10_GX1150, est.fmax_mhz, 16, 32, 16)
    });
    let b16 = step_network_batched(&a, &ARRIA_10_GX1150, est.fmax_mhz, 16, 32, 16);
    let speedup = b16.frames_per_s() / b1.frames_per_s();
    println!(
        "  stepped-full: B=1 {:.2} ms ({:.1} frames/s) -> B=16 {:.2} ms batch ({:.1} frames/s), {speedup:.2}x",
        b1.total_millis(),
        b1.frames_per_s(),
        b16.total_millis(),
        b16.frames_per_s(),
    );
    h.check(
        speedup >= 3.0,
        &format!("stepped-full B=16 serves {speedup:.2}x >= 3x the B=1 frames/s"),
    );
    h.check(
        b16.millis_per_frame() < b1.total_millis(),
        "amortized per-frame latency drops under batching",
    );
    let (s1, s16) = (starved_rounds(&b1), starved_rounds(&b16));
    let rounds = b1.layers.len();
    println!("  DDR-starved rounds (> {STARVED_FRAC:.2}): B=1 {s1}/{rounds}, B=16 {s16}/{rounds}");
    h.check(
        s1 == b1.layers.len(),
        &format!("B=1: all {s1}/{} rounds DDR-starved under the streamed kernel", b1.layers.len()),
    );
    h.check(
        s16 == 0,
        &format!("B=16: every round flips compute-bound ({s16} still starved)"),
    );

    // machine-readable PR-8 perf record — deterministic model outputs
    // only, so tools/perf_compare.sh diffs are noise-free
    {
        let mut stepped = JsonObj::new();
        stepped.insert("b1_batch_millis", b1.total_millis().into());
        stepped.insert("b16_batch_millis", b16.total_millis().into());
        stepped.insert("b1_frames_per_s", b1.frames_per_s().into());
        stepped.insert("b16_frames_per_s", b16.frames_per_s().into());
        stepped.insert("frames_per_s_speedup", speedup.into());
        stepped.insert("starved_rounds_b1", s1.into());
        stepped.insert("starved_rounds_b16", s16.into());
        let mut analytical = JsonObj::new();
        analytical.insert("alexnet_b16_gain", ga.into());
        analytical.insert("vgg16_b16_gain", gv.into());
        let mut doc = JsonObj::new();
        doc.insert("format", "cnn2gate-bench-pr8".into());
        doc.insert("stepped", Json::Obj(stepped));
        doc.insert("analytical", Json::Obj(analytical));
        let path = std::path::Path::new("BENCH_PR8.json");
        std::fs::write(path, Json::Obj(doc).to_string_pretty()).unwrap();
        println!("perf record written to {}", path.display());
    }

    h.finish();
}
