//! Table 3: comparison to existing works, AlexNet at (16,32) on the
//! Arria 10. Baselines are the published numbers; our row is computed
//! live. Shape checks assert the paper's who-wins claims.

mod common;

use cnn2gate::estimator::device::ARRIA_10_GX1150;
use cnn2gate::estimator::estimate;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::metrics;
use cnn2gate::onnx::zoo;
use cnn2gate::report::{baselines, comparison_table};
use cnn2gate::sim::simulate;
use common::Harness;

fn main() {
    let mut h = Harness::new();
    let flow = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();
    h.bench("table3/pipeline", 50, || {
        let est = estimate(&flow, &ARRIA_10_GX1150, 16, 32);
        let sim = simulate(&flow, &ARRIA_10_GX1150, 16, 32);
        (est, sim)
    });
    let est = estimate(&flow, &ARRIA_10_GX1150, 16, 32);
    let sim = simulate(&flow, &ARRIA_10_GX1150, 16, 32);
    let rows = baselines::alexnet();
    println!(
        "\n{}",
        comparison_table(
            "Table 3: Comparison to existing works, AlexNet (Ni,Nl)=(16,32)",
            &rows,
            &sim,
            (est.alms, est.p_lut),
            (est.dsps, est.p_dsp),
        )
        .render()
    );

    let ours_ms = sim.total_millis;
    let ours_gops = metrics::gops_per_s(sim.gops, ours_ms);
    let ours_density = metrics::gops_per_dsp(ours_gops, est.dsps);

    // paper row values
    h.check_close(ours_ms, 18.24, 0.12, "our latency (ms)");
    h.check_close(ours_gops, 80.04, 0.12, "our performance (GOp/s)");
    h.check_close(est.dsps, 300.0, 0.02, "our DSP count");
    h.check_close(est.p_lut, 30.0, 0.10, "our logic %");

    // who-wins claims of §5
    let zhang = rows.iter().find(|r| r.work.contains("[21]")).unwrap();
    let suda = rows.iter().find(|r| r.work.contains("[20]")).unwrap();
    let ma = rows.iter().find(|r| r.work.contains("[22]")).unwrap();
    let fpgaconvnet = rows.iter().find(|r| r.work.contains("[8]")).unwrap();
    h.check(
        ours_ms < zhang.latency_ms.unwrap(),
        "faster than [21] (paper: 'faster than [21, 20]')",
    );
    h.check(ours_ms < suda.latency_ms.unwrap(), "faster than [20]");
    h.check(
        ours_gops > suda.gops,
        "higher GOp/s than the OpenCL baseline [20]",
    );
    h.check(
        ours_density > metrics::gops_per_dsp(suda.gops, suda.dsp.unwrap().0),
        &format!("higher GOp/s/DSP than [20] ({ours_density:.3}, paper 0.266 vs 0.234)"),
    );
    h.check(
        ma.latency_ms.unwrap() < ours_ms && fpgaconvnet.latency_ms.unwrap() < ours_ms,
        "[22] and [8] remain faster on AlexNet (paper concedes this)",
    );
    h.check(
        (ours_density - 0.266).abs() / 0.266 < 0.15,
        &format!("performance density {ours_density:.3} ≈ paper 0.266"),
    );
    h.finish();
}
