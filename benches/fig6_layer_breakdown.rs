//! Fig. 6: per-layer execution-time breakdown of AlexNet on the Arria 10
//! at (16,32) — 5 fused conv/pool rounds + 3 FC rounds, with the
//! decreasing trend through the conv stack as feature dims shrink.

mod common;

use cnn2gate::estimator::device::ARRIA_10_GX1150;
use cnn2gate::estimator::estimate;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::zoo;
use cnn2gate::report::fig6;
use cnn2gate::sim::{simulate, simulate_layer};
use common::Harness;

fn main() {
    let mut h = Harness::new();
    let flow = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();
    let est = estimate(&flow, &ARRIA_10_GX1150, 16, 32);

    h.bench("fig6/per_layer_sim", 200, || {
        flow.layers
            .iter()
            .map(|l| simulate_layer(l, &ARRIA_10_GX1150, &est, 16, 32).cycles)
            .sum::<u64>()
    });

    let sim = simulate(&flow, &ARRIA_10_GX1150, 16, 32);
    println!("\n{}", fig6(&sim).render());

    let t: Vec<f64> = sim.layers.iter().map(|l| l.millis).collect();
    h.check(t.len() == 8, "8 rounds: 5 fused conv/pool + 3 FC (paper Fig 6)");
    h.check(
        t[1] >= t[2] && t[2] >= t[4],
        "conv execution time decreases as feature dims shrink (L2 -> L5)",
    );
    h.check(t[1] >= t[0], "conv2 carries the most conv MACs");
    h.check(t[5] >= t[6] && t[6] >= t[7], "FC tail decreases with weight size");
    h.check(
        sim.layers[..5].iter().all(|l| !l.memory_bound),
        "conv rounds are lane-bound",
    );
    h.check(
        sim.layers[5..].iter().all(|l| l.memory_bound),
        "FC rounds are DDR-bound (weights stream once per frame)",
    );
    let sum: f64 = t.iter().sum();
    h.check_close(sum, sim.total_millis, 1e-9, "breakdown sums to the total");
    h.finish();
}
