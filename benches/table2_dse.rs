//! Table 2: synthesis + DSE details for AlexNet on the three boards —
//! RL-DSE vs BF-DSE timing, synthesis-time model, chosen options,
//! "does not fit" on the 5CSEMA4 — plus the parallel-evaluation section:
//! sequential seed path vs the `dse::eval` pool at full-network stepped
//! (cycle-accurate) candidate fidelity, with fresh caches on both sides
//! and a chosen-design identity check. Since PR 3's epoch skip-ahead
//! engine, a stepped candidate costs ~ms, not ~s, so the gate here is
//! interactivity of the whole stepped grid rather than a parallel
//! speedup ratio (the pool's speedup on heavy workloads is demonstrated
//! by `hotpath_micro`'s reference-vs-skip-ahead section instead).

mod common;

use std::sync::Arc;
use std::time::Instant;

use cnn2gate::dse::{brute, eval, rl, EvalRequest, Evaluation, Evaluator, Fidelity, RlConfig};
use cnn2gate::dse::{OptionSpace, RewardShaper};
use cnn2gate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4, CYCLONE_V_5CSEMA5};
use cnn2gate::estimator::Thresholds;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::metrics;
use cnn2gate::onnx::zoo;
use cnn2gate::report::table2;
use cnn2gate::session::{CompileJob, Session};
use cnn2gate::synth::Explorer;
use common::Harness;

/// Algorithm-1 reduction over an evaluated grid (order-preserving, so
/// this is exactly what `brute::explore` chooses).
fn choose(grid: &[(Arc<Evaluation>, bool)], th: Thresholds) -> Option<(usize, usize)> {
    let mut shaper = RewardShaper::new(th);
    for (eval, _) in grid {
        shaper.eval(&eval.estimate);
    }
    shaper.h_best
}

fn main() {
    let mut h = Harness::new();
    let graph = zoo::build("alexnet", false).unwrap();
    let flow = ComputationFlow::extract(&graph).unwrap();
    let th = Thresholds::default();

    // time the explorers themselves (the thing Table 2 compares)
    h.bench("dse/bf_seq/arria10 (seed path)", 200, || {
        brute::explore_seq(&flow, &ARRIA_10_GX1150, th)
    });
    h.bench("dse/bf/arria10 (pool + warm memo)", 200, || {
        brute::explore(&flow, &ARRIA_10_GX1150, th)
    });
    h.bench("dse/rl/arria10", 200, || {
        rl::explore(&flow, &ARRIA_10_GX1150, th, RlConfig::default())
    });

    // --- parallel vs sequential exploration, stepped fidelity -------------
    // Here each candidate runs the cycle-stepped simulator on EVERY round
    // of AlexNet (the ground-truth latency census). The epoch skip-ahead
    // engine makes that ~ms-scale per candidate, so the whole stepped
    // grid must stay interactive. Both sides start from a fresh cache.
    let pairs = OptionSpace::from_flow(&flow).pairs();
    let threads = eval::default_threads();

    let stepped = EvalRequest::at(Fidelity::SteppedFullNetwork);
    let seq_ev = Evaluator::new(1);
    let t0 = Instant::now();
    let seq_grid = seq_ev.evaluate_grid(&flow, &ARRIA_10_GX1150, &pairs, stepped);
    let seq_s = t0.elapsed().as_secs_f64();

    let par_ev = Evaluator::new(threads);
    let t0 = Instant::now();
    let par_grid = par_ev.evaluate_grid(&flow, &ARRIA_10_GX1150, &pairs, stepped);
    let par_s = t0.elapsed().as_secs_f64();

    let speedup = metrics::speedup(seq_s, par_s);
    println!(
        "bench dse/bf_stepped_full/arria10  sequential {seq_s:.3} s  parallel({threads} threads) \
         {par_s:.3} s  speedup {speedup:.2}x  ({:.1} vs {:.1} candidates/s)",
        metrics::candidates_per_s(pairs.len(), seq_s),
        metrics::candidates_per_s(pairs.len(), par_s)
    );

    let seq_best = choose(&seq_grid, th);
    let par_best = choose(&par_grid, th);
    let seed_best = brute::explore_seq(&flow, &ARRIA_10_GX1150, th).best;
    h.check(
        seq_best == par_best && par_best == seed_best,
        &format!("parallel + sequential + seed paths agree on H_best {par_best:?}"),
    );
    h.check(
        par_grid.iter().zip(&seq_grid).all(|((p, _), (s, _))| {
            p.estimate == s.estimate && p.stepped_network == s.stepped_network
        }),
        "parallel grid estimates + censuses bit-identical to sequential",
    );
    h.check(
        seq_s < 2.0,
        &format!("full-network stepped grid stays interactive ({seq_s:.3} s sequential)"),
    );
    h.check(
        par_grid.iter().all(|(e, _)| {
            e.stepped_network.as_ref().is_some_and(|n| n.layers.len() == flow.layers.len())
        }),
        "every candidate carries a full per-round census",
    );

    // warm-memo exploration: the second fleet/RL visit of a candidate is
    // a pointer clone, not an estimator + simulator call
    let warm = Evaluator::new(threads);
    warm.evaluate_grid(&flow, &ARRIA_10_GX1150, &pairs, EvalRequest::at(Fidelity::Analytical));
    let wt = h.bench("dse/bf/arria10 (private warm memo)", 200, || {
        brute::explore_with(&warm, &flow, &ARRIA_10_GX1150, th)
    });
    let stats = warm.cache().stats();
    h.check(
        stats.hit_rate() > 0.9,
        &format!("warm memo serves repeats ({:.0}% hit rate)", 100.0 * stats.hit_rate()),
    );
    h.check(wt < 5e-3, "warm exploration stays interactive (<5 ms)");

    // one 1×3 CompileJob supplies the synth column for all three boards
    let boards = [&CYCLONE_V_5CSEMA4, &CYCLONE_V_5CSEMA5, &ARRIA_10_GX1150];
    let session = Session::builder().build();
    let outcome = session
        .run(
            &CompileJob::builder()
                .model(graph)
                .devices(boards)
                .explorer(Explorer::BruteForce)
                .build()
                .unwrap(),
        )
        .unwrap();
    let mut reports = Vec::new();
    for (rep, dev) in outcome.entries.into_iter().zip(boards) {
        let rl_res = rl::explore(&flow, dev, th, RlConfig::default());
        let bf_res = brute::explore(&flow, dev, th);
        reports.push((rep, rl_res, bf_res));
    }
    let refs: Vec<_> = reports.iter().map(|(a, b, c)| (a, b, c)).collect();
    println!("\n{}", table2(&refs).render());

    // --- paper-shape checks ------------------------------------------------
    let (rep4, rl4, _) = &reports[0];
    h.check(!rep4.fits(), "5CSEMA4: does not fit (paper)");
    h.check(rl4.best.is_none(), "5CSEMA4: RL agrees nothing fits");

    let (rep5, rl5, bf5) = &reports[1];
    h.check(rep5.option() == Some((8, 8)), "5CSEMA5 picks (8,8) (paper)");
    h.check_close(rep5.synthesis_minutes.unwrap(), 46.0, 0.15, "5CSEMA5 synthesis minutes");
    h.check_close(bf5.modeled_seconds / 60.0, 3.5, 0.15, "5CSEMA5 BF-DSE minutes");
    h.check(
        rl5.modeled_seconds < bf5.modeled_seconds,
        &format!(
            "RL-DSE faster than BF-DSE ({:.1} vs {:.1} min, paper 2.5 vs 3.5)",
            rl5.modeled_seconds / 60.0,
            bf5.modeled_seconds / 60.0
        ),
    );

    let (rep10, rl10, bf10) = &reports[2];
    h.check(rep10.option() == Some((16, 32)), "Arria 10 picks (16,32) (paper)");
    h.check_close(rep10.synthesis_minutes.unwrap() / 60.0, 8.5, 0.10, "Arria 10 synthesis hours");
    h.check_close(bf10.modeled_seconds / 60.0, 4.0, 0.15, "Arria 10 BF-DSE minutes");
    let rl_speedup = 1.0 - rl10.modeled_seconds / bf10.modeled_seconds;
    h.check(
        (0.05..0.50).contains(&rl_speedup),
        &format!("RL speedup {:.0}% (paper ~25%)", rl_speedup * 100.0),
    );
    // consumed resources at the chosen option (Table 2 anchors)
    let est = rep5.estimate.as_ref().unwrap();
    h.check_close(est.alms, 26_000.0, 0.06, "5CSEMA5 ALMs consumed");
    h.check_close(est.dsps, 72.0, 0.02, "5CSEMA5 DSPs consumed");
    h.check_close(est.ram_blocks, 397.0, 0.06, "5CSEMA5 RAM blocks consumed");
    h.check_close(est.mem_bits, 2.0e6, 0.25, "5CSEMA5 memory bits consumed (~2 Mbit)");
    h.finish();
}
