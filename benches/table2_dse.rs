//! Table 2: synthesis + DSE details for AlexNet on the three boards —
//! RL-DSE vs BF-DSE timing, synthesis-time model, chosen options,
//! "does not fit" on the 5CSEMA4.

mod common;

use cnn2gate::dse::{brute, rl, RlConfig};
use cnn2gate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4, CYCLONE_V_5CSEMA5};
use cnn2gate::estimator::Thresholds;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::zoo;
use cnn2gate::report::table2;
use cnn2gate::synth::{self, Explorer};
use common::Harness;

fn main() {
    let mut h = Harness::new();
    let graph = zoo::build("alexnet", false).unwrap();
    let flow = ComputationFlow::extract(&graph).unwrap();
    let th = Thresholds::default();

    // time the explorers themselves (the thing Table 2 compares)
    h.bench("dse/bf/arria10", 200, || brute::explore(&flow, &ARRIA_10_GX1150, th));
    h.bench("dse/rl/arria10", 200, || {
        rl::explore(&flow, &ARRIA_10_GX1150, th, RlConfig::default())
    });

    let mut reports = Vec::new();
    for dev in [&CYCLONE_V_5CSEMA4, &CYCLONE_V_5CSEMA5, &ARRIA_10_GX1150] {
        let rep = synth::run(&graph, dev, Explorer::BruteForce, th, None).unwrap();
        let rl_res = rl::explore(&flow, dev, th, RlConfig::default());
        let bf_res = brute::explore(&flow, dev, th);
        reports.push((rep, rl_res, bf_res));
    }
    let refs: Vec<_> = reports.iter().map(|(a, b, c)| (a, b, c)).collect();
    println!("\n{}", table2(&refs).render());

    // --- paper-shape checks ------------------------------------------------
    let (rep4, rl4, _) = &reports[0];
    h.check(!rep4.fits(), "5CSEMA4: does not fit (paper)");
    h.check(rl4.best.is_none(), "5CSEMA4: RL agrees nothing fits");

    let (rep5, rl5, bf5) = &reports[1];
    h.check(rep5.option() == Some((8, 8)), "5CSEMA5 picks (8,8) (paper)");
    h.check_close(rep5.synthesis_minutes.unwrap(), 46.0, 0.15, "5CSEMA5 synthesis minutes");
    h.check_close(bf5.modeled_seconds / 60.0, 3.5, 0.15, "5CSEMA5 BF-DSE minutes");
    h.check(
        rl5.modeled_seconds < bf5.modeled_seconds,
        &format!(
            "RL-DSE faster than BF-DSE ({:.1} vs {:.1} min, paper 2.5 vs 3.5)",
            rl5.modeled_seconds / 60.0,
            bf5.modeled_seconds / 60.0
        ),
    );

    let (rep10, rl10, bf10) = &reports[2];
    h.check(rep10.option() == Some((16, 32)), "Arria 10 picks (16,32) (paper)");
    h.check_close(rep10.synthesis_minutes.unwrap() / 60.0, 8.5, 0.10, "Arria 10 synthesis hours");
    h.check_close(bf10.modeled_seconds / 60.0, 4.0, 0.15, "Arria 10 BF-DSE minutes");
    let speedup = 1.0 - rl10.modeled_seconds / bf10.modeled_seconds;
    h.check(
        (0.05..0.50).contains(&speedup),
        &format!("RL speedup {:.0}% (paper ~25%)", speedup * 100.0),
    );
    // consumed resources at the chosen option (Table 2 anchors)
    let est = rep5.estimate.as_ref().unwrap();
    h.check_close(est.alms, 26_000.0, 0.06, "5CSEMA5 ALMs consumed");
    h.check_close(est.dsps, 72.0, 0.02, "5CSEMA5 DSPs consumed");
    h.check_close(est.ram_blocks, 397.0, 0.06, "5CSEMA5 RAM blocks consumed");
    h.check_close(est.mem_bits, 2.0e6, 0.25, "5CSEMA5 memory bits consumed (~2 Mbit)");
    h.finish();
}
