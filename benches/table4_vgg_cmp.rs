//! Table 4: comparison to existing works, VGG-16 at (16,32) on the
//! Arria 10 — including the paper's "18% lower latency than [8] despite
//! fewer DSPs" headline and the concession to hand-tailored RTL [10].

mod common;

use cnn2gate::estimator::device::ARRIA_10_GX1150;
use cnn2gate::estimator::estimate;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::metrics;
use cnn2gate::onnx::zoo;
use cnn2gate::report::{baselines, comparison_table};
use cnn2gate::sim::simulate;
use common::Harness;

fn main() {
    let mut h = Harness::new();
    let vflow = ComputationFlow::extract(&zoo::build("vgg16", false).unwrap()).unwrap();
    let aflow = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();

    h.bench("table4/sim_vgg16", 30, || {
        simulate(&vflow, &ARRIA_10_GX1150, 16, 32).total_millis
    });
    let est = estimate(&aflow, &ARRIA_10_GX1150, 16, 32); // paper reports AlexNet-fit resources
    let sim = simulate(&vflow, &ARRIA_10_GX1150, 16, 32);
    let rows = baselines::vgg16();
    println!(
        "\n{}",
        comparison_table(
            "Table 4: Comparison to existing works, VGG-16 (Ni,Nl)=(16,32)",
            &rows,
            &sim,
            (est.alms, est.p_lut),
            (est.dsps, est.p_dsp),
        )
        .render()
    );

    let ours_ms = sim.total_millis;
    let ours_gops = metrics::gops_per_s(sim.gops, ours_ms);
    h.check_close(ours_ms, 205.0, 0.17, "our VGG-16 latency (ms)");
    h.check_close(ours_gops, 151.7, 0.20, "our VGG-16 performance (GOp/s)");

    let fpgaconvnet = rows.iter().find(|r| r.work.contains("[8]")).unwrap();
    let ma = rows.iter().find(|r| r.work.contains("[10]")).unwrap();
    let suda = rows.iter().find(|r| r.work.contains("[20]")).unwrap();
    h.check(
        ours_ms < fpgaconvnet.latency_ms.unwrap(),
        &format!(
            "lower latency than [8] ({:.0} vs {:.0} ms; paper: 18% lower)",
            ours_ms,
            fpgaconvnet.latency_ms.unwrap()
        ),
    );
    h.check(
        est.dsps < fpgaconvnet.dsp.unwrap().0,
        "using fewer DSPs than [8] (paper claim)",
    );
    h.check(ours_ms < suda.latency_ms.unwrap(), "faster than the OpenCL baseline [20]");
    h.check(
        ma.latency_ms.unwrap() < ours_ms,
        "hand-tailored RTL [10] remains faster (paper concedes)",
    );

    // "CNN2Gate is performing better for larger neural networks": the
    // VGG GOp/s must exceed the AlexNet GOp/s on the same fit
    let asim = simulate(&aflow, &ARRIA_10_GX1150, 16, 32);
    let a_gops = metrics::gops_per_s(asim.gops, asim.total_millis);
    h.check(
        ours_gops > a_gops,
        &format!("VGG throughput {ours_gops:.1} > AlexNet {a_gops:.1} GOp/s (paper 151.7 vs 80.04)"),
    );
    h.finish();
}
