"""L1 tile-policy tests: the (N_i, N_l) -> BlockSpec mapping that carries
the paper's hardware semantics onto the MXU (DESIGN.md §4, §9)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.conv_lane import (
    LANE_TILE_M,
    MAX_LANE_GROUPS,
    MAX_VEC_STEPS,
    block_sizes,
    lane_tile_shapes,
)

settings.register_profile("repo", max_examples=50, deadline=None)
settings.load_profile("repo")

VMEM_BYTES = 16 * 1024 * 1024


@given(
    m=st.integers(1, 60000),
    k=st.integers(1, 9216),
    n=st.integers(1, 4096),
    ni=st.sampled_from([4, 8, 16]),
    nl=st.sampled_from([4, 8, 16, 32]),
)
def test_block_sizes_respect_lane_granularity(m, k, n, ni, nl):
    bm, bk, bn = block_sizes(m, k, n, ni, nl)
    # paper semantics: tiles are whole numbers of N_i vectors / N_l lanes
    assert bk % ni == 0
    assert bn % nl == 0
    # caps
    assert bk <= ni * MAX_VEC_STEPS
    assert bn <= nl * MAX_LANE_GROUPS
    assert 8 <= bm <= max(8, LANE_TILE_M)
    # tiles never larger than the (padded) problem needs
    assert bk <= ((k + ni - 1) // ni) * ni
    assert bn <= ((n + nl - 1) // nl) * nl


@given(
    k=st.integers(1, 9216),
    n=st.integers(1, 4096),
    ni=st.sampled_from([4, 8, 16]),
    nl=st.sampled_from([4, 8, 16, 32]),
)
def test_vmem_budget_for_paper_options(k, n, ni, nl):
    """DESIGN.md §9: the working set of one tile (A + B + O, f32) must fit
    a 16 MB VMEM with double-buffering headroom (x2)."""
    bm, bk, bn = lane_tile_shapes(ni, nl, k, n)
    working = 4 * (bm * bk + bk * bn + bm * bn)
    assert 2 * working <= VMEM_BYTES, f"tile ({bm},{bk},{bn}) blows VMEM"


def test_paper_option_tiles_are_mxu_aligned():
    # at the paper's Arria 10 option, tiles cover full 128x128 MXU tiles
    bm, bk, bn = lane_tile_shapes(16, 32, k=1728, n=384)
    assert bm % 128 == 0
    assert bk % 16 == 0 and bk >= 128
    assert bn % 32 == 0 and bn >= 128


def test_grid_step_budget_for_vgg_worst_layer():
    """Perf regression guard (EXPERIMENTS.md §Perf): VGG conv1_2
    (M=50176, K=576, N=64) must lower to a small grid — the 90 s/layer
    pathology came from a 1960-step grid."""
    m, k, n = 50176, 576, 64
    bm, bk, bn = block_sizes(m, k, n, 16, 32)
    steps = -(-m // bm) * -(-k // bk) * -(-n // bn)
    assert steps <= 32, f"{steps} grid steps"
