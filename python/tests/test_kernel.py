"""Pallas kernel vs pure-jnp reference — the CORE correctness signal.

hypothesis sweeps shapes, strides, pads, dilations and the (N_i, N_l)
lane options; every property asserts allclose (float) or exact equality
(fixed point) against compile.kernels.ref.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import conv_lane, pool, quantized, ref

settings.register_profile("repo", max_examples=25, deadline=None)
settings.load_profile("repo")


def _f32(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(0.0, scale, size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# matmul lane kernel
# ---------------------------------------------------------------------------


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 96),
    n=st.integers(1, 80),
    ni=st.sampled_from([4, 8, 16]),
    nl=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_lanes_matches_ref(m, k, n, ni, nl, seed):
    rng = np.random.default_rng(seed)
    a = _f32(rng, (m, k))
    b = _f32(rng, (k, n))
    got = conv_lane.matmul_lanes(a, b, ni=ni, nl=nl)
    exp = ref.matmul(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-4, atol=1e-4)


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 64),
    n=st.integers(1, 48),
    ni=st.sampled_from([4, 8, 16]),
    nl=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_lanes_exact(m, k, n, ni, nl, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-128, 128, size=(m, k), dtype=np.int8))
    b = jnp.asarray(rng.integers(-128, 128, size=(k, n), dtype=np.int8))
    got = quantized.qmatmul_lanes(a, b, ni=ni, nl=nl)
    exp = a.astype(jnp.int32) @ b.astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


# ---------------------------------------------------------------------------
# conv lane kernel
# ---------------------------------------------------------------------------


@given(
    cin=st.integers(1, 6),
    cout=st.integers(1, 12),
    hw=st.integers(5, 20),
    k=st.sampled_from([1, 3, 5]),
    s=st.sampled_from([1, 2, 3]),
    p=st.sampled_from([0, 1, 2]),
    d=st.sampled_from([1, 2]),
    ni=st.sampled_from([4, 8]),
    nl=st.sampled_from([4, 8]),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_lanes_matches_ref(cin, cout, hw, k, s, p, d, ni, nl, relu, seed):
    if hw + 2 * p < d * (k - 1) + 1:
        return  # degenerate: no output pixels
    rng = np.random.default_rng(seed)
    x = _f32(rng, (cin, hw, hw + 1))
    w = _f32(rng, (cout, cin, k, k), scale=0.5)
    b = _f32(rng, (cout,))
    got = conv_lane.conv2d_lanes(
        x, w, b, stride=(s, s), pad=(p, p), dilation=(d, d), ni=ni, nl=nl, apply_relu=relu
    )
    exp = ref.conv2d(x, w, b, stride=(s, s), pad=(p, p), dilation=(d, d))
    if relu:
        exp = ref.relu(exp)
    assert got.shape == exp.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-3, atol=1e-3)


@given(
    n=st.integers(1, 64),
    k=st.integers(1, 128),
    ni=st.sampled_from([4, 16]),
    nl=st.sampled_from([8, 32]),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_lanes_matches_ref(n, k, ni, nl, relu, seed):
    rng = np.random.default_rng(seed)
    x = _f32(rng, (k,))
    w = _f32(rng, (n, k))
    b = _f32(rng, (n,))
    got = conv_lane.gemm_lanes(x, w, b, ni=ni, nl=nl, apply_relu=relu)
    exp = ref.gemm(x, w, b)
    if relu:
        exp = ref.relu(exp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# pool kernel
# ---------------------------------------------------------------------------


@given(
    c=st.integers(1, 10),
    hw=st.integers(4, 24),
    k=st.sampled_from([2, 3]),
    s=st.sampled_from([1, 2, 3]),
    p=st.sampled_from([0, 1]),
    nl=st.sampled_from([2, 4, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_lanes_matches_ref(c, hw, k, s, p, nl, seed):
    if p >= k:  # XLA forbids pad >= window
        return
    rng = np.random.default_rng(seed)
    x = _f32(rng, (c, hw, hw))
    got = pool.maxpool2d_lanes(x, (k, k), (s, s), (p, p), nl=nl)
    exp = ref.maxpool2d(x, (k, k), (s, s), (p, p))
    assert got.shape == exp.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


# ---------------------------------------------------------------------------
# quantized conv / gemm (exact fixed-point equality)
# ---------------------------------------------------------------------------


@given(
    cin=st.integers(1, 4),
    cout=st.integers(1, 8),
    hw=st.integers(5, 14),
    k=st.sampled_from([1, 3]),
    s=st.sampled_from([1, 2]),
    p=st.sampled_from([0, 1]),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_qconv2d_lanes_exact(cin, cout, hw, k, s, p, relu, seed):
    rng = np.random.default_rng(seed)
    cfg = dict(m_in=4, m_w=5, m_out=3)
    xq = jnp.asarray(rng.integers(-128, 128, size=(cin, hw, hw), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-128, 128, size=(cout, cin, k, k), dtype=np.int8))
    bq = jnp.asarray(rng.integers(-(2**15), 2**15, size=(cout,), dtype=np.int32))
    got = quantized.qconv2d_lanes(
        xq, wq, bq, cfg, stride=(s, s), pad=(p, p), ni=4, nl=4, apply_relu=relu
    )
    exp = ref.qconv2d(xq, wq, bq, cfg, stride=(s, s), pad=(p, p), apply_relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


@given(
    n=st.integers(1, 32),
    k=st.integers(1, 64),
    relu=st.booleans(),
    m_out=st.integers(0, 7),
    seed=st.integers(0, 2**31 - 1),
)
def test_qgemm_lanes_exact(n, k, relu, m_out, seed):
    rng = np.random.default_rng(seed)
    cfg = dict(m_in=4, m_w=5, m_out=m_out)
    xq = jnp.asarray(rng.integers(-128, 128, size=(k,), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-128, 128, size=(n, k), dtype=np.int8))
    bq = jnp.asarray(rng.integers(-(2**15), 2**15, size=(n,), dtype=np.int32))
    got = quantized.qgemm_lanes(xq, wq, bq, cfg, ni=4, nl=4, apply_relu=relu)
    exp = ref.qgemm(xq, wq, bq, cfg, apply_relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


# ---------------------------------------------------------------------------
# fixed-point primitives (properties, not examples)
# ---------------------------------------------------------------------------


@given(
    m=st.integers(0, 7),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_saturates_and_bounds_error(m, seed):
    rng = np.random.default_rng(seed)
    x = _f32(rng, (64,), scale=10.0)
    q = ref.quantize(x, m)
    assert int(jnp.min(q)) >= -128 and int(jnp.max(q)) <= 127
    deq = ref.dequantize(q, m)
    # inside the representable range the error is bounded by half an LSB
    inside = (x * 2.0**m > -128) & (x * 2.0**m < 127)
    err = jnp.abs(deq - x) * inside
    assert float(jnp.max(err)) <= 0.5 * 2.0**-m + 1e-6


@given(
    m_acc=st.integers(0, 20),
    m_out=st.integers(0, 7),
    seed=st.integers(0, 2**31 - 1),
)
def test_requantize_monotone_and_saturating(m_acc, m_out, seed):
    rng = np.random.default_rng(seed)
    acc = jnp.asarray(np.sort(rng.integers(-(2**24), 2**24, size=(128,), dtype=np.int32)))
    out = ref.requantize(acc, m_acc, m_out)
    o = np.asarray(out, dtype=np.int32)
    assert (np.diff(o) >= 0).all(), "requantize must be monotone"
    assert o.min() >= -128 and o.max() <= 127


def test_conv_out_hw_matches_paper_examples():
    # AlexNet conv1: 224x224, k=11, s=4, p=2 -> 55x55
    assert ref.conv_out_hw((224, 224), (11, 11), (4, 4), (2, 2), (1, 1)) == (55, 55)
    # VGG conv: 224x224, k=3, s=1, p=1 -> 224x224
    assert ref.conv_out_hw((224, 224), (3, 3), (1, 1), (1, 1), (1, 1)) == (224, 224)
    # AlexNet pool: 55x55, k=3, s=2 -> 27x27
    assert ref.conv_out_hw((55, 55), (3, 3), (2, 2), (0, 0), (1, 1)) == (27, 27)
