"""L2 model tests: topology shapes, Pallas-vs-reference forward equality,
quantized forward sanity, GOp/param census vs the paper's figures."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model as M
from compile.kernels import ref


def test_zoo_names():
    assert set(M.TOPOLOGIES) == {"tiny", "lenet5", "alexnet", "vgg16"}


@pytest.mark.parametrize("name", ["tiny", "lenet5", "alexnet", "vgg16"])
def test_layer_shapes_terminate_at_classifier(name):
    topo = M.TOPOLOGIES[name]()
    shapes = M.layer_shapes(topo)
    assert shapes[-1][2] == (topo["layers"][-1]["cout"],)


def test_alexnet_shapes_match_paper():
    topo = M.alexnet_topology()
    shapes = [s for _, _, s in M.layer_shapes(topo)]
    assert shapes[0] == (64, 55, 55)  # conv1
    assert shapes[1] == (64, 27, 27)  # pool1
    assert shapes[2] == (192, 27, 27)  # conv2
    assert shapes[7] == (256, 6, 6)  # pool5 -> 9216 flatten
    assert shapes[-1] == (1000,)


def test_vgg16_has_13_convs_5_pools_3_fcs():
    topo = M.vgg16_topology()
    ops = [l["op"] for l in topo["layers"]]
    assert ops.count("Conv") == 13
    assert ops.count("MaxPool") == 5
    assert ops.count("Gemm") == 3


def test_gops_match_paper_headline():
    # paper implies 1.46 GOp (80.04 GOp/s @ 18.24 ms) and 31.1 GOp
    # (151.7 GOp/s @ 205 ms); our census counts MAC=2 ops
    assert abs(M.gops(M.alexnet_topology()) - 1.46) < 0.1
    assert abs(M.gops(M.vgg16_topology()) - 31.1) < 0.5
    assert abs(M.param_count(M.alexnet_topology()) / 1e6 - 61) < 1.0
    assert abs(M.param_count(M.vgg16_topology()) / 1e6 - 138) < 1.0


@pytest.mark.parametrize("name", ["tiny", "lenet5"])
def test_forward_pallas_matches_reference(name):
    topo = M.TOPOLOGIES[name]()
    params = [jnp.asarray(p) for p in M.init_params(topo, seed=3)]
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=tuple(topo["input_shape"])).astype(np.float32))
    got = M.build_forward(topo, ni=8, nl=8)(x, *params)[0]
    exp = M.build_forward(topo, use_pallas=False)(x, *params)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(jnp.sum(got)), 1.0, rtol=1e-5)  # softmax


@pytest.mark.parametrize("name", ["tiny", "lenet5"])
def test_forward_int8_pallas_matches_reference(name):
    topo = M.TOPOLOGIES[name]()
    params = [jnp.asarray(p) for p in M.init_params(topo, seed=3, quantized_model=True)]
    rng = np.random.default_rng(7)
    x = rng.normal(size=tuple(topo["input_shape"])).astype(np.float32)
    xq = ref.quantize(jnp.asarray(x), M.DEFAULT_QCFG["m_in"])
    got = M.build_forward_int8(topo, ni=8, nl=8)(xq, *params)[0]
    exp = M.build_forward_int8(topo, use_pallas=False)(xq, *params)[0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_int8_forward_tracks_float_argmax():
    """Quantized inference should usually agree with float inference on the
    top-1 class — the property the paper's emulation mode exists to check."""
    topo = M.lenet5_topology()
    fparams = [jnp.asarray(p) for p in M.init_params(topo, seed=11)]
    qparams = [jnp.asarray(p) for p in M.init_params(topo, seed=11, quantized_model=True)]
    fwd_f = M.build_forward(topo, use_pallas=False)
    fwd_q = M.build_forward_int8(topo, use_pallas=False)
    rng = np.random.default_rng(5)
    agree = 0
    n = 8
    for _ in range(n):
        x = rng.normal(size=tuple(topo["input_shape"])).astype(np.float32) * 0.5
        xq = ref.quantize(jnp.asarray(x), M.DEFAULT_QCFG["m_in"])
        f = fwd_f(jnp.asarray(x), *fparams)[0]
        q = fwd_q(xq, *qparams)[0]
        agree += int(jnp.argmax(f)) == int(jnp.argmax(q.astype(jnp.int32)))
    assert agree >= n - 2, f"int8 argmax agreed only {agree}/{n}"


def test_param_specs_quantized_dtypes():
    specs = M.param_specs(M.tiny_topology(), quantized_model=True)
    for name, _, dtype in specs:
        assert dtype == ("int8" if name.endswith("_w") else "int32")
