"""AOT pipeline tests: HLO text well-formedness, manifest consistency,
golden round-trip, ONNX-subset export structure."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np
import pytest

import jax.numpy as jnp

from compile import aot, model as M

REPO = Path(__file__).resolve().parents[2]
ART = REPO / "artifacts"


def test_lower_tiny_produces_hlo_text():
    _, _, exposed, (ishape, idt), qcfg, hlo = aot.lower_model("tiny", 8, 8)
    assert hlo.startswith("HloModule")
    assert "ENTRY" in hlo
    assert idt == "float32" and qcfg is None
    # parameter count: image + (w, b) per learnable layer
    assert len(exposed) == 2 * sum(
        1 for l in M.tiny_topology()["layers"] if l["op"] in ("Conv", "Gemm")
    )


def test_lower_tiny_int8_exposes_int32_boundary():
    _, _, exposed, (ishape, idt), qcfg, hlo = aot.lower_model("tiny_int8", 8, 8)
    assert idt == "int32"
    assert all(d == "int32" for _, _, d in exposed)
    assert qcfg == M.DEFAULT_QCFG
    assert "s8" in hlo, "int8 codes must appear inside the quantized graph"


def test_golden_replay_in_python():
    """The golden file must reproduce through an independent forward pass."""
    topo = M.tiny_topology()
    x, params = aot.make_inputs("tiny", topo)
    fwd = M.build_forward(topo, ni=16, nl=32)
    out = np.asarray(fwd(jnp.asarray(x), *[jnp.asarray(p) for p in params])[0])
    out2 = np.asarray(fwd(jnp.asarray(x), *[jnp.asarray(p) for p in params])[0])
    np.testing.assert_array_equal(out, out2)  # determinism
    assert abs(float(out.sum()) - 1.0) < 1e-5


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
def test_manifest_consistent_with_files():
    man = json.loads((ART / "manifest.json").read_text())
    assert man["format"] == "cnn2gate-artifacts-v1"
    for name, entry in man["models"].items():
        assert (ART / entry["hlo"]).exists(), f"{name} hlo missing"
        text = (ART / entry["hlo"]).read_text()
        assert text.startswith("HloModule")
        if "golden" in entry:
            g = entry["golden"]
            assert (ART / g["file"]).stat().st_size == g["nbytes"]
            # offsets are sorted & within the file
            offs = [a["offset"] for a in g["arrays"]]
            assert offs == sorted(offs) and offs[0] == 0


@pytest.mark.skipif(not (ART / "models/lenet5.json").exists(), reason="run `make artifacts` first")
def test_onnx_subset_export_structure():
    doc = json.loads((ART / "models/lenet5.json").read_text())
    assert doc["format"] == "cnn2gate-onnx-subset-v1"
    ops = [n["op_type"] for n in doc["nodes"]]
    assert ops.count("Conv") == 2 and ops.count("Gemm") == 3
    assert ops.count("MaxPool") == 2 and ops[-1] == "Softmax"
    # every initializer referenced by some node, offsets contiguous
    referenced = {i for n in doc["nodes"] for i in n["inputs"]}
    offset = 0
    for init in doc["initializers"]:
        assert init["name"] in referenced
        assert init["offset"] == offset
        offset += init["nbytes"]
    bin_path = ART / "models" / doc["external_data"]
    assert bin_path.stat().st_size == offset


@pytest.mark.skipif(not (ART / "models/vgg16.json").exists(), reason="run `make artifacts` first")
def test_onnx_subset_large_models_have_no_external_data():
    doc = json.loads((ART / "models/vgg16.json").read_text())
    assert doc["external_data"] is None
    assert len([n for n in doc["nodes"] if n["op_type"] == "Conv"]) == 13
