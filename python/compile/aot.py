"""AOT lowering: JAX/Pallas models -> HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/load_hlo/).

Outputs, under ``artifacts/``:

  <name>.hlo.txt        one HLO module per model variant; parameters are
                        (image, l0_w, l0_b, ...) in topology order so the
                        Rust side can feed PJRT literals positionally
  golden_<name>.bin     flat little-endian dump of input + params +
                        expected output (small models only) — the Rust
                        integration tests replay these through PJRT
  models/<name>.json    the ONNX-subset graph the Rust front-end parses
  models/<name>.bin     raw initializer data for the JSON (small models)
  manifest.json         index of everything above (shapes, dtypes, offsets)

Python runs ONLY here (``make artifacts``); the Rust binary is
self-contained afterwards.

int8 note: the ``xla`` crate can only construct i32/i64/u32/u64/f32/f64
literals, so quantized model variants expose int32 parameters/results and
convert to/from int8 codes inside the HLO graph.  Values are int8 codes
throughout, the widening is lossless.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref

GOLDEN_MODELS = ("tiny", "lenet5", "tiny_int8", "lenet5_int8")
DEFAULT_MODELS = ("tiny", "lenet5", "alexnet", "vgg16", "tiny_int8", "lenet5_int8", "alexnet_int8")


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True; the Rust
    side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _wrap_int8_io(forward):
    """Expose an int32 interface around an int8-code forward function."""

    def wrapped(x32, *params32):
        xq = x32.astype(jnp.int8)
        qparams = [
            p.astype(jnp.int8) if p.dtype == jnp.int32 and name.endswith("_w") else p
            for p, name in zip(params32, wrapped._param_names)
        ]
        out = forward(xq, *qparams)
        return tuple(o.astype(jnp.int32) for o in out)

    return wrapped


def build_variant(name, ni, nl):
    """Returns (topology, forward, input_spec, param_specs_exposed, qcfg)."""
    quant = name.endswith("_int8")
    base = name[: -len("_int8")] if quant else name
    topo = M.TOPOLOGIES[base]()
    if quant:
        fwd_q = M.build_forward_int8(topo, ni=ni, nl=nl)
        specs = M.param_specs(topo, quantized_model=True)
        names = [n for n, _, _ in specs]
        wrapped = _wrap_int8_io(fwd_q)
        wrapped._param_names = names
        # exposed dtypes: everything int32 at the PJRT boundary
        exposed = [(n, s, "int32") for n, s, _ in specs]
        ispec = (tuple(topo["input_shape"]), "int32")
        return topo, wrapped, ispec, exposed, M.DEFAULT_QCFG
    fwd = M.build_forward(topo, ni=ni, nl=nl)
    exposed = M.param_specs(topo)
    ispec = (tuple(topo["input_shape"]), "float32")
    return topo, fwd, ispec, exposed, None


def make_inputs(name, topo, seed=0):
    """Concrete input + params for goldens/tests."""
    quant = name.endswith("_int8")
    rng = np.random.default_rng(seed + 1)
    x = rng.normal(0.0, 1.0, size=tuple(topo["input_shape"])).astype(np.float32)
    if quant:
        xq = np.asarray(ref.quantize(x, M.DEFAULT_QCFG["m_in"]))
        params = M.init_params(topo, seed=seed, quantized_model=True)
        return xq.astype(np.int32), [p.astype(np.int32) for p in params]
    return x, M.init_params(topo, seed=seed)


def lower_model(name, ni, nl):
    topo, fwd, (ishape, idt), exposed, qcfg = build_variant(name, ni, nl)
    args = [jax.ShapeDtypeStruct(ishape, np.dtype(idt))]
    for _, shape, dtype in exposed:
        args.append(jax.ShapeDtypeStruct(shape, np.dtype(dtype)))
    lowered = jax.jit(fwd).lower(*args)
    return topo, fwd, exposed, (ishape, idt), qcfg, to_hlo_text(lowered)


def write_golden(path, arrays):
    """Flat little-endian dump; returns (offsets, nbytes)."""
    offsets = []
    with open(path, "wb") as f:
        for arr in arrays:
            offsets.append(f.tell())
            f.write(np.ascontiguousarray(arr).tobytes())
        nbytes = f.tell()
    return offsets, nbytes


def export_onnx_subset(topo, out_json, out_bin, params=None, qcfg=None):
    """Write the ONNX-subset graph file the Rust front-end parses.

    Structure mirrors onnx.GraphProto restricted to the operator set of
    paper §4.1 (Conv/MaxPool/Relu/Gemm/Softmax + Flatten) with external
    raw initializer data, like ONNX's external-data convention.
    """
    nodes = []
    inits = []
    offset = 0
    tname = "input"
    idx = 0
    specs = M.layer_shapes(topo)
    for li, (layer, ishape, oshape) in enumerate(specs):
        if layer["op"] == "Conv":
            wname, bname = f"l{li}_w", f"l{li}_b"
            cin = ishape[0]
            kh, kw = layer["kernel_shape"]
            wshape = [layer["cout"], cin, kh, kw]
            bshape = [layer["cout"]]
            for nm, shp in ((wname, wshape), (bname, bshape)):
                size = int(np.prod(shp)) * 4
                inits.append(dict(name=nm, shape=shp, dtype="float32", offset=offset, nbytes=size))
                offset += size
            out = f"t{idx}"
            idx += 1
            nodes.append(
                dict(
                    op_type="Conv",
                    inputs=[tname, wname, bname],
                    outputs=[out],
                    attrs=dict(
                        kernel_shape=layer["kernel_shape"],
                        strides=layer["strides"],
                        pads=layer["pads"] + layer["pads"],  # ONNX 4-elem pads
                        dilations=layer["dilations"],
                    ),
                )
            )
            tname = out
            if layer["relu"]:
                out = f"t{idx}"
                idx += 1
                nodes.append(dict(op_type="Relu", inputs=[tname], outputs=[out], attrs={}))
                tname = out
        elif layer["op"] == "MaxPool":
            out = f"t{idx}"
            idx += 1
            nodes.append(
                dict(
                    op_type="MaxPool",
                    inputs=[tname],
                    outputs=[out],
                    attrs=dict(
                        kernel_shape=layer["kernel_shape"],
                        strides=layer["strides"],
                        pads=layer["pads"] + layer["pads"],
                    ),
                )
            )
            tname = out
        elif layer["op"] == "Gemm":
            flat = f"t{idx}"
            idx += 1
            nodes.append(dict(op_type="Flatten", inputs=[tname], outputs=[flat], attrs={}))
            tname = flat
            wname, bname = f"l{li}_w", f"l{li}_b"
            k = int(np.prod(ishape))
            for nm, shp in ((wname, [layer["cout"], k]), (bname, [layer["cout"]])):
                size = int(np.prod(shp)) * 4
                inits.append(dict(name=nm, shape=shp, dtype="float32", offset=offset, nbytes=size))
                offset += size
            out = f"t{idx}"
            idx += 1
            nodes.append(
                dict(
                    op_type="Gemm",
                    inputs=[tname, wname, bname],
                    outputs=[out],
                    attrs=dict(transB=1),
                )
            )
            tname = out
            if layer["relu"]:
                out = f"t{idx}"
                idx += 1
                nodes.append(dict(op_type="Relu", inputs=[tname], outputs=[out], attrs={}))
                tname = out
    if topo.get("softmax"):
        out = f"t{idx}"
        nodes.append(dict(op_type="Softmax", inputs=[tname], outputs=[out], attrs={}))
        tname = out
    doc = dict(
        format="cnn2gate-onnx-subset-v1",
        name=topo["name"],
        input=dict(name="input", shape=list(topo["input_shape"]), dtype="float32"),
        output=dict(name=tname),
        nodes=nodes,
        initializers=inits,
        external_data=os.path.basename(out_bin) if params is not None else None,
        quantization=(dict(qcfg) if qcfg else None),
    )
    with open(out_json, "w") as f:
        json.dump(doc, f, indent=1)
    if params is not None:
        with open(out_bin, "wb") as f:
            for arr in params:
                f.write(np.ascontiguousarray(arr.astype(np.float32)).tobytes())


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS))
    ap.add_argument("--ni", type=int, default=16)
    ap.add_argument("--nl", type=int, default=32)
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "models"), exist_ok=True)
    manifest = dict(format="cnn2gate-artifacts-v1", ni=args.ni, nl=args.nl, models={})
    mpath = os.path.join(out_dir, "manifest.json")
    if os.path.exists(mpath):  # merge: partial re-runs must not drop models
        try:
            old = json.load(open(mpath))
            if old.get("format") == manifest["format"]:
                manifest["models"].update(old.get("models", {}))
        except (json.JSONDecodeError, OSError):
            pass

    for name in args.models.split(","):
        name = name.strip()
        if not name:
            continue
        t0 = time.time()
        topo, fwd, exposed, (ishape, idt), qcfg, hlo = lower_model(name, args.ni, args.nl)
        hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(hlo)
        entry = dict(
            hlo=os.path.basename(hlo_path),
            input=dict(shape=list(ishape), dtype=idt),
            params=[dict(name=n, shape=list(s), dtype=d) for n, s, d in exposed],
            quantization=(dict(qcfg) if qcfg else None),
            topology=topo,
        )
        if name in GOLDEN_MODELS:
            x, params = make_inputs(name, topo)
            expected = np.asarray(fwd(jnp.asarray(x), *[jnp.asarray(p) for p in params])[0])
            gpath = os.path.join(out_dir, f"golden_{name}.bin")
            arrays = [x] + params + [expected]
            offsets, nbytes = write_golden(gpath, arrays)
            entry["golden"] = dict(
                file=os.path.basename(gpath),
                nbytes=nbytes,
                arrays=[
                    dict(name=nm, shape=list(np.asarray(a).shape), dtype=str(np.asarray(a).dtype), offset=off)
                    for nm, a, off in zip(
                        ["input"] + [n for n, _, _ in exposed] + ["output"], arrays, offsets
                    )
                ],
            )
        manifest["models"][name] = entry
        print(f"[aot] {name}: {len(hlo)/1e3:.0f} KB hlo in {time.time()-t0:.1f}s")

    # ONNX-subset model files for the Rust front-end parser.
    for base in ("tiny", "lenet5", "alexnet", "vgg16"):
        topo = M.TOPOLOGIES[base]()
        params = M.init_params(topo) if base in ("tiny", "lenet5") else None
        export_onnx_subset(
            topo,
            os.path.join(out_dir, "models", f"{base}.json"),
            os.path.join(out_dir, "models", f"{base}.bin"),
            params=params,
            qcfg=M.DEFAULT_QCFG,
        )
        print(f"[aot] onnx-subset models/{base}.json")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest.json with {len(manifest['models'])} models -> {out_dir}")


if __name__ == "__main__":
    main()
