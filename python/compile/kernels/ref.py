"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Everything in this module is the *reference semantics* of the CNN2Gate
compute pipeline: float conv / maxpool / GEMM plus the paper's 8-bit
fixed-point quantization ((N, m) values, weights represented as N * 2^-m,
see paper §4.2).  The Pallas kernels in `conv_lane.py` / `pool.py` /
`quantized.py` are checked against these functions by pytest + hypothesis.

All activations are CHW (batch dim handled by the caller / vmap); weights
are OIHW, exactly the ONNX convention the Rust-side parser preserves.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Shape arithmetic (paper equation (3)-(4))
# ---------------------------------------------------------------------------


def conv_out_hw(hw, kernel, stride, pad, dilation):
    """Output spatial size of a conv/maxpool node, paper eq. (3)."""
    h, w = hw
    kh, kw = kernel
    sh, sw = stride
    ph, pw = pad
    dh, dw = dilation
    ho = (h + 2 * ph - dh * (kh - 1) - 1) // sh + 1
    wo = (w + 2 * pw - dw * (kw - 1) - 1) // sw + 1
    return ho, wo


# ---------------------------------------------------------------------------
# Float reference ops
# ---------------------------------------------------------------------------


def conv2d(x, w, b=None, stride=(1, 1), pad=(0, 0), dilation=(1, 1)):
    """Reference 2-D convolution.  x: (Cin,H,W), w: (Cout,Cin,KH,KW)."""
    lhs = x[None]  # NCHW with N=1
    out = jax.lax.conv_general_dilated(
        lhs,
        w,
        window_strides=stride,
        padding=[(pad[0], pad[0]), (pad[1], pad[1])],
        rhs_dilation=dilation,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    if b is not None:
        out = out + b[:, None, None]
    return out


def maxpool2d(x, kernel, stride, pad=(0, 0)):
    """Reference max-pool.  x: (C,H,W)."""
    neg = jnp.finfo(x.dtype).min if jnp.issubdtype(x.dtype, jnp.floating) else jnp.iinfo(x.dtype).min
    return jax.lax.reduce_window(
        x,
        neg,
        jax.lax.max,
        window_dimensions=(1, kernel[0], kernel[1]),
        window_strides=(1, stride[0], stride[1]),
        padding=[(0, 0), (pad[0], pad[0]), (pad[1], pad[1])],
    )


def relu(x):
    return jnp.maximum(x, 0)


def gemm(x, w, b=None):
    """Fully connected layer: x: (K,), w: (N,K) -> (N,).  ONNX Gemm, transB=1."""
    out = w @ x
    if b is not None:
        out = out + b
    return out


def softmax(x):
    x = x - jnp.max(x)
    e = jnp.exp(x)
    return e / jnp.sum(e)


def im2col(x, kernel, stride=(1, 1), pad=(0, 0), dilation=(1, 1)):
    """Lower a conv input to the patch matrix of shape (OH*OW, Cin*KH*KW).

    Column order matches ``w.reshape(Cout, -1)`` so that
    ``im2col(x) @ w.reshape(Cout,-1).T == conv2d(x, w)`` — this is the
    contract the Pallas conv-lane kernel relies on.
    """
    cin = x.shape[0]
    patches = jax.lax.conv_general_dilated_patches(
        x[None],
        filter_shape=kernel,
        window_strides=stride,
        padding=[(pad[0], pad[0]), (pad[1], pad[1])],
        rhs_dilation=dilation,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]  # (Cin*KH*KW, OH, OW)
    k = cin * kernel[0] * kernel[1]
    return patches.reshape(k, -1).T  # (P, K)


def matmul(a, b):
    """Plain reference GEMM used as the oracle for the lane kernel."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Fixed-point (N, m) quantization — paper §4.2
# ---------------------------------------------------------------------------
# A quantized value is stored as an 8-bit integer N with an implicit scale
# 2^-m, i.e. real = N * 2^-m.  CNN2Gate "applies a given quantization": it
# never learns m, it just converts float tensors with a user-provided m.

INT8_MIN = -128
INT8_MAX = 127


def quantize(x, m, bits=8):
    """Float -> fixed-point integer code with round-to-nearest + saturate."""
    lo = -(2 ** (bits - 1))
    hi = 2 ** (bits - 1) - 1
    scaled = jnp.round(x * (2.0**m))
    return jnp.clip(scaled, lo, hi).astype(jnp.int8 if bits == 8 else jnp.int32)


def dequantize(q, m):
    return q.astype(jnp.float32) * (2.0**-m)


def requantize(acc, m_acc, m_out, bits=8):
    """Rescale an int32 accumulator with frac bits m_acc to an int8 code
    with frac bits m_out (arithmetic shift with round-half-up, saturate).

    This is exactly what the FPGA datapath does between pipeline stages.
    """
    shift = m_acc - m_out
    if shift > 0:
        rounded = (acc + (1 << (shift - 1))) >> shift
    elif shift < 0:
        rounded = acc << (-shift)
    else:
        rounded = acc
    lo = -(2 ** (bits - 1))
    hi = 2 ** (bits - 1) - 1
    return jnp.clip(rounded, lo, hi).astype(jnp.int8 if bits == 8 else jnp.int32)


def qconv2d(xq, wq, bq, cfg, stride=(1, 1), pad=(0, 0), dilation=(1, 1), apply_relu=True):
    """Reference int8 fixed-point conv.

    xq int8 with frac bits cfg['m_in'], wq int8 with cfg['m_w'],
    bq int32 at the accumulator scale (m_in + m_w frac bits),
    output int8 with cfg['m_out'].
    """
    acc = jax.lax.conv_general_dilated(
        xq[None].astype(jnp.int32),
        wq.astype(jnp.int32),
        window_strides=stride,
        padding=[(pad[0], pad[0]), (pad[1], pad[1])],
        rhs_dilation=dilation,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    acc = acc + bq[:, None, None]
    if apply_relu:
        acc = jnp.maximum(acc, 0)
    return requantize(acc, cfg["m_in"] + cfg["m_w"], cfg["m_out"])


def qgemm(xq, wq, bq, cfg, apply_relu=True):
    """Reference int8 fixed-point fully-connected layer."""
    acc = wq.astype(jnp.int32) @ xq.astype(jnp.int32) + bq
    if apply_relu:
        acc = jnp.maximum(acc, 0)
    return requantize(acc, cfg["m_in"] + cfg["m_w"], cfg["m_out"])
