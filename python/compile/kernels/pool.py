"""L1 Pallas kernel: the max-pooling kernel of the pipelined architecture.

The FPGA pool kernel sits behind the conv lanes (Fig. 3c / Fig. 5) and
consumes one lane-vector per cycle.  Here it is a Pallas kernel blocked
over channels (the lane dimension): each grid step pools ``block_c``
channels, mirroring ``N_l`` pool units operating in parallel.

General (kh, kw, stride, pad) support is implemented with statically
unrolled shifted strided slices — the same structure as the FPGA shift
register window, and the only formulation that works in both interpret
mode and on real Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_NEG = float(jnp.finfo(jnp.float32).min)


def _maxpool_kernel(x_ref, o_ref, *, kernel, stride, oh, ow):
    kh, kw = kernel
    sh, sw = stride
    x = x_ref[...]  # (bc, Hp, Wp)
    bc = x.shape[0]
    m = None
    for i in range(kh):
        for j in range(kw):
            v = jax.lax.slice(
                x,
                (0, i, j),
                (bc, i + sh * (oh - 1) + 1, j + sw * (ow - 1) + 1),
                (1, sh, sw),
            )
            m = v if m is None else jnp.maximum(m, v)
    o_ref[...] = m


def maxpool2d_lanes(x, kernel, stride, pad=(0, 0), *, nl=32):
    """Max-pool x: (C,H,W) with ``nl`` parallel pool units.

    Channels are padded to a multiple of the lane count (idle lanes on the
    FPGA when N_l does not divide C — exactly the situation the paper's
    divisor constraint avoids; we pad instead of forbidding it so the
    kernel is total).
    """
    c, h, w = x.shape
    oh, ow = ref.conv_out_hw((h, w), kernel, stride, pad, (1, 1))
    xp = jnp.pad(
        x,
        ((0, 0), (pad[0], pad[0]), (pad[1], pad[1])),
        constant_values=_NEG,
    )
    # Right-pad so the shifted slices stay in bounds for every (i, j).
    need_h = kernel[0] + stride[0] * (oh - 1)
    need_w = kernel[1] + stride[1] * (ow - 1)
    xp = jnp.pad(
        xp,
        ((0, 0), (0, max(0, need_h - xp.shape[1])), (0, max(0, need_w - xp.shape[2]))),
        constant_values=_NEG,
    )
    bc = min(nl, c)
    xp, _ = _pad_channels(xp, bc)
    cp = xp.shape[0]
    out = pl.pallas_call(
        functools.partial(
            _maxpool_kernel, kernel=kernel, stride=stride, oh=oh, ow=ow
        ),
        grid=(cp // bc,),
        in_specs=[pl.BlockSpec((bc, xp.shape[1], xp.shape[2]), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bc, oh, ow), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((cp, oh, ow), jnp.float32),
        interpret=True,
    )(xp)
    return out[:c]


def _pad_channels(x, mult):
    c = x.shape[0]
    rem = (-c) % mult
    if rem == 0:
        return x, c
    return jnp.pad(x, ((0, rem), (0, 0), (0, 0)), constant_values=_NEG), c
