"""L1 Pallas kernel: the 8-bit fixed-point datapath (paper §4.2).

CNN2Gate's structural domain "uses 8-bit fixed point arithmetic units to
perform computations".  This module is the TPU adaptation of that
datapath: int8 feature/weight codes, int32 accumulation inside the lane
array, and a requantizing epilogue (shift + round + saturate) that maps
the accumulator scale 2^-(m_in+m_w) back to the next layer's 2^-m_out.

Checked against `ref.qconv2d` / `ref.qgemm` by pytest + hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .conv_lane import LANE_TILE_M, _pad_to, block_sizes


def _qmatmul_kernel(a_ref, b_ref, o_ref, *, nsteps):
    """int8 x int8 -> int32 accumulation; grid K-dim innermost."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.int32),
        b_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


@functools.partial(jax.jit, static_argnames=("ni", "nl", "bm"))
def qmatmul_lanes(a, b, *, ni=16, nl=32, bm=LANE_TILE_M):
    """(M,K) int8 x (K,N) int8 -> (M,N) int32 with (N_i,N_l) tiling."""
    (m, k0), (k1, n) = a.shape, b.shape
    assert k0 == k1, f"contraction mismatch {a.shape} x {b.shape}"
    (bm, bk, bn) = block_sizes(m, k0, n, ni, nl, bm_target=bm)
    a, _ = _pad_to(a, 0, bm)
    a, _ = _pad_to(a, 1, bk)
    b, _ = _pad_to(b, 0, bk)
    b, _ = _pad_to(b, 1, bn)
    mp, kp = a.shape
    np_ = b.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_qmatmul_kernel, nsteps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(a, b)
    return out[:m, :n]


def _qim2col(xq, kernel, stride, pad, dilation):
    """int8 im2col: route through int32 for the patch gather (XLA's
    dilated-patch helper requires a conv-friendly dtype), then narrow
    back — values are int8 codes throughout so the cast is lossless."""
    cols = ref.im2col(
        xq.astype(jnp.float32), kernel, stride, pad, dilation
    )
    return cols.astype(jnp.int8)


def qconv2d_lanes(
    xq,
    wq,
    bq,
    cfg,
    stride=(1, 1),
    pad=(0, 0),
    dilation=(1, 1),
    *,
    ni=16,
    nl=32,
    apply_relu=True,
):
    """Quantized conv on the lane array.  See ref.qconv2d for scales."""
    cout = wq.shape[0]
    kernel = (wq.shape[2], wq.shape[3])
    patches = _qim2col(xq, kernel, stride, pad, dilation)  # (P, K) int8
    wmat = wq.reshape(cout, -1).T  # (K, Cout) int8
    acc = qmatmul_lanes(patches, wmat, ni=ni, nl=nl)  # (P, Cout) int32
    acc = acc + bq[None, :]
    if apply_relu:
        acc = jnp.maximum(acc, 0)
    out = ref.requantize(acc, cfg["m_in"] + cfg["m_w"], cfg["m_out"])
    oh, ow = ref.conv_out_hw(xq.shape[1:], kernel, stride, pad, dilation)
    return out.T.reshape(cout, oh, ow)


def qgemm_lanes(xq, wq, bq, cfg, *, ni=16, nl=32, apply_relu=True):
    """Quantized fully-connected layer on the lane array."""
    acc = qmatmul_lanes(xq[None, :], wq.T, ni=ni, nl=nl)[0]
    acc = acc + bq
    if apply_relu:
        acc = jnp.maximum(acc, 0)
    return ref.requantize(acc, cfg["m_in"] + cfg["m_w"], cfg["m_out"])


def qmaxpool2d(xq, kernel, stride, pad=(0, 0)):
    """int8 max-pool: pooling commutes with the fixed-point code, so this
    is a direct reduce-window on the codes (no requantization needed)."""
    return jax.lax.reduce_window(
        xq,
        jnp.int8(-128),
        jax.lax.max,
        window_dimensions=(1, kernel[0], kernel[1]),
        window_strides=(1, stride[0], stride[1]),
        padding=[(0, 0), (pad[0], pad[0]), (pad[1], pad[1])],
    )
