"""L1: Pallas kernels for the CNN2Gate compute hot-spot.

`ref` is the pure-jnp oracle; `conv_lane` / `pool` / `quantized` are the
(N_i, N_l)-blocked Pallas kernels (interpret=True) that L2 composes into
whole-network forward functions.
"""

from . import conv_lane, pool, quantized, ref  # noqa: F401
