"""L1 Pallas kernel: the CNN2Gate/PipeCNN vectorized convolution lane array.

Paper mapping (Fig. 5, §4.2-4.3).  The FPGA design fetches ``N_l`` vectors
of width ``N_i`` for features and weights per cycle, and feeds ``N_l``
parallel CONV lanes, each performing an ``N_i``-wide MAC.  On TPU the same
blocking becomes an im2col GEMM tiled for the MXU:

  * reduction dim (Cin*KH*KW) is tiled in multiples of ``N_i``
    -> the "vectorized input data / weights" of Fig. 5,
  * output-channel dim is tiled in multiples of ``N_l``
    -> the parallel computation lanes,
  * the HBM<->VMEM staging expressed by the BlockSpec index maps plays the
    role of the memory read / write OpenCL kernels, and the grid's
    sequential revisiting of the output block is the FIFO pipe between the
    fetch stage and the lane array (DESIGN.md §4 Hardware-Adaptation).

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, and emulation-mode numerics are the paper's stated purpose
for the CPU path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Tile policy. ni/nl keep the paper's semantics (they set tile
# *granularity* and therefore the legal option grid); the caps lift tiles
# toward MXU-friendly sizes without changing results.
#
# Perf note (EXPERIMENTS.md §Perf, iteration 1): under interpret=True the
# lowered grid loop's per-step cost scales with the *whole* operand
# buffers, not the tile, so the block sizes are chosen to minimize grid
# steps: the reduction dim is kept whole (up to MAX_VEC_STEPS ni-vectors),
# the lane dim covers up to MAX_LANE_GROUPS nl-groups, and the patch dim
# uses a large LANE_TILE_M. This cut VGG-16 emulation from ~90 s for a
# single conv layer to seconds for the whole network.
LANE_TILE_M = 2048
VEC_MULT = 8  # retained for lane_tile_shapes compatibility
LANE_MULT = 4
MAX_VEC_STEPS = 64  # bk <= ni * 64
MAX_LANE_GROUPS = 16  # bn <= nl * 16


def _round_up(x, mult):
    return ((x + mult - 1) // mult) * mult


def _pow2_ceil(x):
    return 1 << (max(1, x) - 1).bit_length()


def block_sizes(m, k, n, ni, nl, bm_target=LANE_TILE_M):
    """(bm, bk, bn) for an (M,K)x(K,N) lane GEMM at option (ni, nl)."""
    bk = min(_round_up(k, ni), ni * MAX_VEC_STEPS)
    bn = min(_round_up(n, nl), nl * MAX_LANE_GROUPS)
    bm = max(8, min(bm_target, _pow2_ceil(m)))
    return bm, bk, bn


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x, size
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads), size


def _matmul_kernel(a_ref, b_ref, o_ref, *, nsteps):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (sequential) dim.

    The output block is revisited across the K steps — the Pallas analogue
    of the accumulator register file inside an FPGA conv lane.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("ni", "nl", "bm"))
def matmul_lanes(a, b, *, ni=16, nl=32, bm=LANE_TILE_M):
    """(M,K) x (K,N) -> (M,N) with (N_i, N_l)-derived MXU tiling.

    Shapes are padded to tile multiples and the result is sliced back, the
    same way the FPGA host pads feature maps so that ``N_i`` divides the
    fetch vectors (paper §4.2 "N_i should be a divisor of the features'
    width ... to avoid padding").
    """
    (m, k0), (k1, n) = a.shape, b.shape
    assert k0 == k1, f"contraction mismatch {a.shape} x {b.shape}"
    let_bm = bm
    (bm, bk, bn) = block_sizes(m, k0, n, ni, nl, bm_target=let_bm)
    a, _ = _pad_to(a, 0, bm)
    a, _ = _pad_to(a, 1, bk)
    b, _ = _pad_to(b, 0, bk)
    b, _ = _pad_to(b, 1, bn)
    mp, kp = a.shape
    np_ = b.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nsteps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a, b)
    return out[:m, :n]


def conv2d_lanes(
    x,
    w,
    b=None,
    stride=(1, 1),
    pad=(0, 0),
    dilation=(1, 1),
    *,
    ni=16,
    nl=32,
    apply_relu=False,
):
    """CNN2Gate convolution layer on the lane array.

    x: (Cin,H,W) float32, w: (Cout,Cin,KH,KW), b: (Cout,) or None.
    The im2col staging is the memory-read kernel's address generation; the
    Pallas GEMM is the lane array; bias+relu fuse into the lane epilogue
    exactly as the RELU units sit behind the CONV units in Fig. 5.
    """
    cout = w.shape[0]
    kernel = (w.shape[2], w.shape[3])
    patches = ref.im2col(x, kernel, stride, pad, dilation)  # (P, K)
    wmat = w.reshape(cout, -1).T  # (K, Cout)
    out = matmul_lanes(patches, wmat, ni=ni, nl=nl)  # (P, Cout)
    if b is not None:
        out = out + b[None, :]
    if apply_relu:
        out = jnp.maximum(out, 0)
    oh, ow = ref.conv_out_hw(x.shape[1:], kernel, stride, pad, dilation)
    return out.T.reshape(cout, oh, ow)


def gemm_lanes(x, w, b=None, *, ni=16, nl=32, apply_relu=False):
    """Fully connected layer on the same lane array (paper §3.2.3: "the
    convolution kernel and the fully connected kernel can be fused together
    as a single 3-D matrix-matrix multiplication unit")."""
    out = matmul_lanes(x[None, :], w.T, ni=ni, nl=nl)[0]
    if b is not None:
        out = out + b
    if apply_relu:
        out = jnp.maximum(out, 0)
    return out


# VMEM budget for the real-TPU tile estimate (bytes); double-buffered
# working set must fit (DESIGN.md §9).
VMEM_BYTES = 16 * 1024 * 1024


def lane_tile_shapes(ni, nl, k, n, m=512):
    """The (bm, bk, bn) tile a given (N_i, N_l) choice would use on a real
    TPU — used by the DESIGN.md §9 MXU-utilization estimate and by
    python/tests.

    Unlike `block_sizes` (which maximizes tile size because the CPU
    interpreter's per-step cost scales with whole operands), the TPU tile
    is shrunk until the double-buffered working set fits VMEM.
    """
    bm, bk, bn = block_sizes(m, k, n, ni, nl, bm_target=m)

    def working(bm, bk, bn):
        return 4 * (bm * bk + bk * bn + bm * bn)

    # shrink the largest dimension first, never below lane granularity
    while 2 * working(bm, bk, bn) > VMEM_BYTES:
        if bm >= bk and bm > 8:
            bm = max(8, bm // 2)
        elif bk >= bn and bk > ni:
            bk = max(ni, (bk // 2 + ni - 1) // ni * ni)
        elif bn > nl:
            bn = max(nl, (bn // 2 + nl - 1) // nl * nl)
        else:
            break  # minimal tile; physically always fits for 8-bit lanes
    return bm, bk, bn
