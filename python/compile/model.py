"""L2: JAX forward graphs for the CNN2Gate model zoo.

A model is described by a *topology* — an ordered list of layer dicts with
exactly the attribute set the paper's ONNX parser extracts (§4.1): op
type, kernel_shape, strides, pads, dilations, channel counts, plus the
activation/softmax flags the parser detects.  The same topology is
serialized to the ONNX-subset JSON that the Rust front-end parses, so the
two sides of the system agree by construction.

`build_forward` composes the L1 Pallas kernels (conv_lane / pool /
quantized) into a whole-network forward function; `aot.py` lowers these to
the HLO text artifacts the Rust runtime executes in emulation mode.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .kernels import conv_lane, pool, quantized, ref

# ---------------------------------------------------------------------------
# Topologies (dims follow the torchvision/ONNX model-zoo definitions)
# ---------------------------------------------------------------------------


def _conv(cout, k, s=1, p=0, relu=True):
    return dict(
        op="Conv",
        cout=cout,
        kernel_shape=[k, k],
        strides=[s, s],
        pads=[p, p],
        dilations=[1, 1],
        relu=relu,
    )


def _pool(k, s, p=0):
    return dict(op="MaxPool", kernel_shape=[k, k], strides=[s, s], pads=[p, p])


def _fc(n, relu=True):
    return dict(op="Gemm", cout=n, relu=relu)


def tiny_topology():
    """8x8 single-channel toy CNN used by unit tests and goldens."""
    return dict(
        name="tiny",
        input_shape=[1, 8, 8],
        layers=[_conv(4, 3, 1, 1), _pool(2, 2), _fc(10, relu=False)],
        softmax=True,
    )


def lenet5_topology():
    return dict(
        name="lenet5",
        input_shape=[1, 28, 28],
        layers=[
            _conv(6, 5, 1, 2),
            _pool(2, 2),
            _conv(16, 5),
            _pool(2, 2),
            _fc(120),
            _fc(84),
            _fc(10, relu=False),
        ],
        softmax=True,
    )


def alexnet_topology():
    return dict(
        name="alexnet",
        input_shape=[3, 224, 224],
        layers=[
            _conv(64, 11, 4, 2),
            _pool(3, 2),
            _conv(192, 5, 1, 2),
            _pool(3, 2),
            _conv(384, 3, 1, 1),
            _conv(256, 3, 1, 1),
            _conv(256, 3, 1, 1),
            _pool(3, 2),
            _fc(4096),
            _fc(4096),
            _fc(1000, relu=False),
        ],
        softmax=True,
    )


def vgg16_topology():
    layers = []
    for block, (reps, cout) in enumerate([(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)]):
        for _ in range(reps):
            layers.append(_conv(cout, 3, 1, 1))
        layers.append(_pool(2, 2))
    layers += [_fc(4096), _fc(4096), _fc(1000, relu=False)]
    return dict(name="vgg16", input_shape=[3, 224, 224], layers=layers, softmax=True)


TOPOLOGIES = {
    "tiny": tiny_topology,
    "lenet5": lenet5_topology,
    "alexnet": alexnet_topology,
    "vgg16": vgg16_topology,
}

# Default per-layer fixed-point config for the int8 variants: activations
# and weights Q(8, m).  These are the "user-given post-training
# quantization values" of paper §4.2 — reasonable static choices, not
# learned.
DEFAULT_QCFG = dict(m_in=4, m_w=6, m_out=4)


# ---------------------------------------------------------------------------
# Shape inference (mirror of the Rust ir::shape module; paper eq. (3)-(4))
# ---------------------------------------------------------------------------


def layer_shapes(topo):
    """Yield (layer, in_shape, out_shape) walking the topology."""
    shape = tuple(topo["input_shape"])
    out = []
    for layer in topo["layers"]:
        if layer["op"] == "Conv":
            c, h, w = shape
            oh, ow = ref.conv_out_hw(
                (h, w),
                tuple(layer["kernel_shape"]),
                tuple(layer["strides"]),
                tuple(layer["pads"]),
                tuple(layer["dilations"]),
            )
            nxt = (layer["cout"], oh, ow)
        elif layer["op"] == "MaxPool":
            c, h, w = shape
            oh, ow = ref.conv_out_hw(
                (h, w),
                tuple(layer["kernel_shape"]),
                tuple(layer["strides"]),
                tuple(layer["pads"]),
                (1, 1),
            )
            nxt = (c, oh, ow)
        elif layer["op"] == "Gemm":
            k = int(np.prod(shape))
            nxt = (layer["cout"],)
        else:
            raise ValueError(f"unknown op {layer['op']}")
        out.append((layer, shape, nxt))
        shape = nxt
    return out


def param_specs(topo, quantized_model=False):
    """Ordered (name, shape, dtype) list for the flat HLO parameter list."""
    specs = []
    for idx, (layer, ishape, _) in enumerate(layer_shapes(topo)):
        if layer["op"] == "Conv":
            cin = ishape[0]
            kh, kw = layer["kernel_shape"]
            wdt = "int8" if quantized_model else "float32"
            bdt = "int32" if quantized_model else "float32"
            specs.append((f"l{idx}_w", (layer["cout"], cin, kh, kw), wdt))
            specs.append((f"l{idx}_b", (layer["cout"],), bdt))
        elif layer["op"] == "Gemm":
            k = int(np.prod(ishape))
            wdt = "int8" if quantized_model else "float32"
            bdt = "int32" if quantized_model else "float32"
            specs.append((f"l{idx}_w", (layer["cout"], k), wdt))
            specs.append((f"l{idx}_b", (layer["cout"],), bdt))
    return specs


def init_params(topo, seed=0, quantized_model=False, qcfg=DEFAULT_QCFG):
    """Synthetic He-scaled weights (the repo has no ImageNet checkpoints;
    see DESIGN.md §2 substitution table)."""
    rng = np.random.default_rng(seed)
    params = []
    for name, shape, dtype in param_specs(topo, quantized_model=False):
        if name.endswith("_w"):
            fan_in = int(np.prod(shape[1:]))
            arr = rng.normal(0.0, np.sqrt(2.0 / fan_in), size=shape).astype(np.float32)
        else:
            arr = rng.normal(0.0, 0.05, size=shape).astype(np.float32)
        params.append(arr)
    if not quantized_model:
        return params
    qparams = []
    m_acc = qcfg["m_in"] + qcfg["m_w"]
    for arr, (name, _, _) in zip(params, param_specs(topo, quantized_model=False)):
        if name.endswith("_w"):
            qparams.append(np.asarray(ref.quantize(arr, qcfg["m_w"])))
        else:
            qparams.append(np.asarray(ref.quantize(arr, m_acc, bits=32)))
    return qparams


# ---------------------------------------------------------------------------
# Forward builders
# ---------------------------------------------------------------------------


def build_forward(topo, ni=16, nl=32, use_pallas=True):
    """float32 forward: image (C,H,W) + flat params -> (logits or probs,).

    ``use_pallas=False`` swaps in the pure-jnp reference ops — the oracle
    variant used by goldens and by the L2 fusion census in the perf pass.
    """
    shapes = layer_shapes(topo)

    def forward(x, *params):
        it = iter(params)
        for layer, _, _ in shapes:
            if layer["op"] == "Conv":
                w, b = next(it), next(it)
                if use_pallas:
                    x = conv_lane.conv2d_lanes(
                        x,
                        w,
                        b,
                        stride=tuple(layer["strides"]),
                        pad=tuple(layer["pads"]),
                        dilation=tuple(layer["dilations"]),
                        ni=ni,
                        nl=nl,
                        apply_relu=layer["relu"],
                    )
                else:
                    x = ref.conv2d(
                        x,
                        w,
                        b,
                        stride=tuple(layer["strides"]),
                        pad=tuple(layer["pads"]),
                        dilation=tuple(layer["dilations"]),
                    )
                    if layer["relu"]:
                        x = ref.relu(x)
            elif layer["op"] == "MaxPool":
                if use_pallas:
                    x = pool.maxpool2d_lanes(
                        x,
                        tuple(layer["kernel_shape"]),
                        tuple(layer["strides"]),
                        tuple(layer["pads"]),
                        nl=nl,
                    )
                else:
                    x = ref.maxpool2d(
                        x,
                        tuple(layer["kernel_shape"]),
                        tuple(layer["strides"]),
                        tuple(layer["pads"]),
                    )
            elif layer["op"] == "Gemm":
                w, b = next(it), next(it)
                x = x.reshape(-1)
                if use_pallas:
                    x = conv_lane.gemm_lanes(x, w, b, ni=ni, nl=nl, apply_relu=layer["relu"])
                else:
                    x = ref.gemm(x, w, b)
                    if layer["relu"]:
                        x = ref.relu(x)
        if topo.get("softmax"):
            x = ref.softmax(x)
        return (x,)

    return forward


def build_forward_int8(topo, ni=16, nl=32, qcfg=DEFAULT_QCFG, use_pallas=True):
    """int8 fixed-point forward: image codes (int8) + int8/int32 params ->
    (int8 feature codes of the last layer,).  Softmax stays off the FPGA
    datapath (the paper's host applies it), so the quantized graph returns
    the final layer codes."""
    shapes = layer_shapes(topo)

    def forward(xq, *params):
        it = iter(params)
        for layer, _, _ in shapes:
            if layer["op"] == "Conv":
                wq, bq = next(it), next(it)
                fn = quantized.qconv2d_lanes if use_pallas else ref.qconv2d
                kwargs = dict(ni=ni, nl=nl) if use_pallas else {}
                xq = fn(
                    xq,
                    wq,
                    bq,
                    qcfg,
                    stride=tuple(layer["strides"]),
                    pad=tuple(layer["pads"]),
                    dilation=tuple(layer["dilations"]),
                    apply_relu=layer["relu"],
                    **kwargs,
                )
            elif layer["op"] == "MaxPool":
                xq = quantized.qmaxpool2d(
                    xq,
                    tuple(layer["kernel_shape"]),
                    tuple(layer["strides"]),
                    tuple(layer["pads"]),
                )
            elif layer["op"] == "Gemm":
                wq, bq = next(it), next(it)
                xq = xq.reshape(-1)
                if use_pallas:
                    xq = quantized.qgemm_lanes(
                        xq, wq, bq, qcfg, ni=ni, nl=nl, apply_relu=layer["relu"]
                    )
                else:
                    xq = ref.qgemm(xq, wq, bq, qcfg, apply_relu=layer["relu"])
        return (xq,)

    return forward


# ---------------------------------------------------------------------------
# Op/parameter census (used by metrics tests and the perf pass)
# ---------------------------------------------------------------------------


def gops(topo):
    """Total Giga-operations per frame, counting MAC=2 ops like the paper
    (AlexNet ~1.46 GOp, VGG-16 ~31 GOp at batch 1)."""
    total = 0
    for layer, ishape, oshape in layer_shapes(topo):
        if layer["op"] == "Conv":
            cin = ishape[0]
            kh, kw = layer["kernel_shape"]
            macs = oshape[0] * oshape[1] * oshape[2] * cin * kh * kw
            total += 2 * macs
        elif layer["op"] == "Gemm":
            total += 2 * int(np.prod(ishape)) * layer["cout"]
    return total / 1e9


def param_count(topo):
    return sum(int(np.prod(s)) for _, s, _ in param_specs(topo))
