#!/usr/bin/env bash
# Compare two sets of BENCH_PR*.json perf records (written by
# `cargo bench -p cnn2gate`) and fail on regressions.
#
#   tools/perf_compare.sh <baseline-dir> <current-dir> [threshold-pct]
#
# Every numeric leaf in each record is compared under a direction
# inferred from its key: *seconds / *wall* / *cycles / *_ms / p50 / p99 /
# max are lower-is-better; *speedup / *per_s / *gain* / candidates are
# higher-is-better; anything else (job counts, worker counts) is
# informational only. A metric that moved in the bad direction by more
# than <threshold-pct> percent (default 10) is a regression and the
# script exits 1. Records present on only one side are reported and
# skipped — benches are allowed to gain metrics across PRs.
set -euo pipefail

if [ "$#" -lt 2 ] || [ "$#" -gt 3 ]; then
    echo "usage: $0 <baseline-dir> <current-dir> [threshold-pct]" >&2
    exit 2
fi

BASE_DIR=$1 CUR_DIR=$2 THRESHOLD=${3:-10} python3 - <<'EOF'
import glob
import json
import os
import sys

base_dir = os.environ["BASE_DIR"]
cur_dir = os.environ["CUR_DIR"]
threshold = float(os.environ["THRESHOLD"]) / 100.0

LOWER_BETTER = ("seconds", "wall", "cycles", "_ms", "p50", "p99", "max")
HIGHER_BETTER = ("speedup", "per_s", "gain", "candidates")


def direction(key):
    leaf = key.rsplit(".", 1)[-1].lower()
    if any(m in leaf for m in LOWER_BETTER):
        return -1
    if any(m in leaf for m in HIGHER_BETTER):
        return +1
    return 0


def flatten(doc, prefix=""):
    out = {}
    if isinstance(doc, dict):
        for k, v in doc.items():
            out.update(flatten(v, f"{prefix}{k}." if prefix or k else k))
    elif isinstance(doc, (int, float)) and not isinstance(doc, bool):
        out[prefix.rstrip(".")] = float(doc)
    return out


def load(path):
    with open(path) as f:
        return flatten(json.load(f))


base_files = {os.path.basename(p): p for p in glob.glob(os.path.join(base_dir, "BENCH_PR*.json"))}
cur_files = {os.path.basename(p): p for p in glob.glob(os.path.join(cur_dir, "BENCH_PR*.json"))}
if not base_files:
    print(f"perf_compare: no BENCH_PR*.json in baseline dir {base_dir}", file=sys.stderr)
    sys.exit(2)

regressions = 0
for name in sorted(set(base_files) | set(cur_files)):
    if name not in base_files or name not in cur_files:
        side = "baseline" if name in base_files else "current"
        print(f"{name}: only in {side} — skipped")
        continue
    base, cur = load(base_files[name]), load(cur_files[name])
    print(f"{name}:")
    for key in sorted(set(base) | set(cur)):
        if key.endswith("format"):
            continue
        if key not in base or key not in cur:
            print(f"  {key:48s} only in {'baseline' if key in base else 'current'}")
            continue
        b, c = base[key], cur[key]
        d = direction(key)
        delta = (c - b) / b if b else 0.0
        tag = "="
        if d != 0 and b:
            worse = delta > threshold if d < 0 else delta < -threshold
            better = delta < -threshold if d < 0 else delta > threshold
            if worse:
                tag, regressions = "REGRESSION", regressions + 1
            elif better:
                tag = "improved"
        print(f"  {key:48s} {b:14.4f} -> {c:14.4f}  ({delta:+7.1%}) {tag}")

if regressions:
    print(f"perf_compare: {regressions} regression(s) beyond {threshold:.0%}")
    sys.exit(1)
print("perf_compare: no regressions")
EOF
