//! Driver for the in-repo analysis suite.
//!
//! ```text
//! cargo run -p analysis --release              # all passes
//! cargo run -p analysis --release lints        # custom source lints only
//! cargo run -p analysis --release locks        # static lock-order check only
//! cargo run -p analysis --release mc           # kernel bounded model checker
//! cargo run -p analysis --release fuzz         # hostile-input fuzz (fast tier)
//! cargo run -p analysis --release -- --seed panic
//! ```
//!
//! `--seed <panic|nondet|float-eq|lock-order>` injects a synthetic
//! violating source into the corresponding pass and must exit nonzero —
//! CI uses this to prove the suite still *fails* on real violations
//! (an analysis pass that always passes is dead weight).
//!
//! Exit code: 0 when every requested pass is clean, 1 otherwise.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use analysis::{fuzz, lints, locks, mc, Finding};

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../..")
}

fn report(pass: &str, findings: &[Finding]) -> bool {
    if findings.is_empty() {
        println!("analysis: {pass}: clean");
        return true;
    }
    for f in findings {
        println!("{f}");
    }
    println!("analysis: {pass}: {} finding(s)", findings.len());
    false
}

fn run_lints(root: &Path) -> bool {
    match lints::run(&root.join("rust/src")) {
        Ok(findings) => report("lints", &findings),
        Err(e) => {
            println!("analysis: lints: error: {e:#}");
            false
        }
    }
}

fn run_locks(root: &Path) -> bool {
    match locks::run(root) {
        Ok(findings) => report("locks", &findings),
        Err(e) => {
            println!("analysis: locks: error: {e:#}");
            false
        }
    }
}

fn run_mc() -> bool {
    let cfg = mc::McConfig::default();
    match mc::explore(&cfg) {
        Ok(stats) => {
            println!(
                "analysis: mc: clean — {} interleavings ({} nodes) at depth {}, \
                 coverage: {} finished / {} failed / {} rejected / {} queued-cancels / \
                 {} running-cancels / {} shutdown-drains",
                stats.leaves,
                stats.nodes,
                cfg.depth,
                stats.finished,
                stats.failed,
                stats.rejected,
                stats.cancelled_queued,
                stats.cancelled_running,
                stats.shutdown_drains,
            );
            true
        }
        Err(e) => {
            println!("analysis: mc: VIOLATION\n{e}");
            false
        }
    }
}

fn run_fuzz(scale: u64) -> bool {
    match fuzz::run(0xC2A7_2026, scale) {
        Ok(outcomes) => {
            for o in &outcomes {
                println!(
                    "analysis: fuzz: {}: clean — {} inputs ({} accepted, {} rejected)",
                    o.target, o.inputs, o.accepted, o.rejected
                );
            }
            true
        }
        Err(e) => {
            println!("analysis: fuzz: FAILURE\n{e}");
            false
        }
    }
}

fn run_seeded(root: &Path, class: &str) -> Result<bool, String> {
    if class == "lock-order" {
        let manifest_text = std::fs::read_to_string(root.join("tools/analysis/lock_order.toml"))
            .map_err(|e| format!("reading lock_order.toml: {e}"))?;
        let manifest = locks::parse_manifest(&manifest_text)?;
        let (rel, text) = locks::SEEDED_VIOLATION;
        return Ok(report("locks[seeded]", &locks::check_sources(&manifest, &[(rel, text)])));
    }
    let (rel, text) = lints::seeded_violation(class)
        .ok_or_else(|| format!("unknown seed class '{class}' (panic|nondet|float-eq|lock-order)"))?;
    Ok(report("lints[seeded]", &lints::lint_file(rel, text)))
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let root = repo_root();

    // seeded-violation mode: the pass must FIND something, so a clean
    // report here still exits nonzero (that is the point of the mode)
    if let Some(pos) = args.iter().position(|a| a == "--seed") {
        let Some(class) = args.get(pos + 1) else {
            println!("analysis: --seed requires a class (panic|nondet|float-eq|lock-order)");
            return ExitCode::FAILURE;
        };
        return match run_seeded(&root, class) {
            Ok(clean) => {
                if clean {
                    println!("analysis: seeded '{class}' violation was NOT caught");
                }
                ExitCode::from(u8::from(!clean))
            }
            Err(e) => {
                println!("analysis: {e}");
                ExitCode::FAILURE
            }
        };
    }

    let mut scale = 1;
    let mut pass = String::from("all");
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        if arg == "--fuzz-scale" {
            match it.next().and_then(|v| v.parse().ok()) {
                Some(v) if v > 0 => scale = v,
                _ => {
                    println!("analysis: --fuzz-scale requires a positive integer");
                    return ExitCode::FAILURE;
                }
            }
        } else if !arg.starts_with("--") {
            pass = arg.clone();
        }
    }
    let pass = pass.as_str();

    let ok = match pass {
        "all" => {
            let a = run_lints(&root);
            let b = run_locks(&root);
            let c = run_mc();
            let d = run_fuzz(scale);
            a && b && c && d
        }
        "lints" => run_lints(&root),
        "locks" => run_locks(&root),
        "mc" => run_mc(),
        "fuzz" => run_fuzz(scale),
        other => {
            println!("analysis: unknown pass '{other}' (all|lints|locks|mc|fuzz)");
            false
        }
    };
    if ok {
        println!("analysis: all requested passes clean");
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
