//! Custom source lints over `rust/src/**`.
//!
//! Three classes, each waivable per site with
//! `// analysis: allow(<class>, <reason>)` on the same line or the line
//! directly above:
//!
//! * `panic` — no `.unwrap()` / `.expect(` / `panic!` / `unreachable!`
//!   / `todo!` / `unimplemented!` in non-`#[cfg(test)]` library code.
//!   Library code returns `Result`; a panic in the service tears down a
//!   worker and poisons shared state.
//! * `nondet` — no nondeterminism sources inside the byte-identity
//!   layers (`sim/`, `dse/`, `report/`, `session.rs`, `util/json.rs`):
//!   wall clocks (`Instant::now`, `SystemTime`), thread-local RNGs, and
//!   `HashMap`/`HashSet` (whose iteration order could leak into
//!   rendered output; `BTreeMap` is the house type there). `use` lines
//!   are exempt so a wildcard import does not need a waiver.
//! * `float-eq` — no `==`/`!=` where either adjacent token is a float
//!   literal or a `.fract()` call. This is a token-level heuristic: it
//!   catches comparisons against literals (`x == 0.5`, sentinel checks)
//!   and fract-style integrality tests, not variable-vs-variable float
//!   comparisons — those need human eyes, which is exactly what the
//!   waiver reason forces at the sites the lint does see.

use std::path::Path;

use crate::scan::{walk_sources, SourceFile};
use crate::Finding;

pub const PANIC_PATTERNS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];

pub const NONDET_PATTERNS: &[&str] = &[
    "Instant::now",
    "SystemTime",
    "thread_rng",
    "ThreadRng",
    "rand::",
];

pub const NONDET_COLLECTIONS: &[&str] = &["HashMap", "HashSet"];

/// The byte-identity layers: modules whose rendered output must be
/// byte-stable across runs and thread interleavings.
pub fn nondet_scope(rel: &str) -> bool {
    rel.starts_with("sim/")
        || rel.starts_with("dse/")
        || rel.starts_with("report/")
        || rel == "session.rs"
        || rel == "util/json.rs"
}

fn context_of(line: &str) -> String {
    line.trim().chars().take(110).collect()
}

/// `pat` present in `line` with non-identifier characters on both sides.
fn contains_word(line: &str, pat: &str) -> bool {
    let chars: Vec<char> = line.chars().collect();
    let p: Vec<char> = pat.chars().collect();
    if chars.len() < p.len() {
        return false;
    }
    let ident = |c: char| c.is_alphanumeric() || c == '_';
    for i in 0..=chars.len() - p.len() {
        if chars[i..i + p.len()] == p[..]
            && (i == 0 || !ident(chars[i - 1]))
            && (i + p.len() == chars.len() || !ident(chars[i + p.len()]))
        {
            return true;
        }
    }
    false
}

/// Matches `\d+\.\d*` with an optional exponent, end-anchored.
fn float_with_point(s: &[char]) -> bool {
    let mut i = 0usize;
    let start = i;
    while i < s.len() && s[i].is_ascii_digit() {
        i += 1;
    }
    if i == start || i >= s.len() || s[i] != '.' {
        return false;
    }
    i += 1;
    while i < s.len() && s[i].is_ascii_digit() {
        i += 1;
    }
    if i == s.len() {
        return true;
    }
    exponent_to_end(s, i)
}

/// Matches `\d+(\.\d*)?` followed by a mandatory exponent, end-anchored.
fn float_with_exponent(s: &[char]) -> bool {
    let mut i = 0usize;
    let start = i;
    while i < s.len() && s[i].is_ascii_digit() {
        i += 1;
    }
    if i == start {
        return false;
    }
    if i < s.len() && s[i] == '.' {
        i += 1;
        while i < s.len() && s[i].is_ascii_digit() {
            i += 1;
        }
    }
    exponent_to_end(s, i)
}

fn exponent_to_end(s: &[char], mut i: usize) -> bool {
    if i >= s.len() || (s[i] != 'e' && s[i] != 'E') {
        return false;
    }
    i += 1;
    if i < s.len() && (s[i] == '+' || s[i] == '-') {
        i += 1;
    }
    let start = i;
    while i < s.len() && s[i].is_ascii_digit() {
        i += 1;
    }
    i > start && i == s.len()
}

/// Matches `\d[\d_]*(\.\d*)?(f32|f64)`, end-anchored.
fn float_with_suffix(s: &[char]) -> bool {
    let mut i = 0usize;
    if s.is_empty() || !s[0].is_ascii_digit() {
        return false;
    }
    i += 1;
    while i < s.len() && (s[i].is_ascii_digit() || s[i] == '_') {
        i += 1;
    }
    if i < s.len() && s[i] == '.' {
        i += 1;
        while i < s.len() && s[i].is_ascii_digit() {
            i += 1;
        }
    }
    let rest: String = s[i..].iter().collect();
    rest == "f32" || rest == "f64"
}

/// True when some suffix of `tok` is a float literal, or `tok` carries
/// a `.fract()` call.
fn is_floaty_token(tok: &str) -> bool {
    if tok.contains(".fract()") {
        return true;
    }
    let chars: Vec<char> = tok.chars().collect();
    (0..chars.len()).any(|i| {
        float_with_point(&chars[i..])
            || float_with_exponent(&chars[i..])
            || float_with_suffix(&chars[i..])
    })
}

/// Contexts of `==`/`!=` comparisons on `line` where an adjacent token
/// is floaty. Compound operators (`<=`, `>=`, `=>`, `+=`, …) and
/// pattern-ish `===` sequences are skipped.
fn float_eq_hits(line: &str) -> Vec<String> {
    let chars: Vec<char> = line.chars().collect();
    let mut hits = Vec::new();
    let mut i = 0usize;
    while i + 1 < chars.len() {
        let op = (chars[i], chars[i + 1]);
        if op != ('=', '=') && op != ('!', '=') {
            i += 1;
            continue;
        }
        let (s, e) = (i, i + 2);
        i += 2; // finditer-style: never re-match inside this operator
        if s > 0 && "=<>!+-*/%&|^".contains(chars[s - 1]) {
            continue;
        }
        if e < chars.len() && chars[e] == '=' {
            continue;
        }
        let token = |c: char| c.is_alphanumeric() || c == '_' || c == '.';
        let mut ls = s;
        while ls > 0 && chars[ls - 1].is_whitespace() {
            ls -= 1;
        }
        let mut lstart = ls;
        while lstart > 0 && token(chars[lstart - 1]) {
            lstart -= 1;
        }
        let ltok: String = chars[lstart..ls].iter().collect();
        let mut rs = e;
        while rs < chars.len() && chars[rs].is_whitespace() {
            rs += 1;
        }
        let mut rend = rs;
        while rend < chars.len() && token(chars[rend]) {
            rend += 1;
        }
        let rtok: String = chars[rs..rend].iter().collect();
        if is_floaty_token(&ltok) || is_floaty_token(&rtok) {
            hits.push(context_of(line));
        }
    }
    hits
}

/// Lint one file (already-loaded text). `rel` is the path relative to
/// `rust/src`, which selects the nondet scope.
pub fn lint_file(rel: &str, text: &str) -> Vec<Finding> {
    let sf = SourceFile::parse(rel, text);
    let mut out = Vec::new();
    for (idx, line) in sf.code.iter().enumerate() {
        if sf.in_test[idx] {
            continue;
        }
        let lineno = idx + 1;
        for pat in PANIC_PATTERNS {
            if line.contains(pat) && !sf.is_waived(idx, "panic") {
                out.push(Finding::new(
                    rel,
                    lineno,
                    "panic",
                    format!("{pat} in non-test library code | {}", context_of(line)),
                ));
            }
        }
        if nondet_scope(rel) {
            for pat in NONDET_PATTERNS {
                if line.contains(pat) && !sf.is_waived(idx, "nondet") {
                    out.push(Finding::new(
                        rel,
                        lineno,
                        "nondet",
                        format!("{pat} in a byte-identity layer | {}", context_of(line)),
                    ));
                }
            }
            if !line.trim_start().starts_with("use ") {
                for pat in NONDET_COLLECTIONS {
                    if contains_word(line, pat) && !sf.is_waived(idx, "nondet") {
                        out.push(Finding::new(
                            rel,
                            lineno,
                            "nondet",
                            format!(
                                "{pat} in a byte-identity layer (iteration order can leak \
                                 into output; use BTreeMap/BTreeSet or waive with the why) | {}",
                                context_of(line)
                            ),
                        ));
                    }
                }
            }
        }
        for ctx in float_eq_hits(line) {
            if !sf.is_waived(idx, "float-eq") {
                out.push(Finding::new(
                    rel,
                    lineno,
                    "float-eq",
                    format!("float comparison with == | {ctx}"),
                ));
            }
        }
    }
    out
}

/// Lint every `.rs` file under `src_root` (normally `rust/src`).
pub fn run(src_root: &Path) -> anyhow::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    for (rel, text) in walk_sources(src_root)? {
        findings.extend(lint_file(&rel, &text));
    }
    Ok(findings)
}

/// A synthetic source that must trip the given lint class — used by
/// `analysis --seed <class>` and the self-tests to prove the pass
/// actually fails the build on a violation.
pub fn seeded_violation(class: &str) -> Option<(&'static str, &'static str)> {
    match class {
        "panic" => Some((
            "seeded/panic.rs",
            "pub fn first(xs: &[u8]) -> u8 {\n    *xs.first().unwrap()\n}\n",
        )),
        "nondet" => Some((
            "dse/seeded_nondet.rs",
            "pub fn stamp() -> std::time::Instant {\n    std::time::Instant::now()\n}\n",
        )),
        "float-eq" => Some((
            "sim/seeded_float.rs",
            "pub fn is_half(x: f64) -> bool {\n    x == 0.5\n}\n",
        )),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn each_seeded_violation_is_caught() {
        for class in ["panic", "nondet", "float-eq"] {
            let (rel, text) = seeded_violation(class).unwrap();
            let findings = lint_file(rel, text);
            assert!(
                findings.iter().any(|f| f.class == class),
                "{class}: {findings:?}"
            );
        }
    }

    #[test]
    fn panics_in_strings_comments_and_tests_are_ignored() {
        let src = r#"
pub fn ok() -> String {
    // .unwrap() would panic! here
    format!("never .unwrap() in messages")
}
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        Some(1).unwrap();
    }
}
"#;
        assert!(lint_file("m.rs", src).is_empty());
    }

    #[test]
    fn waiver_suppresses_exactly_its_class_and_site() {
        let src = "pub fn f(x: Option<u8>) -> u8 {\n    // analysis: allow(panic, caller guarantees Some)\n    x.unwrap()\n}\npub fn g(y: Option<u8>) -> u8 {\n    y.unwrap()\n}\n";
        let findings = lint_file("m.rs", src);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].line, 6);
    }

    #[test]
    fn nondet_scope_is_path_sensitive() {
        let src = "pub fn now() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
        assert!(lint_file("cli_helpers.rs", src).is_empty());
        assert_eq!(lint_file("sim/clock.rs", src).len(), 1);
    }

    #[test]
    fn hashmap_is_flagged_in_scope_but_not_on_use_lines() {
        let src = "use std::collections::HashMap;\npub struct S {\n    pub m: HashMap<u8, u8>,\n}\n";
        let findings = lint_file("report/x.rs", src);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].line, 3);
        // identifier boundaries: MyHashMapLike must not match
        assert!(!contains_word("let x: MyHashMapLike = y;", "HashMap"));
    }

    #[test]
    fn float_eq_catches_literals_and_fract_not_compound_ops() {
        assert_eq!(float_eq_hits("if x == 0.5 {").len(), 1);
        assert_eq!(float_eq_hits("if 1e3 != y {").len(), 1);
        assert_eq!(float_eq_hits("if x == 2f64 {").len(), 1);
        assert_eq!(float_eq_hits("if n.fract() == 0.0 {").len(), 1);
        assert!(float_eq_hits("if x <= 0.5 {").is_empty());
        assert!(float_eq_hits("let f = |a: f64| a >= 1.0;").is_empty());
        assert!(float_eq_hits("if count == 5 {").is_empty());
        // documented limit: variable-vs-variable comparisons pass
        assert!(float_eq_hits("if a.fmax == b.fmax {").is_empty());
    }
}
