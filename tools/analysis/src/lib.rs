//! In-repo analysis suite for the CNN2Gate workspace.
//!
//! Four offline passes, zero dependencies beyond the workspace itself,
//! all runnable as `cargo run -p analysis` (see `src/main.rs`):
//!
//! * [`lints`] — custom source lints over `rust/src/**`: no
//!   panic-capable calls in non-test library code, no nondeterminism
//!   sources inside the byte-identity layers, no float `==` against
//!   literals. Waivable per site with
//!   `// analysis: allow(<class>, <reason>)`.
//! * [`locks`] — static lock-order checking: every Mutex acquisition in
//!   the threaded modules must resolve to a lock declared in
//!   `tools/analysis/lock_order.toml`, and every *nested* acquisition
//!   must be declared there and respect the manifest's total order.
//! * [`mc`] — a bounded model checker that drives the real
//!   [`kernel`](cnn2gate::coordinator::service::kernel) transition
//!   functions and [`Reducer`](cnn2gate::coordinator::service::Reducer)
//!   through every Submit/Cancel/Shutdown/completion interleaving up to
//!   a depth bound, asserting the service invariants at every node.
//! * [`fuzz`] — deterministic structure-aware fuzz harnesses that feed
//!   hostile inputs to the ONNX parser, the JSON parser and the
//!   evaluation-cache loader; every input must be accepted or rejected
//!   gracefully, never by panic.
//!
//! The passes live in a library so both the `analysis` binary and the
//! crate's own tests (including the seeded-violation self-tests) share
//! one implementation.

use std::fmt;

pub mod fuzz;
pub mod lints;
pub mod locks;
pub mod mc;
pub mod scan;

/// One violation reported by the lint or lock pass.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the scanned root (e.g. `dse/eval.rs`).
    pub file: String,
    /// 1-indexed source line.
    pub line: usize,
    /// Lint class: `panic`, `nondet`, `float-eq` or `lock-order`.
    pub class: &'static str,
    /// Human-readable description with source context.
    pub message: String,
}

impl Finding {
    pub fn new(file: &str, line: usize, class: &'static str, message: String) -> Finding {
        Finding {
            file: file.to_string(),
            line,
            class,
            message,
        }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.class, self.message
        )
    }
}
