//! Bounded model checker for the compile-service kernel.
//!
//! The service splits into a pure decision core
//! ([`kernel`](cnn2gate::coordinator::service::kernel) +
//! [`Reducer`](cnn2gate::coordinator::service::Reducer)) and a threaded
//! shell (the orchestrator). This checker exhaustively enumerates every
//! interleaving of the shell's observable actions — Submit, Cancel (of
//! a queued or running job), worker completion (success and failure)
//! and Shutdown — up to a depth bound, driving the *real* kernel
//! functions and the *real* reducer, and asserts the service invariants
//! at every node:
//!
//! * the admission queue never exceeds its capacity;
//! * running jobs never exceed the worker slots;
//! * the reducer's job states stay coherent with the queue/running sets
//!   (no lost jobs, no duplicated jobs, terminal means gone);
//! * launches are fair: the launched job minimizes the documented
//!   `(running-of-tenant, served-of-tenant, cost, seq)` key, checked
//!   against an independent re-derivation, so [`pick_next`] cannot
//!   silently regress into a starvation policy;
//! * after Shutdown the queue is drained (every queued job cancelled)
//!   and new submissions are rejected;
//! * at every leaf, [`Reducer::replay`] of the event log reconstructs
//!   the live reducer exactly, and every per-job event stream is a
//!   legal lifecycle (admission first, at most one terminal event,
//!   nothing after it).
//!
//! With the default bound (2 workers, capacity 2, 5 submissions, depth
//! 6) the tree has ~212k leaves — comfortably past the 10k-interleaving
//! gate — and still runs in seconds because each step is pure data.
//! Five submissions (not four) make the queue-full rejection reachable:
//! two launch immediately, two fill the queue, the fifth bounces.

use std::collections::HashMap;

use cnn2gate::coordinator::service::kernel::{pick_next, QueueView};
use cnn2gate::coordinator::service::{Event, JobId, JobState, Reducer};
use cnn2gate::dse::TenantId;

/// Exploration bounds.
#[derive(Debug, Clone, Copy)]
pub struct McConfig {
    pub workers: usize,
    pub capacity: usize,
    pub max_submits: usize,
    pub depth: usize,
}

impl Default for McConfig {
    fn default() -> Self {
        McConfig {
            workers: 2,
            capacity: 2,
            max_submits: 5,
            depth: 6,
        }
    }
}

/// What the exploration saw. `leaves` is the number of complete
/// interleavings checked end-to-end.
#[derive(Debug, Default, Clone, Copy)]
pub struct McStats {
    pub nodes: u64,
    pub leaves: u64,
    pub rejected: u64,
    pub cancelled_queued: u64,
    pub cancelled_running: u64,
    pub shutdown_drains: u64,
    pub finished: u64,
    pub failed: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Action {
    Submit { tenant: u8, cost: u64 },
    CancelQueued(u64),
    CancelRunning(u64),
    DoneOk(u64),
    DoneErr(u64),
    Shutdown,
}

#[derive(Clone)]
struct QueuedJob {
    id: u64,
    tenant: TenantId,
    cost: u64,
}

#[derive(Clone)]
struct RunningJob {
    id: u64,
    tenant: TenantId,
    cancel_flag: bool,
}

/// The orchestrator shell modeled over the real kernel + reducer: the
/// same admission, drain, launch and completion rules as
/// `orchestrator.rs`, minus threads and channels.
#[derive(Clone)]
struct Model {
    reducer: Reducer,
    queue: Vec<QueuedJob>,
    running: Vec<RunningJob>,
    running_counts: HashMap<u64, usize>,
    served: HashMap<u64, usize>,
    next_id: u64,
    submits: usize,
    shutdown: bool,
}

fn tenant_of(tag: u8) -> TenantId {
    if tag == 0 {
        TenantId::DEFAULT
    } else {
        TenantId::of("acme")
    }
}

impl Model {
    fn new() -> Model {
        Model {
            reducer: Reducer::new(),
            queue: Vec::new(),
            running: Vec::new(),
            running_counts: HashMap::new(),
            served: HashMap::new(),
            next_id: 0,
            submits: 0,
            shutdown: false,
        }
    }

    fn actions(&self, cfg: &McConfig) -> Vec<Action> {
        let mut out = Vec::new();
        if self.submits < cfg.max_submits {
            for tenant in 0..2u8 {
                for cost in [1, 5] {
                    out.push(Action::Submit { tenant, cost });
                }
            }
        }
        for q in &self.queue {
            out.push(Action::CancelQueued(q.id));
        }
        for r in &self.running {
            if !r.cancel_flag {
                out.push(Action::CancelRunning(r.id));
            }
        }
        for r in &self.running {
            out.push(Action::DoneOk(r.id));
            out.push(Action::DoneErr(r.id));
        }
        if !self.shutdown {
            out.push(Action::Shutdown);
        }
        out
    }

    fn apply(&mut self, action: &Action, cfg: &McConfig, stats: &mut McStats) -> Result<(), String> {
        match *action {
            Action::Submit { tenant, cost } => {
                let job = JobId(self.next_id);
                self.next_id += 1;
                self.submits += 1;
                let tenant = tenant_of(tenant);
                if self.shutdown {
                    stats.rejected += 1;
                    self.reducer.apply(&Event::Rejected {
                        job,
                        tenant,
                        reason: "service shutting down".into(),
                    });
                } else if self.queue.len() >= cfg.capacity.max(1) {
                    stats.rejected += 1;
                    self.reducer.apply(&Event::Rejected {
                        job,
                        tenant,
                        reason: format!("admission queue full ({} jobs)", self.queue.len()),
                    });
                } else {
                    self.reducer.apply(&Event::Accepted {
                        job,
                        tenant,
                        queue_depth: self.queue.len(),
                    });
                    self.queue.push(QueuedJob {
                        id: job.0,
                        tenant,
                        cost,
                    });
                    self.launch_ready(cfg)?;
                }
            }
            Action::CancelQueued(id) => {
                let pos = self
                    .queue
                    .iter()
                    .position(|q| q.id == id)
                    .ok_or_else(|| format!("cancel of unqueued job {id}"))?;
                self.queue.remove(pos);
                stats.cancelled_queued += 1;
                self.reducer.apply(&Event::Cancelled { job: JobId(id) });
            }
            Action::CancelRunning(id) => {
                let r = self
                    .running
                    .iter_mut()
                    .find(|r| r.id == id)
                    .ok_or_else(|| format!("cancel of non-running job {id}"))?;
                r.cancel_flag = true;
            }
            Action::DoneOk(id) => {
                self.finish(id, true, cfg, stats)?;
            }
            Action::DoneErr(id) => {
                self.finish(id, false, cfg, stats)?;
            }
            Action::Shutdown => {
                self.shutdown = true;
                if !self.queue.is_empty() {
                    stats.shutdown_drains += 1;
                }
                for q in std::mem::take(&mut self.queue) {
                    self.reducer.apply(&Event::Cancelled { job: JobId(q.id) });
                }
            }
        }
        Ok(())
    }

    /// Completion: the orchestrator counts the tenant as served, then
    /// reports Finished on success (even when a cancel raced in late —
    /// the result is real), Cancelled on a flagged failure, Failed
    /// otherwise; the freed slot immediately launches more work.
    fn finish(
        &mut self,
        id: u64,
        ok: bool,
        cfg: &McConfig,
        stats: &mut McStats,
    ) -> Result<(), String> {
        let pos = self
            .running
            .iter()
            .position(|r| r.id == id)
            .ok_or_else(|| format!("completion of non-running job {id}"))?;
        let r = self.running.remove(pos);
        let t = r.tenant.as_u64();
        *self.served.entry(t).or_insert(0) += 1;
        let slot = self
            .running_counts
            .get_mut(&t)
            .ok_or_else(|| format!("running count missing for tenant {t}"))?;
        *slot = slot.saturating_sub(1);
        let event = if ok {
            stats.finished += 1;
            Event::Finished {
                job: JobId(id),
                outcome_json: "{}".into(),
            }
        } else if r.cancel_flag {
            stats.cancelled_running += 1;
            Event::Cancelled { job: JobId(id) }
        } else {
            stats.failed += 1;
            Event::Failed {
                job: JobId(id),
                error: "boom".into(),
            }
        };
        self.reducer.apply(&event);
        self.launch_ready(cfg)
    }

    /// Fill free worker slots via the real [`pick_next`], re-deriving
    /// the fairness key independently to pin the policy.
    fn launch_ready(&mut self, cfg: &McConfig) -> Result<(), String> {
        while !self.shutdown
            && self.running.len() < cfg.workers.max(1)
            && !self.queue.is_empty()
        {
            let views: Vec<QueueView> = self
                .queue
                .iter()
                .map(|q| QueueView {
                    seq: q.id,
                    tenant: q.tenant,
                    cost: q.cost,
                })
                .collect();
            let pick = pick_next(&views, &self.running_counts, &self.served)
                .ok_or("pick_next returned None for a non-empty queue")?;
            let key = |v: &QueueView| {
                let t = v.tenant.as_u64();
                (
                    self.running_counts.get(&t).copied().unwrap_or(0),
                    self.served.get(&t).copied().unwrap_or(0),
                    v.cost,
                    v.seq,
                )
            };
            let min_key = views.iter().map(key).min().ok_or("empty views")?;
            if key(&views[pick]) != min_key {
                return Err(format!(
                    "fairness violation: pick_next chose {:?} but the minimum key is {min_key:?}",
                    key(&views[pick])
                ));
            }
            let q = self.queue.remove(pick);
            self.reducer.apply(&Event::Started { job: JobId(q.id) });
            *self.running_counts.entry(q.tenant.as_u64()).or_insert(0) += 1;
            self.running.push(RunningJob {
                id: q.id,
                tenant: q.tenant,
                cancel_flag: false,
            });
        }
        Ok(())
    }

    /// Invariants checked at every node.
    fn check(&self, cfg: &McConfig) -> Result<(), String> {
        if self.queue.len() > cfg.capacity.max(1) {
            return Err(format!(
                "queue bound broken: {} queued > capacity {}",
                self.queue.len(),
                cfg.capacity
            ));
        }
        if self.running.len() > cfg.workers.max(1) {
            return Err(format!(
                "worker bound broken: {} running > workers {}",
                self.running.len(),
                cfg.workers
            ));
        }
        if self.shutdown && !self.queue.is_empty() {
            return Err("shutdown left jobs in the queue".into());
        }
        // reducer coherence: exactly the queue is Queued, exactly the
        // running set is Running, everything else is terminal
        for q in &self.queue {
            match self.reducer.get(JobId(q.id)) {
                Some(rec) if rec.state == JobState::Queued => {}
                other => return Err(format!("queued job {} recorded as {other:?}", q.id)),
            }
        }
        for r in &self.running {
            match self.reducer.get(JobId(r.id)) {
                Some(rec) if rec.state == JobState::Running => {}
                other => return Err(format!("running job {} recorded as {other:?}", r.id)),
            }
        }
        for (job, rec) in self.reducer.jobs() {
            let queued = self.queue.iter().any(|q| q.id == job.0);
            let running = self.running.iter().any(|r| r.id == job.0);
            let want = match rec.state {
                JobState::Queued => (true, false),
                JobState::Running => (false, true),
                _ => (false, false),
            };
            if (queued, running) != want {
                return Err(format!(
                    "job {} in state {:?} but (queued, running) = {:?}",
                    job.0,
                    rec.state,
                    (queued, running)
                ));
            }
        }
        Ok(())
    }

    /// Leaf-only checks: replay exactness and per-job stream legality.
    fn check_leaf(&self) -> Result<(), String> {
        if Reducer::replay(self.reducer.log()) != self.reducer {
            return Err("replay of the event log diverged from the live reducer".into());
        }
        // stream legality, tracked independently of kernel::step
        #[derive(PartialEq, Debug, Clone, Copy)]
        enum Phase {
            Queued,
            Running,
            Terminal,
        }
        let mut phases: HashMap<u64, Phase> = HashMap::new();
        for event in self.reducer.log() {
            let id = event.job().0;
            let cur = phases.get(&id).copied();
            let next = match (cur, event) {
                (None, Event::Accepted { .. }) => Phase::Queued,
                (None, Event::Rejected { .. }) => Phase::Terminal,
                (Some(Phase::Queued), Event::Started { .. }) => Phase::Running,
                (Some(Phase::Queued), Event::Cancelled { .. }) => Phase::Terminal,
                (Some(Phase::Running), Event::Finished { .. })
                | (Some(Phase::Running), Event::Failed { .. })
                | (Some(Phase::Running), Event::Cancelled { .. }) => Phase::Terminal,
                (Some(Phase::Running), Event::Progress { .. }) => Phase::Running,
                (cur, e) => {
                    return Err(format!(
                        "illegal event for job {id} in phase {cur:?}: {e:?}"
                    ))
                }
            };
            phases.insert(id, next);
        }
        Ok(())
    }
}

fn dfs(
    model: &Model,
    depth: usize,
    cfg: &McConfig,
    stats: &mut McStats,
    trace: &mut Vec<String>,
) -> Result<(), String> {
    stats.nodes += 1;
    let actions = model.actions(cfg);
    if depth == cfg.depth || actions.is_empty() {
        stats.leaves += 1;
        return model
            .check_leaf()
            .map_err(|e| format!("{e}\n  after: {}", trace.join(", ")));
    }
    for action in actions {
        let mut child = model.clone();
        trace.push(format!("{action:?}"));
        let step = child
            .apply(&action, cfg, stats)
            .and_then(|()| child.check(cfg));
        step.map_err(|e| format!("{e}\n  after: {}", trace.join(", ")))?;
        dfs(&child, depth + 1, cfg, stats, trace)?;
        trace.pop();
    }
    Ok(())
}

/// Exhaustively explore every interleaving up to `cfg.depth`. `Err`
/// carries the invariant violation plus the smallest action trace that
/// reaches it (DFS order visits shorter prefixes first).
pub fn explore(cfg: &McConfig) -> Result<McStats, String> {
    let mut stats = McStats::default();
    let mut trace = Vec::new();
    dfs(&Model::new(), 0, cfg, &mut stats, &mut trace)?;
    // the bound must actually exercise every behavior class, otherwise
    // the invariants above are vacuous
    let covered = [
        ("rejection", stats.rejected),
        ("queued-cancel", stats.cancelled_queued),
        ("running-cancel", stats.cancelled_running),
        ("shutdown-drain", stats.shutdown_drains),
        ("success", stats.finished),
        ("failure", stats.failed),
    ];
    for (what, count) in covered {
        if count == 0 {
            return Err(format!(
                "bound too shallow: no {what} interleaving was explored"
            ));
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_exploration_holds_all_invariants() {
        // depth 5 keeps the debug-profile test fast (~23k leaves); the
        // binary runs the full depth-6 bound (~212k) in release
        let cfg = McConfig {
            depth: 5,
            ..McConfig::default()
        };
        let stats = explore(&cfg).expect("invariants must hold");
        assert!(
            stats.leaves >= 10_000,
            "need >= 10k interleavings, got {}",
            stats.leaves
        );
        assert!(stats.nodes > stats.leaves);
    }

    #[test]
    fn a_planted_unfair_policy_would_be_caught() {
        // sanity-check the independent fairness oracle: feed launch_ready
        // a served table that makes the documented key disagree with a
        // naive FIFO choice, and confirm the model follows the key
        let cfg = McConfig::default();
        let mut m = Model::new();
        // two tenants; tenant 1 heavily served, so tenant 0 must win
        // even though tenant 1's job is older and cheaper
        m.queue.push(QueuedJob {
            id: 0,
            tenant: tenant_of(1),
            cost: 1,
        });
        m.queue.push(QueuedJob {
            id: 1,
            tenant: tenant_of(0),
            cost: 5,
        });
        m.reducer.apply(&Event::Accepted {
            job: JobId(0),
            tenant: tenant_of(1),
            queue_depth: 0,
        });
        m.reducer.apply(&Event::Accepted {
            job: JobId(1),
            tenant: tenant_of(0),
            queue_depth: 1,
        });
        m.served.insert(tenant_of(1).as_u64(), 7);
        m.launch_ready(&cfg).unwrap();
        // both launch (2 workers), but the starved tenant goes first
        assert_eq!(m.running[0].id, 1, "least-served tenant launches first");
        m.check(&cfg).unwrap();
    }
}
