//! Comment/string-aware source scanning shared by the lint and
//! lock-order passes.
//!
//! [`strip`] splits a Rust source into two aligned views: `code` lines
//! (comment and string/char-literal text blanked to spaces) and
//! `comments` lines (only comment text kept). Pattern checks run on the
//! code view, so `panic!` inside a doc comment or an error-message
//! string never trips a lint; waiver scanning runs on the comment view,
//! so a waiver can never hide inside a string literal. Both views keep
//! every newline, so line numbers match the original file exactly.
//!
//! This is a token-level scanner, not a Rust parser: it understands
//! line/nested-block comments, plain and raw (`r"…"`, `r#"…"#`, with a
//! `b` prefix) strings, escapes, and char-vs-lifetime ticks — enough to
//! make substring lints sound on real code — and nothing more.

use std::collections::{BTreeSet, HashMap};
use std::path::Path;

use anyhow::{Context, Result};

/// A source file split into blanked code lines and comment lines.
#[derive(Debug)]
pub struct Stripped {
    pub code: Vec<String>,
    pub comments: Vec<String>,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// True when the `r` at `i` starts a raw string rather than ending an
/// identifier (`var`, or a `b` prefix that itself ends one).
fn raw_string_starts(t: &[char], i: usize) -> bool {
    if i == 0 || !is_ident(t[i - 1]) {
        return true;
    }
    t[i - 1] == 'b' && (i < 2 || !is_ident(t[i - 2]))
}

/// Blank comments and string/char literals out of `text`; collect the
/// comment text separately. Both outputs are split into lines.
pub fn strip(text: &str) -> Stripped {
    #[derive(PartialEq)]
    enum State {
        Code,
        LineComment,
        BlockComment,
        Str,
        RawStr,
        Char,
    }
    let t: Vec<char> = text.chars().collect();
    let n = t.len();
    let mut code = String::with_capacity(n);
    let mut comments = String::with_capacity(n);
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    let mut state = State::Code;
    let mut block_depth = 0usize;
    let mut raw_hashes = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = t[i];
        let nxt = if i + 1 < n { t[i + 1] } else { '\0' };
        match state {
            State::Code => {
                if c == '/' && nxt == '/' {
                    state = State::LineComment;
                    comments.push_str("//");
                    code.push_str("  ");
                    i += 1;
                } else if c == '/' && nxt == '*' {
                    state = State::BlockComment;
                    block_depth = 1;
                    comments.push_str("/*");
                    code.push_str("  ");
                    i += 1;
                } else if c == '"' {
                    state = State::Str;
                    code.push('"');
                    comments.push(' ');
                } else if c == 'r' && (nxt == '"' || nxt == '#') && raw_string_starts(&t, i) {
                    let mut j = i + 1;
                    let mut hashes = 0usize;
                    while j < n && t[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < n && t[j] == '"' {
                        code.push('r');
                        comments.push(' ');
                        for &k in t.iter().take(j + 1).skip(i + 1) {
                            code.push(blank(k));
                            comments.push(blank(k));
                        }
                        i = j;
                        raw_hashes = hashes;
                        state = State::RawStr;
                    } else {
                        code.push(c);
                        comments.push(' ');
                    }
                } else if c == '\'' {
                    if nxt == '\\' {
                        state = State::Char;
                        code.push(' ');
                        comments.push(' ');
                    } else if i + 2 < n && t[i + 2] == '\'' && nxt != '\'' {
                        // plain char literal 'x'
                        code.push(' ');
                        comments.push(' ');
                        code.push(blank(nxt));
                        comments.push(blank(nxt));
                        code.push(' ');
                        comments.push(' ');
                        i += 2;
                    } else {
                        // lifetime tick
                        code.push(c);
                        comments.push(' ');
                    }
                } else {
                    code.push(c);
                    comments.push(blank(c));
                }
            }
            State::LineComment => {
                if c == '\n' {
                    state = State::Code;
                    code.push('\n');
                    comments.push('\n');
                } else {
                    code.push(' ');
                    comments.push(c);
                }
            }
            State::BlockComment => {
                if c == '/' && nxt == '*' {
                    block_depth += 1;
                    comments.push_str("/*");
                    code.push_str("  ");
                    i += 1;
                } else if c == '*' && nxt == '/' {
                    block_depth -= 1;
                    comments.push_str("*/");
                    code.push_str("  ");
                    i += 1;
                    if block_depth == 0 {
                        state = State::Code;
                    }
                } else {
                    code.push(blank(c));
                    comments.push(if c == '\n' { '\n' } else { c });
                }
            }
            State::Str => {
                if c == '\\' {
                    code.push(' ');
                    comments.push(' ');
                    if nxt != '\0' {
                        i += 1;
                        code.push(blank(nxt));
                        comments.push(blank(nxt));
                    }
                } else if c == '"' {
                    code.push('"');
                    comments.push(' ');
                    state = State::Code;
                } else {
                    code.push(blank(c));
                    comments.push(blank(c));
                }
            }
            State::RawStr => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut hashes = 0usize;
                    while j < n && t[j] == '#' && hashes < raw_hashes {
                        hashes += 1;
                        j += 1;
                    }
                    if hashes == raw_hashes {
                        for &k in t.iter().take(j).skip(i) {
                            code.push(blank(k));
                            comments.push(blank(k));
                        }
                        i = j - 1;
                        state = State::Code;
                    } else {
                        code.push(' ');
                        comments.push(' ');
                    }
                } else {
                    code.push(blank(c));
                    comments.push(blank(c));
                }
            }
            State::Char => {
                if c == '\'' {
                    state = State::Code;
                }
                code.push(blank(c));
                comments.push(blank(c));
            }
        }
        i += 1;
    }
    let lines = |s: String| s.split('\n').map(String::from).collect();
    Stripped {
        code: lines(code),
        comments: lines(comments),
    }
}

/// Per-line flags: true for every line covered by a `#[cfg(test)]` item
/// (attribute line through the item's matching closing brace).
pub fn test_region_lines(code: &[String]) -> Vec<bool> {
    let mut covered = vec![false; code.len()];
    let text: Vec<char> = code.join("\n").chars().collect();
    if text.is_empty() {
        return covered;
    }
    // line index of each char position (= newlines before it)
    let mut line_of = Vec::with_capacity(text.len());
    let mut ln = 0usize;
    for &c in &text {
        line_of.push(ln);
        if c == '\n' {
            ln += 1;
        }
    }
    let needle: Vec<char> = "#[cfg(test)]".chars().collect();
    let mut pos = 0usize;
    while pos + needle.len() <= text.len() {
        if text[pos..pos + needle.len()] != needle[..] {
            pos += 1;
            continue;
        }
        let mut i = pos + needle.len();
        let mut depth = 0i64;
        let mut started = false;
        while i < text.len() {
            match text[i] {
                ';' if !started => break,
                '{' => {
                    depth += 1;
                    started = true;
                }
                '}' => {
                    depth -= 1;
                    if started && depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            i += 1;
        }
        let start_line = line_of[pos];
        let end_line = line_of[i.min(text.len() - 1)];
        for flag in covered.iter_mut().take(end_line + 1).skip(start_line) {
            *flag = true;
        }
        pos += needle.len();
    }
    covered
}

/// Waiver classes granted per comment line:
/// `// analysis: allow(<class>, <reason>)`. The reason is mandatory —
/// a waiver without one does not register.
pub fn waivers(comments: &[String]) -> HashMap<usize, BTreeSet<String>> {
    let mut out: HashMap<usize, BTreeSet<String>> = HashMap::new();
    for (idx, line) in comments.iter().enumerate() {
        let chars: Vec<char> = line.chars().collect();
        let mut from = 0usize;
        while let Some(at) = find_from(&chars, from, "analysis:") {
            from = at + 1;
            let mut i = at + "analysis:".len();
            i = skip_ws(&chars, i);
            if !starts_at(&chars, i, "allow(") {
                continue;
            }
            i += "allow(".len();
            i = skip_ws(&chars, i);
            let start = i;
            while i < chars.len() && (chars[i].is_ascii_lowercase() || chars[i] == '-') {
                i += 1;
            }
            if i == start {
                continue;
            }
            let class: String = chars[start..i].iter().collect();
            i = skip_ws(&chars, i);
            if i >= chars.len() || chars[i] != ',' {
                continue;
            }
            i = skip_ws(&chars, i + 1);
            if i >= chars.len() || chars[i] == ')' {
                continue; // empty reason: the waiver does not count
            }
            out.entry(idx).or_default().insert(class);
        }
    }
    out
}

fn skip_ws(chars: &[char], mut i: usize) -> usize {
    while i < chars.len() && chars[i].is_whitespace() {
        i += 1;
    }
    i
}

fn starts_at(chars: &[char], i: usize, pat: &str) -> bool {
    let p: Vec<char> = pat.chars().collect();
    i + p.len() <= chars.len() && chars[i..i + p.len()] == p[..]
}

fn find_from(chars: &[char], from: usize, pat: &str) -> Option<usize> {
    let p: Vec<char> = pat.chars().collect();
    if p.is_empty() || chars.len() < p.len() {
        return None;
    }
    (from..=chars.len() - p.len()).find(|&i| chars[i..i + p.len()] == p[..])
}

/// A parsed source file ready for lint checks.
pub struct SourceFile {
    pub rel: String,
    pub code: Vec<String>,
    pub comments: Vec<String>,
    pub in_test: Vec<bool>,
    waived: HashMap<usize, BTreeSet<String>>,
}

impl SourceFile {
    pub fn parse(rel: &str, text: &str) -> SourceFile {
        let stripped = strip(text);
        let in_test = test_region_lines(&stripped.code);
        let waived = waivers(&stripped.comments);
        SourceFile {
            rel: rel.to_string(),
            code: stripped.code,
            comments: stripped.comments,
            in_test,
            waived,
        }
    }

    /// A waiver applies on its own line or the line directly above.
    pub fn is_waived(&self, idx: usize, class: &str) -> bool {
        let has = |i: usize| self.waived.get(&i).is_some_and(|s| s.contains(class));
        has(idx) || (idx > 0 && has(idx - 1))
    }
}

/// All `.rs` files under `root`, as (relative path, contents), sorted
/// by path for deterministic reports.
pub fn walk_sources(root: &Path) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let entries =
            std::fs::read_dir(&dir).with_context(|| format!("reading {}", dir.display()))?;
        for entry in entries {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path
                    .strip_prefix(root)
                    .unwrap_or(&path)
                    .to_string_lossy()
                    .replace('\\', "/");
                let text = std::fs::read_to_string(&path)
                    .with_context(|| format!("reading {}", path.display()))?;
                out.push((rel, text));
            }
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked_but_lines_hold() {
        let src = "let a = \"panic!\"; // panic! here\nlet b = 1;\n/* panic!\n spans */ let c;\n";
        let s = strip(src);
        assert_eq!(s.code.len(), s.comments.len());
        assert!(!s.code.join("\n").contains("panic!"));
        assert!(s.comments[0].contains("panic! here"));
        assert!(s.comments[2].contains("panic!"));
        assert!(s.code[3].contains("let c;"));
    }

    #[test]
    fn raw_strings_and_chars_are_blanked() {
        let src = "let a = r#\"x.unwrap()\"#; let b = b\"y\"; let c = '\\n'; let d: &'a u8;";
        let s = strip(src);
        let code = s.code.join("\n");
        assert!(!code.contains("unwrap"));
        assert!(code.contains("&'a u8"), "{code}");
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let s = strip("/* a /* b */ c */ live();");
        assert!(s.code.join("\n").contains("live();"));
        assert!(s.comments.join("\n").contains('b'));
    }

    #[test]
    fn cfg_test_regions_cover_the_braced_item() {
        let src = "fn a() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n  fn t() { y.unwrap(); }\n}\nfn b() {}\n";
        let s = strip(src);
        let flags = test_region_lines(&s.code);
        assert!(!flags[0]);
        assert!(flags[1] && flags[2] && flags[3] && flags[4]);
        assert!(!flags[5]);
    }

    #[test]
    fn waivers_need_a_class_and_a_reason() {
        let src = "// analysis: allow(panic, the loop always yields)\nx();\n// analysis: allow(panic)\ny();\n";
        let s = strip(src);
        let w = waivers(&s.comments);
        assert!(w.get(&0).is_some_and(|c| c.contains("panic")));
        assert!(!w.contains_key(&2), "missing reason must not register");
    }

    #[test]
    fn waiver_applies_to_same_and_next_line_only() {
        let src = "// analysis: allow(float-eq, exact sentinel)\nif x == 0.5 {}\nif y == 0.5 {}\n";
        let f = SourceFile::parse("m.rs", src);
        assert!(f.is_waived(1, "float-eq"));
        assert!(!f.is_waived(2, "float-eq"));
        assert!(!f.is_waived(1, "panic"), "class must match");
    }
}
