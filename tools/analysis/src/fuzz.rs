//! Hostile-input fuzz harnesses for the repo's four parsing surfaces:
//! [`Json::parse`], [`onnx::parse_doc`], [`EvalCache::from_json`] and
//! the sharded cache-store loader [`CacheStore::open`] (hostile
//! manifests, shard bases and delta logs, including torn tails).
//!
//! Everything is deterministic: inputs come from the repo's own
//! [`util::rng`](cnn2gate::util::rng) xoshiro generator seeded per
//! harness, so a failure report (`seed`, iteration) replays exactly.
//! Each iteration builds a hostile input by one of several strategies —
//! raw byte noise, structural-character soup, byte-level mutation of a
//! known-valid document, structural mutation of a parsed tree, nesting
//! bombs around the parser's depth limit, and number torture — and
//! feeds it to the target under `catch_unwind`.
//!
//! The contract is uniform: the parser may accept or reject, but it
//! must never panic, and acceptance must be coherent (for JSON:
//! render-then-reparse reproduces the same tree, modulo the documented
//! NaN/Inf→null degradation).

use std::panic::{self, AssertUnwindSafe};

use cnn2gate::dse::{CacheStore, EvalCache, EvalRequest, Evaluator, Fidelity};
use cnn2gate::estimator::device::ARRIA_10_GX1150;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::{parse_doc, zoo};
use cnn2gate::util::json::Json;
use cnn2gate::util::rng::Rng;

/// What one harness run saw.
#[derive(Debug, Clone, Copy)]
pub struct FuzzOutcome {
    pub target: &'static str,
    pub inputs: u64,
    pub accepted: u64,
    pub rejected: u64,
}

/// Run `f` with panics captured instead of unwinding into the harness.
/// Returns `Err` with the panic payload text if the target panicked.
fn shielded<T>(f: impl FnOnce() -> T) -> Result<T, String> {
    panic::catch_unwind(AssertUnwindSafe(f)).map_err(|payload| {
        if let Some(s) = payload.downcast_ref::<&str>() {
            (*s).to_string()
        } else if let Some(s) = payload.downcast_ref::<String>() {
            s.clone()
        } else {
            "non-string panic payload".to_string()
        }
    })
}

/// Install a silent panic hook for the duration of `f` so expected
/// catch_unwind captures don't spray backtraces over the output.
fn hushed<T>(f: impl FnOnce() -> T) -> T {
    let prev = panic::take_hook();
    panic::set_hook(Box::new(|_| {}));
    let out = f();
    panic::set_hook(prev);
    out
}

// ---------------------------------------------------------------------------
// input generators
// ---------------------------------------------------------------------------

const JSON_SOUP: &[u8] = br#"{}[],:".0123456789eE+-truefalsn \t\n\\u"#;

fn random_bytes(rng: &mut Rng, max_len: u64) -> Vec<u8> {
    let len = rng.below(max_len) as usize;
    (0..len).map(|_| rng.below(256) as u8).collect()
}

fn soup_string(rng: &mut Rng, max_len: u64) -> String {
    let len = rng.below(max_len) as usize;
    (0..len).map(|_| *rng.choose(JSON_SOUP) as char).collect()
}

/// Flip, insert, delete or splice a handful of bytes in a valid text.
fn byte_mutate(rng: &mut Rng, base: &str) -> String {
    let mut bytes = base.as_bytes().to_vec();
    let edits = 1 + rng.below(8);
    for _ in 0..edits {
        if bytes.is_empty() {
            break;
        }
        let at = rng.below(bytes.len() as u64) as usize;
        match rng.below(4) {
            0 => bytes[at] = rng.below(256) as u8,
            1 => bytes.insert(at, *rng.choose(JSON_SOUP)),
            2 => {
                bytes.remove(at);
            }
            _ => {
                let upto = (at + 1 + rng.below(16) as usize).min(bytes.len());
                bytes.drain(at..upto);
            }
        }
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

/// `[[[...1...]]]` with depth hovering around the parser's
/// `MAX_DEPTH = 128` limit, alternating arrays and objects.
fn nesting_bomb(rng: &mut Rng) -> String {
    let depth = 100 + rng.below(80) as usize;
    let mut open = String::new();
    let mut close = String::new();
    for i in 0..depth {
        if (i + rng.below(2) as usize) % 2 == 0 {
            open.push('[');
            close.insert(0, ']');
        } else {
            open.push_str("{\"k\":");
            close.insert(0, '}');
        }
    }
    format!("{open}1{close}")
}

fn number_torture(rng: &mut Rng) -> String {
    let cases = [
        "1e999",
        "-1e999",
        "1e-999",
        "-0.0",
        "0.000000000000000000000000000001",
        "9007199254740993",
        "-9223372036854775809",
        "1.7976931348623157e308",
        "2.2250738585072011e-308",
        "0.1e",
        "--1",
        "1.",
        ".5",
        "+1",
        "0x10",
        "1_000",
        "01",
        "NaN",
        "Infinity",
    ];
    let n = *rng.choose(&cases);
    match rng.below(3) {
        0 => n.to_string(),
        1 => format!("[{n}, {n}]"),
        _ => format!("{{\"v\": {n}}}"),
    }
}

/// A random well-formed tree (finite numbers only, so the roundtrip
/// equality invariant is exact).
fn random_tree(rng: &mut Rng, depth: usize) -> Json {
    let pick = if depth == 0 { rng.below(4) } else { rng.below(6) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => match rng.below(3) {
            0 => Json::Num(rng.range_i64(-1_000_000, 1_000_000) as f64),
            1 => Json::Num(rng.next_f64() * 1e6 - 5e5),
            _ => Json::Num(rng.next_f32() as f64),
        },
        3 => Json::Str(soup_string(rng, 24)),
        4 => {
            let n = rng.below(4) as usize;
            Json::Arr((0..n).map(|_| random_tree(rng, depth - 1)).collect())
        }
        _ => {
            let n = rng.below(4) as usize;
            Json::from_iter_obj(
                (0..n).map(|i| (format!("k{i}_{}", rng.below(10)), random_tree(rng, depth - 1))),
            )
        }
    }
}

/// Structurally mutate one node of a parsed tree: retype it, drop an
/// object key, duplicate or truncate an array, poison a string or
/// number. Keeps numbers finite (the hostile non-finite path is covered
/// by text-level number torture).
fn mutate_tree(rng: &mut Rng, doc: &Json) -> Json {
    if rng.below(3) == 0 {
        return match rng.below(6) {
            0 => Json::Null,
            1 => Json::Bool(true),
            2 => Json::Num(-(rng.below(1 << 40) as f64)),
            3 => Json::Str(soup_string(rng, 40)),
            4 => Json::Arr(vec![doc.clone()]),
            _ => Json::from_iter_obj([("zzz".to_string(), doc.clone())]),
        };
    }
    match doc {
        Json::Arr(items) if !items.is_empty() => {
            let at = rng.below(items.len() as u64) as usize;
            let mut out = items.clone();
            match rng.below(3) {
                0 => out[at] = mutate_tree(rng, &items[at]),
                1 => out.push(items[at].clone()), // duplicate an element
                _ => out.truncate(at),
            }
            Json::Arr(out)
        }
        Json::Obj(o) if !o.is_empty() => {
            let victim = rng.below(o.len() as u64) as usize;
            match rng.below(3) {
                // drop a key (JsonObj has no remove; rebuild without it)
                0 => Json::from_iter_obj(
                    o.iter()
                        .enumerate()
                        .filter(|(i, _)| *i != victim)
                        .map(|(_, (k, v))| (k.clone(), v.clone())),
                ),
                // mutate the value under a key
                1 => Json::from_iter_obj(o.iter().enumerate().map(|(i, (k, v))| {
                    if i == victim {
                        (k.clone(), mutate_tree(rng, v))
                    } else {
                        (k.clone(), v.clone())
                    }
                })),
                // add an unexpected key
                _ => {
                    let mut pairs: Vec<(String, Json)> =
                        o.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
                    pairs.push((soup_string(rng, 12), random_tree(rng, 1)));
                    Json::from_iter_obj(pairs)
                }
            }
        }
        Json::Num(n) => Json::Num(match rng.below(4) {
            0 => -n,
            1 => n * 1e9,
            2 => n + 0.5,
            _ => 0.0,
        }),
        Json::Str(_) => Json::Str(soup_string(rng, 40)),
        other => other.clone(),
    }
}

fn tree_is_finite(doc: &Json) -> bool {
    match doc {
        Json::Num(n) => n.is_finite(),
        Json::Arr(items) => items.iter().all(tree_is_finite),
        Json::Obj(o) => o.iter().all(|(_, v)| tree_is_finite(v)),
        _ => true,
    }
}

// ---------------------------------------------------------------------------
// harnesses
// ---------------------------------------------------------------------------

const ONNX_CONV_DOC: &str = r#"{
  "format": "cnn2gate-onnx-subset-v1",
  "name": "m",
  "input": {"name": "input", "shape": [1, 4, 4], "dtype": "float32"},
  "output": {"name": "y"},
  "nodes": [{"op_type": "Conv", "inputs": ["input", "w", "b"], "outputs": ["y"],
    "attrs": {"kernel_shape": [3, 3], "strides": [1, 1], "pads": [1, 1, 1, 1], "dilations": [1, 1]}}],
  "initializers": [
    {"name": "w", "shape": [2, 1, 3, 3], "dtype": "float32", "offset": 0, "nbytes": 72},
    {"name": "b", "shape": [2], "dtype": "float32", "offset": 72, "nbytes": 8}
  ],
  "external_data": null
}"#;

const ONNX_CHAIN_DOC: &str = r#"{
  "format": "cnn2gate-onnx-subset-v1",
  "name": "m2",
  "input": {"name": "input", "shape": [2, 4, 4], "dtype": "float32"},
  "output": {"name": "out"},
  "nodes": [
    {"op_type": "MaxPool", "inputs": ["input"], "outputs": ["p"],
     "attrs": {"kernel_shape": [2, 2], "strides": [2, 2], "pads": [0, 0, 0, 0]}},
    {"op_type": "Flatten", "inputs": ["p"], "outputs": ["f"], "attrs": {}},
    {"op_type": "Gemm", "inputs": ["f", "w", "b"], "outputs": ["g"], "attrs": {"transB": 1}},
    {"op_type": "Softmax", "inputs": ["g"], "outputs": ["out"], "attrs": {}}
  ],
  "initializers": [
    {"name": "w", "shape": [3, 8], "dtype": "float32", "offset": 0, "nbytes": 96},
    {"name": "b", "shape": [3], "dtype": "float32", "offset": 96, "nbytes": 12}
  ],
  "external_data": null
}"#;

/// The PR-10 attack surface: a residual Add join fed by a grouped +
/// dilated Conv on one branch and a 1x1 projection on the other, closed
/// by GlobalAveragePool — every parser arm the branch-aware IR added.
const ONNX_BRANCH_DOC: &str = r#"{
  "format": "cnn2gate-onnx-subset-v1",
  "name": "m3",
  "input": {"name": "input", "shape": [2, 4, 4], "dtype": "float32"},
  "output": {"name": "out"},
  "nodes": [
    {"op_type": "Conv", "inputs": ["input", "w1", "b1"], "outputs": ["t1"],
     "attrs": {"kernel_shape": [3, 3], "strides": [1, 1], "pads": [2, 2, 2, 2],
               "dilations": [2, 2], "group": 2}},
    {"op_type": "Conv", "inputs": ["input", "w2", "b2"], "outputs": ["t2"],
     "attrs": {"kernel_shape": [1, 1], "strides": [1, 1], "pads": [0, 0, 0, 0],
               "dilations": [1, 1]}},
    {"op_type": "Add", "inputs": ["t1", "t2"], "outputs": ["s"], "attrs": {}},
    {"op_type": "Relu", "inputs": ["s"], "outputs": ["r"], "attrs": {}},
    {"op_type": "GlobalAveragePool", "inputs": ["r"], "outputs": ["g"], "attrs": {}},
    {"op_type": "Flatten", "inputs": ["g"], "outputs": ["f"], "attrs": {}},
    {"op_type": "Gemm", "inputs": ["f", "w3", "b3"], "outputs": ["y"], "attrs": {"transB": 1}},
    {"op_type": "Softmax", "inputs": ["y"], "outputs": ["out"], "attrs": {}}
  ],
  "initializers": [
    {"name": "w1", "shape": [4, 1, 3, 3], "dtype": "float32", "offset": 0, "nbytes": 144},
    {"name": "b1", "shape": [4], "dtype": "float32", "offset": 144, "nbytes": 16},
    {"name": "w2", "shape": [4, 2, 1, 1], "dtype": "float32", "offset": 160, "nbytes": 32},
    {"name": "b2", "shape": [4], "dtype": "float32", "offset": 192, "nbytes": 16},
    {"name": "w3", "shape": [3, 4], "dtype": "float32", "offset": 208, "nbytes": 48},
    {"name": "b3", "shape": [3], "dtype": "float32", "offset": 256, "nbytes": 12}
  ],
  "external_data": null
}"#;

/// Fuzz [`Json::parse`]. Invariant: never panics; on accept, the tree
/// renders and reparses to an equal tree (exact when all numbers are
/// finite — NaN/Inf degrade to `null` by design).
pub fn fuzz_json(seed: u64, iters: u64) -> Result<FuzzOutcome, String> {
    let mut rng = Rng::new(seed ^ 0x6a73_6f6e);
    let mut out = FuzzOutcome {
        target: "util::json::Json::parse",
        inputs: 0,
        accepted: 0,
        rejected: 0,
    };
    for i in 0..iters {
        let input = match rng.below(7) {
            0 => String::from_utf8_lossy(&random_bytes(&mut rng, 200)).into_owned(),
            1 => soup_string(&mut rng, 200),
            2 => byte_mutate(&mut rng, ONNX_CONV_DOC),
            3 => nesting_bomb(&mut rng),
            4 => number_torture(&mut rng),
            5 => random_tree(&mut rng, 4).to_string_pretty(),
            _ => mutate_tree(&mut rng, &Json::parse(ONNX_CHAIN_DOC).unwrap()).to_string_pretty(),
        };
        out.inputs += 1;
        let parsed = shielded(|| Json::parse(&input))
            .map_err(|p| format!("json seed={seed} iter={i}: panicked: {p}\ninput: {input:?}"))?;
        match parsed {
            Err(_) => out.rejected += 1,
            Ok(doc) => {
                out.accepted += 1;
                let rendered = shielded(|| doc.to_string_pretty()).map_err(|p| {
                    format!("json seed={seed} iter={i}: render panicked: {p}\ninput: {input:?}")
                })?;
                match Json::parse(&rendered) {
                    Err(e) => {
                        return Err(format!(
                            "json seed={seed} iter={i}: accepted input re-rendered unparseable \
                             ({}): {rendered:?}",
                            e.message
                        ))
                    }
                    Ok(again) if tree_is_finite(&doc) && again != doc => {
                        return Err(format!(
                            "json seed={seed} iter={i}: roundtrip diverged\nfirst:  {doc:?}\n\
                             second: {again:?}"
                        ))
                    }
                    Ok(_) => {}
                }
            }
        }
    }
    Ok(out)
}

/// Fuzz [`onnx::parse_doc`] with mutated model documents and hostile
/// weight blobs. Invariant: never panics; malformed docs come back as
/// `Err`, not aborts — offsets/nbytes out of range must be caught.
pub fn fuzz_onnx(seed: u64, iters: u64) -> Result<FuzzOutcome, String> {
    let mut rng = Rng::new(seed ^ 0x6f6e_6e78);
    let conv = Json::parse(ONNX_CONV_DOC).map_err(|e| e.message)?;
    let chain = Json::parse(ONNX_CHAIN_DOC).map_err(|e| e.message)?;
    let branch = Json::parse(ONNX_BRANCH_DOC).map_err(|e| e.message)?;
    let mut out = FuzzOutcome {
        target: "onnx::parse_doc",
        inputs: 0,
        accepted: 0,
        rejected: 0,
    };
    for i in 0..iters {
        let base = match rng.below(3) {
            0 => &conv,
            1 => &chain,
            _ => &branch,
        };
        let doc = match rng.below(4) {
            0 | 1 => mutate_tree(&mut rng, base),
            2 => {
                // double mutation reaches deeper invalid shapes
                let once = mutate_tree(&mut rng, base);
                mutate_tree(&mut rng, &once)
            }
            _ => match Json::parse(&byte_mutate(&mut rng, ONNX_CONV_DOC)) {
                Ok(d) => d,
                Err(_) => base.clone(), // mutation broke the JSON layer; exercise the base
            },
        };
        let blob = match rng.below(3) {
            0 => None,
            1 => Some(random_bytes(&mut rng, 64)), // usually too small
            _ => Some(random_bytes(&mut rng, 256)),
        };
        out.inputs += 1;
        let parsed = shielded(|| parse_doc(&doc, blob.as_deref())).map_err(|p| {
            format!(
                "onnx seed={seed} iter={i}: panicked: {p}\ndoc: {}",
                doc.to_string_pretty()
            )
        })?;
        match parsed {
            Ok(_) => out.accepted += 1,
            Err(_) => out.rejected += 1,
        }
    }
    Ok(out)
}

/// Build a real populated cache document to mutate: two analytical
/// evaluations of the `tiny` zoo model.
fn cache_template() -> Result<Json, String> {
    let graph = zoo::build("tiny", false).ok_or("zoo model 'tiny' missing")?;
    let flow = ComputationFlow::extract(&graph).map_err(|e| format!("{e:?}"))?;
    let ev = Evaluator::new(2);
    ev.evaluate(&flow, &ARRIA_10_GX1150, 4, 4, EvalRequest::at(Fidelity::Analytical));
    ev.evaluate(&flow, &ARRIA_10_GX1150, 8, 4, EvalRequest::at(Fidelity::Analytical));
    Ok(ev.cache().to_json())
}

/// Fuzz [`EvalCache::from_json`]. Invariant: never panics; anything
/// that is not a well-formed cache document is rejected with `Err`.
pub fn fuzz_cache(seed: u64, iters: u64) -> Result<FuzzOutcome, String> {
    let mut rng = Rng::new(seed ^ 0x6361_6368);
    let template = cache_template()?;
    let rendered = template.to_string_pretty();
    let mut out = FuzzOutcome {
        target: "dse::EvalCache::from_json",
        inputs: 0,
        accepted: 0,
        rejected: 0,
    };
    for i in 0..iters {
        let doc = match rng.below(5) {
            0 | 1 => mutate_tree(&mut rng, &template),
            2 => {
                let once = mutate_tree(&mut rng, &template);
                mutate_tree(&mut rng, &once)
            }
            3 => match Json::parse(&byte_mutate(&mut rng, &rendered)) {
                Ok(d) => d,
                Err(_) => template.clone(),
            },
            _ => random_tree(&mut rng, 3),
        };
        out.inputs += 1;
        let parsed = shielded(|| EvalCache::from_json(&doc)).map_err(|p| {
            format!(
                "cache seed={seed} iter={i}: panicked: {p}\ndoc: {}",
                doc.to_string_pretty()
            )
        })?;
        match parsed {
            Ok(_) => out.accepted += 1,
            Err(_) => out.rejected += 1,
        }
    }
    Ok(out)
}

/// On-disk texts of a small valid store (manifest + one shard's base
/// and delta log), captured once and re-written per fuzz iteration.
struct StoreTemplate {
    manifest: String,
    base_name: String,
    base: String,
    delta_name: String,
    delta: String,
}

/// A scratch directory unique per call — parallel harnesses (e.g. two
/// unit tests in one process) must never share a store directory.
fn store_scratch_dir(tag: &str) -> std::path::PathBuf {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let n = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir().join(format!("cnn2gate-fuzz-store-{tag}-{}-{n}", std::process::id()))
}

/// Build the template store: two generations of tiny-model analytical
/// entries, so the directory holds a manifest, a base AND a live delta.
fn store_template() -> Result<StoreTemplate, String> {
    let dir = store_scratch_dir("template");
    let _ = std::fs::remove_dir_all(&dir);
    let graph = zoo::build("tiny", false).ok_or("zoo model 'tiny' missing")?;
    let flow = ComputationFlow::extract(&graph).map_err(|e| format!("{e:?}"))?;
    let first = CacheStore::open(&dir);
    first
        .cache
        .get_or_compute(&flow, &ARRIA_10_GX1150, 4, 4, EvalRequest::at(Fidelity::Analytical));
    first
        .cache
        .get_or_compute(&flow, &ARRIA_10_GX1150, 8, 4, EvalRequest::at(Fidelity::Analytical));
    first.store.save(&first.cache).map_err(|e| format!("{e:#}"))?;
    let second = CacheStore::open(&dir);
    second
        .cache
        .get_or_compute(&flow, &ARRIA_10_GX1150, 8, 8, EvalRequest::at(Fidelity::Analytical));
    second.store.save(&second.cache).map_err(|e| format!("{e:#}"))?;

    let mut base = None;
    let mut delta = None;
    for entry in std::fs::read_dir(&dir).map_err(|e| e.to_string())? {
        let path = entry.map_err(|e| e.to_string())?.path();
        let name = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
        let text = std::fs::read_to_string(&path).map_err(|e| e.to_string())?;
        if name.ends_with(".delta.jsonl") {
            delta = Some((name, text));
        } else if name.ends_with(".jsonl") {
            base = Some((name, text));
        }
    }
    let manifest = std::fs::read_to_string(dir.join("store.json")).map_err(|e| e.to_string())?;
    std::fs::remove_dir_all(&dir).ok();
    let (base_name, base) = base.ok_or("template store grew no shard base")?;
    let (delta_name, delta) = delta.ok_or("template store grew no delta log")?;
    Ok(StoreTemplate {
        manifest,
        base_name,
        base,
        delta_name,
        delta,
    })
}

/// Hostile mutation of one line-oriented store file: byte noise, a
/// torn tail (mid-line truncation), line drop/duplicate/swap, or a
/// structural mutation of one line's JSON record.
fn hostile_store_text(rng: &mut Rng, text: &str) -> String {
    match rng.below(7) {
        0 => byte_mutate(rng, text),
        1 => soup_string(rng, 200),
        2 => {
            // torn tail: cut mid-way into the final record (byte-level,
            // so multi-byte codepoints can't panic the generator)
            let cut = text.len().saturating_sub(1 + rng.below(40) as usize);
            String::from_utf8_lossy(&text.as_bytes()[..cut]).into_owned()
        }
        kind => {
            let mut lines: Vec<String> = text.lines().map(str::to_string).collect();
            if lines.is_empty() {
                return soup_string(rng, 80);
            }
            let at = rng.below(lines.len() as u64) as usize;
            match kind {
                3 => {
                    lines.remove(at);
                }
                4 => lines.insert(at, lines[at].clone()), // duplicate record
                5 => {
                    let other = rng.below(lines.len() as u64) as usize;
                    lines.swap(at, other); // break the sorted-key order
                }
                _ => {
                    lines[at] = match Json::parse(&lines[at]) {
                        Ok(doc) => mutate_tree(rng, &doc).to_string(),
                        Err(_) => soup_string(rng, 80),
                    };
                }
            }
            let mut out = lines.join("\n");
            out.push('\n');
            out
        }
    }
}

/// Fuzz [`CacheStore::open`] with hostile store directories. Invariant:
/// the strict loader never panics — it loads cleanly or degrades (cold
/// or partial) with a warning — and a subsequent `save` + `compact_all`
/// always heals the directory into one that reopens warning-free.
pub fn fuzz_store(seed: u64, iters: u64) -> Result<FuzzOutcome, String> {
    let mut rng = Rng::new(seed ^ 0x7374_6f72);
    let t = store_template()?;
    let dir = store_scratch_dir(&format!("run-{seed:x}"));
    let mut out = FuzzOutcome {
        target: "dse::CacheStore::open",
        inputs: 0,
        accepted: 0,
        rejected: 0,
    };
    for i in 0..iters {
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).map_err(|e| format!("store scratch dir: {e}"))?;
        let victim = rng.below(7);
        let render = |rng: &mut Rng, hit: bool, text: &str| {
            if hit {
                hostile_store_text(rng, text)
            } else {
                text.to_string()
            }
        };
        let manifest = render(&mut rng, matches!(victim, 0 | 1), &t.manifest);
        let base = render(&mut rng, matches!(victim, 2 | 3), &t.base);
        let delta = render(&mut rng, matches!(victim, 4 | 5), &t.delta);
        // victim == 6 leaves everything intact: the accept path
        for (name, text) in [
            ("store.json", &manifest),
            (t.base_name.as_str(), &base),
            (t.delta_name.as_str(), &delta),
        ] {
            std::fs::write(dir.join(name), text).map_err(|e| format!("store scratch: {e}"))?;
        }
        out.inputs += 1;
        let opened = shielded(|| CacheStore::open(&dir))
            .map_err(|p| format!("store seed={seed} iter={i} victim={victim}: panicked: {p}"))?;
        if opened.warnings.is_empty() {
            out.accepted += 1;
        } else {
            out.rejected += 1;
        }
        // heal invariant (sampled — it costs a full save + compaction):
        // whatever survived the strict load persists into a directory
        // that reopens with no warnings at all
        if rng.below(16) == 0 {
            shielded(|| opened.store.save(&opened.cache))
                .map_err(|p| format!("store seed={seed} iter={i}: save panicked: {p}"))?
                .map_err(|e| format!("store seed={seed} iter={i}: save after load failed: {e:#}"))?;
            shielded(|| opened.store.compact_all())
                .map_err(|p| format!("store seed={seed} iter={i}: compact panicked: {p}"))?
                .map_err(|e| format!("store seed={seed} iter={i}: compact failed: {e:#}"))?;
            let healed = shielded(|| CacheStore::open(&dir))
                .map_err(|p| format!("store seed={seed} iter={i}: reopen panicked: {p}"))?;
            if !healed.warnings.is_empty() {
                return Err(format!(
                    "store seed={seed} iter={i} victim={victim}: save+compact did not heal: {:?}",
                    healed.warnings
                ));
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
    Ok(out)
}

/// Run all four harnesses at `scale`× the fast-tier budget (scale 1 =
/// 15 000 inputs total, past the 10k acceptance gate). Returns per-
/// target outcomes or the first failure with its replay coordinates.
pub fn run(seed: u64, scale: u64) -> Result<Vec<FuzzOutcome>, String> {
    hushed(|| {
        Ok(vec![
            fuzz_json(seed, 6_000 * scale)?,
            fuzz_onnx(seed, 3_000 * scale)?,
            fuzz_cache(seed, 3_000 * scale)?,
            fuzz_store(seed, 3_000 * scale)?,
        ])
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_harness_accepts_and_rejects() {
        let out = hushed(|| fuzz_json(7, 1_500)).expect("no panics");
        assert_eq!(out.inputs, 1_500);
        assert!(out.accepted > 0, "valid-tree strategy must accept");
        assert!(out.rejected > 0, "byte noise must reject");
    }

    #[test]
    fn onnx_harness_accepts_and_rejects() {
        let out = hushed(|| fuzz_onnx(7, 600)).expect("no panics");
        assert_eq!(out.inputs, 600);
        assert!(out.rejected > 0, "mutations must produce invalid docs");
    }

    #[test]
    fn cache_harness_accepts_and_rejects() {
        let out = hushed(|| fuzz_cache(7, 600)).expect("no panics");
        assert_eq!(out.inputs, 600);
        assert!(out.rejected > 0, "mutations must produce invalid docs");
    }

    #[test]
    fn store_harness_accepts_and_rejects() {
        let out = hushed(|| fuzz_store(7, 300)).expect("no panics");
        assert_eq!(out.inputs, 300);
        assert!(out.accepted > 0, "the intact-directory path must accept");
        assert!(out.rejected > 0, "hostile manifests/shards must reject");
    }

    #[test]
    fn store_template_is_itself_valid() {
        let t = store_template().unwrap();
        assert!(t.manifest.contains("cnn2gate-store"));
        assert!(t.base.lines().count() >= 3, "header + 2 entries");
        assert!(!t.delta.is_empty() && t.delta.ends_with('\n'));
        assert_eq!(t.base_name.replace(".jsonl", ".delta.jsonl"), t.delta_name);
    }

    #[test]
    fn branch_template_is_itself_valid() {
        let doc = Json::parse(ONNX_BRANCH_DOC).unwrap();
        let g = parse_doc(&doc, None).expect("unmutated branched template must parse");
        assert_eq!(
            g.op_names(),
            vec!["Conv", "Conv", "Add", "Relu", "GlobalAveragePool", "Flatten", "Gemm", "Softmax"]
        );
    }

    #[test]
    fn cache_template_is_itself_valid() {
        let doc = cache_template().unwrap();
        let cache = EvalCache::from_json(&doc).expect("unmutated template must load");
        assert!(cache.to_json().to_string_pretty().len() > 2);
    }

    #[test]
    fn determinism_same_seed_same_outcome() {
        let a = hushed(|| fuzz_json(42, 300)).unwrap();
        let b = hushed(|| fuzz_json(42, 300)).unwrap();
        assert_eq!((a.accepted, a.rejected), (b.accepted, b.rejected));
    }
}
