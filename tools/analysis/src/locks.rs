//! Static lock-order checking against `tools/analysis/lock_order.toml`.
//!
//! The pass extracts every Mutex acquisition — `receiver.lock()` or the
//! house poison-recovering form `locked(&receiver)` — from the scoped
//! files, resolves each receiver to a declared lock via the manifest's
//! alias table, and infers which acquisitions are *nested* (taken while
//! another guard is live). Violations:
//!
//! * an acquisition whose receiver resolves to no declared lock;
//! * a nested pair absent from the manifest's `nestings` list;
//! * a nested pair that inverts the manifest's total `order`;
//! * a lock nested inside itself (guaranteed self-deadlock with
//!   `std::sync::Mutex`);
//! * any cycle in the union of declared and observed nestings.
//!
//! Guard liveness is inferred conservatively from the token stream:
//! a `let`-bound acquisition whose trailing call chain is only
//! `.unwrap()` / `.expect(…)` / `?` holds its guard to the end of the
//! enclosing block; a statement head that ends in `{` (`if let` /
//! `while let` / `match` scrutinees) holds any guard it takes for that
//! block, matching Rust's temporary-lifetime extension; everything
//! else is a statement-scoped temporary. Over-approximation is fine —
//! it can only surface a nesting for review, never hide one.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use anyhow::{Context, Result};

use crate::scan::strip;
use crate::Finding;

/// Files the checker covers, relative to `rust/src`.
pub const SCOPED_FILES: &[&str] = &[
    "coordinator/scheduler.rs",
    "coordinator/service/orchestrator.rs",
    "dse/eval.rs",
    "dse/store.rs",
];

/// One declared lock: a canonical name plus the receiver spellings that
/// refer to it in source.
#[derive(Debug, Clone)]
pub struct LockDecl {
    pub name: String,
    pub aliases: Vec<String>,
}

/// The parsed `lock_order.toml`.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub locks: Vec<LockDecl>,
    pub order: Vec<String>,
    pub nestings: Vec<(String, String)>,
}

impl Manifest {
    /// Canonical lock name for a normalized receiver, if declared.
    pub fn resolve(&self, receiver: &str) -> Option<&str> {
        self.locks
            .iter()
            .find(|l| l.name == receiver || l.aliases.iter().any(|a| a == receiver))
            .map(|l| l.name.as_str())
    }

    fn order_index(&self, name: &str) -> Option<usize> {
        self.order.iter().position(|n| n == name)
    }
}

/// Hand-rolled parser for the small TOML subset the manifest uses:
/// `[[lock]]` tables with `name`/`aliases`, and top-level `order` /
/// `nestings` single-line string arrays. No dependency needed.
pub fn parse_manifest(text: &str) -> Result<Manifest, String> {
    let mut m = Manifest::default();
    let mut in_lock = false;
    for (no, raw) in text.lines().enumerate() {
        let line = strip_toml_comment(raw);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let err = |what: &str| format!("lock_order.toml:{}: {what}", no + 1);
        if line == "[[lock]]" {
            m.locks.push(LockDecl {
                name: String::new(),
                aliases: Vec::new(),
            });
            in_lock = true;
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| err("expected `key = value`"))?;
        let (key, value) = (key.trim(), value.trim());
        match key {
            "name" if in_lock => {
                let name = parse_toml_string(value).ok_or_else(|| err("bad string"))?;
                m.locks.last_mut().expect("inside [[lock]]").name = name;
            }
            "aliases" if in_lock => {
                let list = parse_toml_list(value).ok_or_else(|| err("bad string array"))?;
                m.locks.last_mut().expect("inside [[lock]]").aliases = list;
            }
            "order" => {
                in_lock = false;
                m.order = parse_toml_list(value).ok_or_else(|| err("bad string array"))?;
            }
            "nestings" => {
                in_lock = false;
                for item in parse_toml_list(value).ok_or_else(|| err("bad string array"))? {
                    let (a, b) = item
                        .split_once("->")
                        .ok_or_else(|| err("nesting entries are \"outer -> inner\""))?;
                    m.nestings
                        .push((a.trim().to_string(), b.trim().to_string()));
                }
            }
            other => return Err(err(&format!("unknown key '{other}'"))),
        }
    }
    // self-consistency
    let mut seen = BTreeSet::new();
    for l in &m.locks {
        if l.name.is_empty() {
            return Err("lock_order.toml: a [[lock]] is missing its name".into());
        }
        if !seen.insert(l.name.clone()) {
            return Err(format!("lock_order.toml: duplicate lock '{}'", l.name));
        }
    }
    for l in &m.locks {
        if m.order_index(&l.name).is_none() {
            return Err(format!(
                "lock_order.toml: lock '{}' missing from `order`",
                l.name
            ));
        }
    }
    for name in &m.order {
        if !seen.contains(name) {
            return Err(format!("lock_order.toml: `order` names unknown lock '{name}'"));
        }
    }
    for (a, b) in &m.nestings {
        if !seen.contains(a) || !seen.contains(b) {
            return Err(format!(
                "lock_order.toml: nesting '{a} -> {b}' names an undeclared lock"
            ));
        }
    }
    Ok(m)
}

fn strip_toml_comment(line: &str) -> String {
    let mut out = String::new();
    let mut in_str = false;
    for c in line.chars() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => break,
            _ => {}
        }
        out.push(c);
    }
    out
}

fn parse_toml_string(v: &str) -> Option<String> {
    let v = v.trim();
    let inner = v.strip_prefix('"')?.strip_suffix('"')?;
    Some(inner.to_string())
}

fn parse_toml_list(v: &str) -> Option<Vec<String>> {
    let inner = v.trim().strip_prefix('[')?.strip_suffix(']')?.trim();
    if inner.is_empty() {
        return Some(Vec::new());
    }
    inner
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty()) // tolerate a trailing comma
        .map(parse_toml_string)
        .collect()
}

/// One Mutex acquisition site in a scanned file.
#[derive(Debug, Clone)]
pub struct Acquisition {
    /// Char offset of the site (ordering within a statement).
    pub pos: usize,
    /// Char offset just past the call (start of any trailing chain).
    pub end: usize,
    /// 1-indexed source line.
    pub line: usize,
    /// Normalized receiver (`&`, `mut`, index/call arguments stripped).
    pub receiver: String,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Strip `&` / `mut ` and drop bracketed segments: `queues[seed(i) % w]`
/// → `queues`, `&self.map` → `self.map`.
fn normalize_receiver(raw: &str) -> String {
    let mut s = raw.trim();
    while let Some(rest) = s.strip_prefix('&') {
        s = rest.trim_start();
    }
    if let Some(rest) = s.strip_prefix("mut ") {
        s = rest.trim_start();
    }
    let mut out = String::new();
    let mut depth = 0usize;
    for c in s.chars() {
        match c {
            '[' | '(' => depth += 1,
            ']' | ')' => depth = depth.saturating_sub(1),
            _ if depth == 0 => out.push(c),
            _ => {}
        }
    }
    out.trim_matches(|c: char| c == '.' || c.is_whitespace())
        .to_string()
}

/// Walk back from `end` (exclusive) over an expression tail: identifier
/// chars, `.`, and balanced `[...]` / `(...)` groups. Returns the start.
fn expr_start(t: &[char], end: usize) -> usize {
    let mut k = end;
    while k > 0 {
        let c = t[k - 1];
        if is_ident(c) || c == '.' {
            k -= 1;
        } else if c == ']' || c == ')' {
            let open = if c == ']' { '[' } else { '(' };
            let mut depth = 1usize;
            let mut j = k - 1;
            while j > 0 && depth > 0 {
                j -= 1;
                if t[j] == c {
                    depth += 1;
                } else if t[j] == open {
                    depth -= 1;
                }
            }
            if depth != 0 {
                break;
            }
            k = j;
        } else {
            break;
        }
    }
    k
}

fn matching_close(t: &[char], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (i, &c) in t.iter().enumerate().skip(open) {
        if c == '(' {
            depth += 1;
        } else if c == ')' {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
    }
    None
}

/// Find every acquisition in stripped code (flattened to chars).
pub fn find_acquisitions(t: &[char], line_of: &[usize]) -> Vec<Acquisition> {
    let mut out = Vec::new();
    let dot_lock: Vec<char> = ".lock()".chars().collect();
    let locked: Vec<char> = "locked(".chars().collect();
    let mut i = 0usize;
    while i < t.len() {
        if i + dot_lock.len() <= t.len() && t[i..i + dot_lock.len()] == dot_lock[..] {
            let start = expr_start(t, i);
            let raw: String = t[start..i].iter().collect();
            let receiver = normalize_receiver(&raw);
            if !receiver.is_empty() {
                out.push(Acquisition {
                    pos: i,
                    end: i + dot_lock.len(),
                    line: line_of[i] + 1,
                    receiver,
                });
            }
            i += dot_lock.len();
        } else if i + locked.len() <= t.len()
            && t[i..i + locked.len()] == locked[..]
            && (i == 0 || (!is_ident(t[i - 1]) && t[i - 1] != '.'))
        {
            let open = i + locked.len() - 1;
            if let Some(close) = matching_close(t, open) {
                let raw: String = t[open + 1..close].iter().collect();
                let receiver = normalize_receiver(&raw);
                if !receiver.is_empty() {
                    out.push(Acquisition {
                        pos: i,
                        end: close + 1,
                        line: line_of[i] + 1,
                        receiver,
                    });
                }
                i = open + 1; // keep scanning inside the argument too
            } else {
                i += locked.len();
            }
        } else {
            i += 1;
        }
    }
    out.sort_by_key(|a| a.pos);
    out
}

/// A nesting observed in source: `inner` acquired at `line` while
/// `outer` was held.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct NestedPair {
    pub outer: String,
    pub inner: String,
    pub file: String,
    pub line: usize,
}

/// True when the chars after an acquisition, up to the statement end,
/// are only `.unwrap()` / `.expect(…)` / `?` chains (guard survives the
/// statement).
fn chain_is_guard_clean(t: &[char], mut i: usize, end: usize) -> bool {
    loop {
        while i < end && t[i].is_whitespace() {
            i += 1;
        }
        if i >= end || t[i] == ';' {
            return true;
        }
        if t[i] == '?' {
            i += 1;
            continue;
        }
        if starts_with_at(t, i, ".unwrap()") {
            i += ".unwrap()".len();
            continue;
        }
        if starts_with_at(t, i, ".expect(") {
            match matching_close(t, i + ".expect(".len() - 1) {
                Some(close) if close < end => i = close + 1,
                _ => return false,
            }
            continue;
        }
        return false;
    }
}

fn starts_with_at(t: &[char], i: usize, pat: &str) -> bool {
    let p: Vec<char> = pat.chars().collect();
    i + p.len() <= t.len() && t[i..i + p.len()] == p[..]
}

/// Extract observed nestings (and recursive acquisitions, as findings)
/// from one file. Returns (nested pairs, findings for unresolvable or
/// recursive sites).
pub fn analyze_file(
    rel: &str,
    text: &str,
    manifest: &Manifest,
) -> (Vec<NestedPair>, Vec<Finding>) {
    let stripped = strip(text);
    let code = stripped.code.join("\n");
    let t: Vec<char> = code.chars().collect();
    let mut line_of = Vec::with_capacity(t.len());
    let mut ln = 0usize;
    for &c in &t {
        line_of.push(ln);
        if c == '\n' {
            ln += 1;
        }
    }
    let acqs = find_acquisitions(&t, &line_of);

    let mut findings = Vec::new();
    // resolve every receiver first; unknown sites are findings and drop
    // out of nesting inference
    let resolved: Vec<Option<String>> = acqs
        .iter()
        .map(|a| match manifest.resolve(&a.receiver) {
            Some(name) => Some(name.to_string()),
            None => {
                findings.push(Finding::new(
                    rel,
                    a.line,
                    "lock-order",
                    format!(
                        "acquisition of undeclared lock '{}' (add it to tools/analysis/lock_order.toml)",
                        a.receiver
                    ),
                ));
                None
            }
        })
        .collect();

    // statement segmentation with brace scoping; a held guard is
    // (lock name, brace depth it dies below)
    let mut pairs = Vec::new();
    let mut held: Vec<(String, usize)> = Vec::new();
    let mut brace_depth = 0usize;
    let mut stmt_start = 0usize;
    let mut ai = 0usize; // next acquisition index ≥ stmt_start
    let process = |start: usize,
                       end: usize,
                       opens_block: bool,
                       depth: usize,
                       ai: &mut usize,
                       held: &mut Vec<(String, usize)>,
                       pairs: &mut Vec<NestedPair>,
                       findings: &mut Vec<Finding>| {
        let mut in_stmt: Vec<usize> = Vec::new();
        while *ai < acqs.len() && acqs[*ai].pos < end {
            if acqs[*ai].pos >= start {
                in_stmt.push(*ai);
            }
            *ai += 1;
        }
        if in_stmt.is_empty() {
            return;
        }
        let stmt: String = t[start..end].iter().collect();
        let has_let = stmt_has_let(&stmt, acqs[in_stmt[0]].pos - start);
        for (k, &idx) in in_stmt.iter().enumerate() {
            let Some(name) = &resolved[idx] else { continue };
            let a = &acqs[idx];
            // against live block-scoped guards
            for (outer, _) in held.iter() {
                push_pair(outer, name, rel, a.line, pairs, findings);
            }
            // against earlier acquisitions in the same statement (their
            // temporaries live to the statement end)
            for &prev in &in_stmt[..k] {
                if let Some(outer) = &resolved[prev] {
                    push_pair(outer, name, rel, a.line, pairs, findings);
                }
            }
        }
        // register guards that outlive the statement
        for &idx in &in_stmt {
            let Some(name) = &resolved[idx] else { continue };
            let a = &acqs[idx];
            if opens_block {
                // if/while-let or match head: temporaries extend over
                // the block that follows
                held.push((name.clone(), depth + 1));
            } else if has_let && chain_is_guard_clean(&t, a.end, end) {
                held.push((name.clone(), depth));
            }
        }
    };
    let mut i = 0usize;
    while i < t.len() {
        match t[i] {
            '{' => {
                process(
                    stmt_start,
                    i,
                    true,
                    brace_depth,
                    &mut ai,
                    &mut held,
                    &mut pairs,
                    &mut findings,
                );
                brace_depth += 1;
                stmt_start = i + 1;
            }
            '}' => {
                process(
                    stmt_start,
                    i,
                    false,
                    brace_depth,
                    &mut ai,
                    &mut held,
                    &mut pairs,
                    &mut findings,
                );
                brace_depth = brace_depth.saturating_sub(1);
                held.retain(|(_, scope)| *scope <= brace_depth);
                stmt_start = i + 1;
            }
            ';' => {
                process(
                    stmt_start,
                    i + 1,
                    false,
                    brace_depth,
                    &mut ai,
                    &mut held,
                    &mut pairs,
                    &mut findings,
                );
                stmt_start = i + 1;
            }
            _ => {}
        }
        i += 1;
    }
    process(
        stmt_start,
        t.len(),
        false,
        brace_depth,
        &mut ai,
        &mut held,
        &mut pairs,
        &mut findings,
    );
    (pairs, findings)
}

fn stmt_has_let(stmt: &str, before: usize) -> bool {
    let chars: Vec<char> = stmt.chars().collect();
    let limit = before.min(chars.len());
    let p: Vec<char> = "let ".chars().collect();
    (0..limit.saturating_sub(p.len() - 1)).any(|i| {
        chars[i..i + p.len()] == p[..] && (i == 0 || !is_ident(chars[i - 1]))
    })
}

fn push_pair(
    outer: &str,
    inner: &str,
    rel: &str,
    line: usize,
    pairs: &mut Vec<NestedPair>,
    findings: &mut Vec<Finding>,
) {
    if outer == inner {
        findings.push(Finding::new(
            rel,
            line,
            "lock-order",
            format!("'{inner}' acquired while already held (std::sync::Mutex self-deadlock)"),
        ));
    } else {
        pairs.push(NestedPair {
            outer: outer.to_string(),
            inner: inner.to_string(),
            file: rel.to_string(),
            line,
        });
    }
}

/// Check a set of already-loaded sources against a manifest.
pub fn check_sources(manifest: &Manifest, sources: &[(&str, &str)]) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut observed: Vec<NestedPair> = Vec::new();
    for (rel, text) in sources {
        let (pairs, mut f) = analyze_file(rel, text, manifest);
        findings.append(&mut f);
        observed.extend(pairs);
    }
    observed.sort();
    observed.dedup();
    for p in &observed {
        let declared = manifest
            .nestings
            .iter()
            .any(|(a, b)| *a == p.outer && *b == p.inner);
        if !declared {
            findings.push(Finding::new(
                &p.file,
                p.line,
                "lock-order",
                format!(
                    "undeclared nesting: '{}' acquired while holding '{}' \
                     (declare it in lock_order.toml nestings)",
                    p.inner, p.outer
                ),
            ));
        }
        if let (Some(oi), Some(ii)) = (
            manifest.order_index(&p.outer),
            manifest.order_index(&p.inner),
        ) {
            if oi >= ii {
                findings.push(Finding::new(
                    &p.file,
                    p.line,
                    "lock-order",
                    format!(
                        "nesting '{}' -> '{}' inverts the declared total order",
                        p.outer, p.inner
                    ),
                ));
            }
        }
    }
    // cycle check over declared ∪ observed edges
    let mut edges: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for (a, b) in &manifest.nestings {
        edges.entry(a).or_default().insert(b);
    }
    for p in &observed {
        edges.entry(&p.outer).or_default().insert(&p.inner);
    }
    if let Some(cycle) = find_cycle(&edges) {
        findings.push(Finding::new(
            "lock_order.toml",
            0,
            "lock-order",
            format!("nesting graph has a cycle: {}", cycle.join(" -> ")),
        ));
    }
    findings
}

fn find_cycle<'a>(edges: &BTreeMap<&'a str, BTreeSet<&'a str>>) -> Option<Vec<String>> {
    #[derive(Clone, Copy, PartialEq)]
    enum Mark {
        White,
        Grey,
        Black,
    }
    fn visit<'a>(
        n: &'a str,
        edges: &BTreeMap<&'a str, BTreeSet<&'a str>>,
        marks: &mut BTreeMap<&'a str, Mark>,
        path: &mut Vec<&'a str>,
    ) -> bool {
        match marks.get(n).copied().unwrap_or(Mark::White) {
            Mark::Black => return false,
            Mark::Grey => {
                path.push(n);
                return true;
            }
            Mark::White => {}
        }
        marks.insert(n, Mark::Grey);
        path.push(n);
        if let Some(next) = edges.get(n) {
            for m in next {
                if visit(m, edges, marks, path) {
                    return true;
                }
            }
        }
        marks.insert(n, Mark::Black);
        path.pop();
        false
    }
    let mut marks = BTreeMap::new();
    for &n in edges.keys() {
        let mut path = Vec::new();
        if visit(n, edges, &mut marks, &mut path) {
            return Some(path.iter().map(|s| s.to_string()).collect());
        }
    }
    None
}

/// Run the pass against the real tree: load the manifest and the scoped
/// files under `repo_root`.
pub fn run(repo_root: &Path) -> Result<Vec<Finding>> {
    let manifest_path = repo_root.join("tools/analysis/lock_order.toml");
    let text = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {}", manifest_path.display()))?;
    let manifest = parse_manifest(&text).map_err(anyhow::Error::msg)?;
    let mut loaded = Vec::new();
    for rel in SCOPED_FILES {
        let path = repo_root.join("rust/src").join(rel);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        loaded.push((*rel, text));
    }
    let sources: Vec<(&str, &str)> = loaded.iter().map(|(r, t)| (*r, t.as_str())).collect();
    Ok(check_sources(&manifest, &sources))
}

/// A synthetic source nesting two declared locks without a declaration
/// — used by `analysis --seed lock-order` and the self-tests.
pub const SEEDED_VIOLATION: (&str, &str) = (
    "seeded/lock_order.rs",
    "pub fn seeded(queues: &[std::sync::Mutex<Vec<u8>>]) {\n    \
     let held = locked(&queues[0]);\n    \
     let inner = locked(&queues[1]).len();\n    \
     drop(held);\n    \
     let _ = inner;\n}\n",
);

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        parse_manifest(
            r#"
[[lock]]
name = "a"
aliases = ["alpha", "self.alpha"]

[[lock]]
name = "b"
aliases = ["beta"]

order = ["a", "b"]
nestings = ["a -> b"]
"#,
        )
        .unwrap()
    }

    #[test]
    fn manifest_parses_and_resolves_aliases() {
        let m = manifest();
        assert_eq!(m.resolve("alpha"), Some("a"));
        assert_eq!(m.resolve("self.alpha"), Some("a"));
        assert_eq!(m.resolve("a"), Some("a"));
        assert_eq!(m.resolve("gamma"), None);
        assert_eq!(m.nestings, vec![("a".to_string(), "b".to_string())]);
    }

    #[test]
    fn manifest_rejects_inconsistency() {
        assert!(parse_manifest("[[lock]]\nname = \"a\"\norder = []\n").is_err());
        assert!(parse_manifest("order = [\"ghost\"]\n").is_err());
        assert!(parse_manifest("nestings = [\"x -> y\"]\n").is_err());
    }

    #[test]
    fn declared_nesting_in_order_passes() {
        let src = "fn f() {\n    let g = locked(&alpha);\n    let n = locked(&beta).len();\n    drop(g);\n}\n";
        let findings = check_sources(&manifest(), &[("m.rs", src)]);
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn inverted_nesting_is_flagged() {
        let src = "fn f() {\n    let g = locked(&beta);\n    let n = locked(&alpha).len();\n    drop(g);\n}\n";
        let findings = check_sources(&manifest(), &[("m.rs", src)]);
        assert!(
            findings
                .iter()
                .any(|f| f.message.contains("undeclared nesting")),
            "{findings:?}"
        );
        assert!(
            findings.iter().any(|f| f.message.contains("cycle")),
            "b -> a plus declared a -> b must close a cycle: {findings:?}"
        );
    }

    #[test]
    fn recursive_acquisition_is_flagged() {
        let src = "fn f() {\n    let g = locked(&alpha);\n    let h = self.alpha.lock().unwrap();\n}\n";
        let findings = check_sources(&manifest(), &[("m.rs", src)]);
        assert!(
            findings.iter().any(|f| f.message.contains("self-deadlock")),
            "{findings:?}"
        );
    }

    #[test]
    fn unknown_receiver_is_flagged() {
        let src = "fn f() {\n    let g = mystery.lock().unwrap();\n}\n";
        let findings = check_sources(&manifest(), &[("m.rs", src)]);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert!(findings[0].message.contains("undeclared lock 'mystery'"));
    }

    #[test]
    fn statement_temporaries_do_not_leak_guards() {
        // back-to-back temporary acquisitions never nest
        let src =
            "fn f() {\n    let x = locked(&alpha).pop();\n    let y = locked(&beta).pop();\n}\n";
        let findings = check_sources(&manifest(), &[("m.rs", src)]);
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn same_statement_nesting_is_observed() {
        // beta taken while alpha's temporary is still live (same stmt):
        // declared a -> b, so clean...
        let ok = "fn f() {\n    let x = locked(&alpha).merge(locked(&beta).take());\n}\n";
        assert!(check_sources(&manifest(), &[("m.rs", ok)]).is_empty());
        // ... but the inverse direction is a violation
        let bad = "fn f() {\n    let x = locked(&beta).merge(locked(&alpha).take());\n}\n";
        assert!(!check_sources(&manifest(), &[("m.rs", bad)]).is_empty());
    }

    #[test]
    fn if_let_heads_hold_their_guard_over_the_block() {
        let src = "fn f() {\n    if let Ok(g) = beta.lock() {\n        let n = locked(&alpha).len();\n    }\n}\n";
        let findings = check_sources(&manifest(), &[("m.rs", src)]);
        assert!(
            findings
                .iter()
                .any(|f| f.message.contains("undeclared nesting")),
            "{findings:?}"
        );
    }

    #[test]
    fn brace_scope_releases_guards() {
        let src = "fn f() {\n    {\n        let g = locked(&beta);\n    }\n    let n = locked(&alpha).len();\n}\n";
        let findings = check_sources(&manifest(), &[("m.rs", src)]);
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn seeded_violation_fails_against_the_real_manifest() {
        let text = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/lock_order.toml"
        ))
        .unwrap();
        let m = parse_manifest(&text).unwrap();
        let (rel, src) = SEEDED_VIOLATION;
        let findings = check_sources(&m, &[(rel, src)]);
        assert!(
            findings.iter().any(|f| f.message.contains("self-deadlock")),
            "{findings:?}"
        );
    }
}
