//! Fuzz tiers. The fast tier runs the full acceptance budget (15 000
//! hostile inputs across the four targets) on every `cargo test -p
//! analysis`; the long tier multiplies it 10× and is `#[ignore]`d —
//! run it with `cargo test -p analysis -- --ignored fuzz_long`.

#[test]
fn fuzz_fast_tier_15k_inputs_no_panics() {
    let outcomes = analysis::fuzz::run(0xF00D, 1).expect("fuzz failure");
    let total: u64 = outcomes.iter().map(|o| o.inputs).sum();
    assert!(total >= 10_000, "acceptance gate: >=10k inputs, got {total}");
    assert_eq!(outcomes.len(), 4, "json, onnx, cache AND store targets");
    for o in &outcomes {
        assert!(
            o.rejected > 0,
            "{}: hostile inputs must exercise the rejection path",
            o.target
        );
        assert_eq!(o.inputs, o.accepted + o.rejected, "{}: every input classified", o.target);
    }
}

#[test]
#[ignore = "10x budget; run with --ignored"]
fn fuzz_long_tier_150k_inputs_no_panics() {
    let outcomes = analysis::fuzz::run(0xF00D_F00D, 10).expect("fuzz failure");
    let total: u64 = outcomes.iter().map(|o| o.inputs).sum();
    assert_eq!(total, 150_000);
}
