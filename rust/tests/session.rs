//! Session-engine integration tests: the deprecated free-function shims
//! must stay bit-identical to [`cnn2gate::session::Session::run`] (cold
//! AND cache-warm), outcomes must be scheduling-independent, and the
//! `--json` document must be stable, round-trip-parseable and match the
//! committed golden schema.
#![allow(deprecated)] // the shims are one side of every identity check

use std::collections::BTreeSet;
use std::path::Path;
use std::sync::Arc;

use cnn2gate::coordinator::pipeline::{self, FleetReport, SweepReport};
use cnn2gate::dse::{EvalCache, Evaluator, Fidelity, OptionSpace};
use cnn2gate::estimator::{device, Thresholds};
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::zoo;
use cnn2gate::quant::QuantSpec;
use cnn2gate::report::{
    fig6, fleet_table, stepped_census_table, sweep_best_device_table, sweep_best_model_table,
    sweep_pareto_table, sweep_table,
};
use cnn2gate::session::{CompileJob, Outcome, Session};
use cnn2gate::synth::{self, Explorer, SynthReport};
use cnn2gate::util::json::Json;

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cnn2gate-session-it-{}-{tag}.json", std::process::id()))
}

/// Field-by-field identity of two synthesis reports (every
/// deterministic field; wall clocks excluded by construction).
fn assert_report_identity(old: &SynthReport, new: &SynthReport, ctx: &str) {
    assert_eq!(old.model, new.model, "{ctx}");
    assert_eq!(old.device, new.device, "{ctx}");
    assert_eq!(old.option(), new.option(), "{ctx}");
    assert_eq!(old.dse.trace, new.dse.trace, "{ctx}: DSE traces");
    assert_eq!(old.dse.queries, new.dse.queries, "{ctx}");
    assert_eq!(old.dse.cache_hits, new.dse.cache_hits, "{ctx}");
    assert_eq!(old.dse.f_max.to_bits(), new.dse.f_max.to_bits(), "{ctx}");
    assert_eq!(old.dse.modeled_seconds, new.dse.modeled_seconds, "{ctx}");
    assert_eq!(old.estimate, new.estimate, "{ctx}");
    assert_eq!(old.synthesis_minutes, new.synthesis_minutes, "{ctx}");
    assert_eq!(old.sim, new.sim, "{ctx}");
    assert_eq!(old.stepped_network, new.stepped_network, "{ctx}");
}

#[test]
fn shim_synth_bit_identity_cold_and_warm() {
    let g = zoo::build("alexnet", false).unwrap();
    let th = Thresholds::default();
    let fidelity = Fidelity::SteppedFullNetwork;

    // cold: old free function vs a fresh session
    let old_ev = Evaluator::new(4);
    let old = synth::run_with_fidelity(
        &old_ev,
        &g,
        &device::ARRIA_10_GX1150,
        Explorer::BruteForce,
        th,
        None,
        fidelity,
    )
    .unwrap();
    let session = Session::builder().threads(4).fidelity(fidelity).build();
    let job = CompileJob::builder()
        .model(g.clone())
        .device(&device::ARRIA_10_GX1150)
        .explorer(Explorer::BruteForce)
        .build()
        .unwrap();
    let new = session.run(&job).unwrap().into_synth_report().unwrap();
    assert_report_identity(&old, &new, "cold synth");
    // rendered output is byte-identical too
    assert_eq!(
        fig6(old.sim.as_ref().unwrap()).render(),
        fig6(new.sim.as_ref().unwrap()).render()
    );
    assert_eq!(
        stepped_census_table(old.sim.as_ref().unwrap(), old.stepped_network.as_ref().unwrap())
            .render(),
        stepped_census_table(new.sim.as_ref().unwrap(), new.stepped_network.as_ref().unwrap())
            .render()
    );

    // warm: persist the memo, reload on both sides, nothing recomputes
    let path = tmp("synth");
    old_ev.cache().save(&path).unwrap();
    let warm_ev = Evaluator::with_cache(4, Arc::new(EvalCache::load(&path).unwrap()));
    let old_warm = synth::run_with_fidelity(
        &warm_ev,
        &g,
        &device::ARRIA_10_GX1150,
        Explorer::BruteForce,
        th,
        None,
        fidelity,
    )
    .unwrap();
    let warm_session = Session::builder().cache_file(&path).fidelity(fidelity).build();
    assert!(warm_session.load_warning().is_none());
    let new_warm = warm_session.run(&job).unwrap().into_synth_report().unwrap();
    assert_eq!(warm_ev.cache().stats().misses, 0, "old warm path recomputed");
    assert_eq!(
        warm_session.evaluator().cache().stats().misses,
        0,
        "new warm path recomputed"
    );
    assert_report_identity(&old_warm, &old, "old warm vs cold");
    assert_report_identity(&new_warm, &new, "new warm vs cold");
    std::fs::remove_file(&path).ok();
}

fn fleet_tables(rep: &FleetReport) -> String {
    fleet_table(&rep.model, &rep.entries).render()
}

#[test]
fn shim_fleet_bit_identity_cold_and_warm() {
    let g = zoo::build("alexnet", false).unwrap();
    let th = Thresholds::default();

    let old_ev = Evaluator::new(4);
    let old = pipeline::fit_fleet_with(&old_ev, &g, Explorer::BruteForce, th).unwrap();
    let session = Session::builder().threads(4).build();
    let job = CompileJob::builder()
        .model(g.clone())
        .all_devices()
        .explorer(Explorer::BruteForce)
        .build()
        .unwrap();
    let outcome = session.run(&job).unwrap();
    let new = outcome.to_fleet_report().unwrap();
    assert_eq!(old.entries.len(), new.entries.len());
    for (o, n) in old.entries.iter().zip(&new.entries) {
        assert_report_identity(o, n, "cold fleet");
    }
    assert_eq!(fleet_tables(&old), fleet_tables(&new), "fleet tables byte-identical");

    // warm on both sides from the same persisted memo
    let path = tmp("fleet");
    old_ev.cache().save(&path).unwrap();
    let warm_ev = Evaluator::with_cache(4, Arc::new(EvalCache::load(&path).unwrap()));
    let old_warm = pipeline::fit_fleet_with(&warm_ev, &g, Explorer::BruteForce, th).unwrap();
    let warm_session = Session::builder().cache_file(&path).build();
    let new_warm = warm_session.run(&job).unwrap().to_fleet_report().unwrap();
    assert_eq!(warm_ev.cache().stats().misses, 0);
    assert_eq!(warm_session.evaluator().cache().stats().misses, 0);
    assert_eq!(fleet_tables(&old_warm), fleet_tables(&old), "old warm drifted");
    assert_eq!(fleet_tables(&new_warm), fleet_tables(&new), "new warm drifted");
    std::fs::remove_file(&path).ok();
}

fn sweep_tables(rep: &SweepReport) -> String {
    format!(
        "{}{}{}{}",
        sweep_table(rep).render(),
        sweep_best_device_table(rep).render(),
        sweep_best_model_table(rep).render(),
        sweep_pareto_table(rep).render()
    )
}

#[test]
fn shim_sweep_bit_identity_cold_and_warm() {
    let models = [
        zoo::build("alexnet", false).unwrap(),
        zoo::build("vgg16", false).unwrap(),
    ];
    let th = Thresholds::default();

    let old_ev = Evaluator::new(4);
    let old = pipeline::sweep_matrix_with(
        &old_ev,
        &models,
        Explorer::BruteForce,
        th,
        Fidelity::Analytical,
    )
    .unwrap();
    let session = Session::builder().threads(4).build();
    let job = CompileJob::builder()
        .models(models.clone())
        .all_devices()
        .explorer(Explorer::BruteForce)
        .build()
        .unwrap();
    let outcome = session.run(&job).unwrap();
    let new = outcome.to_sweep_report();
    assert_eq!(old.entries.len(), new.entries.len());
    for (o, n) in old.entries.iter().zip(&new.entries) {
        assert_report_identity(o, n, "cold sweep");
    }
    assert_eq!(sweep_tables(&old), sweep_tables(&new), "all four sweep tables");

    let path = tmp("sweep");
    old_ev.cache().save(&path).unwrap();
    let warm_ev = Evaluator::with_cache(4, Arc::new(EvalCache::load(&path).unwrap()));
    let old_warm = pipeline::sweep_matrix_with(
        &warm_ev,
        &models,
        Explorer::BruteForce,
        th,
        Fidelity::Analytical,
    )
    .unwrap();
    let warm_session = Session::builder().cache_file(&path).build();
    let new_warm = warm_session.run(&job).unwrap().to_sweep_report();
    assert_eq!(warm_ev.cache().stats().misses, 0);
    assert_eq!(warm_session.evaluator().cache().stats().misses, 0);
    assert_eq!(sweep_tables(&old_warm), sweep_tables(&old));
    assert_eq!(sweep_tables(&new_warm), sweep_tables(&new));
    std::fs::remove_file(&path).ok();
}

#[test]
fn fleet_and_rl_batches_ride_the_scheduler_deterministically() {
    // acceptance shape: fleet fits and RL episode batches execute on the
    // work-stealing deques (StealStats surfaced in the Outcome) while
    // results stay input-order deterministic — byte-identical tables
    // across runs
    let flow = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();
    let grid = OptionSpace::from_flow(&flow).pairs().len();
    let n_dev = device::all().len();
    // chunked prewarm items (CHUNK=4) + one explorer item per pair
    let expected_items = grid.div_ceil(4) * n_dev + n_dev;
    let run = |explorer: Explorer| {
        let session = Session::builder().threads(4).build();
        let job = CompileJob::builder()
            .model(zoo::build("alexnet", false).unwrap())
            .all_devices()
            .explorer(explorer)
            .build()
            .unwrap();
        let outcome = session.run(&job).unwrap();
        assert_eq!(
            outcome.steals.executed, expected_items,
            "every prewarm chunk and every per-pair explorer is a deque item"
        );
        assert!(outcome.steals.workers >= 1);
        let rep = outcome.to_fleet_report().unwrap();
        // database order preserved regardless of who stole what
        for (entry, dev) in rep.entries.iter().zip(device::all()) {
            assert_eq!(entry.device, dev.name);
        }
        fleet_tables(&rep)
    };
    assert_eq!(run(Explorer::BruteForce), run(Explorer::BruteForce));
    assert_eq!(run(Explorer::Reinforcement), run(Explorer::Reinforcement));
}

// ---------------------------------------------------------------------------
// --json document: stability + golden schema
// ---------------------------------------------------------------------------

fn analytical_outcome() -> Outcome {
    let session = Session::builder().threads(4).build();
    session
        .run(
            &CompileJob::builder()
                .model(zoo::build("alexnet", false).unwrap())
                .all_devices()
                .explorer(Explorer::BruteForce)
                .build()
                .unwrap(),
        )
        .unwrap()
}

fn quantized_stepped_outcome() -> Outcome {
    let session = Session::builder()
        .threads(4)
        .fidelity(Fidelity::SteppedFullNetwork)
        .build();
    session
        .run(
            &CompileJob::builder()
                .model(zoo::build("lenet5", true).unwrap())
                .device(&device::ARRIA_10_GX1150)
                .explorer(Explorer::BruteForce)
                .quantize(QuantSpec::default())
                .build()
                .unwrap(),
        )
        .unwrap()
}

#[test]
fn outcome_json_is_stable_across_cold_and_warm_runs() {
    let cold = analytical_outcome().to_json().to_string_pretty();
    // a warm run from a persisted cache must emit the same bytes: the
    // document carries no wall clocks, steal counts or memo counters
    let path = tmp("json-warm");
    let session = Session::builder().cache_file(&path).build();
    let job = CompileJob::builder()
        .model(zoo::build("alexnet", false).unwrap())
        .all_devices()
        .explorer(Explorer::BruteForce)
        .build()
        .unwrap();
    session.run(&job).unwrap();
    session.close().unwrap();
    let warm_session = Session::builder().cache_file(&path).build();
    let warm = warm_session.run(&job).unwrap().to_json().to_string_pretty();
    assert_eq!(warm_session.evaluator().cache().stats().misses, 0);
    assert_eq!(cold, warm, "--json output must not depend on cache state");
    // and it round-trips through the codec byte-for-byte
    let doc = Json::parse(&cold).expect("outcome JSON parses");
    assert_eq!(doc.to_string_pretty(), cold);
    std::fs::remove_file(&path).ok();
}

/// Collect every key path of a JSON document: object keys join with
/// `.`, array elements with `[]`; leaves (and empty containers) record
/// their path.
fn collect_paths(v: &Json, prefix: &str, out: &mut BTreeSet<String>) {
    match v {
        Json::Obj(o) => {
            if o.is_empty() {
                out.insert(prefix.to_string());
            }
            for (k, child) in o.iter() {
                let p = if prefix.is_empty() {
                    k.clone()
                } else {
                    format!("{prefix}.{k}")
                };
                collect_paths(child, &p, out);
            }
        }
        Json::Arr(a) => {
            let p = format!("{prefix}[]");
            if a.is_empty() {
                out.insert(p.clone());
            }
            for child in a {
                collect_paths(child, &p, out);
            }
        }
        _ => {
            out.insert(prefix.to_string());
        }
    }
}

#[test]
fn outcome_json_matches_the_golden_schema() {
    // union of the fitting/non-fitting analytical sweep (nulls, option
    // arrays, rankings) and a quantized stepped-full 1×1 (quant +
    // stepped_network sections): together they exercise every key the
    // v1 schema can emit
    let mut got = BTreeSet::new();
    collect_paths(&analytical_outcome().to_json(), "", &mut got);
    collect_paths(&quantized_stepped_outcome().to_json(), "", &mut got);

    let golden_path =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/outcome_v1_paths.txt");
    if std::env::var("CNN2GATE_UPDATE_GOLDENS").is_ok() {
        let mut text = String::from(
            "# Key paths of the cnn2gate-outcome v1 JSON document (--json).\n\
             # Regenerate with CNN2GATE_UPDATE_GOLDENS=1 cargo test outcome_json_matches.\n",
        );
        for p in &got {
            text.push_str(p);
            text.push('\n');
        }
        std::fs::write(&golden_path, text).unwrap();
    }
    let want: BTreeSet<String> = std::fs::read_to_string(&golden_path)
        .expect("golden schema file committed at rust/tests/golden/outcome_v1_paths.txt")
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(String::from)
        .collect();
    let missing: Vec<&String> = want.difference(&got).collect();
    let extra: Vec<&String> = got.difference(&want).collect();
    assert!(
        missing.is_empty() && extra.is_empty(),
        "outcome schema drift\n  in golden but not emitted: {missing:?}\n  emitted but not in golden: {extra:?}\n  (CNN2GATE_UPDATE_GOLDENS=1 regenerates the golden)"
    );
}

#[test]
fn outcome_json_carries_the_acceptance_payload() {
    let doc = analytical_outcome().to_json();
    assert_eq!(doc.get("format").as_str(), Some("cnn2gate-outcome"));
    assert_eq!(doc.get("version").as_i64(), Some(1));
    assert_eq!(doc.get("explorer").as_str(), Some("bf"));
    assert_eq!(doc.get("fidelity").as_str(), Some("analytical"));
    let entries = doc.get("entries").as_arr().unwrap();
    assert_eq!(entries.len(), device::all().len());
    // the Arria 10 cell carries the paper's design
    let arria = entries
        .iter()
        .find(|e| e.get("device").as_str() == Some("Arria 10 GX 1150"))
        .unwrap();
    assert_eq!(arria.get("fits").as_bool(), Some(true));
    assert_eq!(arria.get("option").as_usize_vec(), Some(vec![16, 32]));
    assert!(arria.get("latency").get("total_millis").as_f64().unwrap() > 0.0);
    assert_eq!(arria.get("trace").as_arr().unwrap().len(), 12);
    // the 5CSEMA4 cell is an explicit no-fit, not an absent row
    let cyclone = entries
        .iter()
        .find(|e| e.get("device").as_str() == Some("Cyclone V SoC 5CSEMA4"))
        .unwrap();
    assert_eq!(cyclone.get("fits").as_bool(), Some(false));
    assert!(cyclone.get("option").is_null());
    assert!(cyclone.get("estimate").is_null());
    // rankings present
    let rankings = doc.get("rankings");
    assert_eq!(
        rankings.get("best_device_per_model").as_arr().unwrap().len(),
        1
    );
    assert!(!rankings.get("pareto_frontier").as_arr().unwrap().is_empty());
    // the stepped/quantized shape carries its sections
    let stepped = quantized_stepped_outcome().to_json();
    let entry = stepped.get("entries").idx(0);
    assert!(!entry.get("stepped_network").is_null());
    assert!(entry.get("quant").get("tensors").as_usize().unwrap() > 0);
    assert_eq!(stepped.get("fidelity").as_str(), Some("stepped-full-network"));
}
