//! Session-engine integration tests: [`cnn2gate::session::Session`] is
//! the single entry point now (the PR-4 deprecated shims are gone), so
//! these tests pin Session-vs-Session determinism — two independent
//! sessions running the same job must agree field-by-field and
//! byte-for-byte, cold AND cache-warm — plus scheduling-independence,
//! the census-γ=0 compatibility guarantee, and the stability of the
//! `--json` document against its committed golden schema.

use std::collections::BTreeSet;
use std::path::Path;

use cnn2gate::coordinator::pipeline::{FleetReport, SweepReport};
use cnn2gate::dse::{Fidelity, OptionSpace};
use cnn2gate::estimator::device;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::zoo;
use cnn2gate::quant::QuantSpec;
use cnn2gate::report::{
    fig6, fleet_table, specialization_table, stepped_census_table, sweep_best_device_table,
    sweep_best_model_table, sweep_pareto_table, sweep_table,
};
use cnn2gate::session::{CompileJob, Outcome, Session, SessionBuilder};
use cnn2gate::synth::{Explorer, SynthReport};
use cnn2gate::util::json::Json;

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cnn2gate-session-it-{}-{tag}.json", std::process::id()))
}

/// Field-by-field identity of two synthesis reports (every
/// deterministic field; wall clocks excluded by construction).
fn assert_report_identity(a: &SynthReport, b: &SynthReport, ctx: &str) {
    assert_eq!(a.model, b.model, "{ctx}");
    assert_eq!(a.device, b.device, "{ctx}");
    assert_eq!(a.option(), b.option(), "{ctx}");
    assert_eq!(a.batch, b.batch, "{ctx}: chosen batch");
    let sweep_view = |r: &SynthReport| {
        r.throughput.as_ref().map(|c| {
            (
                c.chosen,
                c.chosen_batch(),
                c.slo_satisfied,
                c.candidates
                    .iter()
                    .map(|x| {
                        (
                            x.batch,
                            x.option(),
                            x.frames_per_s.to_bits(),
                            x.batch_millis.to_bits(),
                            x.e2e_millis.to_bits(),
                            x.meets_slo,
                        )
                    })
                    .collect::<Vec<_>>(),
            )
        })
    };
    assert_eq!(sweep_view(a), sweep_view(b), "{ctx}: throughput sweep");
    assert_eq!(a.dse.trace, b.dse.trace, "{ctx}: DSE traces");
    assert_eq!(a.dse.queries, b.dse.queries, "{ctx}");
    assert_eq!(a.dse.cache_hits, b.dse.cache_hits, "{ctx}");
    assert_eq!(a.dse.f_max.to_bits(), b.dse.f_max.to_bits(), "{ctx}");
    assert_eq!(a.dse.modeled_seconds, b.dse.modeled_seconds, "{ctx}");
    assert_eq!(a.estimate, b.estimate, "{ctx}");
    assert_eq!(a.synthesis_minutes, b.synthesis_minutes, "{ctx}");
    assert_eq!(a.sim, b.sim, "{ctx}");
    assert_eq!(a.stepped_network, b.stepped_network, "{ctx}");
    assert_eq!(a.specialization, b.specialization, "{ctx}");
    assert_eq!(a.round_producers, b.round_producers, "{ctx}: DAG wiring");
}

fn synth_job(specialize: bool) -> CompileJob {
    let mut builder = CompileJob::builder()
        .model(zoo::build("alexnet", false).unwrap())
        .device(&device::ARRIA_10_GX1150)
        .explorer(Explorer::BruteForce);
    if specialize {
        builder = builder.specialize();
    }
    builder.build().unwrap()
}

fn stepped_builder() -> SessionBuilder {
    Session::builder().threads(4).fidelity(Fidelity::SteppedFullNetwork)
}

#[test]
fn session_synth_determinism_cold_and_warm() {
    let job = synth_job(true);

    // two independent cold sessions: field-identical reports,
    // byte-identical rendered tables
    let first_session = stepped_builder().build();
    let first = first_session.run(&job).unwrap().into_synth_report().unwrap();
    let second = stepped_builder().build().run(&job).unwrap().into_synth_report().unwrap();
    assert_report_identity(&first, &second, "cold synth run-vs-run");
    assert_eq!(
        fig6(first.sim.as_ref().unwrap()).render(),
        fig6(second.sim.as_ref().unwrap()).render()
    );
    assert_eq!(
        stepped_census_table(first.sim.as_ref().unwrap(), first.stepped_network.as_ref().unwrap())
            .render(),
        stepped_census_table(
            second.sim.as_ref().unwrap(),
            second.stepped_network.as_ref().unwrap()
        )
        .render()
    );
    assert_eq!(
        specialization_table(&first, first.specialization.as_ref().unwrap()).render(),
        specialization_table(&second, second.specialization.as_ref().unwrap()).render()
    );

    // warm: persist the first session's memo, replay from disk — nothing
    // recomputes and every field reproduces
    let path = tmp("synth");
    first_session.evaluator().cache().save(&path).unwrap();
    let warm_session = stepped_builder().threads(0).cache_file(&path).build();
    assert!(warm_session.load_warning().is_none());
    let warm = warm_session.run(&job).unwrap().into_synth_report().unwrap();
    assert_eq!(warm_session.evaluator().cache().stats().misses, 0, "warm path recomputed");
    assert_report_identity(&warm, &first, "warm vs cold");
    std::fs::remove_file(&path).ok();
}

fn fleet_tables(rep: &FleetReport) -> String {
    fleet_table(&rep.model, &rep.entries).render()
}

fn fleet_job() -> CompileJob {
    CompileJob::builder()
        .model(zoo::build("alexnet", false).unwrap())
        .all_devices()
        .explorer(Explorer::BruteForce)
        .build()
        .unwrap()
}

#[test]
fn session_fleet_determinism_cold_and_warm() {
    let job = fleet_job();
    let first_session = Session::builder().threads(4).build();
    let first = first_session.run(&job).unwrap().to_fleet_report().unwrap();
    let second = Session::builder()
        .threads(4)
        .build()
        .run(&job)
        .unwrap()
        .to_fleet_report()
        .unwrap();
    assert_eq!(first.entries.len(), second.entries.len());
    for (a, b) in first.entries.iter().zip(&second.entries) {
        assert_report_identity(a, b, "cold fleet run-vs-run");
    }
    assert_eq!(fleet_tables(&first), fleet_tables(&second), "fleet tables byte-identical");

    let path = tmp("fleet");
    first_session.evaluator().cache().save(&path).unwrap();
    let warm_session = Session::builder().cache_file(&path).build();
    let warm = warm_session.run(&job).unwrap().to_fleet_report().unwrap();
    assert_eq!(warm_session.evaluator().cache().stats().misses, 0);
    assert_eq!(fleet_tables(&warm), fleet_tables(&first), "warm fleet drifted");
    std::fs::remove_file(&path).ok();
}

fn sweep_tables(rep: &SweepReport) -> String {
    format!(
        "{}{}{}{}",
        sweep_table(rep).render(),
        sweep_best_device_table(rep).render(),
        sweep_best_model_table(rep).render(),
        sweep_pareto_table(rep).render()
    )
}

#[test]
fn session_sweep_determinism_cold_and_warm() {
    let job = CompileJob::builder()
        .models([
            zoo::build("alexnet", false).unwrap(),
            zoo::build("vgg16", false).unwrap(),
        ])
        .all_devices()
        .explorer(Explorer::BruteForce)
        .build()
        .unwrap();

    let first_session = Session::builder().threads(4).build();
    let first = first_session.run(&job).unwrap().to_sweep_report();
    let second = Session::builder().threads(4).build().run(&job).unwrap().to_sweep_report();
    assert_eq!(first.entries.len(), second.entries.len());
    for (a, b) in first.entries.iter().zip(&second.entries) {
        assert_report_identity(a, b, "cold sweep run-vs-run");
    }
    assert_eq!(sweep_tables(&first), sweep_tables(&second), "all four sweep tables");

    let path = tmp("sweep");
    first_session.evaluator().cache().save(&path).unwrap();
    let warm_session = Session::builder().cache_file(&path).build();
    let warm = warm_session.run(&job).unwrap().to_sweep_report();
    assert_eq!(warm_session.evaluator().cache().stats().misses, 0);
    assert_eq!(sweep_tables(&warm), sweep_tables(&first));
    std::fs::remove_file(&path).ok();
}

#[test]
fn census_gamma_zero_sessions_match_unshaped_sessions_at_any_fidelity() {
    // the acceptance pin: γ = 0 explorer choices and traces are
    // bit-identical to the unshaped path across all fidelities
    let job = synth_job(false);
    for fidelity in [
        Fidelity::Analytical,
        Fidelity::SteppedDominantRound,
        Fidelity::SteppedFullNetwork,
    ] {
        let plain = Session::builder()
            .threads(4)
            .fidelity(fidelity)
            .build()
            .run(&job)
            .unwrap()
            .into_synth_report()
            .unwrap();
        let shaped = Session::builder()
            .threads(4)
            .fidelity(fidelity)
            .census_gamma(0.0)
            .build()
            .run(&job)
            .unwrap()
            .into_synth_report()
            .unwrap();
        assert_report_identity(&plain, &shaped, "γ=0 vs unshaped");
    }
}

#[test]
fn shaped_sessions_are_deterministic_and_key_their_own_cache_space() {
    // a γ > 0 stepped-full session is deterministic cold and cache-warm,
    // and its persisted memo answers a same-γ session without recompute
    let job = synth_job(false);
    let build = || stepped_builder().census_gamma(0.4).build();
    let first_session = build();
    let first = first_session.run(&job).unwrap().into_synth_report().unwrap();
    let second = build().run(&job).unwrap().into_synth_report().unwrap();
    assert_report_identity(&first, &second, "shaped run-vs-run");

    let path = tmp("shaped");
    first_session.evaluator().cache().save(&path).unwrap();
    let warm_session = stepped_builder().threads(0).census_gamma(0.4).cache_file(&path).build();
    let warm = warm_session.run(&job).unwrap().into_synth_report().unwrap();
    assert_eq!(warm_session.evaluator().cache().stats().misses, 0);
    assert_report_identity(&warm, &first, "shaped warm vs cold");

    // a different γ deliberately misses that working set (the γ is part
    // of the memo fingerprint) and recomputes its own
    let other = stepped_builder().threads(0).census_gamma(0.7).cache_file(&path).build();
    other.run(&job).unwrap();
    assert!(other.evaluator().cache().stats().misses > 0, "γ=0.7 must not borrow γ=0.4 entries");
    std::fs::remove_file(&path).ok();
}

#[test]
fn fleet_and_rl_batches_ride_the_scheduler_deterministically() {
    // fleet fits and RL episode batches execute on the work-stealing
    // deques (StealStats surfaced in the Outcome) while results stay
    // input-order deterministic — byte-identical tables across runs
    let flow = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();
    let grid = OptionSpace::from_flow(&flow).pairs().len();
    let n_dev = device::all().len();
    // chunked prewarm items (CHUNK=4) + one explorer item per pair
    let expected_items = grid.div_ceil(4) * n_dev + n_dev;
    let run = |explorer: Explorer| {
        let session = Session::builder().threads(4).build();
        let job = CompileJob::builder()
            .model(zoo::build("alexnet", false).unwrap())
            .all_devices()
            .explorer(explorer)
            .build()
            .unwrap();
        let outcome = session.run(&job).unwrap();
        assert_eq!(
            outcome.steals.executed, expected_items,
            "every prewarm chunk and every per-pair explorer is a deque item"
        );
        assert!(outcome.steals.workers >= 1);
        let rep = outcome.to_fleet_report().unwrap();
        // database order preserved regardless of who stole what
        for (entry, dev) in rep.entries.iter().zip(device::all()) {
            assert_eq!(entry.device, dev.name);
        }
        fleet_tables(&rep)
    };
    assert_eq!(run(Explorer::BruteForce), run(Explorer::BruteForce));
    assert_eq!(run(Explorer::Reinforcement), run(Explorer::Reinforcement));
}

// ---------------------------------------------------------------------------
// --json document: stability + golden schema
// ---------------------------------------------------------------------------

fn analytical_outcome() -> Outcome {
    let session = Session::builder().threads(4).build();
    session
        .run(
            &CompileJob::builder()
                .model(zoo::build("alexnet", false).unwrap())
                .all_devices()
                .explorer(Explorer::BruteForce)
                .build()
                .unwrap(),
        )
        .unwrap()
}

fn quantized_stepped_outcome() -> Outcome {
    let session = Session::builder()
        .threads(4)
        .fidelity(Fidelity::SteppedFullNetwork)
        .build();
    session
        .run(
            &CompileJob::builder()
                .model(zoo::build("lenet5", true).unwrap())
                .device(&device::ARRIA_10_GX1150)
                .explorer(Explorer::BruteForce)
                .quantize(QuantSpec::default())
                .specialize()
                .build()
                .unwrap(),
        )
        .unwrap()
}

fn throughput_outcome() -> Outcome {
    let session = Session::builder().threads(4).build();
    session
        .run(
            &CompileJob::builder()
                .model(zoo::build("alexnet", false).unwrap())
                .device(&device::ARRIA_10_GX1150)
                .explorer(Explorer::BruteForce)
                .batches([1, 16])
                .latency_slo_ms(10_000.0)
                .build()
                .unwrap(),
        )
        .unwrap()
}

/// A branched (residual + depthwise) model through the stepped-full +
/// specialize flow: the v5 shape with `round_producers` DAG wiring and
/// per-feed starvation counters on the Add-merge rounds.
fn branched_stepped_outcome() -> Outcome {
    let session = Session::builder()
        .threads(4)
        .fidelity(Fidelity::SteppedFullNetwork)
        .build();
    session
        .run(
            &CompileJob::builder()
                .model(zoo::build("tinyres", false).unwrap())
                .device(&device::ARRIA_10_GX1150)
                .explorer(Explorer::BruteForce)
                .specialize()
                .build()
                .unwrap(),
        )
        .unwrap()
}

#[test]
fn outcome_json_is_stable_across_cold_and_warm_runs() {
    let cold = analytical_outcome().to_json().to_string_pretty();
    // a warm run from a persisted cache must emit the same bytes: the
    // document carries no wall clocks, steal counts or memo counters
    let path = tmp("json-warm");
    let session = Session::builder().cache_file(&path).build();
    let job = CompileJob::builder()
        .model(zoo::build("alexnet", false).unwrap())
        .all_devices()
        .explorer(Explorer::BruteForce)
        .build()
        .unwrap();
    session.run(&job).unwrap();
    session.close().unwrap();
    let warm_session = Session::builder().cache_file(&path).build();
    let warm = warm_session.run(&job).unwrap().to_json().to_string_pretty();
    assert_eq!(warm_session.evaluator().cache().stats().misses, 0);
    assert_eq!(cold, warm, "--json output must not depend on cache state");
    // and it round-trips through the codec byte-for-byte
    let doc = Json::parse(&cold).expect("outcome JSON parses");
    assert_eq!(doc.to_string_pretty(), cold);
    std::fs::remove_file(&path).ok();
}

/// Collect every key path of a JSON document: object keys join with
/// `.`, array elements with `[]`; leaves (and empty containers) record
/// their path.
fn collect_paths(v: &Json, prefix: &str, out: &mut BTreeSet<String>) {
    match v {
        Json::Obj(o) => {
            if o.is_empty() {
                out.insert(prefix.to_string());
            }
            for (k, child) in o.iter() {
                let p = if prefix.is_empty() {
                    k.clone()
                } else {
                    format!("{prefix}.{k}")
                };
                collect_paths(child, &p, out);
            }
        }
        Json::Arr(a) => {
            let p = format!("{prefix}[]");
            if a.is_empty() {
                out.insert(p.clone());
            }
            for child in a {
                collect_paths(child, &p, out);
            }
        }
        _ => {
            out.insert(prefix.to_string());
        }
    }
}

#[test]
fn outcome_json_matches_the_golden_schema() {
    // union of the fitting/non-fitting analytical sweep (nulls, option
    // arrays, rankings), a quantized+specialized stepped-full 1×1
    // (quant + stepped_network + specialization sections), a
    // throughput-mode 1×1 (per-entry batch + throughput sweep), and a
    // branched stepped-full 1×1 (round_producers DAG wiring + per-feed
    // starvation counters): together they exercise every key the v5
    // schema can emit
    let mut got = BTreeSet::new();
    collect_paths(&analytical_outcome().to_json(), "", &mut got);
    collect_paths(&quantized_stepped_outcome().to_json(), "", &mut got);
    collect_paths(&throughput_outcome().to_json(), "", &mut got);
    collect_paths(&branched_stepped_outcome().to_json(), "", &mut got);

    let golden_path =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/outcome_v5_paths.txt");
    if std::env::var("CNN2GATE_UPDATE_GOLDENS").is_ok() {
        let mut text = String::from(
            "# Key paths of the cnn2gate-outcome v5 JSON document (--json).\n\
             # Regenerate with CNN2GATE_UPDATE_GOLDENS=1 cargo test outcome_json_matches.\n",
        );
        for p in &got {
            text.push_str(p);
            text.push('\n');
        }
        std::fs::write(&golden_path, text).unwrap();
    }
    let want: BTreeSet<String> = std::fs::read_to_string(&golden_path)
        .expect("golden schema file committed at rust/tests/golden/outcome_v5_paths.txt")
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(String::from)
        .collect();
    let missing: Vec<&String> = want.difference(&got).collect();
    let extra: Vec<&String> = got.difference(&want).collect();
    assert!(
        missing.is_empty() && extra.is_empty(),
        "outcome schema drift\n  in golden but not emitted: {missing:?}\n  emitted but not in golden: {extra:?}\n  (CNN2GATE_UPDATE_GOLDENS=1 regenerates the golden)"
    );
}

#[test]
fn outcome_json_carries_the_acceptance_payload() {
    let doc = analytical_outcome().to_json();
    assert_eq!(doc.get("format").as_str(), Some("cnn2gate-outcome"));
    assert_eq!(doc.get("version").as_i64(), Some(5));
    assert_eq!(doc.get("explorer").as_str(), Some("bf"));
    assert_eq!(doc.get("fidelity").as_str(), Some("analytical"));
    assert_eq!(doc.get("census_gamma").as_f64(), Some(0.0));
    let entries = doc.get("entries").as_arr().unwrap();
    assert_eq!(entries.len(), device::all().len());
    // the Arria 10 cell carries the paper's design
    let arria = entries
        .iter()
        .find(|e| e.get("device").as_str() == Some("Arria 10 GX 1150"))
        .unwrap();
    assert_eq!(arria.get("fits").as_bool(), Some(true));
    assert_eq!(arria.get("option").as_usize_vec(), Some(vec![16, 32]));
    assert!(arria.get("latency").get("total_millis").as_f64().unwrap() > 0.0);
    assert_eq!(arria.get("trace").as_arr().unwrap().len(), 12);
    assert!(arria.get("specialization").is_null(), "not requested");
    // the 5CSEMA4 cell is an explicit no-fit, not an absent row
    let cyclone = entries
        .iter()
        .find(|e| e.get("device").as_str() == Some("Cyclone V SoC 5CSEMA4"))
        .unwrap();
    assert_eq!(cyclone.get("fits").as_bool(), Some(false));
    assert!(cyclone.get("option").is_null());
    assert!(cyclone.get("estimate").is_null());
    // rankings present
    let rankings = doc.get("rankings");
    assert_eq!(rankings.get("best_device_per_model").as_arr().unwrap().len(), 1);
    assert!(!rankings.get("pareto_frontier").as_arr().unwrap().is_empty());
    // the stepped/quantized/specialized shape carries its sections
    let stepped = quantized_stepped_outcome().to_json();
    let entry = stepped.get("entries").idx(0);
    assert!(!entry.get("stepped_network").is_null());
    assert!(entry.get("quant").get("tensors").as_usize().unwrap() > 0);
    assert_eq!(stepped.get("fidelity").as_str(), Some("stepped-full-network"));
    let spec = entry.get("specialization");
    assert!(!spec.is_null(), "specialize() was requested");
    assert_eq!(spec.get("uniform").as_usize_vec(), entry.get("option").as_usize_vec());
    let (before, after) = (
        spec.get("uniform_total_cycles").as_f64().unwrap(),
        spec.get("specialized_total_cycles").as_f64().unwrap(),
    );
    assert!(after <= before, "specialization never regresses");
    assert_eq!(
        spec.get("layers").as_arr().unwrap().len(),
        entry.get("latency").get("layers").as_arr().unwrap().len()
    );
    // classic entries pin batch 1 with a null throughput section
    assert_eq!(arria.get("batch").as_i64(), Some(1));
    assert!(arria.get("throughput").is_null());
    assert_eq!(spec.get("batch").as_i64(), Some(1));
    // the throughput-mode shape carries the (Ni, Nl, B) sweep: weight
    // reuse makes B=16 the frames/s winner within the generous SLO
    let batched = throughput_outcome().to_json();
    let entry = batched.get("entries").idx(0);
    assert_eq!(entry.get("batch").as_i64(), Some(16));
    let thr = entry.get("throughput");
    assert_eq!(thr.get("chosen_batch").as_i64(), Some(16));
    assert_eq!(thr.get("latency_slo_ms").as_f64(), Some(10_000.0));
    assert_eq!(thr.get("slo_satisfied").as_bool(), Some(true));
    let candidates = thr.get("candidates").as_arr().unwrap();
    assert_eq!(candidates.len(), 2);
    assert!(
        candidates[1].get("frames_per_s").as_f64().unwrap()
            > candidates[0].get("frames_per_s").as_f64().unwrap()
    );
}

#[test]
fn branched_outcome_carries_dag_wiring_and_feed_stalls() {
    let outcome = branched_stepped_outcome();
    let doc = outcome.to_json();
    let rep = outcome.into_synth_report().unwrap();
    assert!(rep.fits(), "tinyres fits the Arria 10");

    // the DAG wiring rides the report: one producer list per fused
    // round, and at least one Add-merge round reads two of them
    let producers = rep.round_producers.as_ref().expect("branched model carries wiring");
    assert_eq!(producers.len(), rep.sim.as_ref().unwrap().layers.len());
    assert!(
        producers.iter().any(|ps| ps.len() == 2),
        "tinyres has a residual join: {producers:?}"
    );

    // ...and into the document, alongside per-feed starvation counters
    // on the Add rounds (one read port alternating two feeds starves
    // the lagging feed deterministically) and the serving rate
    let entry = doc.get("entries").idx(0);
    let wired = entry.get("round_producers").as_arr().unwrap();
    assert_eq!(wired.len(), producers.len());
    assert!(wired.iter().any(|ps| ps.as_arr().unwrap().len() == 2));
    let spec = entry.get("specialization");
    assert!(spec.get("specialized_frames_per_s").as_f64().unwrap() > 0.0);
    let text = doc.to_string_pretty();
    assert!(text.contains("feed_a_empty_stalls"), "main-branch starvation recorded");
    assert!(text.contains("feed_b_empty_stalls"), "skip-branch starvation recorded");

    // linear chains carry none of the branch-era artifacts: their
    // documents are the chain-era bytes plus only the version literal
    for linear in [analytical_outcome(), quantized_stepped_outcome()] {
        let text = linear.to_json().to_string_pretty();
        assert!(!text.contains("round_producers"), "linear chains imply their wiring");
        assert!(!text.contains("feed_a_empty_stalls"));
        assert!(!text.contains("feed_b_empty_stalls"));
    }
}

#[test]
fn linear_chain_outcome_bytes_are_stable_across_sessions() {
    // AlexNet + VGG16 through two independent cold sessions: the whole
    // rendered document must be byte-identical, at schema v5, with zero
    // branch-era keys — the provably-identical linear path of the DAG
    // refactor, pinned end-to-end
    let run = || {
        let session = Session::builder().threads(4).build();
        let mut texts = Vec::new();
        for model in ["alexnet", "vgg16"] {
            let outcome = session
                .run(
                    &CompileJob::builder()
                        .model(zoo::build(model, false).unwrap())
                        .device(&device::ARRIA_10_GX1150)
                        .explorer(Explorer::BruteForce)
                        .build()
                        .unwrap(),
                )
                .unwrap();
            texts.push(outcome.to_json().to_string_pretty());
        }
        texts
    };
    let (first, second) = (run(), run());
    assert_eq!(first, second, "linear outcome bytes drift across sessions");
    for text in &first {
        assert!(text.contains("\"version\": 5"));
        assert!(!text.contains("round_producers"));
        assert!(!text.contains("feed_a_empty_stalls"));
        assert!(!text.contains("feed_b_empty_stalls"));
    }
}
