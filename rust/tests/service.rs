//! Compile-service integration tests — the PR-6 acceptance gates, from
//! the public API only:
//!
//! 1. **Byte-identity under concurrency**: ≥8 mixed jobs (tiny→vgg16,
//!    brute-force and RL, three tenants, single-device and fleet)
//!    submitted to one daemon produce `Outcome::to_json` documents
//!    byte-identical to solo [`Session::run`]s of the same specs.
//! 2. **Cancellation coherence**: cancelling a job mid-run (and while
//!    queued) leaves the shared cache loadable with a strict
//!    [`EvalCache::load`], and a session warmed from that file
//!    reproduces a cold run byte-for-byte.
//! 3. **Admission control**: a full bounded queue rejects synchronously
//!    with a reasoned error, recorded by the reducer.
//! 4. **Replayable log**: the reducer's event log replays into the
//!    exact final job store across mixed finished/failed outcomes.

use cnn2gate::coordinator::service::kernel::{pick_next, QueueView};
use cnn2gate::coordinator::service::{Completion, Event, JobId, JobState, Reducer};
use cnn2gate::coordinator::{CompileService, JobSpec, ServiceConfig};
use cnn2gate::dse::{EvalCache, Fidelity, TenantId};
use cnn2gate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA5};
use cnn2gate::onnx::zoo;
use cnn2gate::session::{CompileJob, Session};
use cnn2gate::synth::Explorer;

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cnn2gate-service-it-{}-{tag}.json", std::process::id()))
}

/// One mixed-workload row: (model, fleet?, explorer, tenant).
type Mix = (&'static str, bool, Explorer, &'static str);

const MIX: &[Mix] = &[
    ("tiny", false, Explorer::BruteForce, "acme"),
    ("tiny", false, Explorer::Reinforcement, "zen"),
    ("lenet5", true, Explorer::BruteForce, "acme"),
    ("alexnet", false, Explorer::BruteForce, "bolt"),
    ("alexnet", false, Explorer::Reinforcement, "zen"),
    ("vgg16", false, Explorer::BruteForce, "bolt"),
    ("lenet5", false, Explorer::Reinforcement, "acme"),
    ("tiny", true, Explorer::BruteForce, "zen"),
];

fn mix_job(&(model, fleet, explorer, _): &Mix) -> CompileJob {
    let builder = CompileJob::builder().model(zoo::build(model, false).unwrap()).explorer(explorer);
    let builder = if fleet {
        builder.all_devices()
    } else {
        builder.device(&ARRIA_10_GX1150)
    };
    builder.build().unwrap()
}

#[test]
fn concurrent_mixed_jobs_match_solo_sessions_byte_for_byte() {
    let service = CompileService::start(ServiceConfig {
        workers: 4,
        threads: 2,
        ..ServiceConfig::default()
    });
    let tickets: Vec<_> = MIX
        .iter()
        .map(|row| {
            let spec = JobSpec::new(mix_job(row)).tenant(TenantId::of(row.3));
            service.submit(spec).unwrap()
        })
        .collect();

    for (row, ticket) in MIX.iter().zip(&tickets) {
        let completion = ticket.wait().unwrap();
        let served = completion.outcome_json().unwrap_or_else(|| {
            panic!("{:?} did not finish: {completion:?}", row);
        });
        // the solo reference: an independent session, same spec
        let solo_session = Session::builder().threads(2).tenant(TenantId::of(row.3)).build();
        let solo = solo_session.run(&mix_job(row)).unwrap().to_json().to_string_pretty();
        assert_eq!(served, solo, "{row:?}: service vs solo outcome bytes");
    }

    let report = service.shutdown();
    assert_eq!(report.reducer.open_jobs(), 0);
    assert_eq!(report.reducer.jobs().count(), MIX.len());
    for (id, record) in report.reducer.jobs() {
        assert_eq!(record.state, JobState::Finished, "{id}");
    }
}

#[test]
fn cancellation_leaves_the_shared_cache_loadable_and_warm_correct() {
    let slow_spec = || {
        JobSpec::new(
            CompileJob::builder()
                .model(zoo::build("vgg16", false).unwrap())
                .device(&ARRIA_10_GX1150)
                .explorer(Explorer::BruteForce)
                .build()
                .unwrap(),
        )
        .fidelity(Fidelity::SteppedFullNetwork)
        .tenant(TenantId::of("acme"))
    };
    let service = CompileService::start(ServiceConfig {
        workers: 1,
        threads: 2,
        ..ServiceConfig::default()
    });

    // cancel mid-run: wait for the engine to report progress so some —
    // but not all — of the grid is already in the shared cache
    let running = service.submit(slow_spec()).unwrap();
    loop {
        match running.recv().unwrap() {
            Event::Progress { .. } => break,
            e => assert!(!e.is_terminal(), "terminal before progress: {e:?}"),
        }
    }
    service.cancel(running.id()).unwrap();
    assert_eq!(running.wait().unwrap(), Completion::Cancelled);

    // cancel while queued: the single worker is busy with another slow
    // job, so the second submission never starts
    let blocker = service.submit(slow_spec()).unwrap();
    let queued = service.submit(slow_spec()).unwrap();
    service.cancel(queued.id()).unwrap();
    assert_eq!(queued.wait().unwrap(), Completion::Cancelled);
    service.cancel(blocker.id()).unwrap();
    assert_eq!(blocker.wait().unwrap(), Completion::Cancelled);

    // the partially-warmed cache must save and strict-load cleanly
    let path = tmp("cancel");
    service.evaluator().cache().save(&path).unwrap();
    EvalCache::load(&path).unwrap_or_else(|e| {
        panic!("cache written by a cancelled run must strict-load: {e:#}");
    });
    let report = service.shutdown();
    assert_eq!(report.reducer.open_jobs(), 0);

    // warm-correct: a session seeded from that file reproduces a cold
    // run byte-for-byte — cancelled entries are real entries, not junk
    let job = CompileJob::builder()
        .model(zoo::build("vgg16", false).unwrap())
        .device(&ARRIA_10_GX1150)
        .explorer(Explorer::BruteForce)
        .build()
        .unwrap();
    let builder = || {
        Session::builder()
            .threads(2)
            .fidelity(Fidelity::SteppedFullNetwork)
            .tenant(TenantId::of("acme"))
    };
    let cold = builder().build().run(&job).unwrap().to_json().to_string_pretty();
    let warm_session = builder().cache_file(&path).build();
    assert!(warm_session.load_warning().is_none(), "{:?}", warm_session.load_warning());
    let warm = warm_session.run(&job).unwrap().to_json().to_string_pretty();
    assert_eq!(warm, cold, "warm-from-cancelled vs cold outcome bytes");
    std::fs::remove_file(&path).ok();
}

#[test]
fn admission_control_rejects_when_the_bounded_queue_is_full() {
    let slow_spec = || {
        JobSpec::new(
            CompileJob::builder()
                .model(zoo::build("vgg16", false).unwrap())
                .device(&ARRIA_10_GX1150)
                .explorer(Explorer::BruteForce)
                .build()
                .unwrap(),
        )
        .fidelity(Fidelity::SteppedFullNetwork)
        .tenant(TenantId::of("flood"))
    };
    let service = CompileService::start(ServiceConfig {
        workers: 1,
        queue_capacity: 1,
        threads: 2,
        ..ServiceConfig::default()
    });
    // first fills the single worker slot, second fills the whole queue
    let running = service.submit(slow_spec()).unwrap();
    let queued = service.submit(slow_spec()).unwrap();
    let err = service.submit(slow_spec()).unwrap_err();
    assert!(err.to_string().contains("rejected"), "{err:#}");
    assert!(err.to_string().contains("queue full"), "{err:#}");

    service.cancel(queued.id()).unwrap();
    service.cancel(running.id()).unwrap();
    assert_eq!(queued.wait().unwrap(), Completion::Cancelled);
    assert_eq!(running.wait().unwrap(), Completion::Cancelled);

    let report = service.shutdown();
    let rejected: Vec<_> = report
        .reducer
        .jobs()
        .filter(|(_, r)| r.state == JobState::Rejected)
        .collect();
    assert_eq!(rejected.len(), 1);
    assert!(rejected[0].1.error.as_deref().unwrap().contains("queue full"));
}

#[test]
fn reducer_log_replays_into_the_exact_final_store_across_mixed_outcomes() {
    let service = CompileService::start(ServiceConfig {
        workers: 2,
        threads: 2,
        ..ServiceConfig::default()
    });
    let ok_job = |tenant: &str| {
        JobSpec::new(
            CompileJob::builder()
                .model(zoo::build("tiny", false).unwrap())
                .device(&CYCLONE_V_5CSEMA5)
                .explorer(Explorer::BruteForce)
                .build()
                .unwrap(),
        )
        .tenant(TenantId::of(tenant))
    };
    // a job that deterministically fails fast: --specialize consumes
    // the stepped-full census, which analytical fidelity never produces
    let bad_job = JobSpec::new(
        CompileJob::builder()
            .model(zoo::build("tiny", false).unwrap())
            .device(&CYCLONE_V_5CSEMA5)
            .explorer(Explorer::BruteForce)
            .specialize()
            .build()
            .unwrap(),
    )
    .tenant(TenantId::of("zen"));

    let a = service.submit(ok_job("acme")).unwrap();
    let b = service.submit(ok_job("zen")).unwrap();
    let c = service.submit(bad_job).unwrap();
    assert!(a.wait().unwrap().outcome_json().is_some());
    assert!(b.wait().unwrap().outcome_json().is_some());
    let failure = match c.wait().unwrap() {
        Completion::Failed { error } => error,
        other => panic!("expected failure, got {other:?}"),
    };
    assert!(failure.contains("specialization"), "{failure}");

    let report = service.shutdown();
    let reducer = &report.reducer;
    assert_eq!(reducer.jobs().count(), 3);
    assert_eq!(reducer.open_jobs(), 0);
    let failed = reducer.get(c.id()).unwrap();
    assert_eq!(failed.state, JobState::Failed);
    assert!(failed.outcome_json.is_none());
    assert!(failed.error.as_deref().unwrap().contains("specialization"));
    assert_eq!(failed.tenant, TenantId::of("zen"));
    for id in [a.id(), b.id()] {
        let rec = reducer.get(id).unwrap();
        assert_eq!(rec.state, JobState::Finished);
        assert!(rec.outcome_json.is_some());
    }
    // the log IS the store: replay reconstructs it exactly, and holds
    // only lifecycle events (progress volume is deliberately excluded)
    assert_eq!(&Reducer::replay(reducer.log()), reducer);
    assert_eq!(reducer.log().len(), 3 + 3 + 3, "accepted + started + terminal per job");
    assert!(reducer.log().iter().all(|e| !matches!(e, Event::Progress { .. })));
}

// ---------------------------------------------------------------------------
// Regression shapes pinned by the analysis suite's bounded model checker
// (`cargo run -p analysis mc`). Each test replays the *smallest* event
// sequence of a behavior class the checker explores, at the pure
// kernel/Reducer level, so a future kernel change that breaks one fails
// here with a readable trace long before the exhaustive run does.
// ---------------------------------------------------------------------------

fn accepted(id: u64, tenant: &str, depth: usize) -> Event {
    Event::Accepted { job: JobId(id), tenant: TenantId::of(tenant), queue_depth: depth }
}

/// mc shape: Submit, Submit, Submit against capacity 2 — the third
/// admission must reject, and the rejection is a terminal record that
/// never re-enters the queue.
#[test]
fn mc_shape_queue_bound_third_submit_rejects() {
    let mut r = Reducer::new();
    r.apply(&accepted(0, "acme", 0));
    r.apply(&accepted(1, "acme", 1));
    r.apply(&Event::Rejected {
        job: JobId(2),
        tenant: TenantId::of("acme"),
        reason: "admission queue full (2 jobs)".into(),
    });
    assert_eq!(r.open_jobs(), 2, "rejected job must not count as open");
    let rec = r.get(JobId(2)).unwrap();
    assert_eq!(rec.state, JobState::Rejected);
    assert!(rec.state.is_terminal());
    assert!(rec.error.as_deref().unwrap().contains("queue full"));
    // a straggler Started for the rejected job must not resurrect it
    r.apply(&Event::Started { job: JobId(2) });
    assert_eq!(r.get(JobId(2)).unwrap().state, JobState::Rejected);
}

/// mc shape: Submit, Start, CancelRunning, DoneOk — a cancel flag that
/// loses the race to a successful completion is absorbed: the result is
/// real and the job finishes. The queued-cancel variant stays Cancelled
/// even if a late Finished arrives.
#[test]
fn mc_shape_cancel_coherence_late_events_are_absorbed() {
    // running-cancel raced by success: Finished wins
    let mut r = Reducer::new();
    r.apply(&accepted(0, "acme", 0));
    r.apply(&Event::Started { job: JobId(0) });
    r.apply(&Event::Finished { job: JobId(0), outcome_json: "{}".into() });
    assert_eq!(r.get(JobId(0)).unwrap().state, JobState::Finished);

    // queued-cancel with a straggler completion: Cancelled is terminal
    r.apply(&accepted(1, "zen", 0));
    r.apply(&Event::Cancelled { job: JobId(1) });
    r.apply(&Event::Finished { job: JobId(1), outcome_json: "{}".into() });
    let rec = r.get(JobId(1)).unwrap();
    assert_eq!(rec.state, JobState::Cancelled);
    assert!(rec.outcome_json.is_none(), "cancelled job must not keep a straggler outcome");
    assert_eq!(r.open_jobs(), 0);
}

/// mc shape: the exact-replay leaf invariant on an adversarial log —
/// interleaved jobs, duplicate terminals, and events for unknown ids.
/// `Reducer::replay` of the log must equal the live reducer.
#[test]
fn mc_shape_replay_exactness_on_adversarial_log() {
    let mut live = Reducer::new();
    for e in [
        accepted(0, "acme", 0),
        accepted(1, "zen", 1),
        Event::Started { job: JobId(0) },
        Event::Started { job: JobId(99) }, // unknown id
        Event::Cancelled { job: JobId(1) },
        Event::Failed { job: JobId(0), error: "boom".into() },
        Event::Failed { job: JobId(0), error: "boom again".into() }, // duplicate terminal
        accepted(2, "bolt", 0),
        Event::Started { job: JobId(2) },
        Event::Finished { job: JobId(2), outcome_json: "{}".into() },
    ] {
        live.apply(&e);
    }
    assert_eq!(Reducer::replay(live.log()), live);
    assert_eq!(live.open_jobs(), 0);
    assert_eq!(live.get(JobId(0)).unwrap().state, JobState::Failed);
    assert_eq!(live.get(JobId(1)).unwrap().state, JobState::Cancelled);
    assert_eq!(live.get(JobId(2)).unwrap().state, JobState::Finished);
}

/// mc shape: the fairness key — with tenant "busy" already served, a
/// newer, costlier job from the starved tenant must launch first.
#[test]
fn mc_shape_pick_next_prefers_the_starved_tenant() {
    let queue = [
        QueueView { seq: 0, tenant: TenantId::of("busy"), cost: 1 },
        QueueView { seq: 1, tenant: TenantId::of("starved"), cost: 5 },
    ];
    let running = std::collections::HashMap::new();
    let mut served = std::collections::HashMap::new();
    served.insert(TenantId::of("busy").as_u64(), 3);
    assert_eq!(pick_next(&queue, &running, &served), Some(1));
    // all else equal, lower cost then lower seq wins
    served.clear();
    assert_eq!(pick_next(&queue, &running, &served), Some(0));
}
