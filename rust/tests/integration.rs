//! Cross-module integration tests: the full pipeline over every zoo
//! model × every device, the artifact contract, and paper-shape
//! invariants that span estimator + DSE + simulator — all driven
//! through the [`cnn2gate::session`] front door (the only entry point
//! since the PR-4 shims were removed).

use cnn2gate::dse::{brute, rl, Fidelity, OptionSpace, RlConfig};
use cnn2gate::estimator::{device, estimate, Device, Thresholds};
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::{parser, zoo};
use cnn2gate::quant::QuantSpec;
use cnn2gate::session::{CompileJob, Session};
use cnn2gate::sim::simulate;
use cnn2gate::synth::{Explorer, SynthReport};
use cnn2gate::testkit::for_all;

/// One (model, device) pair through a fresh session.
fn solo(model: &str, with_weights: bool, dev: &'static Device, explorer: Explorer) -> SynthReport {
    let session = Session::builder().threads(2).build();
    let mut builder = CompileJob::builder()
        .model(zoo::build(model, with_weights).unwrap())
        .device(dev)
        .explorer(explorer);
    if with_weights {
        builder = builder.quantize(QuantSpec::default());
    }
    session.run(&builder.build().unwrap()).unwrap().into_synth_report().unwrap()
}

#[test]
fn every_zoo_model_fits_somewhere() {
    // every model must fit at least the Arria 10 and produce a latency
    for name in zoo::names() {
        let dev = device::find("arria10").unwrap();
        let rep = solo(name, false, dev, Explorer::BruteForce);
        assert!(rep.fits(), "{name} must fit the Arria 10");
        assert!(rep.latency_ms().unwrap() > 0.0);
    }
}

#[test]
fn full_grid_pipeline_never_panics() {
    for name in zoo::names() {
        for dev in device::all() {
            let rep = solo(name, false, dev, Explorer::Reinforcement);
            // no-fit is a valid outcome; panics/errors are not
            if let Some(ms) = rep.latency_ms() {
                assert!(ms.is_finite() && ms > 0.0);
            }
        }
    }
}

#[test]
fn quantized_synth_flow_for_weighted_models() {
    for name in ["tiny", "lenet5"] {
        let dev = device::find("arria10").unwrap();
        let rep = solo(name, true, dev, Explorer::BruteForce);
        let q = rep.quant.expect("quant report");
        assert!(q.worst_sat_ratio() < 0.05, "{name}: saturation too high");
    }
}

#[test]
fn tighter_thresholds_never_pick_bigger_designs() {
    // DSE invariant: shrinking T_th can only shrink (or keep) H_best
    let flow = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();
    let dev = device::find("arria10").unwrap();
    let loose = brute::explore(&flow, dev, Thresholds::default());
    let tight = brute::explore(
        &flow,
        dev,
        Thresholds {
            lut: 25.0,
            dsp: 25.0,
            mem: 35.0,
            reg: 25.0,
        },
    );
    let f = |r: &cnn2gate::dse::DseResult| r.best.map(|(a, b)| a * b).unwrap_or(0);
    assert!(f(&tight) <= f(&loose));
}

#[test]
fn simulated_latency_decreases_with_parallelism_property() {
    for_all("latency monotone in lanes", |g| {
        let model = *g.choice(&["alexnet", "vgg16"]);
        let flow = ComputationFlow::extract(&zoo::build(model, false).unwrap()).unwrap();
        let dev = *g.choice(&device::all());
        let space = OptionSpace::from_flow(&flow);
        let i = g.usize(0, space.ni.len() - 1);
        let j = g.usize(0, space.nl.len() - 1);
        if i + 1 < space.ni.len() {
            let a = simulate(&flow, dev, space.ni[i], space.nl[j]);
            let b = simulate(&flow, dev, space.ni[i + 1], space.nl[j]);
            assert!(
                b.total_cycles <= a.total_cycles,
                "{model} on {}: Ni {}->{} raised cycles",
                dev.name,
                space.ni[i],
                space.ni[i + 1]
            );
        }
    });
}

#[test]
fn estimator_feasibility_frontier_is_monotone_property() {
    // if (ni, nl) doesn't fit, nothing larger fits either
    for_all("infeasibility is upward-closed", |g| {
        let flow = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();
        let dev = *g.choice(&device::all());
        let th = Thresholds {
            lut: g.f64(20.0, 101.0),
            dsp: g.f64(20.0, 101.0),
            mem: g.f64(20.0, 101.0),
            reg: g.f64(20.0, 101.0),
        };
        let opts = [4usize, 8, 16, 32];
        let i = g.usize(0, opts.len() - 2);
        let j = g.usize(0, opts.len() - 2);
        let small = estimate(&flow, dev, opts[i], opts[j]);
        let big = estimate(&flow, dev, opts[i + 1], opts[j + 1]);
        if !small.fits(&th) {
            assert!(!big.fits(&th), "({},{}) fits but smaller doesn't", opts[i + 1], opts[j + 1]);
        }
    });
}

#[test]
fn rl_and_bf_agree_across_zoo_and_devices() {
    let th = Thresholds::default();
    for name in ["lenet5", "alexnet", "vgg16"] {
        let flow = ComputationFlow::extract(&zoo::build(name, false).unwrap()).unwrap();
        for dev in device::all() {
            let bf = brute::explore(&flow, dev, th);
            let rl = rl::explore(&flow, dev, th, RlConfig::default());
            assert_eq!(bf.best, rl.best, "{name} on {}", dev.name);
        }
    }
}

#[test]
fn exported_models_roundtrip_through_parser() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/models");
    if !dir.exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    for name in zoo::names() {
        let path = dir.join(format!("{name}.json"));
        let parsed = parser::parse_file(&path).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let built = zoo::build(name, false).unwrap();
        // same fused-round structure and op census on both sides
        let pf = ComputationFlow::extract(&parsed).unwrap();
        let bf = ComputationFlow::extract(&built).unwrap();
        assert_eq!(pf.layers.len(), bf.layers.len(), "{name}");
        assert_eq!(pf.conv_rounds(), bf.conv_rounds(), "{name}");
        assert!((pf.gops() - bf.gops()).abs() < 1e-9, "{name}");
        assert_eq!(parsed.param_count(), built.param_count(), "{name}");
    }
}

#[test]
fn failure_injection_corrupted_model_files() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/models");
    if !dir.exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let text = std::fs::read_to_string(dir.join("lenet5.json")).unwrap();
    // truncate: must error, not panic
    for cut in [10, 100, text.len() / 2] {
        let broken = &text[..cut];
        assert!(cnn2gate::util::json::Json::parse(broken).is_err());
    }
    // drop a node output name -> graph validation must fail
    let doc = cnn2gate::util::json::Json::parse(&text).unwrap();
    let mangled = text.replace("\"Softmax\"", "\"SoftMix\"");
    let bad = cnn2gate::util::json::Json::parse(&mangled).unwrap();
    assert!(parser::parse_doc(&bad, None).is_err());
    drop(doc);
}

fn sweep_job(models: &[&str]) -> CompileJob {
    CompileJob::builder()
        .models(models.iter().map(|m| zoo::build(m, false).unwrap()))
        .all_devices()
        .explorer(Explorer::BruteForce)
        .build()
        .unwrap()
}

#[test]
fn sweep_with_cache_file_is_warm_and_bit_identical() {
    // the acceptance shape: a second sweep session against a persisted
    // --cache-file must report >0 cache hits (and recompute nothing)
    // while rendering byte-identical ranking tables to the cold run
    use cnn2gate::report::{
        sweep_best_device_table, sweep_best_model_table, sweep_pareto_table, sweep_table,
    };

    let job = sweep_job(&["alexnet", "vgg16"]);
    let path = std::env::temp_dir().join(format!(
        "cnn2gate-sweep-cache-{}.json",
        std::process::id()
    ));

    let cold_session = Session::builder().threads(4).cache_file(&path).build();
    let cold = cold_session.run(&job).unwrap().to_sweep_report();
    // the work-stealing prewarm computes every candidate exactly once;
    // the explorer phase is then answered from the memo
    let cold_stats = cold_session.evaluator().cache().stats();
    assert!(cold_stats.misses > 0, "cold run must compute candidates");
    assert_eq!(cold_stats.misses, cold_stats.entries, "each unique candidate computed once");
    let save = cold_session.close().unwrap();
    assert!(save.written.unwrap().0 > 0);

    let warm_session = Session::builder().threads(4).cache_file(&path).build();
    assert!(warm_session.load_warning().is_none(), "our own file must load cleanly");
    let warm = warm_session.run(&job).unwrap().to_sweep_report();
    let stats = warm_session.evaluator().cache().stats();
    assert!(stats.hits > 0, "warm run must be served from the cache file");
    assert_eq!(stats.misses, 0, "nothing recomputed on a warm cache");

    assert_eq!(sweep_table(&warm).render(), sweep_table(&cold).render());
    assert_eq!(sweep_best_device_table(&warm).render(), sweep_best_device_table(&cold).render());
    assert_eq!(sweep_best_model_table(&warm).render(), sweep_best_model_table(&cold).render());
    assert_eq!(sweep_pareto_table(&warm).render(), sweep_pareto_table(&cold).render());
    std::fs::remove_file(&path).ok();
}

#[test]
fn sweep_cache_files_are_byte_identical_across_identical_runs() {
    // eviction determinism needs the stamps themselves to be
    // deterministic: two identical cold sweeps (racing phase-2
    // explorers included) must persist byte-identical cache files —
    // the post-sweep re-stamp pass, not thread scheduling, decides the
    // final LRU order
    let job = sweep_job(&["alexnet", "vgg16"]);
    let run = |tag: &str| {
        let path = std::env::temp_dir().join(format!(
            "cnn2gate-stamp-det-{}-{tag}.json",
            std::process::id()
        ));
        let session = Session::builder().threads(4).cache_file(&path).build();
        session.run(&job).unwrap();
        session.close().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        text
    };
    assert_eq!(run("a"), run("b"), "persisted LRU stamps must not depend on scheduling");
}

#[test]
fn stepped_full_sweep_round_trips_warm_and_byte_identical() {
    // the work-stealing sweep at full-network stepped fidelity, re-run
    // against its own cache file, recomputes nothing and reproduces
    // every table and every per-round census
    use cnn2gate::report::sweep_table;

    let job = sweep_job(&["lenet5"]);
    let path = std::env::temp_dir().join(format!(
        "cnn2gate-stepped-sweep-cache-{}.json",
        std::process::id()
    ));
    let cold_session = Session::builder()
        .threads(4)
        .fidelity(Fidelity::SteppedFullNetwork)
        .cache_file(&path)
        .build();
    let cold = cold_session.run(&job).unwrap().to_sweep_report();
    cold_session.close().unwrap();

    let warm_session = Session::builder()
        .threads(4)
        .fidelity(Fidelity::SteppedFullNetwork)
        .cache_file(&path)
        .build();
    let warm = warm_session.run(&job).unwrap().to_sweep_report();
    assert_eq!(warm_session.evaluator().cache().stats().misses, 0, "census served from disk");
    assert_eq!(sweep_table(&warm).render(), sweep_table(&cold).render());
    for (w, c) in warm.entries.iter().zip(&cold.entries) {
        assert_eq!(w.option(), c.option(), "{}", w.device);
        assert_eq!(w.stepped_network, c.stepped_network, "{}", w.device);
        if w.fits() {
            assert!(w.stepped_network.is_some(), "{}", w.device);
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn fleet_with_cache_file_round_trip() {
    let job = CompileJob::builder()
        .model(zoo::build("alexnet", false).unwrap())
        .all_devices()
        .explorer(Explorer::BruteForce)
        .build()
        .unwrap();
    let path = std::env::temp_dir().join(format!(
        "cnn2gate-fleet-cache-{}.json",
        std::process::id()
    ));
    let cold_session = Session::builder().threads(4).cache_file(&path).build();
    let cold = cold_session.run(&job).unwrap().to_fleet_report().unwrap();
    cold_session.close().unwrap();

    let warm_session = Session::builder().threads(4).cache_file(&path).build();
    let warm = warm_session.run(&job).unwrap().to_fleet_report().unwrap();
    assert!(warm_session.evaluator().cache().stats().hits > 0);
    assert_eq!(warm_session.evaluator().cache().stats().misses, 0);
    for (w, c) in warm.entries.iter().zip(&cold.entries) {
        assert_eq!(w.option(), c.option(), "{}", w.device);
        assert_eq!(w.dse.trace, c.dse.trace, "{}", w.device);
    }
    assert_eq!(warm.best().map(|b| b.device), cold.best().map(|b| b.device));
    std::fs::remove_file(&path).ok();
}

#[test]
fn paper_headline_numbers_cross_module() {
    // the single most important reproduction assertion, end to end:
    // AlexNet 18 ms / VGG 205 ms on the Arria 10 at the DSE-chosen option
    let dev = device::find("arria10").unwrap();
    let rep = solo("alexnet", false, dev, Explorer::Reinforcement);
    assert_eq!(rep.option(), Some((16, 32)));
    let ms = rep.latency_ms().unwrap();
    assert!((ms - 18.24).abs() / 18.24 < 0.12, "AlexNet {ms} ms");
    let repv = solo("vgg16", false, dev, Explorer::Reinforcement);
    let msv = repv.latency_ms().unwrap();
    assert!((msv - 205.0).abs() / 205.0 < 0.17, "VGG {msv} ms");
}
