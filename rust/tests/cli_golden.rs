//! CLI-process-level golden tests (ROADMAP follow-up (e)): spawn the
//! built `cnn2gate` binary for `synth --json` and `sweep --json` and pin
//! its stdout BYTES two ways:
//!
//! 1. against the in-process [`Outcome::to_json`] document for the
//!    equivalent job — which pins the CLI adapter layer (flag parsing,
//!    session construction, the `print!` path, stderr/stdout routing)
//!    that the Outcome-level golden in `tests/session.rs` cannot see;
//! 2. against committed golden files, regenerable with
//!    `CNN2GATE_UPDATE_GOLDENS=1 cargo test --test cli_golden`.
//!
//! The tiny zoo model keeps the documents small and the runs fast; the
//! `--explorer bf` grid keeps them free of RNG state.

use std::path::Path;
use std::process::Command;

use cnn2gate::estimator::device;
use cnn2gate::onnx::zoo;
use cnn2gate::session::{CompileJob, Session};
use cnn2gate::synth::Explorer;
use cnn2gate::util::json::Json;

fn run_cli(args: &[&str]) -> (String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_cnn2gate"))
        .args(args)
        .output()
        .expect("spawn the cnn2gate binary");
    assert!(
        out.status.success(),
        "cnn2gate {args:?} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    (
        String::from_utf8(out.stdout).expect("stdout is UTF-8"),
        String::from_utf8(out.stderr).expect("stderr is UTF-8"),
    )
}

/// Compare against (or regenerate) a committed golden file.
fn check_golden(name: &str, got: &str) {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden").join(name);
    if std::env::var("CNN2GATE_UPDATE_GOLDENS").is_ok() {
        std::fs::write(&path, got).unwrap();
    }
    let want = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("golden file {name} must be committed: {e}"));
    assert_eq!(
        got, want,
        "{name} drifted (CNN2GATE_UPDATE_GOLDENS=1 regenerates the goldens)"
    );
}

#[test]
fn synth_json_process_output_is_the_outcome_document() {
    let (stdout, stderr) = run_cli(&[
        "synth",
        "--model",
        "tiny",
        "--device",
        "arria10",
        "--explorer",
        "bf",
        "--json",
    ]);
    assert!(stderr.is_empty(), "no notes expected without a cache file: {stderr}");
    // the adapter pin: the process's stdout is EXACTLY the in-process
    // outcome document for the equivalent job, byte for byte
    let session = Session::builder().build();
    let job = CompileJob::builder()
        .model(zoo::build("tiny", false).unwrap())
        .device(&device::ARRIA_10_GX1150)
        .explorer(Explorer::BruteForce)
        .build()
        .unwrap();
    let expected = session.run(&job).unwrap().to_json().to_string_pretty();
    assert_eq!(stdout, expected, "CLI adapter drifted from Outcome::to_json");
    // stdout stays machine-parseable on its own
    Json::parse(&stdout).expect("CLI stdout parses as JSON");
    check_golden("synth_tiny_arria10.json", &stdout);
}

#[test]
fn sweep_json_process_output_is_the_outcome_document() {
    let (stdout, stderr) = run_cli(&["sweep", "--models", "tiny", "--explorer", "bf", "--json"]);
    assert!(stderr.is_empty(), "no notes expected without a cache file: {stderr}");
    let session = Session::builder().build();
    let job = CompileJob::builder()
        .model(zoo::build("tiny", false).unwrap())
        .all_devices()
        .explorer(Explorer::BruteForce)
        .build()
        .unwrap();
    let expected = session.run(&job).unwrap().to_json().to_string_pretty();
    assert_eq!(stdout, expected, "CLI adapter drifted from Outcome::to_json");
    check_golden("sweep_tiny.json", &stdout);
}

#[test]
fn cli_json_runs_are_byte_deterministic_across_processes() {
    // two independent processes (separate memo, separate scheduler
    // timing) must emit identical bytes — the cold/warm stability the
    // --json contract promises, at process granularity
    let args = ["sweep", "--models", "tiny", "--explorer", "bf", "--json"];
    let (a, _) = run_cli(&args);
    let (b, _) = run_cli(&args);
    assert_eq!(a, b);
}
