//! Cache-store integration tests: the sharded, append-only
//! [`CacheStore`] under the conditions the in-process unit tests can't
//! reach from inside the crate — two concurrent writers appending to
//! one shard under the advisory lock, a torn final delta record left
//! by a crashed writer, the compaction crash window replayed against a
//! restored delta log, legacy `--cache-file` migration, and the
//! acceptance pin: a sweep served warm from the store reproduces the
//! cold outcome document and tables byte-for-byte.

use std::path::{Path, PathBuf};

use cnn2gate::dse::{CacheStore, EvalCache, EvalRequest, Fidelity};
use cnn2gate::estimator::device;
use cnn2gate::ir::ComputationFlow;
use cnn2gate::onnx::zoo;
use cnn2gate::report::{
    sweep_best_device_table, sweep_best_model_table, sweep_pareto_table, sweep_table,
};
use cnn2gate::session::{CompileJob, Outcome, Session};
use cnn2gate::synth::Explorer;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cnn2gate-store-it-{}-{tag}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Warm `cache` with the tiny-model analytical grid at each batch size
/// — 3 entries per batch, all landing in ONE (tenant, model) shard.
fn warm(cache: &EvalCache, batches: &[usize]) {
    let g = zoo::build("tiny", false).unwrap();
    let flow = ComputationFlow::extract(&g).unwrap();
    for &b in batches {
        for (ni, nl) in [(2usize, 2usize), (4, 4), (4, 8)] {
            cache.get_or_compute(
                &flow,
                &device::CYCLONE_V_5CSEMA5,
                ni,
                nl,
                EvalRequest::at(Fidelity::Analytical).batched(b),
            );
        }
    }
}

/// The single shard's (base, delta) paths — fails if the store holds
/// more than one shard.
fn shard_paths(dir: &Path) -> (PathBuf, PathBuf) {
    let mut bases: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            let name = p.file_name().unwrap().to_string_lossy().into_owned();
            name.ends_with(".jsonl") && !name.ends_with(".delta.jsonl")
        })
        .collect();
    assert_eq!(bases.len(), 1, "expected exactly one shard base in {}", dir.display());
    let base = bases.pop().unwrap();
    let name = base.file_name().unwrap().to_string_lossy().into_owned();
    let delta = base.with_file_name(name.replace(".jsonl", ".delta.jsonl"));
    (base, delta)
}

#[test]
fn two_writers_append_to_one_shard_under_the_lock() {
    let dir = tmp_dir("two-writers");

    // seed the shard so both writers open a shared base
    let seed = CacheStore::open(&dir);
    assert!(seed.warnings.is_empty(), "{:?}", seed.warnings);
    warm(&seed.cache, &[1]);
    let first = seed.store.save(&seed.cache).unwrap();
    assert_eq!(first.rewritten, 1);
    assert_eq!(first.entries, 3);

    // two independent handles — a serve daemon and a CLI sweep — each
    // warmed with disjoint batch sizes, saving concurrently: the
    // advisory lock serializes the appends, and neither writer may
    // tombstone entries the other added after its snapshot
    let a = CacheStore::open(&dir);
    let b = CacheStore::open(&dir);
    assert_eq!(a.cache.stats().entries, 3);
    assert_eq!(b.cache.stats().entries, 3);
    std::thread::scope(|scope| {
        let ta = scope.spawn(|| {
            warm(&a.cache, &[2]);
            a.store.save(&a.cache).unwrap()
        });
        let tb = scope.spawn(|| {
            warm(&b.cache, &[3]);
            b.store.save(&b.cache).unwrap()
        });
        let (sa, sb) = (ta.join().unwrap(), tb.join().unwrap());
        assert_eq!(sa.tombstones, 0, "writer A tombstoned a peer's entries");
        assert_eq!(sb.tombstones, 0, "writer B tombstoned a peer's entries");
        assert!(sa.appended >= 3 && sb.appended >= 3);
        assert_eq!(sa.rewritten + sb.rewritten, 0, "existing shard must append, not rewrite");
    });

    let merged = CacheStore::open(&dir);
    assert!(merged.warnings.is_empty(), "{:?}", merged.warnings);
    assert_eq!(merged.cache.stats().entries, 9, "union of the base and both writers' deltas");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn torn_final_delta_record_recovers_the_prefix_loudly() {
    let dir = tmp_dir("torn");
    let seed = CacheStore::open(&dir);
    warm(&seed.cache, &[1]);
    seed.store.save(&seed.cache).unwrap(); // base: 3 entries

    let writer = CacheStore::open(&dir);
    warm(&writer.cache, &[2, 3]); // 6 delta puts
    let saved = writer.store.save(&writer.cache).unwrap();
    assert!(saved.appended >= 6, "{saved:?}");

    // crash mid-append: chop into the middle of the LAST delta record
    let (_, delta) = shard_paths(&dir);
    let bytes = std::fs::read(&delta).unwrap();
    std::fs::write(&delta, &bytes[..bytes.len() - 10]).unwrap();

    // strict load drops exactly the torn record, keeps the prefix, and
    // says so out loud
    let torn = CacheStore::open(&dir);
    assert_eq!(torn.warnings.len(), 1, "{:?}", torn.warnings);
    assert!(torn.warnings[0].contains("torn"), "{}", torn.warnings[0]);
    assert_eq!(torn.cache.stats().entries, 8, "base 3 + 5 recovered delta records");

    // the next exclusive save trims the torn tail before appending, so
    // a reopen is clean
    warm(&torn.cache, &[4]);
    torn.store.save(&torn.cache).unwrap();
    let healed = CacheStore::open(&dir);
    assert!(healed.warnings.is_empty(), "{:?}", healed.warnings);
    assert_eq!(healed.cache.stats().entries, 11);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn compaction_crash_window_replays_idempotently() {
    let dir = tmp_dir("compact-crash");
    let seed = CacheStore::open(&dir);
    warm(&seed.cache, &[1]);
    seed.store.save(&seed.cache).unwrap();

    // a second generation: 3 new puts and 2 tombstones in the delta
    let writer = CacheStore::open(&dir);
    warm(&writer.cache, &[2]);
    let evicted = writer.cache.evict_lru(4);
    assert_eq!(evicted, 2);
    let saved = writer.store.save(&writer.cache).unwrap();
    assert!(saved.appended >= 3 && saved.tombstones == 2, "{saved:?}");

    let (base, delta) = shard_paths(&dir);
    let delta_bytes = std::fs::read(&delta).unwrap();
    let reader = CacheStore::open(&dir);
    assert!(reader.warnings.is_empty(), "{:?}", reader.warnings);
    let pre_entries = reader.cache.stats().entries;
    assert_eq!(pre_entries, 4);

    // compact, then restore the delta log — the crash window between
    // the canonical base rename and the delta removal
    assert_eq!(reader.store.compact_all().unwrap(), 1);
    let canonical = std::fs::read(&base).unwrap();
    assert!(!delta.exists(), "compaction folds the delta away");
    std::fs::write(&delta, &delta_bytes).unwrap();

    // replaying the stale delta over the canonical base is idempotent:
    // puts upsert to identical payloads, dels tolerate absent keys
    let replay = CacheStore::open(&dir);
    assert!(replay.warnings.is_empty(), "{:?}", replay.warnings);
    assert_eq!(replay.cache.stats().entries, pre_entries);

    // recompacting reproduces the canonical bytes exactly
    assert_eq!(replay.store.compact_all().unwrap(), 1);
    assert_eq!(std::fs::read(&base).unwrap(), canonical, "recompaction drifted");
    assert!(!delta.exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn legacy_cache_file_migrates_into_the_store() {
    let file = std::env::temp_dir()
        .join(format!("cnn2gate-store-it-{}-legacy.json", std::process::id()));
    let legacy = EvalCache::new();
    warm(&legacy, &[1]);
    assert_eq!(legacy.save(&file).unwrap(), 3);
    let legacy_bytes = std::fs::read(&file).unwrap();

    // --cache-dir + --cache-file: the store absorbs the v5 entries and
    // owns persistence from here on; the legacy file is never rewritten
    let dir = tmp_dir("migrate");
    let session = Session::builder().cache_dir(&dir).cache_file(&file).build();
    assert!(session.load_warning().is_none());
    assert_eq!(session.evaluator().cache().stats().entries, 3);
    let save = session.close().unwrap();
    let (saved, _) = save.store.expect("a cache-dir session persists through the store");
    assert_eq!(saved.entries, 3);
    assert!(save.written.is_none(), "deprecated single-file save path ran alongside the store");
    assert_eq!(std::fs::read(&file).unwrap(), legacy_bytes, "legacy file was rewritten");

    // the store alone now serves the migrated entries
    let migrated = CacheStore::open(&dir);
    assert!(migrated.warnings.is_empty(), "{:?}", migrated.warnings);
    assert_eq!(migrated.cache.stats().entries, 3);
    std::fs::remove_file(&file).ok();
    std::fs::remove_dir_all(&dir).ok();
}

fn sweep_tables(outcome: &Outcome) -> String {
    let rep = outcome.to_sweep_report();
    format!(
        "{}{}{}{}",
        sweep_table(&rep).render(),
        sweep_best_device_table(&rep).render(),
        sweep_best_model_table(&rep).render(),
        sweep_pareto_table(&rep).render()
    )
}

#[test]
fn warm_store_sweep_reproduces_cold_outcome_byte_for_byte() {
    let dir = tmp_dir("warm-sweep");
    let job = CompileJob::builder()
        .models([zoo::build("tiny", false).unwrap(), zoo::build("lenet5", false).unwrap()])
        .all_devices()
        .explorer(Explorer::BruteForce)
        .build()
        .unwrap();

    let cold_session = Session::builder().threads(2).cache_dir(&dir).build();
    assert!(cold_session.load_warning().is_none());
    let cold_outcome = cold_session.run(&job).unwrap();
    let cold_json = cold_outcome.to_json().to_string_pretty();
    let cold_tables = sweep_tables(&cold_outcome);
    let save = cold_session.close().unwrap();
    let (saved, path) = save.store.expect("cache-dir session persists through the store");
    assert!(saved.entries > 0 && saved.rewritten >= 1, "{saved:?}");
    assert_eq!(path, dir);

    // warm: every evaluation comes off disk, and both the machine
    // document and the rendered tables are byte-identical to cold
    let warm_session = Session::builder().threads(2).cache_dir(&dir).build();
    assert!(warm_session.load_warning().is_none());
    let warm_outcome = warm_session.run(&job).unwrap();
    assert_eq!(warm_session.evaluator().cache().stats().misses, 0, "store-warm run recomputed");
    assert_eq!(warm_outcome.to_json().to_string_pretty(), cold_json);
    assert_eq!(sweep_tables(&warm_outcome), cold_tables);

    // first close persists the warm run's LRU stamp bumps; a second
    // close with nothing changed touches no shard file at all
    warm_session.close().unwrap();
    let idle = warm_session.close().unwrap();
    let (idle_saved, _) = idle.store.unwrap();
    assert_eq!(
        idle_saved.appended + idle_saved.tombstones + idle_saved.rewritten + idle_saved.compacted,
        0,
        "an untouched store must be zero shard I/O: {idle_saved:?}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
