//! Emulation-mode integration tests over the PJRT runtime: the contract
//! between the Python AOT path and the Rust request path, exercised via
//! goldens and the batched server. These are the tests that prove the
//! three-layer architecture composes (Pallas kernel → JAX model → HLO →
//! PJRT → coordinator).

use std::path::{Path, PathBuf};

use cnn2gate::coordinator::pipeline;
use cnn2gate::coordinator::{InferenceServer, ServiceConfig};
use cnn2gate::ir::DType;
use cnn2gate::onnx::parser;
use cnn2gate::runtime::{load_golden, Manifest, Runtime, Tensor};

fn artifacts_dir() -> Option<PathBuf> {
    if !Runtime::available() {
        return None; // stub build: artifacts exist but can't replay
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts`");
                return;
            }
        }
    };
}

#[test]
fn all_goldens_replay_through_pjrt() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut replayed = 0;
    for art in &manifest.models {
        let Some(golden) = &art.golden else { continue };
        let golden = load_golden(golden).unwrap();
        let compiled = rt.load_artifact(art).unwrap();
        let mut inputs = vec![golden.input.clone()];
        inputs.extend(golden.params.iter().cloned());
        let out_dtype = if art.quantization.is_some() {
            DType::I32
        } else {
            DType::F32
        };
        let out = compiled.run(&inputs, out_dtype).unwrap();
        match (&out.tensor, &golden.expected) {
            (Tensor::F32(_, got), Tensor::F32(_, want)) => {
                for (g, w) in got.iter().zip(want) {
                    assert!((g - w).abs() < 1e-4, "{}: {g} vs {w}", art.name);
                }
            }
            (Tensor::I32(_, got), Tensor::I32(_, want)) => {
                assert_eq!(got, want, "{}: int8 path must be exact", art.name);
            }
            _ => panic!("{}: dtype mismatch", art.name),
        }
        replayed += 1;
    }
    assert!(replayed >= 4, "expected ≥4 goldens, replayed {replayed}");
}

#[test]
fn emulation_is_deterministic() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let art = manifest.model("lenet5").unwrap();
    let golden = load_golden(art.golden.as_ref().unwrap()).unwrap();
    let rt = Runtime::cpu().unwrap();
    let compiled = rt.load_artifact(art).unwrap();
    let mut inputs = vec![golden.input.clone()];
    inputs.extend(golden.params.iter().cloned());
    let a = compiled.run(&inputs, DType::F32).unwrap();
    let b = compiled.run(&inputs, DType::F32).unwrap();
    assert_eq!(a.tensor, b.tensor);
}

#[test]
fn parsed_weights_equal_golden_weights() {
    // aot.py exports the ONNX-subset weights with the same seed it used
    // for the goldens: the two independent paths must agree bit-for-bit.
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let art = manifest.model("lenet5").unwrap();
    let golden = load_golden(art.golden.as_ref().unwrap()).unwrap();
    let graph = parser::parse_file(&dir.join("models/lenet5.json")).unwrap();
    for (spec, gold) in art.params.iter().zip(&golden.params) {
        let parsed = graph.initializers[&spec.name].data.as_ref().unwrap();
        assert_eq!(parsed, gold.as_f32().unwrap(), "{}", spec.name);
    }
}

#[test]
fn server_batching_respects_max_batch() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let art = manifest.model("tiny").unwrap();
    let golden = load_golden(art.golden.as_ref().unwrap()).unwrap();
    let server = InferenceServer::start(
        art,
        golden.params.clone(),
        ServiceConfig {
            max_batch: 4,
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    for _ in 0..16 {
        server.infer(golden.input.clone()).unwrap();
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 16);
    // sequential submission can't force batches > max_batch
    assert!(stats.batches >= 16 / 4);
}

#[test]
fn synthetic_emulation_timing_is_positive_and_stable() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let art = manifest.model("lenet5").unwrap();
    let a = pipeline::time_emulation_synthetic(art, 3).unwrap();
    assert!(a > 0.0 && a < 5.0, "lenet5 frame {a} s");
}

#[test]
fn corrupted_golden_detected() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let art = manifest.model("tiny").unwrap();
    let mut g = art.golden.clone().unwrap();
    g.nbytes += 1; // size mismatch must be caught, not mis-sliced
    assert!(load_golden(&g).is_err());
    let mut g2 = art.golden.clone().unwrap();
    g2.arrays[0].offset = usize::MAX - 3;
    assert!(load_golden(&g2).is_err());
}
