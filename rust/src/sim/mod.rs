//! Cycle-level simulator of the deeply pipelined OpenCL kernel
//! architecture (paper §3.2, Fig. 3c/5) — the stand-in for FPGA
//! execution that regenerates Table 1 and Fig. 6.

pub mod engine;
pub mod kernels;
pub mod pipe;

pub use engine::{
    simulate, simulate_batched, simulate_layer, simulate_with_estimate, BatchReport, LayerTiming,
    SimReport,
};
pub use kernels::{
    analytical_cycles, bytes_per_step_with_reuse, ddr_credit_rate, dominant_round_work,
    dominant_round_work_batched, layer_round_work, layer_round_work_batched, network_round_work,
    network_round_work_batched, schedule_tag, scheduled_round_work, scheduled_round_work_batched,
    slice_resident_allowed, step_network, step_network_batched, step_round, step_round_reference,
    NetworkStepReport, RoundWork, StepReport, WeightSchedule,
};
pub use pipe::Pipe;
