//! Cycle-level simulator of the deeply pipelined OpenCL kernel
//! architecture (paper §3.2, Fig. 3c/5) — the stand-in for FPGA
//! execution that regenerates Table 1 and Fig. 6.

pub mod engine;
pub mod kernels;
pub mod pipe;

pub use engine::{
    simulate, simulate_batched, simulate_layer, simulate_with_estimate, BatchReport, LayerTiming,
    SimReport,
};
pub use kernels::{analytical_cycles, dominant_round_work, step_round, RoundWork, StepReport};
pub use pipe::Pipe;
