//! Cycle-level simulator of the deeply pipelined OpenCL kernel
//! architecture (paper §3.2, Fig. 3c/5) — the stand-in for FPGA
//! execution that regenerates Table 1 and Fig. 6.
//!
//! Rounds are stepped by the epoch skip-ahead engine
//! ([`kernels::step_round`]), bit-identical to the naive per-cycle
//! oracle ([`kernels::step_round_reference`]). Residual Add-merge
//! rounds are dual-feed: one read port alternates between the two
//! producer streams (fetching into whichever feed is further behind),
//! the conv stage consumes one token from each feed per step, and the
//! census attributes starvation per branch
//! (`feed_a_empty_stalls`/`feed_b_empty_stalls`). Single-feed rounds
//! (`feed2_bytes_per_step == 0`) take the pre-DAG code path verbatim,
//! so linear-chain censuses are byte-for-byte unchanged.

pub mod engine;
pub mod kernels;
pub mod pipe;

pub use engine::{
    simulate, simulate_batched, simulate_layer, simulate_with_estimate, BatchReport, LayerTiming,
    SimReport,
};
pub use kernels::{
    analytical_cycles, bytes_per_step_with_reuse, ddr_credit_rate, dominant_round_work,
    dominant_round_work_batched, layer_round_work, layer_round_work_batched, network_round_work,
    network_round_work_batched, schedule_tag, scheduled_round_work, scheduled_round_work_batched,
    slice_resident_allowed, step_network, step_network_batched, step_round, step_round_reference,
    NetworkStepReport, RoundWork, StepReport, WeightSchedule,
};
pub use pipe::Pipe;
