//! Cycle-stepped simulation of one pipelined round (paper Fig. 3c/5).
//!
//! Four stages — memory read, conv lane array, pool, memory write —
//! connected by [`Pipe`]s, stepped one kernel clock at a time in vector
//! granularity: a token is one `N_i`-wide vector MAC's worth of work on
//! the conv pipe, one output element per lane elsewhere.
//!
//! Two engines share the same cycle semantics:
//!
//! * [`step_round_reference`] — the naive oracle: one loop iteration per
//!   kernel cycle over real [`Pipe`]s. Millions of iterations for an
//!   AlexNet-conv2-class round; kept as the ground truth the fast engine
//!   is validated against.
//! * [`step_round`] — the **epoch skip-ahead** engine. Between
//!   state-change events (a pipe filling or draining, the DDR credit
//!   counter crossing a transaction boundary, a stream exhausting its
//!   tokens) the four-stage pipeline settles into a steady state: the
//!   per-cycle transition is a deterministic function of the compact
//!   state `(feed occupancy, out occupancy, reduction phase, held slice,
//!   DDR credit)`, so the orbit is eventually periodic. The engine steps
//!   naively while recording the compact state at write-retire cycles;
//!   on the first exact recurrence it has an epoch length and per-epoch
//!   census deltas, and fast-forwards whole epochs in closed form (one
//!   multiply per counter) while keeping a full epoch of headroom to
//!   every end-of-round boundary — which makes the skip provably
//!   bit-identical to the reference, stall counters included. The
//!   property and adversarial tests below enforce that identity.
//!
//! DDR credit is modeled at whole-byte granularity ([`ddr_whole_bytes`]):
//! the credit arithmetic is exact integer math in both engines, which is
//! what makes steady-state recurrence detectable (and is a better model
//! of a byte-granular bus than fractional f64 credit was — the seed's
//! per-cycle float accumulation never bit-repeats for incommensurate
//! rates).
//!
//! This stepping model is the ground truth the analytical round model in
//! [`super::engine`] is validated against (property test: the two agree
//! within a few percent on randomized small rounds). Table-scale runs use
//! the analytical model so regenerating the paper's tables stays
//! interactive; the stepper also feeds the per-layer stall/backpressure
//! census reported by `cnn2gate synth --report` (see [`step_network`]).

use std::collections::HashMap;

use crate::estimator::model::PIPE_DEPTH;
use crate::estimator::Device;
use crate::ir::{ComputationFlow, FusedLayer};

use super::pipe::Pipe;

/// Work description of one round at vector granularity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundWork {
    /// Output pixels (OH*OW for conv rounds, 1 for FC).
    pub pixels: usize,
    /// Output-feature groups: ceil(out_features / N_l).
    pub groups: usize,
    /// Reduction steps per output: ceil(reduction_dim / N_i).
    pub red_steps: usize,
    /// Bytes the memory-read kernel must fetch per reduction step
    /// (feature vector broadcast + per-lane weight vectors).
    pub bytes_per_step: usize,
    /// DDR bytes deliverable per cycle at the kernel clock (quantized to
    /// whole bytes by the steppers — see [`ddr_whole_bytes`]).
    pub ddr_bytes_per_cycle: f64,
    /// Output bytes written per (pixel, group) completion.
    pub out_bytes: usize,
}

/// Per-stage cycle/stall census from a stepped run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StepReport {
    pub cycles: u64,
    pub rd_busy: u64,
    pub conv_busy: u64,
    pub wr_busy: u64,
    pub rd_to_conv_full_stalls: u64,
    pub conv_to_wr_full_stalls: u64,
    pub conv_empty_stalls: u64,
}

impl StepReport {
    pub fn conv_utilization(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.conv_busy as f64 / self.cycles as f64
    }
}

/// DDR bytes per cycle at whole-byte granularity: the exact integer
/// credit quantum both steppers run on. Clamped to ≥ 1 so a nonzero
/// bandwidth always makes progress.
pub fn ddr_whole_bytes(bytes_per_cycle: f64) -> u64 {
    let r = bytes_per_cycle.round();
    if r.is_finite() && r >= 1.0 {
        r as u64
    } else {
        1
    }
}

/// Step one round to completion and return the census — the epoch
/// skip-ahead engine (see the module docs). Bit-identical to
/// [`step_round_reference`], enforced by the property tests below.
///
/// Stage behaviour per cycle (shared by both engines):
/// * mem_write: if the output pipe holds a slice and DDR credit covers
///   `out_bytes`, retire it (writes drain credit first: the pipeline can
///   always retire).
/// * conv: a completed group-slice the output pipe refused is *held* by
///   the lane array and re-offered before any new work is accepted (the
///   lanes stall, counting `conv_to_wr_full_stalls`); otherwise pop one
///   vector token; after `red_steps` tokens a group-slice (N_l elements)
///   is complete and pushed to the pool pipe.
/// * mem_read: if DDR credit covers `bytes_per_step` and the feed pipe
///   has room, produce one vector token.
pub fn step_round(work: &RoundWork) -> StepReport {
    let total_outputs = (work.pixels * work.groups) as u64;
    let total_steps = total_outputs * work.red_steps as u64;
    let pipe_cap = PIPE_DEPTH.max(1) as u64;
    let bw = ddr_whole_bytes(work.ddr_bytes_per_cycle);
    let bps = work.bytes_per_step as u64;
    let ob = work.out_bytes as u64;
    // credit does not accumulate indefinitely (DDR can't time-travel),
    // but the cap must admit the largest single transaction or a slow
    // bus could never complete it
    let cap = (8 * bw).max(2 * bps.max(ob));

    let mut rep = StepReport::default();
    let mut produced = 0u64;
    let mut consumed = 0u64;
    let mut emitted = 0u64;
    let mut written = 0u64;
    let mut red_progress = 0u64;
    let mut pending_slice = false;
    let mut feed_len = 0u64;
    let mut out_len = 0u64;
    let mut credit = 0u64;

    let mut seen: HashMap<EpochKey, EpochSnap> = HashMap::new();

    while written < total_outputs {
        rep.cycles += 1;
        credit += bw;

        // -- memory write --
        let mut wrote = false;
        if out_len > 0 && credit >= ob {
            out_len -= 1;
            written += 1;
            credit -= ob;
            rep.wr_busy += 1;
            wrote = true;
        }

        // -- conv lane array --
        if pending_slice {
            if out_len < pipe_cap {
                out_len += 1;
                emitted += 1;
                pending_slice = false;
            } else {
                rep.conv_to_wr_full_stalls += 1;
            }
        }
        if !pending_slice && consumed < total_steps {
            if feed_len > 0 {
                feed_len -= 1;
                consumed += 1;
                red_progress += 1;
                rep.conv_busy += 1;
                if red_progress == work.red_steps as u64 {
                    red_progress = 0;
                    if out_len < pipe_cap {
                        out_len += 1;
                        emitted += 1;
                    } else {
                        pending_slice = true;
                        rep.conv_to_wr_full_stalls += 1;
                    }
                }
            } else {
                rep.conv_empty_stalls += 1;
            }
        }

        // -- memory read --
        if produced < total_steps && credit >= bps {
            if feed_len < pipe_cap {
                feed_len += 1;
                produced += 1;
                credit -= bps;
                rep.rd_busy += 1;
            } else {
                rep.rd_to_conv_full_stalls += 1;
            }
        }

        credit = credit.min(cap);

        // -- epoch skip-ahead ------------------------------------------------
        // Anchor on write-retire cycles only: every steady state retires
        // outputs, and anchoring there keeps the recurrence map tiny.
        if !wrote || written >= total_outputs {
            continue;
        }
        let key = EpochKey {
            feed: feed_len as u32,
            out: out_len as u32,
            red: red_progress as u32,
            pending: pending_slice,
            credit,
        };
        let Some(&prev) = seen.get(&key) else {
            if seen.len() >= EPOCH_WINDOW {
                seen.clear();
            }
            seen.insert(
                key,
                EpochSnap {
                    cycles: rep.cycles,
                    rd_busy: rep.rd_busy,
                    conv_busy: rep.conv_busy,
                    wr_busy: rep.wr_busy,
                    rd_to_conv: rep.rd_to_conv_full_stalls,
                    conv_to_wr: rep.conv_to_wr_full_stalls,
                    conv_empty: rep.conv_empty_stalls,
                    produced,
                    consumed,
                    emitted,
                    written,
                },
            );
            continue;
        };
        // The compact state recurred: the cycles since the snapshot are
        // one epoch, and (while every stream stays strictly inside its
        // end-of-round boundary) the pipeline will replay it verbatim.
        // Fast-forward k whole epochs, keeping one epoch of headroom to
        // every boundary so each skipped predicate evaluation provably
        // matches the reference's.
        let d_written = written - prev.written;
        if d_written == 0 {
            continue;
        }
        let d_produced = produced - prev.produced;
        let d_consumed = consumed - prev.consumed;
        let d_emitted = emitted - prev.emitted;
        let mut k = ((total_outputs - written) / d_written).saturating_sub(1);
        if d_produced > 0 {
            k = k.min(((total_steps - produced) / d_produced).saturating_sub(1));
        }
        if d_consumed > 0 {
            k = k.min(((total_steps - consumed) / d_consumed).saturating_sub(1));
        }
        if d_emitted > 0 {
            k = k.min(((total_outputs - emitted) / d_emitted).saturating_sub(1));
        }
        if k == 0 {
            continue;
        }
        rep.cycles += (rep.cycles - prev.cycles) * k;
        rep.rd_busy += (rep.rd_busy - prev.rd_busy) * k;
        rep.conv_busy += (rep.conv_busy - prev.conv_busy) * k;
        rep.wr_busy += (rep.wr_busy - prev.wr_busy) * k;
        rep.rd_to_conv_full_stalls += (rep.rd_to_conv_full_stalls - prev.rd_to_conv) * k;
        rep.conv_to_wr_full_stalls += (rep.conv_to_wr_full_stalls - prev.conv_to_wr) * k;
        rep.conv_empty_stalls += (rep.conv_empty_stalls - prev.conv_empty) * k;
        produced += d_produced * k;
        consumed += d_consumed * k;
        emitted += d_emitted * k;
        written += d_written * k;
        // the census jumped: stale snapshots would compute wrong deltas
        seen.clear();
    }
    rep
}

/// Largest number of anchor states the skip-ahead engine remembers
/// before restarting detection (bounds memory; epochs longer than this
/// many write-retires fall back to naive stepping, which is still
/// correct, just not fast).
const EPOCH_WINDOW: usize = 1 << 16;

/// Compact pipeline state at a write-retire cycle. Exact recurrence of
/// this key (integer credit included) means the steady state repeats.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct EpochKey {
    feed: u32,
    out: u32,
    red: u32,
    pending: bool,
    credit: u64,
}

/// Census + stream counters at an anchor, for per-epoch deltas.
#[derive(Debug, Clone, Copy)]
struct EpochSnap {
    cycles: u64,
    rd_busy: u64,
    conv_busy: u64,
    wr_busy: u64,
    rd_to_conv: u64,
    conv_to_wr: u64,
    conv_empty: u64,
    produced: u64,
    consumed: u64,
    emitted: u64,
    written: u64,
}

/// The naive per-cycle oracle the skip-ahead engine is validated
/// against: one loop iteration per kernel cycle over real [`Pipe`]s.
/// Same cycle semantics as [`step_round`] (see there), ~1000x slower on
/// round-scale work.
pub fn step_round_reference(work: &RoundWork) -> StepReport {
    let total_outputs = work.pixels * work.groups; // group-slices to emit
    let total_steps = total_outputs * work.red_steps; // vector MACs
    let mut feed = Pipe::new("rd->conv", PIPE_DEPTH.max(1));
    let mut out = Pipe::new("conv->wr", PIPE_DEPTH.max(1));
    let mut rep = StepReport::default();

    let bw = ddr_whole_bytes(work.ddr_bytes_per_cycle);
    let bps = work.bytes_per_step as u64;
    let ob = work.out_bytes as u64;
    let cap = (8 * bw).max(2 * bps.max(ob));

    let mut produced_steps = 0usize; // vectors fetched
    let mut consumed_steps = 0usize; // vectors MACed
    let mut emitted = 0usize; // group-slices pushed
    let mut written = 0usize; // group-slices written back
    let mut red_progress = 0usize;
    let mut pending_slice = false; // completed slice held by the lanes
    let mut ddr_credit = 0u64; // whole bytes available this cycle

    while written < total_outputs {
        rep.cycles += 1;
        ddr_credit += bw;

        // -- memory write (drains DDR credit first: writes have priority
        //    so the pipeline can always retire) --
        if !out.is_empty() && ddr_credit >= ob {
            out.pop();
            written += 1;
            ddr_credit -= ob;
            rep.wr_busy += 1;
        }

        // -- conv lane array: re-offer a held slice before new work --
        if pending_slice {
            if out.push(emitted as u64) {
                emitted += 1;
                pending_slice = false;
            } else {
                rep.conv_to_wr_full_stalls += 1;
            }
        }
        if !pending_slice && consumed_steps < total_steps {
            if let Some(_tok) = feed.pop() {
                consumed_steps += 1;
                red_progress += 1;
                rep.conv_busy += 1;
                if red_progress == work.red_steps {
                    red_progress = 0;
                    if out.push(emitted as u64) {
                        emitted += 1;
                    } else {
                        // output pipe full: the lane array holds the
                        // completed slice and stalls until accepted
                        pending_slice = true;
                        rep.conv_to_wr_full_stalls += 1;
                    }
                }
            } else {
                rep.conv_empty_stalls += 1;
            }
        }

        // -- memory read --
        if produced_steps < total_steps && ddr_credit >= bps {
            if feed.push(produced_steps as u64) {
                produced_steps += 1;
                ddr_credit -= bps;
                rep.rd_busy += 1;
            } else {
                rep.rd_to_conv_full_stalls += 1;
            }
        }

        ddr_credit = ddr_credit.min(cap);
    }
    rep
}

/// The [`RoundWork`] of one fused round at option (N_i, N_l). One vector
/// step fetches `N_i` feature bytes broadcast to the lanes plus
/// `N_i × N_l` weight bytes (int8 codes); each completed group-slice
/// retires `N_l` output bytes.
pub fn layer_round_work(
    layer: &FusedLayer,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
) -> RoundWork {
    RoundWork {
        pixels: layer.out_pixels().max(1),
        groups: layer.out_features().div_ceil(nl).max(1),
        red_steps: layer.reduction_dim().div_ceil(ni).max(1),
        bytes_per_step: ni * (nl + 1),
        ddr_bytes_per_cycle: device.ddr_gbytes_per_s * 1e9 / (fmax_mhz * 1e6),
        out_bytes: nl,
    }
}

/// Work description of a flow's dominant (most-MAC) round at option
/// (N_i, N_l) — what [`crate::dse::eval`]'s stepped-dominant fidelity
/// mode feeds the cycle-accurate simulator. Returns `None` for an empty
/// flow.
pub fn dominant_round_work(
    flow: &ComputationFlow,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
) -> Option<RoundWork> {
    let layer = flow.layers.iter().max_by_key(|l| l.macs())?;
    Some(layer_round_work(layer, device, fmax_mhz, ni, nl))
}

/// One [`RoundWork`] per fused round, in flow order — the full-network
/// stepped workload ([`crate::dse::eval::Fidelity::SteppedFullNetwork`]).
pub fn network_round_work(
    flow: &ComputationFlow,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
) -> Vec<RoundWork> {
    flow.layers
        .iter()
        .map(|l| layer_round_work(l, device, fmax_mhz, ni, nl))
        .collect()
}

/// Per-layer stepped census for a whole network: every fused round run
/// through the cycle-accurate stepper (skip-ahead engine), in flow
/// order. The rounds execute back-to-back on the pipelined architecture,
/// so totals are sums.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkStepReport {
    /// Kernel clock the cycle counts are measured at.
    pub fmax_mhz: f64,
    /// One census per fused round, aligned with `flow.layers`.
    pub layers: Vec<StepReport>,
}

impl NetworkStepReport {
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }

    pub fn total_millis(&self) -> f64 {
        self.total_cycles() as f64 / (self.fmax_mhz * 1e6) * 1e3
    }

    /// Network-wide lane utilization: conv-busy cycles over all cycles.
    pub fn conv_utilization(&self) -> f64 {
        let cycles = self.total_cycles();
        if cycles == 0 {
            return 0.0;
        }
        self.layers.iter().map(|l| l.conv_busy).sum::<u64>() as f64 / cycles as f64
    }

    /// Field-wise sum over the per-round censuses.
    pub fn totals(&self) -> StepReport {
        let mut t = StepReport::default();
        for l in &self.layers {
            t.cycles += l.cycles;
            t.rd_busy += l.rd_busy;
            t.conv_busy += l.conv_busy;
            t.wr_busy += l.wr_busy;
            t.rd_to_conv_full_stalls += l.rd_to_conv_full_stalls;
            t.conv_to_wr_full_stalls += l.conv_to_wr_full_stalls;
            t.conv_empty_stalls += l.conv_empty_stalls;
        }
        t
    }

    /// Index of the round with the most stepped cycles.
    pub fn bottleneck(&self) -> Option<usize> {
        self.layers
            .iter()
            .enumerate()
            .max_by_key(|(_, l)| l.cycles)
            .map(|(i, _)| i)
    }
}

/// Step *every* round of the flow at option (ni, nl) — the ground-truth
/// counterpart of [`super::engine::simulate`], made affordable by the
/// skip-ahead engine.
pub fn step_network(
    flow: &ComputationFlow,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
) -> NetworkStepReport {
    NetworkStepReport {
        fmax_mhz,
        layers: network_round_work(flow, device, fmax_mhz, ni, nl)
            .iter()
            .map(step_round)
            .collect(),
    }
}

/// The analytical cycle count the engine uses (see engine.rs for the
/// closed form); exposed here so the property test can compare. Uses the
/// same whole-byte DDR quantization as the steppers.
pub fn analytical_cycles(work: &RoundWork) -> u64 {
    let total_outputs = (work.pixels * work.groups) as u64;
    let compute = total_outputs * work.red_steps as u64;
    let bw = ddr_whole_bytes(work.ddr_bytes_per_cycle) as f64;
    let rd_bytes = compute as f64 * work.bytes_per_step as f64;
    let wr_bytes = total_outputs as f64 * work.out_bytes as f64;
    let ddr = ((rd_bytes + wr_bytes) / bw).ceil() as u64;
    compute.max(ddr) + work.red_steps as u64 + 2 // + pipeline fill
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::device::ARRIA_10_GX1150;
    use crate::estimator::estimate;
    use crate::onnx::zoo;
    use crate::testkit::for_all;

    fn alexnet_flow() -> ComputationFlow {
        ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap()
    }

    #[test]
    fn compute_bound_round_is_step_limited() {
        let w = RoundWork {
            pixels: 64,
            groups: 2,
            red_steps: 10,
            bytes_per_step: 4,
            ddr_bytes_per_cycle: 1000.0, // DDR never the limit
            out_bytes: 4,
        };
        let rep = step_round(&w);
        let ideal = (64 * 2 * 10) as u64;
        assert!(rep.cycles >= ideal);
        assert!(rep.cycles < ideal + 2 * PIPE_DEPTH as u64);
        assert!(rep.conv_utilization() > 0.9, "{}", rep.conv_utilization());
    }

    #[test]
    fn memory_bound_round_shows_empty_stalls() {
        let w = RoundWork {
            pixels: 32,
            groups: 2,
            red_steps: 8,
            bytes_per_step: 64,
            ddr_bytes_per_cycle: 8.0, // 8x slower than compute needs
            out_bytes: 8,
        };
        let rep = step_round(&w);
        assert!(rep.conv_empty_stalls > 0);
        assert!(rep.conv_utilization() < 0.5);
        // cycles ≈ bytes / bandwidth
        let bytes = (32 * 2 * 8 * 64 + 32 * 2 * 8) as f64;
        let expect = bytes / 8.0;
        let ratio = rep.cycles as f64 / expect;
        assert!((0.9..1.3).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn analytical_matches_stepped_within_tolerance() {
        for_all("analytical ≈ stepped cycles", |g| {
            let w = RoundWork {
                pixels: g.usize(1, 96),
                groups: g.usize(1, 8),
                red_steps: g.usize(1, 64),
                bytes_per_step: g.usize(1, 128),
                ddr_bytes_per_cycle: g.f64(1.0, 256.0),
                out_bytes: g.usize(1, 32),
            };
            let stepped = step_round(&w).cycles as f64;
            let analytical = analytical_cycles(&w) as f64;
            let rel = (stepped - analytical).abs() / stepped.max(1.0);
            // tiny rounds are dominated by pipeline fill, so allow an
            // absolute slack of one fill in addition to the relative band
            let abs_ok = (stepped - analytical).abs() <= (w.red_steps + 64) as f64;
            assert!(
                rel < 0.15 || abs_ok,
                "stepped {stepped} vs analytical {analytical} (rel {rel:.3}) for {w:?}"
            );
        });
    }

    #[test]
    fn skip_ahead_is_bit_identical_to_reference_property() {
        // THE tentpole contract: same cycles, same busy counters, same
        // stall counters — bit for bit — on randomized rounds spanning
        // compute-bound, memory-bound and stall-heavy regimes.
        for_all("step_round == step_round_reference", |g| {
            let w = RoundWork {
                pixels: g.usize(1, 96),
                groups: g.usize(1, 8),
                red_steps: g.usize(1, 64),
                bytes_per_step: g.usize(1, 128),
                ddr_bytes_per_cycle: g.f64(1.0, 256.0),
                out_bytes: g.usize(1, 32),
            };
            assert_eq!(step_round(&w), step_round_reference(&w), "{w:?}");
        });
    }

    #[test]
    fn skip_ahead_is_bit_identical_on_adversarial_rounds() {
        // hand-picked corners: the DDR credit cap barely admitting one
        // transaction, red_steps == 1, rollback storms where the output
        // pipe fills and the lanes hold their slice, coprime byte rates
        // that maximize the credit-residue period, and the two real
        // dominant-round shapes the DSE actually steps.
        let cases: [(usize, usize, usize, usize, f64, usize); 8] = [
            (32, 2, 8, 64, 1.0, 8),       // cap barely admits the read txn
            (17, 3, 5, 12, 1.5, 200),     // cap pinned by 2*out_bytes
            (500, 4, 1, 4, 3.0, 64),      // red_steps=1 rollback storm
            (2000, 1, 1, 1, 1.25, 64),    // reads starve writes, then drain
            (400, 4, 17, 601, 255.4, 64), // coprime rates, long residue
            (81, 2, 25, 528, 7.0, 32),    // prime bandwidth
            (729, 6, 100, 16, 40.0, 32),  // the hotpath bench round
            (729, 6, 100, 528, 40.2, 32), // alexnet-conv2 at (16,32)
        ];
        for (pixels, groups, red_steps, bytes_per_step, ddr, out_bytes) in cases {
            let w = RoundWork {
                pixels,
                groups,
                red_steps,
                bytes_per_step,
                ddr_bytes_per_cycle: ddr,
                out_bytes,
            };
            assert_eq!(step_round(&w), step_round_reference(&w), "{w:?}");
        }
    }

    #[test]
    fn rollback_storm_terminates_and_conserves() {
        // red_steps == 1 with starved writes fills the output pipe; the
        // held-slice semantics must neither deadlock nor lose work
        let w = RoundWork {
            pixels: 2000,
            groups: 1,
            red_steps: 1,
            bytes_per_step: 1,
            ddr_bytes_per_cycle: 1.25,
            out_bytes: 64,
        };
        let rep = step_round(&w);
        assert_eq!(rep.wr_busy, 2000);
        assert_eq!(rep.conv_busy, 2000);
        assert!(rep.conv_to_wr_full_stalls > 0, "rollback path exercised");
    }

    #[test]
    fn dominant_round_is_alexnet_conv2() {
        let flow = alexnet_flow();
        let w = dominant_round_work(&flow, &ARRIA_10_GX1150, 199.0, 16, 32).unwrap();
        // conv2 carries the most MACs: 27x27 pixels, 192 features over a
        // 1600-long reduction — the "alexnet-conv2-ish" hotpath workload
        assert_eq!(w.pixels, 729);
        assert_eq!(w.groups, 6);
        assert_eq!(w.red_steps, 100);
        assert_eq!(w.out_bytes, 32);
        assert!(w.ddr_bytes_per_cycle > 0.0);
        // the dominant round is the per-layer work of the max-MAC layer
        let layer = flow.layers.iter().max_by_key(|l| l.macs()).unwrap();
        assert_eq!(w, layer_round_work(layer, &ARRIA_10_GX1150, 199.0, 16, 32));
    }

    #[test]
    fn conservation_all_outputs_written() {
        let w = RoundWork {
            pixels: 17,
            groups: 3,
            red_steps: 5,
            bytes_per_step: 12,
            ddr_bytes_per_cycle: 20.0,
            out_bytes: 6,
        };
        let rep = step_round(&w);
        assert_eq!(rep.wr_busy as usize, 17 * 3);
        assert_eq!(rep.conv_busy as usize, 17 * 3 * 5);
    }

    #[test]
    fn full_network_census_conserves_every_round() {
        // stepping every round must retire exactly each round's outputs
        // and MAC exactly each round's vector steps — the conservation
        // invariant of the SteppedFullNetwork fidelity
        let flow = alexnet_flow();
        let (ni, nl) = (16usize, 32usize);
        let est = estimate(&flow, &ARRIA_10_GX1150, ni, nl);
        let net = step_network(&flow, &ARRIA_10_GX1150, est.fmax_mhz, ni, nl);
        assert_eq!(net.layers.len(), flow.layers.len());
        for (census, layer) in net.layers.iter().zip(&flow.layers) {
            let outputs =
                (layer.out_pixels().max(1) * layer.out_features().div_ceil(nl).max(1)) as u64;
            let steps = outputs * layer.reduction_dim().div_ceil(ni).max(1) as u64;
            assert_eq!(census.wr_busy, outputs, "round {}", layer.index);
            assert_eq!(census.conv_busy, steps, "round {}", layer.index);
            assert_eq!(census.rd_busy, steps, "round {}", layer.index);
            assert!(census.cycles >= outputs.max(steps), "round {}", layer.index);
        }
        // totals are the field-wise sums; the bottleneck is a real index
        let totals = net.totals();
        assert_eq!(totals.cycles, net.total_cycles());
        assert_eq!(
            totals.wr_busy,
            net.layers.iter().map(|l| l.wr_busy).sum::<u64>()
        );
        let b = net.bottleneck().unwrap();
        assert!(net.layers.iter().all(|l| l.cycles <= net.layers[b].cycles));
        assert!(net.total_millis() > 0.0);
        assert!(net.conv_utilization() > 0.0 && net.conv_utilization() <= 1.0);
    }

    #[test]
    fn network_work_covers_every_layer_and_contains_dominant() {
        let flow = alexnet_flow();
        let works = network_round_work(&flow, &ARRIA_10_GX1150, 199.0, 16, 32);
        assert_eq!(works.len(), flow.layers.len());
        let dom = dominant_round_work(&flow, &ARRIA_10_GX1150, 199.0, 16, 32).unwrap();
        assert!(works.contains(&dom));
    }

    #[test]
    fn ddr_quantization_is_total_and_clamped() {
        assert_eq!(ddr_whole_bytes(40.2), 40);
        assert_eq!(ddr_whole_bytes(40.5), 41);
        assert_eq!(ddr_whole_bytes(0.2), 1);
        assert_eq!(ddr_whole_bytes(1.0), 1);
        assert_eq!(ddr_whole_bytes(f64::NAN), 1);
        assert_eq!(ddr_whole_bytes(1e9), 1_000_000_000);
    }

    /// CI perf-smoke gate (run with `--ignored` in release mode): the
    /// skip-ahead engine must beat the naive reference by ≥ 10x on the
    /// alexnet-conv2 dominant round — the generous bound of the PR-3
    /// acceptance criteria so runner noise can't flake it (the measured
    /// iteration-count ratio is ~300x).
    #[test]
    #[ignore = "perf gate; run in release via CI perf-smoke"]
    fn perf_smoke_skip_ahead_beats_reference_10x() {
        use std::time::Instant;
        let flow = alexnet_flow();
        let est = estimate(&flow, &ARRIA_10_GX1150, 16, 32);
        let work = dominant_round_work(&flow, &ARRIA_10_GX1150, est.fmax_mhz, 16, 32).unwrap();
        // correctness first — a fast wrong answer is no answer
        assert_eq!(step_round(&work), step_round_reference(&work));
        let best = |f: &dyn Fn() -> StepReport, iters: usize| -> f64 {
            let mut best = f64::INFINITY;
            for _ in 0..iters {
                let t0 = Instant::now();
                std::hint::black_box(f());
                best = best.min(t0.elapsed().as_secs_f64());
            }
            best
        };
        let t_ref = best(&|| step_round_reference(&work), 3);
        let t_fast = best(&|| step_round(&work), 3);
        let speedup = t_ref / t_fast.max(1e-12);
        assert!(
            speedup >= 10.0,
            "skip-ahead speedup {speedup:.1}x < 10x (ref {t_ref:.4}s, fast {t_fast:.6}s)"
        );
    }
}
