//! Cycle-stepped simulation of one pipelined round (paper Fig. 3c/5).
//!
//! Four stages — memory read, conv lane array, pool, memory write —
//! connected by [`Pipe`]s, stepped one kernel clock at a time in vector
//! granularity: a token is one `N_i`-wide vector MAC's worth of work on
//! the conv pipe, one output element per lane elsewhere.
//!
//! Two engines share the same cycle semantics:
//!
//! * [`step_round_reference`] — the naive oracle: one loop iteration per
//!   kernel cycle over real [`Pipe`]s. Millions of iterations for an
//!   AlexNet-conv2-class round; kept as the ground truth the fast engine
//!   is validated against.
//! * [`step_round`] — the **epoch skip-ahead** engine. Between
//!   state-change events (a pipe filling or draining, the DDR credit
//!   counter crossing a transaction boundary, a stream exhausting its
//!   tokens) the four-stage pipeline settles into a steady state: the
//!   per-cycle transition is a deterministic function of the compact
//!   state `(feed occupancy, out occupancy, reduction phase, held slice,
//!   DDR credit)`, so the orbit is eventually periodic. The engine steps
//!   naively while recording the compact state at write-retire cycles;
//!   on the first exact recurrence it has an epoch length and per-epoch
//!   census deltas, and fast-forwards whole epochs in closed form (one
//!   multiply per counter) while keeping a full epoch of headroom to
//!   every end-of-round boundary — which makes the skip provably
//!   bit-identical to the reference, stall counters included. The
//!   property and adversarial tests below enforce that identity.
//!
//! Both engines speak **multi-producer rounds**: an Add-merge round
//! ([`crate::ir::LayerKind::Add`]) is fed by two upstream rounds, so its
//! [`RoundWork`] carries a second feed stream
//! ([`RoundWork::feed2_bytes_per_step`] > 0). The single memory-read
//! port then fetches one token per cycle into whichever stream is
//! further behind (ties go to feed A), the lane array consumes one token
//! from EACH stream per vector step, and starvation is attributed per
//! branch ([`StepReport::feed_a_empty_stalls`]/
//! [`StepReport::feed_b_empty_stalls`]) so the census can name the
//! bottleneck branch. Single-feed rounds (`feed2 == 0`) dispatch to the
//! exact pre-DAG engines — linear-chain censuses are byte-identical.
//!
//! DDR credit is exact u128 fixed-point fractional arithmetic
//! ([`ddr_credit_rate`]): the per-cycle inflow is an integer number of
//! credit units (`num` units per cycle, `den` units per byte), so the
//! credit bookkeeping is exact integer math in both engines. The rate is
//! snapped to the nearest rational on the round's own *write-group byte
//! lattice* (`G = red_steps·bytes_per_step + out_bytes` bytes per
//! retired group-slice): `num = G·k`, `den = round(G·k / rate)` for the
//! smallest `k ≤ 64` within 0.1% of the nominal rate. Snapping to the
//! spend lattice is what keeps steady-state orbits short — an orbit
//! closes exactly when inflow balances an integer number of
//! write-groups, so the minimal period is `k` retires instead of the
//! astronomical denominators a generic binary fixed point produces —
//! while the quantization error (≤0.1%, typically ~0.01%) is orders of
//! magnitude below the old whole-byte rounding (up to several % on
//! low-bandwidth parts, and a hard ≥1 byte/cycle clamp besides).
//!
//! This stepping model is the ground truth the analytical round model in
//! [`super::engine`] is validated against (property test: the two agree
//! within a few percent on randomized small rounds). Table-scale runs use
//! the analytical model so regenerating the paper's tables stays
//! interactive; the stepper also feeds the per-layer stall/backpressure
//! census reported by `cnn2gate synth --report` (see [`step_network`]).

use std::collections::HashMap;

use crate::estimator::model::PIPE_DEPTH;
use crate::estimator::Device;
use crate::ir::{ComputationFlow, FusedLayer};

use super::pipe::Pipe;

/// Work description of one round at vector granularity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundWork {
    /// Output pixels (OH*OW for conv rounds, 1 for FC).
    pub pixels: usize,
    /// Output-feature groups: ceil(out_features / N_l).
    pub groups: usize,
    /// Reduction steps per output: ceil(reduction_dim / N_i).
    pub red_steps: usize,
    /// Bytes the memory-read kernel must fetch per reduction step
    /// (feature vector broadcast + per-lane weight vectors).
    pub bytes_per_step: usize,
    /// Bytes per reduction step of the SECOND feed stream — `0` for
    /// ordinary single-producer rounds (the overwhelmingly common case,
    /// dispatched to the classic single-feed engines), nonzero for
    /// multi-producer merges (an Add round reads `N_l` bytes from each
    /// branch per step). The conv stage consumes one token from each
    /// stream per vector step.
    pub feed2_bytes_per_step: usize,
    /// DDR bytes deliverable per cycle at the kernel clock (snapped to
    /// an exact per-round rational by the steppers — see
    /// [`ddr_credit_rate`]).
    pub ddr_bytes_per_cycle: f64,
    /// Output bytes written per (pixel, group) completion.
    pub out_bytes: usize,
    /// Frames sharing this round pass (the batch dimension). Weights
    /// are fetched once per group pass and held across the batch, so
    /// `bytes_per_step` already carries the B-fold weight amortization
    /// (see [`bytes_per_step_with_reuse`]); activations and compute
    /// scale per frame — `total_outputs`/`total_steps` grow ×B. `0` is
    /// treated as `1` (a round always runs at least one frame).
    pub batch: usize,
}

impl RoundWork {
    /// Group-slices retired by one full round pass (all frames).
    pub fn total_outputs(&self) -> u64 {
        (self.pixels * self.groups) as u64 * self.batch.max(1) as u64
    }

    /// Vector MAC steps in one full round pass (all frames).
    pub fn total_steps(&self) -> u64 {
        self.total_outputs() * self.red_steps as u64
    }
}

/// The one per-round byte formula both the stepped and the analytical
/// model derive from: each vector step fetches the `N_i` feature bytes
/// it always needs, plus the `N_i × N_l` weight bytes amortized over
/// the `reuse` steps that share the loaded slice.
///
/// * `reuse = 1` — the fully streamed schedule: `N_i·(N_l + 1)`,
///   exactly what [`layer_round_work`] has always charged.
/// * `reuse = B` — streamed under a batch of B frames: weights fetched
///   once and held across the batch.
/// * `reuse = pixels` — [`WeightSchedule::SliceResident`] at batch 1:
///   the slice is held across the group pass.
/// * `reuse = pixels·B` — slice-resident under a batch: held across the
///   group pass AND the batch.
///
/// The `div_ceil` keeps the charge conservative (never below the exact
/// preload traffic), and FC rounds (`pixels == 1`) gain reuse only at
/// B > 1 — with B frames sharing the slice they amortize like any conv
/// round.
pub fn bytes_per_step_with_reuse(ni: usize, nl: usize, reuse: usize) -> usize {
    ni + (ni * nl).div_ceil(reuse.max(1))
}

/// Per-stage cycle/stall census from a stepped run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StepReport {
    pub cycles: u64,
    pub rd_busy: u64,
    pub conv_busy: u64,
    pub wr_busy: u64,
    pub rd_to_conv_full_stalls: u64,
    pub conv_to_wr_full_stalls: u64,
    pub conv_empty_stalls: u64,
    /// Per-branch starvation attribution on multi-producer rounds: the
    /// conv-empty cycles where feed A (resp. B) was the empty stream
    /// (both can be charged in one cycle; `conv_empty_stalls` counts the
    /// cycle once). Always 0 on single-feed rounds.
    pub feed_a_empty_stalls: u64,
    pub feed_b_empty_stalls: u64,
}

impl StepReport {
    pub fn conv_utilization(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.conv_busy as f64 / self.cycles as f64
    }
}

/// How many write-group multiples the rate snapper tries.
const SNAP_GROUPS_MAX: u64 = 64;
/// Relative tolerance below which the snapper stops at the smallest
/// multiple (smaller multiples keep steady-state orbits shorter).
const SNAP_REL_TOL: f64 = 1e-3;

/// The exact rational DDR rate both steppers run on: `num` credit units
/// arrive per cycle and one byte costs `den` units, so the modeled rate
/// is exactly `num / den` bytes per cycle. Credit arithmetic on these
/// units is exact u128 fixed point — no float accumulation, no per-cycle
/// rounding — and the numerator is a multiple of the round's write-group
/// byte quantum so steady-state orbits close quickly (see module docs).
/// Degenerate (non-finite or non-positive) rates fall back to 1 byte per
/// cycle so a round always completes.
///
/// Rates faster than `SNAP_GROUPS_MAX` write-groups per cycle saturate
/// the snap (`den` clamps to 1, modeling `64·G` bytes/cycle). That can
/// understate an extreme nominal rate, but it cannot perturb any
/// census: the pipeline's per-cycle spend is bounded by one read plus
/// one write (≤ G bytes), which the saturated inflow already covers
/// sixty-four times over — DDR is simply never the limiter there.
pub fn ddr_credit_rate(work: &RoundWork) -> (u64, u64) {
    let group = (work.red_steps * (work.bytes_per_step + work.feed2_bytes_per_step)
        + work.out_bytes)
        .max(1) as u64;
    let rate = work.ddr_bytes_per_cycle;
    if !(rate.is_finite() && rate > 0.0) {
        return (1, 1);
    }
    let tol = rate * SNAP_REL_TOL;
    let mut best: Option<(f64, u64, u64)> = None;
    for k in 1..=SNAP_GROUPS_MAX {
        let num = group * k;
        let den = ((num as f64 / rate).round() as u64).max(1);
        let err = (num as f64 / den as f64 - rate).abs();
        if err <= tol {
            return (num, den);
        }
        let better = match best {
            Some((e, _, _)) => err < e,
            None => true,
        };
        if better {
            best = Some((err, num, den));
        }
    }
    // analysis: allow(panic, the 1..=SNAP_GROUPS_MAX loop always runs at least once, so `best` is always set)
    let (_, num, den) = best.expect("snap loop ran");
    (num, den)
}

/// Step one round to completion and return the census — the epoch
/// skip-ahead engine (see the module docs). Bit-identical to
/// [`step_round_reference`], enforced by the property tests below.
///
/// Stage behaviour per cycle (shared by both engines):
/// * mem_write: if the output pipe holds a slice and DDR credit covers
///   `out_bytes`, retire it (writes drain credit first: the pipeline can
///   always retire).
/// * conv: a completed group-slice the output pipe refused is *held* by
///   the lane array and re-offered before any new work is accepted (the
///   lanes stall, counting `conv_to_wr_full_stalls`); otherwise pop one
///   vector token; after `red_steps` tokens a group-slice (N_l elements)
///   is complete and pushed to the pool pipe.
/// * mem_read: if DDR credit covers `bytes_per_step` and the feed pipe
///   has room, produce one vector token. On dual-feed rounds the single
///   read port targets whichever stream is further behind (tie: feed A).
pub fn step_round(work: &RoundWork) -> StepReport {
    if work.feed2_bytes_per_step == 0 {
        step_round_single(work)
    } else {
        step_round_dual(work)
    }
}

/// The classic single-feed skip-ahead engine — the exact pre-DAG code
/// path every linear-chain round takes (byte-identical censuses).
fn step_round_single(work: &RoundWork) -> StepReport {
    let total_outputs = work.total_outputs();
    let total_steps = work.total_steps();
    let pipe_cap = PIPE_DEPTH.max(1) as u64;
    let (num, den) = ddr_credit_rate(work);
    let bw = num as u128;
    let bps = work.bytes_per_step as u128 * den as u128;
    let ob = work.out_bytes as u128 * den as u128;
    // credit does not accumulate indefinitely (DDR can't time-travel),
    // but the cap must admit the largest single transaction or a slow
    // bus could never complete it
    let cap = (8 * bw).max(2 * bps.max(ob));

    let mut rep = StepReport::default();
    let mut produced = 0u64;
    let mut consumed = 0u64;
    let mut emitted = 0u64;
    let mut written = 0u64;
    let mut red_progress = 0u64;
    let mut pending_slice = false;
    let mut feed_len = 0u64;
    let mut out_len = 0u64;
    let mut credit = 0u128;

    // analysis: allow(nondet, the epoch-recurrence memo is keyed lookup only; census counters never iterate it)
    let mut seen: HashMap<EpochKey, EpochSnap> = HashMap::new();

    while written < total_outputs {
        rep.cycles += 1;
        credit += bw;

        // -- memory write --
        let mut wrote = false;
        if out_len > 0 && credit >= ob {
            out_len -= 1;
            written += 1;
            credit -= ob;
            rep.wr_busy += 1;
            wrote = true;
        }

        // -- conv lane array --
        if pending_slice {
            if out_len < pipe_cap {
                out_len += 1;
                emitted += 1;
                pending_slice = false;
            } else {
                rep.conv_to_wr_full_stalls += 1;
            }
        }
        if !pending_slice && consumed < total_steps {
            if feed_len > 0 {
                feed_len -= 1;
                consumed += 1;
                red_progress += 1;
                rep.conv_busy += 1;
                if red_progress == work.red_steps as u64 {
                    red_progress = 0;
                    if out_len < pipe_cap {
                        out_len += 1;
                        emitted += 1;
                    } else {
                        pending_slice = true;
                        rep.conv_to_wr_full_stalls += 1;
                    }
                }
            } else {
                rep.conv_empty_stalls += 1;
            }
        }

        // -- memory read --
        if produced < total_steps && credit >= bps {
            if feed_len < pipe_cap {
                feed_len += 1;
                produced += 1;
                credit -= bps;
                rep.rd_busy += 1;
            } else {
                rep.rd_to_conv_full_stalls += 1;
            }
        }

        credit = credit.min(cap);

        // -- epoch skip-ahead ------------------------------------------------
        // Anchor on write-retire cycles only: every steady state retires
        // outputs, and anchoring there keeps the recurrence map tiny.
        if !wrote || written >= total_outputs {
            continue;
        }
        let key = EpochKey {
            feed: feed_len as u32,
            out: out_len as u32,
            red: red_progress as u32,
            pending: pending_slice,
            credit,
        };
        let Some(&prev) = seen.get(&key) else {
            if seen.len() >= EPOCH_WINDOW {
                seen.clear();
            }
            seen.insert(
                key,
                EpochSnap {
                    cycles: rep.cycles,
                    rd_busy: rep.rd_busy,
                    conv_busy: rep.conv_busy,
                    wr_busy: rep.wr_busy,
                    rd_to_conv: rep.rd_to_conv_full_stalls,
                    conv_to_wr: rep.conv_to_wr_full_stalls,
                    conv_empty: rep.conv_empty_stalls,
                    produced,
                    consumed,
                    emitted,
                    written,
                },
            );
            continue;
        };
        // The compact state recurred: the cycles since the snapshot are
        // one epoch, and (while every stream stays strictly inside its
        // end-of-round boundary) the pipeline will replay it verbatim.
        // Fast-forward k whole epochs, keeping one epoch of headroom to
        // every boundary so each skipped predicate evaluation provably
        // matches the reference's.
        let d_written = written - prev.written;
        if d_written == 0 {
            continue;
        }
        let d_produced = produced - prev.produced;
        let d_consumed = consumed - prev.consumed;
        let d_emitted = emitted - prev.emitted;
        let mut k = ((total_outputs - written) / d_written).saturating_sub(1);
        if d_produced > 0 {
            k = k.min(((total_steps - produced) / d_produced).saturating_sub(1));
        }
        if d_consumed > 0 {
            k = k.min(((total_steps - consumed) / d_consumed).saturating_sub(1));
        }
        if d_emitted > 0 {
            k = k.min(((total_outputs - emitted) / d_emitted).saturating_sub(1));
        }
        if k == 0 {
            continue;
        }
        rep.cycles += (rep.cycles - prev.cycles) * k;
        rep.rd_busy += (rep.rd_busy - prev.rd_busy) * k;
        rep.conv_busy += (rep.conv_busy - prev.conv_busy) * k;
        rep.wr_busy += (rep.wr_busy - prev.wr_busy) * k;
        rep.rd_to_conv_full_stalls += (rep.rd_to_conv_full_stalls - prev.rd_to_conv) * k;
        rep.conv_to_wr_full_stalls += (rep.conv_to_wr_full_stalls - prev.conv_to_wr) * k;
        rep.conv_empty_stalls += (rep.conv_empty_stalls - prev.conv_empty) * k;
        produced += d_produced * k;
        consumed += d_consumed * k;
        emitted += d_emitted * k;
        written += d_written * k;
        // the census jumped: stale snapshots would compute wrong deltas
        seen.clear();
    }
    rep
}

/// Largest number of anchor states the skip-ahead engine remembers
/// before restarting detection (bounds memory; epochs longer than this
/// many write-retires fall back to naive stepping, which is still
/// correct, just not fast).
const EPOCH_WINDOW: usize = 1 << 16;

/// Compact pipeline state at a write-retire cycle. Exact recurrence of
/// this key (fixed-point credit included) means the steady state
/// repeats.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct EpochKey {
    feed: u32,
    out: u32,
    red: u32,
    pending: bool,
    credit: u128,
}

/// Census + stream counters at an anchor, for per-epoch deltas.
#[derive(Debug, Clone, Copy)]
struct EpochSnap {
    cycles: u64,
    rd_busy: u64,
    conv_busy: u64,
    wr_busy: u64,
    rd_to_conv: u64,
    conv_to_wr: u64,
    conv_empty: u64,
    produced: u64,
    consumed: u64,
    emitted: u64,
    written: u64,
}

/// The dual-feed skip-ahead engine for multi-producer rounds. Identical
/// cycle skeleton to [`step_round_single`]; the differences are exactly
/// the dual-feed semantics (see module docs): two feed occupancies in
/// the recurrence key, a second produced-count in the snapshot (and a
/// matching skip bound), behind-first read arbitration, and per-branch
/// starvation attribution. Bit-identical to
/// [`step_round_reference_dual`], enforced by the tests below.
fn step_round_dual(work: &RoundWork) -> StepReport {
    let total_outputs = work.total_outputs();
    let total_steps = work.total_steps();
    let pipe_cap = PIPE_DEPTH.max(1) as u64;
    let (num, den) = ddr_credit_rate(work);
    let bw = num as u128;
    let bps_a = work.bytes_per_step as u128 * den as u128;
    let bps_b = work.feed2_bytes_per_step as u128 * den as u128;
    let ob = work.out_bytes as u128 * den as u128;
    let cap = (8 * bw).max(2 * bps_a.max(bps_b).max(ob));

    let mut rep = StepReport::default();
    let mut produced_a = 0u64;
    let mut produced_b = 0u64;
    let mut consumed = 0u64;
    let mut emitted = 0u64;
    let mut written = 0u64;
    let mut red_progress = 0u64;
    let mut pending_slice = false;
    let mut feed_a_len = 0u64;
    let mut feed_b_len = 0u64;
    let mut out_len = 0u64;
    let mut credit = 0u128;

    // analysis: allow(nondet, the epoch-recurrence memo is keyed lookup only; census counters never iterate it)
    let mut seen: HashMap<DualEpochKey, DualEpochSnap> = HashMap::new();

    while written < total_outputs {
        rep.cycles += 1;
        credit += bw;

        // -- memory write --
        let mut wrote = false;
        if out_len > 0 && credit >= ob {
            out_len -= 1;
            written += 1;
            credit -= ob;
            rep.wr_busy += 1;
            wrote = true;
        }

        // -- conv lane array --
        if pending_slice {
            if out_len < pipe_cap {
                out_len += 1;
                emitted += 1;
                pending_slice = false;
            } else {
                rep.conv_to_wr_full_stalls += 1;
            }
        }
        if !pending_slice && consumed < total_steps {
            if feed_a_len > 0 && feed_b_len > 0 {
                feed_a_len -= 1;
                feed_b_len -= 1;
                consumed += 1;
                red_progress += 1;
                rep.conv_busy += 1;
                if red_progress == work.red_steps as u64 {
                    red_progress = 0;
                    if out_len < pipe_cap {
                        out_len += 1;
                        emitted += 1;
                    } else {
                        pending_slice = true;
                        rep.conv_to_wr_full_stalls += 1;
                    }
                }
            } else {
                rep.conv_empty_stalls += 1;
                if feed_a_len == 0 {
                    rep.feed_a_empty_stalls += 1;
                }
                if feed_b_len == 0 {
                    rep.feed_b_empty_stalls += 1;
                }
            }
        }

        // -- memory read: one port, behind-first arbitration --
        let want_a = produced_a < total_steps;
        let want_b = produced_b < total_steps;
        let pick_b = want_b && (!want_a || produced_b < produced_a);
        if pick_b {
            if credit >= bps_b {
                if feed_b_len < pipe_cap {
                    feed_b_len += 1;
                    produced_b += 1;
                    credit -= bps_b;
                    rep.rd_busy += 1;
                } else {
                    rep.rd_to_conv_full_stalls += 1;
                }
            }
        } else if want_a && credit >= bps_a {
            if feed_a_len < pipe_cap {
                feed_a_len += 1;
                produced_a += 1;
                credit -= bps_a;
                rep.rd_busy += 1;
            } else {
                rep.rd_to_conv_full_stalls += 1;
            }
        }

        credit = credit.min(cap);

        // -- epoch skip-ahead (anchored on write-retire cycles) --
        if !wrote || written >= total_outputs {
            continue;
        }
        let key = DualEpochKey {
            feed_a: feed_a_len as u32,
            feed_b: feed_b_len as u32,
            out: out_len as u32,
            red: red_progress as u32,
            pending: pending_slice,
            credit,
        };
        let Some(&prev) = seen.get(&key) else {
            if seen.len() >= EPOCH_WINDOW {
                seen.clear();
            }
            seen.insert(
                key,
                DualEpochSnap {
                    cycles: rep.cycles,
                    rd_busy: rep.rd_busy,
                    conv_busy: rep.conv_busy,
                    wr_busy: rep.wr_busy,
                    rd_to_conv: rep.rd_to_conv_full_stalls,
                    conv_to_wr: rep.conv_to_wr_full_stalls,
                    conv_empty: rep.conv_empty_stalls,
                    feed_a_empty: rep.feed_a_empty_stalls,
                    feed_b_empty: rep.feed_b_empty_stalls,
                    produced_a,
                    produced_b,
                    consumed,
                    emitted,
                    written,
                },
            );
            continue;
        };
        let d_written = written - prev.written;
        if d_written == 0 {
            continue;
        }
        let d_produced_a = produced_a - prev.produced_a;
        let d_produced_b = produced_b - prev.produced_b;
        let d_consumed = consumed - prev.consumed;
        let d_emitted = emitted - prev.emitted;
        let mut k = ((total_outputs - written) / d_written).saturating_sub(1);
        if d_produced_a > 0 {
            k = k.min(((total_steps - produced_a) / d_produced_a).saturating_sub(1));
        }
        if d_produced_b > 0 {
            k = k.min(((total_steps - produced_b) / d_produced_b).saturating_sub(1));
        }
        if d_consumed > 0 {
            k = k.min(((total_steps - consumed) / d_consumed).saturating_sub(1));
        }
        if d_emitted > 0 {
            k = k.min(((total_outputs - emitted) / d_emitted).saturating_sub(1));
        }
        if k == 0 {
            continue;
        }
        rep.cycles += (rep.cycles - prev.cycles) * k;
        rep.rd_busy += (rep.rd_busy - prev.rd_busy) * k;
        rep.conv_busy += (rep.conv_busy - prev.conv_busy) * k;
        rep.wr_busy += (rep.wr_busy - prev.wr_busy) * k;
        rep.rd_to_conv_full_stalls += (rep.rd_to_conv_full_stalls - prev.rd_to_conv) * k;
        rep.conv_to_wr_full_stalls += (rep.conv_to_wr_full_stalls - prev.conv_to_wr) * k;
        rep.conv_empty_stalls += (rep.conv_empty_stalls - prev.conv_empty) * k;
        rep.feed_a_empty_stalls += (rep.feed_a_empty_stalls - prev.feed_a_empty) * k;
        rep.feed_b_empty_stalls += (rep.feed_b_empty_stalls - prev.feed_b_empty) * k;
        produced_a += d_produced_a * k;
        produced_b += d_produced_b * k;
        consumed += d_consumed * k;
        emitted += d_emitted * k;
        written += d_written * k;
        seen.clear();
    }
    rep
}

/// Compact dual-feed pipeline state at a write-retire cycle: the
/// single-feed [`EpochKey`] plus the second feed occupancy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct DualEpochKey {
    feed_a: u32,
    feed_b: u32,
    out: u32,
    red: u32,
    pending: bool,
    credit: u128,
}

/// Census + stream counters at a dual-feed anchor.
#[derive(Debug, Clone, Copy)]
struct DualEpochSnap {
    cycles: u64,
    rd_busy: u64,
    conv_busy: u64,
    wr_busy: u64,
    rd_to_conv: u64,
    conv_to_wr: u64,
    conv_empty: u64,
    feed_a_empty: u64,
    feed_b_empty: u64,
    produced_a: u64,
    produced_b: u64,
    consumed: u64,
    emitted: u64,
    written: u64,
}

/// The naive per-cycle oracle the skip-ahead engine is validated
/// against: one loop iteration per kernel cycle over real [`Pipe`]s.
/// Same cycle semantics as [`step_round`] (see there), ~1000x slower on
/// round-scale work.
pub fn step_round_reference(work: &RoundWork) -> StepReport {
    if work.feed2_bytes_per_step == 0 {
        step_round_reference_single(work)
    } else {
        step_round_reference_dual(work)
    }
}

/// The naive dual-feed oracle: real [`Pipe`]s for both feed streams,
/// one loop iteration per cycle. Ground truth for [`step_round_dual`].
fn step_round_reference_dual(work: &RoundWork) -> StepReport {
    let total_outputs = work.total_outputs();
    let total_steps = work.total_steps();
    let mut feed_a = Pipe::new("rdA->conv", PIPE_DEPTH.max(1));
    let mut feed_b = Pipe::new("rdB->conv", PIPE_DEPTH.max(1));
    let mut out = Pipe::new("conv->wr", PIPE_DEPTH.max(1));
    let mut rep = StepReport::default();

    let (num, den) = ddr_credit_rate(work);
    let bw = num as u128;
    let bps_a = work.bytes_per_step as u128 * den as u128;
    let bps_b = work.feed2_bytes_per_step as u128 * den as u128;
    let ob = work.out_bytes as u128 * den as u128;
    let cap = (8 * bw).max(2 * bps_a.max(bps_b).max(ob));

    let mut produced_a = 0u64;
    let mut produced_b = 0u64;
    let mut consumed_steps = 0u64;
    let mut emitted = 0u64;
    let mut written = 0u64;
    let mut red_progress = 0u64;
    let mut pending_slice = false;
    let mut ddr_credit = 0u128;

    while written < total_outputs {
        rep.cycles += 1;
        ddr_credit += bw;

        // -- memory write --
        if !out.is_empty() && ddr_credit >= ob {
            out.pop();
            written += 1;
            ddr_credit -= ob;
            rep.wr_busy += 1;
        }

        // -- conv lane array: one token from EACH feed per vector step --
        if pending_slice {
            if out.push(emitted) {
                emitted += 1;
                pending_slice = false;
            } else {
                rep.conv_to_wr_full_stalls += 1;
            }
        }
        if !pending_slice && consumed_steps < total_steps {
            if !feed_a.is_empty() && !feed_b.is_empty() {
                feed_a.pop();
                feed_b.pop();
                consumed_steps += 1;
                red_progress += 1;
                rep.conv_busy += 1;
                if red_progress == work.red_steps as u64 {
                    red_progress = 0;
                    if out.push(emitted) {
                        emitted += 1;
                    } else {
                        pending_slice = true;
                        rep.conv_to_wr_full_stalls += 1;
                    }
                }
            } else {
                rep.conv_empty_stalls += 1;
                if feed_a.is_empty() {
                    rep.feed_a_empty_stalls += 1;
                }
                if feed_b.is_empty() {
                    rep.feed_b_empty_stalls += 1;
                }
            }
        }

        // -- memory read: one port, behind-first arbitration --
        let want_a = produced_a < total_steps;
        let want_b = produced_b < total_steps;
        let pick_b = want_b && (!want_a || produced_b < produced_a);
        if pick_b {
            if ddr_credit >= bps_b {
                if feed_b.push(produced_b) {
                    produced_b += 1;
                    ddr_credit -= bps_b;
                    rep.rd_busy += 1;
                } else {
                    rep.rd_to_conv_full_stalls += 1;
                }
            }
        } else if want_a && ddr_credit >= bps_a {
            if feed_a.push(produced_a) {
                produced_a += 1;
                ddr_credit -= bps_a;
                rep.rd_busy += 1;
            } else {
                rep.rd_to_conv_full_stalls += 1;
            }
        }

        ddr_credit = ddr_credit.min(cap);
    }
    rep
}

/// The classic single-feed oracle (the exact pre-DAG code path).
fn step_round_reference_single(work: &RoundWork) -> StepReport {
    let total_outputs = work.total_outputs(); // group-slices to emit
    let total_steps = work.total_steps(); // vector MACs
    let mut feed = Pipe::new("rd->conv", PIPE_DEPTH.max(1));
    let mut out = Pipe::new("conv->wr", PIPE_DEPTH.max(1));
    let mut rep = StepReport::default();

    let (num, den) = ddr_credit_rate(work);
    let bw = num as u128;
    let bps = work.bytes_per_step as u128 * den as u128;
    let ob = work.out_bytes as u128 * den as u128;
    let cap = (8 * bw).max(2 * bps.max(ob));

    let mut produced_steps = 0u64; // vectors fetched
    let mut consumed_steps = 0u64; // vectors MACed
    let mut emitted = 0u64; // group-slices pushed
    let mut written = 0u64; // group-slices written back
    let mut red_progress = 0u64;
    let mut pending_slice = false; // completed slice held by the lanes
    let mut ddr_credit = 0u128; // credit units available this cycle

    while written < total_outputs {
        rep.cycles += 1;
        ddr_credit += bw;

        // -- memory write (drains DDR credit first: writes have priority
        //    so the pipeline can always retire) --
        if !out.is_empty() && ddr_credit >= ob {
            out.pop();
            written += 1;
            ddr_credit -= ob;
            rep.wr_busy += 1;
        }

        // -- conv lane array: re-offer a held slice before new work --
        if pending_slice {
            if out.push(emitted) {
                emitted += 1;
                pending_slice = false;
            } else {
                rep.conv_to_wr_full_stalls += 1;
            }
        }
        if !pending_slice && consumed_steps < total_steps {
            if let Some(_tok) = feed.pop() {
                consumed_steps += 1;
                red_progress += 1;
                rep.conv_busy += 1;
                if red_progress == work.red_steps as u64 {
                    red_progress = 0;
                    if out.push(emitted) {
                        emitted += 1;
                    } else {
                        // output pipe full: the lane array holds the
                        // completed slice and stalls until accepted
                        pending_slice = true;
                        rep.conv_to_wr_full_stalls += 1;
                    }
                }
            } else {
                rep.conv_empty_stalls += 1;
            }
        }

        // -- memory read --
        if produced_steps < total_steps && ddr_credit >= bps {
            if feed.push(produced_steps) {
                produced_steps += 1;
                ddr_credit -= bps;
                rep.rd_busy += 1;
            } else {
                rep.rd_to_conv_full_stalls += 1;
            }
        }

        ddr_credit = ddr_credit.min(cap);
    }
    rep
}

/// The [`RoundWork`] of one fused round at option (N_i, N_l). One vector
/// step fetches `N_i` feature bytes broadcast to the lanes plus
/// `N_i × N_l` weight bytes (int8 codes); each completed group-slice
/// retires `N_l` output bytes.
pub fn layer_round_work(
    layer: &FusedLayer,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
) -> RoundWork {
    layer_round_work_batched(layer, device, fmax_mhz, ni, nl, 1)
}

/// [`layer_round_work`] at batch B: the weight stream is fetched once
/// and held across the B frames of the batch
/// ([`bytes_per_step_with_reuse`] with `reuse = B`), while activations
/// and compute scale per frame (`total_outputs`/`total_steps` grow ×B).
/// The DDR credit rational is re-snapped on the *batched* write-group
/// lattice automatically — [`ddr_credit_rate`] works off the amortized
/// `bytes_per_step`. At `batch = 1` this is exactly the classic
/// [`layer_round_work`].
pub fn layer_round_work_batched(
    layer: &FusedLayer,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
    batch: usize,
) -> RoundWork {
    let batch = batch.max(1);
    let ddr_bytes_per_cycle = device.ddr_gbytes_per_s * 1e9 / (fmax_mhz * 1e6);
    if !layer.has_weights() {
        // Add merge: no weight stream to amortize — each vector step
        // reads N_l activation bytes from EACH producer branch and
        // retires N_l bytes. Activations scale per frame, so the batch
        // rides total_outputs/total_steps alone.
        return RoundWork {
            pixels: layer.out_pixels().max(1),
            groups: layer.out_features().div_ceil(nl).max(1),
            red_steps: layer.reduction_dim().div_ceil(ni).max(1),
            bytes_per_step: nl,
            feed2_bytes_per_step: nl,
            ddr_bytes_per_cycle,
            out_bytes: nl,
            batch,
        };
    }
    RoundWork {
        pixels: layer.out_pixels().max(1),
        groups: layer.out_features().div_ceil(nl).max(1),
        red_steps: layer.reduction_dim().div_ceil(ni).max(1),
        bytes_per_step: bytes_per_step_with_reuse(ni, nl, batch),
        feed2_bytes_per_step: 0,
        ddr_bytes_per_cycle,
        out_bytes: nl,
        batch,
    }
}

/// Weight-slice schedule of one round's memory-read kernel.
///
/// The uniform flow ships ONE generic memory-read kernel shared by every
/// round; since it must also serve rounds whose weight slice exceeds the
/// on-chip weight buffer, it uses the streaming schedule (weights
/// re-fetched per reduction step — what [`layer_round_work`] charges).
/// Per-layer specialization ([`mod@crate::dse::specialize`]) generates a
/// per-round kernel schedule instead, so a round whose slice fits the
/// double-buffered weight budget can hold it on chip and re-fetch
/// weights once per group pass rather than once per output pixel — the
/// per-stage tailoring fpgaConvNet-style toolflows are credited with.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WeightSchedule {
    /// Weights stream from DDR on every reduction step (the generic
    /// kernel; uniform-flow semantics).
    Streamed,
    /// The active `(red × N_l)` weight slice is held in the on-chip
    /// weight buffer and re-streamed once per group pass; DDR then
    /// carries the feature broadcast plus the amortized slice preload.
    SliceResident,
}

/// Stable tag for a [`WeightSchedule`] (reports and the JSON document).
pub fn schedule_tag(schedule: WeightSchedule) -> &'static str {
    match schedule {
        WeightSchedule::Streamed => "streamed",
        WeightSchedule::SliceResident => "slice-resident",
    }
}

/// Whether `layer`'s weight slice at option (ni, nl) fits the device
/// family's double-buffered weight-buffer budget — the precondition for
/// [`WeightSchedule::SliceResident`]. Sized on the streamed reduction
/// length (`ceil(red/ni)·ni`), which is what the kernel actually holds.
pub fn slice_resident_allowed(layer: &FusedLayer, device: &Device, ni: usize, nl: usize) -> bool {
    let red_stream = layer.reduction_dim().div_ceil(ni).max(1) * ni;
    let slice_bits = (2 * red_stream * nl * 8) as f64;
    slice_bits <= device.family.consts().weight_budget_frac * device.mem_bits as f64
}

/// [`layer_round_work`] under an explicit [`WeightSchedule`]. Under
/// [`WeightSchedule::SliceResident`] one vector step fetches the `N_i`
/// feature bytes plus the slice preload amortized over the group's
/// `pixels` steps (`ceil(N_i·N_l / pixels)` — charged conservatively,
/// never below the exact `groups·red·N_l` preload traffic); for FC
/// rounds (`pixels == 1`, zero weight reuse) this degenerates to exactly
/// the streamed schedule.
pub fn scheduled_round_work(
    layer: &FusedLayer,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
    schedule: WeightSchedule,
) -> RoundWork {
    scheduled_round_work_batched(layer, device, fmax_mhz, ni, nl, schedule, 1)
}

/// [`scheduled_round_work`] at batch B. Streamed rounds amortize the
/// weight stream over the B frames of the batch; slice-resident rounds
/// hold the slice across the group pass AND the batch (`reuse =
/// pixels·B`). FC rounds (`pixels == 1`) degenerate to the streamed
/// schedule at batch 1 but gain the same ÷B weight amortization at
/// B > 1 — batching is how FC rounds stop being memory-bound.
pub fn scheduled_round_work_batched(
    layer: &FusedLayer,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
    schedule: WeightSchedule,
    batch: usize,
) -> RoundWork {
    let batch = batch.max(1);
    let mut work = layer_round_work_batched(layer, device, fmax_mhz, ni, nl, batch);
    if schedule == WeightSchedule::SliceResident && layer.has_weights() {
        work.bytes_per_step = bytes_per_step_with_reuse(ni, nl, work.pixels * batch);
    }
    work
}

/// Work description of a flow's dominant (most-MAC) round at option
/// (N_i, N_l) — what [`crate::dse::eval`]'s stepped-dominant fidelity
/// mode feeds the cycle-accurate simulator. Returns `None` for an empty
/// flow.
pub fn dominant_round_work(
    flow: &ComputationFlow,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
) -> Option<RoundWork> {
    dominant_round_work_batched(flow, device, fmax_mhz, ni, nl, 1)
}

/// [`dominant_round_work`] at batch B (see
/// [`layer_round_work_batched`]).
pub fn dominant_round_work_batched(
    flow: &ComputationFlow,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
    batch: usize,
) -> Option<RoundWork> {
    let layer = flow.layers.iter().max_by_key(|l| l.macs())?;
    Some(layer_round_work_batched(layer, device, fmax_mhz, ni, nl, batch))
}

/// One [`RoundWork`] per fused round, in flow order — the full-network
/// stepped workload ([`crate::dse::eval::Fidelity::SteppedFullNetwork`]).
pub fn network_round_work(
    flow: &ComputationFlow,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
) -> Vec<RoundWork> {
    network_round_work_batched(flow, device, fmax_mhz, ni, nl, 1)
}

/// [`network_round_work`] at batch B, in flow order.
pub fn network_round_work_batched(
    flow: &ComputationFlow,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
    batch: usize,
) -> Vec<RoundWork> {
    flow.layers
        .iter()
        .map(|l| layer_round_work_batched(l, device, fmax_mhz, ni, nl, batch))
        .collect()
}

/// Per-layer stepped census for a whole network: every fused round run
/// through the cycle-accurate stepper (skip-ahead engine), in flow
/// order. The rounds execute back-to-back on the pipelined architecture,
/// so totals are sums.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkStepReport {
    /// Kernel clock the cycle counts are measured at.
    pub fmax_mhz: f64,
    /// Frames stepped per round pass; the per-round censuses cover the
    /// whole batch, so [`NetworkStepReport::total_millis`] is the batch
    /// makespan and [`NetworkStepReport::millis_per_frame`] divides it
    /// out. `1` for every report predating the batch dimension.
    pub batch: usize,
    /// One census per fused round, aligned with `flow.layers`.
    pub layers: Vec<StepReport>,
}

impl NetworkStepReport {
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }

    pub fn total_millis(&self) -> f64 {
        self.total_cycles() as f64 / (self.fmax_mhz * 1e6) * 1e3
    }

    /// Batch makespan divided over its frames: the amortized per-frame
    /// latency (equals [`NetworkStepReport::total_millis`] at batch 1).
    pub fn millis_per_frame(&self) -> f64 {
        self.total_millis() / self.batch.max(1) as f64
    }

    /// Steady-state serving throughput at this batch size: the batch's
    /// frames over its makespan.
    pub fn frames_per_s(&self) -> f64 {
        let ms = self.total_millis();
        if ms <= 0.0 {
            return 0.0;
        }
        self.batch.max(1) as f64 * 1e3 / ms
    }

    /// Network-wide lane utilization: conv-busy cycles over all cycles.
    pub fn conv_utilization(&self) -> f64 {
        let cycles = self.total_cycles();
        if cycles == 0 {
            return 0.0;
        }
        self.layers.iter().map(|l| l.conv_busy).sum::<u64>() as f64 / cycles as f64
    }

    /// Field-wise sum over the per-round censuses.
    pub fn totals(&self) -> StepReport {
        let mut t = StepReport::default();
        for l in &self.layers {
            t.cycles += l.cycles;
            t.rd_busy += l.rd_busy;
            t.conv_busy += l.conv_busy;
            t.wr_busy += l.wr_busy;
            t.rd_to_conv_full_stalls += l.rd_to_conv_full_stalls;
            t.conv_to_wr_full_stalls += l.conv_to_wr_full_stalls;
            t.conv_empty_stalls += l.conv_empty_stalls;
            t.feed_a_empty_stalls += l.feed_a_empty_stalls;
            t.feed_b_empty_stalls += l.feed_b_empty_stalls;
        }
        t
    }

    /// Index of the round with the most stepped cycles.
    pub fn bottleneck(&self) -> Option<usize> {
        self.layers
            .iter()
            .enumerate()
            .max_by_key(|(_, l)| l.cycles)
            .map(|(i, _)| i)
    }

    /// Stall fraction of the bottleneck round: the share of its cycles
    /// the lane array spent NOT doing useful MACs (`1 − conv
    /// utilization` of the round [`NetworkStepReport::bottleneck`]
    /// names). This is the census term of the shaped DSE reward
    /// (`β·F_avg − γ·bottleneck_stall_fraction`, see
    /// [`crate::dse::reward::RewardShaper`]).
    pub fn bottleneck_stall_fraction(&self) -> f64 {
        match self.bottleneck() {
            Some(b) => {
                let l = &self.layers[b];
                if l.cycles == 0 {
                    0.0
                } else {
                    1.0 - l.conv_busy as f64 / l.cycles as f64
                }
            }
            None => 0.0,
        }
    }
}

/// Step *every* round of the flow at option (ni, nl) — the ground-truth
/// counterpart of [`super::engine::simulate`], made affordable by the
/// skip-ahead engine.
pub fn step_network(
    flow: &ComputationFlow,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
) -> NetworkStepReport {
    step_network_batched(flow, device, fmax_mhz, ni, nl, 1)
}

/// [`step_network`] at batch B: every round stepped over the batched
/// workload, so the censuses carry the B-fold weight amortization and
/// the per-frame compute scaling.
pub fn step_network_batched(
    flow: &ComputationFlow,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
    batch: usize,
) -> NetworkStepReport {
    NetworkStepReport {
        fmax_mhz,
        batch: batch.max(1),
        layers: network_round_work_batched(flow, device, fmax_mhz, ni, nl, batch)
            .iter()
            .map(step_round)
            .collect(),
    }
}

/// The analytical cycle count the engine uses (see engine.rs for the
/// closed form); exposed here so the property test can compare. Uses the
/// same per-round rational DDR rate as the steppers, and the same
/// batched totals — compute and activation traffic scale ×B while
/// `bytes_per_step` already carries the weight amortization.
pub fn analytical_cycles(work: &RoundWork) -> u64 {
    let total_outputs = work.total_outputs();
    let compute = work.total_steps();
    let (num, den) = ddr_credit_rate(work);
    let rd_bytes =
        compute as u128 * (work.bytes_per_step + work.feed2_bytes_per_step) as u128;
    let wr_bytes = total_outputs as u128 * work.out_bytes as u128;
    let ddr = ((rd_bytes + wr_bytes) * den as u128).div_ceil(num as u128) as u64;
    // dual-feed rounds share ONE read port: two fetches per vector step
    // bound the steady state at 2 cycles/step even when DDR is ample
    let port = if work.feed2_bytes_per_step > 0 {
        2 * compute
    } else {
        compute
    };
    compute.max(port).max(ddr) + work.red_steps as u64 + 2 // + pipeline fill
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::device::ARRIA_10_GX1150;
    use crate::estimator::estimate;
    use crate::onnx::zoo;
    use crate::testkit::for_all;

    fn alexnet_flow() -> ComputationFlow {
        ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap()
    }

    #[test]
    fn compute_bound_round_is_step_limited() {
        let w = RoundWork {
            pixels: 64,
            groups: 2,
            red_steps: 10,
            bytes_per_step: 4,
            feed2_bytes_per_step: 0,
            ddr_bytes_per_cycle: 1000.0, // DDR never the limit
            out_bytes: 4,
            batch: 1,
        };
        let rep = step_round(&w);
        let ideal = (64 * 2 * 10) as u64;
        assert!(rep.cycles >= ideal);
        assert!(rep.cycles < ideal + 2 * PIPE_DEPTH as u64);
        assert!(rep.conv_utilization() > 0.9, "{}", rep.conv_utilization());
    }

    #[test]
    fn memory_bound_round_shows_empty_stalls() {
        let w = RoundWork {
            pixels: 32,
            groups: 2,
            red_steps: 8,
            bytes_per_step: 64,
            feed2_bytes_per_step: 0,
            ddr_bytes_per_cycle: 8.0, // 8x slower than compute needs
            out_bytes: 8,
            batch: 1,
        };
        let rep = step_round(&w);
        assert!(rep.conv_empty_stalls > 0);
        assert!(rep.conv_utilization() < 0.5);
        // cycles ≈ bytes / bandwidth
        let bytes = (32 * 2 * 8 * 64 + 32 * 2 * 8) as f64;
        let expect = bytes / 8.0;
        let ratio = rep.cycles as f64 / expect;
        assert!((0.9..1.3).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn analytical_matches_stepped_within_tolerance() {
        // batched rounds use the SAME closed form and the SAME stepper
        // recurrence, so the agreement band must hold at B ∈ {1, 4, 16}
        for_all("analytical ≈ stepped cycles", |g| {
            let batch = [1usize, 4, 16][g.usize(0, 2)];
            let w = RoundWork {
                pixels: g.usize(1, 96),
                groups: g.usize(1, 8),
                red_steps: g.usize(1, 64),
                bytes_per_step: g.usize(1, 128),
                feed2_bytes_per_step: 0,
                ddr_bytes_per_cycle: g.f64(1.0, 256.0),
                out_bytes: g.usize(1, 32),
                batch,
            };
            let stepped = step_round(&w).cycles as f64;
            let analytical = analytical_cycles(&w) as f64;
            let rel = (stepped - analytical).abs() / stepped.max(1.0);
            // tiny rounds are dominated by pipeline fill, so allow an
            // absolute slack of one fill in addition to the relative band
            let abs_ok = (stepped - analytical).abs() <= (w.red_steps + 64) as f64;
            assert!(
                rel < 0.15 || abs_ok,
                "stepped {stepped} vs analytical {analytical} (rel {rel:.3}) for {w:?}"
            );
        });
    }

    #[test]
    fn skip_ahead_is_bit_identical_to_reference_property() {
        // THE tentpole contract: same cycles, same busy counters, same
        // stall counters — bit for bit — on randomized rounds spanning
        // compute-bound, memory-bound and stall-heavy regimes.
        for_all("step_round == step_round_reference", |g| {
            // the batch axis rides the same recurrence — identity must
            // hold at B ∈ {1, 2, 3, 16}. Frame dims shrink as B grows
            // so the naive oracle stays affordable.
            let batch = [1usize, 2, 3, 16][g.usize(0, 3)];
            let scale = if batch >= 16 { 8 } else { batch };
            let w = RoundWork {
                pixels: g.usize(1, 96 / scale),
                groups: g.usize(1, 8),
                red_steps: g.usize(1, 64),
                bytes_per_step: g.usize(1, 128),
                // a second feed stream on a third of the draws: the
                // dual-feed recurrence rides the same identity contract
                feed2_bytes_per_step: [0, 0, g.usize(1, 64)][g.usize(0, 2)],
                // sub-1 byte/cycle rates are first-class under the
                // fractional credit model (the whole-byte stepper
                // clamped them to 1)
                ddr_bytes_per_cycle: g.f64(0.3, 256.0),
                out_bytes: g.usize(1, 32),
                batch,
            };
            assert_eq!(step_round(&w), step_round_reference(&w), "{w:?}");
        });
    }

    #[test]
    fn skip_ahead_is_bit_identical_on_adversarial_rounds() {
        // hand-picked corners: the DDR credit cap barely admitting one
        // transaction, red_steps == 1, rollback storms where the output
        // pipe fills and the lanes hold their slice, coprime byte rates
        // that maximize the credit-residue period, sub-byte and
        // near-integer fractional rates, and the real dominant-round
        // shapes the DSE actually steps.
        let cases: [(usize, usize, usize, usize, f64, usize); 12] = [
            (32, 2, 8, 64, 1.0, 8),       // cap barely admits the read txn
            (17, 3, 5, 12, 1.5, 200),     // cap pinned by 2*out_bytes
            (500, 4, 1, 4, 3.0, 64),      // red_steps=1 rollback storm
            (2000, 1, 1, 1, 1.25, 64),    // reads starve writes, then drain
            (400, 4, 17, 601, 255.4, 64), // coprime rates, long residue
            (81, 2, 25, 528, 7.0, 32),    // prime bandwidth
            (729, 6, 100, 16, 40.0, 32),  // the hotpath bench round
            (729, 6, 100, 528, 40.2, 32), // alexnet-conv2-ish at (16,32)
            (40, 2, 3, 7, 0.37, 5),       // sub-byte-per-cycle bus
            (200, 1, 2, 3, 0.999_999_9, 4), // just below a whole byte
            (64, 3, 4, 9, 2.5, 6),        // exact half-byte fraction
            // the REAL conv2 rate: 8 GB/s at the 199 MHz kernel clock
            (729, 6, 100, 528, 40.201_005_025_125_63, 32),
        ];
        // every corner also runs under the batch axis — fractional
        // credit rates at B > 1 are exactly where a wrong batched
        // recurrence would diverge from the oracle. Combos whose naive
        // reference would step >400k MACs are kept at the batches that
        // fit (the skipped shapes are covered compute-bound below).
        for (pixels, groups, red_steps, bytes_per_step, ddr, out_bytes) in cases {
            for batch in [1usize, 2, 3, 16] {
                if batch > 1 && pixels * groups * red_steps * batch > 400_000 {
                    continue;
                }
                let w = RoundWork {
                    pixels,
                    groups,
                    red_steps,
                    bytes_per_step,
                    feed2_bytes_per_step: 0,
                    ddr_bytes_per_cycle: ddr,
                    out_bytes,
                    batch,
                };
                assert_eq!(step_round(&w), step_round_reference(&w), "{w:?}");
            }
        }
        // the REAL batched conv2 shape: at B=16 the weight stream
        // amortizes to bytes_per_step_with_reuse(16, 32, 16) = 48 and
        // the round flips compute-bound
        let w = RoundWork {
            pixels: 729,
            groups: 6,
            red_steps: 100,
            bytes_per_step: bytes_per_step_with_reuse(16, 32, 16),
            feed2_bytes_per_step: 0,
            ddr_bytes_per_cycle: 40.201_005_025_125_63,
            out_bytes: 32,
            batch: 16,
        };
        assert_eq!(step_round(&w), step_round_reference(&w), "{w:?}");
    }

    #[test]
    fn rollback_storm_terminates_and_conserves() {
        // red_steps == 1 with starved writes fills the output pipe; the
        // held-slice semantics must neither deadlock nor lose work
        let w = RoundWork {
            pixels: 2000,
            groups: 1,
            red_steps: 1,
            bytes_per_step: 1,
            feed2_bytes_per_step: 0,
            ddr_bytes_per_cycle: 1.25,
            out_bytes: 64,
            batch: 1,
        };
        let rep = step_round(&w);
        assert_eq!(rep.wr_busy, 2000);
        assert_eq!(rep.conv_busy, 2000);
        assert!(rep.conv_to_wr_full_stalls > 0, "rollback path exercised");
    }

    #[test]
    fn dominant_round_is_alexnet_conv2() {
        let flow = alexnet_flow();
        let w = dominant_round_work(&flow, &ARRIA_10_GX1150, 199.0, 16, 32).unwrap();
        // conv2 carries the most MACs: 27x27 pixels, 192 features over a
        // 1600-long reduction — the "alexnet-conv2-ish" hotpath workload
        assert_eq!(w.pixels, 729);
        assert_eq!(w.groups, 6);
        assert_eq!(w.red_steps, 100);
        assert_eq!(w.out_bytes, 32);
        assert!(w.ddr_bytes_per_cycle > 0.0);
        // the dominant round is the per-layer work of the max-MAC layer
        let layer = flow.layers.iter().max_by_key(|l| l.macs()).unwrap();
        assert_eq!(w, layer_round_work(layer, &ARRIA_10_GX1150, 199.0, 16, 32));
    }

    #[test]
    fn conservation_all_outputs_written() {
        // both steppers must retire exactly B·(pixels·groups) slices
        // and MAC exactly B× the per-frame vector steps, at every batch
        for batch in [1usize, 2, 3, 16] {
            let w = RoundWork {
                pixels: 17,
                groups: 3,
                red_steps: 5,
                bytes_per_step: 12,
                feed2_bytes_per_step: 0,
                ddr_bytes_per_cycle: 20.0,
                out_bytes: 6,
                batch,
            };
            let rep = step_round(&w);
            assert_eq!(rep.wr_busy as usize, 17 * 3 * batch, "B={batch}");
            assert_eq!(rep.conv_busy as usize, 17 * 3 * 5 * batch, "B={batch}");
            assert_eq!(rep, step_round_reference(&w), "B={batch}");
        }
    }

    #[test]
    fn full_network_census_conserves_every_round() {
        // stepping every round must retire exactly each round's outputs
        // and MAC exactly each round's vector steps — the conservation
        // invariant of the SteppedFullNetwork fidelity
        let flow = alexnet_flow();
        let (ni, nl) = (16usize, 32usize);
        let est = estimate(&flow, &ARRIA_10_GX1150, ni, nl);
        let net = step_network(&flow, &ARRIA_10_GX1150, est.fmax_mhz, ni, nl);
        assert_eq!(net.layers.len(), flow.layers.len());
        for (census, layer) in net.layers.iter().zip(&flow.layers) {
            let outputs =
                (layer.out_pixels().max(1) * layer.out_features().div_ceil(nl).max(1)) as u64;
            let steps = outputs * layer.reduction_dim().div_ceil(ni).max(1) as u64;
            assert_eq!(census.wr_busy, outputs, "round {}", layer.index);
            assert_eq!(census.conv_busy, steps, "round {}", layer.index);
            assert_eq!(census.rd_busy, steps, "round {}", layer.index);
            assert!(census.cycles >= outputs.max(steps), "round {}", layer.index);
        }
        // totals are the field-wise sums; the bottleneck is a real index
        let totals = net.totals();
        assert_eq!(totals.cycles, net.total_cycles());
        assert_eq!(
            totals.wr_busy,
            net.layers.iter().map(|l| l.wr_busy).sum::<u64>()
        );
        let b = net.bottleneck().unwrap();
        assert!(net.layers.iter().all(|l| l.cycles <= net.layers[b].cycles));
        assert!(net.total_millis() > 0.0);
        assert!(net.conv_utilization() > 0.0 && net.conv_utilization() <= 1.0);
        // the reward's census term is the bottleneck round's idle share
        let stall = net.bottleneck_stall_fraction();
        assert!((0.0..=1.0).contains(&stall), "{stall}");
        let bl = &net.layers[b];
        assert_eq!(stall.to_bits(), (1.0 - bl.conv_busy as f64 / bl.cycles as f64).to_bits());
    }

    #[test]
    fn network_work_covers_every_layer_and_contains_dominant() {
        let flow = alexnet_flow();
        let works = network_round_work(&flow, &ARRIA_10_GX1150, 199.0, 16, 32);
        assert_eq!(works.len(), flow.layers.len());
        let dom = dominant_round_work(&flow, &ARRIA_10_GX1150, 199.0, 16, 32).unwrap();
        assert!(works.contains(&dom));
    }

    #[test]
    fn ddr_credit_rate_is_exact_fractional_and_total() {
        let work = |rate: f64| RoundWork {
            pixels: 729,
            groups: 6,
            red_steps: 100,
            bytes_per_step: 528,
            feed2_bytes_per_step: 0,
            ddr_bytes_per_cycle: rate,
            out_bytes: 32,
            batch: 1,
        };
        // exactly representable rates snap exactly (k = 1: num = G)
        let (num, den) = ddr_credit_rate(&work(1.0));
        assert_eq!((num, den), (52_832, 52_832));
        let (num, den) = ddr_credit_rate(&work(0.25));
        assert_eq!(num as f64 / den as f64, 0.25, "sub-byte rate held exactly");
        // the real conv2 rate lands within the 0.1% snap tolerance —
        // over two decades tighter than the old whole-byte rounding
        // (40.2 -> 40 was 0.5%; a 1.5 B/c part rounded to 2 was 33%)
        let rate = 8.0 * 1e9 / (199.0 * 1e6);
        let (num, den) = ddr_credit_rate(&work(rate));
        let err = (num as f64 / den as f64 - rate).abs() / rate;
        assert!(err <= 1e-3, "snap err {err}");
        // degenerate rates fall back to 1 byte/cycle, never stall
        assert_eq!(ddr_credit_rate(&work(f64::NAN)), (1, 1));
        assert_eq!(ddr_credit_rate(&work(0.0)), (1, 1));
        assert_eq!(ddr_credit_rate(&work(-3.0)), (1, 1));
        // huge rates stay finite and within tolerance of nominal
        let (num, den) = ddr_credit_rate(&work(1e9));
        assert!(num >= 1 && den >= 1);
        // the numerator always rides the write-group lattice
        assert_eq!(num % 52_832, 0);
        // ... and the lattice itself is the BATCHED one: at B=16 the
        // amortized bytes_per_step (48) shrinks the write-group quantum
        // to 100·48 + 32 = 4832, and the snap re-derives on it
        let batched = RoundWork {
            bytes_per_step: bytes_per_step_with_reuse(16, 32, 16),
            batch: 16,
            ..work(1.0)
        };
        assert_eq!(batched.bytes_per_step, 48);
        let (num, _den) = ddr_credit_rate(&batched);
        assert_eq!(num % 4832, 0, "snap must ride the batched lattice");
    }

    #[test]
    fn scheduled_round_work_models_slice_residency() {
        let flow = alexnet_flow();
        let conv2 = flow.layers.iter().max_by_key(|l| l.macs()).unwrap();
        // streamed == layer_round_work (uniform semantics untouched)
        let streamed = scheduled_round_work(
            conv2,
            &ARRIA_10_GX1150,
            199.0,
            16,
            32,
            WeightSchedule::Streamed,
        );
        assert_eq!(streamed, layer_round_work(conv2, &ARRIA_10_GX1150, 199.0, 16, 32));
        // resident drops the per-step traffic to features + amortized
        // preload, and never below the exact preload floor
        let resident = scheduled_round_work(
            conv2,
            &ARRIA_10_GX1150,
            199.0,
            16,
            32,
            WeightSchedule::SliceResident,
        );
        assert_eq!(resident.bytes_per_step, 16 + (16 * 32usize).div_ceil(729));
        assert!(resident.bytes_per_step < streamed.bytes_per_step);
        let charged = resident.pixels * resident.groups * resident.red_steps
            * resident.bytes_per_step;
        let floor = resident.pixels * resident.groups * resident.red_steps * 16
            + resident.groups * (resident.red_steps * 16) * 32;
        assert!(charged >= floor, "amortized preload must stay conservative");
        // every alexnet slice fits the Arria 10 weight budget ...
        for layer in &flow.layers {
            assert!(slice_resident_allowed(layer, &ARRIA_10_GX1150, 16, 32));
        }
        // ... but an FC round gains nothing: pixels == 1 degenerates the
        // resident schedule to exactly the streamed one
        let fc = flow.layers.iter().find(|l| !l.is_conv()).unwrap();
        let fc_res = scheduled_round_work(
            fc,
            &ARRIA_10_GX1150,
            199.0,
            16,
            32,
            WeightSchedule::SliceResident,
        );
        assert_eq!(fc_res, layer_round_work(fc, &ARRIA_10_GX1150, 199.0, 16, 32));
        // a VGG-16-sized FC slice exceeds the budget entirely
        let vgg = ComputationFlow::extract(&zoo::build("vgg16", false).unwrap()).unwrap();
        let fc1 = vgg.layers.iter().find(|l| !l.is_conv()).unwrap();
        assert!(!slice_resident_allowed(fc1, &ARRIA_10_GX1150, 16, 32));
    }

    #[test]
    fn batched_round_work_amortizes_weight_traffic() {
        let flow = alexnet_flow();
        let conv2 = flow.layers.iter().max_by_key(|l| l.macs()).unwrap();
        // batch 1 is bit-for-bit the classic streamed charge
        let b1 = layer_round_work(conv2, &ARRIA_10_GX1150, 199.0, 16, 32);
        assert_eq!(b1.batch, 1);
        assert_eq!(b1.bytes_per_step, bytes_per_step_with_reuse(16, 32, 1));
        assert_eq!(b1.bytes_per_step, 16 * (32 + 1));
        // at B=16 the weight stream amortizes ÷16; activations/compute
        // scale per frame
        let b16 = layer_round_work_batched(conv2, &ARRIA_10_GX1150, 199.0, 16, 32, 16);
        assert_eq!(b16.batch, 16);
        assert_eq!(b16.bytes_per_step, 16 + (16 * 32usize).div_ceil(16));
        assert_eq!(b16.total_outputs(), 16 * b1.total_outputs());
        assert_eq!(b16.total_steps(), 16 * b1.total_steps());
        let dom = dominant_round_work_batched(&flow, &ARRIA_10_GX1150, 199.0, 16, 32, 16).unwrap();
        assert_eq!(dom, b16);
        // batch 0 clamps to 1 everywhere
        let b0 = layer_round_work_batched(conv2, &ARRIA_10_GX1150, 199.0, 16, 32, 0);
        assert_eq!(b0, b1);

        // FC rounds gain reuse ONLY at B > 1: slice-resident degenerates
        // to streamed at batch 1, and both schedules amortize ÷B under a
        // batch (pixels == 1 makes resident reuse = B exactly)
        let fc = flow.layers.iter().find(|l| !l.is_conv()).unwrap();
        let fc_b1 = scheduled_round_work_batched(
            fc,
            &ARRIA_10_GX1150,
            199.0,
            16,
            32,
            WeightSchedule::SliceResident,
            1,
        );
        assert_eq!(fc_b1, layer_round_work(fc, &ARRIA_10_GX1150, 199.0, 16, 32));
        let fc_b16 = scheduled_round_work_batched(
            fc,
            &ARRIA_10_GX1150,
            199.0,
            16,
            32,
            WeightSchedule::SliceResident,
            16,
        );
        assert_eq!(fc_b16.bytes_per_step, bytes_per_step_with_reuse(16, 32, 16));
        assert!(fc_b16.bytes_per_step < fc_b1.bytes_per_step);
        // conv slice-resident at B holds the slice across the group
        // pass AND the batch
        let res16 = scheduled_round_work_batched(
            conv2,
            &ARRIA_10_GX1150,
            199.0,
            16,
            32,
            WeightSchedule::SliceResident,
            16,
        );
        assert_eq!(res16.bytes_per_step, bytes_per_step_with_reuse(16, 32, 729 * 16));
    }

    #[test]
    fn batched_network_census_conserves_and_amortizes() {
        let flow = alexnet_flow();
        let (ni, nl) = (16usize, 32usize);
        let est = estimate(&flow, &ARRIA_10_GX1150, ni, nl);
        let b = 4usize;
        let net = step_network_batched(&flow, &ARRIA_10_GX1150, est.fmax_mhz, ni, nl, b);
        assert_eq!(net.batch, b);
        assert_eq!(net.layers.len(), flow.layers.len());
        // conservation at B: every round retires B× its per-frame slices
        for (census, layer) in net.layers.iter().zip(&flow.layers) {
            let outputs = (layer.out_pixels().max(1) * layer.out_features().div_ceil(nl).max(1))
                as u64
                * b as u64;
            assert_eq!(census.wr_busy, outputs, "round {}", layer.index);
            assert_eq!(
                census.conv_busy,
                outputs * layer.reduction_dim().div_ceil(ni).max(1) as u64,
                "round {}",
                layer.index
            );
        }
        // weight reuse makes the batch makespan sublinear in B, so the
        // amortized per-frame latency drops and frames/s rises
        let b1 = step_network(&flow, &ARRIA_10_GX1150, est.fmax_mhz, ni, nl);
        assert_eq!(b1.batch, 1);
        assert!(net.total_cycles() < b as u64 * b1.total_cycles());
        assert!(net.millis_per_frame() < b1.total_millis());
        assert!(net.frames_per_s() > b1.frames_per_s());
        let fps = net.frames_per_s();
        let inv = 1e3 / net.millis_per_frame();
        assert!((fps - inv).abs() / fps < 1e-12, "fps {fps} vs {inv}");
    }

    #[test]
    fn dual_feed_skip_ahead_is_bit_identical_property() {
        // the multi-producer tentpole contract: the dual-feed skip-ahead
        // engine matches its naive oracle bit for bit — cycles, busy
        // counters, shared stall counters AND the per-branch starvation
        // attribution — across B ∈ {1, 4, 16}
        for_all("dual step_round == reference", |g| {
            let batch = [1usize, 4, 16][g.usize(0, 2)];
            let scale = if batch >= 16 { 8 } else { batch };
            let w = RoundWork {
                pixels: g.usize(1, 96 / scale),
                groups: g.usize(1, 8),
                red_steps: g.usize(1, 16),
                bytes_per_step: g.usize(1, 64),
                feed2_bytes_per_step: g.usize(1, 64),
                ddr_bytes_per_cycle: g.f64(0.3, 256.0),
                out_bytes: g.usize(1, 32),
                batch,
            };
            assert_eq!(step_round(&w), step_round_reference(&w), "{w:?}");
        });
    }

    #[test]
    fn dual_feed_skip_ahead_is_bit_identical_on_adversarial_rounds() {
        // corners specific to the second stream: wildly asymmetric
        // per-stream byte costs (the behind-first arbitration starves
        // the cheap stream while the expensive one catches up),
        // red_steps == 1 rollback storms through the dual path, sub-byte
        // buses where neither fetch fits most cycles, and the Add-merge
        // shape (bps_a == bps_b == out_bytes) the IR actually emits.
        let cases: [(usize, usize, usize, usize, usize, f64, usize); 10] = [
            (64, 2, 1, 32, 32, 8.0, 32),      // the real Add shape (nl=32)
            (64, 2, 1, 32, 32, 1000.0, 32),   // Add, DDR ample: port-bound
            (500, 4, 1, 4, 64, 3.0, 64),      // asymmetric feeds, rollback
            (2000, 1, 1, 1, 1, 1.25, 64),     // starved writes, dual drain
            (400, 4, 17, 601, 7, 255.4, 64),  // coprime rates, long residue
            (81, 2, 25, 528, 528, 7.0, 32),   // symmetric heavyweight feeds
            (40, 2, 3, 7, 11, 0.37, 5),       // sub-byte-per-cycle bus
            (200, 1, 2, 3, 5, 0.999_999_9, 4), // just below a whole byte
            (64, 3, 4, 9, 1, 2.5, 6),         // cheap B stream races ahead
            (729, 6, 1, 32, 32, 40.2, 32),    // conv2-scale Add merge
        ];
        for (pixels, groups, red_steps, bps_a, bps_b, ddr, out_bytes) in cases {
            for batch in [1usize, 2, 16] {
                if batch > 1 && pixels * groups * red_steps * batch > 400_000 {
                    continue;
                }
                let w = RoundWork {
                    pixels,
                    groups,
                    red_steps,
                    bytes_per_step: bps_a,
                    feed2_bytes_per_step: bps_b,
                    ddr_bytes_per_cycle: ddr,
                    out_bytes,
                    batch,
                };
                assert_eq!(step_round(&w), step_round_reference(&w), "{w:?}");
            }
        }
    }

    #[test]
    fn dual_feed_conserves_and_attributes_branches() {
        // an Add-merge round must retire every slice, MAC every pair,
        // and fetch BOTH streams in full through the one read port
        let w = RoundWork {
            pixels: 49,
            groups: 4,
            red_steps: 1,
            bytes_per_step: 32,
            feed2_bytes_per_step: 32,
            ddr_bytes_per_cycle: 1000.0,
            out_bytes: 32,
            batch: 2,
        };
        let rep = step_round(&w);
        let outputs = w.total_outputs();
        assert_eq!(rep.wr_busy, outputs);
        assert_eq!(rep.conv_busy, w.total_steps());
        assert_eq!(rep.rd_busy, 2 * w.total_steps(), "both streams fetched in full");
        // the port admits one token per cycle while conv wants a pair:
        // the lane array starves roughly every other cycle, and every
        // starved cycle names at least one empty branch
        assert!(rep.conv_empty_stalls > 0);
        assert!(
            rep.feed_a_empty_stalls + rep.feed_b_empty_stalls >= rep.conv_empty_stalls,
            "every starved cycle must blame a branch"
        );
        // DDR ample + one port: the round is port-bound at ~2 cycles/step
        let analytical = analytical_cycles(&w);
        assert!(analytical as f64 >= 2.0 * w.total_steps() as f64);
        let rel = (rep.cycles as f64 - analytical as f64).abs() / rep.cycles as f64;
        assert!(rel < 0.15, "stepped {} vs analytical {analytical}", rep.cycles);
        // single-feed rounds never charge the branch counters
        let single = RoundWork {
            feed2_bytes_per_step: 0,
            ..w
        };
        let srep = step_round(&single);
        assert_eq!(srep.feed_a_empty_stalls, 0);
        assert_eq!(srep.feed_b_empty_stalls, 0);
    }

    #[test]
    fn add_round_work_has_two_symmetric_feeds() {
        let flow =
            ComputationFlow::extract(&zoo::build("resnet18", false).unwrap()).unwrap();
        let add = flow.layers.iter().find(|l| !l.has_weights()).unwrap();
        let w = layer_round_work_batched(add, &ARRIA_10_GX1150, 199.0, 16, 32, 4);
        assert_eq!(w.bytes_per_step, 32, "feed A reads N_l activation bytes");
        assert_eq!(w.feed2_bytes_per_step, 32, "feed B mirrors it");
        assert_eq!(w.red_steps, 1);
        assert_eq!(w.out_bytes, 32);
        assert_eq!(w.batch, 4);
        // no weight stream: the batch does not amortize bytes_per_step
        let w1 = layer_round_work_batched(add, &ARRIA_10_GX1150, 199.0, 16, 32, 1);
        assert_eq!(w.bytes_per_step, w1.bytes_per_step);
        // ... and the slice-resident override is a no-op on Add rounds
        let res = scheduled_round_work_batched(
            add,
            &ARRIA_10_GX1150,
            199.0,
            16,
            32,
            WeightSchedule::SliceResident,
            4,
        );
        assert_eq!(res, w);
        // conv rounds are untouched by the dual-feed plumbing
        let conv = flow.layers.iter().find(|l| l.is_conv()).unwrap();
        let cw = layer_round_work(conv, &ARRIA_10_GX1150, 199.0, 16, 32);
        assert_eq!(cw.feed2_bytes_per_step, 0);
    }

    #[test]
    fn branched_network_census_is_bit_identical_to_oracle() {
        // whole-network identity on a real residual graph: every round
        // of the tinyres zoo model (Adds included) stepped by both
        // engines at B ∈ {1, 2, 16}
        let flow =
            ComputationFlow::extract(&zoo::build("tinyres", false).unwrap()).unwrap();
        assert!(flow.layers.iter().any(|l| !l.has_weights()), "tinyres has Adds");
        for batch in [1usize, 2, 16] {
            let works =
                network_round_work_batched(&flow, &ARRIA_10_GX1150, 199.0, 4, 4, batch);
            for (w, layer) in works.iter().zip(&flow.layers) {
                assert_eq!(
                    step_round(w),
                    step_round_reference(w),
                    "B={batch} {}",
                    layer.label()
                );
            }
        }
    }

    /// The batched counterpart of the ≥10x CI gate: skip-ahead must
    /// keep its margin over the naive oracle on the B=16 conv2 round
    /// (the round the throughput objective actually steps).
    #[test]
    #[ignore = "perf gate; run in release via CI perf-smoke"]
    fn perf_smoke_skip_ahead_beats_reference_10x_at_batch_16() {
        use std::time::Instant;
        let flow = alexnet_flow();
        let est = estimate(&flow, &ARRIA_10_GX1150, 16, 32);
        let work =
            dominant_round_work_batched(&flow, &ARRIA_10_GX1150, est.fmax_mhz, 16, 32, 16).unwrap();
        // correctness first — a fast wrong answer is no answer
        assert_eq!(step_round(&work), step_round_reference(&work));
        let best = |f: &dyn Fn() -> StepReport, iters: usize| -> f64 {
            let mut best = f64::INFINITY;
            for _ in 0..iters {
                let t0 = Instant::now();
                std::hint::black_box(f());
                best = best.min(t0.elapsed().as_secs_f64());
            }
            best
        };
        let t_ref = best(&|| step_round_reference(&work), 2);
        let t_fast = best(&|| step_round(&work), 2);
        let speedup = t_ref / t_fast.max(1e-12);
        assert!(
            speedup >= 10.0,
            "batched skip-ahead speedup {speedup:.1}x < 10x (ref {t_ref:.4}s, fast {t_fast:.6}s)"
        );
    }

    /// CI perf-smoke gate (run with `--ignored` in release mode): the
    /// skip-ahead engine must beat the naive reference by ≥ 10x on the
    /// alexnet-conv2 dominant round — the generous bound of the PR-3
    /// acceptance criteria so runner noise can't flake it (the measured
    /// iteration-count ratio is ~300x).
    #[test]
    #[ignore = "perf gate; run in release via CI perf-smoke"]
    fn perf_smoke_skip_ahead_beats_reference_10x() {
        use std::time::Instant;
        let flow = alexnet_flow();
        let est = estimate(&flow, &ARRIA_10_GX1150, 16, 32);
        let work = dominant_round_work(&flow, &ARRIA_10_GX1150, est.fmax_mhz, 16, 32).unwrap();
        // correctness first — a fast wrong answer is no answer
        assert_eq!(step_round(&work), step_round_reference(&work));
        let best = |f: &dyn Fn() -> StepReport, iters: usize| -> f64 {
            let mut best = f64::INFINITY;
            for _ in 0..iters {
                let t0 = Instant::now();
                std::hint::black_box(f());
                best = best.min(t0.elapsed().as_secs_f64());
            }
            best
        };
        let t_ref = best(&|| step_round_reference(&work), 3);
        let t_fast = best(&|| step_round(&work), 3);
        let speedup = t_ref / t_fast.max(1e-12);
        assert!(
            speedup >= 10.0,
            "skip-ahead speedup {speedup:.1}x < 10x (ref {t_ref:.4}s, fast {t_fast:.6}s)"
        );
    }
}
