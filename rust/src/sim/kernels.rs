//! Cycle-stepped simulation of one pipelined round (paper Fig. 3c/5).
//!
//! Four stages — memory read, conv lane array, pool, memory write —
//! connected by [`Pipe`]s, stepped one kernel clock at a time in vector
//! granularity: a token is one `N_i`-wide vector MAC's worth of work on
//! the conv pipe, one output element per lane elsewhere.
//!
//! This stepping model is the ground truth the analytical round model in
//! [`super::engine`] is validated against (property test: the two agree
//! within a few percent on randomized small rounds). Table-scale runs use
//! the analytical model so regenerating the paper's tables stays
//! interactive; the stepper also feeds the stall/backpressure statistics
//! reported by `cnn2gate synth --report`.

use crate::estimator::model::PIPE_DEPTH;
use crate::estimator::Device;
use crate::ir::ComputationFlow;

use super::pipe::Pipe;

/// Work description of one round at vector granularity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundWork {
    /// Output pixels (OH*OW for conv rounds, 1 for FC).
    pub pixels: usize,
    /// Output-feature groups: ceil(out_features / N_l).
    pub groups: usize,
    /// Reduction steps per output: ceil(reduction_dim / N_i).
    pub red_steps: usize,
    /// Bytes the memory-read kernel must fetch per reduction step
    /// (feature vector broadcast + per-lane weight vectors).
    pub bytes_per_step: usize,
    /// DDR bytes deliverable per cycle at the kernel clock.
    pub ddr_bytes_per_cycle: f64,
    /// Output bytes written per (pixel, group) completion.
    pub out_bytes: usize,
}

/// Per-stage cycle/stall census from a stepped run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StepReport {
    pub cycles: u64,
    pub rd_busy: u64,
    pub conv_busy: u64,
    pub wr_busy: u64,
    pub rd_to_conv_full_stalls: u64,
    pub conv_to_wr_full_stalls: u64,
    pub conv_empty_stalls: u64,
}

impl StepReport {
    pub fn conv_utilization(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.conv_busy as f64 / self.cycles as f64
    }
}

/// Step one round to completion and return the census.
///
/// Stage behaviour per cycle:
/// * mem_read: if DDR credit allows and the feed pipe has room, produce
///   one vector token (consuming `bytes_per_step` of DDR credit).
/// * conv: pop one token per cycle; after `red_steps` tokens one output
///   group-slice (N_l elements) is complete and pushed to the pool pipe.
/// * pool+write: drain one output token per cycle, consuming DDR write
///   credit (pool is pass-through at this granularity; its comparators
///   never run slower than one element/lane/cycle).
pub fn step_round(work: &RoundWork) -> StepReport {
    let total_outputs = work.pixels * work.groups; // group-slices to emit
    let total_steps = total_outputs * work.red_steps; // vector MACs
    let mut feed = Pipe::new("rd->conv", PIPE_DEPTH.max(1));
    let mut out = Pipe::new("conv->wr", PIPE_DEPTH.max(1));
    let mut rep = StepReport::default();

    let mut produced_steps = 0usize; // vectors fetched
    let mut consumed_steps = 0usize; // vectors MACed
    let mut emitted = 0usize; // group-slices pushed
    let mut written = 0usize; // group-slices written back
    let mut red_progress = 0usize;
    let mut ddr_credit = 0f64; // bytes available this cycle

    while written < total_outputs {
        rep.cycles += 1;
        ddr_credit += work.ddr_bytes_per_cycle;

        // -- memory write (drains DDR credit first: writes have priority
        //    so the pipeline can always retire) --
        if !out.is_empty() && ddr_credit >= work.out_bytes as f64 {
            out.pop();
            written += 1;
            ddr_credit -= work.out_bytes as f64;
            rep.wr_busy += 1;
        }

        // -- conv lane array --
        if consumed_steps < total_steps {
            if let Some(_tok) = feed.pop() {
                consumed_steps += 1;
                red_progress += 1;
                rep.conv_busy += 1;
                if red_progress == work.red_steps {
                    red_progress = 0;
                    if out.push(emitted as u64) {
                        emitted += 1;
                    } else {
                        // output pipe full: the completed slice re-queues
                        // next cycle by rolling the reduction back one
                        // step (models the lane array holding its result)
                        consumed_steps -= 1;
                        red_progress = work.red_steps - 1;
                        rep.conv_to_wr_full_stalls += 1;
                    }
                }
            } else {
                rep.conv_empty_stalls += 1;
            }
        }

        // -- memory read --
        if produced_steps < total_steps && ddr_credit >= work.bytes_per_step as f64 {
            if feed.push(produced_steps as u64) {
                produced_steps += 1;
                ddr_credit -= work.bytes_per_step as f64;
                rep.rd_busy += 1;
            } else {
                rep.rd_to_conv_full_stalls += 1;
            }
        }

        // credit does not accumulate indefinitely (DDR can't time-travel),
        // but the cap must admit the largest single transaction or a slow
        // bus could never complete it
        let cap = (work.ddr_bytes_per_cycle * 8.0)
            .max(2.0 * work.bytes_per_step.max(work.out_bytes) as f64);
        ddr_credit = ddr_credit.min(cap);
    }
    rep
}

/// Work description of a flow's dominant (most-MAC) round at option
/// (N_i, N_l) — what [`crate::dse::eval`]'s stepped fidelity mode feeds
/// the cycle-accurate simulator. One vector step fetches `N_i` feature
/// bytes broadcast to the lanes plus `N_i × N_l` weight bytes (int8
/// codes); each completed group-slice retires `N_l` output bytes.
/// Returns `None` for an empty flow.
pub fn dominant_round_work(
    flow: &ComputationFlow,
    device: &Device,
    fmax_mhz: f64,
    ni: usize,
    nl: usize,
) -> Option<RoundWork> {
    let layer = flow.layers.iter().max_by_key(|l| l.macs())?;
    Some(RoundWork {
        pixels: layer.out_pixels().max(1),
        groups: layer.out_features().div_ceil(nl).max(1),
        red_steps: layer.reduction_dim().div_ceil(ni).max(1),
        bytes_per_step: ni * (nl + 1),
        ddr_bytes_per_cycle: device.ddr_gbytes_per_s * 1e9 / (fmax_mhz * 1e6),
        out_bytes: nl,
    })
}

/// The analytical cycle count the engine uses (see engine.rs for the
/// closed form); exposed here so the property test can compare.
pub fn analytical_cycles(work: &RoundWork) -> u64 {
    let total_outputs = (work.pixels * work.groups) as u64;
    let compute = total_outputs * work.red_steps as u64;
    let rd_bytes = compute as f64 * work.bytes_per_step as f64;
    let wr_bytes = total_outputs as f64 * work.out_bytes as f64;
    let ddr = ((rd_bytes + wr_bytes) / work.ddr_bytes_per_cycle).ceil() as u64;
    compute.max(ddr) + work.red_steps as u64 + 2 // + pipeline fill
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::for_all;

    #[test]
    fn compute_bound_round_is_step_limited() {
        let w = RoundWork {
            pixels: 64,
            groups: 2,
            red_steps: 10,
            bytes_per_step: 4,
            ddr_bytes_per_cycle: 1000.0, // DDR never the limit
            out_bytes: 4,
        };
        let rep = step_round(&w);
        let ideal = (64 * 2 * 10) as u64;
        assert!(rep.cycles >= ideal);
        assert!(rep.cycles < ideal + 2 * PIPE_DEPTH as u64);
        assert!(rep.conv_utilization() > 0.9, "{}", rep.conv_utilization());
    }

    #[test]
    fn memory_bound_round_shows_empty_stalls() {
        let w = RoundWork {
            pixels: 32,
            groups: 2,
            red_steps: 8,
            bytes_per_step: 64,
            ddr_bytes_per_cycle: 8.0, // 8x slower than compute needs
            out_bytes: 8,
        };
        let rep = step_round(&w);
        assert!(rep.conv_empty_stalls > 0);
        assert!(rep.conv_utilization() < 0.5);
        // cycles ≈ bytes / bandwidth
        let bytes = (32 * 2 * 8 * 64 + 32 * 2 * 8) as f64;
        let expect = bytes / 8.0;
        let ratio = rep.cycles as f64 / expect;
        assert!((0.9..1.3).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn analytical_matches_stepped_within_tolerance() {
        for_all("analytical ≈ stepped cycles", |g| {
            let w = RoundWork {
                pixels: g.usize(1, 96),
                groups: g.usize(1, 8),
                red_steps: g.usize(1, 64),
                bytes_per_step: g.usize(1, 128),
                ddr_bytes_per_cycle: g.f64(1.0, 256.0),
                out_bytes: g.usize(1, 32),
            };
            let stepped = step_round(&w).cycles as f64;
            let analytical = analytical_cycles(&w) as f64;
            let rel = (stepped - analytical).abs() / stepped.max(1.0);
            // tiny rounds are dominated by pipeline fill, so allow an
            // absolute slack of one fill in addition to the relative band
            let abs_ok = (stepped - analytical).abs() <= (w.red_steps + 64) as f64;
            assert!(
                rel < 0.15 || abs_ok,
                "stepped {stepped} vs analytical {analytical} (rel {rel:.3}) for {w:?}"
            );
        });
    }

    #[test]
    fn dominant_round_is_alexnet_conv2() {
        use crate::estimator::device::ARRIA_10_GX1150;
        use crate::onnx::zoo;
        let flow = ComputationFlow::extract(&zoo::build("alexnet", false).unwrap()).unwrap();
        let w = dominant_round_work(&flow, &ARRIA_10_GX1150, 199.0, 16, 32).unwrap();
        // conv2 carries the most MACs: 27x27 pixels, 192 features over a
        // 1600-long reduction — the "alexnet-conv2-ish" hotpath workload
        assert_eq!(w.pixels, 729);
        assert_eq!(w.groups, 6);
        assert_eq!(w.red_steps, 100);
        assert_eq!(w.out_bytes, 32);
        assert!(w.ddr_bytes_per_cycle > 0.0);
    }

    #[test]
    fn conservation_all_outputs_written() {
        let w = RoundWork {
            pixels: 17,
            groups: 3,
            red_steps: 5,
            bytes_per_step: 12,
            ddr_bytes_per_cycle: 20.0,
            out_bytes: 6,
        };
        let rep = step_round(&w);
        assert_eq!(rep.wr_busy as usize, 17 * 3);
        assert_eq!(rep.conv_busy as usize, 17 * 3 * 5);
    }
}
