//! Whole-network latency simulation (paper Table 1, Fig. 6).
//!
//! Each fused round (see [`crate::ir::flow`]) executes on the pipelined
//! kernel architecture at a chosen (N_i, N_l):
//!
//! * conv lane array: `pixels * groups * red_steps` vector cycles, plus a
//!   per-(row, group) pipeline refill while the window slides to the next
//!   output row;
//! * memory read: weight slices stream once per group pass (the
//!   estimator's on-chip weight buffer holds the active slice); feature
//!   vectors are broadcast from the feature buffer; if a round's input
//!   exceeds the feature-buffer budget it is re-fetched per group;
//! * memory write: output feature codes retire at DDR bandwidth;
//! * the round's cycle count is the max of the compute and DDR streams
//!   (they overlap in the deeply pipelined design), divided by the
//!   family's duty factor (calibrated — DESIGN.md §8).
//!
//! The closed form is validated against the cycle-stepped simulator in
//! [`super::kernels`] by a property test there.

use crate::estimator::{estimate, Device, ResourceEstimate};
use crate::ir::{ComputationFlow, FusedLayer, LayerKind};

/// Pipeline refill cycles per (output row, group) transition.
const ROW_REFILL_CYCLES: u64 = 40;

/// Timing of one fused round.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerTiming {
    pub index: usize,
    pub label: String,
    pub is_conv: bool,
    pub macs: u64,
    pub compute_cycles: u64,
    pub ddr_cycles: u64,
    /// max(compute, ddr) / duty — what the round actually takes.
    pub cycles: u64,
    pub millis: f64,
    /// true when the DDR stream, not the lane array, set the pace.
    pub memory_bound: bool,
}

/// Whole-network simulation result.
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    pub model: String,
    pub device: String,
    pub ni: usize,
    pub nl: usize,
    pub fmax_mhz: f64,
    pub layers: Vec<LayerTiming>,
    pub total_cycles: u64,
    pub total_millis: f64,
    pub gops: f64,
}

impl SimReport {
    /// Achieved throughput (GOp/s) at batch 1 — the paper's Performance
    /// row in Tables 3-4.
    pub fn gops_per_s(&self) -> f64 {
        crate::metrics::gops_per_s(self.gops, self.total_millis)
    }

    /// Peak lane-array throughput at this option/fmax (GOp/s).
    pub fn peak_gops_per_s(&self) -> f64 {
        2.0 * (self.ni * self.nl) as f64 * self.fmax_mhz * 1e6 / 1e9
    }

    /// Fraction of peak achieved — the §Perf efficiency ratio.
    pub fn efficiency(&self) -> f64 {
        self.gops_per_s() / self.peak_gops_per_s()
    }
}

/// Simulate one round. Exposed for Fig. 6 and the ablation benches.
pub fn simulate_layer(
    layer: &FusedLayer,
    device: &Device,
    est: &ResourceEstimate,
    ni: usize,
    nl: usize,
) -> LayerTiming {
    let red = layer.reduction_dim();
    let out_f = layer.out_features();
    let pixels = layer.out_pixels() as u64;
    let groups = out_f.div_ceil(nl) as u64;
    let red_steps = red.div_ceil(ni) as u64;

    let label = layer.label();
    let rows = match &layer.kind {
        LayerKind::ConvPool { conv_out_hw, .. } => conv_out_hw.0 as u64,
        LayerKind::DepthwiseConvPool { conv_out_hw, .. } => conv_out_hw.0 as u64,
        LayerKind::Add { hw, .. } => hw.0 as u64,
        LayerKind::Fc { .. } => 1,
    };

    // -- compute stream ----------------------------------------------------
    let compute = pixels * groups * red_steps + rows * groups * ROW_REFILL_CYCLES;

    // -- DDR stream ----------------------------------------------------------
    let bytes_per_cycle = device.ddr_gbytes_per_s * 1e9 / (est.fmax_mhz * 1e6);
    let ddr = (round_ddr_bytes(layer, device, nl, 1) / bytes_per_cycle).ceil() as u64;

    let raw = compute.max(ddr);
    let cycles = (raw as f64 / device.duty_factor).ceil() as u64;
    let millis = cycles as f64 / (est.fmax_mhz * 1e6) * 1e3;
    LayerTiming {
        index: layer.index,
        label,
        is_conv: layer.is_conv(),
        macs: layer.macs(),
        compute_cycles: compute,
        ddr_cycles: ddr,
        cycles,
        millis,
        memory_bound: ddr > compute,
    }
}

/// THE per-round DDR byte formula — the single place the analytical
/// model charges a round's traffic, shared by [`simulate_layer`]
/// (`batch = 1`) and [`simulate_batched`], and the closed-form
/// counterpart of the stepped model's
/// [`super::kernels::bytes_per_step_with_reuse`]:
///
/// * weight slices stream once per group pass (int8 codes) and are
///   **held across the whole batch** — the cross-frame reuse that makes
///   batching pay;
/// * features are read once per frame, unless the input exceeds the
///   feature-buffer budget, in which case every group pass re-fetches
///   its tiles (per frame);
/// * output feature codes retire once per frame.
fn round_ddr_bytes(layer: &FusedLayer, device: &Device, nl: usize, batch: usize) -> f64 {
    let red = layer.reduction_dim();
    let groups = layer.out_features().div_ceil(nl) as u64;
    // Add merges carry no weight tensor; their traffic is both operand
    // streams (input_elems already counts 2×) plus the write-back
    let weight_bytes = if layer.has_weights() {
        (groups * (red * nl) as u64) as f64
    } else {
        0.0
    };
    let in_bytes = layer.input_elems() as f64;
    let feat_budget_bytes = device.family.consts().feat_budget_frac * device.mem_bits as f64 / 8.0;
    let feature_bytes = if in_bytes > feat_budget_bytes {
        in_bytes * groups as f64
    } else {
        in_bytes
    };
    let out_bytes = layer.output_elems() as f64;
    weight_bytes + (feature_bytes + out_bytes) * batch.max(1) as f64
}

/// Simulate the full network at option (ni, nl) on `device`.
pub fn simulate(
    flow: &ComputationFlow,
    device: &Device,
    ni: usize,
    nl: usize,
) -> SimReport {
    let est = estimate(flow, device, ni, nl);
    simulate_with_estimate(flow, device, &est)
}

/// Simulate reusing an already-computed resource estimate (the option is
/// the estimate's own (ni, nl)) — lets dse::eval score a candidate with
/// a single estimator call instead of re-deriving it here.
pub fn simulate_with_estimate(
    flow: &ComputationFlow,
    device: &Device,
    est: &ResourceEstimate,
) -> SimReport {
    let (ni, nl) = (est.ni, est.nl);
    let layers: Vec<LayerTiming> = flow
        .layers
        .iter()
        .map(|l| simulate_layer(l, device, est, ni, nl))
        .collect();
    let total_cycles = layers.iter().map(|l| l.cycles).sum();
    let total_millis = layers.iter().map(|l| l.millis).sum();
    SimReport {
        model: flow.model_name.clone(),
        device: device.name.to_string(),
        ni,
        nl,
        fmax_mhz: est.fmax_mhz,
        layers,
        total_cycles,
        total_millis,
        gops: flow.gops(),
    }
}

/// Batched execution (paper §5: "those latency reports are measured in
/// the favorable batch size (e.g. 16). Increasing batch size can make
/// more parallelism available to the algorithm that can lead to higher
/// throughput").
///
/// In the pipelined architecture a batch shares each round's weight
/// stream: the memory-read kernel fetches the slice once and `batch`
/// frames flow through the lanes back-to-back, so the DDR weight traffic
/// amortizes while compute scales linearly. FC rounds (weight-bound at
/// batch 1) benefit the most — exactly why PipeCNN's headline numbers
/// used batch 16.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchReport {
    pub batch: usize,
    pub total_millis: f64,
    pub millis_per_frame: f64,
    pub gops_per_s: f64,
    pub layers: Vec<LayerTiming>,
}

impl BatchReport {
    /// Steady-state serving throughput at this batch size: the batch's
    /// frames over its makespan.
    pub fn frames_per_s(&self) -> f64 {
        if self.millis_per_frame <= 0.0 {
            return 0.0;
        }
        1e3 / self.millis_per_frame
    }
}

/// Simulate a batch of `batch` frames at option (ni, nl). The per-layer
/// timings carry the *batched* compute/DDR streams (one round pass over
/// all B frames) and derive from the same [`round_ddr_bytes`] formula as
/// [`simulate_layer`] — at `batch = 1` the two agree exactly.
pub fn simulate_batched(
    flow: &ComputationFlow,
    device: &Device,
    ni: usize,
    nl: usize,
    batch: usize,
) -> BatchReport {
    let batch = batch.max(1);
    let est = estimate(flow, device, ni, nl);
    let bytes_per_cycle = device.ddr_gbytes_per_s * 1e9 / (est.fmax_mhz * 1e6);
    let mut layers = Vec::with_capacity(flow.layers.len());
    let mut total_cycles = 0u64;
    for layer in &flow.layers {
        let single = simulate_layer(layer, device, &est, ni, nl);
        // compute stream scales with the batch; the weight stream inside
        // round_ddr_bytes is fetched once and held across the B frames
        let compute = single.compute_cycles * batch as u64;
        let ddr = (round_ddr_bytes(layer, device, nl, batch) / bytes_per_cycle).ceil() as u64;
        let raw = compute.max(ddr);
        let cycles = (raw as f64 / device.duty_factor).ceil() as u64;
        total_cycles += cycles;
        layers.push(LayerTiming {
            compute_cycles: compute,
            ddr_cycles: ddr,
            cycles,
            millis: cycles as f64 / (est.fmax_mhz * 1e6) * 1e3,
            memory_bound: ddr > compute,
            ..single
        });
    }
    let total_millis = total_cycles as f64 / (est.fmax_mhz * 1e6) * 1e3;
    let per_frame = total_millis / batch as f64;
    BatchReport {
        batch,
        total_millis,
        millis_per_frame: per_frame,
        gops_per_s: flow.gops() / (per_frame / 1e3),
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA5};
    use crate::onnx::zoo;

    fn flow(name: &str) -> ComputationFlow {
        ComputationFlow::extract(&zoo::build(name, false).unwrap()).unwrap()
    }

    #[test]
    fn alexnet_arria10_matches_table1() {
        let rep = simulate(&flow("alexnet"), &ARRIA_10_GX1150, 16, 32);
        // paper: 18 ms (18.24 in Table 3)
        assert!(
            (rep.total_millis - 18.24).abs() < 2.0,
            "alexnet a10 = {:.2} ms",
            rep.total_millis
        );
        // paper Table 3: 80.04 GOp/s
        let gops = rep.gops / (rep.total_millis / 1e3);
        assert!((gops - 80.0).abs() < 10.0, "gops {gops}");
    }

    #[test]
    fn vgg16_arria10_matches_table1() {
        let rep = simulate(&flow("vgg16"), &ARRIA_10_GX1150, 16, 32);
        // paper: 205 ms
        assert!(
            (rep.total_millis - 205.0).abs() < 35.0,
            "vgg a10 = {:.2} ms",
            rep.total_millis
        );
    }

    #[test]
    fn alexnet_cyclonev_matches_table1() {
        let rep = simulate(&flow("alexnet"), &CYCLONE_V_5CSEMA5, 8, 8);
        // paper: 153 ms
        assert!(
            (rep.total_millis - 153.0).abs() < 20.0,
            "alexnet cv = {:.2} ms",
            rep.total_millis
        );
    }

    #[test]
    fn vgg_cyclonev_order_of_magnitude() {
        let rep = simulate(&flow("vgg16"), &CYCLONE_V_5CSEMA5, 8, 8);
        // paper: 4.26 s; structural model lands in the same regime
        assert!(
            rep.total_millis > 2000.0 && rep.total_millis < 7000.0,
            "vgg cv = {:.0} ms",
            rep.total_millis
        );
    }

    #[test]
    fn fig6_breakdown_shape() {
        // Fig 6: 8 rounds (5 fused conv/pool + 3 FC); execution time
        // shrinks with the feature dimensions through the conv stack
        // (conv2 carries the most MACs — 224M vs conv1's 105M — so the
        // decreasing trend runs from L2), and the FC tail is small on the
        // Arria 10.
        let rep = simulate(&flow("alexnet"), &ARRIA_10_GX1150, 16, 32);
        assert_eq!(rep.layers.len(), 8);
        let t: Vec<f64> = rep.layers.iter().map(|l| l.millis).collect();
        // conv stack decreases from its L2 peak as feature dims shrink
        assert!(t[1] >= t[2] && t[2] >= t[4], "conv tail must decrease: {t:?}");
        assert!(t[1] >= t[0], "conv2 carries the most MACs");
        // FC tail decreases with the weight-matrix size
        assert!(t[5] >= t[6] && t[6] >= t[7], "fc tail must decrease: {t:?}");
        // FC rounds are memory-bound (weights stream once per frame);
        // conv rounds are lane-bound
        assert!(rep.layers[5..].iter().all(|l| l.memory_bound));
        assert!(rep.layers[..5].iter().all(|l| !l.memory_bound));
    }

    #[test]
    fn more_lanes_never_slower() {
        let f = flow("alexnet");
        let a = simulate(&f, &ARRIA_10_GX1150, 8, 8).total_cycles;
        let b = simulate(&f, &ARRIA_10_GX1150, 16, 32).total_cycles;
        assert!(b < a);
    }

    #[test]
    fn gops_per_s_unit_chain_regression() {
        // the seed multiplied and divided by 1e9 three times; the value
        // is pinned to the plain gops / seconds semantics, bit for bit
        let rep = simulate(&flow("alexnet"), &ARRIA_10_GX1150, 16, 32);
        let expect = rep.gops / (rep.total_millis / 1e3);
        assert_eq!(rep.gops_per_s().to_bits(), expect.to_bits());
        assert_eq!(
            rep.gops_per_s().to_bits(),
            crate::metrics::gops_per_s(rep.gops, rep.total_millis).to_bits()
        );
        // paper Table 3 anchor: ~80 GOp/s for AlexNet on the Arria 10
        assert!((rep.gops_per_s() - 80.0).abs() < 10.0, "{}", rep.gops_per_s());
    }

    #[test]
    fn efficiency_below_one() {
        let rep = simulate(&flow("vgg16"), &ARRIA_10_GX1150, 16, 32);
        assert!(rep.efficiency() > 0.1 && rep.efficiency() < 1.0);
    }

    #[test]
    fn batching_improves_throughput_monotonically() {
        // paper §5: favorable batch sizes raise throughput
        let f = flow("alexnet");
        let mut last = 0.0;
        for batch in [1, 2, 4, 8, 16] {
            let rep = simulate_batched(&f, &ARRIA_10_GX1150, 16, 32, batch);
            assert!(
                rep.gops_per_s >= last - 1e-9,
                "batch {batch}: {} < {last}",
                rep.gops_per_s
            );
            last = rep.gops_per_s;
        }
        // batch 16 must beat batch 1 substantially (FC weights amortized)
        let b1 = simulate_batched(&f, &ARRIA_10_GX1150, 16, 32, 1);
        let b16 = simulate_batched(&f, &ARRIA_10_GX1150, 16, 32, 16);
        assert!(b16.gops_per_s > 1.3 * b1.gops_per_s);
        // batch 1 must agree with the frame simulator
        let single = simulate(&f, &ARRIA_10_GX1150, 16, 32);
        assert!((b1.total_millis - single.total_millis).abs() / single.total_millis < 0.02);
    }

    #[test]
    fn batched_layer_timings_share_the_single_frame_formula() {
        // one shared per-round byte formula: at batch 1 every per-layer
        // timing matches simulate() exactly — including the
        // feature-budget re-fetch rule simulate_batched used to drop
        // (VGG's early conv inputs exceed the Arria 10 feature budget)
        for name in ["alexnet", "vgg16"] {
            let f = flow(name);
            let single = simulate(&f, &ARRIA_10_GX1150, 16, 32);
            let b1 = simulate_batched(&f, &ARRIA_10_GX1150, 16, 32, 1);
            assert_eq!(b1.layers, single.layers, "{name}");
            let rel = (b1.total_millis - single.total_millis).abs() / single.total_millis;
            assert!(rel < 1e-12, "{name}: {rel}");
        }
        // frames/s is the inverse amortized frame latency
        let b16 = simulate_batched(&flow("alexnet"), &ARRIA_10_GX1150, 16, 32, 16);
        let fps = b16.frames_per_s();
        assert!((fps - 1e3 / b16.millis_per_frame).abs() / fps < 1e-12);
        // the batched timings carry the batched streams, not frame ones
        let single = simulate(&flow("alexnet"), &ARRIA_10_GX1150, 16, 32);
        for (b, s) in b16.layers.iter().zip(&single.layers) {
            assert_eq!(b.compute_cycles, 16 * s.compute_cycles, "{}", s.label);
            assert!(b.ddr_cycles < 16 * s.ddr_cycles, "{}", s.label);
        }
    }

    #[test]
    fn branch_families_simulate_end_to_end() {
        // the analytical model must speak the new families: residual
        // Adds are weight-free rounds, depthwise rounds reduce over k²
        // alone, and both networks produce finite positive timings
        for name in ["resnet18", "mobilenetv1", "tinyres"] {
            let f = flow(name);
            let rep = simulate(&f, &ARRIA_10_GX1150, 16, 32);
            assert_eq!(rep.layers.len(), f.layers.len(), "{name}");
            assert!(rep.total_millis > 0.0 && rep.total_millis.is_finite(), "{name}");
            let b4 = simulate_batched(&f, &ARRIA_10_GX1150, 16, 32, 4);
            assert!(b4.gops_per_s > 0.0, "{name}");
        }
        let res = flow("resnet18");
        let rep = simulate(&res, &ARRIA_10_GX1150, 16, 32);
        // an Add round's DDR traffic is pure activations: far below a
        // same-size dense conv's weight-laden stream — and it never
        // dominates a stage's 3x3 convs
        let add_t = rep
            .layers
            .iter()
            .zip(&res.layers)
            .find(|(_, l)| !l.has_weights())
            .map(|(t, _)| t)
            .unwrap();
        let conv2_t = &rep.layers[2];
        assert!(add_t.millis < conv2_t.millis, "{} vs {}", add_t.millis, conv2_t.millis);
    }

    #[test]
    fn batched_fc_rounds_become_compute_bound() {
        let f = flow("alexnet");
        let b16 = simulate_batched(&f, &ARRIA_10_GX1150, 16, 32, 16);
        // at batch 16 the fc1 weight stream is amortized 16x; the round
        // flips from memory- to compute-bound
        assert!(!b16.layers[5].memory_bound, "fc1 should be compute-bound at batch 16");
    }
}
