//! OpenCL pipes as FIFOs (paper §3.2.2, Fig. 3b).
//!
//! "In FPGAs, pipes are implemented as FIFOs" — this module is the
//! cycle-stepped FIFO used by the stage simulator in [`super::kernels`],
//! with full/empty stall accounting so backpressure between the deeply
//! pipelined kernels is observable.
//!
//! Only the naive oracle (`step_round_reference`) steps real token-level
//! `Pipe`s; the epoch skip-ahead engine models each pipe by its
//! occupancy alone (tokens are opaque, so occupancy fully determines
//! full/empty behaviour) — that compact state is what makes steady-state
//! recurrence detectable and the fast-forward exact.

use std::collections::VecDeque;

/// A bounded FIFO carrying opaque work tokens, with stall counters.
#[derive(Debug, Clone)]
pub struct Pipe {
    pub name: &'static str,
    capacity: usize,
    queue: VecDeque<u64>,
    /// Cycles a producer wanted to push but the pipe was full.
    pub full_stalls: u64,
    /// Cycles a consumer wanted to pop but the pipe was empty.
    pub empty_stalls: u64,
    /// Total tokens that transited the pipe.
    pub transferred: u64,
    /// High-water mark of occupancy.
    pub max_occupancy: usize,
}

impl Pipe {
    pub fn new(name: &'static str, capacity: usize) -> Self {
        assert!(capacity > 0, "pipe capacity must be positive");
        Pipe {
            name,
            capacity,
            queue: VecDeque::with_capacity(capacity),
            full_stalls: 0,
            empty_stalls: 0,
            transferred: 0,
            max_occupancy: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.queue.len() >= self.capacity
    }

    /// Try to push a token; on a full pipe, count a stall and refuse.
    pub fn push(&mut self, token: u64) -> bool {
        if self.is_full() {
            self.full_stalls += 1;
            false
        } else {
            self.queue.push_back(token);
            self.max_occupancy = self.max_occupancy.max(self.queue.len());
            true
        }
    }

    /// Try to pop a token; on an empty pipe, count a stall.
    pub fn pop(&mut self) -> Option<u64> {
        match self.queue.pop_front() {
            Some(t) => {
                self.transferred += 1;
                Some(t)
            }
            None => {
                self.empty_stalls += 1;
                None
            }
        }
    }

    /// Occupancy as a fraction of capacity.
    pub fn fill_ratio(&self) -> f64 {
        self.queue.len() as f64 / self.capacity as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::for_all;

    #[test]
    fn fifo_order_preserved() {
        let mut p = Pipe::new("t", 4);
        for i in 0..4 {
            assert!(p.push(i));
        }
        assert!(!p.push(99)); // full
        assert_eq!(p.full_stalls, 1);
        for i in 0..4 {
            assert_eq!(p.pop(), Some(i));
        }
        assert_eq!(p.pop(), None);
        assert_eq!(p.empty_stalls, 1);
        assert_eq!(p.transferred, 4);
    }

    #[test]
    fn conservation_property() {
        for_all("tokens in == tokens out + resident", |g| {
            let cap = g.usize(1, 32);
            let mut p = Pipe::new("prop", cap);
            let mut pushed = 0u64;
            let mut popped = 0u64;
            for _ in 0..g.usize(1, 500) {
                if g.bool() {
                    if p.push(pushed) {
                        pushed += 1;
                    }
                } else if p.pop().is_some() {
                    popped += 1;
                }
            }
            assert_eq!(pushed, popped + p.len() as u64);
            assert!(p.max_occupancy <= cap);
        });
    }

    #[test]
    #[should_panic(expected = "capacity must be positive")]
    fn zero_capacity_rejected() {
        Pipe::new("bad", 0);
    }
}
