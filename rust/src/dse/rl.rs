//! RL-DSE: reinforcement-learning design-space exploration (paper §4.4).
//!
//! A tabular Q-learning agent over the (N_i, N_l) option grid:
//!
//! * state  = (index into ni options, index into nl options)
//! * actions = {increase N_l, increase N_i, increase both} — the paper's
//!   action set; a variable that would exceed its maximum wraps to its
//!   initial value ("the variable is reset to its initial value")
//! * reward = Algorithm 1 (see [`super::reward`]), β = 0.01
//! * discount γ = 0.1, time-limited episodes (paper cites [34])
//!
//! Estimator results are memoized at two levels: a run-local map replays
//! the shaped outcome of revisited states (each *unique* option costs
//! one modeled Intel-compiler query — what makes RL-DSE ~25% faster than
//! BF-DSE on the paper's grid), and the process-wide [`super::eval`]
//! cache deduplicates the underlying estimator + simulator work across
//! episodes, runs and explorers, so only wall time (never the modeled
//! query count) changes.

use std::collections::HashMap;
use std::time::Instant;

use crate::estimator::{query_seconds, Device, Thresholds};
use crate::ir::ComputationFlow;
use crate::util::rng::Rng;

use super::brute::DseResult;
use super::eval::{self, EvalRequest, Evaluator, Fidelity};
use super::options::OptionSpace;
use super::reward::RewardShaper;

/// Hyper-parameters (paper values where given, conventional elsewhere).
#[derive(Debug, Clone, Copy)]
pub struct RlConfig {
    /// Discount factor γ (paper: 0.1).
    pub gamma: f64,
    /// Learning rate α.
    pub alpha: f64,
    /// ε-greedy exploration rate.
    pub epsilon: f64,
    /// Time-limited episodes: iterations per episode.
    pub steps_per_episode: usize,
    /// Number of episodes.
    pub episodes: usize,
    /// PRNG seed (deterministic runs).
    pub seed: u64,
}

impl Default for RlConfig {
    fn default() -> Self {
        RlConfig {
            gamma: 0.1,
            alpha: 0.5,
            epsilon: 0.35,
            steps_per_episode: 8,
            episodes: 4,
            seed: 0xD5E,
        }
    }
}

const N_ACTIONS: usize = 3; // inc nl | inc ni | inc both

/// Run RL-DSE through the process-wide evaluator. Returns the same
/// [`DseResult`] shape as BF-DSE.
pub fn explore(
    flow: &ComputationFlow,
    device: &Device,
    thresholds: Thresholds,
    cfg: RlConfig,
) -> DseResult {
    explore_with(eval::global(), flow, device, thresholds, cfg)
}

/// Run RL-DSE through a caller-provided evaluator (isolated caches for
/// deterministic hit-count tests).
pub fn explore_with(
    evaluator: &Evaluator,
    flow: &ComputationFlow,
    device: &Device,
    thresholds: Thresholds,
    cfg: RlConfig,
) -> DseResult {
    explore_with_fidelity(
        evaluator,
        flow,
        device,
        thresholds,
        cfg,
        EvalRequest::at(Fidelity::Analytical),
    )
}

/// RL-DSE under an explicit [`EvalRequest`]. With `req.census_gamma ==
/// 0` the agent's trajectory, choice and query count are
/// fidelity-independent (rewards come from the estimator); stepped
/// modes additionally leave a cycle-accurate census in the memo for
/// every state the agent actually visited. With γ > 0 under
/// `SteppedFullNetwork` the Q-learning reward becomes the shaped
/// `β·F_avg − γ·bottleneck_stall_fraction` of Algorithm 1's census
/// extension ([`RewardShaper::eval_censused`]).
pub fn explore_with_fidelity(
    evaluator: &Evaluator,
    flow: &ComputationFlow,
    device: &Device,
    thresholds: Thresholds,
    cfg: RlConfig,
    req: EvalRequest,
) -> DseResult {
    // analysis: allow(nondet, wall-clock feeds only the volatile wall_seconds field, never ranking or rendered bytes)
    let t0 = Instant::now();
    let space = OptionSpace::from_flow(flow);
    let (ni_n, nl_n) = (space.ni.len(), space.nl.len());
    let mut rng = Rng::new(cfg.seed);
    let mut q = vec![[0f64; N_ACTIONS]; ni_n * nl_n];
    let mut shaper = RewardShaper::with_census(thresholds, req.census_gamma);
    // per visited state: was it feasible? (tracked explicitly — under
    // γ > 0 a feasible state's shaped reward can be negative, so the
    // sign of the stored reward no longer implies infeasibility)
    // analysis: allow(nondet, run-local memo; keyed lookups only, never iterated into output)
    let mut visited: HashMap<(usize, usize), bool> = HashMap::new();
    let mut trace = Vec::new();
    let mut queries = 0usize;
    let mut cache_hits = 0usize;

    // reward of *visiting* a state: query (memoized twice — run-local
    // shaped outcome, process-wide estimate) + Algorithm 1
    let mut visit = |i: usize,
                     j: usize,
                     shaper: &mut RewardShaper,
                     queries: &mut usize,
                     cache_hits: &mut usize,
                     trace: &mut Vec<(usize, usize, f64, bool)>|
     -> f64 {
        let (ni, nl) = (space.ni[i], space.nl[j]);
        if let Some(&was_feasible) = visited.get(&(ni, nl)) {
            // revisits replay the shaped outcome without a compiler call;
            // Algorithm 1 gives 0 for known-feasible non-improving states
            // and -1 for known-infeasible ones
            return if was_feasible { 0.0 } else { -1.0 };
        }
        let (eval, hit) = evaluator.evaluate(flow, device, ni, nl, req);
        *queries += 1;
        if hit {
            *cache_hits += 1;
        }
        let est = &eval.estimate;
        let feasible = est.fits(&shaper.thresholds);
        let r = shaper.eval_censused(est, eval.stepped_network.as_ref());
        trace.push((ni, nl, est.f_avg(), feasible));
        visited.insert((ni, nl), feasible);
        r
    };

    for _episode in 0..cfg.episodes {
        // "The agent starts from the minimum values of N_l and N_i."
        let (mut i, mut j) = (0usize, 0usize);
        visit(i, j, &mut shaper, &mut queries, &mut cache_hits, &mut trace);
        for _step in 0..cfg.steps_per_episode {
            let s = i * nl_n + j;
            let a = if rng.next_f64() < cfg.epsilon {
                rng.below(N_ACTIONS as u64) as usize
            } else {
                argmax_tiebreak(&q[s], &mut rng)
            };
            // apply action with wraparound reset
            let (ni2, nj2) = match a {
                0 => (i, wrap(j + 1, nl_n)),
                1 => (wrap(i + 1, ni_n), j),
                _ => (wrap(i + 1, ni_n), wrap(j + 1, nl_n)),
            };
            let r = visit(ni2, nj2, &mut shaper, &mut queries, &mut cache_hits, &mut trace);
            let s2 = ni2 * nl_n + nj2;
            let max_next = q[s2].iter().copied().fold(f64::NEG_INFINITY, f64::max);
            q[s][a] += cfg.alpha * (r + cfg.gamma * max_next - q[s][a]);
            i = ni2;
            j = nj2;
        }
    }

    DseResult {
        best: shaper.h_best,
        best_estimate: shaper.best_estimate,
        f_max: shaper.f_max,
        queries,
        cache_hits,
        wall_seconds: t0.elapsed().as_secs_f64(),
        modeled_seconds: queries as f64 * query_seconds(device),
        trace,
    }
}

fn wrap(x: usize, n: usize) -> usize {
    if x >= n {
        0
    } else {
        x
    }
}

/// Greedy action with uniform tie-breaking — without it the agent locks
/// onto action 0 while all Q-values are still zero and never leaves the
/// first grid column.
fn argmax_tiebreak(xs: &[f64], rng: &mut Rng) -> usize {
    let best = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let ties: Vec<usize> = (0..xs.len()).filter(|&i| xs[i] == best).collect();
    *rng.choose(&ties)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::brute;
    use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4, CYCLONE_V_5CSEMA5};
    use crate::onnx::zoo;
    use crate::testkit::for_all;

    fn flow(name: &str) -> ComputationFlow {
        ComputationFlow::extract(&zoo::build(name, false).unwrap()).unwrap()
    }

    #[test]
    fn rl_finds_bf_optimum_on_paper_devices() {
        for (dev, expect) in [
            (&ARRIA_10_GX1150, Some((16usize, 32usize))),
            (&CYCLONE_V_5CSEMA5, Some((8, 8))),
            (&CYCLONE_V_5CSEMA4, None),
        ] {
            let bf = brute::explore(&flow("alexnet"), dev, Thresholds::default());
            let rl = explore(&flow("alexnet"), dev, Thresholds::default(), RlConfig::default());
            assert_eq!(bf.best, expect, "{}", dev.name);
            assert_eq!(rl.best, bf.best, "{} rl trace: {:?}", dev.name, rl.trace);
        }
    }

    #[test]
    fn rl_uses_fewer_queries_than_bf() {
        // Table 2: RL-DSE ~25-30% faster than BF-DSE
        let bf = brute::explore(&flow("alexnet"), &ARRIA_10_GX1150, Thresholds::default());
        let rl = explore(
            &flow("alexnet"),
            &ARRIA_10_GX1150,
            Thresholds::default(),
            RlConfig::default(),
        );
        assert!(
            rl.queries < bf.queries,
            "rl {} vs bf {}",
            rl.queries,
            bf.queries
        );
        let ratio = rl.modeled_seconds / bf.modeled_seconds;
        assert!(
            (0.5..0.95).contains(&ratio),
            "modeled time ratio {ratio} outside paper band"
        );
    }

    #[test]
    fn rl_best_is_always_feasible_property() {
        for_all("rl H_best feasible for random thresholds/seeds", |g| {
            let th = Thresholds {
                lut: g.f64(20.0, 101.0),
                dsp: g.f64(20.0, 101.0),
                mem: g.f64(20.0, 101.0),
                reg: g.f64(20.0, 101.0),
            };
            let cfg = RlConfig {
                seed: g.int(0, i64::MAX) as u64,
                ..RlConfig::default()
            };
            let f = flow("alexnet");
            let r = explore(&f, &ARRIA_10_GX1150, th, cfg);
            if let Some(est) = &r.best_estimate {
                assert!(est.fits(&th));
                // never beaten by any feasible state it actually visited
                for (ni, nl, favg, feas) in &r.trace {
                    if *feas {
                        assert!(
                            *favg <= r.f_max + 1e-9,
                            "visited ({ni},{nl}) favg {favg} > fmax {}",
                            r.f_max
                        );
                    }
                }
            } else {
                assert!(r.trace.iter().all(|(_, _, _, f)| !f));
            }
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let a = explore(
            &flow("alexnet"),
            &ARRIA_10_GX1150,
            Thresholds::default(),
            RlConfig::default(),
        );
        let b = explore(
            &flow("alexnet"),
            &ARRIA_10_GX1150,
            Thresholds::default(),
            RlConfig::default(),
        );
        assert_eq!(a.best, b.best);
        assert_eq!(a.queries, b.queries);
        assert_eq!(a.trace, b.trace);
    }

    #[test]
    fn stepped_fidelity_does_not_change_the_agent() {
        // the reward signal is the estimator's; stepping every visited
        // candidate (full-network fidelity) must leave the trajectory,
        // query count and chosen design bit-identical
        let f = flow("alexnet");
        let (th, cfg) = (Thresholds::default(), RlConfig::default());
        let a = explore_with(&Evaluator::new(2), &f, &ARRIA_10_GX1150, th, cfg);
        let ev = Evaluator::new(2);
        let b = explore_with_fidelity(
            &ev,
            &f,
            &ARRIA_10_GX1150,
            th,
            cfg,
            EvalRequest::at(Fidelity::SteppedFullNetwork),
        );
        assert_eq!(a.best, b.best);
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.queries, b.queries);
        // and the visited states' censuses are in the memo
        let (ni, nl) = b.best.unwrap();
        let (eval, hit) = ev.evaluate(
            &f,
            &ARRIA_10_GX1150,
            ni,
            nl,
            EvalRequest::at(Fidelity::SteppedFullNetwork),
        );
        assert!(hit);
        assert!(eval.stepped_network.is_some());
    }

    #[test]
    fn census_gamma_shapes_the_agent_deterministically() {
        // γ > 0 at stepped-full fidelity: the seeded agent remains
        // deterministic, its H_best stays feasible, and the (ni, nl,
        // F_avg, feasible) trace format is unchanged
        let f = flow("alexnet");
        let (th, cfg) = (Thresholds::default(), RlConfig::default());
        let run = || {
            let ev = Evaluator::new(2);
            explore_with_fidelity(
                &ev,
                &f,
                &ARRIA_10_GX1150,
                th,
                cfg,
                EvalRequest::shaped(Fidelity::SteppedFullNetwork, 0.5),
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a.best, b.best);
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.queries, b.queries);
        if let Some(est) = &a.best_estimate {
            assert!(est.fits(&th));
        }
    }

    #[test]
    fn rl_served_from_disk_cache_reproduces_cold_trace() {
        // the seeded agent revisits the same states whether its hardware
        // queries are computed or answered from a persisted memo
        use super::eval::EvalCache;
        use std::sync::Arc;
        let f = flow("alexnet");
        let (th, cfg) = (Thresholds::default(), RlConfig::default());
        let ev = Evaluator::new(2);
        let cold = explore_with(&ev, &f, &ARRIA_10_GX1150, th, cfg);
        let path =
            std::env::temp_dir().join(format!("cnn2gate-rl-cache-{}.json", std::process::id()));
        ev.cache().save(&path).unwrap();
        let warm_ev = Evaluator::with_cache(2, Arc::new(EvalCache::load(&path).unwrap()));
        let warm = explore_with(&warm_ev, &f, &ARRIA_10_GX1150, th, cfg);
        assert_eq!(warm.cache_hits, warm.queries, "all unique visits from disk");
        assert_eq!(warm.best, cold.best);
        assert_eq!(warm.trace, cold.trace);
        assert_eq!(warm.queries, cold.queries);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn warm_cache_preserves_result_and_counts_hits() {
        // Seeded RNG + fresh evaluator: hit counts are deterministic.
        let f = flow("alexnet");
        let ev = Evaluator::new(2);
        let (th, cfg) = (Thresholds::default(), RlConfig::default());
        let cold = explore_with(&ev, &f, &ARRIA_10_GX1150, th, cfg);
        assert_eq!(cold.cache_hits, 0, "fresh cache cannot hit");
        let warm = explore_with(&ev, &f, &ARRIA_10_GX1150, th, cfg);
        assert_eq!(warm.cache_hits, warm.queries, "all unique visits memoized");
        assert_eq!(warm.best, cold.best);
        assert_eq!(warm.trace, cold.trace);
        assert_eq!(warm.queries, cold.queries);
        // and the determinism extends across evaluator instances
        let ev2 = Evaluator::new(2);
        let cold2 = explore_with(&ev2, &f, &ARRIA_10_GX1150, th, cfg);
        assert_eq!(cold2.cache_hits, cold.cache_hits);
        assert_eq!(ev2.cache().stats().misses, ev.cache().stats().misses);
    }
}
