//! Reward shaping — paper Algorithm 1, verbatim.
//!
//! Feasible option improving the running max usage factor: `β·F_avg`;
//! feasible but not improving: `0`; any quota over threshold: `-1`.
//! β = 0.01 rescales percentages into [0, 1] (paper §4.4).

use crate::estimator::{ResourceEstimate, Thresholds};

pub const BETA: f64 = 0.01;

/// Stateful reward shaper: tracks `F_max` and `H_best` across the
/// exploration exactly like Algorithm 1's outputs.
#[derive(Debug, Clone)]
pub struct RewardShaper {
    pub thresholds: Thresholds,
    pub f_max: f64,
    pub h_best: Option<(usize, usize)>,
    pub best_estimate: Option<ResourceEstimate>,
}

impl RewardShaper {
    pub fn new(thresholds: Thresholds) -> Self {
        RewardShaper {
            thresholds,
            f_max: 0.0,
            h_best: None,
            best_estimate: None,
        }
    }

    /// Algorithm 1. Returns the shaped reward for this estimate.
    pub fn eval(&mut self, est: &ResourceEstimate) -> f64 {
        if est.fits(&self.thresholds) {
            let f_avg = est.f_avg();
            if f_avg > self.f_max {
                self.f_max = f_avg;
                self.h_best = Some((est.ni, est.nl));
                self.best_estimate = Some(est.clone());
                BETA * f_avg
            } else {
                0.0
            }
        } else {
            -1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::{estimate, device::ARRIA_10_GX1150, Thresholds};
    use crate::ir::ComputationFlow;
    use crate::onnx::zoo;

    fn est(ni: usize, nl: usize) -> ResourceEstimate {
        let g = zoo::build("alexnet", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        estimate(&flow, &ARRIA_10_GX1150, ni, nl)
    }

    #[test]
    fn first_feasible_is_rewarded() {
        let mut rs = RewardShaper::new(Thresholds::default());
        let e = est(8, 8);
        let r = rs.eval(&e);
        assert!((r - BETA * e.f_avg()).abs() < 1e-12);
        assert_eq!(rs.h_best, Some((8, 8)));
    }

    #[test]
    fn non_improving_feasible_gets_zero() {
        let mut rs = RewardShaper::new(Thresholds::default());
        rs.eval(&est(16, 32));
        assert_eq!(rs.eval(&est(4, 4)), 0.0);
        assert_eq!(rs.h_best, Some((16, 32)));
    }

    #[test]
    fn infeasible_gets_minus_one_and_does_not_update_best() {
        let mut rs = RewardShaper::new(Thresholds {
            lut: 10.0,
            dsp: 10.0,
            mem: 10.0,
            reg: 10.0,
        });
        assert_eq!(rs.eval(&est(64, 64)), -1.0);
        assert_eq!(rs.h_best, None);
        assert_eq!(rs.f_max, 0.0);
    }

    #[test]
    fn reward_is_in_unit_scale() {
        // β converts percentage scale to [0, 1] (paper §4.4)
        let mut rs = RewardShaper::new(Thresholds::default());
        let r = rs.eval(&est(64, 64));
        assert!(r <= 1.0 && r > -1.0 - 1e-12);
    }
}
