//! Reward shaping — paper Algorithm 1, plus the optional census term.
//!
//! Feasible option improving the running max score: `β·F_avg −
//! γ·bottleneck_stall_fraction`; feasible but not improving: `0`; any
//! quota over threshold: `-1`. β = 0.01 rescales percentages into
//! [0, 1] (paper §4.4).
//!
//! With `census_gamma == 0` (the default) this is EXACTLY Algorithm 1 —
//! the improvement comparison runs on the raw usage factor, so explorer
//! choices and traces are bit-identical to the pre-census code. With
//! γ > 0 and a [`NetworkStepReport`] census attached (the
//! `Fidelity::SteppedFullNetwork` grids), the shaped score additionally
//! penalizes candidates whose bottleneck round idles its lane array —
//! the ROADMAP follow-up that feeds the PR-3 stepped census back into
//! Algorithm 1 instead of only reporting it.

use crate::estimator::{ResourceEstimate, Thresholds};
use crate::sim::NetworkStepReport;

pub const BETA: f64 = 0.01;

/// Stateful reward shaper: tracks the best score and `H_best` across the
/// exploration exactly like Algorithm 1's outputs.
#[derive(Debug, Clone)]
pub struct RewardShaper {
    pub thresholds: Thresholds,
    /// γ: weight of the bottleneck stall fraction. 0 (default) is the
    /// paper's Algorithm 1, bit for bit.
    pub census_gamma: f64,
    /// Usage factor of the current `H_best` (Algorithm 1's `F_max`).
    /// Under γ > 0 this is the F_avg of the best *shaped* candidate,
    /// not necessarily the max F_avg visited.
    pub f_max: f64,
    /// Shaped score of the current `H_best` (`β·f_max` when γ = 0).
    pub best_score: f64,
    pub h_best: Option<(usize, usize)>,
    pub best_estimate: Option<ResourceEstimate>,
}

impl RewardShaper {
    pub fn new(thresholds: Thresholds) -> Self {
        RewardShaper::with_census(thresholds, 0.0)
    }

    /// Shaper with a census term of weight `census_gamma`.
    pub fn with_census(thresholds: Thresholds, census_gamma: f64) -> Self {
        RewardShaper {
            thresholds,
            census_gamma,
            f_max: 0.0,
            best_score: 0.0,
            h_best: None,
            best_estimate: None,
        }
    }

    /// Algorithm 1 without a census (equivalent to
    /// [`RewardShaper::eval_censused`] with `None`).
    pub fn eval(&mut self, est: &ResourceEstimate) -> f64 {
        self.eval_censused(est, None)
    }

    /// Algorithm 1 with the optional census term. Returns the shaped
    /// reward for this candidate. The census is only available on
    /// stepped-full-network evaluations; analytical/stepped-dominant
    /// candidates score with a zero stall term (γ is inert there).
    pub fn eval_censused(
        &mut self,
        est: &ResourceEstimate,
        census: Option<&NetworkStepReport>,
    ) -> f64 {
        if !est.fits(&self.thresholds) {
            return -1.0;
        }
        let f_avg = est.f_avg();
        // analysis: allow(float-eq, γ = 0.0 is the exact unshaped seed-path sentinel, never a computed value)
        if self.census_gamma == 0.0 {
            // γ = 0 pins the seed path: compare raw usage factors so the
            // pre-census explorers' choices reproduce bit for bit
            if f_avg > self.f_max {
                self.f_max = f_avg;
                self.best_score = BETA * f_avg;
                self.h_best = Some((est.ni, est.nl));
                self.best_estimate = Some(est.clone());
                BETA * f_avg
            } else {
                0.0
            }
        } else {
            let stall = census.map_or(0.0, NetworkStepReport::bottleneck_stall_fraction);
            let score = BETA * f_avg - self.census_gamma * stall;
            // the first feasible candidate always becomes H_best, even
            // at a negative shaped score — Algorithm 1 never reports
            // "does not fit" while something fits
            if self.h_best.is_none() || score > self.best_score {
                // the returned reward is the shaped-score IMPROVEMENT
                // over the previous best (clamped at 0 for the first
                // feasible candidate), not the raw score: a shaped
                // score is routinely negative (γ·stall can exceed
                // β·F_avg), and a negative reward for the new best
                // would rank it below known non-improving states
                // (which earn 0) in the RL agent's Q-function —
                // inverting Algorithm 1's improvement > no-improvement
                // > infeasible ordering
                let reward = if self.h_best.is_none() {
                    score.max(0.0)
                } else {
                    score - self.best_score
                };
                self.f_max = f_avg;
                self.best_score = score;
                self.h_best = Some((est.ni, est.nl));
                self.best_estimate = Some(est.clone());
                reward
            } else {
                0.0
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::device::ARRIA_10_GX1150;
    use crate::estimator::{estimate, Thresholds};
    use crate::ir::ComputationFlow;
    use crate::onnx::zoo;
    use crate::sim::step_network;

    fn est(ni: usize, nl: usize) -> ResourceEstimate {
        let g = zoo::build("alexnet", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        estimate(&flow, &ARRIA_10_GX1150, ni, nl)
    }

    fn census(ni: usize, nl: usize) -> crate::sim::NetworkStepReport {
        let g = zoo::build("alexnet", false).unwrap();
        let flow = ComputationFlow::extract(&g).unwrap();
        let e = estimate(&flow, &ARRIA_10_GX1150, ni, nl);
        step_network(&flow, &ARRIA_10_GX1150, e.fmax_mhz, ni, nl)
    }

    #[test]
    fn first_feasible_is_rewarded() {
        let mut rs = RewardShaper::new(Thresholds::default());
        let e = est(8, 8);
        let r = rs.eval(&e);
        assert!((r - BETA * e.f_avg()).abs() < 1e-12);
        assert_eq!(rs.h_best, Some((8, 8)));
    }

    #[test]
    fn non_improving_feasible_gets_zero() {
        let mut rs = RewardShaper::new(Thresholds::default());
        rs.eval(&est(16, 32));
        assert_eq!(rs.eval(&est(4, 4)), 0.0);
        assert_eq!(rs.h_best, Some((16, 32)));
    }

    #[test]
    fn infeasible_gets_minus_one_and_does_not_update_best() {
        let mut rs = RewardShaper::new(Thresholds {
            lut: 10.0,
            dsp: 10.0,
            mem: 10.0,
            reg: 10.0,
        });
        assert_eq!(rs.eval(&est(64, 64)), -1.0);
        assert_eq!(rs.h_best, None);
        assert_eq!(rs.f_max, 0.0);
    }

    #[test]
    fn reward_is_in_unit_scale() {
        // β converts percentage scale to [0, 1] (paper §4.4)
        let mut rs = RewardShaper::new(Thresholds::default());
        let r = rs.eval(&est(64, 64));
        assert!(r <= 1.0 && r > -1.0 - 1e-12);
    }

    #[test]
    fn gamma_zero_is_bit_identical_to_algorithm_1_with_or_without_census() {
        // the γ=0 pin of the acceptance criteria: attaching a census
        // changes NOTHING — rewards, best, f_max all bit-identical
        let options = [(4, 4), (16, 32), (8, 8), (16, 4), (4, 32)];
        let mut plain = RewardShaper::new(Thresholds::default());
        let mut censused = RewardShaper::with_census(Thresholds::default(), 0.0);
        for &(ni, nl) in &options {
            let e = est(ni, nl);
            let c = census(ni, nl);
            let a = plain.eval(&e);
            let b = censused.eval_censused(&e, Some(&c));
            assert_eq!(a.to_bits(), b.to_bits(), "({ni},{nl})");
        }
        assert_eq!(plain.h_best, censused.h_best);
        assert_eq!(plain.f_max.to_bits(), censused.f_max.to_bits());
        assert_eq!(plain.best_score.to_bits(), censused.best_score.to_bits());
    }

    #[test]
    fn census_term_shapes_the_reward_under_positive_gamma() {
        let e = est(16, 32);
        let c = census(16, 32);
        let stall = c.bottleneck_stall_fraction();
        assert!(stall > 0.0, "alexnet at (16,32) is DDR-starved");
        let mut rs = RewardShaper::with_census(Thresholds::default(), 0.5);
        let r = rs.eval_censused(&e, Some(&c));
        let score = BETA * e.f_avg() - 0.5 * stall;
        // the improvement reward never goes negative (ordering:
        // improvement ≥ non-improvement 0 > infeasible -1), while the
        // tracked best_score is the raw shaped score
        assert_eq!(r.to_bits(), score.max(0.0).to_bits());
        assert_eq!(rs.h_best, Some((16, 32)), "first feasible still wins");
        assert_eq!(rs.best_score.to_bits(), score.to_bits());
        // a second candidate with a non-improving shaped score gets 0
        // and does not displace H_best
        let r2 = rs.eval_censused(&e, Some(&c));
        assert_eq!(r2, 0.0);
        // without a census the stall term is zero (γ inert), and the
        // shaper starts fresh: reward = β·F_avg exactly
        let mut rs2 = RewardShaper::with_census(Thresholds::default(), 0.5);
        let r3 = rs2.eval_censused(&e, None);
        assert_eq!(r3.to_bits(), (BETA * e.f_avg()).to_bits());
        // an actual improvement earns the (positive) score gain
        let small = est(4, 4);
        let small_c = census(4, 4);
        let mut rs3 = RewardShaper::with_census(Thresholds::default(), 1e-6);
        rs3.eval_censused(&small, Some(&small_c));
        let prev = rs3.best_score;
        let gain = rs3.eval_censused(&e, Some(&c));
        assert!(gain > 0.0, "improvement reward must be positive");
        assert_eq!(gain.to_bits(), (rs3.best_score - prev).to_bits());
    }

    #[test]
    fn negative_shaped_score_still_selects_a_feasible_best() {
        // a huge γ drives every score negative; the shaper must still
        // name a feasible H_best rather than reporting no fit, and the
        // first-feasible reward clamps at 0 (never an infeasible-like
        // negative signal for a feasible state)
        let e = est(16, 32);
        let c = census(16, 32);
        let mut rs = RewardShaper::with_census(Thresholds::default(), 1e3);
        let r = rs.eval_censused(&e, Some(&c));
        assert_eq!(r, 0.0);
        assert!(rs.best_score < 0.0);
        assert_eq!(rs.h_best, Some((16, 32)));
        assert!(rs.best_estimate.is_some());
    }
}
