//! Throughput-mode DSE: co-optimize (N_i, N_l, B) for serving.
//!
//! Latency-mode DSE picks the (N_i, N_l) that maximizes silicon
//! utilization for a single frame. A serving deployment cares about
//! frames/s instead, and the batched stepped pipeline changes the
//! ranking: fetching a round's weights once and holding them across B
//! frames amortizes the dominant DDR stream, so rounds that are
//! memory-bound at B = 1 (FC rounds especially) flip compute-bound at
//! modest batch sizes. [`co_optimize`] runs the configured explorer once
//! per candidate batch size (each under its own `(…, B)` memo keys),
//! scores every winner by its closed-form frames/s, and picks the
//! highest-throughput batch whose *end-to-end* latency still meets the
//! optional SLO. End-to-end means queueing delay plus makespan: in the
//! steady state a new batch launches every makespan, so the worst-case
//! frame arrives just after a batch closes, waits one full batch period,
//! then rides the next batch — `2 × batch_millis` in total. An SLO that
//! only bounded the makespan would under-count exactly the large
//! batches it exists to police.
//!
//! The pass is explorer-agnostic: callers hand it a closure that runs
//! their explorer under a given [`EvalRequest`], so BF, RL and joint
//! searches all co-optimize the same way (`session::execute` wires this
//! up for the CLI's `--batch`/`--latency-slo` flags).

use crate::estimator::Device;
use crate::ir::ComputationFlow;

use super::brute::DseResult;
use super::eval::{EvalRequest, Evaluator};

/// One explored batch size: the explorer's winner at that B plus the
/// closed-form serving metrics the ranking runs on.
#[derive(Debug, Clone)]
pub struct BatchCandidate {
    /// Batch size this exploration ran at.
    pub batch: usize,
    /// The explorer's full result at this batch size.
    pub dse: DseResult,
    /// Steady-state serving throughput of the winner (0 when nothing
    /// fits).
    pub frames_per_s: f64,
    /// Makespan of one batch through the winner's schedule in ms — the
    /// compute latency a frame sees when it lands first in a batch
    /// (0 when nothing fits).
    pub batch_millis: f64,
    /// Worst-case end-to-end latency in ms: a frame arriving just after
    /// a batch closes waits one batch period for its batch to launch,
    /// then the batch makespan — `2 × batch_millis` (0 when nothing
    /// fits).
    pub e2e_millis: f64,
    /// Whether `e2e_millis` meets the latency SLO (always true when
    /// no SLO was requested; false when nothing fits).
    pub meets_slo: bool,
}

impl BatchCandidate {
    /// The winning option at this batch size, when one fits.
    pub fn option(&self) -> Option<(usize, usize)> {
        self.dse.best
    }
}

/// Outcome of a (N_i, N_l, B) co-optimization sweep.
#[derive(Debug, Clone)]
pub struct ThroughputChoice {
    /// The SLO the sweep ran under, if any.
    pub latency_slo_ms: Option<f64>,
    /// One candidate per explored batch size, ascending in B.
    pub candidates: Vec<BatchCandidate>,
    /// Index into `candidates` of the chosen batch size (the highest
    /// frames/s among fitting, SLO-meeting candidates; ties prefer the
    /// smaller B). When no candidate meets the SLO the fitting
    /// candidate with the lowest end-to-end latency is chosen instead —
    /// the closest the design space gets to the requested latency.
    /// `None` only when nothing fits at any batch size.
    pub chosen: usize,
    /// True when the chosen candidate satisfies the SLO; false means
    /// the choice is the documented best-effort fallback.
    pub slo_satisfied: bool,
}

impl ThroughputChoice {
    /// The chosen candidate, when any batch size produced a fit.
    pub fn chosen_candidate(&self) -> Option<&BatchCandidate> {
        let c = self.candidates.get(self.chosen)?;
        c.dse.best.is_some().then_some(c)
    }

    /// The chosen batch size (1 when nothing fits anywhere — the
    /// degenerate single-frame schedule).
    pub fn chosen_batch(&self) -> usize {
        self.chosen_candidate().map_or(1, |c| c.batch)
    }
}

/// Normalize a `--batch` list: clamp zeros to 1, sort ascending, dedup.
/// An empty list explores the classic single-frame schedule only.
pub fn normalize_batches(batches: &[usize]) -> Vec<usize> {
    let mut out: Vec<usize> = batches.iter().map(|&b| b.max(1)).collect();
    if out.is_empty() {
        out.push(1);
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// Run `explore_at` once per batch size and rank the winners by
/// frames/s under the optional latency SLO (end-to-end latency —
/// queueing delay + batch makespan — ≤ SLO). Deterministic: batches are
/// normalized ascending, the serving metrics come from the closed-form
/// batched model, and ties break toward the smaller batch.
pub fn co_optimize<F>(
    evaluator: &Evaluator,
    flow: &ComputationFlow,
    device: &Device,
    base: EvalRequest,
    batches: &[usize],
    latency_slo_ms: Option<f64>,
    mut explore_at: F,
) -> ThroughputChoice
where
    F: FnMut(EvalRequest) -> DseResult,
{
    let mut candidates = Vec::new();
    for b in normalize_batches(batches) {
        let req = base.batched(b);
        let dse = explore_at(req);
        let (batch_millis, frames_per_s) = match dse.best {
            Some((ni, nl)) => {
                // the winner is memoized under (…, B) by the explorer
                // pass that just ran; this lookup is a cache hit
                let (eval, _) = evaluator.evaluate(flow, device, ni, nl, req);
                match &eval.batched {
                    Some(rep) => (rep.total_millis, rep.frames_per_s()),
                    None => {
                        let ms = eval.latency.total_millis;
                        (ms, if ms > 0.0 { 1e3 / ms } else { 0.0 })
                    }
                }
            }
            None => (0.0, 0.0),
        };
        // worst case: miss one batch launch, wait a full period, then
        // ride the next batch — the steady-state period is the makespan
        let e2e_millis = 2.0 * batch_millis;
        let meets_slo =
            dse.best.is_some() && latency_slo_ms.map_or(true, |slo| e2e_millis <= slo);
        candidates.push(BatchCandidate {
            batch: b,
            dse,
            frames_per_s,
            batch_millis,
            e2e_millis,
            meets_slo,
        });
    }
    // primary ranking: max frames/s among fitting, SLO-meeting
    // candidates (strict > keeps ties on the smaller batch)
    let mut chosen: Option<usize> = None;
    for (i, c) in candidates.iter().enumerate() {
        if !c.meets_slo {
            continue;
        }
        let better = match chosen {
            Some(j) => c.frames_per_s > candidates[j].frames_per_s,
            None => true,
        };
        if better {
            chosen = Some(i);
        }
    }
    let slo_satisfied = chosen.is_some();
    // fallback: nothing meets the SLO — serve the fitting candidate
    // closest to it (lowest end-to-end latency; ties on the smaller
    // batch)
    if chosen.is_none() {
        for (i, c) in candidates.iter().enumerate() {
            if c.dse.best.is_none() {
                continue;
            }
            let better = match chosen {
                Some(j) => c.e2e_millis < candidates[j].e2e_millis,
                None => true,
            };
            if better {
                chosen = Some(i);
            }
        }
    }
    ThroughputChoice {
        latency_slo_ms,
        candidates,
        // with no fit anywhere, point at the first (batch-ascending)
        // candidate; chosen_candidate() still reports None
        chosen: chosen.unwrap_or(0),
        slo_satisfied,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::brute;
    use crate::dse::eval::Fidelity;
    use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4};
    use crate::estimator::Thresholds;
    use crate::onnx::zoo;

    fn flow(name: &str) -> ComputationFlow {
        ComputationFlow::extract(&zoo::build(name, false).unwrap()).unwrap()
    }

    fn sweep(
        f: &ComputationFlow,
        device: &Device,
        batches: &[usize],
        slo: Option<f64>,
    ) -> (Evaluator, ThroughputChoice) {
        let ev = Evaluator::new(2);
        let choice = co_optimize(
            &ev,
            f,
            device,
            EvalRequest::at(Fidelity::Analytical),
            batches,
            slo,
            |req| brute::explore_with_fidelity(&ev, f, device, Thresholds::default(), req),
        );
        (ev, choice)
    }

    #[test]
    fn normalize_sorts_dedups_and_defaults() {
        assert_eq!(normalize_batches(&[]), vec![1]);
        assert_eq!(normalize_batches(&[16, 1, 4, 16, 0]), vec![1, 4, 16]);
    }

    #[test]
    fn batching_wins_the_throughput_ranking() {
        // cross-frame weight reuse strictly helps AlexNet on the Arria
        // 10: frames/s grows with B, so the unconstrained sweep picks
        // the largest batch
        let f = flow("alexnet");
        let (_, choice) = sweep(&f, &ARRIA_10_GX1150, &[1, 4, 16], None);
        assert_eq!(choice.candidates.len(), 3);
        assert!(choice.slo_satisfied, "no SLO means every fit qualifies");
        let fps: Vec<f64> = choice.candidates.iter().map(|c| c.frames_per_s).collect();
        assert!(fps[1] > fps[0], "B=4 beats B=1: {fps:?}");
        assert!(fps[2] > fps[1], "B=16 beats B=4: {fps:?}");
        let chosen = choice.chosen_candidate().expect("alexnet fits");
        assert_eq!(chosen.batch, 16);
        assert_eq!(choice.chosen_batch(), 16);
        // every batch size explored the same paper option space and the
        // estimator-driven winner is batch-independent here
        for c in &choice.candidates {
            assert_eq!(c.option(), Some((16, 32)), "B={}", c.batch);
        }
    }

    #[test]
    fn latency_slo_caps_the_batch() {
        // pick an SLO between the B=1 and B=16 end-to-end latencies:
        // the sweep must fall back to the largest batch that still
        // meets it
        let f = flow("alexnet");
        let (_, unconstrained) = sweep(&f, &ARRIA_10_GX1150, &[1, 16], None);
        let e1 = unconstrained.candidates[0].e2e_millis;
        let e16 = unconstrained.candidates[1].e2e_millis;
        assert!(e16 > e1, "a 16-frame batch takes longer than one frame");
        let slo = (e1 + e16) / 2.0;
        let (_, capped) = sweep(&f, &ARRIA_10_GX1150, &[1, 16], Some(slo));
        assert!(capped.slo_satisfied);
        assert_eq!(capped.chosen_batch(), 1, "B=16 breaks the {slo:.2} ms SLO");
        // an SLO tighter than every end-to-end latency falls back to
        // the lowest one and reports the SLO as unsatisfied
        let (_, strict) = sweep(&f, &ARRIA_10_GX1150, &[1, 16], Some(e1 / 2.0));
        assert!(!strict.slo_satisfied, "nothing meets half the B=1 latency");
        assert_eq!(strict.chosen_batch(), 1, "fallback picks the closest");
        assert!(strict.chosen_candidate().is_some());
    }

    #[test]
    fn slo_bounds_end_to_end_latency_not_makespan() {
        // the boundary batch: an SLO the B=16 *makespan* meets but its
        // end-to-end latency (one batch period of queueing delay + the
        // makespan) does not. A bare makespan check would accept it;
        // the queueing-aware check must reject it.
        let f = flow("alexnet");
        let (_, unconstrained) = sweep(&f, &ARRIA_10_GX1150, &[16], None);
        let c16 = &unconstrained.candidates[0];
        assert_eq!(c16.batch, 16);
        assert!(c16.batch_millis > 0.0, "alexnet fits the Arria 10");
        assert_eq!(
            c16.e2e_millis.to_bits(),
            (2.0 * c16.batch_millis).to_bits(),
            "e2e is exactly one queueing period plus the makespan"
        );
        let slo = 1.5 * c16.batch_millis;
        assert!(c16.batch_millis < slo && slo < c16.e2e_millis);
        let (_, capped) = sweep(&f, &ARRIA_10_GX1150, &[16], Some(slo));
        assert!(
            !capped.candidates[0].meets_slo,
            "makespan fits under the SLO but end-to-end latency must not"
        );
        assert!(!capped.slo_satisfied);
        assert_eq!(capped.chosen_batch(), 16, "best-effort fallback still serves");
    }

    #[test]
    fn no_fit_anywhere_reports_none() {
        // AlexNet does not fit the small Cyclone V at any batch size
        let f = flow("alexnet");
        let (_, choice) = sweep(&f, &CYCLONE_V_5CSEMA4, &[1, 8], None);
        assert!(choice.chosen_candidate().is_none());
        assert_eq!(choice.chosen_batch(), 1, "degenerate single-frame");
        assert!(!choice.slo_satisfied);
        assert!(choice.candidates.iter().all(|c| !c.meets_slo));
    }

    #[test]
    fn co_optimize_is_deterministic() {
        let f = flow("alexnet");
        let run = || {
            let (_, c) = sweep(&f, &ARRIA_10_GX1150, &[16, 1, 4], Some(25.0));
            (
                c.chosen,
                c.chosen_batch(),
                c.slo_satisfied,
                c.candidates
                    .iter()
                    .map(|x| {
                        (
                            x.batch,
                            x.frames_per_s.to_bits(),
                            x.batch_millis.to_bits(),
                            x.e2e_millis.to_bits(),
                        )
                    })
                    .collect::<Vec<_>>(),
            )
        };
        assert_eq!(run(), run());
    }
}
