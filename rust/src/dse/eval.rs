//! Shared, multi-threaded candidate-evaluation core for the DSE layer.
//!
//! Every explorer (BF, RL, joint) ultimately scores `(N_i, N_l)` options
//! by calling the estimator and the latency simulator — the stand-ins
//! for the "first stage of the synthesis tool" the paper queries (§4.3).
//! The seed explorers did this strictly sequentially and re-derived the
//! same estimates across runs. This module centralizes that work:
//!
//! * [`EvalCache`] — a process-wide memo keyed on
//!   `(model fingerprint, device fingerprint, N_i, N_l, fidelity,
//!   census γ, tenant)` that deduplicates the estimator + simulator
//!   calls the RL and joint agents revisit constantly (and that repeat
//!   across fleet fits). Entries carry a last-used LRU stamp so
//!   oversized disk caches can be evicted deterministically
//!   ([`EvalCache::evict_lru`]);
//! * [`EvalRequest`] — the params struct naming what one evaluation
//!   runs under: a [`Fidelity`], the census-reward γ, and the
//!   [`TenantId`] cache namespace. [`EvalRequest::at`] is the γ = 0,
//!   default-tenant convenience constructor unshaped callers use;
//! * [`ThreadPool`] — a plain `std::thread` + channel worker pool (the
//!   `coordinator::server` idiom; tokio is not in the offline crate
//!   set) that [`Evaluator::evaluate_grid`] fans candidate scoring out
//!   across cores while preserving the sequential result order, so
//!   parallel exploration is bit-identical to the seed path;
//! * [`parallel_map`] — a scoped fork/join helper used by the fleet-fit
//!   flow to run whole per-device explorations concurrently (scoped
//!   threads, not the pool, so explorers running inside it can still
//!   use the pool without self-deadlock);
//! * [`Fidelity`] — analytical (closed-form, µs-scale), stepped dominant
//!   round (cycle-accurate simulation of the heaviest round), or stepped
//!   full network (cycle-accurate simulation of *every* round, with a
//!   per-layer stall/backpressure census). The stepped modes ride the
//!   epoch skip-ahead engine ([`crate::sim::step_round`]), which is what
//!   makes whole-network stepped DSE interactive.
//!
//! Deadlock rule: [`Evaluator::evaluate_grid`] must not be called from
//! inside one of the pool's own workers (a worker waiting on sub-jobs
//! would starve the queue). Nothing in this crate does; fleet fan-out
//! deliberately uses [`parallel_map`]'s scoped threads instead.

// analysis: allow(nondet, the memo map is keyed lookup only; every iteration that feeds output is sorted by EvalKey::sort_key first)
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context};

use crate::estimator::{estimate, Device, ResourceEstimate, Thresholds};
use crate::ir::ComputationFlow;
use crate::sim::{
    dominant_round_work_batched, simulate_batched, simulate_with_estimate, step_network_batched,
    step_round, BatchReport, LayerTiming, NetworkStepReport, SimReport, StepReport,
};
use crate::util::json::{Json, JsonObj};
use crate::util::sync::locked;

/// How much simulation each candidate evaluation buys.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Fidelity {
    /// Resource estimate + closed-form whole-network latency (default).
    Analytical,
    /// Additionally run the cycle-stepped simulator on the flow's
    /// dominant round — the classic ground-truth spot check.
    SteppedDominantRound,
    /// Run the cycle-stepped simulator on *every* round and surface the
    /// per-layer stall/backpressure census ([`NetworkStepReport`]).
    SteppedFullNetwork,
}

fn fidelity_rank(f: Fidelity) -> u8 {
    match f {
        Fidelity::Analytical => 0,
        Fidelity::SteppedDominantRound => 1,
        Fidelity::SteppedFullNetwork => 2,
    }
}

/// Stable on-disk tag for a fidelity mode (cache format v2).
pub fn fidelity_tag(f: Fidelity) -> &'static str {
    match f {
        Fidelity::Analytical => "analytical",
        Fidelity::SteppedDominantRound => "stepped-dominant-round",
        Fidelity::SteppedFullNetwork => "stepped-full-network",
    }
}

pub(crate) fn parse_fidelity_tag(s: &str) -> Result<Fidelity, String> {
    match s {
        "analytical" => Ok(Fidelity::Analytical),
        "stepped-dominant-round" => Ok(Fidelity::SteppedDominantRound),
        "stepped-full-network" => Ok(Fidelity::SteppedFullNetwork),
        other => Err(format!("unknown fidelity tag '{other}'")),
    }
}

/// Cache namespace a request evaluates under. The compile service gives
/// every tenant its own namespace (folded into the memo key as a stable
/// FNV-1a fingerprint of the tenant name), so tenants can neither
/// poison nor age out each other's cached working sets. Single-tenant
/// flows use [`TenantId::DEFAULT`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TenantId(u64);

impl TenantId {
    /// The default (single-tenant) namespace.
    pub const DEFAULT: TenantId = TenantId(0);

    /// Namespace for a named tenant; the empty name maps to the default
    /// namespace.
    pub fn of(name: &str) -> TenantId {
        if name.is_empty() {
            TenantId::DEFAULT
        } else {
            TenantId(crate::util::hash::fnv1a(name.as_bytes()))
        }
    }

    /// The raw memo-key component (0 for the default namespace).
    pub fn as_u64(self) -> u64 {
        self.0
    }
}

/// Everything that parameterizes one evaluation besides the candidate
/// itself: the [`Fidelity`], the census-reward γ the exploration runs
/// under (part of the memo key even though the payload is
/// γ-independent) and the [`TenantId`] cache namespace. This params
/// struct replaced the `evaluate`/`evaluate_shaped`/
/// `evaluate_grid_shaped`/`get_or_compute_shaped` method ladder:
/// [`EvalRequest::at`] is the γ = 0, default-tenant convenience
/// constructor, [`EvalRequest::shaped`] sets γ, and
/// [`EvalRequest::tenant`] moves the request into a tenant namespace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalRequest {
    pub fidelity: Fidelity,
    /// Census-reward γ (exact f64; -0.0 normalizes to +0.0 in the key).
    pub census_gamma: f64,
    pub tenant: TenantId,
    /// Frames simulated per weight fetch (cross-frame reuse); 1 is the
    /// classic single-frame evaluation, 0 normalizes to 1 in the key.
    pub batch: usize,
}

impl EvalRequest {
    /// Unshaped request: γ = 0, default tenant, batch 1.
    pub fn at(fidelity: Fidelity) -> EvalRequest {
        EvalRequest {
            fidelity,
            census_gamma: 0.0,
            tenant: TenantId::DEFAULT,
            batch: 1,
        }
    }

    /// γ-shaped request in the default tenant namespace.
    pub fn shaped(fidelity: Fidelity, census_gamma: f64) -> EvalRequest {
        EvalRequest {
            census_gamma,
            ..EvalRequest::at(fidelity)
        }
    }

    /// The same request in `tenant`'s cache namespace.
    pub fn tenant(self, tenant: TenantId) -> EvalRequest {
        EvalRequest { tenant, ..self }
    }

    /// The same request at batch size `batch` (0 normalizes to 1).
    pub fn batched(self, batch: usize) -> EvalRequest {
        EvalRequest {
            batch: batch.max(1),
            ..self
        }
    }
}

/// Everything one estimator/simulator query produces for a candidate.
#[derive(Debug, Clone, PartialEq)]
pub struct Evaluation {
    pub ni: usize,
    pub nl: usize,
    /// Batch size the stepped/batched payloads were simulated at (1 for
    /// classic single-frame evaluations).
    pub batch: usize,
    pub estimate: ResourceEstimate,
    /// Closed-form batch-1 latency at this option (computed for every
    /// candidate, feasible or not — fleet reports rank by it).
    pub latency: SimReport,
    /// Closed-form batched latency/throughput (present iff batch ≥ 2).
    pub batched: Option<BatchReport>,
    /// Cycle-stepped dominant-round census (stepped-dominant fidelity).
    pub stepped: Option<StepReport>,
    /// Cycle-stepped census of every round (stepped-full fidelity).
    pub stepped_network: Option<NetworkStepReport>,
}

impl Evaluation {
    /// Compute from scratch at batch 1 — the pure function the cache
    /// memoizes for classic single-frame requests.
    pub fn compute(
        flow: &ComputationFlow,
        device: &Device,
        ni: usize,
        nl: usize,
        fidelity: Fidelity,
    ) -> Evaluation {
        Evaluation::compute_batched(flow, device, ni, nl, fidelity, 1)
    }

    /// Compute from scratch at batch `batch`: the stepped payloads run
    /// the batched recurrence (weights fetched once per group pass, held
    /// across the B frames) and, at batch ≥ 2, the closed-form batched
    /// throughput model rides along in [`Evaluation::batched`].
    pub fn compute_batched(
        flow: &ComputationFlow,
        device: &Device,
        ni: usize,
        nl: usize,
        fidelity: Fidelity,
        batch: usize,
    ) -> Evaluation {
        let batch = batch.max(1);
        let estimate = estimate(flow, device, ni, nl);
        // reuse the estimate for the latency model (one estimator call
        // per candidate, exactly like the sequential seed path)
        let latency = simulate_with_estimate(flow, device, &estimate);
        let batched = (batch >= 2).then(|| simulate_batched(flow, device, ni, nl, batch));
        let (stepped, stepped_network) = match fidelity {
            Fidelity::Analytical => (None, None),
            Fidelity::SteppedDominantRound => (
                dominant_round_work_batched(flow, device, estimate.fmax_mhz, ni, nl, batch)
                    .map(|work| step_round(&work)),
                None,
            ),
            Fidelity::SteppedFullNetwork => (
                None,
                Some(step_network_batched(flow, device, estimate.fmax_mhz, ni, nl, batch)),
            ),
        };
        Evaluation {
            ni,
            nl,
            batch,
            estimate,
            latency,
            batched,
            stepped,
            stepped_network,
        }
    }

    pub fn f_avg(&self) -> f64 {
        self.estimate.f_avg()
    }

    pub fn feasible(&self, thresholds: &Thresholds) -> bool {
        self.estimate.fits(thresholds)
    }
}

/// Cache key: structural fingerprints, not pointers, so equal models
/// built twice (or the same zoo model across tests) share entries. The
/// census-reward γ participates (as its exact f64 bits) even though the
/// memoized payload itself is γ-independent: a run's cached working set
/// is then keyed on the reward configuration that produced it, so a
/// warm cache can never mix entries across differently-shaped
/// explorations (and `--cache-max-entries` eviction ages the γ-spaces
/// independently). The tenant namespace participates the same way: the
/// compile service folds each job's [`TenantId`] into the key, so one
/// tenant's working set can neither poison nor age out another's.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) struct EvalKey {
    pub(crate) model: u64,
    pub(crate) device: u64,
    pub(crate) ni: usize,
    pub(crate) nl: usize,
    pub(crate) fidelity: Fidelity,
    /// `f64::to_bits` of the run's census γ (0.0 for unshaped runs).
    pub(crate) census_gamma: u64,
    /// The request's [`TenantId`] (0 for the default namespace).
    pub(crate) tenant: u64,
    /// Batch size the payload was simulated at (1 for single-frame).
    pub(crate) batch: usize,
}

/// The γ component of the memo key: exact f64 bits, with -0.0
/// normalized to +0.0 so the unshaped key is unique (JSON cannot tell
/// the zeros apart, and neither can the reward). Every key construction
/// site goes through this one helper.
pub(crate) fn gamma_key_bits(census_gamma: f64) -> u64 {
    (census_gamma + 0.0).to_bits()
}

impl EvalKey {
    fn new(
        flow: &ComputationFlow,
        device: &Device,
        ni: usize,
        nl: usize,
        req: EvalRequest,
    ) -> EvalKey {
        EvalKey {
            model: flow.fingerprint(),
            device: device.fingerprint(),
            ni,
            nl,
            fidelity: req.fidelity,
            census_gamma: gamma_key_bits(req.census_gamma),
            tenant: req.tenant.as_u64(),
            batch: req.batch.max(1),
        }
    }

    /// Deterministic total order for serialization and eviction ties.
    #[allow(clippy::type_complexity)]
    pub(crate) fn sort_key(&self) -> (u64, u64, usize, usize, u8, u64, u64, usize) {
        let rank = fidelity_rank(self.fidelity);
        (
            self.model,
            self.device,
            self.ni,
            self.nl,
            rank,
            self.census_gamma,
            self.tenant,
            self.batch,
        )
    }
}

/// Point-in-time cache counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
    pub entries: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A memoized evaluation plus its LRU stamp.
struct CacheEntry {
    eval: Arc<Evaluation>,
    /// Logical generation of the last lookup that served this entry
    /// (one generation per cache *operation*, not per access, so
    /// parallel grid scoring can't make the stamps nondeterministic).
    last_used: u64,
}

/// Memoized estimator/simulator results, shared across explorers and
/// threads. Values are `Arc`ed so a hit is a pointer clone.
#[derive(Default)]
pub struct EvalCache {
    // analysis: allow(nondet, keyed lookups only; to_json sorts entries before serialization)
    map: Mutex<HashMap<EvalKey, CacheEntry>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    /// LRU generation clock; see [`EvalCache::tick`].
    clock: AtomicU64,
}

impl EvalCache {
    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    /// Advance and return the LRU generation. One lookup takes one tick;
    /// schedulers batching many lookups under one logical operation take
    /// one tick and pass it to [`EvalCache::get_or_compute_at`] so the
    /// threads' completion order can't perturb the persisted stamps.
    pub fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Look up or compute one candidate under `req`'s fidelity, census γ
    /// and tenant namespace. Returns the evaluation and whether it was
    /// served from cache.
    pub fn get_or_compute(
        &self,
        flow: &ComputationFlow,
        device: &Device,
        ni: usize,
        nl: usize,
        req: EvalRequest,
    ) -> (Arc<Evaluation>, bool) {
        let stamp = self.tick();
        self.get_or_compute_at(stamp, flow, device, ni, nl, req)
    }

    /// Same, under a caller-held LRU generation (see [`EvalCache::tick`]).
    /// The (potentially heavy) compute runs outside the lock so parallel
    /// misses don't serialize.
    pub fn get_or_compute_at(
        &self,
        stamp: u64,
        flow: &ComputationFlow,
        device: &Device,
        ni: usize,
        nl: usize,
        req: EvalRequest,
    ) -> (Arc<Evaluation>, bool) {
        let key = EvalKey::new(flow, device, ni, nl, req);
        self.get_or_compute_keyed(key, stamp, flow, device, req.fidelity)
    }

    /// Same, with the (loop-invariant) fingerprints already folded into
    /// `key` — `evaluate_grid` hashes the model/device once per grid,
    /// not once per candidate.
    fn get_or_compute_keyed(
        &self,
        key: EvalKey,
        stamp: u64,
        flow: &ComputationFlow,
        device: &Device,
        fidelity: Fidelity,
    ) -> (Arc<Evaluation>, bool) {
        if let Some(found) = locked(&self.map).get_mut(&key) {
            found.last_used = found.last_used.max(stamp);
            self.hits.fetch_add(1, Ordering::Relaxed);
            return (Arc::clone(&found.eval), true);
        }
        let eval = Arc::new(Evaluation::compute_batched(
            flow, device, key.ni, key.nl, fidelity, key.batch,
        ));
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = locked(&self.map);
        let entry = map.entry(key).or_insert_with(|| CacheEntry {
            eval: Arc::clone(&eval),
            last_used: 0,
        });
        entry.last_used = entry.last_used.max(stamp);
        (Arc::clone(&entry.eval), false)
    }

    /// Re-stamp (without ever computing) whichever of `pairs`' entries
    /// are present, all under one fresh generation; returns how many
    /// were present. Hit/miss counters are untouched. Fan-outs call
    /// this per (model, device) in deterministic order *after* their
    /// racy parallel phase, so the highest (decision-making) LRU stamps
    /// depend on the work done, not on thread scheduling — which keeps
    /// `--cache-max-entries` eviction and the saved cache file
    /// byte-deterministic across identical runs.
    pub fn touch_present(
        &self,
        flow: &ComputationFlow,
        device: &Device,
        pairs: &[(usize, usize)],
        req: EvalRequest,
    ) -> usize {
        let stamp = self.tick();
        let (model, device) = (flow.fingerprint(), device.fingerprint());
        let mut map = locked(&self.map);
        let mut present = 0;
        for &(ni, nl) in pairs {
            let key = EvalKey {
                model,
                device,
                ni,
                nl,
                fidelity: req.fidelity,
                census_gamma: gamma_key_bits(req.census_gamma),
                tenant: req.tenant.as_u64(),
                batch: req.batch.max(1),
            };
            if let Some(entry) = map.get_mut(&key) {
                entry.last_used = entry.last_used.max(stamp);
                present += 1;
            }
        }
        present
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: locked(&self.map).len(),
        }
    }

    /// Drop all entries and zero the counters + clock (bench isolation).
    pub fn clear(&self) {
        locked(&self.map).clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.clock.store(0, Ordering::Relaxed);
    }

    /// Evict least-recently-used entries until at most `max_entries`
    /// remain; returns how many were dropped. Ties on the stamp break by
    /// key, so eviction (and therefore the saved file) is deterministic.
    /// The `--cache-max-entries` CLI knob applies this before saving, so
    /// disk caches stop growing monotonically (ROADMAP follow-up).
    pub fn evict_lru(&self, max_entries: usize) -> usize {
        let mut map = locked(&self.map);
        if map.len() <= max_entries {
            return 0;
        }
        let mut by_age: Vec<_> = map
            .iter()
            .map(|(k, e)| (e.last_used, k.sort_key(), *k))
            .collect();
        by_age.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let evict = map.len() - max_entries;
        for (_, _, key) in by_age.into_iter().take(evict) {
            map.remove(&key);
        }
        evict
    }
}

// ---------------------------------------------------------------------------
// On-disk persistence
//
// The FNV fingerprints in EvalKey are process-stable by design (see
// util::hash), so a memoized evaluation survives across processes: a
// repeat sweep starts warm instead of re-deriving every estimate. The
// format is versioned JSON through util::json; loading is strict — any
// parse failure, schema mismatch or key/payload contradiction rejects
// the whole file so a corrupt or stale cache can never serve wrong
// entries — and the CLI falls back to a cold cache with a warning via
// [`EvalCache::load_or_cold`].
//
// v5 (this version) additionally records each entry's batch size (part
// of the key) plus, at batch ≥ 2, the closed-form batched throughput
// payload. Older files still load:
//
// * v4 entries carry over unchanged at batch = 1 — a single-frame v4
//   evaluation is bit-identical to a fresh batch-1 computation, so
//   nothing is dropped.
// * v3 entries carry over unchanged into the tenant-0 (default)
//   namespace at batch = 1 — the payload layout is identical, only the
//   namespace and batch components are new.
// * v2 analytical entries carry over (keyed at γ = 0, tenant 0); v2
//   *stepped* entries are dropped, because v3 replaced the whole-byte
//   DDR credit with the exact fractional-rational model
//   (`sim::ddr_credit_rate`), so a v2 stepped census would contradict a
//   fresh computation.
// * v1 analytical entries carry over with stamp 0 (oldest, first to
//   evict); v1 stepped entries are dropped (PR 3 changed the stepped
//   semantics first: whole-byte credit + held-slice rollback).
// ---------------------------------------------------------------------------

/// Format tag of the on-disk cache file.
pub const CACHE_FORMAT: &str = "cnn2gate-evalcache-v1";
/// Schema version within the format; bumped on any layout change.
pub const CACHE_VERSION: i64 = 5;
/// Oldest version [`EvalCache::from_json`] still accepts.
pub const CACHE_VERSION_MIN: i64 = 1;
/// Largest integer `util::json` round-trips exactly (below 2^53).
const JSON_MAX_INT: u64 = 9_000_000_000_000_000;

pub(crate) fn hex16(v: u64) -> String {
    format!("{v:016x}")
}

pub(crate) fn parse_hex16(s: &str) -> Result<u64, String> {
    if s.len() != 16 {
        return Err(format!("bad fingerprint '{s}' (want 16 hex digits)"));
    }
    u64::from_str_radix(s, 16).map_err(|e| format!("bad fingerprint '{s}': {e}"))
}

pub(crate) fn jf(v: &Json, key: &str) -> Result<f64, String> {
    v.get(key)
        .as_f64()
        .filter(|x| x.is_finite())
        .ok_or_else(|| format!("missing or non-finite number '{key}'"))
}

fn ju(v: &Json, key: &str) -> Result<u64, String> {
    let n = v
        .get(key)
        .as_i64()
        .ok_or_else(|| format!("missing integer '{key}'"))?;
    u64::try_from(n).map_err(|_| format!("negative '{key}'"))
}

pub(crate) fn jus(v: &Json, key: &str) -> Result<usize, String> {
    v.get(key)
        .as_usize()
        .ok_or_else(|| format!("missing count '{key}'"))
}

fn jb(v: &Json, key: &str) -> Result<bool, String> {
    v.get(key)
        .as_bool()
        .ok_or_else(|| format!("missing bool '{key}'"))
}

pub(crate) fn js(v: &Json, key: &str) -> Result<String, String> {
    v.get(key)
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| format!("missing string '{key}'"))
}

fn step_ints(s: &StepReport) -> [u64; 9] {
    [
        s.cycles,
        s.rd_busy,
        s.conv_busy,
        s.wr_busy,
        s.rd_to_conv_full_stalls,
        s.conv_to_wr_full_stalls,
        s.conv_empty_stalls,
        s.feed_a_empty_stalls,
        s.feed_b_empty_stalls,
    ]
}

/// Whether every integer/float in the evaluation survives a JSON
/// round-trip bit-for-bit; unsafe entries are skipped on save rather
/// than persisted lossily.
pub(crate) fn json_safe(e: &Evaluation, last_used: u64) -> bool {
    let ints_ok = std::iter::once(e.latency.total_cycles)
        .chain(std::iter::once(last_used))
        .chain(
            e.latency
                .layers
                .iter()
                .flat_map(|l| [l.macs, l.compute_cycles, l.ddr_cycles, l.cycles]),
        )
        .chain(
            e.batched
                .iter()
                .flat_map(|b| b.layers.iter())
                .flat_map(|l| [l.macs, l.compute_cycles, l.ddr_cycles, l.cycles]),
        )
        .chain(e.stepped.iter().flat_map(step_ints))
        .chain(
            e.stepped_network
                .iter()
                .flat_map(|n| n.layers.iter().flat_map(step_ints)),
        )
        .all(|v| v < JSON_MAX_INT);
    let est = &e.estimate;
    let floats_ok = [
        est.alms,
        est.dsps,
        est.ram_blocks,
        est.mem_bits,
        est.registers,
        est.p_lut,
        est.p_dsp,
        est.p_mem,
        est.p_reg,
        est.fmax_mhz,
        e.latency.fmax_mhz,
        e.latency.total_millis,
        e.latency.gops,
    ]
    .iter()
    .all(|v| v.is_finite())
        && e.latency.layers.iter().all(|l| l.millis.is_finite())
        && e.batched.iter().all(|b| {
            b.total_millis.is_finite()
                && b.millis_per_frame.is_finite()
                && b.gops_per_s.is_finite()
                && b.layers.iter().all(|l| l.millis.is_finite())
        })
        && e.stepped_network.iter().all(|n| n.fmax_mhz.is_finite());
    ints_ok && floats_ok
}

pub(crate) fn est_to_json(e: &ResourceEstimate) -> Json {
    let mut o = JsonObj::new();
    o.insert("ni", e.ni.into());
    o.insert("nl", e.nl.into());
    o.insert("alms", e.alms.into());
    o.insert("dsps", e.dsps.into());
    o.insert("ram_blocks", e.ram_blocks.into());
    o.insert("mem_bits", e.mem_bits.into());
    o.insert("registers", e.registers.into());
    o.insert("p_lut", e.p_lut.into());
    o.insert("p_dsp", e.p_dsp.into());
    o.insert("p_mem", e.p_mem.into());
    o.insert("p_reg", e.p_reg.into());
    o.insert("fmax_mhz", e.fmax_mhz.into());
    Json::Obj(o)
}

fn est_from_json(v: &Json) -> Result<ResourceEstimate, String> {
    Ok(ResourceEstimate {
        ni: jus(v, "ni")?,
        nl: jus(v, "nl")?,
        alms: jf(v, "alms")?,
        dsps: jf(v, "dsps")?,
        ram_blocks: jf(v, "ram_blocks")?,
        mem_bits: jf(v, "mem_bits")?,
        registers: jf(v, "registers")?,
        p_lut: jf(v, "p_lut")?,
        p_dsp: jf(v, "p_dsp")?,
        p_mem: jf(v, "p_mem")?,
        p_reg: jf(v, "p_reg")?,
        fmax_mhz: jf(v, "fmax_mhz")?,
    })
}

fn layer_to_json(l: &LayerTiming) -> Json {
    let mut o = JsonObj::new();
    o.insert("index", l.index.into());
    o.insert("label", l.label.as_str().into());
    o.insert("is_conv", l.is_conv.into());
    o.insert("macs", Json::Num(l.macs as f64));
    o.insert("compute_cycles", Json::Num(l.compute_cycles as f64));
    o.insert("ddr_cycles", Json::Num(l.ddr_cycles as f64));
    o.insert("cycles", Json::Num(l.cycles as f64));
    o.insert("millis", l.millis.into());
    o.insert("memory_bound", l.memory_bound.into());
    Json::Obj(o)
}

fn layer_from_json(v: &Json) -> Result<LayerTiming, String> {
    Ok(LayerTiming {
        index: jus(v, "index")?,
        label: js(v, "label")?,
        is_conv: jb(v, "is_conv")?,
        macs: ju(v, "macs")?,
        compute_cycles: ju(v, "compute_cycles")?,
        ddr_cycles: ju(v, "ddr_cycles")?,
        cycles: ju(v, "cycles")?,
        millis: jf(v, "millis")?,
        memory_bound: jb(v, "memory_bound")?,
    })
}

pub(crate) fn sim_to_json(s: &SimReport) -> Json {
    let mut o = JsonObj::new();
    o.insert("model", s.model.as_str().into());
    o.insert("device", s.device.as_str().into());
    o.insert("ni", s.ni.into());
    o.insert("nl", s.nl.into());
    o.insert("fmax_mhz", s.fmax_mhz.into());
    o.insert("total_cycles", Json::Num(s.total_cycles as f64));
    o.insert("total_millis", s.total_millis.into());
    o.insert("gops", s.gops.into());
    o.insert("layers", Json::Arr(s.layers.iter().map(layer_to_json).collect()));
    Json::Obj(o)
}

fn sim_from_json(v: &Json) -> Result<SimReport, String> {
    let layers = v
        .get("layers")
        .as_arr()
        .ok_or_else(|| "latency missing 'layers'".to_string())?
        .iter()
        .map(layer_from_json)
        .collect::<Result<Vec<_>, String>>()?;
    Ok(SimReport {
        model: js(v, "model")?,
        device: js(v, "device")?,
        ni: jus(v, "ni")?,
        nl: jus(v, "nl")?,
        fmax_mhz: jf(v, "fmax_mhz")?,
        layers,
        total_cycles: ju(v, "total_cycles")?,
        total_millis: jf(v, "total_millis")?,
        gops: jf(v, "gops")?,
    })
}

fn step_to_json(s: &StepReport) -> Json {
    let mut o = JsonObj::new();
    o.insert("cycles", Json::Num(s.cycles as f64));
    o.insert("rd_busy", Json::Num(s.rd_busy as f64));
    o.insert("conv_busy", Json::Num(s.conv_busy as f64));
    o.insert("wr_busy", Json::Num(s.wr_busy as f64));
    o.insert("rd_to_conv_full_stalls", Json::Num(s.rd_to_conv_full_stalls as f64));
    o.insert("conv_to_wr_full_stalls", Json::Num(s.conv_to_wr_full_stalls as f64));
    o.insert("conv_empty_stalls", Json::Num(s.conv_empty_stalls as f64));
    // per-feed starvation attribution only exists on multi-producer
    // (Add-merge) rounds; emitting the fields only when nonzero keeps
    // every linear-chain census byte-identical to its pre-branch form
    if s.feed_a_empty_stalls != 0 {
        o.insert("feed_a_empty_stalls", Json::Num(s.feed_a_empty_stalls as f64));
    }
    if s.feed_b_empty_stalls != 0 {
        o.insert("feed_b_empty_stalls", Json::Num(s.feed_b_empty_stalls as f64));
    }
    Json::Obj(o)
}

fn step_from_json(v: &Json) -> Result<StepReport, String> {
    Ok(StepReport {
        cycles: ju(v, "cycles")?,
        rd_busy: ju(v, "rd_busy")?,
        conv_busy: ju(v, "conv_busy")?,
        wr_busy: ju(v, "wr_busy")?,
        rd_to_conv_full_stalls: ju(v, "rd_to_conv_full_stalls")?,
        conv_to_wr_full_stalls: ju(v, "conv_to_wr_full_stalls")?,
        conv_empty_stalls: ju(v, "conv_empty_stalls")?,
        // absent on single-feed rounds and in every pre-v5 census
        feed_a_empty_stalls: v.get("feed_a_empty_stalls").as_usize().unwrap_or(0) as u64,
        feed_b_empty_stalls: v.get("feed_b_empty_stalls").as_usize().unwrap_or(0) as u64,
    })
}

pub(crate) fn net_to_json(n: &NetworkStepReport) -> Json {
    let mut o = JsonObj::new();
    o.insert("fmax_mhz", n.fmax_mhz.into());
    o.insert("batch", n.batch.into());
    o.insert("layers", Json::Arr(n.layers.iter().map(step_to_json).collect()));
    Json::Obj(o)
}

fn net_from_json(v: &Json) -> Result<NetworkStepReport, String> {
    let layers = v
        .get("layers")
        .as_arr()
        .ok_or_else(|| "stepped_network missing 'layers'".to_string())?
        .iter()
        .map(step_from_json)
        .collect::<Result<Vec<_>, String>>()?;
    Ok(NetworkStepReport {
        fmax_mhz: jf(v, "fmax_mhz")?,
        // pre-v5 censuses predate the batch dimension (single-frame)
        batch: v.get("batch").as_usize().unwrap_or(1),
        layers,
    })
}

fn batch_to_json(b: &BatchReport) -> Json {
    let mut o = JsonObj::new();
    o.insert("batch", b.batch.into());
    o.insert("total_millis", b.total_millis.into());
    o.insert("millis_per_frame", b.millis_per_frame.into());
    o.insert("gops_per_s", b.gops_per_s.into());
    o.insert("layers", Json::Arr(b.layers.iter().map(layer_to_json).collect()));
    Json::Obj(o)
}

fn batch_from_json(v: &Json) -> Result<BatchReport, String> {
    let layers = v
        .get("layers")
        .as_arr()
        .ok_or_else(|| "batched missing 'layers'".to_string())?
        .iter()
        .map(layer_from_json)
        .collect::<Result<Vec<_>, String>>()?;
    Ok(BatchReport {
        batch: jus(v, "batch")?,
        total_millis: jf(v, "total_millis")?,
        millis_per_frame: jf(v, "millis_per_frame")?,
        gops_per_s: jf(v, "gops_per_s")?,
        layers,
    })
}

pub(crate) fn entry_to_json(key: &EvalKey, eval: &Evaluation, last_used: u64) -> Json {
    let mut o = JsonObj::new();
    o.insert("model", Json::Str(hex16(key.model)));
    o.insert("device", Json::Str(hex16(key.device)));
    o.insert("ni", key.ni.into());
    o.insert("nl", key.nl.into());
    o.insert("batch", key.batch.into());
    o.insert("fidelity", fidelity_tag(key.fidelity).into());
    o.insert("census_gamma", Json::Num(f64::from_bits(key.census_gamma)));
    o.insert("tenant", Json::Str(hex16(key.tenant)));
    o.insert("last_used", Json::Num(last_used as f64));
    o.insert("estimate", est_to_json(&eval.estimate));
    o.insert("latency", sim_to_json(&eval.latency));
    o.insert(
        "batched",
        match &eval.batched {
            Some(b) => batch_to_json(b),
            None => Json::Null,
        },
    );
    o.insert(
        "stepped_report",
        match &eval.stepped {
            Some(s) => step_to_json(s),
            None => Json::Null,
        },
    );
    o.insert(
        "stepped_network",
        match &eval.stepped_network {
            Some(n) => net_to_json(n),
            None => Json::Null,
        },
    );
    Json::Obj(o)
}

/// Parse one v5 entry; `Err` rejects the whole file.
pub(crate) fn entry_from_json_v5(v: &Json) -> Result<(EvalKey, Evaluation, u64), String> {
    let census_gamma = jf(v, "census_gamma")?;
    let tenant = parse_hex16(&js(v, "tenant")?)?;
    let batch = jus(v, "batch")?;
    if batch == 0 {
        return Err("zero batch".to_string());
    }
    entry_from_json_tagged(v, census_gamma, tenant, batch)
}

/// Parse one v4 entry (no batch field; carries over at batch = 1);
/// `Err` rejects the whole file.
fn entry_from_json_v4(v: &Json) -> Result<(EvalKey, Evaluation, u64), String> {
    let census_gamma = jf(v, "census_gamma")?;
    let tenant = parse_hex16(&js(v, "tenant")?)?;
    entry_from_json_tagged(v, census_gamma, tenant, 1)
}

/// Parse one v3 entry (no tenant field; carries into the default
/// namespace at batch = 1); `Err` rejects the whole file.
fn entry_from_json_v3(v: &Json) -> Result<(EvalKey, Evaluation, u64), String> {
    let census_gamma = jf(v, "census_gamma")?;
    entry_from_json_tagged(v, census_gamma, 0, 1)
}

/// Parse one v2 entry. `Ok(None)` means a valid-but-dropped entry (v2
/// stepped censuses predate the fractional-credit stepper and are
/// discarded); carried analytical entries key at γ = 0, tenant 0. `Err`
/// rejects the whole file.
fn entry_from_json_v2(v: &Json) -> Result<Option<(EvalKey, Evaluation, u64)>, String> {
    if parse_fidelity_tag(&js(v, "fidelity")?)? != Fidelity::Analytical {
        return Ok(None);
    }
    entry_from_json_tagged(v, 0.0, 0, 1).map(Some)
}

/// The shared v2/v3/v4/v5 entry body (v5 carries the γ, tenant and batch
/// fields, v4 γ and tenant, v3 the γ field only, v2 none of them).
fn entry_from_json_tagged(
    v: &Json,
    census_gamma: f64,
    tenant: u64,
    batch: usize,
) -> Result<(EvalKey, Evaluation, u64), String> {
    let fidelity = parse_fidelity_tag(&js(v, "fidelity")?)?;
    let key = EvalKey {
        model: parse_hex16(&js(v, "model")?)?,
        device: parse_hex16(&js(v, "device")?)?,
        ni: jus(v, "ni")?,
        nl: jus(v, "nl")?,
        fidelity,
        census_gamma: gamma_key_bits(census_gamma),
        tenant,
        batch,
    };
    let last_used = ju(v, "last_used")?;
    let estimate = est_from_json(v.get("estimate"))?;
    let latency = sim_from_json(v.get("latency"))?;
    // pre-v5 entries have no batched payload; at their batch = 1 the
    // shape check below demands None, so the two cases coincide
    let batched = match v.get("batched") {
        Json::Null => None,
        b => Some(batch_from_json(b)?),
    };
    let stepped = match v.get("stepped_report") {
        Json::Null => None,
        s => Some(step_from_json(s)?),
    };
    let stepped_network = match v.get("stepped_network") {
        Json::Null => None,
        n => Some(net_from_json(n)?),
    };
    // fingerprint-collision / tamper paranoia: the payload carries the
    // option redundantly, so a mis-keyed entry is detectable — reject
    // the file rather than risk serving a wrong estimate
    if estimate.ni != key.ni || estimate.nl != key.nl {
        return Err(format!(
            "estimate option ({},{}) contradicts key ({},{})",
            estimate.ni, estimate.nl, key.ni, key.nl
        ));
    }
    if latency.ni != key.ni || latency.nl != key.nl {
        return Err(format!(
            "latency option ({},{}) contradicts key ({},{})",
            latency.ni, latency.nl, key.ni, key.nl
        ));
    }
    let shape_ok = match fidelity {
        Fidelity::Analytical => stepped.is_none() && stepped_network.is_none(),
        Fidelity::SteppedDominantRound => stepped.is_some() && stepped_network.is_none(),
        Fidelity::SteppedFullNetwork => stepped.is_none() && stepped_network.is_some(),
    };
    if !shape_ok {
        return Err(format!(
            "fidelity '{}' contradicts stepped payload shape",
            fidelity_tag(fidelity)
        ));
    }
    if batched.is_some() != (batch >= 2) {
        return Err(format!(
            "batch {batch} contradicts batched payload presence"
        ));
    }
    if let Some(b) = &batched {
        if b.batch != batch {
            return Err(format!(
                "batched payload says batch {} but key says {batch}",
                b.batch
            ));
        }
        if b.layers.len() != latency.layers.len() {
            return Err(format!(
                "batched payload has {} rounds but latency has {}",
                b.layers.len(),
                latency.layers.len()
            ));
        }
    }
    if let Some(net) = &stepped_network {
        if net.layers.len() != latency.layers.len() {
            return Err(format!(
                "stepped_network has {} rounds but latency has {}",
                net.layers.len(),
                latency.layers.len()
            ));
        }
        if net.batch != batch {
            return Err(format!(
                "stepped_network census says batch {} but key says {batch}",
                net.batch
            ));
        }
    }
    let eval = Evaluation {
        ni: key.ni,
        nl: key.nl,
        batch,
        estimate,
        latency,
        batched,
        stepped,
        stepped_network,
    };
    Ok((key, eval, last_used))
}

/// Parse one v1 entry. `Ok(None)` means a valid-but-dropped entry (v1
/// stepped censuses predate the exact-credit stepper and are discarded);
/// `Err` rejects the whole file.
fn entry_from_json_v1(v: &Json) -> Result<Option<(EvalKey, Evaluation, u64)>, String> {
    if jb(v, "stepped")? {
        return Ok(None);
    }
    let key = EvalKey {
        model: parse_hex16(&js(v, "model")?)?,
        device: parse_hex16(&js(v, "device")?)?,
        ni: jus(v, "ni")?,
        nl: jus(v, "nl")?,
        fidelity: Fidelity::Analytical,
        census_gamma: 0f64.to_bits(),
        tenant: 0,
        batch: 1,
    };
    let estimate = est_from_json(v.get("estimate"))?;
    let latency = sim_from_json(v.get("latency"))?;
    if estimate.ni != key.ni || estimate.nl != key.nl {
        return Err(format!(
            "estimate option ({},{}) contradicts key ({},{})",
            estimate.ni, estimate.nl, key.ni, key.nl
        ));
    }
    if latency.ni != key.ni || latency.nl != key.nl {
        return Err(format!(
            "latency option ({},{}) contradicts key ({},{})",
            latency.ni, latency.nl, key.ni, key.nl
        ));
    }
    if !v.get("stepped_report").is_null() {
        return Err("v1 analytical entry carries a stepped payload".to_string());
    }
    let eval = Evaluation {
        ni: key.ni,
        nl: key.nl,
        batch: 1,
        estimate,
        latency,
        batched: None,
        stepped: None,
        stepped_network: None,
    };
    Ok(Some((key, eval, 0)))
}

impl EvalCache {
    /// Snapshot every entry as `(key, payload, LRU stamp)`, sorted by
    /// [`EvalKey::sort_key`] — the deterministic export both the legacy
    /// whole-file serializer and the sharded store diff against.
    pub(crate) fn export_entries(&self) -> Vec<(EvalKey, Arc<Evaluation>, u64)> {
        let mut entries: Vec<(EvalKey, Arc<Evaluation>, u64)> = locked(&self.map)
            .iter()
            .map(|(k, e)| (*k, Arc::clone(&e.eval), e.last_used))
            .collect();
        entries.sort_by_key(|(k, _, _)| k.sort_key());
        entries
    }

    /// Insert one deserialized entry. Returns `false` (and keeps the
    /// resident entry) when the key is already present — loaders use
    /// this to make the first-loaded source win deterministically.
    pub(crate) fn insert_entry(&self, key: EvalKey, eval: Arc<Evaluation>, last_used: u64) -> bool {
        let mut map = locked(&self.map);
        if map.contains_key(&key) {
            return false;
        }
        map.insert(key, CacheEntry { eval, last_used });
        true
    }

    /// Advance the LRU clock to at least `stamp`, so generations issued
    /// after a load always outrank every loaded entry's stamp.
    pub(crate) fn resume_clock(&self, stamp: u64) {
        self.clock.fetch_max(stamp, Ordering::Relaxed);
    }

    /// Copy every entry of `other` that this cache does not already
    /// have (this cache's entries win conflicts) and resume the clock
    /// past the absorbed stamps; returns how many entries were copied.
    /// This is the one-shot legacy-file → store migration primitive.
    pub(crate) fn absorb_missing(&self, other: &EvalCache) -> usize {
        let mut absorbed = 0;
        let mut newest = 0u64;
        for (key, eval, last_used) in other.export_entries() {
            newest = newest.max(last_used);
            if self.insert_entry(key, eval, last_used) {
                absorbed += 1;
            }
        }
        self.resume_clock(newest);
        absorbed
    }

    /// Serialize every (JSON-safe) entry. Entries are sorted by key so
    /// repeated saves of the same cache are byte-identical (diff-stable).
    pub fn to_json(&self) -> Json {
        let entries = self.export_entries();
        let rows: Vec<Json> = entries
            .iter()
            .filter(|(k, e, last_used)| {
                json_safe(e, *last_used) && f64::from_bits(k.census_gamma).is_finite()
            })
            .map(|(k, e, last_used)| entry_to_json(k, e, *last_used))
            .collect();
        let mut o = JsonObj::new();
        o.insert("format", CACHE_FORMAT.into());
        o.insert("version", CACHE_VERSION.into());
        o.insert("entries", Json::Arr(rows));
        Json::Obj(o)
    }

    /// Deserialize a cache document (current v5 or legacy v1/v2/v3/v4 —
    /// see the module docs for the carry-over rules). Strict: schema
    /// mismatches, missing fields, duplicate keys and key/payload
    /// contradictions all reject the whole document. Counters start at
    /// zero (a loaded entry counts as a hit only when something looks it
    /// up); the LRU clock resumes past the newest loaded stamp.
    pub fn from_json(doc: &Json) -> Result<EvalCache, String> {
        match doc.get("format").as_str() {
            Some(f) if f == CACHE_FORMAT => {}
            other => {
                return Err(format!(
                    "unsupported cache format {other:?} (want {CACHE_FORMAT:?})"
                ))
            }
        }
        let version = match doc.get("version").as_i64() {
            Some(v) if (CACHE_VERSION_MIN..=CACHE_VERSION).contains(&v) => v,
            other => {
                return Err(format!(
                    "unsupported cache version {other:?} (want {CACHE_VERSION_MIN}..={CACHE_VERSION})"
                ))
            }
        };
        let rows = doc
            .get("entries")
            .as_arr()
            .ok_or_else(|| "missing 'entries' array".to_string())?;
        let cache = EvalCache::new();
        let mut newest = 0u64;
        {
            let mut map = locked(&cache.map);
            map.reserve(rows.len());
            for (i, row) in rows.iter().enumerate() {
                let parsed = match version {
                    1 => entry_from_json_v1(row).map_err(|e| format!("entry {i}: {e}"))?,
                    2 => entry_from_json_v2(row).map_err(|e| format!("entry {i}: {e}"))?,
                    3 => Some(entry_from_json_v3(row).map_err(|e| format!("entry {i}: {e}"))?),
                    4 => Some(entry_from_json_v4(row).map_err(|e| format!("entry {i}: {e}"))?),
                    _ => Some(entry_from_json_v5(row).map_err(|e| format!("entry {i}: {e}"))?),
                };
                let Some((key, eval, last_used)) = parsed else {
                    continue; // dropped legacy stepped entry
                };
                newest = newest.max(last_used);
                let entry = CacheEntry {
                    eval: Arc::new(eval),
                    last_used,
                };
                if map.insert(key, entry).is_some() {
                    return Err(format!("entry {i}: duplicate cache key"));
                }
            }
        }
        cache.clock.store(newest, Ordering::Relaxed);
        Ok(cache)
    }

    /// Write the cache to `path` (via a sibling tmp file + rename, so a
    /// crash mid-write never leaves a truncated cache behind). Returns
    /// the number of entries written.
    pub fn save(&self, path: &Path) -> anyhow::Result<usize> {
        let json = self.to_json();
        let written = json.get("entries").as_arr().map_or(0, <[Json]>::len);
        // per-process tmp name: concurrent saves to the same cache file
        // must never publish each other's half-written tmp via rename
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        std::fs::write(&tmp, json.to_string_pretty())
            .with_context(|| format!("writing cache file {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("moving cache file into place at {}", path.display()))?;
        Ok(written)
    }

    /// Strict load: a missing file, a parse error, a schema mismatch or
    /// a failed validation is an error (see [`EvalCache::load_or_cold`]
    /// for the tolerant CLI path).
    pub fn load(path: &Path) -> anyhow::Result<EvalCache> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading cache file {}", path.display()))?;
        let doc = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        EvalCache::from_json(&doc).map_err(|e| anyhow!("{}: {e}", path.display()))
    }

    /// Tolerant load for the CLI: a missing file is a silent cold start;
    /// a corrupt or stale file falls back to a cold cache with a warning
    /// message — never a panic, never a suspect entry.
    pub fn load_or_cold(path: &Path) -> (EvalCache, Option<String>) {
        if !path.exists() {
            return (EvalCache::new(), None);
        }
        match EvalCache::load(path) {
            Ok(cache) => (cache, None),
            Err(e) => (
                EvalCache::new(),
                Some(format!(
                    "ignoring corrupt or stale cache file {} ({e:#}); starting cold",
                    path.display()
                )),
            ),
        }
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Plain worker pool over `std::thread` + mpsc channels (the
/// `coordinator::server` threading idiom). Workers pull boxed jobs off
/// a shared queue; dropping the pool closes the queue and joins them.
/// The submit side is mutex-wrapped so the pool is `Sync` (the global
/// evaluator lives in a static) on every supported toolchain.
pub struct ThreadPool {
    tx: Option<Mutex<mpsc::Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    // Holding the lock across recv is the standard
                    // hand-off: the holder parks until a job arrives,
                    // takes it, releases, and the next worker parks.
                    let job = locked(&rx).recv();
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // queue closed: pool dropped
                    }
                })
            })
            .collect();
        ThreadPool {
            tx: Some(Mutex::new(tx)),
            workers,
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Queue one job. The sender is `Some` for the pool's whole borrowed
    /// lifetime (it is only taken in `Drop`), and a failed `send` means
    /// every worker already panicked — the job is dropped and the
    /// caller's result loop observes the closed channel instead.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        let Some(tx) = self.tx.as_ref() else {
            return; // unreachable outside Drop, which holds &mut self
        };
        let _ = locked(tx).send(Box::new(job));
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the queue; workers drain and exit
        for worker in self.workers.drain(..) {
            let _ = worker.join();
        }
    }
}

/// The evaluation core an explorer talks to: a thread pool plus a
/// (shareable) memo cache.
pub struct Evaluator {
    pool: ThreadPool,
    cache: Arc<EvalCache>,
}

impl Evaluator {
    /// Fresh cache, `threads` workers.
    pub fn new(threads: usize) -> Evaluator {
        Evaluator::with_cache(threads, Arc::new(EvalCache::new()))
    }

    /// Share an existing cache (e.g. the global one) with a private pool.
    pub fn with_cache(threads: usize, cache: Arc<EvalCache>) -> Evaluator {
        Evaluator {
            pool: ThreadPool::new(threads),
            cache,
        }
    }

    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    pub fn cache(&self) -> &EvalCache {
        &self.cache
    }

    /// The shared cache handle itself — for seeding another evaluator
    /// with the same memo (e.g. `serve` sharing a session's store-backed
    /// cache with its compile daemon).
    pub fn cache_handle(&self) -> Arc<EvalCache> {
        Arc::clone(&self.cache)
    }

    /// Evaluate one candidate inline (cache-aware, no pool dispatch) —
    /// what the inherently sequential RL/joint agents call per step.
    pub fn evaluate(
        &self,
        flow: &ComputationFlow,
        device: &Device,
        ni: usize,
        nl: usize,
        req: EvalRequest,
    ) -> (Arc<Evaluation>, bool) {
        self.cache.get_or_compute(flow, device, ni, nl, req)
    }

    /// Evaluate a whole candidate grid, fanning the misses out across
    /// the pool. Results come back in `pairs` order, so a sequential
    /// reduction over them (e.g. Algorithm 1's running max) is
    /// bit-identical to the sequential seed path. Must not be called
    /// from inside a pool worker (see module docs).
    pub fn evaluate_grid(
        &self,
        flow: &ComputationFlow,
        device: &Device,
        pairs: &[(usize, usize)],
        req: EvalRequest,
    ) -> Vec<(Arc<Evaluation>, bool)> {
        // fingerprints are loop-invariant: hash once per grid; the whole
        // grid shares one LRU generation so worker completion order
        // can't perturb the persisted stamps
        let (model_fp, device_fp) = (flow.fingerprint(), device.fingerprint());
        let stamp = self.cache.tick();
        let fidelity = req.fidelity;
        let key_of = |ni: usize, nl: usize| EvalKey {
            model: model_fp,
            device: device_fp,
            ni,
            nl,
            fidelity,
            census_gamma: gamma_key_bits(req.census_gamma),
            tenant: req.tenant.as_u64(),
            batch: req.batch.max(1),
        };
        if pairs.len() < 2 || self.pool.size() < 2 {
            return pairs
                .iter()
                .map(|&(ni, nl)| {
                    self.cache
                        .get_or_compute_keyed(key_of(ni, nl), stamp, flow, device, fidelity)
                })
                .collect();
        }
        let flow = Arc::new(flow.clone());
        let device = Arc::new(device.clone());
        let (tx, rx) = mpsc::channel();
        for (idx, &(ni, nl)) in pairs.iter().enumerate() {
            let key = key_of(ni, nl);
            let flow = Arc::clone(&flow);
            let device = Arc::clone(&device);
            let cache = Arc::clone(&self.cache);
            let tx = tx.clone();
            self.pool.execute(move || {
                let out = cache.get_or_compute_keyed(key, stamp, &flow, &device, fidelity);
                let _ = tx.send((idx, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<(Arc<Evaluation>, bool)>> = vec![None; pairs.len()];
        while let Ok((idx, out)) = rx.recv() {
            slots[idx] = Some(out);
        }
        slots
            .into_iter()
            // analysis: allow(panic, a hole means a pool worker panicked inside Evaluation::compute — an unrecoverable bug, not a fallible path)
            .map(|s| s.expect("every candidate evaluated"))
            .collect()
    }
}

/// Worker count for the process-wide evaluator: one per core, clamped
/// to [2, 8] (the option grids are small; more threads only add queue
/// contention).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 8)
}

static GLOBAL: OnceLock<Evaluator> = OnceLock::new();

/// The process-wide evaluator every explorer uses by default. Its cache
/// persists for the process lifetime, so repeated explorations of the
/// same (model, device) — RL episodes, fleet fits, report regeneration —
/// pay for each unique candidate once.
pub fn global() -> &'static Evaluator {
    GLOBAL.get_or_init(|| Evaluator::new(default_threads()))
}

/// Fork/join map over scoped threads with a shared work queue: applies
/// `f` to every item on up to `threads` workers and returns results in
/// input order. Used for coarse-grained fan-out (one job per device in
/// the fleet fit) where jobs themselves may use the global pool.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    if items.is_empty() {
        return Vec::new();
    }
    let workers = threads.clamp(1, items.len());
    if workers == 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let next_ref = &next;
    let f_ref = &f;
    std::thread::scope(|s| {
        for _ in 0..workers {
            let tx = tx.clone();
            s.spawn(move || loop {
                let i = next_ref.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let _ = tx.send((i, f_ref(&items[i])));
            });
        }
    });
    drop(tx);
    let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    for (i, r) in rx {
        slots[i] = Some(r);
    }
    slots
        .into_iter()
        // analysis: allow(panic, the shared-cursor loop claims every index exactly once; a hole means `f` itself panicked in a worker)
        .map(|s| s.expect("scoped worker produced result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::OptionSpace;
    use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4, CYCLONE_V_5CSEMA5};
    use crate::onnx::zoo;
    use crate::sim::simulate;

    fn flow(name: &str) -> ComputationFlow {
        ComputationFlow::extract(&zoo::build(name, false).unwrap()).unwrap()
    }

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cnn2gate-evalcache-{}-{tag}.json", std::process::id()))
    }

    /// Shorthand for the unshaped, default-tenant request.
    fn req(fidelity: Fidelity) -> EvalRequest {
        EvalRequest::at(fidelity)
    }

    #[test]
    fn pool_runs_every_job() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let counter = Arc::clone(&counter);
            pool.execute(move || {
                counter.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool); // joins workers after the queue drains
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..57).collect();
        let out = parallel_map(&items, 4, |&i| i * i);
        assert_eq!(out, items.iter().map(|&i| i * i).collect::<Vec<_>>());
        // degenerate widths
        assert_eq!(parallel_map(&items, 1, |&i| i + 1).len(), 57);
        assert!(parallel_map::<usize, usize, _>(&[], 4, |&i| i).is_empty());
    }

    #[test]
    fn parallel_grid_is_bit_identical_to_sequential() {
        // The satellite contract: fanning candidate scoring across the
        // pool must not change a single bit of any estimate, on either
        // paper fixture.
        for model in ["alexnet", "vgg16"] {
            let f = flow(model);
            let pairs = OptionSpace::from_flow(&f).pairs();
            for dev in [&ARRIA_10_GX1150, &CYCLONE_V_5CSEMA5, &CYCLONE_V_5CSEMA4] {
                let ev = Evaluator::new(4);
                let grid = ev.evaluate_grid(&f, dev, &pairs, req(Fidelity::Analytical));
                assert_eq!(grid.len(), pairs.len());
                for ((eval, hit), &(ni, nl)) in grid.iter().zip(&pairs) {
                    assert!(!hit, "fresh cache cannot hit");
                    let seq = estimate(&f, dev, ni, nl);
                    assert_eq!(eval.estimate, seq, "{model} {} ({ni},{nl})", dev.name);
                    assert_eq!(eval.latency.total_cycles, simulate(&f, dev, ni, nl).total_cycles);
                }
            }
        }
    }

    #[test]
    fn cache_hit_counts_are_deterministic() {
        let f = flow("alexnet");
        let pairs = OptionSpace::from_flow(&f).pairs();
        let run = || {
            let ev = Evaluator::new(4);
            ev.evaluate_grid(&f, &ARRIA_10_GX1150, &pairs, req(Fidelity::Analytical));
            let first = ev.cache().stats();
            ev.evaluate_grid(&f, &ARRIA_10_GX1150, &pairs, req(Fidelity::Analytical));
            (first, ev.cache().stats())
        };
        let (first_a, second_a) = run();
        let (first_b, second_b) = run();
        assert_eq!(first_a, first_b, "cold-run stats must reproduce");
        assert_eq!(second_a, second_b, "warm-run stats must reproduce");
        assert_eq!(first_a.misses, pairs.len());
        assert_eq!(first_a.hits, 0);
        assert_eq!(second_a.hits, pairs.len());
        assert_eq!(second_a.misses, pairs.len());
        assert_eq!(second_a.entries, pairs.len());
        assert!((second_a.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cache_discriminates_models_devices_and_fidelities() {
        let a = flow("alexnet");
        let v = flow("vgg16");
        assert_ne!(a.fingerprint(), v.fingerprint());
        assert_ne!(
            ARRIA_10_GX1150.fingerprint(),
            CYCLONE_V_5CSEMA5.fingerprint()
        );
        let ev = Evaluator::new(2);
        ev.evaluate(&a, &ARRIA_10_GX1150, 8, 8, req(Fidelity::Analytical));
        let (_, hit) = ev.evaluate(&v, &ARRIA_10_GX1150, 8, 8, req(Fidelity::Analytical));
        assert!(!hit, "different model must miss");
        let (_, hit) = ev.evaluate(&a, &CYCLONE_V_5CSEMA5, 8, 8, req(Fidelity::Analytical));
        assert!(!hit, "different device must miss");
        let (_, hit) = ev.evaluate(&a, &ARRIA_10_GX1150, 8, 8, req(Fidelity::Analytical));
        assert!(hit, "same key must hit");
        let (_, hit) = ev.evaluate(&a, &ARRIA_10_GX1150, 8, 8, req(Fidelity::SteppedFullNetwork));
        assert!(!hit, "different fidelity must miss");
        // the census-reward γ is a key component: a shaped run can
        // never be served another γ-space's working set
        let shaped_req = EvalRequest::shaped(Fidelity::Analytical, 0.25);
        let (shaped, hit) = ev.evaluate(&a, &ARRIA_10_GX1150, 8, 8, shaped_req);
        assert!(!hit, "different census γ must miss");
        let (_, hit) = ev.evaluate(&a, &ARRIA_10_GX1150, 8, 8, shaped_req);
        assert!(hit, "same γ hits");
        // ... while the payload itself is γ-independent
        let (plain, _) = ev.evaluate(&a, &ARRIA_10_GX1150, 8, 8, req(Fidelity::Analytical));
        assert_eq!(*shaped, *plain);
    }

    #[test]
    fn stepped_fidelity_runs_the_dominant_round() {
        let f = flow("tiny");
        let ev = Evaluator::new(2);
        let (eval, _) =
            ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, req(Fidelity::SteppedDominantRound));
        let stepped = eval.stepped.as_ref().expect("stepped census present");
        assert!(stepped.cycles > 0);
        assert!(eval.stepped_network.is_none());
        // analytical fidelity for the same option is a distinct entry
        let (eval2, hit) = ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, req(Fidelity::Analytical));
        assert!(!hit);
        assert!(eval2.stepped.is_none());
    }

    #[test]
    fn full_network_fidelity_steps_every_round() {
        let f = flow("alexnet");
        let ev = Evaluator::new(2);
        let (eval, _) =
            ev.evaluate(&f, &ARRIA_10_GX1150, 16, 32, req(Fidelity::SteppedFullNetwork));
        let net = eval.stepped_network.as_ref().expect("network census");
        assert_eq!(net.layers.len(), f.layers.len());
        assert!(eval.stepped.is_none());
        assert!(net.total_cycles() > 0);
        // the dominant round's census equals the stepped-dominant run's
        let (dom, _) =
            ev.evaluate(&f, &ARRIA_10_GX1150, 16, 32, req(Fidelity::SteppedDominantRound));
        let dom_idx = f
            .layers
            .iter()
            .enumerate()
            .max_by_key(|(_, l)| l.macs())
            .unwrap()
            .0;
        assert_eq!(net.layers[dom_idx], *dom.stepped.as_ref().unwrap());
    }

    #[test]
    fn shared_cache_spans_evaluators() {
        let cache = Arc::new(EvalCache::new());
        let f = flow("alexnet");
        let a = Evaluator::with_cache(2, Arc::clone(&cache));
        a.evaluate(&f, &ARRIA_10_GX1150, 16, 32, req(Fidelity::Analytical));
        let b = Evaluator::with_cache(2, Arc::clone(&cache));
        let (_, hit) = b.evaluate(&f, &ARRIA_10_GX1150, 16, 32, req(Fidelity::Analytical));
        assert!(hit, "cache shared across evaluator instances");
        cache.clear();
        assert_eq!(cache.stats().entries, 0);
        assert_eq!(cache.stats().hits, 0);
    }

    #[test]
    fn lru_eviction_keeps_recently_used_entries() {
        let f = flow("tiny");
        let cache = EvalCache::new();
        // three entries, touched in order (4,4), (4,8), (8,4)
        cache.get_or_compute(&f, &ARRIA_10_GX1150, 4, 4, req(Fidelity::Analytical));
        cache.get_or_compute(&f, &ARRIA_10_GX1150, 4, 8, req(Fidelity::Analytical));
        cache.get_or_compute(&f, &ARRIA_10_GX1150, 8, 4, req(Fidelity::Analytical));
        // re-touch the oldest so (4,8) becomes LRU
        cache.get_or_compute(&f, &ARRIA_10_GX1150, 4, 4, req(Fidelity::Analytical));
        assert_eq!(cache.evict_lru(2), 1);
        assert_eq!(cache.stats().entries, 2);
        let (_, hit) = cache.get_or_compute(&f, &ARRIA_10_GX1150, 4, 4, req(Fidelity::Analytical));
        assert!(hit, "recently used survives");
        let (_, hit) = cache.get_or_compute(&f, &ARRIA_10_GX1150, 8, 4, req(Fidelity::Analytical));
        assert!(hit, "recently used survives");
        let (_, hit) = cache.get_or_compute(&f, &ARRIA_10_GX1150, 4, 8, req(Fidelity::Analytical));
        assert!(!hit, "LRU entry was evicted");
        // no-op when already under the bound
        assert_eq!(cache.evict_lru(100), 0);
    }

    #[test]
    fn eviction_then_save_shrinks_the_file() {
        let f = flow("alexnet");
        let pairs = OptionSpace::from_flow(&f).pairs();
        let ev = Evaluator::new(2);
        ev.evaluate_grid(&f, &ARRIA_10_GX1150, &pairs, req(Fidelity::Analytical));
        let path = tmp_path("evict");
        let full = ev.cache().save(&path).unwrap();
        assert_eq!(full, pairs.len());
        let evicted = ev.cache().evict_lru(4);
        assert_eq!(evicted, pairs.len() - 4);
        let trimmed = ev.cache().save(&path).unwrap();
        assert_eq!(trimmed, 4);
        assert_eq!(EvalCache::load(&path).unwrap().stats().entries, 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cache_roundtrips_through_disk_bit_for_bit() {
        let f = flow("alexnet");
        let tiny = flow("tiny");
        let pairs = OptionSpace::from_flow(&f).pairs();
        let ev = Evaluator::new(2);
        ev.evaluate_grid(&f, &ARRIA_10_GX1150, &pairs, req(Fidelity::Analytical));
        ev.evaluate(&tiny, &ARRIA_10_GX1150, 4, 4, req(Fidelity::SteppedDominantRound));
        ev.evaluate(&tiny, &ARRIA_10_GX1150, 4, 4, req(Fidelity::SteppedFullNetwork));
        let shaped_req = EvalRequest::shaped(Fidelity::Analytical, 0.25);
        ev.evaluate(&tiny, &ARRIA_10_GX1150, 4, 4, shaped_req);
        let acme = req(Fidelity::Analytical).tenant(TenantId::of("acme"));
        ev.evaluate(&tiny, &ARRIA_10_GX1150, 4, 4, acme);
        let path = tmp_path("roundtrip");
        let written = ev.cache().save(&path).unwrap();
        assert_eq!(
            written,
            pairs.len() + 4,
            "grid plus the two stepped entries, the γ-shaped one and the tenant one"
        );
        let loaded = EvalCache::load(&path).unwrap();
        assert_eq!(loaded.stats().entries, written);
        assert_eq!(loaded.stats().hits, 0, "counters start cold");
        assert_eq!(loaded.stats().misses, 0);
        // a warm evaluator over the loaded cache: every candidate hits,
        // and every payload is bit-identical to a fresh computation
        let warm = Evaluator::with_cache(2, Arc::new(loaded));
        let grid = warm.evaluate_grid(&f, &ARRIA_10_GX1150, &pairs, req(Fidelity::Analytical));
        assert!(grid.iter().all(|(_, hit)| *hit), "all served from disk");
        for ((eval, _), &(ni, nl)) in grid.iter().zip(&pairs) {
            let fresh = Evaluation::compute(&f, &ARRIA_10_GX1150, ni, nl, Fidelity::Analytical);
            assert_eq!(**eval, fresh, "({ni},{nl}) drifted through the disk format");
        }
        let (stepped, hit) =
            warm.evaluate(&tiny, &ARRIA_10_GX1150, 4, 4, req(Fidelity::SteppedDominantRound));
        assert!(hit, "stepped entry survives the round trip");
        assert_eq!(
            *stepped,
            Evaluation::compute(&tiny, &ARRIA_10_GX1150, 4, 4, Fidelity::SteppedDominantRound)
        );
        let (net, hit) =
            warm.evaluate(&tiny, &ARRIA_10_GX1150, 4, 4, req(Fidelity::SteppedFullNetwork));
        assert!(hit, "full-network entry survives the round trip");
        assert_eq!(
            *net,
            Evaluation::compute(&tiny, &ARRIA_10_GX1150, 4, 4, Fidelity::SteppedFullNetwork)
        );
        let (_, hit) = warm.evaluate(&tiny, &ARRIA_10_GX1150, 4, 4, shaped_req);
        assert!(hit, "γ-shaped entry survives with its exact γ bits");
        let hotter = EvalRequest::shaped(Fidelity::Analytical, 0.75);
        let (_, hit) = warm.evaluate(&tiny, &ARRIA_10_GX1150, 4, 4, hotter);
        assert!(!hit, "a different γ never borrows it");
        let (_, hit) = warm.evaluate(&tiny, &ARRIA_10_GX1150, 4, 4, acme);
        assert!(hit, "tenant entry survives with its namespace intact");
        let stats = warm.cache().stats();
        assert_eq!(stats.hits, pairs.len() + 4);
        assert_eq!(stats.misses, 1, "only the γ=0.75 probe recomputed");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_load_save_is_byte_stable() {
        // hit-count determinism across processes needs the file itself to
        // be deterministic: save → load → save must be a fixed point
        // (LRU stamps included)
        let f = flow("alexnet");
        let pairs = OptionSpace::from_flow(&f).pairs();
        let ev = Evaluator::new(2);
        ev.evaluate_grid(&f, &ARRIA_10_GX1150, &pairs, req(Fidelity::Analytical));
        ev.evaluate_grid(&f, &CYCLONE_V_5CSEMA5, &pairs, req(Fidelity::Analytical));
        let (a, b) = (tmp_path("stable-a"), tmp_path("stable-b"));
        ev.cache().save(&a).unwrap();
        let reloaded = EvalCache::load(&a).unwrap();
        reloaded.save(&b).unwrap();
        assert_eq!(
            std::fs::read_to_string(&a).unwrap(),
            std::fs::read_to_string(&b).unwrap(),
            "cache file must be a serialization fixed point"
        );
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn v1_files_load_analytical_entries_and_drop_stepped_ones() {
        // build a current file, rewrite it into the v1 shape, and check
        // the v1 carry-over rules: analytical entries survive (stamp 0),
        // stepped entries are dropped, nothing errors (the v1 parser
        // ignores the post-v1 census_gamma/tenant fields)
        let f = flow("tiny");
        let ev = Evaluator::new(2);
        ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, req(Fidelity::Analytical));
        ev.evaluate(&f, &ARRIA_10_GX1150, 4, 8, req(Fidelity::SteppedDominantRound));
        let path = tmp_path("v1compat");
        ev.cache().save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v1 = text
            .replace("\"version\": 5", "\"version\": 1")
            .replace("\"fidelity\": \"analytical\"", "\"stepped\": false")
            .replace(
                "\"fidelity\": \"stepped-dominant-round\"",
                "\"stepped\": true",
            );
        assert_ne!(text, v1, "rewrite must land");
        std::fs::write(&path, &v1).unwrap();
        let loaded = EvalCache::load(&path).unwrap();
        assert_eq!(loaded.stats().entries, 1, "stepped v1 entry dropped");
        let warm = Evaluator::with_cache(2, Arc::new(loaded));
        let (eval, hit) = warm.evaluate(&f, &ARRIA_10_GX1150, 4, 4, req(Fidelity::Analytical));
        assert!(hit, "analytical v1 entry carried over");
        assert_eq!(
            *eval,
            Evaluation::compute(&f, &ARRIA_10_GX1150, 4, 4, Fidelity::Analytical)
        );
        let (_, hit) =
            warm.evaluate(&f, &ARRIA_10_GX1150, 4, 8, req(Fidelity::SteppedDominantRound));
        assert!(!hit, "dropped stepped entry recomputes");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_files_load_analytical_entries_and_drop_stepped_ones() {
        // v2 files predate both the census-γ key component and the
        // fractional-credit stepper: analytical entries carry over at
        // γ = 0, stepped entries are dropped (their censuses would
        // contradict a fresh computation)
        let f = flow("tiny");
        let ev = Evaluator::new(2);
        ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, req(Fidelity::Analytical));
        ev.evaluate(&f, &ARRIA_10_GX1150, 4, 8, req(Fidelity::SteppedFullNetwork));
        let path = tmp_path("v2compat");
        ev.cache().save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // a v2 entry is the v5 shape minus the census_gamma, tenant,
        // batch and batched fields
        let v2 = text
            .replace("\"version\": 5", "\"version\": 2")
            .replace("\"census_gamma\": 0,", "")
            .replace("\"tenant\": \"0000000000000000\",", "")
            .replace("\"batch\": 1,", "")
            .replace("\"batched\": null,", "");
        assert_ne!(text, v2, "rewrite must land");
        std::fs::write(&path, &v2).unwrap();
        let loaded = EvalCache::load(&path).unwrap();
        assert_eq!(loaded.stats().entries, 1, "stepped v2 entry dropped");
        let warm = Evaluator::with_cache(2, Arc::new(loaded));
        let (eval, hit) = warm.evaluate(&f, &ARRIA_10_GX1150, 4, 4, req(Fidelity::Analytical));
        assert!(hit, "analytical v2 entry carried over at γ = 0");
        let fresh = Evaluation::compute(&f, &ARRIA_10_GX1150, 4, 4, Fidelity::Analytical);
        assert_eq!(*eval, fresh);
        let (_, hit) =
            warm.evaluate(&f, &ARRIA_10_GX1150, 4, 8, req(Fidelity::SteppedFullNetwork));
        assert!(!hit, "dropped stepped entry recomputes");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v3_files_carry_every_entry_into_the_default_namespace() {
        // v3 files predate only the tenant key component; analytical,
        // stepped and γ-shaped entries all carry over into tenant 0
        let f = flow("tiny");
        let ev = Evaluator::new(2);
        ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, req(Fidelity::Analytical));
        ev.evaluate(&f, &ARRIA_10_GX1150, 4, 8, req(Fidelity::SteppedFullNetwork));
        let shaped_req = EvalRequest::shaped(Fidelity::Analytical, 0.25);
        ev.evaluate(&f, &ARRIA_10_GX1150, 8, 4, shaped_req);
        let path = tmp_path("v3compat");
        ev.cache().save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // a v3 entry is the v5 shape minus the tenant, batch and
        // batched fields
        let v3 = text
            .replace("\"version\": 5", "\"version\": 3")
            .replace("\"tenant\": \"0000000000000000\",", "")
            .replace("\"batch\": 1,", "")
            .replace("\"batched\": null,", "");
        assert_ne!(text, v3, "rewrite must land");
        std::fs::write(&path, &v3).unwrap();
        let loaded = EvalCache::load(&path).unwrap();
        assert_eq!(loaded.stats().entries, 3, "every v3 entry carries over");
        let warm = Evaluator::with_cache(2, Arc::new(loaded));
        let (_, hit) = warm.evaluate(&f, &ARRIA_10_GX1150, 4, 4, req(Fidelity::Analytical));
        assert!(hit, "analytical v3 entry carried over");
        let (_, hit) =
            warm.evaluate(&f, &ARRIA_10_GX1150, 4, 8, req(Fidelity::SteppedFullNetwork));
        assert!(hit, "stepped v3 entry carried over");
        let (_, hit) = warm.evaluate(&f, &ARRIA_10_GX1150, 8, 4, shaped_req);
        assert!(hit, "γ-shaped v3 entry carried over with its exact γ");
        let other = req(Fidelity::Analytical).tenant(TenantId::of("acme"));
        let (_, hit) = warm.evaluate(&f, &ARRIA_10_GX1150, 4, 4, other);
        assert!(!hit, "v3 entries land in the default namespace only");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v4_files_carry_every_entry_over_at_batch_1() {
        // v4 files predate only the batch key component; every entry
        // carries over at batch = 1 (a single-frame v4 evaluation is
        // bit-identical to a fresh batch-1 computation)
        let f = flow("tiny");
        let ev = Evaluator::new(2);
        ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, req(Fidelity::Analytical));
        ev.evaluate(&f, &ARRIA_10_GX1150, 4, 8, req(Fidelity::SteppedFullNetwork));
        let acme = req(Fidelity::Analytical).tenant(TenantId::of("acme"));
        ev.evaluate(&f, &ARRIA_10_GX1150, 8, 4, acme);
        let path = tmp_path("v4compat");
        ev.cache().save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // a v4 entry is the v5 shape minus the batch and batched fields
        let v4 = text
            .replace("\"version\": 5", "\"version\": 4")
            .replace("\"batch\": 1,", "")
            .replace("\"batched\": null,", "");
        assert_ne!(text, v4, "rewrite must land");
        std::fs::write(&path, &v4).unwrap();
        let loaded = EvalCache::load(&path).unwrap();
        assert_eq!(loaded.stats().entries, 3, "every v4 entry carries over");
        let warm = Evaluator::with_cache(2, Arc::new(loaded));
        let (eval, hit) = warm.evaluate(&f, &ARRIA_10_GX1150, 4, 4, req(Fidelity::Analytical));
        assert!(hit, "analytical v4 entry carried over at batch 1");
        assert_eq!(
            *eval,
            Evaluation::compute(&f, &ARRIA_10_GX1150, 4, 4, Fidelity::Analytical)
        );
        let (net, hit) =
            warm.evaluate(&f, &ARRIA_10_GX1150, 4, 8, req(Fidelity::SteppedFullNetwork));
        assert!(hit, "stepped v4 entry carried over");
        assert_eq!(net.stepped_network.as_ref().unwrap().batch, 1);
        let (_, hit) = warm.evaluate(&f, &ARRIA_10_GX1150, 8, 4, acme);
        assert!(hit, "tenant v4 entry carried over");
        // a batched request never borrows the single-frame carry-over
        let batched = req(Fidelity::Analytical).batched(16);
        let (_, hit) = warm.evaluate(&f, &ARRIA_10_GX1150, 4, 4, batched);
        assert!(!hit, "batch 16 is a distinct key");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn batched_requests_namespace_the_cache_and_roundtrip() {
        let f = flow("tiny");
        let ev = Evaluator::new(2);
        let base = req(Fidelity::SteppedFullNetwork);
        let b16 = base.batched(16);
        ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, base);
        let (eval, hit) = ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, b16);
        assert!(!hit, "a batched request must miss the batch-1 entry");
        assert_eq!(eval.batch, 16);
        let net = eval.stepped_network.as_ref().expect("batched census");
        assert_eq!(net.batch, 16);
        let b = eval.batched.as_ref().expect("closed-form batched payload");
        assert_eq!(b.batch, 16);
        assert!(b.frames_per_s() > 0.0);
        assert_eq!(
            *eval,
            Evaluation::compute_batched(
                &f,
                &ARRIA_10_GX1150,
                4,
                4,
                Fidelity::SteppedFullNetwork,
                16
            )
        );
        // batch 0 normalizes to 1 and shares the batch-1 entry
        let (eval0, hit) =
            ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, base.batched(0));
        assert!(hit, "batch 0 normalizes to the batch-1 key");
        assert_eq!(eval0.batch, 1);
        assert!(eval0.batched.is_none(), "no batched payload at batch 1");
        // round-trip: the batched entry survives disk with its key and
        // payloads intact
        let path = tmp_path("batched");
        assert_eq!(ev.cache().save(&path).unwrap(), 2);
        let warm = Evaluator::with_cache(2, Arc::new(EvalCache::load(&path).unwrap()));
        let (roundtrip, hit) = warm.evaluate(&f, &ARRIA_10_GX1150, 4, 4, b16);
        assert!(hit, "batched entry survives the round trip");
        assert_eq!(*roundtrip, *eval, "batched payload drifted through disk");
        let (_, hit) = warm.evaluate(&f, &ARRIA_10_GX1150, 4, 4, base.batched(8));
        assert!(!hit, "a different batch size never borrows it");
        // tampering with the batch key is caught by the payload checks
        let text = std::fs::read_to_string(&path).unwrap();
        let tampered = text.replacen("\"batch\": 16,", "\"batch\": 8,", 1);
        assert_ne!(text, tampered, "tamper must land");
        std::fs::write(&path, tampered).unwrap();
        assert!(EvalCache::load(&path).is_err(), "batch tamper rejected");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn tenants_namespace_the_cache_and_survive_disk() {
        let f = flow("tiny");
        let ev = Evaluator::new(2);
        let base = req(Fidelity::Analytical);
        let acme = base.tenant(TenantId::of("acme"));
        let zenith = base.tenant(TenantId::of("zenith"));
        assert_eq!(TenantId::of(""), TenantId::DEFAULT);
        assert_ne!(TenantId::of("acme"), TenantId::of("zenith"));
        ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, base);
        let (acme_eval, hit) = ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, acme);
        assert!(!hit, "another tenant's namespace must miss");
        let (_, hit) = ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, acme);
        assert!(hit, "same tenant hits its own namespace");
        // the payload itself is tenant-independent
        let (default_eval, _) = ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, base);
        assert_eq!(*acme_eval, *default_eval);
        // namespaces round-trip through disk intact
        let path = tmp_path("tenant");
        assert_eq!(ev.cache().save(&path).unwrap(), 2);
        let warm = Evaluator::with_cache(2, Arc::new(EvalCache::load(&path).unwrap()));
        let (_, hit) = warm.evaluate(&f, &ARRIA_10_GX1150, 4, 4, acme);
        assert!(hit, "tenant entry survives the round trip");
        let (_, hit) = warm.evaluate(&f, &ARRIA_10_GX1150, 4, 4, zenith);
        assert!(!hit, "a third tenant still misses");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_cache_roundtrip() {
        let path = tmp_path("empty");
        let n = EvalCache::new().save(&path).unwrap();
        assert_eq!(n, 0);
        assert_eq!(EvalCache::load(&path).unwrap().stats().entries, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_cache_files_fall_back_cold() {
        let path = tmp_path("corrupt");
        // truncated JSON
        std::fs::write(&path, "{\"format\": \"cnn2gate-evalc").unwrap();
        assert!(EvalCache::load(&path).is_err());
        let (cold, warn) = EvalCache::load_or_cold(&path);
        assert_eq!(cold.stats().entries, 0);
        assert!(warn.is_some(), "corruption must be reported");
        // wrong format tag
        std::fs::write(
            &path,
            r#"{"format": "something-else", "version": 2, "entries": []}"#,
        )
        .unwrap();
        assert!(EvalCache::load(&path).is_err());
        // wrong version
        std::fs::write(
            &path,
            format!(r#"{{"format": "{CACHE_FORMAT}", "version": 999, "entries": []}}"#),
        )
        .unwrap();
        assert!(EvalCache::load(&path).is_err());
        // missing entries array
        std::fs::write(
            &path,
            format!(r#"{{"format": "{CACHE_FORMAT}", "version": {CACHE_VERSION}}}"#),
        )
        .unwrap();
        assert!(EvalCache::load(&path).is_err());
        // missing file: cold start without a warning
        std::fs::remove_file(&path).ok();
        let (cold, warn) = EvalCache::load_or_cold(&path);
        assert_eq!(cold.stats().entries, 0);
        assert!(warn.is_none(), "a missing file is not corruption");
    }

    #[test]
    fn tampered_entries_are_rejected_not_served() {
        // flip one entry's ni in the serialized JSON: the key no longer
        // agrees with its payload, so the whole file is refused
        let f = flow("tiny");
        let ev = Evaluator::new(2);
        ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, req(Fidelity::Analytical));
        let path = tmp_path("tamper");
        ev.cache().save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let tampered = text.replacen("\"ni\": 4", "\"ni\": 8", 1);
        assert_ne!(text, tampered, "tamper must land");
        std::fs::write(&path, tampered).unwrap();
        assert!(EvalCache::load(&path).is_err());
        let (cold, warn) = EvalCache::load_or_cold(&path);
        assert_eq!(cold.stats().entries, 0, "tampered entries never served");
        assert!(warn.is_some());
        // a fidelity tag contradicting the payload shape is also refused
        let mangled = text.replacen(
            "\"fidelity\": \"analytical\"",
            "\"fidelity\": \"stepped-dominant-round\"",
            1,
        );
        assert_ne!(text, mangled);
        std::fs::write(&path, mangled).unwrap();
        assert!(EvalCache::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
