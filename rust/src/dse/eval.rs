//! Shared, multi-threaded candidate-evaluation core for the DSE layer.
//!
//! Every explorer (BF, RL, joint) ultimately scores `(N_i, N_l)` options
//! by calling the estimator and the latency simulator — the stand-ins
//! for the "first stage of the synthesis tool" the paper queries (§4.3).
//! The seed explorers did this strictly sequentially and re-derived the
//! same estimates across runs. This module centralizes that work:
//!
//! * [`EvalCache`] — a process-wide memo keyed on
//!   `(model fingerprint, device fingerprint, N_i, N_l)` that
//!   deduplicates the estimator + simulator calls the RL and joint
//!   agents revisit constantly (and that repeat across fleet fits);
//! * [`ThreadPool`] — a plain `std::thread` + channel worker pool (the
//!   `coordinator::server` idiom; tokio is not in the offline crate
//!   set) that [`Evaluator::evaluate_grid`] fans candidate scoring out
//!   across cores while preserving the sequential result order, so
//!   parallel exploration is bit-identical to the seed path;
//! * [`parallel_map`] — a scoped fork/join helper used by the fleet-fit
//!   flow to run whole per-device explorations concurrently (scoped
//!   threads, not the pool, so explorers running inside it can still
//!   use the pool without self-deadlock);
//! * [`Fidelity`] — analytical (closed-form, µs-scale) or stepped
//!   (cycle-accurate dominant-round simulation, ms-scale) candidate
//!   latency. Explorers default to analytical; the stepped mode is what
//!   the `table2_dse` bench uses to demonstrate the parallel speedup on
//!   an honestly heavy per-candidate workload.
//!
//! Deadlock rule: [`Evaluator::evaluate_grid`] must not be called from
//! inside one of the pool's own workers (a worker waiting on sub-jobs
//! would starve the queue). Nothing in this crate does; fleet fan-out
//! deliberately uses [`parallel_map`]'s scoped threads instead.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::estimator::{estimate, Device, ResourceEstimate, Thresholds};
use crate::ir::ComputationFlow;
use crate::sim::{dominant_round_work, simulate_with_estimate, step_round, SimReport, StepReport};

/// How much simulation each candidate evaluation buys.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fidelity {
    /// Resource estimate + closed-form whole-network latency (default).
    Analytical,
    /// Additionally run the cycle-stepped simulator on the flow's
    /// dominant round — the ground-truth check, ~1000x more expensive.
    SteppedDominantRound,
}

/// Everything one estimator/simulator query produces for a candidate.
#[derive(Debug, Clone)]
pub struct Evaluation {
    pub ni: usize,
    pub nl: usize,
    pub estimate: ResourceEstimate,
    /// Closed-form latency at this option (computed for every candidate,
    /// feasible or not — fleet reports rank by it).
    pub latency: SimReport,
    /// Cycle-stepped dominant-round census (stepped fidelity only).
    pub stepped: Option<StepReport>,
}

impl Evaluation {
    /// Compute from scratch — the pure function the cache memoizes.
    pub fn compute(
        flow: &ComputationFlow,
        device: &Device,
        ni: usize,
        nl: usize,
        fidelity: Fidelity,
    ) -> Evaluation {
        let estimate = estimate(flow, device, ni, nl);
        // reuse the estimate for the latency model (one estimator call
        // per candidate, exactly like the sequential seed path)
        let latency = simulate_with_estimate(flow, device, &estimate);
        let stepped = match fidelity {
            Fidelity::Analytical => None,
            Fidelity::SteppedDominantRound => {
                dominant_round_work(flow, device, estimate.fmax_mhz, ni, nl)
                    .map(|work| step_round(&work))
            }
        };
        Evaluation {
            ni,
            nl,
            estimate,
            latency,
            stepped,
        }
    }

    pub fn f_avg(&self) -> f64 {
        self.estimate.f_avg()
    }

    pub fn feasible(&self, thresholds: &Thresholds) -> bool {
        self.estimate.fits(thresholds)
    }
}

/// Cache key: structural fingerprints, not pointers, so equal models
/// built twice (or the same zoo model across tests) share entries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct EvalKey {
    model: u64,
    device: u64,
    ni: usize,
    nl: usize,
    stepped: bool,
}

impl EvalKey {
    fn new(
        flow: &ComputationFlow,
        device: &Device,
        ni: usize,
        nl: usize,
        fidelity: Fidelity,
    ) -> EvalKey {
        EvalKey {
            model: flow.fingerprint(),
            device: device.fingerprint(),
            ni,
            nl,
            stepped: matches!(fidelity, Fidelity::SteppedDominantRound),
        }
    }
}

/// Point-in-time cache counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
    pub entries: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Memoized estimator/simulator results, shared across explorers and
/// threads. Values are `Arc`ed so a hit is a pointer clone.
#[derive(Default)]
pub struct EvalCache {
    map: Mutex<HashMap<EvalKey, Arc<Evaluation>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl EvalCache {
    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    /// Look up or compute one candidate. Returns the evaluation and
    /// whether it was served from cache. The (potentially heavy)
    /// compute runs outside the lock so parallel misses don't serialize.
    pub fn get_or_compute(
        &self,
        flow: &ComputationFlow,
        device: &Device,
        ni: usize,
        nl: usize,
        fidelity: Fidelity,
    ) -> (Arc<Evaluation>, bool) {
        let key = EvalKey::new(flow, device, ni, nl, fidelity);
        self.get_or_compute_keyed(key, flow, device, fidelity)
    }

    /// Same, with the (loop-invariant) fingerprints already folded into
    /// `key` — `evaluate_grid` hashes the model/device once per grid,
    /// not once per candidate.
    fn get_or_compute_keyed(
        &self,
        key: EvalKey,
        flow: &ComputationFlow,
        device: &Device,
        fidelity: Fidelity,
    ) -> (Arc<Evaluation>, bool) {
        if let Some(found) = self.map.lock().expect("eval cache poisoned").get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return (Arc::clone(found), true);
        }
        let eval = Arc::new(Evaluation::compute(flow, device, key.ni, key.nl, fidelity));
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.lock().expect("eval cache poisoned");
        let entry = map.entry(key).or_insert_with(|| Arc::clone(&eval));
        (Arc::clone(entry), false)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.map.lock().expect("eval cache poisoned").len(),
        }
    }

    /// Drop all entries and zero the counters (bench isolation).
    pub fn clear(&self) {
        self.map.lock().expect("eval cache poisoned").clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Plain worker pool over `std::thread` + mpsc channels (the
/// `coordinator::server` threading idiom). Workers pull boxed jobs off
/// a shared queue; dropping the pool closes the queue and joins them.
/// The submit side is mutex-wrapped so the pool is `Sync` (the global
/// evaluator lives in a static) on every supported toolchain.
pub struct ThreadPool {
    tx: Option<Mutex<mpsc::Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    // Holding the lock across recv is the standard
                    // hand-off: the holder parks until a job arrives,
                    // takes it, releases, and the next worker parks.
                    let job = rx.lock().expect("pool queue poisoned").recv();
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // queue closed: pool dropped
                    }
                })
            })
            .collect();
        ThreadPool {
            tx: Some(Mutex::new(tx)),
            workers,
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Queue one job. Panics if the pool is shut down (it never is while
    /// borrowed: shutdown happens in Drop).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool live")
            .lock()
            .expect("pool submit side poisoned")
            .send(Box::new(job))
            .expect("pool workers alive");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the queue; workers drain and exit
        for worker in self.workers.drain(..) {
            let _ = worker.join();
        }
    }
}

/// The evaluation core an explorer talks to: a thread pool plus a
/// (shareable) memo cache.
pub struct Evaluator {
    pool: ThreadPool,
    cache: Arc<EvalCache>,
}

impl Evaluator {
    /// Fresh cache, `threads` workers.
    pub fn new(threads: usize) -> Evaluator {
        Evaluator::with_cache(threads, Arc::new(EvalCache::new()))
    }

    /// Share an existing cache (e.g. the global one) with a private pool.
    pub fn with_cache(threads: usize, cache: Arc<EvalCache>) -> Evaluator {
        Evaluator {
            pool: ThreadPool::new(threads),
            cache,
        }
    }

    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    pub fn cache(&self) -> &EvalCache {
        &self.cache
    }

    /// Evaluate one candidate inline (cache-aware, no pool dispatch) —
    /// what the inherently sequential RL/joint agents call per step.
    pub fn evaluate(
        &self,
        flow: &ComputationFlow,
        device: &Device,
        ni: usize,
        nl: usize,
        fidelity: Fidelity,
    ) -> (Arc<Evaluation>, bool) {
        self.cache.get_or_compute(flow, device, ni, nl, fidelity)
    }

    /// Evaluate a whole candidate grid, fanning the misses out across
    /// the pool. Results come back in `pairs` order, so a sequential
    /// reduction over them (e.g. Algorithm 1's running max) is
    /// bit-identical to the sequential seed path. Must not be called
    /// from inside a pool worker (see module docs).
    pub fn evaluate_grid(
        &self,
        flow: &ComputationFlow,
        device: &Device,
        pairs: &[(usize, usize)],
        fidelity: Fidelity,
    ) -> Vec<(Arc<Evaluation>, bool)> {
        // fingerprints are loop-invariant: hash once per grid
        let (model_fp, device_fp) = (flow.fingerprint(), device.fingerprint());
        let stepped = matches!(fidelity, Fidelity::SteppedDominantRound);
        let key_of = |ni: usize, nl: usize| EvalKey {
            model: model_fp,
            device: device_fp,
            ni,
            nl,
            stepped,
        };
        if pairs.len() < 2 || self.pool.size() < 2 {
            return pairs
                .iter()
                .map(|&(ni, nl)| {
                    self.cache
                        .get_or_compute_keyed(key_of(ni, nl), flow, device, fidelity)
                })
                .collect();
        }
        let flow = Arc::new(flow.clone());
        let device = Arc::new(device.clone());
        let (tx, rx) = mpsc::channel();
        for (idx, &(ni, nl)) in pairs.iter().enumerate() {
            let key = key_of(ni, nl);
            let flow = Arc::clone(&flow);
            let device = Arc::clone(&device);
            let cache = Arc::clone(&self.cache);
            let tx = tx.clone();
            self.pool.execute(move || {
                let out = cache.get_or_compute_keyed(key, &flow, &device, fidelity);
                let _ = tx.send((idx, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<(Arc<Evaluation>, bool)>> = vec![None; pairs.len()];
        for _ in 0..pairs.len() {
            let (idx, out) = rx.recv().expect("eval pool worker died");
            slots[idx] = Some(out);
        }
        slots
            .into_iter()
            .map(|s| s.expect("every candidate evaluated"))
            .collect()
    }
}

/// Worker count for the process-wide evaluator: one per core, clamped
/// to [2, 8] (the option grids are small; more threads only add queue
/// contention).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 8)
}

static GLOBAL: OnceLock<Evaluator> = OnceLock::new();

/// The process-wide evaluator every explorer uses by default. Its cache
/// persists for the process lifetime, so repeated explorations of the
/// same (model, device) — RL episodes, fleet fits, report regeneration —
/// pay for each unique candidate once.
pub fn global() -> &'static Evaluator {
    GLOBAL.get_or_init(|| Evaluator::new(default_threads()))
}

/// Fork/join map over scoped threads with a shared work queue: applies
/// `f` to every item on up to `threads` workers and returns results in
/// input order. Used for coarse-grained fan-out (one job per device in
/// the fleet fit) where jobs themselves may use the global pool.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    if items.is_empty() {
        return Vec::new();
    }
    let workers = threads.clamp(1, items.len());
    if workers == 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let next_ref = &next;
    let f_ref = &f;
    std::thread::scope(|s| {
        for _ in 0..workers {
            let tx = tx.clone();
            s.spawn(move || loop {
                let i = next_ref.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let _ = tx.send((i, f_ref(&items[i])));
            });
        }
    });
    drop(tx);
    let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    for (i, r) in rx {
        slots[i] = Some(r);
    }
    slots
        .into_iter()
        .map(|s| s.expect("scoped worker produced result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::OptionSpace;
    use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4, CYCLONE_V_5CSEMA5};
    use crate::onnx::zoo;

    fn flow(name: &str) -> ComputationFlow {
        ComputationFlow::extract(&zoo::build(name, false).unwrap()).unwrap()
    }

    #[test]
    fn pool_runs_every_job() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let counter = Arc::clone(&counter);
            pool.execute(move || {
                counter.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool); // joins workers after the queue drains
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..57).collect();
        let out = parallel_map(&items, 4, |&i| i * i);
        assert_eq!(out, items.iter().map(|&i| i * i).collect::<Vec<_>>());
        // degenerate widths
        assert_eq!(parallel_map(&items, 1, |&i| i + 1).len(), 57);
        assert!(parallel_map::<usize, usize, _>(&[], 4, |&i| i).is_empty());
    }

    #[test]
    fn parallel_grid_is_bit_identical_to_sequential() {
        // The satellite contract: fanning candidate scoring across the
        // pool must not change a single bit of any estimate, on either
        // paper fixture.
        for model in ["alexnet", "vgg16"] {
            let f = flow(model);
            let pairs = OptionSpace::from_flow(&f).pairs();
            for dev in [&ARRIA_10_GX1150, &CYCLONE_V_5CSEMA5, &CYCLONE_V_5CSEMA4] {
                let ev = Evaluator::new(4);
                let grid = ev.evaluate_grid(&f, dev, &pairs, Fidelity::Analytical);
                assert_eq!(grid.len(), pairs.len());
                for ((eval, hit), &(ni, nl)) in grid.iter().zip(&pairs) {
                    assert!(!hit, "fresh cache cannot hit");
                    let seq = estimate(&f, dev, ni, nl);
                    assert_eq!(eval.estimate, seq, "{model} {} ({ni},{nl})", dev.name);
                    assert_eq!(eval.latency.total_cycles, simulate(&f, dev, ni, nl).total_cycles);
                }
            }
        }
    }

    #[test]
    fn cache_hit_counts_are_deterministic() {
        let f = flow("alexnet");
        let pairs = OptionSpace::from_flow(&f).pairs();
        let run = || {
            let ev = Evaluator::new(4);
            ev.evaluate_grid(&f, &ARRIA_10_GX1150, &pairs, Fidelity::Analytical);
            let first = ev.cache().stats();
            ev.evaluate_grid(&f, &ARRIA_10_GX1150, &pairs, Fidelity::Analytical);
            (first, ev.cache().stats())
        };
        let (first_a, second_a) = run();
        let (first_b, second_b) = run();
        assert_eq!(first_a, first_b, "cold-run stats must reproduce");
        assert_eq!(second_a, second_b, "warm-run stats must reproduce");
        assert_eq!(first_a.misses, pairs.len());
        assert_eq!(first_a.hits, 0);
        assert_eq!(second_a.hits, pairs.len());
        assert_eq!(second_a.misses, pairs.len());
        assert_eq!(second_a.entries, pairs.len());
        assert!((second_a.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cache_discriminates_models_and_devices() {
        let a = flow("alexnet");
        let v = flow("vgg16");
        assert_ne!(a.fingerprint(), v.fingerprint());
        assert_ne!(
            ARRIA_10_GX1150.fingerprint(),
            CYCLONE_V_5CSEMA5.fingerprint()
        );
        let ev = Evaluator::new(2);
        ev.evaluate(&a, &ARRIA_10_GX1150, 8, 8, Fidelity::Analytical);
        let (_, hit) = ev.evaluate(&v, &ARRIA_10_GX1150, 8, 8, Fidelity::Analytical);
        assert!(!hit, "different model must miss");
        let (_, hit) = ev.evaluate(&a, &CYCLONE_V_5CSEMA5, 8, 8, Fidelity::Analytical);
        assert!(!hit, "different device must miss");
        let (_, hit) = ev.evaluate(&a, &ARRIA_10_GX1150, 8, 8, Fidelity::Analytical);
        assert!(hit, "same key must hit");
    }

    #[test]
    fn stepped_fidelity_runs_the_dominant_round() {
        let f = flow("tiny");
        let ev = Evaluator::new(2);
        let (eval, _) = ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, Fidelity::SteppedDominantRound);
        let stepped = eval.stepped.as_ref().expect("stepped census present");
        assert!(stepped.cycles > 0);
        // analytical fidelity for the same option is a distinct entry
        let (eval2, hit) = ev.evaluate(&f, &ARRIA_10_GX1150, 4, 4, Fidelity::Analytical);
        assert!(!hit);
        assert!(eval2.stepped.is_none());
    }

    #[test]
    fn shared_cache_spans_evaluators() {
        let cache = Arc::new(EvalCache::new());
        let f = flow("alexnet");
        let a = Evaluator::with_cache(2, Arc::clone(&cache));
        a.evaluate(&f, &ARRIA_10_GX1150, 16, 32, Fidelity::Analytical);
        let b = Evaluator::with_cache(2, Arc::clone(&cache));
        let (_, hit) = b.evaluate(&f, &ARRIA_10_GX1150, 16, 32, Fidelity::Analytical);
        assert!(hit, "cache shared across evaluator instances");
        cache.clear();
        assert_eq!(cache.stats().entries, 0);
        assert_eq!(cache.stats().hits, 0);
    }
}
