//! Per-layer (N_i, N_l) specialization — the census-to-hardware payoff.
//!
//! The paper's flow (and PR 1-4 of this repo) picks ONE uniform
//! `(N_i, N_l)` fold for the whole network: the option grid is
//! gcd-constrained across layers, and every fused round executes on the
//! same generic kernel configuration. The FPGA-toolflow survey (Venieris
//! et al.) identifies exactly this as what separates uniform-fold
//! single-engine flows from latency-optimal per-stage ones
//! (fpgaConvNet-style): each stage wants its own fold and its own
//! memory schedule.
//!
//! [`specialize`] converts the stepped per-layer census of the uniform
//! winner ([`NetworkStepReport`], `Fidelity::SteppedFullNetwork`) into
//! such a per-stage tailoring. Starting from the uniform winner it
//! walks the rounds bottleneck-first (descending stepped cycles) and
//! greedily re-folds each round to the per-layer option + weight
//! schedule that minimizes that round's stepped cycles, subject to:
//!
//! * the per-LAYER divisor constraints (N_i divides the round's own
//!   reduction dim, N_l its own feature count — the gcd across layers is
//!   gone, which is the point), within the same hardware caps
//!   ([`MAX_NI`], [`MAX_NL`]) as the uniform grid; the
//!   uniform option itself is always admissible, so the pass can never
//!   regress a round;
//! * the estimator: whenever a candidate would grow the resource
//!   envelope (the componentwise max option any round uses), the
//!   envelope estimate must still fit the thresholds AND hold the
//!   uniform winner's kernel clock — the pass never trades fmax for
//!   cycles, so the before/after cycle counts always share one clock
//!   and the gain is a real latency gain;
//! * the weight budget: [`WeightSchedule::SliceResident`] — the
//!   per-round memory schedule the specialized kernel generation
//!   unlocks — is only offered when the round's weight slice fits the
//!   family's double-buffered weight-buffer budget
//!   ([`crate::sim::slice_resident_allowed`]).
//!
//! The pass is a pure deterministic function of its inputs (grid order,
//! strict tie-breaks), so repeated runs — cold or cache-warm — produce
//! identical [`SpecializationReport`]s. On AlexNet / Arria 10 the
//! headline effect is the DDR-starved conv rounds flipping to the
//! slice-resident schedule and becoming compute-bound: total
//! stepped-full cycles drop by far more than the 5% the perf gate
//! demands (see the tests and `benches/hotpath_micro.rs`).

use std::collections::HashMap;

use crate::estimator::{estimate, Device, ResourceEstimate, Thresholds};
use crate::ir::ComputationFlow;
use crate::sim::{
    scheduled_round_work_batched, simulate_layer, slice_resident_allowed, step_round,
    NetworkStepReport, SimReport, WeightSchedule,
};

use super::options::{MAX_NI, MAX_NL, MIN_OPT};

/// One round's specialization outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSpecialization {
    /// Index into `flow.layers`.
    pub index: usize,
    /// Round label (matches the latency/census tables).
    pub label: String,
    /// The per-layer option the round runs at.
    pub ni: usize,
    pub nl: usize,
    /// The round's weight schedule.
    pub schedule: WeightSchedule,
    /// Stepped cycles under the uniform winner (the census's numbers).
    pub uniform_cycles: u64,
    /// Stepped cycles under the specialization.
    pub cycles: u64,
}

impl LayerSpecialization {
    /// Whether the pass changed anything about this round.
    pub fn specialized(&self) -> bool {
        self.schedule != WeightSchedule::Streamed || self.cycles != self.uniform_cycles
    }
}

/// What [`specialize`] produced: per-round options/schedules plus the
/// resource envelope they imply.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecializationReport {
    /// The uniform winner the pass started from.
    pub uniform: (usize, usize),
    /// Batch size the census — and therefore every cycle count in this
    /// report — was stepped at (1 for the classic single-frame pass).
    pub batch: usize,
    /// Componentwise max option across the specialized rounds — what the
    /// lane array / fetch vector must be provisioned for.
    pub envelope: (usize, usize),
    /// Kernel clock the cycle counts (both sides) are measured at —
    /// always the uniform winner's fmax: envelope growth is only
    /// admitted while the clock holds, so before/after cycles are
    /// directly comparable.
    pub fmax_mhz: f64,
    /// Estimate at the envelope option — diff against the uniform
    /// winner's estimate for the resource delta.
    pub envelope_estimate: ResourceEstimate,
    /// One row per fused round, in flow order.
    pub layers: Vec<LayerSpecialization>,
}

impl SpecializationReport {
    /// Total stepped cycles of the uniform baseline.
    pub fn uniform_total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.uniform_cycles).sum()
    }

    /// Total stepped cycles after specialization.
    pub fn specialized_total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }

    /// Fraction of the uniform cycles the specialization removed.
    pub fn gain_fraction(&self) -> f64 {
        let before = self.uniform_total_cycles();
        if before == 0 {
            return 0.0;
        }
        1.0 - self.specialized_total_cycles() as f64 / before as f64
    }

    /// Specialized total latency (one batch's makespan) at the
    /// report's kernel clock.
    pub fn specialized_millis(&self) -> f64 {
        self.specialized_total_cycles() as f64 / (self.fmax_mhz * 1e6) * 1e3
    }

    /// Specialized per-frame latency: the batch makespan amortized over
    /// the frames it carries.
    pub fn specialized_millis_per_frame(&self) -> f64 {
        self.specialized_millis() / self.batch.max(1) as f64
    }

    /// Specialized steady-state throughput in frames per second — the
    /// serving-facing payoff figure: batch frames delivered per batch
    /// makespan at the report's kernel clock.
    pub fn specialized_frames_per_s(&self) -> f64 {
        let ms = self.specialized_millis();
        if ms <= 0.0 {
            return 0.0;
        }
        1e3 * self.batch.max(1) as f64 / ms
    }

    /// How many rounds the pass actually changed.
    pub fn specialized_rounds(&self) -> usize {
        self.layers.iter().filter(|l| l.specialized()).count()
    }

    /// Per-layer latency breakdown of the specialized network under the
    /// *analytical* simulator: each round re-simulated at its own
    /// specialized option, priced at the envelope estimate (whose clock
    /// is the uniform winner's, by construction). The report's
    /// `(ni, nl)` is the envelope — what the lane array must be
    /// provisioned for — so
    /// [`fig6_specialized`](crate::report::fig6_specialized) renders
    /// the specialized network the same way Fig. 6 renders a uniform
    /// design. The cycle counts here are the analytical model's, not
    /// the stepped census's ([`LayerSpecialization::cycles`]); the two
    /// columns answer different questions (closed-form breakdown vs
    /// cycle-stepped ground truth) and the tables label them as such.
    pub fn analytical_breakdown(&self, flow: &ComputationFlow, device: &Device) -> SimReport {
        let layers: Vec<_> = self
            .layers
            .iter()
            .zip(&flow.layers)
            .map(|(l, layer)| simulate_layer(layer, device, &self.envelope_estimate, l.ni, l.nl))
            .collect();
        let total_cycles = layers.iter().map(|l| l.cycles).sum();
        let total_millis = layers.iter().map(|l| l.millis).sum();
        SimReport {
            model: flow.model_name.clone(),
            device: device.name.to_string(),
            ni: self.envelope.0,
            nl: self.envelope.1,
            fmax_mhz: self.fmax_mhz,
            layers,
            total_cycles,
            total_millis,
            gops: flow.gops(),
        }
    }
}

/// Power-of-two values in `[MIN_OPT, cap]`.
fn pow2_options(cap: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut v = MIN_OPT;
    while v <= cap {
        out.push(v);
        v *= 2;
    }
    out
}

/// Candidate rank: strictly fewer cycles wins; ties prefer the uniform
/// option, then the streamed schedule, then the smaller fold — so the
/// pass only reports a specialization when it actually buys cycles.
type CandidateKey = (u64, u8, u8, usize, usize, usize);

fn candidate_key(
    cycles: u64,
    uniform: (usize, usize),
    ni: usize,
    nl: usize,
    schedule: WeightSchedule,
) -> CandidateKey {
    (
        cycles,
        u8::from((ni, nl) != uniform),
        u8::from(schedule != WeightSchedule::Streamed),
        ni * nl,
        nl,
        ni,
    )
}

/// Greedy per-layer specialization of `flow`'s rounds, starting from
/// the `uniform` winner whose stepped-full census is `census`. See the
/// module docs for the exact constraints and guarantees.
pub fn specialize(
    flow: &ComputationFlow,
    device: &Device,
    thresholds: &Thresholds,
    uniform: &ResourceEstimate,
    census: &NetworkStepReport,
) -> SpecializationReport {
    let uniform_opt = (uniform.ni, uniform.nl);
    // the census carries the batch it was stepped at; every candidate
    // re-fold is stepped at the same batch so the before/after cycle
    // counts compare one schedule against another, never two batches
    let batch = census.batch.max(1);
    let rounds = flow.layers.len().min(census.layers.len());
    let first_conv = flow.layers.iter().position(|l| l.is_conv());

    // bottleneck-first: descending uniform cycles, index breaks ties
    let mut order: Vec<usize> = (0..rounds).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(census.layers[i].cycles), i));

    let mut chosen: Vec<(usize, usize, WeightSchedule, u64)> = (0..rounds)
        .map(|i| (uniform_opt.0, uniform_opt.1, WeightSchedule::Streamed, census.layers[i].cycles))
        .collect();
    let mut envelope = uniform_opt;
    // memo over candidate envelopes: each unique grown option is priced
    // by the estimator once, not once per (round, candidate)
    // analysis: allow(nondet, run-local memo; keyed lookups only, never iterated into output)
    let mut admissible: HashMap<(usize, usize), bool> = HashMap::new();

    for &li in &order {
        let layer = &flow.layers[li];
        let mut best: Option<(CandidateKey, (usize, usize, WeightSchedule, u64))> = None;
        for &ni in &pow2_options(MAX_NI) {
            for &nl in &pow2_options(MAX_NL) {
                // per-layer divisor constraints, mirroring the uniform
                // OptionSpace: only conv rounds are divisor-constrained
                // (FC rounds pad via div_ceil, as they always have), and
                // the uniform option is always admissible regardless —
                // it is what the flow already runs, padding included
                if (ni, nl) != uniform_opt {
                    let conv = layer.is_conv();
                    // depthwise rounds reduce over k² alone, which no
                    // power-of-two N_i divides — they pad via div_ceil
                    // like FC rounds, so the divisor filter exempts them
                    if conv
                        && Some(li) != first_conv
                        && !layer.is_depthwise()
                        && layer.reduction_dim() % ni != 0
                    {
                        continue;
                    }
                    if conv && layer.out_features() % nl != 0 {
                        continue;
                    }
                }
                // growing the envelope must keep the estimator feasible
                // at the SAME kernel clock: trading fmax for cycles
                // would make the before/after comparison mix clocks
                let grown = (envelope.0.max(ni), envelope.1.max(nl));
                let grown_ok = grown == envelope
                    || *admissible.entry(grown).or_insert_with(|| {
                        let est = estimate(flow, device, grown.0, grown.1);
                        est.fits(thresholds) && est.fmax_mhz == uniform.fmax_mhz
                    });
                if !grown_ok {
                    continue;
                }
                for schedule in [WeightSchedule::Streamed, WeightSchedule::SliceResident] {
                    if schedule == WeightSchedule::SliceResident
                        && !slice_resident_allowed(layer, device, ni, nl)
                    {
                        continue;
                    }
                    let work = scheduled_round_work_batched(
                        layer,
                        device,
                        uniform.fmax_mhz,
                        ni,
                        nl,
                        schedule,
                        batch,
                    );
                    let cycles = step_round(&work).cycles;
                    let key = candidate_key(cycles, uniform_opt, ni, nl, schedule);
                    let better = match &best {
                        Some((k, _)) => key < *k,
                        None => true,
                    };
                    if better {
                        best = Some((key, (ni, nl, schedule, cycles)));
                    }
                }
            }
        }
        // analysis: allow(panic, the uniform option bypasses every admission filter, so the candidate loop always sets `best`)
        let (_, pick) = best.expect("the uniform option is always a candidate");
        envelope = (envelope.0.max(pick.0), envelope.1.max(pick.1));
        chosen[li] = pick;
    }

    // the envelope estimate prices the specialized design; by
    // construction (the same-clock admission rule above) its fmax is
    // the uniform winner's, so every cycle count in the report shares
    // one clock
    let envelope_estimate = if envelope == uniform_opt {
        uniform.clone()
    } else {
        estimate(flow, device, envelope.0, envelope.1)
    };

    let layers = chosen
        .into_iter()
        .enumerate()
        .map(|(i, (ni, nl, schedule, cycles))| LayerSpecialization {
            index: i,
            label: flow.layers[i].label(),
            ni,
            nl,
            schedule,
            uniform_cycles: census.layers[i].cycles,
            cycles,
        })
        .collect();

    SpecializationReport {
        uniform: uniform_opt,
        batch,
        envelope,
        fmax_mhz: uniform.fmax_mhz,
        envelope_estimate,
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA5};
    use crate::onnx::zoo;
    use crate::sim::step_network;

    fn setup(
        model: &str,
        device: &'static Device,
    ) -> (ComputationFlow, ResourceEstimate, NetworkStepReport) {
        let flow = ComputationFlow::extract(&zoo::build(model, false).unwrap()).unwrap();
        let dse = crate::dse::brute::explore(&flow, device, Thresholds::default());
        let est = dse.best_estimate.expect("fits");
        let census = step_network(&flow, device, est.fmax_mhz, est.ni, est.nl);
        (flow, est, census)
    }

    #[test]
    fn alexnet_arria10_specialization_beats_uniform_by_5_percent() {
        // THE acceptance gate: specialized AlexNet on the Arria 10 must
        // shave ≥5% of the uniform winner's stepped-full total cycles
        // (the slice-resident refolds of the DDR-starved conv rounds
        // actually shave far more)
        let (flow, est, census) = setup("alexnet", &ARRIA_10_GX1150);
        assert_eq!((est.ni, est.nl), (16, 32));
        let rep = specialize(&flow, &ARRIA_10_GX1150, &Thresholds::default(), &est, &census);
        assert_eq!(rep.uniform, (16, 32));
        assert_eq!(rep.envelope, (16, 32), "no envelope growth on the A10");
        assert_eq!(rep.fmax_mhz, est.fmax_mhz);
        assert_eq!(rep.envelope_estimate, est, "zero resource delta");
        assert_eq!(rep.uniform_total_cycles(), census.total_cycles());
        assert!(
            rep.specialized_total_cycles() as f64 <= 0.95 * rep.uniform_total_cycles() as f64,
            "only {:.1}% gain",
            100.0 * rep.gain_fraction()
        );
        // every conv round flips to the slice-resident schedule and goes
        // compute-bound; the FC rounds (zero weight reuse at batch 1)
        // stay exactly at the uniform baseline
        for (l, layer) in rep.layers.iter().zip(&flow.layers) {
            if layer.is_conv() {
                assert_eq!(l.schedule, WeightSchedule::SliceResident, "{}", l.label);
                assert!(l.cycles < l.uniform_cycles, "{}", l.label);
            } else {
                assert_eq!(l.schedule, WeightSchedule::Streamed, "{}", l.label);
                assert_eq!((l.ni, l.nl), rep.uniform, "{}", l.label);
                assert_eq!(l.cycles, l.uniform_cycles, "{}", l.label);
                assert!(!l.specialized());
            }
        }
        assert_eq!(rep.specialized_rounds(), flow.conv_rounds());
        assert!(rep.specialized_millis() > 0.0);
    }

    #[test]
    fn specialization_is_deterministic_across_runs() {
        let (flow, est, census) = setup("alexnet", &ARRIA_10_GX1150);
        let a = specialize(&flow, &ARRIA_10_GX1150, &Thresholds::default(), &est, &census);
        let b = specialize(&flow, &ARRIA_10_GX1150, &Thresholds::default(), &est, &census);
        assert_eq!(a, b, "pure function of its inputs");
        assert_eq!(a.batch, 1, "a single-frame census specializes at batch 1");
    }

    #[test]
    fn batched_census_specializes_at_its_own_batch() {
        // a batch-16 census threads its batch into every candidate
        // re-fold: the report compares batched schedules against the
        // batched uniform baseline, and no round ever regresses
        use crate::sim::step_network_batched;
        let (flow, est, census1) = setup("alexnet", &ARRIA_10_GX1150);
        let census16 =
            step_network_batched(&flow, &ARRIA_10_GX1150, est.fmax_mhz, est.ni, est.nl, 16);
        assert_eq!(census16.batch, 16);
        let th = Thresholds::default();
        let rep1 = specialize(&flow, &ARRIA_10_GX1150, &th, &est, &census1);
        let rep16 = specialize(&flow, &ARRIA_10_GX1150, &th, &est, &census16);
        assert_eq!(rep16.batch, 16);
        assert_eq!(rep16.uniform_total_cycles(), census16.total_cycles());
        for l in &rep16.layers {
            assert!(l.cycles <= l.uniform_cycles, "{} regressed at B=16", l.label);
        }
        // cross-frame weight reuse already amortized the uniform
        // baseline's streamed weight traffic, so the batched makespan is
        // far below 16 single-frame passes and the slice-resident
        // refolds have less left to shave than at batch 1
        assert!(rep16.uniform_total_cycles() < 16 * rep1.uniform_total_cycles());
        assert!(rep16.gain_fraction() <= rep1.gain_fraction() + 1e-12);
        assert!(rep16.gain_fraction() >= 0.0);
        // per-frame latency beats the single-frame specialized pass —
        // the serving payoff the throughput DSE ranks on
        assert!(rep16.specialized_millis_per_frame() < rep1.specialized_millis());
        assert!(
            (rep16.specialized_millis_per_frame() - rep16.specialized_millis() / 16.0).abs()
                < 1e-12
        );
        // frames/s is the same figure inverted: batch frames per batch
        // makespan, and batching must beat single-frame throughput
        assert!(
            (rep16.specialized_frames_per_s() - 1e3 * 16.0 / rep16.specialized_millis()).abs()
                < 1e-9
        );
        assert!(rep16.specialized_frames_per_s() > rep1.specialized_frames_per_s());
        // determinism holds at B=16 too
        let again = specialize(&flow, &ARRIA_10_GX1150, &th, &est, &census16);
        assert_eq!(rep16, again);
    }

    #[test]
    fn specialization_never_regresses_any_round() {
        // the uniform option is always in each round's candidate set, so
        // no round can get slower — on any model/device pair that fits
        for (model, device) in [
            ("alexnet", &ARRIA_10_GX1150),
            ("alexnet", &CYCLONE_V_5CSEMA5),
            ("lenet5", &ARRIA_10_GX1150),
            ("tiny", &CYCLONE_V_5CSEMA5),
            ("vgg16", &ARRIA_10_GX1150),
        ] {
            let (flow, est, census) = setup(model, device);
            let rep = specialize(&flow, device, &Thresholds::default(), &est, &census);
            assert_eq!(rep.layers.len(), flow.layers.len());
            for l in &rep.layers {
                assert!(
                    l.cycles <= l.uniform_cycles,
                    "{model} on {}: {} regressed",
                    device.name,
                    l.label
                );
                assert!(l.ni <= MAX_NI && l.nl <= MAX_NL);
                assert!(l.ni >= MIN_OPT && l.nl >= MIN_OPT);
            }
            assert!(rep.gain_fraction() >= 0.0);
            // the envelope estimate always fits the thresholds, at the
            // uniform winner's clock (never traded for cycles)
            assert!(rep.envelope_estimate.fits(&Thresholds::default()));
            assert_eq!(rep.fmax_mhz, est.fmax_mhz, "{model} on {}", device.name);
            assert_eq!(rep.envelope_estimate.fmax_mhz, est.fmax_mhz);
            assert!(rep.envelope.0 >= est.ni && rep.envelope.1 >= est.nl);
        }
    }

    #[test]
    fn branched_models_specialize_without_regressions() {
        // residual Adds (no weights — never slice-resident) and
        // depthwise rounds (k² reduction — exempt from the N_i divisor
        // filter) flow through the pass without regressing any round
        for model in ["tinyres", "mobilenetv1"] {
            let (flow, est, census) = setup(model, &ARRIA_10_GX1150);
            // tinyres joins branches; mobilenet's separable stack is a
            // chain of depthwise rounds with no join
            assert_eq!(flow.is_linear_chain(), model == "mobilenetv1", "{model}");
            let rep = specialize(&flow, &ARRIA_10_GX1150, &Thresholds::default(), &est, &census);
            assert_eq!(rep.layers.len(), flow.layers.len());
            for (l, layer) in rep.layers.iter().zip(&flow.layers) {
                assert!(l.cycles <= l.uniform_cycles, "{model}: {} regressed", l.label);
                if !layer.has_weights() {
                    assert_eq!(
                        l.schedule,
                        WeightSchedule::Streamed,
                        "{model}: Add rounds carry no weights to pin"
                    );
                }
            }
            assert!(rep.envelope_estimate.fits(&Thresholds::default()));
            assert!(rep.specialized_frames_per_s() > 0.0);
        }
    }

    #[test]
    fn lenet5_uniform_fallback_option_stays_admissible() {
        // lenet5's uniform grid fell back to N_l = 4, which does NOT
        // divide its first conv round's 6 features — the pass must keep
        // the uniform option admissible (padding and all) rather than
        // strand the round without candidates
        let (flow, est, census) = setup("lenet5", &ARRIA_10_GX1150);
        assert_eq!(est.nl % 4, 0);
        let rep = specialize(&flow, &ARRIA_10_GX1150, &Thresholds::default(), &est, &census);
        assert_eq!(rep.layers.len(), flow.layers.len());
        for l in &rep.layers {
            assert!(l.cycles <= l.uniform_cycles);
        }
    }

    #[test]
    fn analytical_breakdown_renders_the_specialized_network() {
        let (flow, est, census) = setup("alexnet", &ARRIA_10_GX1150);
        let rep = specialize(&flow, &ARRIA_10_GX1150, &Thresholds::default(), &est, &census);
        let sim = rep.analytical_breakdown(&flow, &ARRIA_10_GX1150);
        assert_eq!(sim.layers.len(), rep.layers.len());
        assert_eq!((sim.ni, sim.nl), rep.envelope);
        assert_eq!(sim.fmax_mhz, rep.fmax_mhz);
        assert_eq!(sim.model, flow.model_name);
        assert!(sim.total_millis > 0.0);
        assert_eq!(sim.total_cycles, sim.layers.iter().map(|l| l.cycles).sum::<u64>());
        // rounds the pass left at the uniform option reproduce the
        // uniform analytical breakdown exactly (alexnet/A10 has zero
        // envelope growth, so the estimates — and clocks — coincide)
        let uniform = crate::sim::simulate(&flow, &ARRIA_10_GX1150, est.ni, est.nl);
        for ((s, u), l) in sim.layers.iter().zip(&uniform.layers).zip(&rep.layers) {
            if (l.ni, l.nl) == rep.uniform {
                assert_eq!(s.cycles, u.cycles, "{}", l.label);
            }
        }
    }

    /// CI perf-smoke gate (run with `--ignored` in release mode): the
    /// PR-5 acceptance criterion, as a cycle-count (deterministic,
    /// runner-noise-free) comparison.
    #[test]
    #[ignore = "perf gate; run in release via CI perf-smoke"]
    fn perf_smoke_specialized_alexnet_5pct_fewer_cycles() {
        let (flow, est, census) = setup("alexnet", &ARRIA_10_GX1150);
        let rep = specialize(&flow, &ARRIA_10_GX1150, &Thresholds::default(), &est, &census);
        let (before, after) = (rep.uniform_total_cycles(), rep.specialized_total_cycles());
        assert!(
            after as f64 <= 0.95 * before as f64,
            "specialized {after} vs uniform {before} cycles ({:.1}% gain < 5%)",
            100.0 * rep.gain_fraction()
        );
    }
}
