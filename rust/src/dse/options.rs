//! (N_i, N_l) option-space enumeration (paper §4.2-4.3).
//!
//! "Arbitrary choices for N_l and N_i are not always possible. N_i should
//! be a divisor of the features' width for all layers to avoid padding.
//! Likewise, N_l should be a divisor of the number of features for all
//! layers to avoid idle lanes."
//!
//! We enumerate power-of-two divisors of the gcd of the constraint dims
//! (the PipeCNN kernels are generated with power-of-two vector widths),
//! clamped to the practical range [4, 64]. The first conv round is
//! excluded from the N_i constraint — its input is host-padded, exactly
//! as PipeCNN zero-pads the 3-channel image layer.
//!
//! Two additional *hardware* caps bound the grid, and they are the reason
//! the paper's Arria 10 run stops at (16, 32) with only ~30% of the chip
//! used ("the design-space exploration algorithm ... has limited options
//! to attempt using the hardware platform to its full extent", §5):
//! `N_i` is bounded by the global-memory interface width (16 bytes per
//! stream per cycle on these boards), and `N_l` by the pipe fan-out the
//! OpenCL compiler will route (32).

use crate::ir::ComputationFlow;

pub const MIN_OPT: usize = 4;
pub const MAX_OPT: usize = 64;
/// Memory-interface cap on the fetch vector width.
pub const MAX_NI: usize = 16;
/// Pipe fan-out cap on the lane count.
pub const MAX_NL: usize = 32;

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn gcd_all(xs: &[usize]) -> usize {
    xs.iter().copied().fold(0, gcd)
}

/// Power-of-two divisors of `n` within `[MIN_OPT, cap]`; if `n` admits
/// none (tiny models), fall back to `{MIN_OPT}` so the space is never
/// empty.
fn pow2_divisors(n: usize, cap: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut d = MIN_OPT;
    while d <= cap {
        if n % d == 0 {
            out.push(d);
        }
        d *= 2;
    }
    if out.is_empty() {
        out.push(MIN_OPT);
    }
    out
}

/// The legal option grid for one model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OptionSpace {
    pub ni: Vec<usize>,
    pub nl: Vec<usize>,
}

impl OptionSpace {
    pub fn from_flow(flow: &ComputationFlow) -> OptionSpace {
        let ni_g = gcd_all(&flow.ni_constraint_dims());
        let nl_g = gcd_all(&flow.nl_constraint_dims());
        OptionSpace {
            ni: pow2_divisors(if ni_g == 0 { MAX_OPT } else { ni_g }, MAX_NI),
            nl: pow2_divisors(if nl_g == 0 { MAX_OPT } else { nl_g }, MAX_NL),
        }
    }

    pub fn len(&self) -> usize {
        self.ni.len() * self.nl.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, ni: usize, nl: usize) -> bool {
        self.ni.contains(&ni) && self.nl.contains(&nl)
    }

    /// All (ni, nl) pairs, row-major.
    pub fn pairs(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.len());
        for &ni in &self.ni {
            for &nl in &self.nl {
                out.push((ni, nl));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::zoo;

    fn space(name: &str) -> OptionSpace {
        let g = zoo::build(name, false).unwrap();
        OptionSpace::from_flow(&ComputationFlow::extract(&g).unwrap())
    }

    #[test]
    fn alexnet_grid_includes_paper_points() {
        let s = space("alexnet");
        assert_eq!(s.ni, vec![4, 8, 16]); // capped by MAX_NI
        assert_eq!(s.nl, vec![4, 8, 16, 32]); // capped by MAX_NL
        assert_eq!(s.len(), 12); // the grid the paper's DSE timings imply
        assert!(s.contains(16, 32)); // Arria 10 choice (grid max corner)
        assert!(s.contains(8, 8)); // Cyclone V choice
    }

    #[test]
    fn vgg_grid_admits_paper_choice() {
        let s = space("vgg16");
        assert!(s.contains(16, 32));
        // VGG reduction dims are multiples of 576 = 2^6*9, features of 64
        assert_eq!(s.ni, vec![4, 8, 16]);
        assert_eq!(s.nl, vec![4, 8, 16, 32]);
    }

    #[test]
    fn tiny_model_space_nonempty() {
        let s = space("tiny");
        assert!(!s.is_empty());
        assert!(s.ni.iter().all(|&v| (MIN_OPT..=MAX_OPT).contains(&v)));
    }

    #[test]
    fn pairs_cover_grid() {
        let s = space("alexnet");
        let pairs = s.pairs();
        assert_eq!(pairs.len(), 12);
        assert!(pairs.contains(&(16, 4)));
        assert!(pairs.contains(&(4, 32)));
    }

    #[test]
    fn gcd_helpers() {
        assert_eq!(gcd(1600, 1728), 64);
        assert_eq!(gcd_all(&[64, 192, 384, 256]), 64);
        assert_eq!(pow2_divisors(64, 64), vec![4, 8, 16, 32, 64]);
        assert_eq!(pow2_divisors(64, MAX_NI), vec![4, 8, 16]);
        assert_eq!(pow2_divisors(3, 64), vec![MIN_OPT]); // fallback
    }
}
