//! BF-DSE: brute-force design-space exploration (paper §4.3.1).
//!
//! "This method exhaustively searches for all possible pairs of N_l and
//! N_i and finds the feasible option that maximizes FPGA resource
//! utilization [= best throughput]. It is simple to execute and it
//! always finds the best solutions."
//!
//! [`explore`] scores the grid through the shared [`super::eval`] core:
//! candidates fan out across the worker pool and previously seen
//! (model, device, option) triples come straight from the memo cache.
//! The reduction over the (order-preserved) results is the sequential
//! Algorithm-1 pass, so the chosen design is bit-identical to
//! [`explore_seq`], the seed path kept as reference and bench baseline.

use std::time::Instant;

use crate::estimator::{estimate, query_seconds, Device, ResourceEstimate, Thresholds};
use crate::ir::ComputationFlow;

use super::eval::{self, EvalRequest, Evaluator, Fidelity};
use super::options::OptionSpace;
use super::reward::RewardShaper;

/// Outcome of a DSE run (shared by BF and RL).
#[derive(Debug, Clone)]
pub struct DseResult {
    /// H_best: the chosen (N_i, N_l), None when nothing fits.
    pub best: Option<(usize, usize)>,
    pub best_estimate: Option<ResourceEstimate>,
    pub f_max: f64,
    /// Number of estimator queries issued (unique compiler invocations
    /// this run would have cost at the Intel-compiler time scale —
    /// memo-cache hits still count, the cache only saves wall time).
    pub queries: usize,
    /// How many of those queries were served from the eval memo cache.
    pub cache_hits: usize,
    /// Actual wall time of the search.
    pub wall_seconds: f64,
    /// Modeled wall time had each query hit the real Intel compiler
    /// (paper Table 2 time scale).
    pub modeled_seconds: f64,
    /// (ni, nl, f_avg, feasible) visit trace for reports/ablation.
    pub trace: Vec<(usize, usize, f64, bool)>,
}

impl DseResult {
    pub fn modeled_minutes(&self) -> f64 {
        self.modeled_seconds / 60.0
    }
}

/// Exhaustive search over the option grid, scored through the
/// process-wide [`eval::global`] evaluator (parallel + memoized).
pub fn explore(flow: &ComputationFlow, device: &Device, thresholds: Thresholds) -> DseResult {
    explore_with(eval::global(), flow, device, thresholds)
}

/// Exhaustive search through a caller-provided evaluator (isolated
/// caches for tests/benches, custom worker counts for the CLI).
pub fn explore_with(
    evaluator: &Evaluator,
    flow: &ComputationFlow,
    device: &Device,
    thresholds: Thresholds,
) -> DseResult {
    explore_with_fidelity(
        evaluator,
        flow,
        device,
        thresholds,
        EvalRequest::at(Fidelity::Analytical),
    )
}

/// Exhaustive search under an explicit [`EvalRequest`]: stepped
/// fidelities run the cycle-accurate simulator on every candidate (the
/// skip-ahead engine keeps even `SteppedFullNetwork` grids
/// interactive). With `req.census_gamma == 0` the chosen design and
/// trace are fidelity-independent — feasibility and F_avg come from the
/// estimator — so any fidelity reproduces the seed path's choice and
/// the stepped censuses just ride along in the memo for reporting. With
/// γ > 0 under `SteppedFullNetwork`, Algorithm 1's improvement test
/// runs on the shaped score `β·F_avg − γ·bottleneck_stall_fraction`
/// (see [`RewardShaper::eval_censused`]), so the explorer can trade a
/// little silicon utilization for a less-stalled bottleneck round.
pub fn explore_with_fidelity(
    evaluator: &Evaluator,
    flow: &ComputationFlow,
    device: &Device,
    thresholds: Thresholds,
    req: EvalRequest,
) -> DseResult {
    // analysis: allow(nondet, wall-clock feeds only the volatile wall_seconds field, never ranking or rendered bytes)
    let t0 = Instant::now();
    let space = OptionSpace::from_flow(flow);
    let pairs = space.pairs();
    let grid = evaluator.evaluate_grid(flow, device, &pairs, req);

    let mut shaper = RewardShaper::with_census(thresholds, req.census_gamma);
    let mut trace = Vec::with_capacity(pairs.len());
    let mut cache_hits = 0usize;
    for (eval, hit) in &grid {
        if *hit {
            cache_hits += 1;
        }
        let est = &eval.estimate;
        let feasible = est.fits(&shaper.thresholds);
        shaper.eval_censused(est, eval.stepped_network.as_ref());
        trace.push((est.ni, est.nl, est.f_avg(), feasible));
    }
    let queries = pairs.len();
    DseResult {
        best: shaper.h_best,
        best_estimate: shaper.best_estimate,
        f_max: shaper.f_max,
        queries,
        cache_hits,
        wall_seconds: t0.elapsed().as_secs_f64(),
        modeled_seconds: queries as f64 * query_seconds(device),
        trace,
    }
}

/// The sequential seed path: one estimator call per candidate, in grid
/// order, no pool, no cache. Kept as the reference implementation the
/// parallel explorer is validated against and as the bench baseline.
pub fn explore_seq(flow: &ComputationFlow, device: &Device, thresholds: Thresholds) -> DseResult {
    // analysis: allow(nondet, wall-clock feeds only the volatile wall_seconds field, never ranking or rendered bytes)
    let t0 = Instant::now();
    let space = OptionSpace::from_flow(flow);
    let mut shaper = RewardShaper::new(thresholds);
    let mut trace = Vec::with_capacity(space.len());
    let mut queries = 0usize;
    for (ni, nl) in space.pairs() {
        let est = estimate(flow, device, ni, nl);
        queries += 1;
        let feasible = est.fits(&shaper.thresholds);
        shaper.eval(&est);
        trace.push((ni, nl, est.f_avg(), feasible));
    }
    DseResult {
        best: shaper.h_best,
        best_estimate: shaper.best_estimate,
        f_max: shaper.f_max,
        queries,
        cache_hits: 0,
        wall_seconds: t0.elapsed().as_secs_f64(),
        modeled_seconds: queries as f64 * query_seconds(device),
        trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4, CYCLONE_V_5CSEMA5};
    use crate::onnx::zoo;

    fn flow(name: &str) -> ComputationFlow {
        ComputationFlow::extract(&zoo::build(name, false).unwrap()).unwrap()
    }

    #[test]
    fn arria10_picks_paper_option() {
        let r = explore(&flow("alexnet"), &ARRIA_10_GX1150, Thresholds::default());
        assert_eq!(r.best, Some((16, 32)), "trace: {:?}", r.trace);
        assert_eq!(r.queries, 12);
    }

    #[test]
    fn cyclone_v_picks_paper_option() {
        let r = explore(&flow("alexnet"), &CYCLONE_V_5CSEMA5, Thresholds::default());
        assert_eq!(r.best, Some((8, 8)), "trace: {:?}", r.trace);
    }

    #[test]
    fn small_cyclone_reports_no_fit() {
        let r = explore(&flow("alexnet"), &CYCLONE_V_5CSEMA4, Thresholds::default());
        assert_eq!(r.best, None);
        assert_eq!(r.f_max, 0.0);
        assert!(r.trace.iter().all(|(_, _, _, feasible)| !feasible));
    }

    #[test]
    fn vgg_on_arria_matches_paper_option() {
        let r = explore(&flow("vgg16"), &ARRIA_10_GX1150, Thresholds::default());
        assert_eq!(r.best, Some((16, 32)), "trace: {:?}", r.trace);
    }

    #[test]
    fn modeled_time_in_paper_band() {
        // Table 2: BF-DSE 3.5 min (Cyclone V), 4 min (Arria 10)
        let cv = explore(&flow("alexnet"), &CYCLONE_V_5CSEMA5, Thresholds::default());
        assert!((cv.modeled_minutes() - 3.5).abs() < 0.4, "{}", cv.modeled_minutes());
        let a10 = explore(&flow("alexnet"), &ARRIA_10_GX1150, Thresholds::default());
        assert!((a10.modeled_minutes() - 4.0).abs() < 0.4, "{}", a10.modeled_minutes());
    }

    #[test]
    fn best_is_argmax_of_feasible_trace() {
        let r = explore(&flow("alexnet"), &ARRIA_10_GX1150, Thresholds::default());
        let best_in_trace = r
            .trace
            .iter()
            .filter(|(_, _, _, feas)| *feas)
            .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .map(|(ni, nl, _, _)| (*ni, *nl));
        assert_eq!(r.best, best_in_trace);
    }

    #[test]
    fn parallel_matches_sequential_seed_path_bit_for_bit() {
        // The satellite contract on the paper fixtures: same best, same
        // f_max bits, same trace, same query count — on every device,
        // for AlexNet and VGG-16.
        for model in ["alexnet", "vgg16"] {
            let f = flow(model);
            for dev in [&ARRIA_10_GX1150, &CYCLONE_V_5CSEMA5, &CYCLONE_V_5CSEMA4] {
                let ev = Evaluator::new(4);
                let par = explore_with(&ev, &f, dev, Thresholds::default());
                let seq = explore_seq(&f, dev, Thresholds::default());
                assert_eq!(par.best, seq.best, "{model} on {}", dev.name);
                assert_eq!(par.best_estimate, seq.best_estimate);
                assert_eq!(par.f_max.to_bits(), seq.f_max.to_bits());
                assert_eq!(par.trace, seq.trace);
                assert_eq!(par.queries, seq.queries);
                assert_eq!(par.modeled_seconds, seq.modeled_seconds);
            }
        }
    }

    #[test]
    fn exploration_served_from_disk_cache_matches_cold_run() {
        // cold run → persist the memo → reload in a fresh evaluator: the
        // warm exploration must be answered entirely from disk and pick
        // the identical design with an identical trace
        use super::eval::EvalCache;
        use std::sync::Arc;
        let f = flow("alexnet");
        let ev = Evaluator::new(2);
        let cold = explore_with(&ev, &f, &ARRIA_10_GX1150, Thresholds::default());
        let path = std::env::temp_dir()
            .join(format!("cnn2gate-brute-cache-{}.json", std::process::id()));
        ev.cache().save(&path).unwrap();
        let warm_ev = Evaluator::with_cache(2, Arc::new(EvalCache::load(&path).unwrap()));
        let warm = explore_with(&warm_ev, &f, &ARRIA_10_GX1150, Thresholds::default());
        assert_eq!(warm.cache_hits, warm.queries, "every candidate from disk");
        assert_eq!(warm.best, cold.best);
        assert_eq!(warm.best_estimate, cold.best_estimate);
        assert_eq!(warm.f_max.to_bits(), cold.f_max.to_bits());
        assert_eq!(warm.trace, cold.trace);
        assert_eq!(warm_ev.cache().stats().misses, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stepped_full_network_grid_picks_the_same_design() {
        // stepped fidelity buys censuses, never a different answer: the
        // choice and trace are bit-identical to the analytical grid, and
        // every candidate carries a full per-round census
        let f = flow("alexnet");
        let ev = Evaluator::new(4);
        let stepped = explore_with_fidelity(
            &ev,
            &f,
            &ARRIA_10_GX1150,
            Thresholds::default(),
            EvalRequest::at(Fidelity::SteppedFullNetwork),
        );
        let analytical =
            explore_with(&Evaluator::new(4), &f, &ARRIA_10_GX1150, Thresholds::default());
        assert_eq!(stepped.best, analytical.best);
        assert_eq!(stepped.best_estimate, analytical.best_estimate);
        assert_eq!(stepped.f_max.to_bits(), analytical.f_max.to_bits());
        assert_eq!(stepped.trace, analytical.trace);
        // the memo now holds a census for every candidate
        let pairs = crate::dse::OptionSpace::from_flow(&f).pairs();
        for (ni, nl) in pairs {
            let (eval, hit) = ev.evaluate(
                &f,
                &ARRIA_10_GX1150,
                ni,
                nl,
                EvalRequest::at(Fidelity::SteppedFullNetwork),
            );
            assert!(hit, "({ni},{nl}) memoized during the grid");
            let net = eval.stepped_network.as_ref().expect("census present");
            assert_eq!(net.layers.len(), f.layers.len());
        }
    }

    #[test]
    fn census_guided_reward_is_deterministic_and_argmax_of_shaped_score() {
        // γ > 0 under stepped-full fidelity: the explorer maximizes
        // β·F_avg − γ·bottleneck_stall_fraction, deterministically
        let f = flow("alexnet");
        let gamma = 0.5;
        let run = || {
            let ev = Evaluator::new(4);
            explore_with_fidelity(
                &ev,
                &f,
                &ARRIA_10_GX1150,
                Thresholds::default(),
                EvalRequest::shaped(Fidelity::SteppedFullNetwork, gamma),
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a.best, b.best);
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.f_max.to_bits(), b.f_max.to_bits());
        assert!(a.best.is_some(), "alexnet fits the Arria 10");
        // the chosen design is the grid argmax of the shaped score
        // (first-wins on ties, like the shaper's strict improvement)
        let ev = Evaluator::new(2);
        let mut best: Option<(f64, (usize, usize))> = None;
        for (ni, nl) in OptionSpace::from_flow(&f).pairs() {
            let (e, _) = ev.evaluate(
                &f,
                &ARRIA_10_GX1150,
                ni,
                nl,
                EvalRequest::shaped(Fidelity::SteppedFullNetwork, gamma),
            );
            if !e.estimate.fits(&Thresholds::default()) {
                continue;
            }
            let stall = e
                .stepped_network
                .as_ref()
                .expect("stepped-full census")
                .bottleneck_stall_fraction();
            let score = crate::dse::reward::BETA * e.estimate.f_avg() - gamma * stall;
            let better = match best {
                Some((s, _)) => score > s,
                None => true,
            };
            if better {
                best = Some((score, (ni, nl)));
            }
        }
        assert_eq!(a.best, best.map(|(_, o)| o));
        // the trace format is unchanged: (ni, nl, F_avg, feasible)
        assert_eq!(a.trace.len(), a.queries);
    }

    #[test]
    fn repeat_exploration_is_served_from_cache() {
        let f = flow("alexnet");
        let ev = Evaluator::new(4);
        let cold = explore_with(&ev, &f, &ARRIA_10_GX1150, Thresholds::default());
        assert_eq!(cold.cache_hits, 0);
        let warm = explore_with(&ev, &f, &ARRIA_10_GX1150, Thresholds::default());
        assert_eq!(warm.cache_hits, warm.queries, "every candidate memoized");
        assert_eq!(warm.best, cold.best);
        assert_eq!(warm.trace, cold.trace);
        // modeled (compiler-scale) cost is unchanged: the cache saves
        // wall time, not modeled compiler invocations
        assert_eq!(warm.modeled_seconds, cold.modeled_seconds);
    }
}
