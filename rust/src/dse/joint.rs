//! Joint RL agent: parallelism + quantization (paper §4.4's outlook).
//!
//! "The RL-DSE algorithm would be more valuable if it could be exploited
//! in conjunction to the reinforcement learning quantization algorithms
//! such as ReLeQ" — and §2 cites HAQ's hardware-aware action space. This
//! module implements that suggested extension: one tabular Q-learning
//! agent over the product space
//!
//! ```text
//! (N_i option) x (N_l option) x (weight fraction bits m_w)
//! ```
//!
//! with a composite reward that extends Algorithm 1:
//!
//! ```text
//! infeasible                -> -1
//! feasible, improves score  ->  β·F_avg − λ·E_q(m_w)
//! feasible, no improvement  ->  0
//! ```
//!
//! where `E_q(m_w)` is the measured mean quantization error of the
//! model's weights at m_w (from [`crate::quant`]), normalized to the
//! worst m in the sweep. λ trades silicon utilization against numeric
//! fidelity exactly the way HAQ's accuracy term does.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::estimator::{query_seconds, Device, Thresholds};
use crate::ir::{ComputationFlow, Graph};
use crate::quant::{self, LayerQuant, QuantSpec};
use crate::util::rng::Rng;

use super::eval::{self, EvalRequest, Evaluator, Fidelity};
use super::options::OptionSpace;

/// m_w sweep range (8-bit codes admit at most 7 fraction bits).
pub const M_MIN: i8 = 2;
pub const M_MAX: i8 = 7;

/// Joint agent configuration.
#[derive(Debug, Clone, Copy)]
pub struct JointConfig {
    pub gamma: f64,
    pub alpha: f64,
    pub epsilon: f64,
    pub episodes: usize,
    pub steps_per_episode: usize,
    /// Weight of the quantization-error term (HAQ's accuracy trade-off).
    pub lambda: f64,
    pub seed: u64,
}

impl Default for JointConfig {
    fn default() -> Self {
        JointConfig {
            gamma: 0.1,
            alpha: 0.5,
            epsilon: 0.35,
            episodes: 6,
            steps_per_episode: 10,
            lambda: 0.5,
            seed: 0x10177,
        }
    }
}

/// Result of a joint exploration.
#[derive(Debug, Clone)]
pub struct JointResult {
    /// (N_i, N_l, m_w).
    pub best: Option<(usize, usize, i8)>,
    pub best_score: f64,
    pub queries: usize,
    /// Hardware queries served by the process-wide eval memo.
    pub cache_hits: usize,
    pub wall_seconds: f64,
    pub modeled_seconds: f64,
    /// (ni, nl, m, score, feasible) visit trace.
    pub trace: Vec<(usize, usize, i8, f64, bool)>,
}

/// Precompute the normalized quantization-error curve E_q(m) for the
/// model's weights (0 = best m in sweep, 1 = worst).
pub fn quant_error_curve(graph: &Graph) -> Result<Vec<(i8, f64)>> {
    let mut raw = Vec::new();
    for m in M_MIN..=M_MAX {
        let spec = QuantSpec::uniform(LayerQuant {
            m_in: 4,
            m_w: m,
            m_out: 4,
        });
        let rep = quant::apply(graph, &spec)
            .map_err(|e| anyhow!("quantization sweep at m_w={m}: {e}"))?;
        let mean = rep.tensors.iter().map(|t| t.mean_abs_err).sum::<f64>()
            / rep.tensors.len() as f64;
        // saturation is worse than rounding: penalize clipped codes hard
        let sat = rep.worst_sat_ratio();
        raw.push((m, mean + 10.0 * sat));
    }
    let worst = raw.iter().map(|(_, e)| *e).fold(f64::MIN, f64::max);
    let best = raw.iter().map(|(_, e)| *e).fold(f64::MAX, f64::min);
    let span = (worst - best).max(1e-12);
    Ok(raw
        .into_iter()
        .map(|(m, e)| (m, (e - best) / span))
        .collect())
}

const N_ACTIONS: usize = 5; // inc nl | inc ni | inc both | inc m | dec m

/// Run the joint exploration through the process-wide evaluator.
pub fn explore(
    graph: &Graph,
    flow: &ComputationFlow,
    device: &Device,
    thresholds: Thresholds,
    cfg: JointConfig,
) -> Result<JointResult> {
    explore_with(eval::global(), graph, flow, device, thresholds, cfg)
}

/// Run the joint exploration through a caller-provided evaluator.
pub fn explore_with(
    evaluator: &Evaluator,
    graph: &Graph,
    flow: &ComputationFlow,
    device: &Device,
    thresholds: Thresholds,
    cfg: JointConfig,
) -> Result<JointResult> {
    explore_with_fidelity(
        evaluator,
        graph,
        flow,
        device,
        thresholds,
        cfg,
        EvalRequest::at(Fidelity::Analytical),
    )
}

/// Joint exploration under an explicit [`EvalRequest`] for the hardware
/// queries (the quantization sweep is fidelity-independent). With γ = 0,
/// stepped modes leave cycle-accurate censuses in the memo for every
/// visited option without changing the agent's trajectory; with γ > 0
/// under `SteppedFullNetwork` the composite score gains the census
/// term: `β·F_avg − λ·E_q(m_w) − γ·bottleneck_stall_fraction`.
pub fn explore_with_fidelity(
    evaluator: &Evaluator,
    graph: &Graph,
    flow: &ComputationFlow,
    device: &Device,
    thresholds: Thresholds,
    cfg: JointConfig,
    req: EvalRequest,
) -> Result<JointResult> {
    // analysis: allow(nondet, wall-clock feeds only the volatile wall_seconds field, never ranking or rendered bytes)
    let t0 = Instant::now();
    let space = OptionSpace::from_flow(flow);
    let errs = quant_error_curve(graph)?;
    let m_levels: Vec<i8> = errs.iter().map(|(m, _)| *m).collect();
    let err_of = |mi: usize| errs[mi].1;
    let (ni_n, nl_n, m_n) = (space.ni.len(), space.nl.len(), m_levels.len());

    let mut rng = Rng::new(cfg.seed);
    let mut q = vec![[0f64; N_ACTIONS]; ni_n * nl_n * m_n];
    // analysis: allow(nondet, run-local memo; keyed lookups only, never iterated into output)
    let mut visited: HashMap<(usize, usize), (f64, f64)> = HashMap::new(); // hw queries
    let mut queries = 0usize;
    let mut cache_hits = 0usize;
    let mut best: Option<(usize, usize, i8)> = None;
    let mut best_score = f64::MIN;
    let mut trace = Vec::new();

    let mut visit = |i: usize,
                     j: usize,
                     mi: usize,
                     queries: &mut usize,
                     cache_hits: &mut usize|
     -> (f64, bool) {
        let (ni, nl) = (space.ni[i], space.nl[j]);
        // per (ni, nl): (F_avg, bottleneck stall fraction); NaN F_avg
        // marks infeasible
        let (f_avg, stall) = *visited.entry((ni, nl)).or_insert_with(|| {
            *queries += 1;
            let (eval, hit) = evaluator.evaluate(flow, device, ni, nl, req);
            if hit {
                *cache_hits += 1;
            }
            let est = &eval.estimate;
            let stall = eval
                .stepped_network
                .as_ref()
                .map_or(0.0, |n| n.bottleneck_stall_fraction());
            if est.fits(&thresholds) {
                (est.f_avg(), stall)
            } else {
                (f64::NAN, stall) // infeasible marker
            }
        });
        if f_avg.is_nan() {
            return (-1.0, false);
        }
        let score =
            super::reward::BETA * f_avg - cfg.lambda * err_of(mi) - req.census_gamma * stall;
        (score, true)
    };

    for _ in 0..cfg.episodes {
        let (mut i, mut j) = (0usize, 0usize);
        let mut mi = m_n / 2;
        for _ in 0..cfg.steps_per_episode {
            let s = (i * nl_n + j) * m_n + mi;
            let a = if rng.next_f64() < cfg.epsilon {
                rng.below(N_ACTIONS as u64) as usize
            } else {
                argmax_tiebreak(&q[s], &mut rng)
            };
            let (i2, j2, m2) = match a {
                0 => (i, wrap(j + 1, nl_n), mi),
                1 => (wrap(i + 1, ni_n), j, mi),
                2 => (wrap(i + 1, ni_n), wrap(j + 1, nl_n), mi),
                3 => (i, j, (mi + 1).min(m_n - 1)),
                _ => (i, j, mi.saturating_sub(1)),
            };
            let (score, feasible) = visit(i2, j2, m2, &mut queries, &mut cache_hits);
            trace.push((space.ni[i2], space.nl[j2], m_levels[m2], score, feasible));
            let reward = if !feasible {
                -1.0
            } else if score > best_score {
                best_score = score;
                best = Some((space.ni[i2], space.nl[j2], m_levels[m2]));
                score
            } else {
                0.0
            };
            let s2 = (i2 * nl_n + j2) * m_n + m2;
            let max_next = q[s2].iter().copied().fold(f64::NEG_INFINITY, f64::max);
            q[s][a] += cfg.alpha * (reward + cfg.gamma * max_next - q[s][a]);
            (i, j, mi) = (i2, j2, m2);
        }
    }

    Ok(JointResult {
        best,
        best_score,
        queries,
        cache_hits,
        wall_seconds: t0.elapsed().as_secs_f64(),
        modeled_seconds: queries as f64 * query_seconds(device),
        trace,
    })
}

fn wrap(x: usize, n: usize) -> usize {
    if x >= n {
        0
    } else {
        x
    }
}

fn argmax_tiebreak(xs: &[f64], rng: &mut Rng) -> usize {
    let best = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let ties: Vec<usize> = (0..xs.len()).filter(|&i| xs[i] == best).collect();
    *rng.choose(&ties)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4};
    use crate::onnx::zoo;

    fn setup(name: &str) -> (Graph, ComputationFlow) {
        let g = zoo::build(name, true).unwrap();
        let f = ComputationFlow::extract(&g).unwrap();
        (g, f)
    }

    #[test]
    fn error_curve_is_monotone_decreasing_until_saturation() {
        let (g, _) = setup("lenet5");
        let curve = quant_error_curve(&g).unwrap();
        assert_eq!(curve.len(), (M_MAX - M_MIN + 1) as usize);
        // normalized into [0, 1]
        for (_, e) in &curve {
            assert!((0.0..=1.0).contains(e));
        }
        // more fraction bits -> lower rounding error (He-scaled weights
        // don't saturate below m=7 for LeNet)
        let errs: Vec<f64> = curve.iter().map(|(_, e)| *e).collect();
        assert!(errs[0] > errs[errs.len() - 1]);
    }

    #[test]
    fn joint_agent_finds_parallel_and_precise_corner() {
        let (g, f) = setup("lenet5");
        let r = explore(&g, &f, &ARRIA_10_GX1150, Thresholds::default(), JointConfig::default())
            .unwrap();
        let (ni, nl, m) = r.best.expect("lenet5 fits");
        // utilization term pushes to the grid max; error term to high m
        assert!(m >= 5, "chose m_w={m}");
        assert!(ni * nl >= 16, "chose ({ni},{nl})");
    }

    #[test]
    fn lambda_zero_ignores_quantization() {
        let (g, f) = setup("lenet5");
        let cfg = JointConfig {
            lambda: 0.0,
            ..JointConfig::default()
        };
        let r = explore(&g, &f, &ARRIA_10_GX1150, Thresholds::default(), cfg).unwrap();
        // score must equal β·F_avg of the best state: any m ties, agent
        // keeps the first maximal F_avg it sees
        assert!(r.best.is_some());
        assert!(r.best_score > 0.0);
    }

    #[test]
    fn infeasible_device_yields_none() {
        let (g, f) = setup("alexnet");
        let r = explore(
            &g,
            &f,
            &CYCLONE_V_5CSEMA4,
            Thresholds::default(),
            JointConfig::default(),
        )
        .unwrap();
        assert!(r.best.is_none());
        assert!(r.trace.iter().all(|(_, _, _, _, feas)| !feas));
    }

    #[test]
    fn stepped_fidelity_leaves_the_joint_choice_unchanged() {
        use crate::dse::Evaluator;
        let (g, f) = setup("lenet5");
        let cfg = JointConfig::default();
        let a = explore(&g, &f, &ARRIA_10_GX1150, Thresholds::default(), cfg).unwrap();
        let ev = Evaluator::new(2);
        let b = explore_with_fidelity(
            &ev,
            &g,
            &f,
            &ARRIA_10_GX1150,
            Thresholds::default(),
            cfg,
            EvalRequest::at(crate::dse::Fidelity::SteppedDominantRound),
        )
        .unwrap();
        assert_eq!(a.best, b.best);
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.queries, b.queries);
    }

    #[test]
    fn census_gamma_joins_the_composite_score_deterministically() {
        // the joint score gains the census term under stepped-full
        // fidelity; the seeded agent stays deterministic and feasible
        let (g, f) = setup("lenet5");
        let run = || {
            let ev = crate::dse::Evaluator::new(2);
            explore_with_fidelity(
                &ev,
                &g,
                &f,
                &ARRIA_10_GX1150,
                Thresholds::default(),
                JointConfig::default(),
                EvalRequest::shaped(crate::dse::Fidelity::SteppedFullNetwork, 0.5),
            )
            .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.best, b.best);
        assert_eq!(a.trace, b.trace);
        assert!(a.best.is_some(), "lenet5 fits");
    }

    #[test]
    fn higher_lambda_prefers_more_fraction_bits() {
        let (g, f) = setup("lenet5");
        let pick_m = |lambda: f64, seed: u64| -> i8 {
            let cfg = JointConfig {
                lambda,
                seed,
                ..JointConfig::default()
            };
            explore(&g, &f, &ARRIA_10_GX1150, Thresholds::default(), cfg)
                .unwrap()
                .best
                .map(|(_, _, m)| m)
                .unwrap_or(0)
        };
        // average over seeds to damp exploration noise
        let avg = |lambda: f64| -> f64 {
            (0..8).map(|s| pick_m(lambda, s) as f64).sum::<f64>() / 8.0
        };
        assert!(avg(2.0) >= avg(0.01) - 0.5, "λ=2 m̄={} vs λ≈0 m̄={}", avg(2.0), avg(0.01));
    }
}
