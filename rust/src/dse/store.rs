//! Sharded, append-only, streaming persistence for the evaluation memo
//! — the fleet-scale replacement for the single `--cache-file` JSON
//! document.
//!
//! The v5 cache file is one key-sorted document rewritten atomically in
//! full on every save: fine for thousands of entries, wrong for a
//! fleet-wide store millions of evaluations deep, where a sweep that
//! touches 4 models would re-serialize the other 96. This module keeps
//! the exact v5 *entry* codec (one [`eval::entry_to_json`] object per
//! entry, every paranoid cross-check of
//! [`eval::entry_from_json_v5`]) but changes the *container*:
//!
//! * **Line-delimited records.** Every file is JSON-lines: one compact
//!   [`crate::util::json`] document per line, so loads stream line by
//!   line and saves append records instead of re-serializing the world.
//! * **Sharding.** Entries live in one file per `(tenant, model)`
//!   fingerprint pair — the compile service's per-tenant namespaces are
//!   a shard key dimension, so tenants never share files. A small
//!   versioned manifest (`store.json`) catalogs the shards.
//! * **Differential persistence.** Each shard owns an append-only delta
//!   log (`<shard>.delta.jsonl`): new and updated entries append as
//!   `put` records, evictions as `del` tombstones. A size/ratio trigger
//!   compacts the shard back to its canonical key-sorted base file —
//!   whose bytes depend only on the logical entry set, never on the
//!   put/del history that produced it.
//! * **Advisory locking.** A `store.lock` file taken shared for loads
//!   and exclusive for saves/compactions (std `File` locking) keeps
//!   concurrent `serve` daemons and CLI sweeps from corrupting each
//!   other; writers from separate processes interleave their appends
//!   safely under it.
//!
//! Loading keeps the strict paranoid semantics of the legacy file, per
//! shard: format/version checks on the manifest and every shard header,
//! strictly-ascending (therefore duplicate-free) keys in the base,
//! shard-membership checks on every record, and all the payload-vs-key
//! contradictions [`eval::entry_from_json_v5`] rejects. A corrupt shard
//! goes cold with a loud warning — its suspect entries are never served
//! — while healthy shards still load; a *torn final delta record*
//! (crash mid-append: the trailing newline never hit disk) drops only
//! that record, with a warning, and the next exclusive-lock write
//! truncates the torn tail before appending.
//!
//! Migration from the v5 single file is one-shot: configure both
//! `--cache-dir` (the store) and `--cache-file` (the legacy document)
//! and the session absorbs every legacy entry the store doesn't already
//! have, then saves through the store only. The legacy whole-file save
//! path remains for `--cache-file`-only flows but is deprecated.

use std::collections::{BTreeMap, BTreeSet};
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context};

use super::eval::{self, EvalCache, EvalKey, Evaluation};
use crate::util::json::{Json, JsonObj};
use crate::util::sync::locked;

/// Format tag of the store manifest (`store.json`).
pub const STORE_FORMAT: &str = "cnn2gate-store";
/// Format tag of every shard base file's header line.
pub const SHARD_FORMAT: &str = "cnn2gate-shard";
/// Schema version of the manifest, shard headers and delta records;
/// bumped on any container layout change (entry payloads version
/// independently via `entry_version` = [`eval::CACHE_VERSION`]).
pub const STORE_VERSION: i64 = 1;
/// Manifest file name inside the store directory.
pub const MANIFEST_FILE: &str = "store.json";
/// Advisory lock file name inside the store directory.
pub const LOCK_FILE: &str = "store.lock";

/// Compact a shard once its delta log holds at least this many records…
const COMPACT_MIN_DELTA: usize = 256;
/// …or once it holds more than `base_entries / COMPACT_RATIO` records,
/// whichever threshold is larger — so a 1-entry append into a
/// 100k-entry shard stays an O(1) append, while a shard whose history
/// outgrows its base folds back to canonical form.
const COMPACT_RATIO: usize = 4;

/// The total order [`EvalKey::sort_key`] serializes to.
type SortKey = (u64, u64, usize, usize, u8, u64, u64, usize);

// ---------------------------------------------------------------------------
// Shard identity
// ---------------------------------------------------------------------------

/// A shard's identity: the `(tenant, model)` fingerprint pair every key
/// in it must carry. File names derive from it (`t<tenant>-m<model>`),
/// and the fixed-width hex means lexical file order equals numeric
/// `(tenant, model)` order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct ShardId {
    tenant: u64,
    model: u64,
}

impl ShardId {
    fn of(key: &EvalKey) -> ShardId {
        ShardId {
            tenant: key.tenant,
            model: key.model,
        }
    }

    fn name(&self) -> String {
        format!("t{}-m{}", eval::hex16(self.tenant), eval::hex16(self.model))
    }

    fn parse(s: &str) -> Result<ShardId, String> {
        let rest = s
            .strip_prefix('t')
            .ok_or_else(|| format!("bad shard id '{s}' (want t<hex16>-m<hex16>)"))?;
        let (tenant, model) = rest
            .split_once("-m")
            .ok_or_else(|| format!("bad shard id '{s}' (want t<hex16>-m<hex16>)"))?;
        Ok(ShardId {
            tenant: eval::parse_hex16(tenant)?,
            model: eval::parse_hex16(model)?,
        })
    }
}

fn base_path(dir: &Path, id: ShardId) -> PathBuf {
    dir.join(format!("{}.jsonl", id.name()))
}

fn delta_path(dir: &Path, id: ShardId) -> PathBuf {
    dir.join(format!("{}.delta.jsonl", id.name()))
}

// ---------------------------------------------------------------------------
// Record codecs (all single-line, via the compact Json Display form)
// ---------------------------------------------------------------------------

fn manifest_json(ids: &BTreeSet<ShardId>) -> Json {
    let mut o = JsonObj::new();
    o.insert("format", STORE_FORMAT.into());
    o.insert("version", STORE_VERSION.into());
    o.insert("entry_version", eval::CACHE_VERSION.into());
    o.insert(
        "shards",
        Json::Arr(ids.iter().map(|id| id.name().into()).collect()),
    );
    Json::Obj(o)
}

fn parse_manifest(doc: &Json) -> Result<Vec<ShardId>, String> {
    match doc.get("format").as_str() {
        Some(f) if f == STORE_FORMAT => {}
        other => {
            return Err(format!(
                "unsupported store format {other:?} (want {STORE_FORMAT:?})"
            ))
        }
    }
    match doc.get("version").as_i64() {
        Some(STORE_VERSION) => {}
        other => {
            return Err(format!(
                "unsupported store version {other:?} (want {STORE_VERSION})"
            ))
        }
    }
    match doc.get("entry_version").as_i64() {
        Some(v) if v == eval::CACHE_VERSION => {}
        other => {
            return Err(format!(
                "unsupported store entry version {other:?} (want {})",
                eval::CACHE_VERSION
            ))
        }
    }
    let arr = doc
        .get("shards")
        .as_arr()
        .ok_or_else(|| "missing 'shards' array".to_string())?;
    let mut ids = Vec::with_capacity(arr.len());
    let mut prev: Option<ShardId> = None;
    for (i, v) in arr.iter().enumerate() {
        let s = v
            .as_str()
            .ok_or_else(|| format!("shard {i}: not a string"))?;
        let id = ShardId::parse(s).map_err(|e| format!("shard {i}: {e}"))?;
        if prev.is_some_and(|p| id <= p) {
            return Err(format!("shard {i}: ids out of order or duplicated"));
        }
        prev = Some(id);
        ids.push(id);
    }
    Ok(ids)
}

fn shard_header(id: ShardId, entries: usize) -> Json {
    let mut o = JsonObj::new();
    o.insert("format", SHARD_FORMAT.into());
    o.insert("version", STORE_VERSION.into());
    o.insert("entry_version", eval::CACHE_VERSION.into());
    o.insert("shard", Json::Str(id.name()));
    o.insert("entries", entries.into());
    Json::Obj(o)
}

fn parse_shard_header(doc: &Json, id: ShardId) -> Result<usize, String> {
    match doc.get("format").as_str() {
        Some(f) if f == SHARD_FORMAT => {}
        other => {
            return Err(format!(
                "unsupported shard format {other:?} (want {SHARD_FORMAT:?})"
            ))
        }
    }
    match doc.get("version").as_i64() {
        Some(STORE_VERSION) => {}
        other => {
            return Err(format!(
                "unsupported shard version {other:?} (want {STORE_VERSION})"
            ))
        }
    }
    match doc.get("entry_version").as_i64() {
        Some(v) if v == eval::CACHE_VERSION => {}
        other => {
            return Err(format!(
                "unsupported shard entry version {other:?} (want {})",
                eval::CACHE_VERSION
            ))
        }
    }
    let named = eval::js(doc, "shard")?;
    if named != id.name() {
        return Err(format!(
            "shard header names '{named}' but the file is '{}'",
            id.name()
        ));
    }
    eval::jus(doc, "entries")
}

/// Serialize a bare [`EvalKey`] (the `del` tombstone payload) in the
/// same field spellings the v5 entry codec uses.
fn key_to_json(key: &EvalKey) -> Json {
    let mut o = JsonObj::new();
    o.insert("model", Json::Str(eval::hex16(key.model)));
    o.insert("device", Json::Str(eval::hex16(key.device)));
    o.insert("ni", key.ni.into());
    o.insert("nl", key.nl.into());
    o.insert("batch", key.batch.into());
    o.insert("fidelity", eval::fidelity_tag(key.fidelity).into());
    o.insert("census_gamma", Json::Num(f64::from_bits(key.census_gamma)));
    o.insert("tenant", Json::Str(eval::hex16(key.tenant)));
    Json::Obj(o)
}

fn key_from_json(v: &Json) -> Result<EvalKey, String> {
    let batch = eval::jus(v, "batch")?;
    if batch == 0 {
        return Err("zero batch".to_string());
    }
    Ok(EvalKey {
        model: eval::parse_hex16(&eval::js(v, "model")?)?,
        device: eval::parse_hex16(&eval::js(v, "device")?)?,
        ni: eval::jus(v, "ni")?,
        nl: eval::jus(v, "nl")?,
        fidelity: eval::parse_fidelity_tag(&eval::js(v, "fidelity")?)?,
        census_gamma: eval::gamma_key_bits(eval::jf(v, "census_gamma")?),
        tenant: eval::parse_hex16(&eval::js(v, "tenant")?)?,
        batch,
    })
}

fn put_record(key: &EvalKey, payload: &Evaluation, last_used: u64) -> String {
    let mut o = JsonObj::new();
    o.insert("op", "put".into());
    o.insert("entry", eval::entry_to_json(key, payload, last_used));
    format!("{}\n", Json::Obj(o))
}

fn del_record(key: &EvalKey) -> String {
    let mut o = JsonObj::new();
    o.insert("op", "del".into());
    o.insert("key", key_to_json(key));
    format!("{}\n", Json::Obj(o))
}

// ---------------------------------------------------------------------------
// Advisory locking
// ---------------------------------------------------------------------------

/// Take the store-wide advisory lock: shared for loads, exclusive for
/// saves and compactions. The lock is held by the returned `File` and
/// released when it drops. Lock files are advisory — they serialize
/// cooperating cnn2gate processes, they do not fence other tools.
fn store_lock(dir: &Path, exclusive: bool) -> std::io::Result<File> {
    let lockfile = OpenOptions::new()
        .create(true)
        .truncate(false)
        .read(true)
        .write(true)
        .open(dir.join(LOCK_FILE))?;
    if exclusive {
        lockfile.lock()?;
    } else {
        lockfile.lock_shared()?;
    }
    Ok(lockfile)
}

// ---------------------------------------------------------------------------
// Loading
// ---------------------------------------------------------------------------

/// One shard's fully-validated on-disk state: base ∪ delta applied.
struct LoadedShard {
    /// Logical entries after replaying the delta, in key order.
    entries: BTreeMap<SortKey, (EvalKey, Evaluation, u64)>,
    base_entries: usize,
    delta_records: usize,
    /// Set when the final delta record was torn (truncated mid-line):
    /// the recovered-prefix warning the caller must surface.
    torn_warning: Option<String>,
}

fn apply_delta_record(
    id: ShardId,
    v: &Json,
    entries: &mut BTreeMap<SortKey, (EvalKey, Evaluation, u64)>,
) -> Result<(), String> {
    match v.get("op").as_str() {
        Some("put") => {
            let (key, payload, last_used) = eval::entry_from_json_v5(v.get("entry"))?;
            if ShardId::of(&key) != id {
                return Err(format!(
                    "put record belongs to shard {}, not {}",
                    ShardId::of(&key).name(),
                    id.name()
                ));
            }
            entries.insert(key.sort_key(), (key, payload, last_used));
            Ok(())
        }
        Some("del") => {
            let key = key_from_json(v.get("key"))?;
            if ShardId::of(&key) != id {
                return Err(format!(
                    "del record belongs to shard {}, not {}",
                    ShardId::of(&key).name(),
                    id.name()
                ));
            }
            // deleting an absent key is fine: a crash between base
            // compaction and delta truncation replays old tombstones
            entries.remove(&key.sort_key());
            Ok(())
        }
        other => Err(format!("unknown delta op {other:?}")),
    }
}

/// Strict streaming load of one shard: header checks, strictly
/// ascending base keys (canonical order, no duplicates), membership
/// checks on every record, delta replay in append order. Only the
/// *final* delta record may be torn (no trailing newline — the crash
/// signature of an interrupted append); anything else wrong rejects the
/// whole shard.
fn load_shard(dir: &Path, id: ShardId) -> Result<LoadedShard, String> {
    let bpath = base_path(dir, id);
    let text = std::fs::read_to_string(&bpath)
        .map_err(|e| format!("reading {}: {e}", bpath.display()))?;
    let mut lines = text.lines();
    let header = lines
        .next()
        .ok_or_else(|| format!("{}: empty shard file", bpath.display()))?;
    let hdoc = Json::parse(header).map_err(|e| format!("{}: header: {e}", bpath.display()))?;
    let declared =
        parse_shard_header(&hdoc, id).map_err(|e| format!("{}: header: {e}", bpath.display()))?;
    let mut entries: BTreeMap<SortKey, (EvalKey, Evaluation, u64)> = BTreeMap::new();
    let mut prev: Option<SortKey> = None;
    for (no, line) in lines.enumerate() {
        let at = || format!("{}: entry {}", bpath.display(), no + 1);
        let v = Json::parse(line).map_err(|e| format!("{}: {e}", at()))?;
        let (key, payload, last_used) =
            eval::entry_from_json_v5(&v).map_err(|e| format!("{}: {e}", at()))?;
        if ShardId::of(&key) != id {
            return Err(format!(
                "{}: entry belongs to shard {}, not {}",
                at(),
                ShardId::of(&key).name(),
                id.name()
            ));
        }
        let sk = key.sort_key();
        if prev.is_some_and(|p| sk <= p) {
            return Err(format!("{}: keys out of order or duplicated", at()));
        }
        prev = Some(sk);
        entries.insert(sk, (key, payload, last_used));
    }
    let base_entries = entries.len();
    if base_entries != declared {
        return Err(format!(
            "{}: header declares {declared} entries, found {base_entries}",
            bpath.display()
        ));
    }

    let dpath = delta_path(dir, id);
    let dtext = match std::fs::read_to_string(&dpath) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
        Err(e) => return Err(format!("reading {}: {e}", dpath.display())),
    };
    let mut delta_records = 0usize;
    let mut torn_warning = None;
    let records: Vec<&str> = dtext.split_inclusive('\n').collect();
    for (i, raw) in records.iter().enumerate() {
        let last = i + 1 == records.len();
        if !raw.ends_with('\n') {
            // only reachable on the final chunk: a record is durable
            // only once its newline hit disk, so drop it — loudly
            torn_warning = Some(format!(
                "cache store: dropped a torn final delta record in {} \
                 (truncated mid-line; {delta_records} records recovered)",
                dpath.display()
            ));
            break;
        }
        let line = raw.trim_end_matches('\n');
        let applied = Json::parse(line)
            .map_err(|e| e.to_string())
            .and_then(|v| apply_delta_record(id, &v, &mut entries));
        match applied {
            Ok(()) => delta_records += 1,
            Err(e) if last => {
                return Err(format!("{}: final delta record: {e}", dpath.display()))
            }
            Err(e) => {
                return Err(format!(
                    "{}: delta record {}: {e}",
                    dpath.display(),
                    i + 1
                ))
            }
        }
    }
    Ok(LoadedShard {
        entries,
        base_entries,
        delta_records,
        torn_warning,
    })
}

// ---------------------------------------------------------------------------
// The store
// ---------------------------------------------------------------------------

/// Differential bookkeeping for one shard: the stamps this process last
/// saw on disk, so a save appends exactly the entries that changed.
#[derive(Debug, Default)]
struct ShardState {
    /// On-disk `(key, last_used)` per sort key (base ∪ delta applied).
    stamps: BTreeMap<SortKey, (EvalKey, u64)>,
    /// Entry count of the base file (drives the compaction ratio).
    base_entries: usize,
    /// Record count of the delta log (drives the compaction trigger).
    delta_records: usize,
    /// The shard failed to load: the next save rewrites it canonically
    /// instead of appending to files that cannot be trusted.
    corrupt: bool,
}

/// What [`CacheStore::open`] produced: the store handle, the cache
/// seeded from every healthy shard, and the (possibly empty) list of
/// warnings — corrupt shards gone cold, torn delta tails dropped.
pub struct StoreOpen {
    pub store: CacheStore,
    pub cache: EvalCache,
    pub warnings: Vec<String>,
}

/// What one [`CacheStore::save`] did, for CLI reporting and tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreSave {
    /// Shards whose files changed (appended, rewritten or compacted).
    pub shards_written: usize,
    /// `put` records appended across all delta logs.
    pub appended: usize,
    /// `del` tombstones appended across all delta logs.
    pub tombstones: usize,
    /// Shards rewritten canonically from scratch (new or healed).
    pub rewritten: usize,
    /// Shards compacted after their append tripped the trigger.
    pub compacted: usize,
    /// Total logical entries persisted across the store after the save.
    pub entries: usize,
}

/// Handle on a sharded cache store directory. Open one with
/// [`CacheStore::open`] (which also loads the cache it persists), run
/// the session, then [`CacheStore::save`] appends exactly what changed.
pub struct CacheStore {
    dir: PathBuf,
    /// Per-shard differential state; the file lock orders cross-process
    /// access, this mutex orders threads sharing the handle.
    snapshot: Mutex<BTreeMap<ShardId, ShardState>>,
}

impl CacheStore {
    /// Open (or prepare to create) the store at `dir` and load every
    /// healthy shard into a fresh [`EvalCache`]. Never fails and never
    /// panics: a missing directory or manifest is a silent cold start
    /// (the first save creates both); a corrupt manifest or shard goes
    /// cold with a warning — suspect entries are never served.
    pub fn open(dir: impl Into<PathBuf>) -> StoreOpen {
        let dir = dir.into();
        let cache = EvalCache::new();
        let mut warnings = Vec::new();
        let mut shards: BTreeMap<ShardId, ShardState> = BTreeMap::new();
        if dir.join(MANIFEST_FILE).exists() {
            // shared lock for the whole read: a concurrent compaction
            // must not swap shard files out from under the load
            match store_lock(&dir, false) {
                Err(e) => warnings.push(format!(
                    "cache store {}: could not take the shared lock ({e}); starting cold",
                    dir.display()
                )),
                Ok(_lockfile) => {
                    load_store(&dir, &cache, &mut shards, &mut warnings);
                }
            }
        }
        StoreOpen {
            store: CacheStore {
                dir,
                snapshot: Mutex::new(shards),
            },
            cache,
            warnings,
        }
    }

    /// The store directory this handle persists to.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Persist `cache` differentially: for every shard, append `put`
    /// records for new/updated entries and `del` tombstones for evicted
    /// ones; brand-new and corrupt shards are written canonically from
    /// scratch; shards whose delta log trips the size/ratio trigger are
    /// compacted. Untouched shards' files are not opened at all. The
    /// whole save runs under the exclusive store lock.
    pub fn save(&self, cache: &EvalCache) -> anyhow::Result<StoreSave> {
        // export before taking any store lock: the cache's own mutex
        // must never nest inside the store's
        let all = cache.export_entries();
        struct Live {
            key: EvalKey,
            payload: Arc<Evaluation>,
            last_used: u64,
            /// JSON-safe entries persist; unsafe ones stay resident but
            /// are neither appended nor tombstoned (the legacy
            /// skip-on-save rule).
            safe: bool,
        }
        let mut live: BTreeMap<ShardId, Vec<Live>> = BTreeMap::new();
        for (key, payload, last_used) in all {
            let safe = eval::json_safe(&payload, last_used)
                && f64::from_bits(key.census_gamma).is_finite();
            live.entry(ShardId::of(&key)).or_default().push(Live {
                key,
                payload,
                last_used,
                safe,
            });
        }
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating store directory {}", self.dir.display()))?;
        let _lockfile = store_lock(&self.dir, true)
            .with_context(|| format!("locking store {}", self.dir.display()))?;
        let mut snap = locked(&self.snapshot);
        let mut out = StoreSave::default();
        let ids: BTreeSet<ShardId> = live.keys().chain(snap.keys()).copied().collect();
        for id in ids {
            let known = snap.contains_key(&id);
            let entries = live.get(&id).map(Vec::as_slice).unwrap_or(&[]);
            let safe: Vec<&Live> = entries.iter().filter(|e| e.safe).collect();
            let fresh = !known && !base_path(&self.dir, id).exists();
            if fresh && safe.is_empty() {
                continue; // nothing persistable; don't create an empty shard
            }
            let state = snap.entry(id).or_default();
            if fresh || state.corrupt {
                // canonical full write. Remove the (untrusted) delta
                // FIRST: a crash between the two steps leaves the old
                // corrupt base — still corrupt, healed again next save —
                // never a fresh base polluted by stale delta records.
                let dpath = delta_path(&self.dir, id);
                if state.corrupt && dpath.exists() {
                    std::fs::remove_file(&dpath)
                        .with_context(|| format!("removing {}", dpath.display()))?;
                }
                write_base(
                    &self.dir,
                    id,
                    safe.len(),
                    safe.iter().map(|e| (&e.key, e.payload.as_ref(), e.last_used)),
                )?;
                state.stamps = safe
                    .iter()
                    .map(|e| (e.key.sort_key(), (e.key, e.last_used)))
                    .collect();
                state.base_entries = safe.len();
                state.delta_records = 0;
                state.corrupt = false;
                out.rewritten += 1;
                out.shards_written += 1;
                continue;
            }
            // differential append: diff the JSON-safe entries against
            // the stamps this process last saw on disk
            let puts: Vec<&Live> = safe
                .iter()
                .filter(|e| match state.stamps.get(&e.key.sort_key()) {
                    Some((_, stamp)) => *stamp != e.last_used,
                    None => true,
                })
                .copied()
                .collect();
            let present: BTreeSet<SortKey> =
                entries.iter().map(|e| e.key.sort_key()).collect();
            let dels: Vec<EvalKey> = state
                .stamps
                .iter()
                .filter(|(sk, _)| !present.contains(*sk))
                .map(|(_, (key, _))| *key)
                .collect();
            if puts.is_empty() && dels.is_empty() {
                continue; // untouched shard: no file I/O at all
            }
            let dpath = delta_path(&self.dir, id);
            repair_delta_tail(&dpath)
                .with_context(|| format!("repairing torn tail of {}", dpath.display()))?;
            let mut buf = String::new();
            for e in &puts {
                buf.push_str(&put_record(&e.key, &e.payload, e.last_used));
            }
            for key in &dels {
                buf.push_str(&del_record(key));
            }
            let mut file = OpenOptions::new()
                .create(true)
                .append(true)
                .open(&dpath)
                .with_context(|| format!("opening {}", dpath.display()))?;
            file.write_all(buf.as_bytes())
                .with_context(|| format!("appending to {}", dpath.display()))?;
            for e in &puts {
                state
                    .stamps
                    .insert(e.key.sort_key(), (e.key, e.last_used));
            }
            for key in &dels {
                state.stamps.remove(&key.sort_key());
            }
            state.delta_records += puts.len() + dels.len();
            out.appended += puts.len();
            out.tombstones += dels.len();
            out.shards_written += 1;
            if state.delta_records >= COMPACT_MIN_DELTA.max(state.base_entries / COMPACT_RATIO) {
                compact_shard(&self.dir, id, state)?;
                out.compacted += 1;
            }
        }
        out.entries = snap.values().map(|s| s.stamps.len()).sum();
        write_manifest(&self.dir, &snap)?;
        Ok(out)
    }

    /// Compact every shard that has delta records, folding base ∪ delta
    /// back to the canonical key-sorted base file (whose bytes depend
    /// only on the logical entry set). Returns how many shards were
    /// compacted. Corrupt shards are skipped (the next save heals
    /// them); concurrent writers' appends are preserved because
    /// compaction re-reads the files under the exclusive lock.
    pub fn compact_all(&self) -> anyhow::Result<usize> {
        if !self.dir.exists() {
            return Ok(0);
        }
        let _lockfile = store_lock(&self.dir, true)
            .with_context(|| format!("locking store {}", self.dir.display()))?;
        let mut snap = locked(&self.snapshot);
        let mut compacted = 0;
        for (id, state) in snap.iter_mut() {
            if state.corrupt {
                continue;
            }
            let dpath = delta_path(&self.dir, *id);
            let has_delta = std::fs::metadata(&dpath).map(|m| m.len() > 0).unwrap_or(false);
            if !has_delta {
                continue;
            }
            compact_shard(&self.dir, *id, state)?;
            compacted += 1;
        }
        Ok(compacted)
    }
}

/// The body of [`CacheStore::open`] once the shared lock is held.
fn load_store(
    dir: &Path,
    cache: &EvalCache,
    shards: &mut BTreeMap<ShardId, ShardState>,
    warnings: &mut Vec<String>,
) {
    let ids = match read_manifest(dir) {
        Ok(ids) => ids,
        Err(e) => {
            warnings.push(format!(
                "cache store {}: corrupt manifest ({e}); starting cold \
                 (the next save rebuilds it)",
                dir.display()
            ));
            return;
        }
    };
    let mut newest = 0u64;
    for id in ids {
        match load_shard(dir, id) {
            Ok(loaded) => {
                if let Some(w) = loaded.torn_warning {
                    warnings.push(w);
                }
                let mut stamps = BTreeMap::new();
                for (sk, (key, payload, last_used)) in loaded.entries {
                    newest = newest.max(last_used);
                    stamps.insert(sk, (key, last_used));
                    // shard membership was checked per record and keys
                    // are unique per shard, so this cannot collide
                    let _ = cache.insert_entry(key, Arc::new(payload), last_used);
                }
                shards.insert(
                    id,
                    ShardState {
                        stamps,
                        base_entries: loaded.base_entries,
                        delta_records: loaded.delta_records,
                        corrupt: false,
                    },
                );
            }
            Err(e) => {
                warnings.push(format!(
                    "cache store: shard {} is corrupt ({e}); its entries start \
                     cold and the next save rewrites it",
                    id.name()
                ));
                shards.insert(
                    id,
                    ShardState {
                        corrupt: true,
                        ..ShardState::default()
                    },
                );
            }
        }
    }
    cache.resume_clock(newest);
}

fn read_manifest(dir: &Path) -> Result<Vec<ShardId>, String> {
    let path = dir.join(MANIFEST_FILE);
    let text = std::fs::read_to_string(&path).map_err(|e| format!("reading {}: {e}", path.display()))?;
    let doc = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    parse_manifest(&doc).map_err(|e| format!("{}: {e}", path.display()))
}

/// Rewrite the manifest iff its shard catalog changed. The on-disk
/// manifest is re-read under the exclusive lock and unioned with ours,
/// so one writer publishing a new shard never drops another's.
fn write_manifest(dir: &Path, snap: &BTreeMap<ShardId, ShardState>) -> anyhow::Result<()> {
    let mut ids: BTreeSet<ShardId> = read_manifest(dir).unwrap_or_default().into_iter().collect();
    ids.extend(snap.keys().copied());
    let rendered = manifest_json(&ids).to_string_pretty();
    let path = dir.join(MANIFEST_FILE);
    if std::fs::read_to_string(&path).ok().as_deref() == Some(rendered.as_str()) {
        return Ok(());
    }
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, rendered).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("moving manifest into place at {}", path.display()))?;
    Ok(())
}

/// Write a shard's canonical base file: the header line followed by one
/// compact entry per line in key order, via tmp + rename so a crash
/// mid-write never publishes a truncated base.
fn write_base<'a>(
    dir: &Path,
    id: ShardId,
    count: usize,
    rows: impl Iterator<Item = (&'a EvalKey, &'a Evaluation, u64)>,
) -> anyhow::Result<()> {
    let mut text = String::new();
    text.push_str(&shard_header(id, count).to_string());
    text.push('\n');
    for (key, payload, last_used) in rows {
        text.push_str(&eval::entry_to_json(key, payload, last_used).to_string());
        text.push('\n');
    }
    let path = base_path(dir, id);
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, text).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("moving shard into place at {}", path.display()))?;
    Ok(())
}

/// Truncate a torn final delta record (no trailing newline) back to the
/// last complete line. Called under the exclusive lock before every
/// append, so a crash by any writer — including one that raced between
/// this process's open and its save — can't garble the next record.
fn repair_delta_tail(path: &Path) -> std::io::Result<()> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(e),
    };
    if bytes.is_empty() || bytes.ends_with(b"\n") {
        return Ok(());
    }
    let valid = bytes.iter().rposition(|&b| b == b'\n').map_or(0, |p| p + 1);
    let file = OpenOptions::new().write(true).open(path)?;
    file.set_len(valid as u64)
}

/// Fold base ∪ delta back to the canonical base. Reads the files — not
/// this process's memory — so entries a concurrent writer appended are
/// preserved; afterwards this process's differential state is exactly
/// the on-disk union. The delta truncates only AFTER the new base is
/// in place: replaying it over the compacted base is idempotent (puts
/// re-assert identical entries, dels remove already-absent keys), so
/// the crash window between the two steps is safe.
fn compact_shard(dir: &Path, id: ShardId, state: &mut ShardState) -> anyhow::Result<()> {
    let loaded =
        load_shard(dir, id).map_err(|e| anyhow!("compacting shard {}: {e}", id.name()))?;
    write_base(
        dir,
        id,
        loaded.entries.len(),
        loaded
            .entries
            .values()
            .map(|(key, payload, last_used)| (key, payload, *last_used)),
    )?;
    let dpath = delta_path(dir, id);
    if dpath.exists() {
        std::fs::remove_file(&dpath).with_context(|| format!("removing {}", dpath.display()))?;
    }
    state.stamps = loaded
        .entries
        .iter()
        .map(|(sk, (key, _, last_used))| (*sk, (*key, *last_used)))
        .collect();
    state.base_entries = loaded.entries.len();
    state.delta_records = 0;
    state.corrupt = false;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::eval::{EvalRequest, Evaluator, Fidelity};
    use crate::estimator::device;
    use crate::ir::ComputationFlow;
    use crate::onnx::zoo;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "cnn2gate-store-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn warm_cache(batches: &[usize]) -> EvalCache {
        let flow = ComputationFlow::extract(&zoo::build("tiny", false).unwrap()).unwrap();
        let dev = &device::CYCLONE_V_5CSEMA5;
        let cache = EvalCache::new();
        for &b in batches {
            for (ni, nl) in [(2, 2), (4, 4), (4, 8)] {
                cache.get_or_compute(
                    &flow,
                    dev,
                    ni,
                    nl,
                    EvalRequest::at(Fidelity::Analytical).batched(b),
                );
            }
        }
        cache
    }

    fn entry_set(cache: &EvalCache) -> Vec<(SortKey, u64)> {
        cache
            .export_entries()
            .iter()
            .map(|(k, _, stamp)| (k.sort_key(), *stamp))
            .collect()
    }

    #[test]
    fn shard_id_round_trips_and_orders() {
        let id = ShardId {
            tenant: 0xDEAD_BEEF,
            model: 7,
        };
        assert_eq!(ShardId::parse(&id.name()), Ok(id));
        assert!(ShardId::parse("nonsense").is_err());
        assert!(ShardId::parse("t123-m456").is_err(), "hex16 is fixed-width");
        // lexical file-name order equals numeric (tenant, model) order
        let lo = ShardId { tenant: 1, model: 2 };
        let hi = ShardId { tenant: 1, model: 3 };
        assert!(lo.name() < hi.name());
    }

    #[test]
    fn key_codec_round_trips() {
        let key = EvalKey {
            model: 11,
            device: 22,
            ni: 4,
            nl: 8,
            fidelity: Fidelity::SteppedFullNetwork,
            census_gamma: eval::gamma_key_bits(0.25),
            tenant: 33,
            batch: 16,
        };
        assert_eq!(key_from_json(&key_to_json(&key)), Ok(key));
        assert!(key_from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn fresh_store_round_trips_and_loads_warm() {
        let dir = tmp_dir("roundtrip");
        let cache = warm_cache(&[1, 4]);
        let open = CacheStore::open(&dir);
        assert!(open.warnings.is_empty());
        let save = open.store.save(&cache).unwrap();
        assert_eq!(save.rewritten, 1, "one (tenant 0, tiny) shard");
        assert_eq!(save.entries, 6);
        let reopened = CacheStore::open(&dir);
        assert!(reopened.warnings.is_empty(), "{:?}", reopened.warnings);
        assert_eq!(entry_set(&reopened.cache), entry_set(&cache));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn second_save_appends_a_delta_instead_of_rewriting() {
        let dir = tmp_dir("delta");
        let cache = warm_cache(&[1]);
        let open = CacheStore::open(&dir);
        open.store.save(&cache).unwrap();
        let base = base_path(&dir, ShardId::parse_first(&dir));
        let before = std::fs::read(&base).unwrap();
        // warm one more candidate: the next save must append, not rewrite
        let flow = ComputationFlow::extract(&zoo::build("tiny", false).unwrap()).unwrap();
        cache.get_or_compute(
            &flow,
            &device::CYCLONE_V_5CSEMA5,
            8,
            8,
            EvalRequest::at(Fidelity::Analytical),
        );
        let save = open.store.save(&cache).unwrap();
        assert_eq!(save.rewritten, 0);
        assert!(save.appended >= 1);
        assert_eq!(save.compacted, 0);
        assert_eq!(std::fs::read(&base).unwrap(), before, "base untouched");
        // and the union loads back
        let reopened = CacheStore::open(&dir);
        assert!(reopened.warnings.is_empty(), "{:?}", reopened.warnings);
        assert_eq!(entry_set(&reopened.cache), entry_set(&cache));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_appends_tombstones_that_replay() {
        let dir = tmp_dir("tombstone");
        let cache = warm_cache(&[1, 4]);
        let open = CacheStore::open(&dir);
        open.store.save(&cache).unwrap();
        let evicted = cache.evict_lru(2);
        assert!(evicted > 0);
        let save = open.store.save(&cache).unwrap();
        assert_eq!(save.tombstones, evicted);
        assert_eq!(save.entries, 2);
        let reopened = CacheStore::open(&dir);
        assert!(reopened.warnings.is_empty(), "{:?}", reopened.warnings);
        assert_eq!(entry_set(&reopened.cache), entry_set(&cache));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_is_byte_stable_across_histories() {
        // same logical entries via different put/del histories must
        // compact to byte-identical base files
        let dir_a = tmp_dir("stable-a");
        let dir_b = tmp_dir("stable-b");
        let cache = warm_cache(&[1, 4]);
        let a = CacheStore::open(&dir_a);
        a.store.save(&cache).unwrap();
        // history B: save a subset first, then the rest (delta), then compact
        let sub = warm_cache(&[1]);
        let b = CacheStore::open(&dir_b);
        b.store.save(&sub).unwrap();
        // then the full set, stamps and all, so the logical sets agree
        let fixed = EvalCache::new();
        fixed.absorb_missing(&cache);
        b.store.save(&fixed).unwrap();
        assert_eq!(a.store.compact_all().unwrap(), 0, "no delta after a fresh write");
        assert!(b.store.compact_all().unwrap() >= 1);
        let id = ShardId::parse_first(&dir_a);
        let bytes_a = std::fs::read(base_path(&dir_a, id)).unwrap();
        let bytes_b = std::fs::read(base_path(&dir_b, id)).unwrap();
        assert_eq!(bytes_a, bytes_b, "canonical bytes depend only on the entry set");
        let _ = std::fs::remove_dir_all(&dir_a);
        let _ = std::fs::remove_dir_all(&dir_b);
    }

    #[test]
    fn corrupt_shard_goes_cold_with_warning_and_heals() {
        let dir = tmp_dir("corrupt");
        let cache = warm_cache(&[1]);
        let open = CacheStore::open(&dir);
        open.store.save(&cache).unwrap();
        let id = ShardId::parse_first(&dir);
        // garble a middle byte of the base
        let path = base_path(&dir, id);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] = b'!';
        std::fs::write(&path, &bytes).unwrap();
        let reopened = CacheStore::open(&dir);
        assert_eq!(reopened.warnings.len(), 1, "{:?}", reopened.warnings);
        assert!(reopened.warnings[0].contains("corrupt"));
        assert_eq!(reopened.cache.stats().entries, 0, "suspect entries never load");
        // the next save heals the shard canonically
        let save = reopened.store.save(&cache).unwrap();
        assert_eq!(save.rewritten, 1);
        let healed = CacheStore::open(&dir);
        assert!(healed.warnings.is_empty(), "{:?}", healed.warnings);
        assert_eq!(entry_set(&healed.cache), entry_set(&cache));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_manifest_is_a_cold_start_with_warning() {
        let dir = tmp_dir("badmanifest");
        let cache = warm_cache(&[1]);
        let open = CacheStore::open(&dir);
        open.store.save(&cache).unwrap();
        std::fs::write(dir.join(MANIFEST_FILE), "not json").unwrap();
        let reopened = CacheStore::open(&dir);
        assert_eq!(reopened.warnings.len(), 1);
        assert!(reopened.warnings[0].contains("manifest"));
        assert_eq!(reopened.cache.stats().entries, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mis_sharded_records_reject_the_shard() {
        let dir = tmp_dir("missharded");
        let cache = warm_cache(&[1]);
        let open = CacheStore::open(&dir);
        open.store.save(&cache).unwrap();
        let id = ShardId::parse_first(&dir);
        // rename the shard files to a different (tenant, model): every
        // record now contradicts its container
        let other = ShardId {
            tenant: id.tenant,
            model: id.model ^ 1,
        };
        std::fs::rename(base_path(&dir, id), base_path(&dir, other)).unwrap();
        let mut ids = BTreeSet::new();
        ids.insert(other);
        std::fs::write(dir.join(MANIFEST_FILE), manifest_json(&ids).to_string_pretty()).unwrap();
        let reopened = CacheStore::open(&dir);
        assert_eq!(reopened.warnings.len(), 1, "{:?}", reopened.warnings);
        assert_eq!(reopened.cache.stats().entries, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn evaluator_integration_serves_store_entries_as_hits() {
        let dir = tmp_dir("hits");
        let cache = warm_cache(&[1]);
        let open = CacheStore::open(&dir);
        open.store.save(&cache).unwrap();
        let reopened = CacheStore::open(&dir);
        let ev = Evaluator::with_cache(2, std::sync::Arc::new(reopened.cache));
        let flow = ComputationFlow::extract(&zoo::build("tiny", false).unwrap()).unwrap();
        let (_, hit) = ev.evaluate(
            &flow,
            &device::CYCLONE_V_5CSEMA5,
            2,
            2,
            EvalRequest::at(Fidelity::Analytical),
        );
        assert!(hit, "store-loaded entry must serve as a cache hit");
        let _ = std::fs::remove_dir_all(&dir);
    }

    impl ShardId {
        /// Test helper: the first shard named by the store's manifest.
        fn parse_first(dir: &Path) -> ShardId {
            read_manifest(dir).unwrap()[0]
        }
    }
}
