//! Hardware-aware design-space exploration (paper §4.3-4.4): option
//! enumeration, Algorithm-1 reward shaping, brute-force and Q-learning
//! explorers over the estimator feedback loop.
//!
//! All explorers score candidates through [`eval`] — a shared
//! multi-threaded evaluation core with a process-wide memo cache keyed
//! on `(model fingerprint, device fingerprint, N_i, N_l, fidelity,
//! census γ, tenant)`. Brute force fans its grid out across the worker
//! pool (bit-identical results to the sequential path, validated by
//! tests); the sequential RL/joint agents go through the same cache so
//! revisited candidates — and whole re-explorations, as in fleet fits —
//! cost one lookup. Every explorer also runs under an explicit
//! [`EvalRequest`] naming the [`Fidelity`], census-reward γ and
//! [`TenantId`] namespace (`explore_with_fidelity`): with γ = 0 the
//! stepped modes attach
//! cycle-accurate censuses to each scored candidate without changing the
//! chosen design or trace — feasibility and F_avg come from the
//! estimator either way — while γ > 0 under `SteppedFullNetwork` feeds
//! the census back into Algorithm 1 as a bottleneck-stall penalty
//! ([`reward::RewardShaper::eval_censused`]). The [`specialize()`](specialize::specialize) pass
//! then converts the winner's census into per-layer (N_i, N_l) options
//! and weight schedules ([`SpecializationReport`]). For serving, the
//! [`throughput`] pass re-runs the configured explorer across candidate
//! batch sizes (each under its own `(…, B)` memo keys) and picks the
//! highest-frames/s (N_i, N_l, B) whose end-to-end latency — queueing
//! delay plus batch makespan — meets the optional SLO
//! ([`co_optimize`]).
//!
//! The memo cache persists through [`store`] — a sharded, append-only
//! store directory (`--cache-dir`) where each `(tenant, model)` shard
//! is its own line-delimited file with a differential delta log, so
//! fleet-scale sweeps load by streaming and save by appending exactly
//! what changed. The legacy single-file `--cache-file` document still
//! loads (one-shot migration) but its save path is deprecated.

pub mod brute;
pub mod eval;
pub mod joint;
pub mod options;
pub mod reward;
pub mod rl;
pub mod specialize;
pub mod store;
pub mod throughput;

pub use brute::DseResult;
pub use eval::{
    CacheStats, EvalCache, EvalRequest, Evaluation, Evaluator, Fidelity, TenantId, ThreadPool,
};
pub use joint::{JointConfig, JointResult};
pub use options::OptionSpace;
pub use reward::RewardShaper;
pub use rl::RlConfig;
pub use specialize::{specialize, LayerSpecialization, SpecializationReport};
pub use store::{CacheStore, StoreOpen, StoreSave};
pub use throughput::{co_optimize, BatchCandidate, ThroughputChoice};
