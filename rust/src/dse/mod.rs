//! Hardware-aware design-space exploration (paper §4.3-4.4): option
//! enumeration, Algorithm-1 reward shaping, brute-force and Q-learning
//! explorers over the estimator feedback loop.

pub mod brute;
pub mod joint;
pub mod options;
pub mod reward;
pub mod rl;

pub use brute::DseResult;
pub use options::OptionSpace;
pub use reward::RewardShaper;
pub use joint::{JointConfig, JointResult};
pub use rl::RlConfig;
