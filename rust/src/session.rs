//! The framework's front door: one typed entry point for the whole
//! parse → quantize → DSE → synth flow.
//!
//! Three PRs of knobs (evaluator sharing, cache files, fidelity,
//! schedulers) each grew a new positional-arg variant — `synth::run` /
//! `run_with` / `run_with_fidelity`, `fit_fleet` / `fit_fleet_with`,
//! `sweep_matrix` / `sweep_matrix_with` — and every CLI subcommand
//! re-derived the same plumbing by hand. This module replaces that
//! ladder with two typed values and one verb:
//!
//! * [`Session`] owns the run-scoped machinery: the [`Evaluator`]
//!   (worker pool + estimator memo), the [`CachePolicy`] (`--cache-file`
//!   load/save lifecycle, `--cache-max-entries` LRU bound), the
//!   [`Fidelity`] every candidate is scored at, the [`Thresholds`] the
//!   explorers fit against, and the work-stealing scheduler
//!   ([`crate::coordinator::scheduler`]) its runs fan out on. Build one
//!   via [`Session::builder`] (or [`SessionBuilder::from_args`] straight
//!   from parsed CLI flags) and reuse it across jobs so every
//!   exploration in the session shares one memo.
//! * [`CompileJob`] is the work spec: models × devices × [`Explorer`] ×
//!   optional [`QuantSpec`]. The single-model/single-device synth flow,
//!   the one-model fleet fit and the full model×device sweep are the
//!   1×1, 1×N and M×N shapes of the same matrix.
//! * [`Session::run`] executes the job and returns an [`Outcome`]:
//!   entries in deterministic model-major order, the legacy
//!   [`SynthReport`] / [`FleetReport`] / [`SweepReport`] as
//!   degenerate views, [`StealStats`] from the scheduler, and a stable
//!   machine-readable [`Outcome::to_json`] document (the CLI's `--json`).
//!
//! Every run — synth, fleet, sweep, RL episode batches included —
//! executes on the same two-phase engine: a **work-stealing prewarm**
//! over `(model, device, candidate-chunk)` deque items scores every
//! candidate of every pair's option grid into the shared memo (skewed
//! grid sizes rebalance at chunk granularity), then the per-pair
//! explorers run as deque items themselves, answered entirely from the
//! memo, and entries merge in input order. Results are therefore
//! bit-identical to the sequential seed paths, and identical runs render
//! byte-identical tables — pinned by the Session-vs-Session determinism
//! tests in `rust/tests/session.rs` (the PR-4 deprecated free-function
//! shims are gone; the session IS the only entry point now).
//!
//! Two census-era knobs ride the same machinery: the builder's
//! [`SessionBuilder::census_gamma`] shapes every explorer's reward with
//! the stepped census's bottleneck stall fraction, and
//! [`CompileJobBuilder::specialize`] runs the per-layer (N_i, N_l)
//! specialization pass ([`mod@crate::dse::specialize`]) on each fitting
//! cell.
//!
//! ```
//! # fn main() -> anyhow::Result<()> {
//! use cnn2gate::session::{CompileJob, Session};
//! use cnn2gate::synth::Explorer;
//!
//! let session = Session::builder().build();
//! let model = cnn2gate::onnx::zoo::build("tiny", false).unwrap();
//! let job = CompileJob::builder()
//!     .model(model)
//!     .all_devices()
//!     .explorer(Explorer::BruteForce)
//!     .build()?;
//! let outcome = session.run(&job)?;
//! let devices = cnn2gate::estimator::device::all().len();
//! assert_eq!(outcome.shape(), (1, devices));
//! # Ok(())
//! # }
//! ```

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::cli::Args;
use crate::coordinator::pipeline::{FleetReport, SweepReport};
use crate::coordinator::scheduler::{work_steal_map_seeded, StealStats};
use crate::dse::{
    brute, eval, rl, throughput, CacheStats, CacheStore, EvalCache, EvalRequest, Evaluator,
    Fidelity, OptionSpace, RlConfig, StoreSave, TenantId,
};
use crate::estimator::{device, synthesis_minutes, Device, Thresholds};
use crate::ir::{ComputationFlow, Graph};
use crate::quant::{self, QuantReport, QuantSpec};
use crate::synth::{Explorer, SynthReport};
use crate::util::json::{Json, JsonObj};

/// Format tag of the [`Outcome::to_json`] document.
pub const OUTCOME_FORMAT: &str = "cnn2gate-outcome";
/// Schema version of the [`Outcome::to_json`] document; bumped on any
/// layout change (v2: top-level `census_gamma`, per-entry
/// `specialization`; v3: per-entry `batch` + `throughput` and
/// `specialization.batch` for the batched serving flow; v4: per-
/// candidate `e2e_millis` — queueing delay + makespan — which the
/// latency SLO now bounds instead of the bare makespan; v5: branched
/// graph families — per-entry `round_producers` (DAG wiring, emitted
/// only for non-linear flows), per-feed starvation counters inside
/// stepped censuses (emitted only when nonzero) and
/// `specialization.specialized_frames_per_s`).
pub const OUTCOME_VERSION: i64 = 5;

/// Candidates per work-stealing prewarm item. Small enough that a
/// VGG-16-sized grid splits across several workers, big enough that the
/// deque traffic stays negligible against even an analytical candidate.
const CHUNK: usize = 4;

// ---------------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------------

/// How the session's estimator memo persists across processes: the
/// `--cache-dir` store (sharded + differential, the current format) or
/// the legacy `--cache-file` document it migrates from, plus the
/// `--cache-max-entries` LRU bound applied before saving (0 = unlimited).
#[derive(Debug, Clone, Default)]
pub struct CachePolicy {
    /// Sharded store directory ([`CacheStore`]); the preferred home.
    /// When both `dir` and `file` are set, the legacy file is loaded
    /// once and absorbed into the store (the store wins conflicts) —
    /// the one-shot v5 migration path.
    pub dir: Option<PathBuf>,
    /// Legacy single-document home of the memo; `None` (like `dir`
    /// `None`) keeps the cache in-process only. Its whole-file save
    /// path is deprecated: it only runs when no `dir` is configured.
    pub file: Option<PathBuf>,
    /// LRU-evict down to this many entries before saving (0 = unlimited).
    pub max_entries: usize,
}

/// Typed builder for [`Session`]. All knobs default to the paper flow:
/// shared process-global evaluator, no cache file, analytical fidelity,
/// threshold-free fitting (101% on every resource).
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    threads: usize,
    cache: CachePolicy,
    thresholds: Thresholds,
    fidelity: Fidelity,
    census_gamma: f64,
    tenant: TenantId,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            threads: 0,
            cache: CachePolicy::default(),
            thresholds: Thresholds::default(),
            fidelity: Fidelity::Analytical,
            census_gamma: 0.0,
            tenant: TenantId::DEFAULT,
        }
    }
}

impl SessionBuilder {
    pub fn new() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// Build a session straight from parsed CLI flags — the one place
    /// `--threads`, `--cache-dir`, `--cache-file`, `--cache-max-entries`,
    /// `--fidelity` and the `--max-*` thresholds are interpreted (every
    /// subcommand used to hand-roll its own copies).
    pub fn from_args(args: &Args) -> Result<SessionBuilder> {
        Ok(SessionBuilder::new()
            .threads(args.get_usize("threads", 0)?)
            .cache_policy(CachePolicy {
                dir: args.get("cache-dir").map(PathBuf::from),
                file: args.get("cache-file").map(PathBuf::from),
                max_entries: args.get_usize("cache-max-entries", 0)?,
            })
            .thresholds(Self::thresholds_from(args)?)
            .fidelity(Self::fidelity_from(args)?)
            .census_gamma(Self::census_gamma_from(args)?))
    }

    /// Parse `--census-gamma` (the shaped-reward γ; 0 = Algorithm 1).
    /// Rejects negative and non-finite weights.
    pub fn census_gamma_from(args: &Args) -> Result<f64> {
        let gamma = args.get_f64("census-gamma", 0.0)?;
        if !gamma.is_finite() || gamma < 0.0 {
            bail!("--census-gamma must be a finite non-negative number, got {gamma}");
        }
        Ok(gamma)
    }

    /// Parse the `--max-lut/--max-dsp/--max-mem/--max-reg` thresholds
    /// (101% each when absent: "fits" means "fits the chip").
    pub fn thresholds_from(args: &Args) -> Result<Thresholds> {
        Ok(Thresholds {
            lut: args.get_f64("max-lut", 101.0)?,
            dsp: args.get_f64("max-dsp", 101.0)?,
            mem: args.get_f64("max-mem", 101.0)?,
            reg: args.get_f64("max-reg", 101.0)?,
        })
    }

    /// Parse `--fidelity analytical|stepped|stepped-full`.
    pub fn fidelity_from(args: &Args) -> Result<Fidelity> {
        Ok(
            match args.get_choice(
                "fidelity",
                &["analytical", "stepped", "stepped-full"],
                "analytical",
            )? {
                "stepped" => Fidelity::SteppedDominantRound,
                "stepped-full" => Fidelity::SteppedFullNetwork,
                _ => Fidelity::Analytical,
            },
        )
    }

    /// Private worker-pool size; 0 (default) shares the process-global
    /// evaluator unless a cache file forces a private one.
    pub fn threads(mut self, threads: usize) -> SessionBuilder {
        self.threads = threads;
        self
    }

    /// Replace the whole [`CachePolicy`].
    pub fn cache_policy(mut self, cache: CachePolicy) -> SessionBuilder {
        self.cache = cache;
        self
    }

    /// Seed the memo from (and save it back to) this file. Deprecated
    /// in favor of [`SessionBuilder::cache_dir`]; with both set, the
    /// file only seeds (one-shot migration) and is never written.
    pub fn cache_file(mut self, path: impl Into<PathBuf>) -> SessionBuilder {
        self.cache.file = Some(path.into());
        self
    }

    /// Seed the memo from (and save it back to) the sharded store at
    /// this directory ([`CacheStore`]).
    pub fn cache_dir(mut self, dir: impl Into<PathBuf>) -> SessionBuilder {
        self.cache.dir = Some(dir.into());
        self
    }

    /// LRU bound applied before saving (0 = unlimited).
    pub fn cache_max_entries(mut self, max_entries: usize) -> SessionBuilder {
        self.cache.max_entries = max_entries;
        self
    }

    pub fn thresholds(mut self, thresholds: Thresholds) -> SessionBuilder {
        self.thresholds = thresholds;
        self
    }

    pub fn fidelity(mut self, fidelity: Fidelity) -> SessionBuilder {
        self.fidelity = fidelity;
        self
    }

    /// Census-reward γ: every explorer in the session scores candidates
    /// with `β·F_avg − γ·bottleneck_stall_fraction` (the stall term is
    /// live under [`Fidelity::SteppedFullNetwork`], inert elsewhere).
    /// 0 (default) is the paper's Algorithm 1, bit for bit.
    pub fn census_gamma(mut self, census_gamma: f64) -> SessionBuilder {
        self.census_gamma = census_gamma;
        self
    }

    /// Cache namespace every evaluation in the session is keyed under.
    /// Defaults to [`TenantId::DEFAULT`] — the single-tenant namespace
    /// the whole CLI runs in; the compile service sets a per-client
    /// tenant so co-resident clients never share memo entries.
    pub fn tenant(mut self, tenant: TenantId) -> SessionBuilder {
        self.tenant = tenant;
        self
    }

    /// Build the session. With a cache dir the evaluator is private and
    /// seeded from the sharded [`CacheStore`]; with a cache file it is
    /// seeded from the legacy document (and with both, the store loads
    /// first and absorbs whatever legacy entries it lacks — the one-shot
    /// v5 migration). Loading is tolerant either way: a missing
    /// file/store starts cold silently, a corrupt one starts cold with a
    /// [`Session::load_warning`] — suspect entries are never trusted.
    /// With only `threads` the pool is private but cold; with nothing,
    /// the process-global evaluator is shared.
    pub fn build(self) -> Session {
        let mut load_warning = None;
        let mut store = None;
        let evaluator = match (&self.cache.dir, &self.cache.file, self.threads) {
            (None, None, 0) => None,
            (None, None, n) => Some(Evaluator::new(n)),
            (Some(dir), legacy, n) => {
                let opened = CacheStore::open(dir);
                let mut warnings = opened.warnings;
                if let Some(path) = legacy {
                    // one-shot migration: absorb every legacy entry the
                    // store doesn't already have (the store wins
                    // conflicts); close() then saves through the store
                    // only, leaving the legacy file untouched
                    let (old, warning) = EvalCache::load_or_cold(path);
                    warnings.extend(warning);
                    opened.cache.absorb_missing(&old);
                }
                if !warnings.is_empty() {
                    load_warning = Some(warnings.join("; "));
                }
                store = Some(opened.store);
                let n = if n == 0 { eval::default_threads() } else { n };
                Some(Evaluator::with_cache(n, Arc::new(opened.cache)))
            }
            (None, Some(path), n) => {
                let (cache, warning) = EvalCache::load_or_cold(path);
                load_warning = warning;
                let n = if n == 0 { eval::default_threads() } else { n };
                Some(Evaluator::with_cache(n, Arc::new(cache)))
            }
        };
        Session {
            evaluator,
            store,
            cache: self.cache,
            thresholds: self.thresholds,
            fidelity: self.fidelity,
            census_gamma: self.census_gamma,
            tenant: self.tenant,
            load_warning,
        }
    }
}

/// What [`Session::close`] did: how many memo entries were LRU-evicted
/// and, when a cache store or file is configured, what was written
/// where.
#[derive(Debug, Clone, Default)]
pub struct CacheSave {
    pub evicted: usize,
    /// `(entries written, path)` when only a legacy cache file was
    /// configured (the deprecated whole-file save path).
    pub written: Option<(usize, PathBuf)>,
    /// `(differential save counters, store dir)` when a cache dir was
    /// configured.
    pub store: Option<(StoreSave, PathBuf)>,
}

/// The run-scoped machinery every [`CompileJob`] executes through. See
/// the [module docs](crate::session) for the full picture.
pub struct Session {
    /// `None` shares the process-global evaluator ([`eval::global`]).
    evaluator: Option<Evaluator>,
    /// The sharded store backing the memo when `--cache-dir` is set.
    store: Option<CacheStore>,
    cache: CachePolicy,
    thresholds: Thresholds,
    fidelity: Fidelity,
    census_gamma: f64,
    tenant: TenantId,
    load_warning: Option<String>,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    /// The evaluator this session scores candidates through.
    pub fn evaluator(&self) -> &Evaluator {
        match &self.evaluator {
            Some(ev) => ev,
            None => eval::global(),
        }
    }

    pub fn thresholds(&self) -> Thresholds {
        self.thresholds
    }

    pub fn fidelity(&self) -> Fidelity {
        self.fidelity
    }

    /// The census-reward γ every exploration in this session runs at.
    pub fn census_gamma(&self) -> f64 {
        self.census_gamma
    }

    /// The cache namespace this session's evaluations are keyed under.
    pub fn tenant(&self) -> TenantId {
        self.tenant
    }

    /// The [`EvalRequest`] every evaluation in this session runs under:
    /// the builder's fidelity, census γ and tenant namespace, as one
    /// value.
    pub fn request(&self) -> EvalRequest {
        EvalRequest::shaped(self.fidelity, self.census_gamma).tenant(self.tenant)
    }

    pub fn cache_policy(&self) -> &CachePolicy {
        &self.cache
    }

    /// Set when the configured cache file was corrupt or stale and the
    /// session fell back to a cold memo.
    pub fn load_warning(&self) -> Option<&str> {
        self.load_warning.as_deref()
    }

    /// Execute `job` on the session's two-phase work-stealing engine and
    /// return its [`Outcome`]. Entries come back model-major in job
    /// order; identical jobs produce identical entries (and therefore
    /// byte-identical rendered tables) regardless of thread scheduling.
    pub fn run(&self, job: &CompileJob) -> Result<Outcome> {
        if job.specialize && self.fidelity != Fidelity::SteppedFullNetwork {
            bail!(
                "per-layer specialization consumes the stepped-full census: \
                 set Fidelity::SteppedFullNetwork on the SessionBuilder \
                 (the CLI's --specialize does this automatically)"
            );
        }
        let run = execute(
            self.evaluator(),
            &job.models,
            &job.devices,
            job.explorer,
            self.thresholds,
            job.quant.as_ref(),
            self.request(),
            job.specialize,
            &job.batches,
            job.latency_slo_ms,
            &ExecHooks::default(),
        )?;
        Ok(Outcome {
            explorer: job.explorer,
            fidelity: self.fidelity,
            census_gamma: self.census_gamma,
            models: job.models.iter().map(|g| g.name.clone()).collect(),
            devices: job.devices.iter().map(|d| d.name).collect(),
            entries: run.entries,
            wall_seconds: run.wall_seconds,
            steals: run.steals,
            cache: self.evaluator().cache().stats(),
        })
    }

    /// The sharded [`CacheStore`] backing this session's memo, when a
    /// cache dir is configured.
    pub fn store(&self) -> Option<&CacheStore> {
        self.store.as_ref()
    }

    /// Persist the memo back to the [`CachePolicy`]'s store (when a
    /// cache dir is configured) or its legacy file (when only a cache
    /// file is), LRU-evicting first when `max_entries` bounds it. The
    /// store save is differential — it appends what changed instead of
    /// rewriting the world. A no-op session close (no persistence
    /// configured) returns a default [`CacheSave`].
    pub fn close(&self) -> Result<CacheSave> {
        let mut out = CacheSave::default();
        if self.cache.max_entries > 0 && (self.store.is_some() || self.cache.file.is_some()) {
            out.evicted = self.evaluator().cache().evict_lru(self.cache.max_entries);
        }
        if let Some(store) = &self.store {
            let saved = store.save(self.evaluator().cache())?;
            out.store = Some((saved, store.dir().to_path_buf()));
        } else if let Some(path) = &self.cache.file {
            let written = self.evaluator().cache().save(path)?;
            out.written = Some((written, path.clone()));
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// CompileJob
// ---------------------------------------------------------------------------

/// The work spec a [`Session`] executes: which models against which
/// devices, driven by which explorer, with optional post-training
/// quantization. `1×1` is the classic synth flow, `1×N` the fleet fit,
/// `M×N` the sweep — all the same matrix.
#[derive(Debug, Clone)]
pub struct CompileJob {
    /// Models, in report order.
    pub models: Vec<Graph>,
    /// Targets, in report order (defaults to the whole database).
    pub devices: Vec<&'static Device>,
    pub explorer: Explorer,
    /// Applied per (model, device) pair when present; requires resident
    /// weights.
    pub quant: Option<QuantSpec>,
    /// Run the per-layer (N_i, N_l) specialization pass on every fitting
    /// cell (requires the session's `Fidelity::SteppedFullNetwork`).
    pub specialize: bool,
    /// Candidate batch sizes for the throughput co-optimization
    /// ([`crate::dse::throughput`]). The default `[1]` keeps the classic
    /// latency-mode flow; anything else (or a latency SLO) re-runs the
    /// explorer per batch size and reports the highest-frames/s
    /// (N_i, N_l, B).
    pub batches: Vec<usize>,
    /// Optional serving SLO in ms: the chosen batch's end-to-end
    /// latency — queueing delay (a frame can wait up to one batch
    /// period before its batch launches) plus the batch makespan —
    /// must stay under it.
    pub latency_slo_ms: Option<f64>,
}

impl CompileJob {
    pub fn builder() -> CompileJobBuilder {
        CompileJobBuilder::default()
    }

    /// Parse `--explorer rl|bf` (default rl, the paper's headline
    /// agent).
    pub fn explorer_from_args(args: &Args) -> Result<Explorer> {
        Ok(match args.get_choice("explorer", &["rl", "bf"], "rl")? {
            "bf" => Explorer::BruteForce,
            _ => Explorer::Reinforcement,
        })
    }

    /// Parse `--batch b1,b2,...` (default `[1]`, the single-frame
    /// schedule). Rejects empty items and zeros; the engine normalizes
    /// (sort + dedup) later.
    pub fn batches_from_args(args: &Args) -> Result<Vec<usize>> {
        let items = args.get_list("batch", &[]);
        if items.is_empty() {
            return Ok(vec![1]);
        }
        let mut out = Vec::with_capacity(items.len());
        for item in items {
            let b: usize = item
                .parse()
                .map_err(|_| anyhow!("--batch expects positive integers, got {item:?}"))?;
            if b == 0 {
                bail!("--batch sizes must be >= 1, got 0");
            }
            out.push(b);
        }
        Ok(out)
    }

    /// Parse `--latency-slo <ms>` (absent = unconstrained throughput).
    /// Rejects non-positive and non-finite values.
    pub fn latency_slo_from_args(args: &Args) -> Result<Option<f64>> {
        match args.get("latency-slo") {
            None => Ok(None),
            Some(raw) => {
                let ms: f64 = raw
                    .parse()
                    .map_err(|_| anyhow!("--latency-slo expects milliseconds, got {raw:?}"))?;
                if !ms.is_finite() || ms <= 0.0 {
                    bail!("--latency-slo must be a finite positive number of ms, got {ms}");
                }
                Ok(Some(ms))
            }
        }
    }
}

/// Typed builder for [`CompileJob`].
#[derive(Debug, Clone)]
pub struct CompileJobBuilder {
    models: Vec<Graph>,
    devices: Vec<&'static Device>,
    explorer: Explorer,
    quant: Option<QuantSpec>,
    specialize: bool,
    batches: Vec<usize>,
    latency_slo_ms: Option<f64>,
}

impl Default for CompileJobBuilder {
    fn default() -> Self {
        CompileJobBuilder {
            models: Vec::new(),
            devices: Vec::new(),
            explorer: Explorer::Reinforcement,
            quant: None,
            specialize: false,
            batches: Vec::new(),
            latency_slo_ms: None,
        }
    }
}

impl CompileJobBuilder {
    /// Add one model.
    pub fn model(mut self, graph: Graph) -> CompileJobBuilder {
        self.models.push(graph);
        self
    }

    /// Add several models.
    pub fn models(mut self, graphs: impl IntoIterator<Item = Graph>) -> CompileJobBuilder {
        self.models.extend(graphs);
        self
    }

    /// Add one target device.
    pub fn device(mut self, device: &'static Device) -> CompileJobBuilder {
        self.devices.push(device);
        self
    }

    /// Add several target devices.
    pub fn devices(
        mut self,
        devices: impl IntoIterator<Item = &'static Device>,
    ) -> CompileJobBuilder {
        self.devices.extend(devices);
        self
    }

    /// Target every device in the database ([`device::all`]) — also the
    /// default when no device is named.
    pub fn all_devices(self) -> CompileJobBuilder {
        self.devices(device::all())
    }

    pub fn explorer(mut self, explorer: Explorer) -> CompileJobBuilder {
        self.explorer = explorer;
        self
    }

    /// Apply this post-training quantization spec to every model in the
    /// job (models must carry resident weights).
    pub fn quantize(mut self, spec: QuantSpec) -> CompileJobBuilder {
        self.quant = Some(spec);
        self
    }

    /// Run the per-layer (N_i, N_l) specialization pass on every fitting
    /// cell ([`mod@crate::dse::specialize`]). The session must score at
    /// [`Fidelity::SteppedFullNetwork`] — the pass consumes the chosen
    /// design's stepped census.
    pub fn specialize(mut self) -> CompileJobBuilder {
        self.specialize = true;
        self
    }

    /// Candidate batch sizes for the (N_i, N_l, B) throughput
    /// co-optimization (`--batch`). An empty list — the default — keeps
    /// the classic single-frame flow.
    pub fn batches(mut self, batches: impl IntoIterator<Item = usize>) -> CompileJobBuilder {
        self.batches.extend(batches);
        self
    }

    /// Serving latency SLO in ms (`--latency-slo`): the chosen batch's
    /// end-to-end latency (queueing delay + makespan) must stay under
    /// it.
    pub fn latency_slo_ms(mut self, ms: f64) -> CompileJobBuilder {
        self.latency_slo_ms = Some(ms);
        self
    }

    /// Validate and build. A job needs at least one model; an empty
    /// device list targets the whole database; an empty batch list means
    /// the single-frame schedule.
    pub fn build(self) -> Result<CompileJob> {
        if self.models.is_empty() {
            bail!("compile job needs at least one model");
        }
        if self.batches.contains(&0) {
            bail!("compile job batch sizes must be >= 1");
        }
        if let Some(ms) = self.latency_slo_ms {
            if !ms.is_finite() || ms <= 0.0 {
                bail!("compile job latency SLO must be a finite positive number of ms, got {ms}");
            }
        }
        let devices = if self.devices.is_empty() {
            device::all()
        } else {
            self.devices
        };
        let batches = if self.batches.is_empty() {
            vec![1]
        } else {
            self.batches
        };
        Ok(CompileJob {
            models: self.models,
            devices,
            explorer: self.explorer,
            quant: self.quant,
            specialize: self.specialize,
            batches,
            latency_slo_ms: self.latency_slo_ms,
        })
    }
}

// ---------------------------------------------------------------------------
// Outcome
// ---------------------------------------------------------------------------

/// Everything a [`Session::run`] produced: one [`SynthReport`] per
/// (model, device) pair in model-major job order, plus run-level
/// scheduler and memo counters. The legacy report shapes are views:
/// [`Outcome::synth_report`] (1×1), [`Outcome::to_fleet_report`] (one
/// model), [`Outcome::to_sweep_report`] (any shape).
#[derive(Debug)]
pub struct Outcome {
    pub explorer: Explorer,
    pub fidelity: Fidelity,
    /// Census-reward γ the explorations ran at (0 = plain Algorithm 1).
    pub census_gamma: f64,
    /// Model names in job order.
    pub models: Vec<String>,
    /// Device names in job order.
    pub devices: Vec<&'static str>,
    /// One report per (model, device) pair: model-major in `models`
    /// order, devices in `devices` order within a model.
    pub entries: Vec<SynthReport>,
    /// Wall time of the whole run (prewarm + exploration).
    pub wall_seconds: f64,
    /// Work-stealing scheduler counters across both engine phases.
    pub steals: StealStats,
    /// Point-in-time memo counters after the run.
    pub cache: CacheStats,
}

fn latency_key(r: &SynthReport) -> f64 {
    r.latency_ms().unwrap_or(f64::MAX)
}

fn resource_key(r: &SynthReport) -> f64 {
    r.estimate.as_ref().map_or(f64::MAX, |e| e.f_avg())
}

fn explorer_tag(explorer: Explorer) -> &'static str {
    match explorer {
        Explorer::BruteForce => "bf",
        Explorer::Reinforcement => "rl",
    }
}

impl Outcome {
    /// `(models, devices)` — `(1, 1)` is a synth flow, `(1, N)` a fleet
    /// fit, `(M, N)` a sweep.
    pub fn shape(&self) -> (usize, usize) {
        (self.models.len(), self.devices.len())
    }

    /// The matrix cell for one (model, device) pair, if present.
    pub fn entry(&self, model: &str, device: &str) -> Option<&SynthReport> {
        self.entries
            .iter()
            .find(|e| e.model == model && e.device == device)
    }

    /// The single report of a 1×1 job; `None` for larger shapes.
    pub fn synth_report(&self) -> Option<&SynthReport> {
        if self.entries.len() == 1 {
            self.entries.first()
        } else {
            None
        }
    }

    /// Like [`Outcome::synth_report`], taking ownership.
    pub fn into_synth_report(mut self) -> Option<SynthReport> {
        if self.entries.len() == 1 {
            self.entries.pop()
        } else {
            None
        }
    }

    /// The legacy fleet view of a single-model job; `None` when the job
    /// spans several models.
    pub fn to_fleet_report(&self) -> Option<FleetReport> {
        if self.models.len() != 1 {
            return None;
        }
        Some(FleetReport {
            model: self.models[0].clone(),
            explorer: self.explorer,
            entries: self.entries.clone(),
            wall_seconds: self.wall_seconds,
        })
    }

    /// The legacy sweep view (any shape). Its rankings run over the
    /// devices its entries actually cover (the job's device set), same
    /// as the rankings on `Outcome` itself.
    pub fn to_sweep_report(&self) -> SweepReport {
        SweepReport {
            explorer: self.explorer,
            models: self.models.clone(),
            entries: self.entries.clone(),
            wall_seconds: self.wall_seconds,
        }
    }

    /// Best (lowest simulated latency) fitting device per model, in job
    /// order; `None` when the model fits nothing.
    pub fn best_device_per_model(&self) -> Vec<(&str, Option<&SynthReport>)> {
        self.models
            .iter()
            .map(|m| {
                let best = self
                    .entries
                    .iter()
                    .filter(|e| e.model == *m && e.fits())
                    .min_by(|a, b| latency_key(a).total_cmp(&latency_key(b)));
                (m.as_str(), best)
            })
            .collect()
    }

    /// Best (lowest simulated latency) fitting model per device, in job
    /// order; `None` when nothing fits the device.
    pub fn best_model_per_device(&self) -> Vec<(&str, Option<&SynthReport>)> {
        self.devices
            .iter()
            .map(|dev| {
                let best = self
                    .entries
                    .iter()
                    .filter(|e| e.device == *dev && e.fits())
                    .min_by(|a, b| latency_key(a).total_cmp(&latency_key(b)));
                (*dev, best)
            })
            .collect()
    }

    /// Matrix-wide Pareto frontier over (simulated latency, F_avg): the
    /// fitting (model, device) points no other fit beats on both axes,
    /// sorted by latency.
    pub fn pareto_frontier(&self) -> Vec<&SynthReport> {
        let mut fits: Vec<&SynthReport> = self.entries.iter().filter(|e| e.fits()).collect();
        fits.sort_by(|a, b| {
            latency_key(a)
                .total_cmp(&latency_key(b))
                .then(resource_key(a).total_cmp(&resource_key(b)))
        });
        let mut frontier: Vec<&SynthReport> = Vec::new();
        let mut best_resource = f64::INFINITY;
        for entry in fits {
            let r = resource_key(entry);
            if r < best_resource {
                best_resource = r;
                frontier.push(entry);
            }
        }
        frontier
    }

    /// Render the outcome as a stable, machine-consumable JSON document
    /// (the CLI's `--json` on `synth`/`fit-fleet`/`sweep`).
    ///
    /// Deliberately **excludes** every volatile field — wall clocks,
    /// steal counts, memo hit totals — so identical jobs emit
    /// byte-identical documents across runs, warm or cold (pinned by the
    /// golden-file test). Numbers round-trip exactly through
    /// [`crate::util::json`].
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("format", OUTCOME_FORMAT.into());
        o.insert("version", OUTCOME_VERSION.into());
        o.insert("explorer", explorer_tag(self.explorer).into());
        o.insert("fidelity", eval::fidelity_tag(self.fidelity).into());
        o.insert("census_gamma", self.census_gamma.into());
        o.insert(
            "models",
            Json::Arr(self.models.iter().map(|m| m.as_str().into()).collect()),
        );
        o.insert(
            "devices",
            Json::Arr(self.devices.iter().map(|d| (*d).into()).collect()),
        );
        o.insert(
            "entries",
            Json::Arr(self.entries.iter().map(entry_to_json).collect()),
        );
        let mut rankings = JsonObj::new();
        rankings.insert(
            "best_device_per_model",
            Json::Arr(
                self.best_device_per_model()
                    .into_iter()
                    .map(|(model, best)| {
                        let mut r = JsonObj::new();
                        r.insert("model", model.into());
                        r.insert("device", best.map_or(Json::Null, |b| b.device.into()));
                        Json::Obj(r)
                    })
                    .collect(),
            ),
        );
        rankings.insert(
            "best_model_per_device",
            Json::Arr(
                self.best_model_per_device()
                    .into_iter()
                    .map(|(device, best)| {
                        let mut r = JsonObj::new();
                        r.insert("device", device.into());
                        r.insert("model", best.map_or(Json::Null, |b| b.model.as_str().into()));
                        Json::Obj(r)
                    })
                    .collect(),
            ),
        );
        rankings.insert(
            "pareto_frontier",
            Json::Arr(
                self.pareto_frontier()
                    .into_iter()
                    .map(|e| {
                        let mut r = JsonObj::new();
                        r.insert("model", e.model.as_str().into());
                        r.insert("device", e.device.into());
                        Json::Obj(r)
                    })
                    .collect(),
            ),
        );
        o.insert("rankings", Json::Obj(rankings));
        Json::Obj(o)
    }
}

/// One (model, device) entry of the JSON document. Every entry carries
/// the same key set (absent sections are `null`) so consumers — and the
/// golden schema test — see one shape. The one exception is
/// `round_producers` (schema v5): it exists only for non-linear flows,
/// so every chain-era document keeps its exact byte layout.
fn entry_to_json(rep: &SynthReport) -> Json {
    let mut o = JsonObj::new();
    o.insert("model", rep.model.as_str().into());
    o.insert("device", rep.device.into());
    o.insert("batch", rep.batch.into());
    o.insert("fits", rep.fits().into());
    o.insert(
        "option",
        match rep.option() {
            Some((ni, nl)) => Json::Arr(vec![ni.into(), nl.into()]),
            None => Json::Null,
        },
    );
    o.insert("f_max", rep.dse.f_max.into());
    o.insert("queries", rep.dse.queries.into());
    o.insert("cache_hits", rep.dse.cache_hits.into());
    o.insert("modeled_seconds", rep.dse.modeled_seconds.into());
    o.insert(
        "trace",
        Json::Arr(
            rep.dse
                .trace
                .iter()
                .map(|&(ni, nl, favg, feasible)| {
                    Json::Arr(vec![ni.into(), nl.into(), favg.into(), feasible.into()])
                })
                .collect(),
        ),
    );
    o.insert(
        "estimate",
        rep.estimate.as_ref().map_or(Json::Null, eval::est_to_json),
    );
    o.insert(
        "synthesis_minutes",
        rep.synthesis_minutes.map_or(Json::Null, Json::Num),
    );
    o.insert(
        "latency",
        rep.sim.as_ref().map_or(Json::Null, eval::sim_to_json),
    );
    o.insert(
        "throughput",
        rep.throughput.as_ref().map_or(Json::Null, throughput_to_json),
    );
    o.insert(
        "stepped_network",
        rep.stepped_network.as_ref().map_or(Json::Null, eval::net_to_json),
    );
    if let Some(producers) = &rep.round_producers {
        o.insert(
            "round_producers",
            Json::Arr(
                producers
                    .iter()
                    .map(|ps| Json::Arr(ps.iter().map(|&p| p.into()).collect()))
                    .collect(),
            ),
        );
    }
    o.insert("specialization", rep.specialization.as_ref().map_or(Json::Null, spec_to_json));
    o.insert(
        "quant",
        match &rep.quant {
            Some(q) => {
                let mut r = JsonObj::new();
                r.insert("tensors", q.tensors.len().into());
                r.insert("worst_abs_err", q.worst_abs_err().into());
                r.insert("worst_sat_ratio", q.worst_sat_ratio().into());
                Json::Obj(r)
            }
            None => Json::Null,
        },
    );
    Json::Obj(o)
}

/// The (N_i, N_l, B) throughput co-optimization section of one entry
/// (schema v4; present only when the job ran in throughput mode).
fn throughput_to_json(choice: &crate::dse::ThroughputChoice) -> Json {
    let mut o = JsonObj::new();
    o.insert(
        "latency_slo_ms",
        choice.latency_slo_ms.map_or(Json::Null, Json::Num),
    );
    o.insert("slo_satisfied", choice.slo_satisfied.into());
    o.insert("chosen_batch", choice.chosen_batch().into());
    o.insert(
        "candidates",
        Json::Arr(
            choice
                .candidates
                .iter()
                .map(|c| {
                    let mut r = JsonObj::new();
                    r.insert("batch", c.batch.into());
                    r.insert(
                        "option",
                        match c.option() {
                            Some((ni, nl)) => Json::Arr(vec![ni.into(), nl.into()]),
                            None => Json::Null,
                        },
                    );
                    r.insert("frames_per_s", c.frames_per_s.into());
                    r.insert("batch_millis", c.batch_millis.into());
                    r.insert("e2e_millis", c.e2e_millis.into());
                    r.insert("meets_slo", c.meets_slo.into());
                    Json::Obj(r)
                })
                .collect(),
        ),
    );
    Json::Obj(o)
}

/// The specialization section of one entry (schema v2; `batch` since
/// v3, `specialized_frames_per_s` since v5).
fn spec_to_json(spec: &crate::dse::SpecializationReport) -> Json {
    let mut o = JsonObj::new();
    o.insert("uniform", Json::Arr(vec![spec.uniform.0.into(), spec.uniform.1.into()]));
    o.insert("envelope", Json::Arr(vec![spec.envelope.0.into(), spec.envelope.1.into()]));
    o.insert("fmax_mhz", spec.fmax_mhz.into());
    o.insert("batch", spec.batch.into());
    o.insert("uniform_total_cycles", Json::Num(spec.uniform_total_cycles() as f64));
    o.insert("specialized_total_cycles", Json::Num(spec.specialized_total_cycles() as f64));
    o.insert("specialized_frames_per_s", spec.specialized_frames_per_s().into());
    o.insert("envelope_estimate", eval::est_to_json(&spec.envelope_estimate));
    o.insert(
        "layers",
        Json::Arr(
            spec.layers
                .iter()
                .map(|l| {
                    let mut r = JsonObj::new();
                    r.insert("index", l.index.into());
                    r.insert("label", l.label.as_str().into());
                    r.insert("ni", l.ni.into());
                    r.insert("nl", l.nl.into());
                    r.insert("schedule", crate::sim::schedule_tag(l.schedule).into());
                    r.insert("uniform_cycles", Json::Num(l.uniform_cycles as f64));
                    r.insert("cycles", Json::Num(l.cycles as f64));
                    Json::Obj(r)
                })
                .collect(),
        ),
    );
    Json::Obj(o)
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

/// Service-side hooks into [`execute`]: a cooperative cancel flag and a
/// progress callback. Both default to absent — [`Session::run`] passes
/// `ExecHooks::default()` and behaves exactly as before.
///
/// The cancel flag is checked once per prewarm chunk and once per
/// explored pair; a set flag makes the run bail with an error whose
/// message contains `"cancelled"`. The progress callback is invoked as
/// `(done, total)` where `total` counts the engine's work items
/// (prewarm chunks + explored pairs) — it runs on worker threads, so it
/// must be `Sync`.
#[derive(Default)]
pub(crate) struct ExecHooks<'a> {
    pub cancel: Option<&'a AtomicBool>,
    pub progress: Option<&'a (dyn Fn(usize, usize) + Sync)>,
}

impl ExecHooks<'_> {
    fn cancelled(&self) -> bool {
        self.cancel.is_some_and(|flag| flag.load(Ordering::Relaxed))
    }

    fn report(&self, done: usize, total: usize) {
        if let Some(notify) = self.progress {
            notify(done, total);
        }
    }
}

/// What [`execute`] hands back to [`Session::run`] and the compile
/// service's job runners.
pub(crate) struct EngineRun {
    pub entries: Vec<SynthReport>,
    pub steals: StealStats,
    pub wall_seconds: f64,
}

fn merge_steals(a: StealStats, b: StealStats) -> StealStats {
    StealStats {
        executed: a.executed + b.executed,
        steals: a.steals + b.steals,
        workers: a.workers.max(b.workers),
    }
}

/// The two-phase work-stealing engine behind [`Session::run`].
///
/// Phase 1 prewarms the shared memo over `(model, device,
/// candidate-chunk)` deque items under ONE LRU generation, so worker
/// completion order can't perturb the persisted cache stamps. The
/// prewarm deliberately scores the FULL grid even for the RL explorer
/// (which visits only a trajectory subset): grids cap at 12 options,
/// and full presence is what makes phase 2 hit-only — the source of
/// both the load balancing and the deterministic-output guarantee.
///
/// Phase 2 runs the per-pair explorers as deque items themselves —
/// fleet fits and RL episode batches ride the same work-stealing deques
/// — answered entirely from the memo, and merges entries model-major in
/// input order. A final [`EvalCache::touch_present`] pass re-stamps
/// every grid in deterministic order so `--cache-max-entries` eviction
/// and the saved cache bytes are scheduling-independent.
///
/// `req` names the [`Fidelity`], census γ and tenant namespace every
/// candidate is scored under; `batches`/`latency_slo_ms` select the
/// throughput co-optimization (the prewarm scores every grid once per
/// normalized batch size so the per-batch explorer passes stay
/// hit-only); `hooks` carries the compile service's cancel flag and
/// progress callback (see [`ExecHooks`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn execute(
    evaluator: &Evaluator,
    models: &[Graph],
    devices: &[&'static Device],
    explorer: Explorer,
    thresholds: Thresholds,
    quant: Option<&QuantSpec>,
    req: EvalRequest,
    specialize: bool,
    batches: &[usize],
    latency_slo_ms: Option<f64>,
    hooks: &ExecHooks,
) -> Result<EngineRun> {
    if models.is_empty() {
        bail!("compile job needs at least one model");
    }
    if devices.is_empty() {
        bail!("compile job needs at least one device");
    }
    // analysis: allow(nondet, wall-clock feeds only the volatile wall_seconds field, never the byte-stable document body)
    let t0 = Instant::now();
    let flows: Vec<ComputationFlow> = models
        .iter()
        .map(|g| ComputationFlow::extract(g).map_err(|e| anyhow!("flow extraction: {e}")))
        .collect::<Result<_>>()?;

    // quantization is device-independent: apply once per model up front
    // (before any exploration spends work), clone into each pair's report
    let quants: Vec<Option<QuantReport>> = match quant {
        Some(spec) => models
            .iter()
            .map(|g| {
                quant::apply(g, spec)
                    .map(Some)
                    .map_err(|e| anyhow!("quantization: {e}"))
            })
            .collect::<Result<_>>()?,
        None => vec![None; models.len()],
    };

    // phase 1: work-stealing prewarm — once per normalized batch size,
    // so the throughput co-optimization's per-batch explorer passes are
    // answered entirely from the memo
    let norm_batches = throughput::normalize_batches(batches);
    let reqs: Vec<EvalRequest> = norm_batches.iter().map(|&b| req.batched(b)).collect();
    let grids: Vec<Vec<(usize, usize)>> = flows
        .iter()
        .map(|f| OptionSpace::from_flow(f).pairs())
        .collect();
    let mut chunks: Vec<(usize, &'static Device, EvalRequest, Vec<(usize, usize)>)> = Vec::new();
    for (mi, grid) in grids.iter().enumerate() {
        for &dev in devices {
            for &breq in &reqs {
                for chunk in grid.chunks(CHUNK) {
                    chunks.push((mi, dev, breq, chunk.to_vec()));
                }
            }
        }
    }
    // phase 2's work items, listed up front so progress totals span both
    // phases
    let pairs: Vec<(usize, &'static Device)> = (0..models.len())
        .flat_map(|mi| devices.iter().map(move |&d| (mi, d)))
        .collect();
    let total = chunks.len() + pairs.len();
    let done = AtomicUsize::new(0);

    let stamp = evaluator.cache().tick();
    let prewarm_width = chunks.len().min(eval::default_threads());
    let (_, prewarm_steals) =
        work_steal_map_seeded(&chunks, prewarm_width, |i| i, |(mi, dev, breq, options)| {
            if hooks.cancelled() {
                return;
            }
            for &(ni, nl) in options {
                evaluator.cache().get_or_compute_at(stamp, &flows[*mi], dev, ni, nl, *breq);
            }
            hooks.report(done.fetch_add(1, Ordering::Relaxed) + 1, total);
        });
    if hooks.cancelled() {
        bail!("compile job cancelled during prewarm");
    }

    // phase 2: per-pair explorers on the same deques, all memo hits
    let explore_width = pairs.len().min(2 * eval::default_threads());
    let (results, explore_steals) =
        work_steal_map_seeded(&pairs, explore_width, |i| i, |&(mi, dev)| {
            if hooks.cancelled() {
                bail!("compile job cancelled");
            }
            let entry = compile_pair(
                evaluator,
                &models[mi],
                &flows[mi],
                dev,
                explorer,
                thresholds,
                quants[mi].as_ref(),
                req,
                specialize,
                &norm_batches,
                latency_slo_ms,
            )?;
            hooks.report(done.fetch_add(1, Ordering::Relaxed) + 1, total);
            Ok(entry)
        });
    let mut entries = Vec::with_capacity(results.len());
    for result in results {
        entries.push(result?);
    }

    // deterministic re-stamp (see the function docs), once per batch
    for (flow, grid) in flows.iter().zip(&grids) {
        for &dev in devices {
            for &breq in &reqs {
                evaluator.cache().touch_present(flow, dev, grid, breq);
            }
        }
    }
    Ok(EngineRun {
        entries,
        steals: merge_steals(prewarm_steals, explore_steals),
        wall_seconds: t0.elapsed().as_secs_f64(),
    })
}

/// One (model, device) cell: DSE → estimate at H_best → synthesis-time
/// model → latency (pulled from the memo; the chosen option was already
/// scored during exploration, so nothing is recomputed) → optional
/// per-layer specialization of the chosen design.
///
/// With the default `batches == [1]` and no SLO this is the classic
/// latency-mode flow, bit-identical to pre-batch outputs. Otherwise the
/// [`throughput`] pass re-runs the explorer per batch size (all memo
/// hits after the prewarm), the entry reports the chosen batch's
/// winner, and the full sweep rides along in [`SynthReport::throughput`].
#[allow(clippy::too_many_arguments)]
fn compile_pair(
    evaluator: &Evaluator,
    graph: &Graph,
    flow: &ComputationFlow,
    device: &'static Device,
    explorer: Explorer,
    thresholds: Thresholds,
    quant: Option<&QuantReport>,
    req: EvalRequest,
    specialize: bool,
    norm_batches: &[usize],
    latency_slo_ms: Option<f64>,
) -> Result<SynthReport> {
    let explore_at = |r: EvalRequest| match explorer {
        Explorer::BruteForce => {
            brute::explore_with_fidelity(evaluator, flow, device, thresholds, r)
        }
        Explorer::Reinforcement => {
            rl::explore_with_fidelity(evaluator, flow, device, thresholds, RlConfig::default(), r)
        }
    };
    let throughput_mode = norm_batches != [1] || latency_slo_ms.is_some();
    let (dse, batch, choice, req) = if throughput_mode {
        let choice = throughput::co_optimize(
            evaluator,
            flow,
            device,
            req,
            norm_batches,
            latency_slo_ms,
            explore_at,
        );
        let batch = choice.chosen_batch();
        let dse = choice.candidates[choice.chosen].dse.clone();
        (dse, batch, Some(choice), req.batched(batch))
    } else {
        (explore_at(req), 1, None, req)
    };

    let (estimate, synth_min, sim, stepped_network, specialization) =
        match (dse.best, &dse.best_estimate) {
            (Some((ni, nl)), Some(est)) => {
                let minutes = synthesis_minutes(est, device);
                let (chosen, _) = evaluator.evaluate(flow, device, ni, nl, req);
                let specialization = match (&chosen.stepped_network, specialize) {
                    (Some(census), true) => Some(crate::dse::specialize::specialize(
                        flow,
                        device,
                        &thresholds,
                        est,
                        census,
                    )),
                    _ => None,
                };
                (
                    Some(est.clone()),
                    Some(minutes),
                    Some(chosen.latency.clone()),
                    chosen.stepped_network.clone(),
                    specialization,
                )
            }
            _ => (None, None, None, None, None),
        };

    Ok(SynthReport {
        model: graph.name.clone(),
        device: device.name,
        explorer,
        batch,
        throughput: choice,
        dse,
        estimate,
        synthesis_minutes: synth_min,
        sim,
        stepped_network,
        specialization,
        round_producers: (!flow.is_linear_chain())
            .then(|| flow.layers.iter().map(|l| l.producers.clone()).collect()),
        quant: quant.cloned(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA4};
    use crate::onnx::zoo;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn default_session_shares_the_global_evaluator() {
        let s = Session::builder().build();
        assert!(std::ptr::eq(s.evaluator(), eval::global()));
        assert_eq!(s.fidelity(), Fidelity::Analytical);
        assert!(s.load_warning().is_none());
        // explicit threads means a private evaluator
        let p = Session::builder().threads(3).build();
        assert!(!std::ptr::eq(p.evaluator(), eval::global()));
        assert_eq!(p.evaluator().threads(), 3);
    }

    #[test]
    fn cache_file_session_is_private_and_warns_on_corruption() {
        let path = std::env::temp_dir().join(format!(
            "cnn2gate-session-cache-{}.json",
            std::process::id()
        ));
        std::fs::remove_file(&path).ok();
        // missing file: cold start, no warning
        let s = Session::builder().cache_file(&path).build();
        assert!(!std::ptr::eq(s.evaluator(), eval::global()));
        assert!(s.load_warning().is_none());
        // corrupt file: cold start with a warning
        std::fs::write(&path, "{not json").unwrap();
        let s = Session::builder().cache_file(&path).build();
        assert!(s.load_warning().is_some());
        assert_eq!(s.evaluator().cache().stats().entries, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn close_evicts_and_saves_per_policy() {
        let path = std::env::temp_dir().join(format!(
            "cnn2gate-session-close-{}.json",
            std::process::id()
        ));
        std::fs::remove_file(&path).ok();
        let session = Session::builder()
            .cache_file(&path)
            .cache_max_entries(4)
            .build();
        let job = CompileJob::builder()
            .model(zoo::build("alexnet", false).unwrap())
            .device(&ARRIA_10_GX1150)
            .explorer(Explorer::BruteForce)
            .build()
            .unwrap();
        session.run(&job).unwrap();
        let save = session.close().unwrap();
        let (written, at) = save.written.expect("cache file configured");
        assert_eq!(written, 4, "evicted down to --cache-max-entries");
        assert_eq!(at, path);
        assert!(save.evicted > 0);
        assert_eq!(EvalCache::load(&path).unwrap().stats().entries, 4);
        // a session without a cache file closes as a no-op
        let plain = Session::builder().build().close().unwrap();
        assert_eq!(plain.evicted, 0);
        assert!(plain.written.is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn builder_from_args_reads_all_session_flags() {
        let args = Args::parse(
            &sv(&[
                "sweep",
                "--threads",
                "3",
                "--cache-file",
                "/tmp/x.json",
                "--cache-max-entries",
                "7",
                "--fidelity",
                "stepped-full",
                "--census-gamma",
                "0.25",
                "--max-lut",
                "50",
            ]),
            &[
                "threads",
                "cache-file",
                "cache-max-entries",
                "fidelity",
                "census-gamma",
                "max-lut",
            ],
            &[],
        )
        .unwrap();
        let b = SessionBuilder::from_args(&args).unwrap();
        assert_eq!(b.threads, 3);
        assert_eq!(b.cache.file.as_deref(), Some(std::path::Path::new("/tmp/x.json")));
        assert_eq!(b.cache.max_entries, 7);
        assert_eq!(b.fidelity, Fidelity::SteppedFullNetwork);
        assert_eq!(b.census_gamma, 0.25);
        assert_eq!(b.thresholds.lut, 50.0);
        assert_eq!(b.thresholds.dsp, 101.0);
        // a negative or non-finite γ is rejected
        for bad in ["-1", "NaN", "inf"] {
            let a =
                Args::parse(&sv(&["dse", "--census-gamma", bad]), &["census-gamma"], &[]).unwrap();
            assert!(SessionBuilder::from_args(&a).is_err(), "γ={bad} must be rejected");
        }
        // defaults when nothing is given
        let empty = Args::parse(&sv(&["synth"]), &[], &[]).unwrap();
        let d = SessionBuilder::from_args(&empty).unwrap();
        assert_eq!(d.threads, 0);
        assert!(d.cache.file.is_none());
        assert_eq!(d.fidelity, Fidelity::Analytical);
        assert_eq!(d.census_gamma, 0.0);
        // explorer parsing lives on the job side
        let bf = Args::parse(&sv(&["synth", "--explorer", "bf"]), &["explorer"], &[]).unwrap();
        assert_eq!(CompileJob::explorer_from_args(&bf).unwrap(), Explorer::BruteForce);
        assert_eq!(CompileJob::explorer_from_args(&empty).unwrap(), Explorer::Reinforcement);
        let bad = Args::parse(&sv(&["synth", "--explorer", "x"]), &["explorer"], &[]).unwrap();
        assert!(CompileJob::explorer_from_args(&bad).is_err());
        // so do the throughput knobs
        let batched = Args::parse(
            &sv(&["synth", "--batch", "16,1,4", "--latency-slo", "25"]),
            &["batch", "latency-slo"],
            &[],
        )
        .unwrap();
        assert_eq!(CompileJob::batches_from_args(&batched).unwrap(), vec![16, 1, 4]);
        assert_eq!(CompileJob::latency_slo_from_args(&batched).unwrap(), Some(25.0));
        assert_eq!(CompileJob::batches_from_args(&empty).unwrap(), vec![1]);
        assert_eq!(CompileJob::latency_slo_from_args(&empty).unwrap(), None);
        for bad in ["0", "x", "-2"] {
            let a = Args::parse(&sv(&["synth", "--batch", bad]), &["batch"], &[]).unwrap();
            assert!(CompileJob::batches_from_args(&a).is_err(), "batch={bad} must be rejected");
        }
        for bad in ["0", "-5", "NaN", "x"] {
            let a =
                Args::parse(&sv(&["synth", "--latency-slo", bad]), &["latency-slo"], &[]).unwrap();
            assert!(
                CompileJob::latency_slo_from_args(&a).is_err(),
                "slo={bad} must be rejected"
            );
        }
    }

    #[test]
    fn job_builder_validates_and_defaults() {
        let err = CompileJob::builder().build().unwrap_err();
        assert!(err.to_string().contains("at least one model"));
        let job = CompileJob::builder()
            .model(zoo::build("tiny", false).unwrap())
            .build()
            .unwrap();
        assert_eq!(job.devices.len(), device::all().len(), "defaults to the database");
        assert_eq!(job.explorer, Explorer::Reinforcement);
        assert!(job.quant.is_none());
        assert!(!job.specialize);
        assert_eq!(job.batches, vec![1], "default is the single-frame schedule");
        assert!(job.latency_slo_ms.is_none());
        // throughput knobs are validated at build time
        let err = CompileJob::builder()
            .model(zoo::build("tiny", false).unwrap())
            .batches([4, 0])
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("batch sizes"));
        let err = CompileJob::builder()
            .model(zoo::build("tiny", false).unwrap())
            .latency_slo_ms(-1.0)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("latency SLO"));
        let job = CompileJob::builder()
            .model(zoo::build("tiny", false).unwrap())
            .batches([16, 1, 4])
            .latency_slo_ms(25.0)
            .build()
            .unwrap();
        assert_eq!(job.batches, vec![16, 1, 4], "engine normalizes, builder preserves");
        assert_eq!(job.latency_slo_ms, Some(25.0));
    }

    #[test]
    fn specialize_requires_stepped_full_fidelity() {
        let session = Session::builder().threads(2).build(); // analytical
        let job = CompileJob::builder()
            .model(zoo::build("tiny", false).unwrap())
            .device(&ARRIA_10_GX1150)
            .explorer(Explorer::BruteForce)
            .specialize()
            .build()
            .unwrap();
        let err = session.run(&job).unwrap_err();
        assert!(err.to_string().contains("stepped-full"), "{err}");
        // at the right fidelity the same job carries the report
        let stepped = Session::builder().threads(2).fidelity(Fidelity::SteppedFullNetwork).build();
        let outcome = stepped.run(&job).unwrap();
        let rep = outcome.synth_report().unwrap();
        let spec = rep.specialization.as_ref().expect("specialization present");
        assert_eq!(spec.uniform, rep.option().unwrap());
        assert!(spec.specialized_total_cycles() <= spec.uniform_total_cycles());
    }

    #[test]
    fn outcome_shapes_and_views() {
        let session = Session::builder().threads(2).build();
        // 1×1: synth view
        let one = session
            .run(
                &CompileJob::builder()
                    .model(zoo::build("alexnet", false).unwrap())
                    .device(&ARRIA_10_GX1150)
                    .explorer(Explorer::BruteForce)
                    .build()
                    .unwrap(),
            )
            .unwrap();
        assert_eq!(one.shape(), (1, 1));
        let rep = one.synth_report().expect("1x1 synth view");
        assert_eq!(rep.option(), Some((16, 32)));
        assert!(one.to_fleet_report().is_some(), "1×1 is also a 1-model fleet");
        // 1×N: fleet view
        let fleet = session
            .run(
                &CompileJob::builder()
                    .model(zoo::build("alexnet", false).unwrap())
                    .all_devices()
                    .explorer(Explorer::BruteForce)
                    .build()
                    .unwrap(),
            )
            .unwrap();
        assert_eq!(fleet.shape(), (1, device::all().len()));
        assert!(fleet.synth_report().is_none());
        let fr = fleet.to_fleet_report().expect("fleet view");
        assert_eq!(fr.entries.len(), device::all().len());
        assert_eq!(
            fr.best().unwrap().device,
            fleet
                .best_device_per_model()
                .first()
                .and_then(|(_, b)| *b)
                .unwrap()
                .device
        );
        // M×N: sweep view, model-major entry order
        let sweep = session
            .run(
                &CompileJob::builder()
                    .models([
                        zoo::build("alexnet", false).unwrap(),
                        zoo::build("tiny", false).unwrap(),
                    ])
                    .all_devices()
                    .explorer(Explorer::BruteForce)
                    .build()
                    .unwrap(),
            )
            .unwrap();
        assert_eq!(sweep.shape(), (2, device::all().len()));
        assert!(sweep.to_fleet_report().is_none());
        for (mi, model) in sweep.models.iter().enumerate() {
            for (di, dev) in sweep.devices.iter().enumerate() {
                let entry = &sweep.entries[mi * sweep.devices.len() + di];
                assert_eq!(entry.model, *model);
                assert_eq!(entry.device, *dev);
            }
        }
        assert_eq!(
            sweep.entry("alexnet", "Arria 10 GX 1150").unwrap().option(),
            Some((16, 32))
        );
        // rankings agree with the legacy SweepReport views on the full DB
        let legacy = sweep.to_sweep_report();
        let ours: Vec<_> = sweep
            .best_device_per_model()
            .into_iter()
            .map(|(m, b)| (m.to_string(), b.map(|r| r.device)))
            .collect();
        let theirs: Vec<_> = legacy
            .best_device_per_model()
            .into_iter()
            .map(|(m, b)| (m.to_string(), b.map(|r| r.device)))
            .collect();
        assert_eq!(ours, theirs);
        let ours: Vec<_> = sweep
            .pareto_frontier()
            .into_iter()
            .map(|r| (r.model.clone(), r.device))
            .collect();
        let theirs: Vec<_> = legacy
            .pareto_frontier()
            .into_iter()
            .map(|r| (r.model.clone(), r.device))
            .collect();
        assert_eq!(ours, theirs);
        assert!(sweep.steals.executed > 0);
        assert!(sweep.steals.workers >= 1);
    }

    #[test]
    fn subset_device_rankings_stay_within_the_job() {
        let session = Session::builder().threads(2).build();
        let outcome = session
            .run(
                &CompileJob::builder()
                    .model(zoo::build("alexnet", false).unwrap())
                    .device(&CYCLONE_V_5CSEMA4)
                    .device(&ARRIA_10_GX1150)
                    .explorer(Explorer::BruteForce)
                    .build()
                    .unwrap(),
            )
            .unwrap();
        let per_device = outcome.best_model_per_device();
        assert_eq!(per_device.len(), 2, "only the job's devices are ranked");
        assert!(per_device[0].1.is_none(), "nothing fits the 5CSEMA4");
        assert_eq!(per_device[1].1.unwrap().model, "alexnet");
    }

    #[test]
    fn throughput_job_reports_the_chosen_batch() {
        let session = Session::builder().threads(2).build();
        let job = CompileJob::builder()
            .model(zoo::build("alexnet", false).unwrap())
            .device(&ARRIA_10_GX1150)
            .explorer(Explorer::BruteForce)
            .batches([1, 16])
            .build()
            .unwrap();
        let outcome = session.run(&job).unwrap();
        let rep = outcome.synth_report().expect("1x1 view");
        // unconstrained throughput mode picks the largest batch — the
        // cross-frame weight reuse strictly grows frames/s here
        assert_eq!(rep.batch, 16);
        assert_eq!(rep.option(), Some((16, 32)), "winner matches latency mode");
        let choice = rep.throughput.as_ref().expect("throughput sweep on the entry");
        assert_eq!(choice.candidates.len(), 2);
        assert!(choice.slo_satisfied);
        assert_eq!(choice.chosen_batch(), 16);
        assert!(
            choice.candidates[1].frames_per_s > choice.candidates[0].frames_per_s,
            "B=16 serves more frames/s than B=1"
        );
        // the JSON document carries the new v3 sections
        let doc = outcome.to_json();
        let entry = &doc.get("entries").as_arr().unwrap()[0];
        assert_eq!(entry.get("batch").as_i64(), Some(16));
        assert_eq!(
            entry.get("throughput").get("chosen_batch").as_i64(),
            Some(16)
        );
        // a classic job reports batch 1 and no throughput section
        let classic = session
            .run(
                &CompileJob::builder()
                    .model(zoo::build("alexnet", false).unwrap())
                    .device(&ARRIA_10_GX1150)
                    .explorer(Explorer::BruteForce)
                    .build()
                    .unwrap(),
            )
            .unwrap();
        let base = classic.synth_report().unwrap();
        assert_eq!(base.batch, 1);
        assert!(base.throughput.is_none());
        assert_eq!(base.option(), rep.option());
    }

    #[test]
    fn quantization_errors_propagate_with_context() {
        let session = Session::builder().threads(2).build();
        let job = CompileJob::builder()
            .model(zoo::build("alexnet", false).unwrap()) // no weights
            .device(&ARRIA_10_GX1150)
            .explorer(Explorer::BruteForce)
            .quantize(QuantSpec::default())
            .build()
            .unwrap();
        let err = session.run(&job).unwrap_err();
        assert!(err.to_string().contains("quantization"));
    }

    #[test]
    fn outcome_json_round_trips_and_repeats_byte_identically() {
        let run = || {
            let session = Session::builder().threads(2).build();
            session
                .run(
                    &CompileJob::builder()
                        .model(zoo::build("tiny", false).unwrap())
                        .all_devices()
                        .explorer(Explorer::BruteForce)
                        .build()
                        .unwrap(),
                )
                .unwrap()
                .to_json()
        };
        let doc = run();
        assert_eq!(doc.get("format").as_str(), Some(OUTCOME_FORMAT));
        assert_eq!(doc.get("version").as_i64(), Some(OUTCOME_VERSION));
        let text = doc.to_string_pretty();
        let parsed = Json::parse(&text).expect("outcome JSON parses");
        assert_eq!(parsed, doc, "document round-trips through the codec");
        assert_eq!(parsed.to_string_pretty(), text);
        // volatile fields (wall clocks, steals, memo counters) are
        // excluded, so a second independent run emits identical bytes
        assert_eq!(run().to_string_pretty(), text);
    }
}
