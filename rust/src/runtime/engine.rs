//! PJRT execution engine — the emulation-mode substrate (paper §4.2).
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. HLO *text*
//! is the interchange format (the crate's xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos — see /opt/xla-example/README.md).
//!
//! Python never runs here: the artifacts were lowered once at build time
//! and this module is the only thing the request path touches.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::ir::DType;

use super::artifacts::{ModelArtifact, Tensor};

/// A PJRT CPU runtime holding the client and compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled model ready to execute.
pub struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    /// Parameter count expected (input + weights).
    pub arity: usize,
    pub name: String,
}

impl Runtime {
    /// Whether this build carries a real PJRT backend (`pjrt` feature).
    pub fn available() -> bool {
        true
    }

    /// Create the CPU PJRT client (once per process).
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text module.
    pub fn load_hlo_text(&self, path: &Path, name: &str, arity: usize) -> Result<Compiled> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        Ok(Compiled {
            exe,
            arity,
            name: name.to_string(),
        })
    }

    /// Load a model artifact (input + params arity from the manifest).
    pub fn load_artifact(&self, art: &ModelArtifact) -> Result<Compiled> {
        self.load_hlo_text(&art.hlo_path, &art.name, 1 + art.params.len())
    }
}

/// Build a PJRT literal from a tensor (f32 passthrough; i32 carries int8
/// codes widened at the AOT boundary — see aot.py).
pub fn literal_of(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t {
        Tensor::F32(_, data) => xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape f32 literal: {e}"))?,
        Tensor::I32(_, data) => xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape i32 literal: {e}"))?,
    };
    Ok(lit)
}

/// Result of one execution.
#[derive(Debug, Clone)]
pub struct RunOutput {
    pub tensor: Tensor,
    pub exec_seconds: f64,
}

impl Compiled {
    /// Execute with the given inputs; unwraps the 1-tuple the AOT path
    /// emits (`return_tuple=True`).
    pub fn run(&self, inputs: &[Tensor], out_dtype: DType) -> Result<RunOutput> {
        if inputs.len() != self.arity {
            bail!(
                "model '{}' expects {} inputs, got {}",
                self.name,
                self.arity,
                inputs.len()
            );
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(literal_of)
            .collect::<Result<_>>()
            .context("building literals")?;
        let t0 = Instant::now();
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing '{}': {e}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e}"))?;
        let exec_seconds = t0.elapsed().as_secs_f64();
        let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
        let shape = out
            .array_shape()
            .map_err(|e| anyhow!("result shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let tensor = match out_dtype {
            DType::F32 => Tensor::F32(
                dims,
                out.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))?,
            ),
            DType::I32 | DType::I8 => Tensor::I32(
                dims,
                out.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))?,
            ),
        };
        Ok(RunOutput {
            tensor,
            exec_seconds,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::{load_golden, Manifest};
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn tiny_golden_replays_through_pjrt() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let art = manifest.model("tiny").unwrap();
        let golden = load_golden(art.golden.as_ref().unwrap()).unwrap();
        let rt = Runtime::cpu().unwrap();
        let compiled = rt.load_artifact(art).unwrap();
        let mut inputs = vec![golden.input.clone()];
        inputs.extend(golden.params.iter().cloned());
        let out = compiled.run(&inputs, DType::F32).unwrap();
        let got = out.tensor.as_f32().unwrap();
        let expect = golden.expected.as_f32().unwrap();
        assert_eq!(got.len(), expect.len());
        for (g, e) in got.iter().zip(expect) {
            assert!((g - e).abs() < 1e-5, "mismatch {g} vs {e}");
        }
    }

    #[test]
    fn tiny_int8_golden_replays_exactly() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let art = manifest.model("tiny_int8").unwrap();
        let golden = load_golden(art.golden.as_ref().unwrap()).unwrap();
        let rt = Runtime::cpu().unwrap();
        let compiled = rt.load_artifact(art).unwrap();
        let mut inputs = vec![golden.input.clone()];
        inputs.extend(golden.params.iter().cloned());
        let out = compiled.run(&inputs, DType::I32).unwrap();
        assert_eq!(
            out.tensor.as_i32().unwrap(),
            golden.expected.as_i32().unwrap(),
            "fixed-point path must be bit-exact"
        );
    }

    #[test]
    fn wrong_arity_is_rejected() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let art = manifest.model("tiny").unwrap();
        let rt = Runtime::cpu().unwrap();
        let compiled = rt.load_artifact(art).unwrap();
        let err = compiled
            .run(&[Tensor::F32(vec![1], vec![0.0])], DType::F32)
            .unwrap_err();
        assert!(err.to_string().contains("expects"));
    }
}
