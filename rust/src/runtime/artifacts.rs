//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! (build time) and the Rust runtime (request path).
//!
//! `artifacts/manifest.json` indexes one HLO-text module per model
//! variant plus optional golden dumps (input + params + expected output)
//! that the integration tests replay bit-for-bit through PJRT.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::ir::DType;
use crate::util::json::Json;

pub const FORMAT: &str = "cnn2gate-artifacts-v1";

/// Shape + dtype of one PJRT parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One array inside a golden dump.
#[derive(Debug, Clone, PartialEq)]
pub struct GoldenArray {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub offset: usize,
}

/// Golden dump descriptor.
#[derive(Debug, Clone, PartialEq)]
pub struct Golden {
    pub file: PathBuf,
    pub nbytes: usize,
    pub arrays: Vec<GoldenArray>,
}

/// Decoded golden data: input, params (in declared order), expected output.
#[derive(Debug, Clone)]
pub struct GoldenData {
    pub input: Tensor,
    pub params: Vec<Tensor>,
    pub expected: Tensor,
}

/// A concrete tensor loaded from a golden file (f32 or i32 payload).
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(s, _) | Tensor::I32(s, _) => s,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32(_, d) => Some(d),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Tensor::I32(_, d) => Some(d),
            _ => None,
        }
    }
}

/// Manifest entry for one compiled model variant.
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    pub name: String,
    pub hlo_path: PathBuf,
    pub input: ParamSpec,
    pub params: Vec<ParamSpec>,
    pub golden: Option<Golden>,
    /// Quantization config when this is an int8 variant.
    pub quantization: Option<(i8, i8, i8)>, // (m_in, m_w, m_out)
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub ni: usize,
    pub nl: usize,
    pub models: Vec<ModelArtifact>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let doc = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        if doc.get("format").as_str() != Some(FORMAT) {
            bail!("unsupported manifest format {:?}", doc.get("format").as_str());
        }
        let mut models = Vec::new();
        let obj = doc
            .get("models")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest missing models"))?;
        for (name, entry) in obj.iter() {
            models.push(parse_entry(dir, name, entry)?);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            ni: doc.get("ni").as_usize().unwrap_or(16),
            nl: doc.get("nl").as_usize().unwrap_or(32),
            models,
        })
    }

    pub fn model(&self, name: &str) -> Option<&ModelArtifact> {
        self.models.iter().find(|m| m.name == name)
    }
}

fn parse_spec(name: &str, v: &Json) -> Result<ParamSpec> {
    Ok(ParamSpec {
        name: name.to_string(),
        shape: v
            .get("shape")
            .as_usize_vec()
            .ok_or_else(|| anyhow!("spec '{name}' missing shape"))?,
        dtype: DType::parse(v.get("dtype").as_str().unwrap_or("float32"))
            .ok_or_else(|| anyhow!("spec '{name}' bad dtype"))?,
    })
}

fn parse_entry(dir: &Path, name: &str, entry: &Json) -> Result<ModelArtifact> {
    let hlo = entry
        .get("hlo")
        .as_str()
        .ok_or_else(|| anyhow!("model '{name}' missing hlo"))?;
    let input = parse_spec("input", entry.get("input"))?;
    let mut params = Vec::new();
    for p in entry.get("params").as_arr().unwrap_or(&[]) {
        let pname = p.get("name").as_str().unwrap_or("param");
        params.push(parse_spec(pname, p)?);
    }
    let golden = if entry.get("golden").is_null() {
        None
    } else {
        let g = entry.get("golden");
        let mut arrays = Vec::new();
        for a in g.get("arrays").as_arr().unwrap_or(&[]) {
            arrays.push(GoldenArray {
                name: a.get("name").as_str().unwrap_or("").to_string(),
                shape: a.get("shape").as_usize_vec().unwrap_or_default(),
                dtype: DType::parse(a.get("dtype").as_str().unwrap_or("float32"))
                    .ok_or_else(|| anyhow!("golden array bad dtype"))?,
                offset: a.get("offset").as_usize().unwrap_or(0),
            });
        }
        Some(Golden {
            file: dir.join(g.get("file").as_str().unwrap_or("")),
            nbytes: g.get("nbytes").as_usize().unwrap_or(0),
            arrays,
        })
    };
    let quantization = if entry.get("quantization").is_null() {
        None
    } else {
        let q = entry.get("quantization");
        Some((
            q.get("m_in").as_i64().unwrap_or(4) as i8,
            q.get("m_w").as_i64().unwrap_or(6) as i8,
            q.get("m_out").as_i64().unwrap_or(4) as i8,
        ))
    };
    Ok(ModelArtifact {
        name: name.to_string(),
        hlo_path: dir.join(hlo),
        input,
        params,
        golden,
        quantization,
    })
}

/// Load and slice a golden dump into concrete tensors.
pub fn load_golden(g: &Golden) -> Result<GoldenData> {
    let bytes = std::fs::read(&g.file)
        .with_context(|| format!("reading golden {}", g.file.display()))?;
    if bytes.len() != g.nbytes {
        bail!(
            "golden {}: expected {} bytes, found {}",
            g.file.display(),
            g.nbytes,
            bytes.len()
        );
    }
    let mut tensors = Vec::new();
    for a in &g.arrays {
        let numel: usize = a.shape.iter().product();
        let size = numel * a.dtype.size_bytes();
        let end = a
            .offset
            .checked_add(size)
            .filter(|&e| e <= bytes.len())
            .ok_or_else(|| anyhow!("golden array '{}' out of bounds", a.name))?;
        let chunk = &bytes[a.offset..end];
        let t = match a.dtype {
            DType::F32 => Tensor::F32(
                a.shape.clone(),
                chunk
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            DType::I32 => Tensor::I32(
                a.shape.clone(),
                chunk
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            DType::I8 => Tensor::I32(
                a.shape.clone(),
                chunk.iter().map(|&b| b as i8 as i32).collect(),
            ),
        };
        tensors.push(t);
    }
    let Some(expected) = tensors.pop() else {
        bail!("golden must contain at least input and output");
    };
    if tensors.is_empty() {
        bail!("golden must contain at least input and output");
    }
    let input = tensors.remove(0);
    Ok(GoldenData {
        input,
        params: tensors,
        expected,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_manifest_when_present() {
        let Some(dir) = repo_artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model("tiny").is_some());
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.input.shape, vec![1, 8, 8]);
        assert!(tiny.hlo_path.exists());
        assert!(tiny.golden.is_some());
    }

    #[test]
    fn golden_roundtrip_when_present() {
        let Some(dir) = repo_artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let tiny = m.model("tiny").unwrap();
        let g = load_golden(tiny.golden.as_ref().unwrap()).unwrap();
        assert_eq!(g.input.shape(), &[1, 8, 8]);
        assert_eq!(g.params.len(), tiny.params.len());
        // tiny ends in softmax: expected output sums to 1
        let out = g.expected.as_f32().unwrap();
        let sum: f32 = out.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "softmax sum {sum}");
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
