//! Emulation-mode runtime: loads the AOT-compiled JAX/Pallas HLO-text
//! artifacts and executes them on the PJRT CPU client. Python is never
//! on this path — `make artifacts` ran once at build time.
//!
//! The real PJRT backend needs the `xla` bindings crate from the offline
//! image and is gated behind the `pjrt` cargo feature; the default build
//! substitutes an API-identical stub whose `Runtime::cpu()` returns a
//! descriptive error, so every artifact-dependent test and subcommand
//! degrades to the same "skipping: run `make artifacts`" path it already
//! takes when the artifacts directory is absent.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;

pub use artifacts::{load_golden, GoldenData, Manifest, ModelArtifact, ParamSpec, Tensor};
pub use engine::{literal_of, Compiled, RunOutput, Runtime};
