//! Emulation-mode runtime: loads the AOT-compiled JAX/Pallas HLO-text
//! artifacts and executes them on the PJRT CPU client. Python is never
//! on this path — `make artifacts` ran once at build time.

pub mod artifacts;
pub mod engine;

pub use artifacts::{load_golden, GoldenData, Manifest, ModelArtifact, ParamSpec, Tensor};
pub use engine::{literal_of, Compiled, RunOutput, Runtime};
