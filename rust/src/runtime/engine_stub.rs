//! Stub PJRT engine — compiled when the `pjrt` feature is off.
//!
//! Mirrors the public surface of `engine.rs` (Runtime, Compiled,
//! RunOutput, literal_of) so the coordinator, server, CLI and tests
//! compile unchanged in environments without the `xla` bindings crate.
//! Every entry point fails with [`UNAVAILABLE`], which callers already
//! treat the same way as missing artifacts: they skip.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::ir::DType;

use super::artifacts::{ModelArtifact, Tensor};

/// The error every stub entry point returns.
pub const UNAVAILABLE: &str = "PJRT runtime not built: add the `xla` bindings crate to \
     rust/Cargo.toml, then rebuild with `--features pjrt` (see README.md §Emulation mode)";

/// Stand-in for the PJRT CPU client. Cannot be constructed.
pub struct Runtime {
    _private: (),
}

/// Stand-in for a compiled executable. Cannot be constructed.
pub struct Compiled {
    pub arity: usize,
    pub name: String,
    _private: (),
}

/// Stand-in for `xla::Literal` in the [`literal_of`] signature.
pub struct Literal {
    _private: (),
}

impl Runtime {
    /// Whether this build carries a real PJRT backend. `false` here:
    /// artifact-gated tests, benches and table rows check this before
    /// treating an artifacts directory as runnable.
    pub fn available() -> bool {
        false
    }

    /// Always fails: the emulation backend is not part of this build.
    pub fn cpu() -> Result<Runtime> {
        Err(anyhow!(UNAVAILABLE))
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load_hlo_text(&self, _path: &Path, _name: &str, _arity: usize) -> Result<Compiled> {
        Err(anyhow!(UNAVAILABLE))
    }

    pub fn load_artifact(&self, _art: &ModelArtifact) -> Result<Compiled> {
        Err(anyhow!(UNAVAILABLE))
    }
}

/// Always fails in the stub build.
pub fn literal_of(_t: &Tensor) -> Result<Literal> {
    Err(anyhow!(UNAVAILABLE))
}

/// Result of one execution (same shape as the real engine's).
#[derive(Debug, Clone)]
pub struct RunOutput {
    pub tensor: Tensor,
    pub exec_seconds: f64,
}

impl Compiled {
    pub fn run(&self, _inputs: &[Tensor], _out_dtype: DType) -> Result<RunOutput> {
        Err(anyhow!(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = Runtime::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn literal_of_is_total() {
        let t = Tensor::F32(vec![2], vec![1.0, 2.0]);
        assert!(literal_of(&t).is_err());
    }
}
