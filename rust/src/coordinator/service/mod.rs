//! The compile service: a long-lived in-process daemon that accepts
//! concurrent [`CompileJob`](crate::session::CompileJob)s — and,
//! optionally, classify requests against a compiled artifact —
//! multiplexed onto ONE shared [`Evaluator`] / work-stealing scheduler,
//! streaming typed progress events to each client.
//!
//! Layering (each layer only knows the one below):
//!
//! * [`ports`] — the typed [`Command`]/[`Event`] vocabulary and the
//!   client handles ([`ServiceClient`], [`JobTicket`]).
//! * [`kernel`] — pure state transitions and the admission/fairness
//!   policy (no channels, no threads; unit-tested in isolation).
//! * `orchestrator` — the daemon thread: bounded-queue admission,
//!   per-tenant fair launch order, job runners on the shared evaluator,
//!   and the PJRT inference lane.
//! * [`reducer`] — the reducer-owned job-state store with a replayable
//!   event log ([`Reducer::replay`] reconstructs the exact final store).
//!
//! Sharing one evaluator means every job — regardless of tenant — funds
//! the same memo: two tenants compiling the same model at the same
//! fidelity still occupy distinct cache namespaces (the tenant id is
//! folded into the evaluation memo key's fingerprint), so
//! eviction pressure and persistence are shared while lookups never
//! cross tenants. Because the engine prewarms a job's FULL option grid
//! before exploring, concurrent jobs interleaved on the shared cache
//! still render outcome documents byte-identical to a solo
//! [`Session::run`](crate::session::Session::run) — the property the
//! service determinism tests pin.
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use cnn2gate::coordinator::service::{CompileService, JobSpec, ServiceConfig};
//! use cnn2gate::onnx::zoo;
//! use cnn2gate::session::CompileJob;
//!
//! let service = CompileService::start(ServiceConfig::default());
//! let job = CompileJob::builder().model(zoo::build("tiny", false)?).build()?;
//! let ticket = service.submit(JobSpec::new(job))?;
//! let completion = ticket.wait()?;
//! println!("{:?}", completion.outcome_json());
//! let report = service.shutdown();
//! assert_eq!(report.reducer.open_jobs(), 0);
//! # Ok(())
//! # }
//! ```

pub mod kernel;
mod orchestrator;
pub mod ports;
pub mod reducer;

use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::dse::{eval, EvalCache, Evaluator};
use crate::ir::DType;
use crate::runtime::{ModelArtifact, Tensor};

use orchestrator::{InferLane, Msg};

pub use kernel::JobState;
pub use ports::{
    Command, Completion, Event, InferReply, InferStats, JobId, JobSpec, JobTicket, ServiceClient,
};
pub use reducer::{JobRecord, Reducer};

/// Service sizing knobs (admission control + the shared evaluator +
/// the optional inference lane).
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    /// Compile jobs allowed to run concurrently (worker slots).
    pub workers: usize,
    /// Bounded admission queue: submissions beyond this many *queued*
    /// jobs are [`Event::Rejected`] instead of enqueued.
    pub queue_capacity: usize,
    /// Threads for the shared evaluator pool (0 = one per core).
    pub threads: usize,
    /// Most inference requests fused into one PJRT dispatch.
    pub max_batch: usize,
    /// Bounded inference queue depth (back-pressure on classify
    /// clients).
    pub infer_queue_depth: usize,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            workers: 2,
            queue_capacity: 64,
            threads: 0,
            max_batch: 8,
            infer_queue_depth: 64,
        }
    }
}

/// What [`CompileService::shutdown`] returns: the reducer's final state
/// (event log + job records) and, when the inference lane ran, its
/// latency statistics.
#[derive(Debug)]
pub struct ServiceReport {
    /// Final job store; `Reducer::replay(report.reducer.log())`
    /// reconstructs it exactly.
    pub reducer: Reducer,
    /// Inference-lane statistics, when one was started.
    pub infer: Option<InferStats>,
}

/// The running service: owns the daemon thread, the shared evaluator,
/// and (optionally) the inference lane. Dropping it shuts everything
/// down; call [`CompileService::shutdown`] instead to keep the final
/// [`ServiceReport`].
pub struct CompileService {
    tx: mpsc::Sender<Msg>,
    daemon: Option<JoinHandle<()>>,
    evaluator: Arc<Evaluator>,
    infer: Option<InferLane>,
}

impl CompileService {
    /// Start the daemon with compile lanes only.
    pub fn start(cfg: ServiceConfig) -> CompileService {
        CompileService::start_with_cache(cfg, Arc::new(EvalCache::new()))
    }

    /// Start the daemon with its shared evaluator seeded from an
    /// existing memo — e.g. a session's store-backed cache, so `serve`
    /// compile jobs hit entries persisted by earlier CLI sweeps.
    pub fn start_with_cache(cfg: ServiceConfig, cache: Arc<EvalCache>) -> CompileService {
        let threads = if cfg.threads == 0 {
            eval::default_threads()
        } else {
            cfg.threads
        };
        let evaluator = Arc::new(Evaluator::with_cache(threads, cache));
        let (tx, daemon) = orchestrator::spawn(cfg, Arc::clone(&evaluator));
        CompileService {
            tx,
            daemon: Some(daemon),
            evaluator,
            infer: None,
        }
    }

    /// Start the daemon AND the emulation-inference lane serving
    /// `art` with fixed `weights` (one tensor per artifact parameter).
    /// Fails when the artifact cannot be compiled — with the worker
    /// joined, not leaked, on the failure path.
    pub fn start_with_inference(
        cfg: ServiceConfig,
        art: &ModelArtifact,
        weights: Vec<Tensor>,
    ) -> Result<CompileService> {
        CompileService::start_with_inference_cached(cfg, art, weights, Arc::new(EvalCache::new()))
    }

    /// [`CompileService::start_with_inference`] with the seeded memo of
    /// [`CompileService::start_with_cache`]: both lanes come up, and
    /// compile jobs run against the caller's cache handle.
    pub fn start_with_inference_cached(
        cfg: ServiceConfig,
        art: &ModelArtifact,
        weights: Vec<Tensor>,
        cache: Arc<EvalCache>,
    ) -> Result<CompileService> {
        let lane = InferLane::start(&cfg, art, weights)?;
        let mut service = CompileService::start_with_cache(cfg, cache);
        service.infer = Some(lane);
        Ok(service)
    }

    /// A cheap, cloneable submission handle (for client threads).
    pub fn client(&self) -> ServiceClient {
        ServiceClient { tx: self.tx.clone() }
    }

    /// Submit a job and block until the admission decision (see
    /// [`ServiceClient::submit`]).
    pub fn submit(&self, spec: JobSpec) -> Result<JobTicket> {
        self.client().submit(spec)
    }

    /// Request cancellation of a queued or running job.
    pub fn cancel(&self, job: JobId) -> Result<()> {
        self.client().cancel(job)
    }

    /// Classify one input on the inference lane (blocking).
    pub fn infer(&self, input: Tensor) -> Result<InferReply> {
        self.infer
            .as_ref()
            .ok_or_else(|| anyhow!("inference lane not started (use start_with_inference)"))?
            .infer(input)
    }

    /// Output dtype the inference lane produces, when one is running
    /// (I32 for quantized artifacts, F32 otherwise).
    pub fn out_dtype(&self) -> Option<DType> {
        self.infer.as_ref().map(InferLane::out_dtype)
    }

    /// The shared evaluator every compile job runs on (e.g. to persist
    /// its memo with [`EvalCache::save`](crate::dse::EvalCache::save)).
    pub fn evaluator(&self) -> &Evaluator {
        &self.evaluator
    }

    /// Graceful shutdown: stop admitting, cancel queued jobs, drain
    /// running ones, stop the inference lane, and return the final
    /// [`ServiceReport`].
    pub fn shutdown(mut self) -> ServiceReport {
        let reducer = self.stop_daemon();
        let infer = self.infer.take().map(InferLane::shutdown);
        ServiceReport { reducer, infer }
    }

    /// Send `Shutdown`, wait for the reducer snapshot, join the daemon.
    fn stop_daemon(&mut self) -> Reducer {
        let Some(daemon) = self.daemon.take() else {
            return Reducer::new();
        };
        let (reply_tx, reply_rx) = mpsc::channel();
        let _ = self.tx.send(Msg::Command(Command::Shutdown { reply: reply_tx }));
        let reducer = reply_rx.recv().unwrap_or_default();
        let _ = daemon.join();
        reducer
    }
}

impl Drop for CompileService {
    fn drop(&mut self) {
        if self.daemon.is_some() {
            let _ = self.stop_daemon();
        }
        // InferLane's own Drop closes and joins its worker
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::TenantId;
    use crate::estimator::device::ARRIA_10_GX1150;
    use crate::onnx::zoo;
    use crate::session::CompileJob;
    use crate::synth::Explorer;
    use std::time::Instant;

    fn spec_for(model: &str, tenant: &str) -> JobSpec {
        let job = CompileJob::builder()
            .model(zoo::build(model, false).unwrap())
            .device(&ARRIA_10_GX1150)
            .explorer(Explorer::BruteForce)
            .build()
            .unwrap();
        JobSpec::new(job).tenant(TenantId::of(tenant))
    }

    fn tiny_spec(tenant: &str) -> JobSpec {
        spec_for("tiny", tenant)
    }

    fn small_cfg() -> ServiceConfig {
        ServiceConfig {
            workers: 2,
            threads: 2,
            ..ServiceConfig::default()
        }
    }

    #[test]
    fn service_runs_jobs_and_streams_ordered_events() {
        let service = CompileService::start(small_cfg());
        let a = service.submit(tiny_spec("acme")).unwrap();
        let b = service.submit(tiny_spec("zen")).unwrap();
        assert_ne!(a.id(), b.id(), "ids are unique");

        // drain a's stream by hand: Started, Progress (monotone, ending
        // at total), then exactly one terminal
        let mut saw_started = false;
        let mut last = 0usize;
        let mut total = 0usize;
        loop {
            let event = a.recv().unwrap();
            assert_eq!(event.job(), a.id(), "stream carries only this job's events");
            match event {
                Event::Started { .. } => saw_started = true,
                Event::Progress { scored, total: t, .. } => {
                    assert!(saw_started, "progress only after start");
                    assert!(scored > last, "progress is monotone");
                    last = scored;
                    total = t;
                }
                Event::Finished { outcome_json, .. } => {
                    assert!(saw_started);
                    assert_eq!(last, total, "final progress covered the whole grid");
                    assert!(outcome_json.contains("\"models\""), "terminal carries the document");
                    break;
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
        assert!(matches!(b.wait().unwrap(), Completion::Finished { .. }));

        let report = service.shutdown();
        assert!(report.infer.is_none());
        let reducer = &report.reducer;
        assert_eq!(reducer.open_jobs(), 0);
        assert_eq!(reducer.jobs().count(), 2);
        assert!(reducer.jobs().all(|(_, r)| r.state == JobState::Finished));
        assert_eq!(&Reducer::replay(reducer.log()), reducer);
    }

    #[test]
    fn shutdown_cancels_queued_jobs_and_drop_is_clean() {
        // one worker, so the second submission is still queued when
        // shutdown arrives (vgg16's grid keeps the worker busy)
        let cfg = ServiceConfig {
            workers: 1,
            threads: 2,
            ..ServiceConfig::default()
        };
        let service = CompileService::start(cfg);
        let running = service.submit(spec_for("vgg16", "acme")).unwrap();
        let queued = service.submit(tiny_spec("acme")).unwrap();
        let report = service.shutdown();
        // the running job drained to completion; the queued one was
        // cancelled without ever starting
        assert!(matches!(running.wait().unwrap(), Completion::Finished { .. }));
        assert_eq!(queued.wait().unwrap(), Completion::Cancelled);
        let record = report.reducer.get(queued.id()).unwrap();
        assert_eq!(record.state, JobState::Cancelled);
        assert!(record.outcome_json.is_none());

        // dropping without shutdown must not hang or leak
        let service = CompileService::start(small_cfg());
        let _ = service.submit(tiny_spec("zen")).unwrap();
        drop(service);
    }

    /// CI perf gate (`perf_smoke` name filter): a flood of queued tiny
    /// jobs across three tenants must drain promptly AND fairly — no
    /// tenant's jobs systematically finish later than another's.
    #[test]
    #[ignore]
    fn perf_smoke_service_drains_mixed_tenants_fairly() {
        const JOBS: usize = 120;
        let tenants = ["acme", "zen", "inst"];
        let cfg = ServiceConfig {
            workers: 4,
            queue_capacity: JOBS + 8,
            threads: 2,
            ..ServiceConfig::default()
        };
        let service = CompileService::start(cfg);
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..JOBS)
            .map(|i| service.submit(tiny_spec(tenants[i % tenants.len()])).unwrap())
            .collect();
        for t in &tickets {
            assert!(matches!(t.wait().unwrap(), Completion::Finished { .. }));
        }
        let wall = t0.elapsed().as_secs_f64();
        assert!(wall < 60.0, "{JOBS} tiny jobs drained in {wall:.1} s (gate: 60 s)");

        // fairness: completion order from the reducer log — each
        // tenant's mean finish rank should be close to the middle
        let report = service.shutdown();
        let mut rank = 0usize;
        let mut sums = std::collections::HashMap::new();
        for event in report.reducer.log() {
            if let Event::Finished { job, .. } = event {
                rank += 1;
                let tenant = report.reducer.get(*job).unwrap().tenant.as_u64();
                let (sum, n) = sums.entry(tenant).or_insert((0usize, 0usize));
                *sum += rank;
                *n += 1;
            }
        }
        assert_eq!(rank, JOBS, "every job finished");
        let means: Vec<f64> = sums.values().map(|(sum, n)| *sum as f64 / *n as f64).collect();
        let worst = means.iter().cloned().fold(f64::MIN, f64::max);
        let best = means.iter().cloned().fold(f64::MAX, f64::min);
        assert!(
            worst / best < 1.5,
            "per-tenant mean finish ranks stay balanced ({best:.1} vs {worst:.1})"
        );
    }
}
