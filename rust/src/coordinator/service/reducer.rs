//! The reducer-owned job-state store: every lifecycle event the
//! orchestrator emits is folded through [`kernel::step`] into one
//! [`JobRecord`] per job AND appended to a replayable log. The log is
//! the source of truth — [`Reducer::replay`] over [`Reducer::log`]
//! reconstructs the exact final store (pinned by the service tests) —
//! so the store can never drift from the events clients observed.
//!
//! Progress events are deliberately kept out of the reducer: they are
//! volume (one per engine work item), they never change job state
//! ([`kernel::step`] ignores them), and logging them would make the
//! replay log size depend on grid sizes rather than job count.

use std::collections::BTreeMap;

use crate::dse::TenantId;

use super::kernel::{self, JobState};
use super::ports::{Event, JobId};

/// The reducer's materialized view of one job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    /// Tenant the job was submitted under.
    pub tenant: TenantId,
    /// Current lifecycle state.
    pub state: JobState,
    /// The outcome document, once [`JobState::Finished`].
    pub outcome_json: Option<String>,
    /// The error chain, once [`JobState::Failed`] (or the admission
    /// reason, once [`JobState::Rejected`]).
    pub error: Option<String>,
}

/// Event log + job store. [`Reducer::apply`] is the only mutation path,
/// so `replay(r.log()) == r` holds by construction — the equality the
/// service determinism tests assert end-to-end.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Reducer {
    log: Vec<Event>,
    jobs: BTreeMap<u64, JobRecord>,
}

impl Reducer {
    /// An empty store.
    pub fn new() -> Reducer {
        Reducer::default()
    }

    /// Fold one event into the store and append it to the log.
    pub fn apply(&mut self, event: &Event) {
        self.log.push(event.clone());
        match event {
            Event::Accepted { job, tenant, .. } => {
                self.jobs.insert(
                    job.0,
                    JobRecord {
                        tenant: *tenant,
                        state: JobState::Queued,
                        outcome_json: None,
                        error: None,
                    },
                );
            }
            Event::Rejected { job, tenant, reason } => {
                self.jobs.insert(
                    job.0,
                    JobRecord {
                        tenant: *tenant,
                        state: JobState::Rejected,
                        outcome_json: None,
                        error: Some(reason.clone()),
                    },
                );
            }
            _ => {
                let Some(record) = self.jobs.get_mut(&event.job().0) else {
                    return; // event for a job we never admitted: ignore
                };
                let next = kernel::step(record.state, event);
                match (next, event) {
                    (JobState::Finished, Event::Finished { outcome_json, .. }) => {
                        record.outcome_json = Some(outcome_json.clone());
                    }
                    (JobState::Failed, Event::Failed { error, .. }) => {
                        record.error = Some(error.clone());
                    }
                    _ => {}
                }
                record.state = next;
            }
        }
    }

    /// Rebuild a store from scratch by replaying an event log.
    pub fn replay(events: &[Event]) -> Reducer {
        let mut reducer = Reducer::new();
        for event in events {
            reducer.apply(event);
        }
        reducer
    }

    /// The append-only event log, in emission order.
    pub fn log(&self) -> &[Event] {
        &self.log
    }

    /// The record for one job, if it was ever admitted or rejected.
    pub fn get(&self, job: JobId) -> Option<&JobRecord> {
        self.jobs.get(&job.0)
    }

    /// All job records in id order.
    pub fn jobs(&self) -> impl Iterator<Item = (JobId, &JobRecord)> {
        self.jobs.iter().map(|(&id, record)| (JobId(id), record))
    }

    /// Jobs currently in a non-terminal state.
    pub fn open_jobs(&self) -> usize {
        self.jobs.values().filter(|r| !r.state.is_terminal()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn accepted(id: u64, tenant: &str) -> Event {
        Event::Accepted {
            job: JobId(id),
            tenant: TenantId::of(tenant),
            queue_depth: 0,
        }
    }

    #[test]
    fn reducer_folds_a_lifecycle_and_replays_exactly() {
        let mut r = Reducer::new();
        r.apply(&accepted(0, "acme"));
        r.apply(&accepted(1, "zen"));
        r.apply(&Event::Started { job: JobId(0) });
        r.apply(&Event::Finished {
            job: JobId(0),
            outcome_json: "{\"ok\":true}".into(),
        });
        r.apply(&Event::Cancelled { job: JobId(1) });
        r.apply(&Event::Rejected {
            job: JobId(2),
            tenant: TenantId::of("acme"),
            reason: "queue full".into(),
        });

        let done = r.get(JobId(0)).unwrap();
        assert_eq!(done.state, JobState::Finished);
        assert_eq!(done.outcome_json.as_deref(), Some("{\"ok\":true}"));
        assert_eq!(done.tenant, TenantId::of("acme"));
        assert_eq!(r.get(JobId(1)).unwrap().state, JobState::Cancelled);
        let rejected = r.get(JobId(2)).unwrap();
        assert_eq!(rejected.state, JobState::Rejected);
        assert_eq!(rejected.error.as_deref(), Some("queue full"));
        assert_eq!(r.open_jobs(), 0);
        assert_eq!(r.jobs().count(), 3);

        // the log IS the store: replaying it reconstructs equality
        assert_eq!(Reducer::replay(r.log()), r);
        assert_eq!(r.log().len(), 6);
    }

    #[test]
    fn reducer_ignores_events_for_unknown_jobs_and_late_events() {
        let mut r = Reducer::new();
        r.apply(&Event::Started { job: JobId(9) }); // never admitted
        assert!(r.get(JobId(9)).is_none());
        r.apply(&accepted(3, "acme"));
        r.apply(&Event::Started { job: JobId(3) });
        r.apply(&Event::Cancelled { job: JobId(3) });
        // a straggler Finished after cancellation changes nothing
        r.apply(&Event::Finished {
            job: JobId(3),
            outcome_json: "{}".into(),
        });
        let rec = r.get(JobId(3)).unwrap();
        assert_eq!(rec.state, JobState::Cancelled);
        assert!(rec.outcome_json.is_none());
        assert_eq!(Reducer::replay(r.log()), r);
    }
}
