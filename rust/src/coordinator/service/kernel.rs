//! The service's pure decision core: job-state transitions and the
//! admission/fairness policy, as plain functions over plain data — no
//! channels, no threads, no clocks — so every scheduling decision the
//! orchestrator makes is unit-testable in isolation.

use std::collections::HashMap;

use crate::dse::{OptionSpace, TenantId};
use crate::ir::ComputationFlow;
use crate::session::CompileJob;

use super::ports::{Event, JobId};

/// Where one job is in its lifecycle. Transitions are driven purely by
/// [`Event`]s via [`step`]; [`Rejected`](JobState::Rejected),
/// [`Finished`](JobState::Finished), [`Failed`](JobState::Failed) and
/// [`Cancelled`](JobState::Cancelled) are terminal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Admitted, waiting for a worker slot.
    Queued,
    /// Executing on the shared evaluator.
    Running,
    /// Completed with an outcome document.
    Finished,
    /// Errored.
    Failed,
    /// Cancelled while queued or running.
    Cancelled,
    /// Turned away by admission control.
    Rejected,
}

impl JobState {
    /// True once no further transition is possible.
    pub fn is_terminal(self) -> bool {
        !matches!(self, JobState::Queued | JobState::Running)
    }
}

/// The pure transition function: the state a job is in after `event`,
/// given it was in `state`. Progress events and out-of-order lifecycle
/// events leave the state unchanged, so replaying any event log is
/// total (never panics) and idempotent on terminal states.
pub fn step(state: JobState, event: &Event) -> JobState {
    match (state, event) {
        (_, Event::Accepted { .. }) => JobState::Queued,
        (_, Event::Rejected { .. }) => JobState::Rejected,
        (JobState::Queued, Event::Started { .. }) => JobState::Running,
        (JobState::Running, Event::Finished { .. }) => JobState::Finished,
        (JobState::Running, Event::Failed { .. }) => JobState::Failed,
        (JobState::Queued | JobState::Running, Event::Cancelled { .. }) => JobState::Cancelled,
        (state, _) => state,
    }
}

/// What the fairness policy sees of one queued job.
#[derive(Debug, Clone, Copy)]
pub struct QueueView {
    /// Admission order (the [`JobId`] sequence number).
    pub seq: u64,
    /// Tenant the job will run under.
    pub tenant: TenantId,
    /// Estimated work ([`job_cost`]).
    pub cost: u64,
}

/// Pick the queued job to launch next, or `None` on an empty queue.
///
/// Cross-tenant fairness first, size second, age last: minimize
/// `(running jobs of the tenant, jobs already served for the tenant,
/// estimated cost, admission order)`. A tenant that floods the queue
/// therefore cannot starve others — each completion advances its
/// `served` count and hands the next slot to the least-served tenant —
/// and within a tenant small (interactive) jobs jump big ones while
/// equal-cost jobs stay FIFO. Deterministic for a given queue + counts.
pub fn pick_next(
    queue: &[QueueView],
    running: &HashMap<u64, usize>,
    served: &HashMap<u64, usize>,
) -> Option<usize> {
    queue
        .iter()
        .enumerate()
        .min_by_key(|(_, q)| {
            let tenant = q.tenant.as_u64();
            (
                running.get(&tenant).copied().unwrap_or(0),
                served.get(&tenant).copied().unwrap_or(0),
                q.cost,
                q.seq,
            )
        })
        .map(|(i, _)| i)
}

/// Estimated work of a job: Σ over its models of the option-grid size,
/// times the device count — the number of candidate evaluations the
/// engine will prewarm, which is what actually costs time. Models whose
/// flow cannot be extracted sort last (they fail fast at run time, so
/// deprioritizing them keeps real work flowing).
pub fn job_cost(job: &CompileJob) -> u64 {
    let grids: u64 = job
        .models
        .iter()
        .map(|g| match ComputationFlow::extract(g) {
            Ok(flow) => OptionSpace::from_flow(&flow).pairs().len() as u64,
            Err(_) => 1 << 20,
        })
        .sum();
    grids.saturating_mul(job.devices.len().max(1) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::device::ARRIA_10_GX1150;
    use crate::onnx::zoo;
    use crate::synth::Explorer;

    fn ev_started(id: u64) -> Event {
        Event::Started { job: JobId(id) }
    }

    #[test]
    fn step_walks_the_lifecycle_and_absorbs_noise() {
        let job = JobId(7);
        let accepted = Event::Accepted {
            job,
            tenant: TenantId::DEFAULT,
            queue_depth: 0,
        };
        let finished = Event::Finished {
            job,
            outcome_json: "{}".into(),
        };
        let failed = Event::Failed {
            job,
            error: "boom".into(),
        };
        let cancelled = Event::Cancelled { job };
        let progress = Event::Progress {
            job,
            scored: 1,
            total: 2,
        };

        let s = step(JobState::Queued, &ev_started(7));
        assert_eq!(s, JobState::Running);
        assert_eq!(step(s, &finished), JobState::Finished);
        assert_eq!(step(s, &failed), JobState::Failed);
        assert_eq!(step(s, &cancelled), JobState::Cancelled);
        assert_eq!(step(JobState::Queued, &cancelled), JobState::Cancelled);
        // progress never changes state; terminal states absorb everything
        assert_eq!(step(s, &progress), s);
        for terminal in [JobState::Finished, JobState::Failed, JobState::Cancelled] {
            assert!(terminal.is_terminal());
            assert_eq!(step(terminal, &ev_started(7)), terminal);
            assert_eq!(step(terminal, &cancelled), terminal);
        }
        // a fresh accept always lands in Queued, a reject in Rejected
        assert_eq!(step(JobState::Queued, &accepted), JobState::Queued);
        let rejected = Event::Rejected {
            job,
            tenant: TenantId::DEFAULT,
            reason: "queue full".into(),
        };
        assert_eq!(step(JobState::Queued, &rejected), JobState::Rejected);
        assert!(JobState::Rejected.is_terminal());
        assert!(!JobState::Queued.is_terminal());
        assert!(!JobState::Running.is_terminal());
    }

    fn view(seq: u64, tenant: &str, cost: u64) -> QueueView {
        QueueView {
            seq,
            tenant: TenantId::of(tenant),
            cost,
        }
    }

    #[test]
    fn pick_next_balances_tenants_before_size_before_age() {
        let acme = TenantId::of("acme").as_u64();
        let zen = TenantId::of("zen").as_u64();
        let queue = [view(0, "acme", 10), view(1, "acme", 10), view(2, "zen", 10)];
        // nothing running, nothing served: FIFO
        assert_eq!(pick_next(&queue, &HashMap::new(), &HashMap::new()), Some(0));
        // acme already has a job running: zen's job jumps the queue
        let running = HashMap::from([(acme, 1)]);
        assert_eq!(pick_next(&queue, &running, &HashMap::new()), Some(2));
        // equal running, but acme has been served more: zen goes first
        let served = HashMap::from([(acme, 5), (zen, 1)]);
        assert_eq!(pick_next(&queue, &HashMap::new(), &served), Some(2));
        // within one tenant, the small job jumps the big one
        let queue = [view(0, "acme", 100), view(1, "acme", 4)];
        assert_eq!(pick_next(&queue, &HashMap::new(), &HashMap::new()), Some(1));
        // ... and equal costs stay FIFO
        let queue = [view(3, "acme", 4), view(4, "acme", 4)];
        assert_eq!(pick_next(&queue, &HashMap::new(), &HashMap::new()), Some(0));
        assert_eq!(pick_next(&[], &HashMap::new(), &HashMap::new()), None);
    }

    #[test]
    fn job_cost_scales_with_grid_and_devices() {
        let tiny = CompileJob::builder()
            .model(zoo::build("tiny", false).unwrap())
            .device(&ARRIA_10_GX1150)
            .explorer(Explorer::BruteForce)
            .build()
            .unwrap();
        let vgg = CompileJob::builder()
            .model(zoo::build("vgg16", false).unwrap())
            .device(&ARRIA_10_GX1150)
            .explorer(Explorer::BruteForce)
            .build()
            .unwrap();
        let vgg_fleet = CompileJob::builder()
            .model(zoo::build("vgg16", false).unwrap())
            .all_devices()
            .explorer(Explorer::BruteForce)
            .build()
            .unwrap();
        assert!(job_cost(&tiny) >= 1);
        assert!(job_cost(&vgg) >= job_cost(&tiny), "bigger model, bigger cost");
        assert_eq!(
            job_cost(&vgg_fleet),
            job_cost(&vgg) * crate::estimator::device::all().len() as u64,
            "cost is per-device"
        );
    }
}
