//! The compile service's typed vocabulary: the [`Command`]s a client
//! can issue, the [`Event`]s the service streams back per job, and the
//! client-side handles ([`ServiceClient`], [`JobTicket`]) that wrap the
//! channel plumbing in a typed API.
//!
//! The protocol is deliberately small and explicit:
//!
//! * [`ServiceClient::submit`] sends [`Command::Submit`] and blocks
//!   until the admission decision — the FIRST event on the job's stream
//!   is always [`Event::Accepted`] or [`Event::Rejected`], so admission
//!   is synchronous even though execution is not.
//! * An accepted job streams [`Event::Started`], zero or more
//!   [`Event::Progress`] updates (one per engine work item scored), and
//!   exactly one terminal event: [`Event::Finished`] carrying the
//!   byte-stable [`Outcome::to_json`](crate::session::Outcome::to_json)
//!   document, [`Event::Failed`], or [`Event::Cancelled`].
//! * [`JobTicket::wait`] folds that stream into a [`Completion`].
//!
//! Everything here is transport-free (std `mpsc` channels, in-process);
//! the orchestrator behind the channel is
//! [`CompileService`](super::CompileService).

use std::fmt;
use std::sync::mpsc;

use anyhow::{anyhow, Result};

use crate::dse::{Fidelity, TenantId};
use crate::estimator::Thresholds;
use crate::metrics::LatencyStats;
use crate::runtime::Tensor;
use crate::session::CompileJob;

use super::orchestrator::Msg;
use super::reducer::Reducer;

/// Service-assigned job identity: a monotonically increasing sequence
/// number, unique for the service's lifetime (it doubles as the
/// admission-order tie-breaker in the fairness policy).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "job {}", self.0)
    }
}

/// Everything one compile job runs under: the [`CompileJob`] work spec
/// plus the per-job session knobs a [`Session`](crate::session::Session)
/// would carry (fidelity, census γ, thresholds) and the [`TenantId`]
/// cache namespace. Defaults mirror `Session::builder()`: analytical
/// fidelity, γ = 0, threshold-free fitting, default tenant.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Cache namespace the job's evaluations are keyed under.
    pub tenant: TenantId,
    /// The models × devices × explorer work spec.
    pub job: CompileJob,
    /// Fidelity every candidate is scored at.
    pub fidelity: Fidelity,
    /// Census-reward γ (0 = the paper's Algorithm 1).
    pub census_gamma: f64,
    /// Resource thresholds the explorers fit against.
    pub thresholds: Thresholds,
}

impl JobSpec {
    /// A spec with the default session knobs around `job`.
    pub fn new(job: CompileJob) -> JobSpec {
        JobSpec {
            tenant: TenantId::DEFAULT,
            job,
            fidelity: Fidelity::Analytical,
            census_gamma: 0.0,
            thresholds: Thresholds::default(),
        }
    }

    /// Run under this tenant's cache namespace.
    pub fn tenant(mut self, tenant: TenantId) -> JobSpec {
        self.tenant = tenant;
        self
    }

    /// Score candidates at this fidelity.
    pub fn fidelity(mut self, fidelity: Fidelity) -> JobSpec {
        self.fidelity = fidelity;
        self
    }

    /// Shape explorer rewards with this census γ.
    pub fn census_gamma(mut self, census_gamma: f64) -> JobSpec {
        self.census_gamma = census_gamma;
        self
    }

    /// Fit against these resource thresholds.
    pub fn thresholds(mut self, thresholds: Thresholds) -> JobSpec {
        self.thresholds = thresholds;
        self
    }
}

/// One client request to the service daemon.
#[derive(Debug)]
pub enum Command {
    /// Submit a compile job. The admission decision and every
    /// subsequent lifecycle/progress update arrive on `events`; the
    /// first event is always [`Event::Accepted`] or [`Event::Rejected`].
    Submit {
        /// The job and its session knobs.
        spec: JobSpec,
        /// Per-job event stream back to the client.
        events: mpsc::Sender<Event>,
    },
    /// Cancel a queued or running job. Queued jobs are removed
    /// immediately; running jobs stop cooperatively at the next engine
    /// checkpoint. Unknown or already-terminal ids are ignored.
    Cancel {
        /// The job to cancel.
        job: JobId,
    },
    /// Stop admitting, cancel the queue, drain running jobs, then reply
    /// with the reducer's final state (event log + job records).
    Shutdown {
        /// Receives the final [`Reducer`] snapshot.
        reply: mpsc::Sender<Reducer>,
    },
}

/// One typed progress/lifecycle update on a job's event stream. Every
/// variant names its job, so streams can be multiplexed or logged
/// as-is; the reducer records every variant except [`Event::Progress`]
/// (volume) and can replay the log into the exact final job store.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// The job passed admission control and is queued.
    Accepted {
        /// The service-assigned id.
        job: JobId,
        /// The tenant it will run under.
        tenant: TenantId,
        /// Jobs already queued ahead of it at admission time.
        queue_depth: usize,
    },
    /// Admission control turned the job away (bounded queue, shutdown).
    Rejected {
        /// The id the submission would have had.
        job: JobId,
        /// The tenant that submitted it.
        tenant: TenantId,
        /// Why it was turned away.
        reason: String,
    },
    /// The job left the queue and is executing on the shared evaluator.
    Started {
        /// The job that started.
        job: JobId,
    },
    /// Engine progress: `scored` of `total` work items (prewarm chunks +
    /// explored pairs) are done.
    Progress {
        /// The job making progress.
        job: JobId,
        /// Work items completed so far.
        scored: usize,
        /// Total work items in the job.
        total: usize,
    },
    /// Terminal: the job completed; `outcome_json` is the byte-stable
    /// [`Outcome::to_json`](crate::session::Outcome::to_json) document —
    /// identical bytes to a solo [`Session::run`](crate::session::Session::run)
    /// of the same spec.
    Finished {
        /// The job that finished.
        job: JobId,
        /// The rendered outcome document.
        outcome_json: String,
    },
    /// Terminal: the job errored (flow extraction, quantization, ...).
    Failed {
        /// The job that failed.
        job: JobId,
        /// The rendered error chain.
        error: String,
    },
    /// Terminal: the job was cancelled (while queued or mid-run).
    Cancelled {
        /// The job that was cancelled.
        job: JobId,
    },
}

impl Event {
    /// The job this event is about.
    pub fn job(&self) -> JobId {
        match self {
            Event::Accepted { job, .. }
            | Event::Rejected { job, .. }
            | Event::Started { job }
            | Event::Progress { job, .. }
            | Event::Finished { job, .. }
            | Event::Failed { job, .. }
            | Event::Cancelled { job } => *job,
        }
    }

    /// True for the three stream-ending variants.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            Event::Rejected { .. }
                | Event::Finished { .. }
                | Event::Failed { .. }
                | Event::Cancelled { .. }
        )
    }

    /// One-line human rendering (the CLI's `serve` progress log).
    pub fn describe(&self) -> String {
        match self {
            Event::Accepted { job, tenant, queue_depth } => format!(
                "{job}: accepted (tenant {:016x}, {queue_depth} queued ahead)",
                tenant.as_u64()
            ),
            Event::Rejected { job, reason, .. } => format!("{job}: rejected — {reason}"),
            Event::Started { job } => format!("{job}: started"),
            Event::Progress { job, scored, total } => format!("{job}: {scored}/{total} scored"),
            Event::Finished { job, .. } => format!("{job}: finished"),
            Event::Failed { job, error } => format!("{job}: failed — {error}"),
            Event::Cancelled { job } => format!("{job}: cancelled"),
        }
    }
}

/// How a [`JobTicket::wait`] ended.
#[derive(Debug, Clone, PartialEq)]
pub enum Completion {
    /// The job ran to completion.
    Finished {
        /// The byte-stable outcome document.
        outcome_json: String,
    },
    /// The job errored.
    Failed {
        /// The rendered error chain.
        error: String,
    },
    /// The job was cancelled before finishing.
    Cancelled,
}

impl Completion {
    /// The outcome document, when the job finished.
    pub fn outcome_json(&self) -> Option<&str> {
        match self {
            Completion::Finished { outcome_json } => Some(outcome_json),
            _ => None,
        }
    }
}

/// A cheap, cloneable handle for submitting work to a running
/// [`CompileService`](super::CompileService) — hand clones to as many
/// client threads as needed.
#[derive(Clone)]
pub struct ServiceClient {
    pub(crate) tx: mpsc::Sender<Msg>,
}

impl ServiceClient {
    /// Send a raw [`Command`]. Most callers want [`ServiceClient::submit`]
    /// or [`ServiceClient::cancel`] instead.
    pub fn send(&self, command: Command) -> Result<()> {
        self.tx
            .send(Msg::Command(command))
            .map_err(|_| anyhow!("compile service stopped"))
    }

    /// Submit a job and block until the admission decision: `Ok` with a
    /// live [`JobTicket`] when accepted, `Err` naming the reason when
    /// admission control turns it away.
    pub fn submit(&self, spec: JobSpec) -> Result<JobTicket> {
        let (events_tx, events) = mpsc::channel();
        self.send(Command::Submit {
            spec,
            events: events_tx,
        })?;
        match events.recv() {
            Ok(Event::Accepted { job, .. }) => Ok(JobTicket { job, events }),
            Ok(Event::Rejected { reason, .. }) => Err(anyhow!("job rejected: {reason}")),
            Ok(other) => Err(anyhow!("protocol error: {} before admission", other.describe())),
            Err(_) => Err(anyhow!("compile service dropped the submission")),
        }
    }

    /// Request cancellation of a queued or running job (fire and
    /// forget; the job's own event stream reports the outcome).
    pub fn cancel(&self, job: JobId) -> Result<()> {
        self.send(Command::Cancel { job })
    }
}

/// The client's end of one accepted job: its id plus the live event
/// stream ([`Event::Accepted`] has already been consumed by admission).
pub struct JobTicket {
    pub(crate) job: JobId,
    pub(crate) events: mpsc::Receiver<Event>,
}

impl JobTicket {
    /// The service-assigned id (usable with
    /// [`ServiceClient::cancel`]).
    pub fn id(&self) -> JobId {
        self.job
    }

    /// Block for the next event on this job's stream.
    pub fn recv(&self) -> Result<Event> {
        self.events
            .recv()
            .map_err(|_| anyhow!("compile service dropped the event stream"))
    }

    /// Drain the stream to its terminal event and fold it into a
    /// [`Completion`], discarding progress updates along the way.
    pub fn wait(&self) -> Result<Completion> {
        loop {
            match self.recv()? {
                Event::Finished { outcome_json, .. } => {
                    return Ok(Completion::Finished { outcome_json })
                }
                Event::Failed { error, .. } => return Ok(Completion::Failed { error }),
                Event::Cancelled { .. } => return Ok(Completion::Cancelled),
                _ => {}
            }
        }
    }
}

/// One served inference (the emulation lane's reply).
#[derive(Debug, Clone)]
pub struct InferReply {
    /// The model's output tensor.
    pub output: Tensor,
    /// Pure PJRT execute time.
    pub exec_seconds: f64,
    /// Queue + batch + execute time, as the client saw it.
    pub e2e_seconds: f64,
}

/// Aggregate statistics over the inference lane's lifetime.
#[derive(Debug, Clone)]
pub struct InferStats {
    /// Requests served.
    pub served: usize,
    /// Micro-batches executed.
    pub batches: usize,
    /// Pure execute-time distribution.
    pub exec: LatencyStats,
    /// End-to-end latency distribution.
    pub e2e: LatencyStats,
}
