//! The service daemon: one plain thread owning all mutable scheduling
//! state, driven by a single typed channel (std `mpsc` has no `select`,
//! so client commands and job-runner completions share one [`Msg`]
//! enum — the same single-owner pattern as the PJRT inference lane).
//!
//! Scheduling is a thin imperative shell over [`kernel`]: admission
//! checks the bounded queue, launch picks [`kernel::pick_next`]'s
//! choice whenever a worker slot is free, and every lifecycle event is
//! routed through the [`Reducer`] before it reaches the client, so the
//! replay log and the client's view can never disagree.
//!
//! Job runners are plain `std::thread`s calling the session engine
//! ([`session::execute`]) on the one shared [`Evaluator`] — NOT
//! evaluator-pool workers, so the engine's own fan-out (prewarm deques,
//! `evaluate_grid`) keeps its no-nesting invariant. Each runner streams
//! [`Event::Progress`] straight to its client (bypassing the daemon —
//! progress is volume) and reports completion back as [`Msg::Done`].

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::dse::{EvalRequest, Evaluator, Fidelity};
use crate::ir::DType;
use crate::metrics::LatencyStats;
use crate::runtime::{ModelArtifact, Runtime, Tensor};
use crate::session::{self, ExecHooks, Outcome};

use super::kernel::{self, QueueView};
use super::ports::{Command, Event, InferReply, InferStats, JobId, JobSpec};
use super::reducer::Reducer;
use super::ServiceConfig;

/// Everything the daemon can receive: client commands and job-runner
/// completions, multiplexed onto one channel.
#[derive(Debug)]
pub(crate) enum Msg {
    /// A client command ([`ServiceClient`](super::ServiceClient)).
    Command(Command),
    /// A job runner finished: the rendered outcome document, or the
    /// rendered error chain.
    Done {
        job: JobId,
        result: std::result::Result<String, String>,
    },
}

/// Spawn the daemon; returns the command channel and the join handle.
pub(crate) fn spawn(
    cfg: ServiceConfig,
    evaluator: Arc<Evaluator>,
) -> (mpsc::Sender<Msg>, JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let self_tx = tx.clone();
    let daemon = std::thread::spawn(move || {
        Orchestrator {
            cfg,
            evaluator,
            rx,
            self_tx,
            queue: VecDeque::new(),
            running: HashMap::new(),
            served: HashMap::new(),
            reducer: Reducer::new(),
            next_id: 0,
            shutdown_reply: None,
        }
        .run()
    });
    (tx, daemon)
}

/// One admitted, not-yet-launched job.
struct Queued {
    id: JobId,
    spec: JobSpec,
    events: mpsc::Sender<Event>,
    cost: u64,
}

/// One launched job.
struct Running {
    tenant: u64,
    events: mpsc::Sender<Event>,
    cancel: Arc<AtomicBool>,
    handle: JoinHandle<()>,
}

struct Orchestrator {
    cfg: ServiceConfig,
    evaluator: Arc<Evaluator>,
    rx: mpsc::Receiver<Msg>,
    /// Cloned into runners so completions come back on the same channel.
    self_tx: mpsc::Sender<Msg>,
    queue: VecDeque<Queued>,
    running: HashMap<JobId, Running>,
    /// Per-tenant completed-job counts (the fairness history).
    served: HashMap<u64, usize>,
    reducer: Reducer,
    next_id: u64,
    /// Set once [`Command::Shutdown`] arrives; replied to when drained.
    shutdown_reply: Option<mpsc::Sender<Reducer>>,
}

impl Orchestrator {
    fn run(mut self) {
        while let Ok(msg) = self.rx.recv() {
            match msg {
                Msg::Command(Command::Submit { spec, events }) => self.admit(spec, events),
                Msg::Command(Command::Cancel { job }) => self.cancel(job),
                Msg::Command(Command::Shutdown { reply }) => {
                    self.shutdown_reply = Some(reply);
                    // queued jobs never ran: cancel them deterministically
                    while let Some(q) = self.queue.pop_front() {
                        self.reducer.apply(&Event::Cancelled { job: q.id });
                        let _ = q.events.send(Event::Cancelled { job: q.id });
                    }
                }
                Msg::Done { job, result } => self.finish(job, result),
            }
            self.launch_ready();
            if let Some(reply) = &self.shutdown_reply {
                if self.running.is_empty() && self.queue.is_empty() {
                    let _ = reply.send(self.reducer.clone());
                    return;
                }
            }
        }
    }

    /// Record a lifecycle event in the reducer AND stream it to the
    /// job's client — one call site, so the two views cannot diverge.
    fn emit(&mut self, events: &mpsc::Sender<Event>, event: Event) {
        self.reducer.apply(&event);
        let _ = events.send(event);
    }

    fn admit(&mut self, spec: JobSpec, events: mpsc::Sender<Event>) {
        let id = JobId(self.next_id);
        self.next_id += 1;
        let tenant = spec.tenant;
        if self.shutdown_reply.is_some() {
            let reason = "service shutting down".to_string();
            let rejected = Event::Rejected {
                job: id,
                tenant,
                reason,
            };
            self.emit(&events, rejected);
            return;
        }
        if self.queue.len() >= self.cfg.queue_capacity.max(1) {
            let reason = format!("admission queue full ({} jobs)", self.queue.len());
            let rejected = Event::Rejected {
                job: id,
                tenant,
                reason,
            };
            self.emit(&events, rejected);
            return;
        }
        let accepted = Event::Accepted {
            job: id,
            tenant,
            queue_depth: self.queue.len(),
        };
        self.emit(&events, accepted);
        let cost = kernel::job_cost(&spec.job);
        self.queue.push_back(Queued {
            id,
            spec,
            events,
            cost,
        });
    }

    fn cancel(&mut self, job: JobId) {
        if let Some(pos) = self.queue.iter().position(|q| q.id == job) {
            if let Some(q) = self.queue.remove(pos) {
                self.emit(&q.events, Event::Cancelled { job });
            }
        } else if let Some(running) = self.running.get(&job) {
            // cooperative: the engine checks per chunk / per pair and
            // bails; the Done handler converts that into Cancelled
            running.cancel.store(true, Ordering::Relaxed);
        }
    }

    /// Launch queued jobs while worker slots are free, in the order the
    /// fairness kernel dictates.
    fn launch_ready(&mut self) {
        while self.shutdown_reply.is_none()
            && self.running.len() < self.cfg.workers.max(1)
            && !self.queue.is_empty()
        {
            let mut running_counts: HashMap<u64, usize> = HashMap::new();
            for r in self.running.values() {
                *running_counts.entry(r.tenant).or_insert(0) += 1;
            }
            let view: Vec<QueueView> = self
                .queue
                .iter()
                .map(|q| QueueView {
                    seq: q.id.0,
                    tenant: q.spec.tenant,
                    cost: q.cost,
                })
                .collect();
            let Some(pick) = kernel::pick_next(&view, &running_counts, &self.served) else {
                return;
            };
            let Some(q) = self.queue.remove(pick) else {
                return; // pick_next only returns indices into `view`
            };
            self.launch(q);
        }
    }

    fn launch(&mut self, q: Queued) {
        self.emit(&q.events, Event::Started { job: q.id });
        let cancel = Arc::new(AtomicBool::new(false));
        let runner_cancel = Arc::clone(&cancel);
        let evaluator = Arc::clone(&self.evaluator);
        let done = self.self_tx.clone();
        let events = q.events.clone();
        let (id, spec) = (q.id, q.spec);
        let tenant = spec.tenant.as_u64();
        let handle = std::thread::spawn(move || {
            let result = run_job(&evaluator, &spec, id, &events, &runner_cancel)
                .map_err(|e| format!("{e:#}"));
            let _ = done.send(Msg::Done { job: id, result });
        });
        let running = Running {
            tenant,
            events: q.events,
            cancel,
            handle,
        };
        self.running.insert(id, running);
    }

    fn finish(&mut self, job: JobId, result: std::result::Result<String, String>) {
        let Some(run) = self.running.remove(&job) else {
            return;
        };
        let _ = run.handle.join();
        *self.served.entry(run.tenant).or_insert(0) += 1;
        let event = match result {
            Ok(outcome_json) => Event::Finished { job, outcome_json },
            Err(_) if run.cancel.load(Ordering::Relaxed) => Event::Cancelled { job },
            Err(error) if error.contains("cancelled") => Event::Cancelled { job },
            Err(error) => Event::Failed { job, error },
        };
        self.emit(&run.events, event);
    }
}

/// One job on the shared evaluator: the same engine call, outcome
/// assembly and JSON rendering as a solo
/// [`Session::run`](crate::session::Session::run), so the `Finished`
/// document is byte-identical to the solo path (pinned by
/// `rust/tests/service.rs`).
fn run_job(
    evaluator: &Evaluator,
    spec: &JobSpec,
    id: JobId,
    events: &mpsc::Sender<Event>,
    cancel: &AtomicBool,
) -> Result<String> {
    if spec.job.specialize && spec.fidelity != Fidelity::SteppedFullNetwork {
        bail!(
            "per-layer specialization consumes the stepped-full census: \
             set JobSpec::fidelity to Fidelity::SteppedFullNetwork"
        );
    }
    let req = EvalRequest::shaped(spec.fidelity, spec.census_gamma).tenant(spec.tenant);
    // mpsc senders are Send but not Sync; the progress hook runs on the
    // engine's worker threads, so serialize sends through a mutex
    let progress_tx = Mutex::new(events.clone());
    let progress = move |scored: usize, total: usize| {
        if let Ok(tx) = progress_tx.lock() {
            let _ = tx.send(Event::Progress {
                job: id,
                scored,
                total,
            });
        }
    };
    let hooks = ExecHooks {
        cancel: Some(cancel),
        progress: Some(&progress),
    };
    let run = session::execute(
        evaluator,
        &spec.job.models,
        &spec.job.devices,
        spec.job.explorer,
        spec.thresholds,
        spec.job.quant.as_ref(),
        req,
        spec.job.specialize,
        &spec.job.batches,
        spec.job.latency_slo_ms,
        &hooks,
    )?;
    let outcome = Outcome {
        explorer: spec.job.explorer,
        fidelity: spec.fidelity,
        census_gamma: spec.census_gamma,
        models: spec.job.models.iter().map(|g| g.name.clone()).collect(),
        devices: spec.job.devices.iter().map(|d| d.name).collect(),
        entries: run.entries,
        wall_seconds: run.wall_seconds,
        steals: run.steals,
        cache: evaluator.cache().stats(),
    };
    Ok(outcome.to_json().to_string_pretty())
}

// ---------------------------------------------------------------------------
// Inference lane
// ---------------------------------------------------------------------------

struct InferRequest {
    input: Tensor,
    enqueued: Instant,
    reply: mpsc::Sender<Result<InferReply>>,
}

/// The emulation-inference lane: the compiled PJRT executable on a
/// single-owner worker thread (PJRT client types are `!Send`, so the
/// client is created and compiled *inside* the worker), serving
/// micro-batched requests over a bounded channel — the paper's OpenCL
/// host-program analogue, now one lane of the compile service.
pub(crate) struct InferLane {
    tx: Option<mpsc::SyncSender<InferRequest>>,
    worker: Option<JoinHandle<(Vec<f64>, Vec<f64>, usize)>>,
    out_dtype: DType,
}

impl InferLane {
    /// Start the worker: it creates the PJRT client, compiles the
    /// artifact, reports readiness, then serves. Weights are fixed at
    /// startup (they are part of the served model), so requests carry
    /// only the image tensor.
    pub(crate) fn start(
        cfg: &ServiceConfig,
        art: &ModelArtifact,
        weights: Vec<Tensor>,
    ) -> Result<InferLane> {
        if weights.len() != art.params.len() {
            return Err(anyhow!(
                "expected {} weight tensors, got {}",
                art.params.len(),
                weights.len()
            ));
        }
        let out_dtype = if art.quantization.is_some() {
            DType::I32
        } else {
            DType::F32
        };
        let hlo_path = art.hlo_path.clone();
        let name = art.name.clone();
        let arity = 1 + art.params.len();
        let (tx, rx) = mpsc::sync_channel::<InferRequest>(cfg.infer_queue_depth.max(1));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let max_batch = cfg.max_batch.max(1);
        let worker = std::thread::spawn(move || {
            let mut exec_samples = Vec::new();
            let mut e2e_samples = Vec::new();
            let mut batches = 0usize;
            // PJRT client + executable live entirely on this thread
            let setup = Runtime::cpu()
                .and_then(|rt| rt.load_hlo_text(&hlo_path, &name, arity).map(|c| (rt, c)));
            let (_rt, compiled) = match setup {
                Ok(pair) => {
                    let _ = ready_tx.send(Ok(()));
                    pair
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return (exec_samples, e2e_samples, batches);
                }
            };
            while let Ok(first) = rx.recv() {
                // drain a micro-batch
                let mut batch = vec![first];
                while batch.len() < max_batch {
                    match rx.try_recv() {
                        Ok(req) => batch.push(req),
                        Err(_) => break,
                    }
                }
                batches += 1;
                for req in batch {
                    let mut inputs = vec![req.input.clone()];
                    inputs.extend(weights.iter().cloned());
                    let result = compiled.run(&inputs, out_dtype).map(|out| {
                        let e2e = req.enqueued.elapsed().as_secs_f64();
                        exec_samples.push(out.exec_seconds);
                        e2e_samples.push(e2e);
                        InferReply {
                            output: out.tensor,
                            exec_seconds: out.exec_seconds,
                            e2e_seconds: e2e,
                        }
                    });
                    let _ = req.reply.send(result);
                }
            }
            (exec_samples, e2e_samples, batches)
        });
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(InferLane {
                tx: Some(tx),
                worker: Some(worker),
                out_dtype,
            }),
            Ok(Err(e)) => {
                let _ = worker.join();
                Err(e)
            }
            Err(_) => {
                // the worker panicked before reporting readiness: join
                // it (don't leak the handle) before surfacing the error
                let _ = worker.join();
                Err(anyhow!("inference worker died during startup"))
            }
        }
    }

    pub(crate) fn out_dtype(&self) -> DType {
        self.out_dtype
    }

    /// Submit one image and wait for the reply (blocking client call).
    pub(crate) fn infer(&self, input: Tensor) -> Result<InferReply> {
        let tx = self.tx.as_ref().ok_or_else(|| anyhow!("inference lane stopped"))?;
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(InferRequest {
            input,
            enqueued: Instant::now(),
            reply: reply_tx,
        })
        .map_err(|_| anyhow!("inference lane stopped"))?;
        reply_rx.recv().map_err(|_| anyhow!("inference lane dropped reply"))?
    }

    /// Stop the worker and collect statistics. A worker that died
    /// abnormally yields empty statistics (with a warning) instead of
    /// propagating its panic into the caller.
    pub(crate) fn shutdown(mut self) -> InferStats {
        self.tx.take(); // close the queue; worker loop exits
        match self.worker.take().map(JoinHandle::join) {
            Some(Ok((exec, e2e, batches))) => InferStats {
                served: exec.len(),
                batches,
                exec: LatencyStats::from_seconds(&exec),
                e2e: LatencyStats::from_seconds(&e2e),
            },
            _ => {
                eprintln!("warning: inference worker exited abnormally; statistics lost");
                InferStats {
                    served: 0,
                    batches: 0,
                    exec: LatencyStats::from_seconds(&[]),
                    e2e: LatencyStats::from_seconds(&[]),
                }
            }
        }
    }
}

impl Drop for InferLane {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(worker) = self.worker.take() {
            let _ = worker.join();
        }
    }
}
