//! L3 coordinator: the end-to-end CNN2Gate pipeline (paper Fig. 4a),
//! the compile-service daemon, and the batched emulation-inference
//! lane.
//!
//! `pipeline` wires front-end parsing → quantization → DSE → synthesis
//! (simulated) → emulation (PJRT); `service` is the long-lived daemon
//! multiplexing concurrent compile jobs and classify requests onto one
//! shared evaluator with admission control, per-tenant fairness and
//! streamed progress events; `server` is the thin legacy adapter that
//! keeps the old `InferenceServer` API alive on top of the service's
//! inference lane.

pub mod pipeline;
pub mod scheduler;
pub mod server;
pub mod service;

pub use pipeline::{run_pipeline, FleetReport, PipelineConfig, PipelineResult, SweepReport};
pub use scheduler::{work_steal_map, work_steal_map_seeded, StealStats};
pub use server::InferenceServer;
pub use service::{CompileService, JobSpec, ServiceConfig, ServiceReport};
