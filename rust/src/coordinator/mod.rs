//! L3 coordinator: the end-to-end CNN2Gate pipeline (paper Fig. 4a) and
//! the batched emulation-inference server.
//!
//! `pipeline` wires front-end parsing → quantization → DSE → synthesis
//! (simulated) → emulation (PJRT); `server` owns the compiled executable
//! on a worker thread and serves inference requests over channels —
//! the request path is pure Rust, Python compiled the artifacts once.

pub mod pipeline;
pub mod scheduler;
pub mod server;

pub use pipeline::{run_pipeline, FleetReport, PipelineConfig, PipelineResult, SweepReport};
pub use scheduler::{work_steal_map, work_steal_map_seeded, StealStats};
pub use server::{InferenceServer, ServerConfig, ServerStats};
