//! The end-to-end pipeline: everything CNN2Gate does for one model.
//!
//! parse (file or zoo) → validate → quantize (when weights are resident)
//! → DSE + fit on the target device → simulated synthesis + latency →
//! optional emulation-mode numerics check against the AOT artifacts.
//!
//! [`fit_fleet`] is the multi-device variant: one model fitted against
//! every device in the database concurrently (scoped fan-out via
//! [`crate::dse::eval::parallel_map`]; the per-device explorers share
//! the process-wide estimator memo underneath), for the `fit-fleet`
//! CLI subcommand and the fleet comparison table.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::dse::eval;
use crate::estimator::{device, Device, Thresholds};
use crate::ir::Graph;
use crate::onnx::{parser, zoo};
use crate::quant::QuantSpec;
use crate::runtime::{load_golden, Manifest, Runtime, Tensor};
use crate::synth::{self, Explorer, SynthReport};
use crate::ir::DType;

/// What to run.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Zoo name ("alexnet") or a path ending in .json.
    pub model: String,
    /// Device fuzzy name ("arria10", "5csema5").
    pub device: String,
    pub explorer: Explorer,
    pub thresholds: Thresholds,
    /// Apply the default quantization spec when weights are present.
    pub quantize: bool,
    /// Artifacts dir for the emulation check (None skips it).
    pub artifacts: Option<std::path::PathBuf>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            model: "alexnet".into(),
            device: "arria10".into(),
            explorer: Explorer::Reinforcement,
            thresholds: Thresholds::default(),
            quantize: false,
            artifacts: None,
        }
    }
}

/// Emulation-mode outcome.
#[derive(Debug, Clone)]
pub struct EmulationResult {
    pub model: String,
    pub exec_seconds: f64,
    /// Max |got - expected| when a golden was available.
    pub golden_max_err: Option<f64>,
}

/// Everything the pipeline produced.
#[derive(Debug)]
pub struct PipelineResult {
    pub graph: Graph,
    pub synth: SynthReport,
    pub emulation: Option<EmulationResult>,
}

/// Resolve a model argument into a graph: zoo name or ONNX-subset file.
pub fn load_model(model: &str, with_weights: bool) -> Result<Graph> {
    if model.ends_with(".json") {
        parser::parse_file(Path::new(model))
    } else {
        zoo::build(model, with_weights)
            .ok_or_else(|| anyhow!("unknown zoo model '{model}' (have {:?})", zoo::names()))
    }
}

/// Resolve a device argument.
pub fn load_device(name: &str) -> Result<&'static Device> {
    device::find(name).ok_or_else(|| {
        anyhow!(
            "unknown device '{name}' (have {:?})",
            device::all().iter().map(|d| d.name).collect::<Vec<_>>()
        )
    })
}

/// Run the full pipeline.
pub fn run_pipeline(cfg: &PipelineConfig) -> Result<PipelineResult> {
    let graph = load_model(&cfg.model, cfg.quantize)?;
    let dev = load_device(&cfg.device)?;
    let spec = QuantSpec::default();
    let quant_spec = (cfg.quantize && graph.has_weights()).then_some(&spec);
    let synth = synth::run(&graph, dev, cfg.explorer, cfg.thresholds, quant_spec)?;

    let emulation = match &cfg.artifacts {
        Some(dir) => run_emulation(dir, &graph.name)?,
        None => None,
    };

    Ok(PipelineResult {
        graph,
        synth,
        emulation,
    })
}

/// One model fitted against the whole device database.
#[derive(Debug)]
pub struct FleetReport {
    pub model: String,
    pub explorer: Explorer,
    /// One synthesis report per device, in [`device::all`] order.
    pub entries: Vec<SynthReport>,
    /// Wall time of the concurrent fleet fit.
    pub wall_seconds: f64,
}

impl FleetReport {
    /// Devices the model fits, best (lowest simulated latency) first.
    pub fn ranked_fits(&self) -> Vec<&SynthReport> {
        let mut fits: Vec<&SynthReport> = self.entries.iter().filter(|r| r.fits()).collect();
        fits.sort_by(|a, b| {
            let (la, lb) = (a.latency_ms().unwrap_or(f64::MAX), b.latency_ms().unwrap_or(f64::MAX));
            la.partial_cmp(&lb).expect("latencies are finite")
        });
        fits
    }

    /// The recommended target: the fitting device with the lowest
    /// simulated latency, if any fits at all.
    pub fn best(&self) -> Option<&SynthReport> {
        self.ranked_fits().into_iter().next()
    }
}

/// Fit `graph` on every device in [`device::all`] concurrently: each
/// device gets the full DSE + fit + synthesis-time + latency flow on its
/// own scoped thread, while all of them score candidates through the
/// shared estimator memo (so the fleet costs each unique candidate
/// once). Entries come back in database order.
pub fn fit_fleet(
    graph: &Graph,
    explorer: Explorer,
    thresholds: Thresholds,
) -> Result<FleetReport> {
    let t0 = Instant::now();
    let devices = device::all();
    let results = eval::parallel_map(&devices, devices.len(), |&dev| {
        synth::run(graph, dev, explorer, thresholds, None)
    });
    let mut entries = Vec::with_capacity(results.len());
    for result in results {
        entries.push(result?);
    }
    Ok(FleetReport {
        model: graph.name.clone(),
        explorer,
        entries,
        wall_seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Emulation mode: run the AOT HLO through PJRT; replay the golden when
/// one exists (small models), otherwise run with the golden-less path
/// skipped (large models are timed by `examples/` with synthetic weights).
pub fn run_emulation(dir: &Path, model: &str) -> Result<Option<EmulationResult>> {
    let manifest = Manifest::load(dir)?;
    let Some(art) = manifest.model(model) else {
        return Ok(None);
    };
    let Some(golden) = &art.golden else {
        return Ok(None);
    };
    let golden = load_golden(golden)?;
    let rt = Runtime::cpu()?;
    let compiled = rt.load_artifact(art)?;
    let mut inputs = vec![golden.input.clone()];
    inputs.extend(golden.params.iter().cloned());
    let out_dtype = if art.quantization.is_some() {
        DType::I32
    } else {
        DType::F32
    };
    let out = compiled.run(&inputs, out_dtype)?;
    let max_err = match (&out.tensor, &golden.expected) {
        (Tensor::F32(_, got), Tensor::F32(_, want)) => got
            .iter()
            .zip(want)
            .map(|(g, w)| (g - w).abs() as f64)
            .fold(0.0, f64::max),
        (Tensor::I32(_, got), Tensor::I32(_, want)) => got
            .iter()
            .zip(want)
            .map(|(g, w)| (g - w).abs() as f64)
            .fold(0.0, f64::max),
        _ => return Err(anyhow!("golden dtype mismatch")),
    };
    Ok(Some(EmulationResult {
        model: model.to_string(),
        exec_seconds: out.exec_seconds,
        golden_max_err: Some(max_err),
    }))
}

/// Deterministic synthetic weights matching an artifact's parameter list
/// (the paper's emulation timing runs don't need trained weights — see
/// DESIGN.md §2 substitution table).
pub fn synthetic_weights(art: &crate::runtime::ModelArtifact, seed: u64) -> Vec<Tensor> {
    let mut rng = crate::util::rng::Rng::new(seed);
    art.params
        .iter()
        .map(|p| match p.dtype {
            DType::F32 => {
                let fan_in: usize = p.shape.iter().skip(1).product::<usize>().max(1);
                Tensor::F32(p.shape.clone(), rng.he_weights(p.numel(), fan_in))
            }
            // int8-variant params cross the PJRT boundary as int32 codes
            DType::I32 | DType::I8 => Tensor::I32(
                p.shape.clone(),
                (0..p.numel())
                    .map(|_| rng.range_i64(-128, 127) as i32)
                    .collect(),
            ),
        })
        .collect()
}

/// Time one emulation-mode inference with synthetic weights (Table 1's
/// CPU column for the large models). Returns seconds per frame averaged
/// over `frames` runs after one warm-up.
pub fn time_emulation_synthetic(
    art: &crate::runtime::ModelArtifact,
    frames: usize,
) -> Result<f64> {
    let rt = Runtime::cpu()?;
    let compiled = rt.load_artifact(art)?;
    let mut rng = crate::util::rng::Rng::new(3);
    let numel = art.input.numel();
    let input = match art.input.dtype {
        DType::F32 => Tensor::F32(art.input.shape.clone(), rng.tensor_f32(numel)),
        _ => Tensor::I32(
            art.input.shape.clone(),
            (0..numel).map(|_| rng.range_i64(-128, 127) as i32).collect(),
        ),
    };
    let mut inputs = vec![input];
    inputs.extend(synthetic_weights(art, 7));
    let out_dtype = if art.quantization.is_some() {
        DType::I32
    } else {
        DType::F32
    };
    compiled.run(&inputs, out_dtype)?; // warm-up (compile caches etc.)
    let t0 = std::time::Instant::now();
    for _ in 0..frames.max(1) {
        compiled.run(&inputs, out_dtype)?;
    }
    Ok(t0.elapsed().as_secs_f64() / frames.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ComputationFlow;

    #[test]
    fn zoo_pipeline_runs_end_to_end() {
        let cfg = PipelineConfig {
            model: "alexnet".into(),
            device: "arria10".into(),
            quantize: true,
            ..PipelineConfig::default()
        };
        let res = run_pipeline(&cfg).unwrap();
        assert!(res.synth.fits());
        assert_eq!(res.synth.option(), Some((16, 32)));
        assert!(res.synth.quant.is_some());
    }

    #[test]
    fn fleet_fit_covers_every_device_and_ranks_fits() {
        let g = crate::onnx::zoo::build("alexnet", false).unwrap();
        let rep = fit_fleet(&g, Explorer::BruteForce, Thresholds::default()).unwrap();
        assert_eq!(rep.entries.len(), device::all().len());
        // entries preserve database order
        for (entry, dev) in rep.entries.iter().zip(device::all()) {
            assert_eq!(entry.device, dev.name);
        }
        // paper shape: AlexNet fits the Arria 10 at (16,32), not the 5CSEMA4
        let by_name = |n: &str| rep.entries.iter().find(|e| e.device.contains(n)).unwrap();
        assert_eq!(by_name("Arria 10").option(), Some((16, 32)));
        assert!(!by_name("5CSEMA4").fits());
        // ranking is by simulated latency, best first
        let ranked = rep.ranked_fits();
        assert!(!ranked.is_empty());
        for pair in ranked.windows(2) {
            assert!(pair[0].latency_ms().unwrap() <= pair[1].latency_ms().unwrap());
        }
        assert_eq!(
            rep.best().unwrap().device,
            ranked[0].device,
            "best() is the top-ranked fit"
        );
    }

    #[test]
    fn fleet_fit_matches_single_device_runs() {
        // concurrency must not change any per-device outcome
        let g = crate::onnx::zoo::build("alexnet", false).unwrap();
        let rep = fit_fleet(&g, Explorer::BruteForce, Thresholds::default()).unwrap();
        for (entry, dev) in rep.entries.iter().zip(device::all()) {
            let solo = synth::run(&g, dev, Explorer::BruteForce, Thresholds::default(), None)
                .unwrap();
            assert_eq!(entry.option(), solo.option(), "{}", dev.name);
            assert_eq!(entry.dse.trace, solo.dse.trace, "{}", dev.name);
            assert_eq!(entry.synthesis_minutes, solo.synthesis_minutes, "{}", dev.name);
        }
    }

    #[test]
    fn unknown_model_and_device_error_clearly() {
        assert!(load_model("resnet152", false).is_err());
        assert!(load_device("virtex9").is_err());
    }

    #[test]
    fn parses_exported_onnx_subset_when_present() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/models/lenet5.json");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let g = load_model(path.to_str().unwrap(), false).unwrap();
        assert_eq!(g.name, "lenet5");
        assert!(g.has_weights(), "lenet5 export carries external data");
        let flow = ComputationFlow::extract(&g).unwrap();
        assert_eq!(flow.layers.len(), 5); // 2 conv+pool + 3 fc
    }

    #[test]
    fn emulation_with_goldens_when_present() {
        if !crate::runtime::Runtime::available() {
            eprintln!("skipping: pjrt feature disabled");
            return;
        }
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let res = run_emulation(&dir, "lenet5").unwrap().unwrap();
        assert!(res.golden_max_err.unwrap() < 1e-4);
        // int8 variant must be exact
        let res8 = run_emulation(&dir, "lenet5_int8").unwrap().unwrap();
        assert_eq!(res8.golden_max_err.unwrap(), 0.0);
    }
}
