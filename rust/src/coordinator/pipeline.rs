//! The end-to-end pipeline: everything CNN2Gate does for one model.
//!
//! parse (file or zoo) → validate → quantize (when weights are resident)
//! → DSE + fit on the target device → simulated synthesis + latency →
//! optional emulation-mode numerics check against the AOT artifacts.
//!
//! The multi-target fan-outs — the fleet fit (one model × every device)
//! and the sweep (models × devices, with rankings and the Pareto
//! frontier) — are shapes of one job since PR 4: a [`CompileJob`]
//! executed by [`Session::run`] on the two-phase work-stealing engine
//! ([`crate::session`]). The PR-4 deprecated shims (`fit_fleet[_with]`,
//! `sweep_matrix[_with]`) are gone now that nothing cites them; the
//! report structs ([`FleetReport`], [`SweepReport`]) remain the legacy
//! views an [`Outcome`](crate::session::Outcome) renders to, and their
//! rankings run over the devices the job actually evaluated (a device
//! subset is ranked as a subset, never against the whole database).

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::estimator::{device, Device, Thresholds};
use crate::ir::DType;
use crate::ir::Graph;
use crate::onnx::{parser, zoo};
use crate::quant::QuantSpec;
use crate::runtime::{load_golden, Manifest, Runtime, Tensor};
use crate::session::{CompileJob, Session};
use crate::synth::{Explorer, SynthReport};

/// What to run.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Zoo name ("alexnet") or a path ending in .json.
    pub model: String,
    /// Device fuzzy name ("arria10", "5csema5").
    pub device: String,
    pub explorer: Explorer,
    pub thresholds: Thresholds,
    /// Apply the default quantization spec when weights are present.
    pub quantize: bool,
    /// Artifacts dir for the emulation check (None skips it).
    pub artifacts: Option<std::path::PathBuf>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            model: "alexnet".into(),
            device: "arria10".into(),
            explorer: Explorer::Reinforcement,
            thresholds: Thresholds::default(),
            quantize: false,
            artifacts: None,
        }
    }
}

/// Emulation-mode outcome.
#[derive(Debug, Clone)]
pub struct EmulationResult {
    pub model: String,
    pub exec_seconds: f64,
    /// Max |got - expected| when a golden was available.
    pub golden_max_err: Option<f64>,
}

/// Everything the pipeline produced.
#[derive(Debug)]
pub struct PipelineResult {
    pub graph: Graph,
    pub synth: SynthReport,
    pub emulation: Option<EmulationResult>,
}

/// Resolve a model argument into a graph: zoo name or ONNX-subset file.
pub fn load_model(model: &str, with_weights: bool) -> Result<Graph> {
    if model.ends_with(".json") {
        parser::parse_file(Path::new(model))
    } else {
        zoo::build(model, with_weights)
            .ok_or_else(|| anyhow!("unknown zoo model '{model}' (have {:?})", zoo::names()))
    }
}

/// Resolve a device argument.
pub fn load_device(name: &str) -> Result<&'static Device> {
    device::find(name).ok_or_else(|| {
        anyhow!(
            "unknown device '{name}' (have {:?})",
            device::all().iter().map(|d| d.name).collect::<Vec<_>>()
        )
    })
}

/// Run the full pipeline: a 1×1 [`CompileJob`] through a default
/// [`Session`], plus the optional emulation check.
pub fn run_pipeline(cfg: &PipelineConfig) -> Result<PipelineResult> {
    let graph = load_model(&cfg.model, cfg.quantize)?;
    let dev = load_device(&cfg.device)?;
    let quantize = cfg.quantize && graph.has_weights();
    let session = Session::builder().thresholds(cfg.thresholds).build();
    let mut builder = CompileJob::builder()
        .model(graph)
        .device(dev)
        .explorer(cfg.explorer);
    if quantize {
        builder = builder.quantize(QuantSpec::default());
    }
    let job = builder.build()?;
    let synth = session
        .run(&job)?
        .into_synth_report()
        .ok_or_else(|| anyhow!("a 1x1 job yielded no synthesis report"))?;
    // the job owned the graph; take it back for the result
    let CompileJob { mut models, .. } = job;
    let graph = models
        .pop()
        .ok_or_else(|| anyhow!("the 1x1 job no longer holds its model"))?;

    let emulation = match &cfg.artifacts {
        Some(dir) => run_emulation(dir, &graph.name)?,
        None => None,
    };

    Ok(PipelineResult {
        graph,
        synth,
        emulation,
    })
}

/// One model fitted against the whole device database.
#[derive(Debug)]
pub struct FleetReport {
    pub model: String,
    pub explorer: Explorer,
    /// One synthesis report per device, in [`device::all`] order.
    pub entries: Vec<SynthReport>,
    /// Wall time of the concurrent fleet fit.
    pub wall_seconds: f64,
}

impl FleetReport {
    /// Devices the model fits, best (lowest simulated latency) first.
    pub fn ranked_fits(&self) -> Vec<&SynthReport> {
        let mut fits: Vec<&SynthReport> = self.entries.iter().filter(|r| r.fits()).collect();
        fits.sort_by(|a, b| {
            let (la, lb) = (a.latency_ms().unwrap_or(f64::MAX), b.latency_ms().unwrap_or(f64::MAX));
            la.total_cmp(&lb)
        });
        fits
    }

    /// The recommended target: the fitting device with the lowest
    /// simulated latency, if any fits at all.
    pub fn best(&self) -> Option<&SynthReport> {
        self.ranked_fits().into_iter().next()
    }
}

/// Every (model, device) pair explored: the fleet fit generalized to the
/// full model×device matrix the `sweep` subcommand reports. Produced by
/// [`Outcome::to_sweep_report`](crate::session::Outcome::to_sweep_report).
#[derive(Debug)]
pub struct SweepReport {
    pub explorer: Explorer,
    /// Model names in job order.
    pub models: Vec<String>,
    /// One synthesis report per (model, device) pair: model-major in
    /// `models` order, devices in the job's device order within a model.
    pub entries: Vec<SynthReport>,
    /// Wall time of the concurrent sweep.
    pub wall_seconds: f64,
}

fn latency_key(r: &SynthReport) -> f64 {
    r.latency_ms().unwrap_or(f64::MAX)
}

fn resource_key(r: &SynthReport) -> f64 {
    r.estimate.as_ref().map_or(f64::MAX, |e| e.f_avg())
}

impl SweepReport {
    /// The matrix cell for one (model, device) pair, if present.
    pub fn entry(&self, model: &str, device: &str) -> Option<&SynthReport> {
        self.entries
            .iter()
            .find(|e| e.model == model && e.device == device)
    }

    /// Best (lowest simulated latency) fitting device per model, in
    /// model order; `None` when the model fits nothing.
    pub fn best_device_per_model(&self) -> Vec<(&str, Option<&SynthReport>)> {
        self.models
            .iter()
            .map(|m| {
                let best = self
                    .entries
                    .iter()
                    .filter(|e| e.model == *m && e.fits())
                    .min_by(|a, b| latency_key(a).total_cmp(&latency_key(b)));
                (m.as_str(), best)
            })
            .collect()
    }

    /// The devices this sweep actually evaluated, in job order (first
    /// occurrence across the model-major entries).
    pub fn devices(&self) -> Vec<&'static str> {
        let mut seen: Vec<&'static str> = Vec::new();
        for e in &self.entries {
            if !seen.contains(&e.device) {
                seen.push(e.device);
            }
        }
        seen
    }

    /// Best (lowest simulated latency) fitting model per device, over
    /// the job's OWN device set in job order; `None` when nothing fits
    /// the device. (This used to iterate the full device database, so a
    /// subset sweep grew spurious "none fits" rows for devices the job
    /// never evaluated — ROADMAP follow-up (f).)
    pub fn best_model_per_device(&self) -> Vec<(&'static str, Option<&SynthReport>)> {
        self.devices()
            .into_iter()
            .map(|dev| {
                let best = self
                    .entries
                    .iter()
                    .filter(|e| e.device == dev && e.fits())
                    .min_by(|a, b| latency_key(a).total_cmp(&latency_key(b)));
                (dev, best)
            })
            .collect()
    }

    /// Matrix-wide Pareto frontier over (simulated latency, F_avg):
    /// the fitting (model, device) points no other fit beats on both
    /// axes, sorted by latency.
    pub fn pareto_frontier(&self) -> Vec<&SynthReport> {
        let mut fits: Vec<&SynthReport> = self.entries.iter().filter(|e| e.fits()).collect();
        fits.sort_by(|a, b| {
            latency_key(a)
                .total_cmp(&latency_key(b))
                .then(resource_key(a).total_cmp(&resource_key(b)))
        });
        let mut frontier: Vec<&SynthReport> = Vec::new();
        let mut best_resource = f64::INFINITY;
        for entry in fits {
            let r = resource_key(entry);
            if r < best_resource {
                best_resource = r;
                frontier.push(entry);
            }
        }
        frontier
    }
}

/// Emulation mode: run the AOT HLO through PJRT; replay the golden when
/// one exists (small models), otherwise run with the golden-less path
/// skipped (large models are timed by `examples/` with synthetic weights).
pub fn run_emulation(dir: &Path, model: &str) -> Result<Option<EmulationResult>> {
    let manifest = Manifest::load(dir)?;
    let Some(art) = manifest.model(model) else {
        return Ok(None);
    };
    let Some(golden) = &art.golden else {
        return Ok(None);
    };
    let golden = load_golden(golden)?;
    let rt = Runtime::cpu()?;
    let compiled = rt.load_artifact(art)?;
    let mut inputs = vec![golden.input.clone()];
    inputs.extend(golden.params.iter().cloned());
    let out_dtype = if art.quantization.is_some() {
        DType::I32
    } else {
        DType::F32
    };
    let out = compiled.run(&inputs, out_dtype)?;
    let max_err = match (&out.tensor, &golden.expected) {
        (Tensor::F32(_, got), Tensor::F32(_, want)) => got
            .iter()
            .zip(want)
            .map(|(g, w)| (g - w).abs() as f64)
            .fold(0.0, f64::max),
        (Tensor::I32(_, got), Tensor::I32(_, want)) => got
            .iter()
            .zip(want)
            .map(|(g, w)| (g - w).abs() as f64)
            .fold(0.0, f64::max),
        _ => return Err(anyhow!("golden dtype mismatch")),
    };
    Ok(Some(EmulationResult {
        model: model.to_string(),
        exec_seconds: out.exec_seconds,
        golden_max_err: Some(max_err),
    }))
}

/// Deterministic synthetic weights matching an artifact's parameter list
/// (the paper's emulation timing runs don't need trained weights — see
/// DESIGN.md §2 substitution table).
pub fn synthetic_weights(art: &crate::runtime::ModelArtifact, seed: u64) -> Vec<Tensor> {
    let mut rng = crate::util::rng::Rng::new(seed);
    art.params
        .iter()
        .map(|p| match p.dtype {
            DType::F32 => {
                let fan_in: usize = p.shape.iter().skip(1).product::<usize>().max(1);
                Tensor::F32(p.shape.clone(), rng.he_weights(p.numel(), fan_in))
            }
            // int8-variant params cross the PJRT boundary as int32 codes
            DType::I32 | DType::I8 => Tensor::I32(
                p.shape.clone(),
                (0..p.numel())
                    .map(|_| rng.range_i64(-128, 127) as i32)
                    .collect(),
            ),
        })
        .collect()
}

/// Time one emulation-mode inference with synthetic weights (Table 1's
/// CPU column for the large models). Returns seconds per frame averaged
/// over `frames` runs after one warm-up.
pub fn time_emulation_synthetic(
    art: &crate::runtime::ModelArtifact,
    frames: usize,
) -> Result<f64> {
    let rt = Runtime::cpu()?;
    let compiled = rt.load_artifact(art)?;
    let mut rng = crate::util::rng::Rng::new(3);
    let numel = art.input.numel();
    let input = match art.input.dtype {
        DType::F32 => Tensor::F32(art.input.shape.clone(), rng.tensor_f32(numel)),
        _ => Tensor::I32(
            art.input.shape.clone(),
            (0..numel).map(|_| rng.range_i64(-128, 127) as i32).collect(),
        ),
    };
    let mut inputs = vec![input];
    inputs.extend(synthetic_weights(art, 7));
    let out_dtype = if art.quantization.is_some() {
        DType::I32
    } else {
        DType::F32
    };
    compiled.run(&inputs, out_dtype)?; // warm-up (compile caches etc.)
    let t0 = std::time::Instant::now();
    for _ in 0..frames.max(1) {
        compiled.run(&inputs, out_dtype)?;
    }
    Ok(t0.elapsed().as_secs_f64() / frames.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Fidelity;
    use crate::ir::ComputationFlow;

    /// 1×N fleet through the session front door.
    fn fleet(model: &str, explorer: Explorer) -> FleetReport {
        let session = Session::builder().threads(4).build();
        let job = CompileJob::builder()
            .model(zoo::build(model, false).unwrap())
            .all_devices()
            .explorer(explorer)
            .build()
            .unwrap();
        session.run(&job).unwrap().to_fleet_report().unwrap()
    }

    /// M×N sweep through the session front door.
    fn sweep(models: &[&str], explorer: Explorer, fidelity: Fidelity) -> SweepReport {
        let session = Session::builder().threads(4).fidelity(fidelity).build();
        let job = CompileJob::builder()
            .models(models.iter().map(|m| zoo::build(m, false).unwrap()))
            .all_devices()
            .explorer(explorer)
            .build()
            .unwrap();
        session.run(&job).unwrap().to_sweep_report()
    }

    /// 1×1 synth through the session front door.
    fn solo(model: &str, device: &'static Device) -> SynthReport {
        let session = Session::builder().threads(2).build();
        let job = CompileJob::builder()
            .model(zoo::build(model, false).unwrap())
            .device(device)
            .explorer(Explorer::BruteForce)
            .build()
            .unwrap();
        session.run(&job).unwrap().into_synth_report().unwrap()
    }

    #[test]
    fn zoo_pipeline_runs_end_to_end() {
        let cfg = PipelineConfig {
            model: "alexnet".into(),
            device: "arria10".into(),
            quantize: true,
            ..PipelineConfig::default()
        };
        let res = run_pipeline(&cfg).unwrap();
        assert!(res.synth.fits());
        assert_eq!(res.synth.option(), Some((16, 32)));
        assert!(res.synth.quant.is_some());
        assert_eq!(res.graph.name, "alexnet", "the job hands the graph back");
    }

    #[test]
    fn fleet_fit_covers_every_device_and_ranks_fits() {
        let rep = fleet("alexnet", Explorer::BruteForce);
        assert_eq!(rep.entries.len(), device::all().len());
        // entries preserve database order
        for (entry, dev) in rep.entries.iter().zip(device::all()) {
            assert_eq!(entry.device, dev.name);
        }
        // paper shape: AlexNet fits the Arria 10 at (16,32), not the 5CSEMA4
        let by_name = |n: &str| rep.entries.iter().find(|e| e.device.contains(n)).unwrap();
        assert_eq!(by_name("Arria 10").option(), Some((16, 32)));
        assert!(!by_name("5CSEMA4").fits());
        // ranking is by simulated latency, best first
        let ranked = rep.ranked_fits();
        assert!(!ranked.is_empty());
        for pair in ranked.windows(2) {
            assert!(pair[0].latency_ms().unwrap() <= pair[1].latency_ms().unwrap());
        }
        assert_eq!(
            rep.best().unwrap().device,
            ranked[0].device,
            "best() is the top-ranked fit"
        );
    }

    #[test]
    fn fleet_fit_matches_single_device_runs() {
        // concurrency must not change any per-device outcome
        let rep = fleet("alexnet", Explorer::BruteForce);
        for (entry, dev) in rep.entries.iter().zip(device::all()) {
            let one = solo("alexnet", dev);
            assert_eq!(entry.option(), one.option(), "{}", dev.name);
            assert_eq!(entry.dse.trace, one.dse.trace, "{}", dev.name);
            assert_eq!(entry.synthesis_minutes, one.synthesis_minutes, "{}", dev.name);
        }
    }

    #[test]
    fn unknown_model_and_device_error_clearly() {
        assert!(load_model("resnet152", false).is_err());
        assert!(load_device("virtex9").is_err());
    }

    #[test]
    fn sweep_matrix_matches_per_pair_seed_exploration() {
        // the sweep's concurrent fan-out must choose exactly the design
        // the sequential seed path picks for every (model, device) pair
        let rep = sweep(&["alexnet", "vgg16"], Explorer::BruteForce, Fidelity::Analytical);
        assert_eq!(rep.entries.len(), 2 * device::all().len());
        assert_eq!(rep.models, vec!["alexnet", "vgg16"]);
        assert_eq!(rep.devices().len(), device::all().len());
        // model-major, database-order layout
        for (mi, model) in rep.models.iter().enumerate() {
            for (di, dev) in device::all().iter().enumerate() {
                let entry = &rep.entries[mi * device::all().len() + di];
                assert_eq!(entry.model, *model);
                assert_eq!(entry.device, dev.name);
            }
        }
        for entry in &rep.entries {
            let g = zoo::build(&entry.model, false).unwrap();
            let flow = ComputationFlow::extract(&g).unwrap();
            let dev = device::find(entry.device).unwrap();
            let seed = crate::dse::brute::explore_seq(&flow, dev, Thresholds::default());
            assert_eq!(
                entry.option(),
                seed.best,
                "{} on {}",
                entry.model,
                entry.device
            );
            assert_eq!(entry.dse.trace, seed.trace, "{} on {}", entry.model, entry.device);
        }
    }

    #[test]
    fn sweep_rankings_and_pareto_are_consistent() {
        let rep = sweep(&["alexnet", "vgg16"], Explorer::BruteForce, Fidelity::Analytical);
        // best device per model is the row's latency argmin over fits
        for (model, best) in rep.best_device_per_model() {
            let row_min = rep
                .entries
                .iter()
                .filter(|e| e.model == model && e.fits())
                .map(|e| e.latency_ms().unwrap())
                .fold(f64::INFINITY, f64::min);
            match best {
                Some(b) => assert_eq!(b.latency_ms().unwrap(), row_min, "{model}"),
                None => assert!(row_min.is_infinite(), "{model}"),
            }
        }
        // paper shape: the Arria 10 is the best target for both fixtures
        for (model, best) in rep.best_device_per_model() {
            let b = best.unwrap_or_else(|| panic!("{model} fits nothing"));
            assert!(b.device.contains("Arria 10"), "{model} best on {}", b.device);
        }
        // best model per device: AlexNet (fewer GOp) beats VGG wherever
        // both fit; the 5CSEMA4 fits neither
        for (device, best) in rep.best_model_per_device() {
            if device.contains("5CSEMA4") {
                assert!(best.is_none(), "nothing fits the 5CSEMA4");
            } else {
                assert_eq!(best.unwrap().model, "alexnet", "{device}");
            }
        }
        // pareto frontier: non-empty, latency-sorted, and undominated
        let frontier = rep.pareto_frontier();
        assert!(!frontier.is_empty());
        for w in frontier.windows(2) {
            assert!(w[0].latency_ms().unwrap() <= w[1].latency_ms().unwrap());
            assert!(
                w[0].estimate.as_ref().unwrap().f_avg()
                    > w[1].estimate.as_ref().unwrap().f_avg(),
                "frontier must strictly improve on resources as latency grows"
            );
        }
        for p in &frontier {
            let (pl, pr) = (
                p.latency_ms().unwrap(),
                p.estimate.as_ref().unwrap().f_avg(),
            );
            for e in rep.entries.iter().filter(|e| e.fits()) {
                let (el, er) = (
                    e.latency_ms().unwrap(),
                    e.estimate.as_ref().unwrap().f_avg(),
                );
                let dominates = (el < pl && er <= pr) || (el <= pl && er < pr);
                assert!(
                    !dominates,
                    "{} on {} dominates frontier point {} on {}",
                    e.model, e.device, p.model, p.device
                );
            }
        }
    }

    #[test]
    fn stepped_full_sweep_matches_analytical_and_carries_censuses() {
        // the work-stealing sweep at full-network stepped fidelity must
        // pick exactly the analytical designs and attach a per-round
        // census to every fitting cell
        let analytical = sweep(&["tiny"], Explorer::BruteForce, Fidelity::Analytical);
        let stepped = sweep(&["tiny"], Explorer::BruteForce, Fidelity::SteppedFullNetwork);
        assert_eq!(stepped.entries.len(), analytical.entries.len());
        let flow = ComputationFlow::extract(&zoo::build("tiny", false).unwrap()).unwrap();
        for (s, a) in stepped.entries.iter().zip(&analytical.entries) {
            assert_eq!(s.option(), a.option(), "{}", s.device);
            assert_eq!(s.dse.trace, a.dse.trace, "{}", s.device);
            match (&s.stepped_network, s.fits()) {
                (Some(net), true) => {
                    assert_eq!(net.layers.len(), flow.layers.len(), "{}", s.device);
                    assert!(net.total_cycles() > 0);
                }
                (None, false) => {}
                (census, fits) => panic!(
                    "{}: census presence {:?} disagrees with fits {}",
                    s.device,
                    census.is_some(),
                    fits
                ),
            }
            assert!(a.stepped_network.is_none(), "analytical sweep carries none");
        }
    }

    #[test]
    fn sweep_entry_lookup_finds_cells() {
        let rep = sweep(&["alexnet"], Explorer::BruteForce, Fidelity::Analytical);
        let cell = rep.entry("alexnet", "Arria 10 GX 1150").unwrap();
        assert_eq!(cell.option(), Some((16, 32)));
        assert!(rep.entry("alexnet", "no-such-device").is_none());
        assert!(rep.entry("no-such-model", "Arria 10 GX 1150").is_none());
    }

    #[test]
    fn subset_sweep_ranks_only_the_jobs_devices() {
        // ROADMAP follow-up (f): with a device subset the per-device
        // ranking must cover exactly the job's devices — no spurious
        // "none fits" rows for devices that were never evaluated
        use crate::estimator::device::{ARRIA_10_GX1150, CYCLONE_V_5CSEMA5};
        let session = Session::builder().threads(2).build();
        let job = CompileJob::builder()
            .models([
                zoo::build("alexnet", false).unwrap(),
                zoo::build("tiny", false).unwrap(),
            ])
            .device(&CYCLONE_V_5CSEMA5)
            .device(&ARRIA_10_GX1150)
            .explorer(Explorer::BruteForce)
            .build()
            .unwrap();
        let rep = session.run(&job).unwrap().to_sweep_report();
        assert_eq!(
            rep.devices(),
            vec![CYCLONE_V_5CSEMA5.name, ARRIA_10_GX1150.name],
            "job order, job devices only"
        );
        let ranked = rep.best_model_per_device();
        assert_eq!(ranked.len(), 2, "one row per job device, not per database device");
        for (dev, best) in &ranked {
            assert!(
                *dev == CYCLONE_V_5CSEMA5.name || *dev == ARRIA_10_GX1150.name,
                "ranked a device outside the job: {dev}"
            );
            let b = best.unwrap_or_else(|| panic!("{dev}: something fits both job devices"));
            assert_eq!(b.model, "tiny", "tiny's latency beats alexnet's wherever both fit");
        }
        // and a genuinely unfittable device inside the job still shows
        // its honest none-fits row
        use crate::estimator::device::CYCLONE_V_5CSEMA4;
        let job = CompileJob::builder()
            .model(zoo::build("alexnet", false).unwrap())
            .device(&CYCLONE_V_5CSEMA4)
            .explorer(Explorer::BruteForce)
            .build()
            .unwrap();
        let rep = session.run(&job).unwrap().to_sweep_report();
        let ranked = rep.best_model_per_device();
        assert_eq!(ranked.len(), 1);
        assert_eq!(ranked[0].0, CYCLONE_V_5CSEMA4.name);
        assert!(ranked[0].1.is_none(), "alexnet really does not fit the 5CSEMA4");
    }

    #[test]
    fn parses_exported_onnx_subset_when_present() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/models/lenet5.json");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let g = load_model(path.to_str().unwrap(), false).unwrap();
        assert_eq!(g.name, "lenet5");
        assert!(g.has_weights(), "lenet5 export carries external data");
        let flow = ComputationFlow::extract(&g).unwrap();
        assert_eq!(flow.layers.len(), 5); // 2 conv+pool + 3 fc
    }

    #[test]
    fn emulation_with_goldens_when_present() {
        if !crate::runtime::Runtime::available() {
            eprintln!("skipping: pjrt feature disabled");
            return;
        }
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let res = run_emulation(&dir, "lenet5").unwrap().unwrap();
        assert!(res.golden_max_err.unwrap() < 1e-4);
        // int8 variant must be exact
        let res8 = run_emulation(&dir, "lenet5_int8").unwrap().unwrap();
        assert_eq!(res8.golden_max_err.unwrap(), 0.0);
    }
}
