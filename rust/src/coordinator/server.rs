//! Legacy inference-server adapter over the compile service.
//!
//! The seed's `InferenceServer` owned its own worker thread, channel
//! protocol and config struct. All of that now lives in the compile
//! service's inference lane ([`service`](super::service)); this module
//! keeps the old surface alive as a thin adapter so existing callers
//! (the `serve` demo, the emulation tests, `examples/e2e_classify`)
//! migrate by swapping `ServerConfig` for [`ServiceConfig`] — the old
//! `max_batch` / `queue_depth` knobs are now
//! [`ServiceConfig::max_batch`] / [`ServiceConfig::infer_queue_depth`].
//!
//! The adapter also inherits the lane's startup fix: when the worker
//! dies before reporting readiness, its `JoinHandle` is joined instead
//! of leaked (the seed dropped it un-joined on that path).

use anyhow::Result;

use crate::ir::DType;
use crate::runtime::{ModelArtifact, Tensor};

use super::service::{CompileService, ServiceConfig};

pub use super::service::{InferReply as Reply, InferStats as ServerStats};

/// A running server bound to one model variant: a [`CompileService`]
/// with only its inference lane exercised. Compile jobs can still be
/// submitted through [`InferenceServer::service`] — there is one
/// submit path, not two.
pub struct InferenceServer {
    service: CompileService,
}

impl InferenceServer {
    /// Start the service's inference lane on `art` with fixed
    /// `weights` (one tensor per artifact parameter).
    pub fn start(art: &ModelArtifact, weights: Vec<Tensor>, cfg: ServiceConfig) -> Result<Self> {
        let service = CompileService::start_with_inference(cfg, art, weights)?;
        Ok(InferenceServer { service })
    }

    /// Output dtype the lane produces (I32 quantized, F32 float).
    pub fn out_dtype(&self) -> DType {
        self.service
            .out_dtype()
            // analysis: allow(panic, start() is the only constructor and it always starts the inference lane)
            .expect("adapter always starts the inference lane")
    }

    /// Submit one image and wait for the reply (blocking client call).
    pub fn infer(&self, input: Tensor) -> Result<Reply> {
        self.service.infer(input)
    }

    /// The service underneath, for callers that also want to submit
    /// compile jobs over the same daemon.
    pub fn service(&self) -> &CompileService {
        &self.service
    }

    /// Stop the lane and collect its statistics.
    pub fn shutdown(self) -> ServerStats {
        self.service
            .shutdown()
            .infer
            // analysis: allow(panic, start() is the only constructor and it always starts the inference lane)
            .expect("adapter always starts the inference lane")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{load_golden, Manifest};
    use std::path::Path;

    fn artifacts() -> Option<Manifest> {
        if !crate::runtime::Runtime::available() {
            return None; // stub build: artifacts exist but can't replay
        }
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| Manifest::load(&dir).unwrap())
    }

    #[test]
    fn serves_golden_requests_batched() {
        let Some(manifest) = artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let art = manifest.model("tiny").unwrap();
        let golden = load_golden(art.golden.as_ref().unwrap()).unwrap();
        let server =
            InferenceServer::start(art, golden.params.clone(), ServiceConfig::default()).unwrap();
        let n = 12;
        for _ in 0..n {
            let reply = server.infer(golden.input.clone()).unwrap();
            let got = reply.output.as_f32().unwrap();
            let want = golden.expected.as_f32().unwrap();
            for (g, w) in got.iter().zip(want) {
                assert!((g - w).abs() < 1e-5);
            }
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, n);
        assert!(stats.exec.p50_ms > 0.0);
    }

    #[test]
    fn rejects_weight_arity_mismatch() {
        let Some(manifest) = artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let art = manifest.model("tiny").unwrap();
        let err = match InferenceServer::start(art, vec![], ServiceConfig::default()) {
            Err(e) => e,
            Ok(_) => panic!("arity mismatch accepted"),
        };
        assert!(err.to_string().contains("weight tensors"));
    }

    #[test]
    fn startup_error_propagates() {
        let Some(manifest) = artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let mut art = manifest.model("tiny").unwrap().clone();
        art.hlo_path = "/nonexistent/x.hlo.txt".into();
        let golden = load_golden(manifest.model("tiny").unwrap().golden.as_ref().unwrap()).unwrap();
        assert!(InferenceServer::start(&art, golden.params, ServiceConfig::default()).is_err());
    }

    #[test]
    fn concurrent_clients_all_served() {
        let Some(manifest) = artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let art = manifest.model("tiny").unwrap();
        let golden = load_golden(art.golden.as_ref().unwrap()).unwrap();
        let server = std::sync::Arc::new(
            InferenceServer::start(art, golden.params.clone(), ServiceConfig::default()).unwrap(),
        );
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = server.clone();
            let input = golden.input.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..5 {
                    s.infer(input.clone()).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let server = std::sync::Arc::into_inner(server).expect("sole owner");
        let stats = server.shutdown();
        assert_eq!(stats.served, 20);
    }
}
