//! Batched emulation-inference server.
//!
//! The OpenCL host program of the paper owns the FPGA command queues; our
//! analogue owns the compiled PJRT executable on a dedicated worker
//! thread and serves requests over channels (std::thread + mpsc — tokio
//! is not in the offline crate set, and PJRT's client types are !Send, so
//! a single-owner worker loop is the only sound threading model anyway:
//! the client is created and compiled *inside* the worker).
//!
//! Requests are micro-batched: the worker drains up to `max_batch`
//! queued requests before executing them back-to-back, which amortizes
//! dispatch overhead the same way the FPGA host amortizes DMA setup.

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::ir::DType;
use crate::metrics::LatencyStats;
use crate::runtime::{ModelArtifact, Runtime, Tensor};

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max requests drained per batch.
    pub max_batch: usize,
    /// Queue capacity before submitters block.
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            queue_depth: 64,
        }
    }
}

struct Request {
    input: Tensor,
    enqueued: Instant,
    reply: mpsc::Sender<Result<Reply>>,
}

/// One served inference.
#[derive(Debug, Clone)]
pub struct Reply {
    pub output: Tensor,
    /// Pure PJRT execute time.
    pub exec_seconds: f64,
    /// Queue + batch + execute time, as the client saw it.
    pub e2e_seconds: f64,
}

/// Aggregate statistics over the server's lifetime.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub served: usize,
    pub batches: usize,
    pub exec: LatencyStats,
    pub e2e: LatencyStats,
}

/// A running server bound to one model variant.
pub struct InferenceServer {
    tx: Option<mpsc::SyncSender<Request>>,
    worker: Option<JoinHandle<(Vec<f64>, Vec<f64>, usize)>>,
    out_dtype: DType,
}

impl InferenceServer {
    /// Start the worker: it creates the PJRT client, compiles the
    /// artifact, reports readiness, then serves. Weights are fixed at
    /// startup (they are part of the served model), so requests carry
    /// only the image tensor.
    pub fn start(art: &ModelArtifact, weights: Vec<Tensor>, cfg: ServerConfig) -> Result<Self> {
        if weights.len() != art.params.len() {
            return Err(anyhow!(
                "expected {} weight tensors, got {}",
                art.params.len(),
                weights.len()
            ));
        }
        let out_dtype = if art.quantization.is_some() {
            DType::I32
        } else {
            DType::F32
        };
        let hlo_path = art.hlo_path.clone();
        let name = art.name.clone();
        let arity = 1 + art.params.len();
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_depth);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let max_batch = cfg.max_batch.max(1);
        let worker = std::thread::spawn(move || {
            let mut exec_samples = Vec::new();
            let mut e2e_samples = Vec::new();
            let mut batches = 0usize;
            // PJRT client + executable live entirely on this thread
            let setup = Runtime::cpu()
                .and_then(|rt| rt.load_hlo_text(&hlo_path, &name, arity).map(|c| (rt, c)));
            let (_rt, compiled) = match setup {
                Ok(pair) => {
                    let _ = ready_tx.send(Ok(()));
                    pair
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return (exec_samples, e2e_samples, batches);
                }
            };
            while let Ok(first) = rx.recv() {
                // drain a micro-batch
                let mut batch = vec![first];
                while batch.len() < max_batch {
                    match rx.try_recv() {
                        Ok(req) => batch.push(req),
                        Err(_) => break,
                    }
                }
                batches += 1;
                for req in batch {
                    let mut inputs = vec![req.input.clone()];
                    inputs.extend(weights.iter().cloned());
                    let result = compiled.run(&inputs, out_dtype).map(|out| {
                        let e2e = req.enqueued.elapsed().as_secs_f64();
                        exec_samples.push(out.exec_seconds);
                        e2e_samples.push(e2e);
                        Reply {
                            output: out.tensor,
                            exec_seconds: out.exec_seconds,
                            e2e_seconds: e2e,
                        }
                    });
                    let _ = req.reply.send(result);
                }
            }
            (exec_samples, e2e_samples, batches)
        });
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(InferenceServer {
                tx: Some(tx),
                worker: Some(worker),
                out_dtype,
            }),
            Ok(Err(e)) => {
                let _ = worker.join();
                Err(e)
            }
            Err(_) => Err(anyhow!("server worker died during startup")),
        }
    }

    pub fn out_dtype(&self) -> DType {
        self.out_dtype
    }

    /// Submit one image and wait for the reply (blocking client call).
    pub fn infer(&self, input: Tensor) -> Result<Reply> {
        let tx = self.tx.as_ref().ok_or_else(|| anyhow!("server stopped"))?;
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(Request {
            input,
            enqueued: Instant::now(),
            reply: reply_tx,
        })
        .map_err(|_| anyhow!("server stopped"))?;
        reply_rx.recv().map_err(|_| anyhow!("server dropped reply"))?
    }

    /// Stop the worker and collect statistics. A worker that died
    /// abnormally yields empty statistics (with a warning) instead of
    /// propagating its panic into the caller.
    pub fn shutdown(mut self) -> ServerStats {
        self.tx.take(); // close the queue; worker loop exits
        match self.worker.take().map(JoinHandle::join) {
            Some(Ok((exec, e2e, batches))) => ServerStats {
                served: exec.len(),
                batches,
                exec: LatencyStats::from_seconds(&exec),
                e2e: LatencyStats::from_seconds(&e2e),
            },
            _ => {
                eprintln!("warning: inference worker exited abnormally; statistics lost");
                ServerStats {
                    served: 0,
                    batches: 0,
                    exec: LatencyStats::from_seconds(&[]),
                    e2e: LatencyStats::from_seconds(&[]),
                }
            }
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{load_golden, Manifest};
    use std::path::Path;

    fn artifacts() -> Option<Manifest> {
        if !crate::runtime::Runtime::available() {
            return None; // stub build: artifacts exist but can't replay
        }
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| Manifest::load(&dir).unwrap())
    }

    #[test]
    fn serves_golden_requests_batched() {
        let Some(manifest) = artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let art = manifest.model("tiny").unwrap();
        let golden = load_golden(art.golden.as_ref().unwrap()).unwrap();
        let server =
            InferenceServer::start(art, golden.params.clone(), ServerConfig::default()).unwrap();
        let n = 12;
        for _ in 0..n {
            let reply = server.infer(golden.input.clone()).unwrap();
            let got = reply.output.as_f32().unwrap();
            let want = golden.expected.as_f32().unwrap();
            for (g, w) in got.iter().zip(want) {
                assert!((g - w).abs() < 1e-5);
            }
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, n);
        assert!(stats.exec.p50_ms > 0.0);
    }

    #[test]
    fn rejects_weight_arity_mismatch() {
        let Some(manifest) = artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let art = manifest.model("tiny").unwrap();
        let err = match InferenceServer::start(art, vec![], ServerConfig::default()) {
            Err(e) => e,
            Ok(_) => panic!("arity mismatch accepted"),
        };
        assert!(err.to_string().contains("weight tensors"));
    }

    #[test]
    fn startup_error_propagates() {
        let Some(manifest) = artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let mut art = manifest.model("tiny").unwrap().clone();
        art.hlo_path = "/nonexistent/x.hlo.txt".into();
        let golden = load_golden(manifest.model("tiny").unwrap().golden.as_ref().unwrap()).unwrap();
        assert!(InferenceServer::start(&art, golden.params, ServerConfig::default()).is_err());
    }

    #[test]
    fn concurrent_clients_all_served() {
        let Some(manifest) = artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let art = manifest.model("tiny").unwrap();
        let golden = load_golden(art.golden.as_ref().unwrap()).unwrap();
        let server = std::sync::Arc::new(
            InferenceServer::start(art, golden.params.clone(), ServerConfig::default()).unwrap(),
        );
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = server.clone();
            let input = golden.input.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..5 {
                    s.infer(input.clone()).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let server = std::sync::Arc::into_inner(server).expect("sole owner");
        let stats = server.shutdown();
        assert_eq!(stats.served, 20);
    }
}
