//! Work-stealing scheduler for sweep-scale fan-out.
//!
//! [`crate::dse::eval::parallel_map`] hands out items from one shared
//! atomic cursor — fine when items are uniform, but a model×device sweep
//! mixes VGG-16-sized candidate grids with AlexNet-sized ones, and at
//! stepped fidelity the spread is ~100x: whoever draws the big item last
//! leaves every other worker idle. This module schedules over per-worker
//! deques instead: each worker drains its own queue from the front and,
//! when empty, steals from the *back* of the fullest victim, so skewed
//! item costs rebalance automatically while results still come back in
//! deterministic input order.
//!
//! The deques are `Mutex<VecDeque>`s, not lock-free Chase-Lev — the
//! items here are whole candidate-chunk evaluations (micro- to
//! milliseconds each), so a mutex pop is noise, and the offline crate
//! set has no `crossbeam` anyway.

use crate::util::sync::locked;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

/// Counters from one [`work_steal_map_seeded`] run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StealStats {
    /// Items executed (always `items.len()`).
    pub executed: usize,
    /// Items a worker took from another worker's deque.
    pub steals: usize,
    /// Workers actually spawned.
    pub workers: usize,
}

/// Apply `f` to every item on up to `workers` work-stealing workers;
/// results come back in input order. Items are dealt round-robin.
pub fn work_steal_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let w = workers.max(1);
    work_steal_map_seeded(items, workers, |i| i % w, f).0
}

/// [`work_steal_map`] with an explicit initial placement: item `i`
/// starts on worker `seed(i) % workers`. Exposed so tests (and callers
/// that know their skew) can control the starting imbalance.
pub fn work_steal_map_seeded<T, R, F, S>(
    items: &[T],
    workers: usize,
    seed: S,
    f: F,
) -> (Vec<R>, StealStats)
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
    S: Fn(usize) -> usize,
{
    if items.is_empty() {
        return (
            Vec::new(),
            StealStats {
                executed: 0,
                steals: 0,
                workers: 0,
            },
        );
    }
    let workers = workers.clamp(1, items.len());
    let queues: Vec<Mutex<VecDeque<usize>>> =
        (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
    for i in 0..items.len() {
        locked(&queues[seed(i) % workers]).push_back(i);
    }
    let steals = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let queues_ref = &queues;
    let steals_ref = &steals;
    let f_ref = &f;
    std::thread::scope(|s| {
        for w in 0..workers {
            let tx = tx.clone();
            s.spawn(move || loop {
                // own deque first (front: the order we were dealt)...
                let own = locked(&queues_ref[w]).pop_front();
                if let Some(i) = own {
                    let _ = tx.send((i, f_ref(&items[i])));
                    continue;
                }
                // ...then steal from the back of the fullest victim
                let mut victim: Option<(usize, usize)> = None; // (len, idx)
                for (v, q) in queues_ref.iter().enumerate() {
                    if v == w {
                        continue;
                    }
                    let len = locked(q).len();
                    if len > victim.map_or(0, |(best, _)| best) {
                        victim = Some((len, v));
                    }
                }
                let Some((_, v)) = victim else {
                    break; // every deque empty: all items claimed
                };
                let stolen = locked(&queues_ref[v]).pop_back();
                if let Some(i) = stolen {
                    steals_ref.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send((i, f_ref(&items[i])));
                }
                // a raced-away victim just rescans
            });
        }
    });
    drop(tx);
    let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    for (i, r) in rx {
        slots[i] = Some(r);
    }
    let results = slots
        .into_iter()
        // analysis: allow(panic, every index is dealt to exactly one deque and executed once; a hole means `f` itself panicked in a worker thread)
        .map(|s| s.expect("work-stealing worker produced result"))
        .collect();
    (
        results,
        StealStats {
            executed: items.len(),
            steals: steals.load(Ordering::Relaxed),
            workers,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Barrier;

    #[test]
    fn preserves_input_order_and_runs_everything() {
        let items: Vec<usize> = (0..57).collect();
        let (out, stats) = work_steal_map_seeded(&items, 4, |i| i % 4, |&i| i * i);
        assert_eq!(out, items.iter().map(|&i| i * i).collect::<Vec<_>>());
        assert_eq!(stats.executed, 57);
        assert_eq!(stats.workers, 4);
        // degenerate shapes
        assert_eq!(work_steal_map(&items, 1, |&i| i + 1).len(), 57);
        assert!(work_steal_map::<usize, usize, _>(&[], 4, |&i| i).is_empty());
        let (single, stats1) = work_steal_map_seeded(&[7usize], 8, |_| 0, |&i| i);
        assert_eq!(single, vec![7]);
        assert_eq!(stats1.workers, 1, "workers clamp to the item count");
    }

    #[test]
    fn idle_workers_steal_from_a_skewed_deque() {
        // deal every item to worker 0; a barrier inside the first four
        // executions forces four *distinct* workers to hold an item at
        // once, which is only possible via stealing — so the skewed
        // deque provably rebalances (≥ 3 steals), deterministically
        let items: Vec<usize> = (0..32).collect();
        let gate = Barrier::new(4);
        let started = AtomicUsize::new(0);
        let (out, stats) = work_steal_map_seeded(&items, 4, |_| 0, |&i| {
            if started.fetch_add(1, Ordering::Relaxed) < 4 {
                gate.wait();
            }
            i + 100
        });
        assert_eq!(out, (100..132).collect::<Vec<usize>>());
        assert!(stats.steals >= 3, "only {} steals", stats.steals);
        assert_eq!(stats.executed, 32);
    }

    #[test]
    fn every_item_runs_exactly_once() {
        let items: Vec<usize> = (0..200).collect();
        let counts: Vec<AtomicUsize> = (0..200).map(|_| AtomicUsize::new(0)).collect();
        let counts_ref = &counts;
        work_steal_map(&items, 6, |&i| {
            counts_ref[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }
}
